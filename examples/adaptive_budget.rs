//! Budget-dynamism probe (Appendix A / Fig. 11): oracle top-p budgets
//! across prompts (tasks), queries, and heads, demonstrating why a
//! single fixed top-k budget cannot fit all of them.
//!
//! ```bash
//! cargo run --release --example adaptive_budget
//! ```

use twilight::evalsuite::distributions::{entropy, final_position_weights, head_budgets};
use twilight::model::retrieval::build_retrieval_model;
use twilight::util::rng::Rng;
use twilight::util::stats::Histogram;
use twilight::workload::{gen_fwe, gen_niah, RetrievalVocab};

fn main() {
    let v = RetrievalVocab::DEFAULT;
    let ctx = 2048;
    let model = build_retrieval_model(v, ctx * 2);
    let p = 0.9f32;
    let mut rng = Rng::new(3);

    println!("oracle top-p (p={p}) budgets over {ctx}-token contexts\n");
    println!("— prompt-wise (task) dynamism —");
    let prompts = [
        ("niah (focused)", gen_niah(&mut rng, v, ctx)),
        ("fwe (diffuse)", gen_fwe(&mut rng, v, ctx, 6.0)),
    ];
    for (name, g) in &prompts {
        let ws = final_position_weights(&model, &g.prompt, 0);
        let budgets = head_budgets(&ws, p);
        let min = budgets.iter().min().unwrap();
        let max = budgets.iter().max().unwrap();
        println!(
            "  {name:<18} per-head budgets {budgets:?}  (min {min}, max {max})"
        );
    }

    println!("\n— head-wise dynamism on one NIAH query —");
    let g = gen_niah(&mut rng, v, ctx);
    let ws = final_position_weights(&model, &g.prompt, 0);
    for (h, w) in ws.iter().enumerate() {
        let b = twilight::pruner::topp::oracle_budget(w, p);
        let kind = if h < 4 { "retrieval " } else { "aggregate " };
        println!(
            "  head {h} ({kind}) budget {:6}  entropy {:6.2} nats",
            b,
            entropy(w)
        );
    }

    println!("\n— query-wise dynamism (budget of retrieval head 0 across 24 queries) —");
    let mut hist = Histogram::new(0.0, 64.0, 16);
    let mut budgets = Vec::new();
    for _ in 0..24 {
        let g = gen_niah(&mut rng, v, ctx);
        let ws = final_position_weights(&model, &g.prompt, 0);
        let b = twilight::pruner::topp::oracle_budget(&ws[0], p);
        hist.add(b as f64);
        budgets.push(b);
    }
    println!("  budgets: {budgets:?}");
    println!("  histogram [0,64): {}", hist.sparkline());
    println!(
        "\nConclusion: any fixed k either over-selects the focused heads or\n\
         starves the diffuse ones — the motivation for top-p (Fig. 1)."
    );
}
