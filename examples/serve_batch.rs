//! End-to-end serving driver (the EXPERIMENTS.md §E2E run): load the
//! retrieval model (TWT artifact if built, else in-process), generate a
//! mixed long-context workload with Poisson arrivals, push it through the
//! full coordinator (queue → continuous batcher → Select-then-Prune
//! engine), and report accuracy + latency/throughput for the dense
//! baseline, the Quest baseline, and Quest+Twilight.
//!
//! ```bash
//! cargo run --release --example serve_batch -- --requests 24 --ctx 4096
//! ```

use std::sync::Arc;
use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::model::weights;
use twilight::selector::SelectorKind;
use twilight::util::cli::Args;
use twilight::util::json::{self, Json};
use twilight::util::rng::Rng;
use twilight::workload::{gen_fwe, gen_niah, poissonize, GenRequest, RetrievalVocab};

fn workload(seed: u64, n: usize, ctx: usize) -> Vec<GenRequest> {
    let v = RetrievalVocab::DEFAULT;
    let mut rng = Rng::new(seed);
    let mut reqs = Vec::new();
    for i in 0..n {
        let mut g = if i % 3 == 2 {
            gen_fwe(&mut rng, v, ctx, 6.0)
        } else {
            gen_niah(&mut rng, v, ctx)
        };
        g.max_new_tokens = 8; // decode a few tokens so TPOT is meaningful
        reqs.push(g);
    }
    poissonize(&mut reqs, seed + 1, 50.0);
    reqs
}

fn run(
    model: Arc<twilight::model::Model>,
    cfg: SparseConfig,
    reqs: &[GenRequest],
    capacity: usize,
    max_batch: usize,
) -> Json {
    let engine = Engine::new(model, cfg.clone(), capacity);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig { max_batch, ..Default::default() },
    );
    for (i, g) in reqs.iter().enumerate() {
        let mut r = Request::new(i as u64, g.prompt.clone(), g.max_new_tokens);
        r.arrival = g.arrival;
        sched.submit(r);
    }
    let report = sched.run_to_completion();
    // Accuracy: first output token vs ground truth.
    let mut correct = 0;
    for f in sched.finished_requests() {
        let want = reqs[f.id as usize].answer;
        if f.output.first() == Some(&want) {
            correct += 1;
        }
    }
    let s = &sched.engine.stats;
    let mut j = report.to_json();
    if let Json::Obj(kv) = &mut j {
        kv.push(("label".into(), json::s(&cfg.label())));
        kv.push(("accuracy".into(), Json::Num(correct as f64 / reqs.len() as f64)));
        kv.push(("avg_budget".into(), Json::Num(s.avg_kept())));
        kv.push(("prune_ratio".into(), Json::Num(s.prune_ratio())));
    }
    j
}

fn main() {
    let a = Args::from_env(&[]);
    let n = a.usize_or("requests", 18);
    let ctx = a.usize_or("ctx", 4096);
    let max_batch = a.usize_or("max-batch", 8);
    let dir = a.str_or("artifacts", "artifacts");
    let model = Arc::new(weights::load_model(&dir, "retrieval").unwrap_or_else(|_| {
        twilight::model::retrieval::build_retrieval_model(RetrievalVocab::DEFAULT, 1 << 17)
    }));
    let reqs = workload(11, n, ctx);
    let capacity = (ctx + 64) * (max_batch + 2);

    println!(
        "serving {n} requests (ctx={ctx}, Poisson arrivals, max_batch={max_batch})\n"
    );
    println!(
        "{:<22} {:>9} {:>12} {:>12} {:>12} {:>11}",
        "pipeline", "accuracy", "tpot-ms", "ttft-ms", "tok/s", "avg-budget"
    );
    let mut results = Vec::new();
    for cfg in [
        SparseConfig::dense(),
        {
            let mut c = SparseConfig::baseline(SelectorKind::Quest, ctx / 4);
            c.skip_layers = 0;
            c
        },
        {
            let mut c = SparseConfig::twilight(SelectorKind::Quest, 0.95);
            c.skip_layers = 0;
            c
        },
    ] {
        let j = run(model.clone(), cfg, &reqs, capacity, max_batch);
        println!(
            "{:<22} {:>9.3} {:>12.2} {:>12.2} {:>12.1} {:>11.1}",
            j.get_str("label").unwrap_or("?"),
            j.get_f64("accuracy").unwrap_or(0.0),
            j.get_f64("tpot_mean_s").unwrap_or(0.0) * 1e3,
            j.get_f64("ttft_mean_s").unwrap_or(0.0) * 1e3,
            j.get_f64("throughput_tok_s").unwrap_or(0.0),
            j.get_f64("avg_budget").unwrap_or(0.0),
        );
        results.push(j);
    }
    let out = Json::Arr(results).pretty();
    let path = format!("{dir}/e2e_report.json");
    if std::fs::write(&path, &out).is_ok() {
        println!("\nwrote {path}");
    }
}
