//! Governed serving: attach the adaptive budget governor to the
//! continuous-batching scheduler, drive a bursty trace through it, and
//! watch the control loop move p / B0 in response to load and memory
//! pressure.
//!
//! ```bash
//! cargo run --release --example governed_serve [-- --policy aimd --slo-tpot-ms 5]
//! ```
//!
//! For a *live* view of the same control loop, run the real server and
//! scrape the always-on observability endpoints over the line protocol
//! (DESIGN.md §10):
//!
//! ```bash
//! cargo run --release -- serve --addr 127.0.0.1:7070 \
//!     --governor aimd --slo-tpot-ms 5 --trace --trace-out trace.json &
//!
//! # Prometheus text (counters, gauges, TTFT/TPOT histograms, …):
//! echo '{"cmd":"metrics"}' | nc 127.0.0.1 7070
//! # Flight recorder: the last N step summaries, as JSON:
//! echo '{"cmd":"dump"}' | nc 127.0.0.1 7070
//! ```
//!
//! `twilight_p_scale` / `twilight_budget_scale` in the scrape are the
//! governor's live directive — the same signals this example prints
//! after the fact; `trace.json` (written at shutdown) opens in
//! Perfetto / `chrome://tracing`.

use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::governor::slo::SloConfig;
use twilight::governor::{Governor, GovernorConfig};
use twilight::model::retrieval::build_retrieval_model;
use twilight::selector::SelectorKind;
use twilight::util::cli::Args;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

fn main() {
    let a = Args::from_env(&[]);
    let policy = a.str_or("policy", "aimd");
    let slo_ms = a.f64_or("slo-tpot-ms", 5.0);
    let ctx = a.usize_or("ctx", 1024);
    let vocab = RetrievalVocab::DEFAULT;

    // 1. Engine with a deliberately tight page pool (bursts must hurt).
    let model = std::sync::Arc::new(build_retrieval_model(vocab, ctx * 2));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    cfg.skip_layers = 0;
    let engine = Engine::new(model, cfg, (ctx + 64) * 5);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig { max_batch: 8, ..Default::default() },
    );

    // 2. The governor: policy + TPOT SLO + default pressure ladder.
    let gcfg = GovernorConfig {
        slo: SloConfig { target_tpot_s: slo_ms / 1e3, ..Default::default() },
        ..Default::default()
    };
    let gov = Governor::new(&policy, gcfg).unwrap_or_else(|| {
        eprintln!("unknown policy '{policy}' (use static, aimd, or mass)");
        std::process::exit(2)
    });
    println!("governor: policy={policy}, slo_tpot={slo_ms}ms");
    sched.attach_governor(gov);

    // 3. A bursty trace: three waves of requests with quiet gaps.
    let mut rng = Rng::new(7);
    let mut id = 0u64;
    for burst in 0..3 {
        for _ in 0..8 {
            let g = gen_niah(&mut rng, vocab, ctx);
            let mut r = Request::new(id, g.prompt, 6);
            r.arrival = burst as f64 * 0.2;
            sched.submit(r);
            id += 1;
        }
    }

    // 4. Serve to completion and replay the governor's decisions.
    let rep = sched.run_to_completion();
    let tpot = rep.tpot_summary();
    println!(
        "\nserved {} requests in {:.2}s: tpot p50={:.2}ms p99={:.2}ms, {} preemptions",
        rep.requests.len(),
        rep.duration,
        tpot.p50 * 1e3,
        tpot.p99 * 1e3,
        rep.preemptions(),
    );
    println!("\ngovernor trace ({} decisions, sampled):", rep.governor.len());
    println!(
        "{:>8} {:>8} {:>9} {:>9} {:>10} {:>10} {:>4}",
        "t-ms", "tpot-ms", "p-scale", "B0-scale", "free-frac", "mass", "deg"
    );
    let stride = (rep.governor.len() / 16).max(1);
    for e in rep.governor.iter().step_by(stride) {
        println!(
            "{:>8.1} {:>8.2} {:>9.2} {:>9.2} {:>10.2} {:>10.2} {:>4}",
            e.t * 1e3,
            e.tpot_ema * 1e3,
            e.p_scale,
            e.budget_scale,
            e.free_frac,
            e.mean_mass,
            e.degrade_level,
        );
    }
    let moved = rep.governor.iter().any(|e| e.p_scale < 1.0 || e.budget_scale < 1.0);
    println!(
        "\nthe loop {}.",
        if moved { "closed: sparsity followed the signals" } else { "stayed neutral (SLO was easy)" }
    );
}
