//! Internal profiling driver for the perf pass (EXPERIMENTS.md §Perf).
use twilight::pruner::{prune_group, PrunerConfig, PrunerScratch};
use twilight::selector::{quest::QuestSelector, TokenSelector};
use std::time::Instant;
fn main() {
    let d = 64; let n = 16384; let group = 4;
    let mut cache = twilight::kvcache::PagedKvCache::new(twilight::kvcache::CacheConfig::new(1, d, n/16+2));
    let mut seq = twilight::kvcache::SeqCache::default();
    let mut r = twilight::util::rng::Rng::new(1);
    for _ in 0..n {
        let k: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0,1.0)).collect();
        cache.append(&mut seq, &k, &k).unwrap();
    }
    let qs: Vec<f32> = (0..group*d).map(|_| r.normal_f32(0.0,2.0)).collect();
    let use_sort = std::env::args().any(|a| a == "--sort");
    let pc = PrunerConfig { p: 0.9, use_sort, ..Default::default() };
    let mut scratch = PrunerScratch::default();
    let mut sel = QuestSelector::new();
    let mut out = vec![0.0f32; group*d];
    let iters = 200;
    let (mut t_sel, mut t_prune, mut t_attn) = (0.0, 0.0, 0.0);
    let mut b1 = 0usize;
    for _ in 0..iters {
        let t0 = Instant::now();
        let cand = sel.select(&cache, &seq, 0, &qs, group, n/4);
        t_sel += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let (kept, _) = prune_group(&pc, &cache, &seq, 0, &qs, group, &cand, &mut scratch);
        t_prune += t0.elapsed().as_secs_f64();
        b1 = kept.len();
        let t0 = Instant::now();
        twilight::attention::sparse::group_varlen(&cache, &seq, 0, &qs, group, &kept, &mut out);
        t_attn += t0.elapsed().as_secs_f64();
    }
    let f = 1e3 / iters as f64;
    println!("select {:.3}ms prune {:.3}ms attend {:.3}ms total {:.3}ms (B0={} B1={b1}, sort={use_sort})",
        t_sel*f, t_prune*f, t_attn*f, (t_sel+t_prune+t_attn)*f, n/4);
}
