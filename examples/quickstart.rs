//! Quickstart: build the retrieval model, wrap Quest in the Twilight
//! pruner, serve one needle-in-a-haystack request, and print what the
//! pipeline did.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;
use twilight::coordinator::engine::Engine;
use twilight::coordinator::SparseConfig;
use twilight::model::retrieval::build_retrieval_model;
use twilight::model::sampler::greedy;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

fn main() {
    let vocab = RetrievalVocab::DEFAULT;
    let ctx = 8192;

    // 1. A model. (Real deployments load TWT weights from `artifacts/`;
    //    the retrieval model can also be constructed in-process.)
    let model = Arc::new(build_retrieval_model(vocab, ctx * 2));
    println!("model: {} ({} params)", model.cfg.name, model.param_count());

    // 2. The paper's pipeline: Quest selects a conservative 1/4-context
    //    candidate set; the Twilight pruner keeps the minimal top-p set.
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    cfg.skip_layers = 0; // single-layer model
    println!("pipeline: {}", cfg.label());

    // 3. An engine with a paged KV pool.
    let mut engine = Engine::new(model, cfg, ctx + 64);

    // 4. One long-context request.
    let mut rng = Rng::new(7);
    let request = gen_niah(&mut rng, vocab, ctx);
    println!("prompt: {} tokens (needle hidden somewhere inside)", request.prompt.len());

    let logits = engine.prefill(0, &request.prompt).expect("out of KV pages");
    let predicted = greedy(&logits);
    println!(
        "answer: token {predicted} — {}",
        if predicted == request.answer { "CORRECT" } else { "WRONG" }
    );

    // 5. What the hierarchy did.
    let s = &engine.stats;
    println!(
        "\nSelect-then-Prune on the final decode step:\n  \
         stage-1 candidates/head: {:8.1}\n  \
         final budget/head:       {:8.1}  ({:.1}% pruned)\n  \
         context length:          {:8}",
        s.avg_candidates(),
        s.avg_kept(),
        s.prune_ratio() * 100.0,
        ctx,
    );
    println!(
        "timing: select {:.2}ms | prune {:.2}ms | attend {:.2}ms",
        s.t_select * 1e3,
        s.t_prune * 1e3,
        s.t_attend * 1e3
    );
}
