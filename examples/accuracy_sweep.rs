//! Accuracy sweep — the Table 2 / Table 3 / Table 6 analog generator.
//!
//! Sweeps every base algorithm at several fixed budgets and with the
//! Twilight pruner, across context lengths, printing one table per
//! context (RULER-style) on the synthetic suite.
//!
//! ```bash
//! cargo run --release --example accuracy_sweep -- --ctxs 1024,4096 --n 4
//! ```

use std::sync::Arc;
use twilight::coordinator::SparseConfig;
use twilight::evalsuite::{render_table, run_accuracy, suite_requests};
use twilight::model::retrieval::build_retrieval_model;
use twilight::selector::SelectorKind;
use twilight::util::cli::Args;
use twilight::workload::RetrievalVocab;

fn main() {
    let a = Args::from_env(&[]);
    let ctxs = a.usize_list_or("ctxs", &[1024, 4096]);
    let n = a.usize_or("n", 4);
    let p = a.f64_or("p", 0.95) as f32;
    let budgets = a.usize_list_or("budgets", &[32, 128, 512]);
    let model = Arc::new(build_retrieval_model(
        RetrievalVocab::DEFAULT,
        *ctxs.iter().max().unwrap() * 2,
    ));
    let selectors = [
        SelectorKind::Quest,
        SelectorKind::DoubleSparsity,
        SelectorKind::StreamingLlm,
        SelectorKind::SnapKv,
        SelectorKind::Oracle,
    ];
    for &ctx in &ctxs {
        let reqs = suite_requests(42, ctx, n);
        let capacity = (ctx + 64) * 2;
        let mut results = vec![run_accuracy(model.clone(), &SparseConfig::dense(), &reqs, capacity)];
        // Full + Twilight (pruner-only row).
        let mut full_twi = SparseConfig::twilight(SelectorKind::Full, p);
        full_twi.skip_layers = 0;
        results.push(run_accuracy(model.clone(), &full_twi, &reqs, capacity));
        for sel in selectors {
            for &b in &budgets {
                let mut c = SparseConfig::baseline(sel, b);
                c.skip_layers = 0;
                results.push(run_accuracy(model.clone(), &c, &reqs, capacity));
            }
            let mut c = SparseConfig::twilight(sel, p);
            c.skip_layers = 0;
            results.push(run_accuracy(model.clone(), &c, &reqs, capacity));
        }
        println!("{}", render_table(&format!("ctx = {ctx}"), &results));
    }
}
