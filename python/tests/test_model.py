"""L2 model tests: shapes, decode-vs-prefill parity, training step, and
the retrieval model's analytic correctness in JAX."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model, retrieval_model, weights_io


def small_cfg():
    cfg = dict(model.CHARLM_CONFIG)
    cfg.update(d_model=32, n_layers=2, n_heads=4, n_kv_heads=4, head_dim=8, d_ff=64)
    return cfg


def test_forward_shapes():
    cfg = small_cfg()
    params = model.init_params(cfg, 0)
    toks = jnp.zeros((2, 16), jnp.int32)
    logits = model.forward_train(params, toks, cfg)
    assert logits.shape == (2, 16, cfg["vocab_size"])
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decode_step_matches_prefill():
    """Teacher-forced decode through the cache must reproduce the causal
    prefill logits position by position."""
    cfg = small_cfg()
    params = model.init_params(cfg, 1)
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg["vocab_size"], 12), jnp.int32)
    want = model.forward_prefill(params, toks, cfg)
    N = 16
    L, Hkv, dh = cfg["n_layers"], cfg["n_kv_heads"], cfg["head_dim"]
    kc = jnp.zeros((L, N, Hkv, dh), jnp.float32)
    vc = jnp.zeros((L, N, Hkv, dh), jnp.float32)
    for pos in range(12):
        logits, k_new, v_new = model.decode_step(
            params, toks[pos], jnp.int32(pos), kc, vc, jnp.int32(pos), cfg
        )
        np.testing.assert_allclose(logits, want[pos], rtol=2e-3, atol=2e-3)
        kc = kc.at[:, pos].set(k_new)
        vc = vc.at[:, pos].set(v_new)


def test_rope_relative_invariance():
    x = jnp.asarray(np.random.default_rng(1).normal(size=(8,)), jnp.float32)
    y = jnp.asarray(np.random.default_rng(2).normal(size=(8,)), jnp.float32)
    def dot_at(p, delta):
        a = model.rope(x[None], jnp.asarray([float(p + delta)]), 10000.0)[0]
        b = model.rope(y[None], jnp.asarray([float(p)]), 10000.0)[0]
        return float(a @ b)
    assert abs(dot_at(0, 7) - dot_at(50, 7)) < 1e-3


def test_training_reduces_loss():
    from compile import train_lm

    msgs = []
    params, stats = train_lm.train(steps=12, batch=4, seqlen=64, log_every=6,
                                   progress=msgs.append)
    losses = stats["train_losses"]
    assert losses[-1] < losses[0], losses
    assert np.isfinite(stats["eval_loss"])


def test_retrieval_model_niah_fwe_in_jax():
    cfg = retrieval_model.RETRIEVAL_CONFIG
    params = retrieval_model.build_params()
    rng = np.random.default_rng(3)
    # Build a NIAH prompt by hand.
    nk, nv = retrieval_model.N_KEYS, retrieval_model.N_VALS
    needle_k, needle_v = 3, 11
    ctx = 96
    toks = []
    for i in range(ctx):
        if i == 40:
            toks.append(retrieval_model.pair(needle_k, needle_v))
        else:
            k = int(rng.integers(nk))
            while k == needle_k:
                k = int(rng.integers(nk))
            toks.append(retrieval_model.pair(k, int(rng.integers(nv))))
    toks.append(retrieval_model.query_niah(needle_k))
    logits = model.forward_prefill(params, jnp.asarray(toks, jnp.int32), cfg)
    pred = int(jnp.argmax(logits[-1]))
    assert pred == retrieval_model.answer(needle_v)


def test_weights_io_roundtrip(tmp_path):
    cfg = small_cfg()
    cfg["name"] = "roundtrip"
    params = model.init_params(cfg, 7)
    weights_io.save_model(str(tmp_path), cfg, params)
    back = weights_io.read_twt(str(tmp_path / "roundtrip.twt"))
    np.testing.assert_array_equal(back["embed"], params["embed"])
    np.testing.assert_array_equal(back["layers.1.wo"], params["layers"][1]["wo"])
    import json

    cfg2 = json.load(open(tmp_path / "roundtrip.json"))
    assert cfg2["d_model"] == cfg["d_model"]


def test_corpus_deterministic_and_copies():
    from compile import corpus

    a = corpus.generate(5, 4096)
    b = corpus.generate(5, 4096)
    np.testing.assert_array_equal(a, b)
    assert a.max() < corpus.VOCAB
    # Long-range copies exist: find at least one repeated 16-gram far apart.
    found = False
    for i in range(200, 4096 - 16):
        window = a[i:i + 16]
        for j in range(0, i - 64):
            if np.array_equal(window, a[j:j + 16]):
                found = True
                break
        if found:
            break
    assert found, "no long-range copy found in corpus"
