"""Pallas kernels vs pure-jnp oracles — the L1 correctness contract.

Hypothesis sweeps shapes and distributions; every property asserts
allclose (or the kernel's documented invariant) against `ref.py`.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import quant, ref, sparse_attn, spgemv, topp

SETTINGS = dict(max_examples=12, deadline=None)


def rand(rng, *shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, size=shape), jnp.float32)


# ---------------------------------------------------------------- spgemv --


@settings(**SETTINGS)
@given(
    n_blocks=st.integers(1, 4),
    d=st.sampled_from([8, 32, 128]),
    bits=st.sampled_from([2, 4, 8]),
    seed=st.integers(0, 10_000),
)
def test_spgemv_matches_ref(n_blocks, d, bits, seed):
    rng = np.random.default_rng(seed)
    N = 64 * n_blocks
    k = rand(rng, 1, N, d)
    q = rand(rng, d)
    codes, s, z = quant.quantize_paged(k, bits, 16)
    got = spgemv.spgemv(q, codes[0], s[0], z[0], block_n=64)
    want = ref.spgemv_ref(q, codes[0], s[0], z[0])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(seed=st.integers(0, 10_000), bits=st.sampled_from([4, 8]))
def test_spgemv_approximates_exact_scores(seed, bits):
    rng = np.random.default_rng(seed)
    N, d = 128, 64
    k = rand(rng, 1, N, d)
    q = rand(rng, d)
    codes, s, z = quant.quantize_paged(k, bits, 16)
    est = spgemv.spgemv(q, codes[0], s[0], z[0], block_n=64)
    exact = k[0] @ q
    err = float(jnp.max(jnp.abs(est - exact)))
    # Error bounded by step/2 * sum|q| (per-element worst case).
    step = float(jnp.max(s))
    bound = 0.5 * step * float(jnp.sum(jnp.abs(q))) + 1e-3
    assert err <= bound, f"err {err} > bound {bound}"


# ------------------------------------------------------------------ topp --


@settings(**SETTINGS)
@given(
    n=st.sampled_from([16, 100, 512]),
    sharp=st.sampled_from([0.3, 2.0, 8.0]),
    p=st.sampled_from([0.5, 0.85, 0.95]),
    seed=st.integers(0, 10_000),
)
def test_topp_mass_and_near_minimality(n, sharp, p, seed):
    rng = np.random.default_rng(seed)
    w = jax.nn.softmax(rand(rng, 4, n, scale=sharp), axis=-1)
    mask = topp.topp_mask(w, p)
    kept_mass = (w * mask).sum(-1)
    assert bool(jnp.all(kept_mass >= p - 1e-3)), kept_mass
    # Compare budget to the sort oracle; ties allow small slack.
    oracle = ref.topp_mask_ref(w, p)
    assert int(mask.sum()) <= int(oracle.sum()) + 4 * w.shape[0]


def test_topp_single_spike():
    w = np.full((1, 128), 1e-4, np.float32)
    w[0, 7] = 1.0
    w /= w.sum()
    mask = topp.topp_mask(jnp.asarray(w), 0.9)
    assert mask[0, 7] == 1.0
    assert int(mask.sum()) == 1


def test_topp_grouped_union():
    rng = np.random.default_rng(0)
    w = jax.nn.softmax(rand(rng, 8, 64, scale=4.0), axis=-1)
    g = topp.topp_mask_grouped(w, 0.8, group=4)
    per_head = topp.topp_mask(w, 0.8)
    # Union property: grouped mask covers each head's own mask.
    assert bool(jnp.all(g >= per_head))
    # And is constant within each group.
    gr = np.asarray(g).reshape(2, 4, 64)
    assert (gr == gr[:, :1]).all()


# --------------------------------------------------------- sparse attention --


@settings(**SETTINGS)
@given(
    hkv=st.sampled_from([1, 2]),
    group=st.sampled_from([1, 4]),
    n=st.sampled_from([32, 256]),
    d=st.sampled_from([16, 32]),
    seed=st.integers(0, 10_000),
)
def test_sparse_attention_matches_ref(hkv, group, n, d, seed):
    rng = np.random.default_rng(seed)
    H = hkv * group
    q = rand(rng, H, d)
    k = rand(rng, hkv, n, d)
    v = rand(rng, hkv, n, d)
    mask = (rng.random((H, n)) < 0.3).astype(np.float32)
    mask[:, 0] = 1.0  # never fully empty
    got = sparse_attn.sparse_attention(q, k, v, jnp.asarray(mask), group)
    want = ref.masked_attention_ref(q, k, v, jnp.asarray(mask))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_sparse_attention_full_mask_equals_dense():
    rng = np.random.default_rng(3)
    q, k, v = rand(rng, 8, 32), rand(rng, 2, 64, 32), rand(rng, 2, 64, 32)
    mask = jnp.ones((8, 64), jnp.float32)
    got = sparse_attn.sparse_attention(q, k, v, mask, 4)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- pipeline --


@settings(**SETTINGS)
@given(p=st.sampled_from([0.7, 0.9, 0.95]), seed=st.integers(0, 10_000))
def test_twilight_pipeline_matches_ref(p, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, 8, 32), rand(rng, 2, 256, 32), rand(rng, 2, 256, 32)
    out, mask = sparse_attn.twilight_attention(q, k, v, p, group=4)
    out_ref, mask_ref = ref.twilight_pipeline_ref(q, k, v, p)
    assert float((mask == mask_ref).mean()) > 0.999
    np.testing.assert_allclose(out, out_ref, rtol=1e-3, atol=1e-4)


def test_pipeline_output_close_to_full_attention():
    # The paper's bound: error <= (1-p)·||V||_F in the attention-weight
    # metric; empirically the pruned output stays close to dense.
    rng = np.random.default_rng(4)
    # Sharpen the queries so the weight distribution is focused (random
    # N(0,1) data is maximally diffuse and top-p correctly keeps ~all).
    q = rand(rng, 8, 32, scale=4.0)
    k, v = rand(rng, 2, 512, 32), rand(rng, 2, 512, 32)
    dense = ref.attention_ref(q, k, v)
    out, mask = sparse_attn.twilight_attention(q, k, v, 0.95, group=4)
    err = float(jnp.max(jnp.abs(out - dense)))
    assert err < 0.35, err
    # And it actually pruned something.
    assert float(mask.mean()) < 0.6, float(mask.mean())
