"""TWT tensor-archive writer (the Rust side reads it in
`rust/src/model/weights.rs`; see that file for the format spec)."""

import json
import struct

import numpy as np

MAGIC = b"TWT1"


def write_twt(path, tensors):
    """tensors: list of (name, np.ndarray f32)."""
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr, dtype=np.float32)
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", 0, arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read_twt(path):
    """Read back (for tests)."""
    out = {}
    with open(path, "rb") as f:
        assert f.read(4) == MAGIC, "bad magic"
        (n,) = struct.unpack("<I", f.read(4))
        for _ in range(n):
            (ln,) = struct.unpack("<I", f.read(4))
            name = f.read(ln).decode()
            dtype, ndim = struct.unpack("<BB", f.read(2))
            assert dtype == 0
            shape = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            numel = int(np.prod(shape)) if ndim else 1
            data = np.frombuffer(f.read(4 * numel), dtype="<f4").reshape(shape)
            out[name] = data
    return out


def params_to_tensors(params):
    """Flatten a charlm-style params dict to TWT (name, array) pairs using
    the Rust naming convention."""
    out = [
        ("embed", params["embed"]),
        ("lm_head", params["lm_head"]),
        ("final_norm", params["final_norm"]),
    ]
    for i, lw in enumerate(params["layers"]):
        for key in ("wq", "wk", "wv", "wo", "w1", "w2", "ln1", "ln2"):
            out.append((f"layers.{i}.{key}", lw[key]))
    return out


def save_model(dirpath, cfg, params):
    """Write `<dir>/<name>.json` + `<dir>/<name>.twt`."""
    name = cfg["name"]
    with open(f"{dirpath}/{name}.json", "w") as f:
        json.dump(cfg, f, indent=2)
    write_twt(f"{dirpath}/{name}.twt", params_to_tensors(params))
