"""Train charlm on the synthetic corpus (build-time, CPU).

Hand-rolled Adam (the image carries jax but not optax). A few hundred
steps on the corpus of `corpus.py` brings held-out perplexity well below
the unigram baseline and, crucially, teaches the copy/induction structure
that gives the attention maps their focused-vs-diffuse dichotomy.

Usage: python -m compile.train_lm [--steps 240] [--out ../artifacts]
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus, model, weights_io


def make_batches(data, batch, seqlen, seed):
    rng = np.random.default_rng(seed)
    n = len(data) - seqlen - 1
    while True:
        idx = rng.integers(0, n, size=batch)
        yield np.stack([data[i:i + seqlen + 1] for i in idx]).astype(np.int32)


def adam_init(params):
    zeros = jax.tree.map(lambda p: jnp.zeros_like(jnp.asarray(p)), params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, zeros), "t": 0}


def train(steps=240, batch=8, seqlen=256, lr=3e-3, seed=0, log_every=20,
          progress=print):
    cfg = model.CHARLM_CONFIG
    train_data, eval_data = corpus.train_eval_corpora(1 << 16, 1 << 14)
    params = jax.tree.map(jnp.asarray, model.init_params(cfg, seed))
    opt = adam_init(params)
    cfg_key = tuple(sorted(cfg.items()))

    @jax.jit
    def update(params, opt, tokens):
        loss, grads = jax.value_and_grad(model._loss_jit)(params, tokens, cfg_key)
        t = opt["t"] + 1
        b1, b2, eps = 0.9, 0.99, 1e-8
        m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, opt["m"], grads)
        v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, opt["v"], grads)
        mh = jax.tree.map(lambda m: m / (1 - b1 ** t), m)
        vh = jax.tree.map(lambda v: v / (1 - b2 ** t), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mh, vh
        )
        return params, {"m": m, "v": v, "t": t}, loss

    batches = make_batches(train_data, batch, seqlen, seed + 1)
    t0 = time.time()
    losses = []
    for step in range(steps):
        tokens = jnp.asarray(next(batches))
        params, opt, loss = update(params, opt, tokens)
        losses.append(float(loss))
        if step % log_every == 0 or step == steps - 1:
            progress(
                f"step {step:4d} loss {float(loss):.4f} "
                f"({time.time() - t0:.0f}s elapsed)"
            )
    # Held-out perplexity on a few eval windows.
    eval_tok = jnp.asarray(
        np.stack([eval_data[i * seqlen:(i + 1) * seqlen + 1] for i in range(8)]).astype(np.int32)
    )
    eval_loss = float(model._loss_jit(params, eval_tok, cfg_key))
    progress(f"eval loss {eval_loss:.4f}  ppl {np.exp(eval_loss):.2f}")
    return jax.tree.map(np.asarray, params), {"train_losses": losses, "eval_loss": eval_loss}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    params, stats = train(steps=args.steps)
    weights_io.save_model(args.out, model.CHARLM_CONFIG, params)
    print(f"saved charlm to {args.out} (eval ppl {np.exp(stats['eval_loss']):.2f})")


if __name__ == "__main__":
    main()
