"""AOT compile path: lower every L2 graph to HLO *text* and write all
build artifacts. Runs ONCE (`make artifacts`); Python never touches the
request path.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5
emits HloModuleProtos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Artifacts written to --out:
  corpus_eval.bin            held-out token stream (PG-19 analog)
  retrieval.{json,twt}       constructed retrieval model
  charlm.{json,twt}          trained charlm (trains if .twt missing)
  charlm_prefill_128.hlo.txt tokens[128] -> logits[128,64]
  charlm_step_512.hlo.txt    decode step against a 512-slot cache
  twilight_attn_1024.hlo.txt L1 pipeline: quant+spgemv+topp+sparse attn
  model.hlo.txt              alias of charlm_prefill_128 (Makefile contract)
  manifest.json              signature index for the Rust runtime
"""

import argparse
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus, model, retrieval_model, weights_io
from .kernels import sparse_attn


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def lower_and_write(fn, specs, path):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {path} ({len(text)} chars)")


def ensure_charlm(out, steps):
    twt = os.path.join(out, "charlm.twt")
    if os.path.exists(twt):
        print(f"charlm weights cached at {twt}")
        raw = weights_io.read_twt(twt)
        params = dict(
            embed=raw["embed"],
            lm_head=raw["lm_head"],
            final_norm=raw["final_norm"],
            layers=[
                {k: raw[f"layers.{i}.{k}"] for k in
                 ("wq", "wk", "wv", "wo", "w1", "w2", "ln1", "ln2")}
                for i in range(model.CHARLM_CONFIG["n_layers"])
            ],
        )
        return params
    from . import train_lm

    print(f"training charlm for {steps} steps ...")
    params, _ = train_lm.train(steps=steps)
    weights_io.save_model(out, model.CHARLM_CONFIG, params)
    return params


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--steps", type=int, default=240)
    ap.add_argument("--skip-train", action="store_true",
                    help="use random charlm weights (CI smoke mode)")
    args = ap.parse_args()
    out = args.out
    os.makedirs(out, exist_ok=True)

    # --- corpora ---------------------------------------------------------
    _, eval_data = corpus.train_eval_corpora(1 << 16, 1 << 14)
    eval_data.tofile(os.path.join(out, "corpus_eval.bin"))
    print(f"wrote corpus_eval.bin ({len(eval_data)} tokens)")

    # --- retrieval model ---------------------------------------------------
    rparams = retrieval_model.build_params()
    weights_io.save_model(out, retrieval_model.RETRIEVAL_CONFIG, rparams)
    print("wrote retrieval.{json,twt}")

    # --- charlm ------------------------------------------------------------
    cfg = model.CHARLM_CONFIG
    if args.skip_train and not os.path.exists(os.path.join(out, "charlm.twt")):
        params = model.init_params(cfg, seed=0)
        weights_io.save_model(out, cfg, params)
        print("wrote charlm (RANDOM weights; --skip-train)")
    else:
        params = ensure_charlm(out, args.steps)
    params = jax.tree.map(jnp.asarray, params)

    # --- HLO graphs ----------------------------------------------------------
    i32 = jnp.int32
    f32 = jnp.float32

    # charlm_prefill_128: tokens[128] -> (logits[128, V],)
    lower_and_write(
        lambda toks: (model.forward_prefill(params, toks, cfg),),
        [jax.ShapeDtypeStruct((128,), i32)],
        os.path.join(out, "charlm_prefill_128.hlo.txt"),
    )

    # charlm_step_512: (tok, pos, cur_len, k_cache, v_cache)
    L, Hkv, dh = cfg["n_layers"], cfg["n_kv_heads"], cfg["head_dim"]
    lower_and_write(
        lambda tok, pos, cur, kc, vc: model.decode_step(
            params, tok, pos, kc, vc, cur, cfg
        ),
        [
            jax.ShapeDtypeStruct((), i32),
            jax.ShapeDtypeStruct((), i32),
            jax.ShapeDtypeStruct((), i32),
            jax.ShapeDtypeStruct((L, 512, Hkv, dh), f32),
            jax.ShapeDtypeStruct((L, 512, Hkv, dh), f32),
        ],
        os.path.join(out, "charlm_step_512.hlo.txt"),
    )

    # twilight_attn_1024: the L1 Pallas pipeline at retrieval geometry.
    rcfg = retrieval_model.RETRIEVAL_CONFIG
    H, rHkv, rdh = rcfg["n_heads"], rcfg["n_kv_heads"], rcfg["head_dim"]
    group = H // rHkv
    lower_and_write(
        lambda q, k, v, p: sparse_attn.twilight_attention(q, k, v, p, group),
        [
            jax.ShapeDtypeStruct((H, rdh), f32),
            jax.ShapeDtypeStruct((rHkv, 1024, rdh), f32),
            jax.ShapeDtypeStruct((rHkv, 1024, rdh), f32),
            jax.ShapeDtypeStruct((), f32),
        ],
        os.path.join(out, "twilight_attn_1024.hlo.txt"),
    )

    # Makefile contract: artifacts/model.hlo.txt.
    shutil.copyfile(
        os.path.join(out, "charlm_prefill_128.hlo.txt"),
        os.path.join(out, "model.hlo.txt"),
    )

    manifest = {
        "charlm_prefill_128": {
            "file": "charlm_prefill_128.hlo.txt",
            "inputs": [["i32", [128]]],
            "outputs": [["f32", [128, cfg["vocab_size"]]]],
        },
        "charlm_step_512": {
            "file": "charlm_step_512.hlo.txt",
            "inputs": [
                ["i32", []], ["i32", []], ["i32", []],
                ["f32", [L, 512, Hkv, dh]], ["f32", [L, 512, Hkv, dh]],
            ],
            "outputs": [
                ["f32", [cfg["vocab_size"]]],
                ["f32", [L, Hkv, dh]],
                ["f32", [L, Hkv, dh]],
            ],
        },
        "twilight_attn_1024": {
            "file": "twilight_attn_1024.hlo.txt",
            "inputs": [
                ["f32", [H, rdh]],
                ["f32", [rHkv, 1024, rdh]],
                ["f32", [rHkv, 1024, rdh]],
                ["f32", []],
            ],
            "outputs": [["f32", [H, rdh]], ["f32", [H, 1024]]],
        },
    }
    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print("wrote manifest.json — artifacts complete")


if __name__ == "__main__":
    main()
