"""L1 Pallas kernels (build-time; interpret=True for CPU PJRT) and their pure-jnp oracles."""
