"""L1 Pallas kernel: masked sparse attention (the final stage of Fig. 5).

One grid step per query head. BlockSpec's index_map implements the GQA
group mapping (query head h reads KV head h // group), so the K/V tiles
are pulled into VMEM once per head without a host-side gather. The softmax
is computed over kept (mask=1) entries only — Definition 3.1 with Λ
restricted to the selected index set.

VMEM footprint per step (N=4096, d=128 f32): K 2 MiB + V 2 MiB + row
vectors — within the 16 MiB VMEM budget; longer contexts use bucketed
artifacts (DESIGN.md §Perf).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, out_ref):
    q = q_ref[...]  # [1, d]
    k = k_ref[...][0]  # [N, d]
    v = v_ref[...][0]
    mask = mask_ref[...]  # [1, N]
    d = q.shape[-1]
    logits = (k @ q[0]) / jnp.sqrt(d).astype(jnp.float32)  # [N]
    logits = jnp.where(mask[0] > 0, logits, NEG_INF)
    m = jnp.max(logits)
    w = jnp.exp(logits - m)
    w = w / jnp.sum(w)
    out_ref[...] = (w @ v)[None, :]


@functools.partial(jax.jit, static_argnames=("group",))
def sparse_attention(q, k, v, mask, group):
    """q: [H, d]; k, v: [Hkv, N, d]; mask: [H, N]. Returns [H, d]."""
    H, d = q.shape
    Hkv, N, _ = k.shape
    assert H == Hkv * group
    return pl.pallas_call(
        _kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1, d), lambda h: (h, 0)),
            pl.BlockSpec((1, N, d), lambda h: (h // group, 0, 0)),
            pl.BlockSpec((1, N, d), lambda h: (h // group, 0, 0)),
            pl.BlockSpec((1, N), lambda h: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((H, d), jnp.float32),
        interpret=True,
    )(q, k, v, mask)


def twilight_attention(q, k, v, p, group, bits=4, page=16):
    """The full L1 pipeline (Fig. 5 with a trivial Full selector):
    INT-quantize K per page → SpGEMV estimation → softmax → top-p binary
    search → GQA-union mask → masked sparse attention.

    Returns (out [H, d], mask [H, N]). This is the graph `aot.py` lowers
    to `twilight_attn_*.hlo.txt` for the Rust PJRT path.
    """
    from . import quant, spgemv, topp

    H, d = q.shape
    codes, scale_row, zero_row = quant.quantize_paged(k, bits=bits, page=page)
    est = spgemv.spgemv_all_heads(q, codes, scale_row, zero_row, group,
                                  block_n=min(256, k.shape[1]))
    est = est / jnp.sqrt(d).astype(jnp.float32)
    w = jax.nn.softmax(est, axis=-1)
    mask = topp.topp_mask_grouped(w, p, group)
    out = sparse_attention(q, k, v, mask, group)
    return out, mask
