"""Pure-jnp oracles for every L1 kernel.

These are the correctness contracts: each Pallas kernel in this package
must match its `*_ref` here (pytest enforces allclose across shape/dtype
sweeps), and the Rust kernels match the same semantics on the other side
of the TWT/HLO interchange.
"""

import jax.numpy as jnp


def quantize_ref(x, bits):
    """Per-array asymmetric quantization.

    Matches rust `tensor::quant::quantize`: scale = (max-min)/(2^b - 1),
    zero = min, code = round((x - zero)/scale) clamped to [0, 2^b - 1].
    Returns (codes int32, scale, zero).
    """
    lo = jnp.min(x)
    hi = jnp.max(x)
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    zero = lo
    codes = jnp.clip(jnp.round((x - zero) / scale), 0, levels).astype(jnp.int32)
    return codes, scale, zero


def dequantize_ref(codes, scale, zero):
    """dequant(code) = zero + code * scale."""
    return zero + codes.astype(jnp.float32) * scale


def spgemv_ref(q, codes, scale_row, zero_row):
    """Estimated scores: out[i] = zero_row[i]*sum(q) + scale_row[i]*(codes[i]·q).

    q: [d]; codes: [N, d] int; scale_row/zero_row: [N] per-row quant params
    (page-level params expanded per row).
    """
    qsum = jnp.sum(q)
    code_dot = codes.astype(jnp.float32) @ q
    return zero_row * qsum + scale_row * code_dot


def topp_mask_ref(w, p):
    """Oracle top-p mask: minimal descending-prefix with mass >= p.

    w: [..., N] normalized along the last axis. Returns float mask, 1.0
    for kept entries. Ties at the threshold weight are kept (matching the
    binary-search kernel, which thresholds by value).
    """
    order = jnp.argsort(-w, axis=-1)
    sorted_w = jnp.take_along_axis(w, order, axis=-1)
    csum = jnp.cumsum(sorted_w, axis=-1)
    # Number of entries needed: first index where csum >= p.
    needed = jnp.sum((csum < p).astype(jnp.int32), axis=-1, keepdims=True) + 1
    needed = jnp.minimum(needed, w.shape[-1])
    # Threshold weight = the needed-th largest value; keep w >= threshold.
    thresh = jnp.take_along_axis(sorted_w, needed - 1, axis=-1)
    return (w >= thresh).astype(jnp.float32)


def attention_ref(q, k, v):
    """Dense single-query attention. q: [H, d]; k, v: [Hkv, N, d] (GQA:
    head h uses kv head h // (H // Hkv)). Returns [H, d]."""
    H, d = q.shape
    Hkv = k.shape[0]
    group = H // Hkv
    outs = []
    for h in range(H):
        kh = k[h // group]
        vh = v[h // group]
        logits = kh @ q[h] / jnp.sqrt(d).astype(jnp.float32)
        wts = jnp.exp(logits - jnp.max(logits))
        wts = wts / jnp.sum(wts)
        outs.append(wts @ vh)
    return jnp.stack(outs)


def masked_attention_ref(q, k, v, mask):
    """Sparse (masked) attention. mask: [H, N] with 1.0 = keep. Softmax is
    computed over kept entries only (Definition 3.1)."""
    H, d = q.shape
    Hkv = k.shape[0]
    group = H // Hkv
    outs = []
    for h in range(H):
        kh = k[h // group]
        vh = v[h // group]
        logits = kh @ q[h] / jnp.sqrt(d).astype(jnp.float32)
        logits = jnp.where(mask[h] > 0, logits, -jnp.inf)
        m = jnp.max(logits)
        wts = jnp.exp(logits - m)
        wts = wts / jnp.sum(wts)
        outs.append(wts @ vh)
    return jnp.stack(outs)


def twilight_pipeline_ref(q, k, v, p, bits=4, page=16):
    """End-to-end Select(Full)-then-Prune reference: estimate scores from
    a per-(kv-head, page) quantized K, softmax per query head, top-p mask
    (union over the GQA group), masked attention. Returns (out, mask)."""
    H, d = q.shape
    Hkv, N, _ = k.shape
    group = H // Hkv
    masks = []
    for h in range(H):
        kh = k[h // group]
        # Per-page quantization of this kv head's K.
        scores = []
        for p0 in range(0, N, page):
            blk = kh[p0:p0 + page]
            codes, scale, zero = quantize_ref(blk, bits)
            scores.append(
                spgemv_ref(
                    q[h],
                    codes,
                    jnp.full((blk.shape[0],), scale),
                    jnp.full((blk.shape[0],), zero),
                )
            )
        est = jnp.concatenate(scores) / jnp.sqrt(d).astype(jnp.float32)
        w = jnp.exp(est - jnp.max(est))
        w = w / jnp.sum(w)
        masks.append(topp_mask_ref(w, p))
    mask = jnp.stack(masks)
    # GQA union within each group.
    mask = mask.reshape(Hkv, group, N).max(axis=1, keepdims=True)
    mask = jnp.broadcast_to(mask, (Hkv, group, N)).reshape(H, N)
    return masked_attention_ref(q, k, v, mask), mask
