"""Paged INT-quantization of the K cache (jnp, build-time).

Produces the mirror-cache representation the SpGEMV kernel consumes:
per-(kv-head, page) asymmetric codes + scale/zero, with the params
expanded to per-row vectors so the kernel blocks stay rectangular.
"""

import jax.numpy as jnp

from . import ref


def quantize_paged(k, bits=4, page=16):
    """Quantize k: [Hkv, N, d] into (codes int32 [Hkv, N, d],
    scale_row [Hkv, N], zero_row [Hkv, N]) with one (scale, zero) per
    (kv head, page) group — the paper's per-head dynamic quantization at
    Quest's page granularity."""
    Hkv, N, d = k.shape
    assert N % page == 0, "context must be page-aligned (pad first)"
    blk = k.reshape(Hkv, N // page, page * d)
    lo = blk.min(axis=-1, keepdims=True)
    hi = blk.max(axis=-1, keepdims=True)
    levels = (1 << bits) - 1
    scale = jnp.where(hi > lo, (hi - lo) / levels, 1.0)
    zero = lo
    codes = jnp.clip(jnp.round((blk - zero) / scale), 0, levels).astype(jnp.int32)
    codes = codes.reshape(Hkv, N, d)
    scale_row = jnp.repeat(scale[..., 0], page, axis=-1)
    zero_row = jnp.repeat(zero[..., 0], page, axis=-1)
    return codes, scale_row, zero_row


def dequantize_paged(codes, scale_row, zero_row):
    """Inverse of `quantize_paged` (up to quantization error)."""
    return zero_row[..., None] + codes.astype(jnp.float32) * scale_row[..., None]


def quantization_error(k, bits, page=16):
    """Max |k - dequant(quant(k))| — used by the Fig. 6 precision sweep."""
    c, s, z = quantize_paged(k, bits, page)
    return jnp.max(jnp.abs(k - dequantize_paged(c, s, z)))


__all__ = ["quantize_paged", "dequantize_paged", "quantization_error", "ref"]
