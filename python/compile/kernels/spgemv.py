"""L1 Pallas kernel: score-estimation SpGEMV over the INT4 mirror K cache
(paper Appendix B.1).

TPU adaptation of the paper's CUDA kernel (DESIGN.md §Hardware-Adaptation):
the CUDA version unpacks INT4 in shared memory with cp.async double
buffering; here BlockSpec expresses the HBM→VMEM schedule — each grid step
pulls one (BN × d) tile of codes plus its per-row scale/zero into VMEM,
dequantizes in-register via the scale/zero identity, and contracts against
the resident query vector. Block sizes keep the VMEM footprint under
256 KiB (BN=256, d=128: codes f32 tile 128 KiB + rows 2 KiB).

Runs under interpret=True on CPU (real-TPU lowering would emit a Mosaic
custom-call the CPU PJRT plugin cannot execute).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256


def _kernel(q_ref, codes_ref, scale_ref, zero_ref, out_ref):
    q = q_ref[...]  # [d]
    codes = codes_ref[...].astype(jnp.float32)  # [BN, d]
    # dot(q, zero + code*scale) = zero*sum(q) + scale*dot(q, code):
    # dequantization never materializes the fp K tile.
    qsum = jnp.sum(q)
    code_dot = codes @ q
    out_ref[...] = zero_ref[...] * qsum + scale_ref[...] * code_dot


@functools.partial(jax.jit, static_argnames=("block_n",))
def spgemv(q, codes, scale_row, zero_row, block_n=DEFAULT_BLOCK_N):
    """Estimated scores q·K̂ᵀ for one head.

    q: [d] f32; codes: [N, d] int32 (unsigned codes); scale_row/zero_row:
    [N] per-row quant params. N must be a multiple of block_n (pad with
    zero rows — they dequantize to `zero` and are cheap to ignore
    downstream). Returns [N] f32.
    """
    N, d = codes.shape
    assert N % block_n == 0, f"N={N} not a multiple of block_n={block_n}"
    grid = (N // block_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),  # q resident across steps
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
            pl.BlockSpec((block_n,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N,), jnp.float32),
        interpret=True,
    )(q, codes, scale_row, zero_row)


def spgemv_all_heads(q, codes, scale_row, zero_row, group, block_n=DEFAULT_BLOCK_N):
    """Vectorized over query heads: q [H, d], codes [Hkv, N, d],
    scale/zero [Hkv, N]; head h uses kv head h // group. Returns [H, N]."""
    H = q.shape[0]
    outs = [
        spgemv(q[h], codes[h // group], scale_row[h // group], zero_row[h // group],
               block_n=block_n)
        for h in range(H)
    ]
    return jnp.stack(outs)
