"""L1 Pallas kernel: top-p selection via binary search (paper Algorithm 1).

One grid step per head; the head's normalized weight row lives in VMEM and
the fixed-trip binary search runs as a fori_loop whose body is a single
fused elementwise pass (`where`/`sum` tensorized — exactly the fusion the
paper's GPU kernel performs; the intermediate W0/W1/W2 of the listing are
never materialized). 24 iterations bisect the threshold to ~max(w)/2^24,
far below any epsilon of interest.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ITERS = 24


def _kernel(w_ref, p_ref, mask_ref):
    w = w_ref[...]  # [1, N]
    p = p_ref[0, 0]

    def body(_, lr):
        l, r = lr
        m = 0.5 * (l + r)
        mass = jnp.sum(jnp.where(w >= m, w, 0.0))
        ge = mass >= p
        return (jnp.where(ge, m, l), jnp.where(ge, r, m))

    l, _ = jax.lax.fori_loop(0, ITERS, body, (jnp.float32(0.0), jnp.max(w)))
    mask_ref[...] = (w >= l).astype(jnp.float32)


@jax.jit
def topp_mask(w, p):
    """Top-p keep mask. w: [H, N] softmax-normalized rows; p: scalar.
    Returns float mask [H, N]: 1.0 for kept weights; kept mass >= p
    (invariant: l only moves to thresholds whose at-or-above mass >= p)."""
    H, N = w.shape
    p_arr = jnp.full((1, 1), p, jnp.float32)
    return pl.pallas_call(
        _kernel,
        grid=(H,),
        in_specs=[
            pl.BlockSpec((1, N), lambda h: (h, 0)),
            pl.BlockSpec((1, 1), lambda h: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, N), lambda h: (h, 0)),
        out_shape=jax.ShapeDtypeStruct((H, N), jnp.float32),
        interpret=True,
    )(w, p_arr)


@functools.partial(jax.jit, static_argnames=("group",))
def topp_mask_grouped(w, p, group):
    """Top-p per query head followed by the GQA group union (paper B.2):
    the final mask is shared by the group so the attention kernel loads
    each KV row once. w: [H, N]."""
    mask = topp_mask(w, p)
    H, N = w.shape
    hkv = H // group
    grouped = mask.reshape(hkv, group, N).max(axis=1, keepdims=True)
    return jnp.broadcast_to(grouped, (hkv, group, N)).reshape(H, N)
