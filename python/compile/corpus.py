"""Deterministic synthetic pseudo-language corpus (the PG-19 analog).

Structure chosen so that a small trained LM exhibits the attention-weight
phenomenology the paper studies:

* a seeded order-2 "letter" Markov chain gives local statistics that
  dense local attention learns quickly (→ diffuse/local heads);
* with probability `copy_prob`, the generator emits a verbatim *copy* of
  an earlier span — long-range structure that is only predictable by
  attending far back to a handful of tokens (→ focused retrieval heads,
  the induction pattern).

Vocabulary: 64 token ids. The same generator (same seed) produces the
train and the held-out eval corpora (disjoint seeds), and `aot.py` dumps
the eval stream to `artifacts/corpus_eval.bin` (raw u8) for the Rust
perplexity harness.
"""

import numpy as np

VOCAB = 64


def make_transition(seed: int) -> np.ndarray:
    """Sparse-ish order-1 transition matrix over VOCAB tokens."""
    rng = np.random.default_rng(seed)
    # Each token prefers ~6 successors heavily, with smoothing.
    T = rng.gamma(0.08, 1.0, size=(VOCAB, VOCAB))
    for i in range(VOCAB):
        hot = rng.choice(VOCAB, size=6, replace=False)
        T[i, hot] += rng.gamma(4.0, 1.0, size=6)
    T /= T.sum(axis=1, keepdims=True)
    return T


def generate(seed: int, length: int, copy_prob: float = 0.02,
             copy_len_lo: int = 16, copy_len_hi: int = 64) -> np.ndarray:
    """Generate `length` tokens (uint8)."""
    rng = np.random.default_rng(seed + 1)
    T = make_transition(1234)  # shared dynamics across train/eval
    out = np.empty(length, dtype=np.uint8)
    out[0] = rng.integers(VOCAB)
    i = 1
    while i < length:
        if i > 2 * copy_len_hi and rng.random() < copy_prob:
            # Copy an earlier span verbatim.
            span = int(rng.integers(copy_len_lo, copy_len_hi))
            start = int(rng.integers(0, i - span))
            span = min(span, length - i)
            out[i:i + span] = out[start:start + span]
            i += span
        else:
            out[i] = rng.choice(VOCAB, p=T[out[i - 1]])
            i += 1
    return out


def train_eval_corpora(train_len: int, eval_len: int):
    """The canonical corpora: disjoint seeds, shared dynamics."""
    return generate(17, train_len), generate(9999, eval_len)


if __name__ == "__main__":
    tr, ev = train_eval_corpora(1 << 16, 1 << 14)
    print(f"train {tr.shape} eval {ev.shape}; head: {tr[:16].tolist()}")
