"""Constructed-weights retrieval model — python mirror of
`rust/src/model/retrieval.rs` (same constants, same channel layout).
The Rust integration test `weights_parity` loads the TWT written here and
asserts exact equality with the Rust-built weights.
"""

import numpy as np

N_KEYS = 16
N_VALS = 16
BETA = 90.0
SELF_SUPPRESS = 10.0
FWE_GAIN = 17.0
ALPHA_R = 4.0
ALPHA_F = 1.0

CH_KEY = 0
CH_VAL = 16
CH_IS_PAIR = 32
CH_IS_QNIAH = 33
CH_IS_QFWE = 34
CH_OUT = 48

RETRIEVAL_CONFIG = dict(
    name="retrieval",
    vocab_size=N_KEYS * N_VALS + N_KEYS + 1 + N_VALS,
    d_model=64,
    n_layers=1,
    n_heads=8,
    n_kv_heads=2,
    head_dim=32,
    d_ff=4,
    use_rope=False,
    rope_theta=10000.0,
    use_norm=False,
    norm_eps=1e-5,
    max_ctx=131072,
)


def pair(k, v):
    return k * N_VALS + v


def query_niah(k):
    return N_KEYS * N_VALS + k


def query_fwe():
    return N_KEYS * N_VALS + N_KEYS


def answer(v):
    return N_KEYS * N_VALS + N_KEYS + 1 + v


def build_params():
    cfg = RETRIEVAL_CONFIG
    d = cfg["d_model"]
    dh = cfg["head_dim"]
    V = cfg["vocab_size"]
    qd = cfg["n_heads"] * dh
    kvd = cfg["n_kv_heads"] * dh

    embed = np.zeros((V, d), np.float32)
    for k in range(N_KEYS):
        for v in range(N_VALS):
            row = pair(k, v)
            embed[row, CH_KEY + k] = 1.0
            embed[row, CH_VAL + v] = 1.0
            embed[row, CH_IS_PAIR] = 1.0
        embed[query_niah(k), CH_KEY + k] = 1.0
        embed[query_niah(k), CH_IS_QNIAH] = 1.0
    embed[query_fwe(), CH_IS_QFWE] = 1.0
    for v in range(N_VALS):
        embed[answer(v), CH_VAL + v] = 1.0

    wq = np.zeros((qd, d), np.float32)
    for h in range(4):
        for i in range(N_KEYS):
            wq[h * dh + i, CH_KEY + i] = BETA
    for h in range(4, 8):
        wq[h * dh, CH_IS_QFWE] = FWE_GAIN

    wk = np.zeros((kvd, d), np.float32)
    for i in range(N_KEYS):
        wk[i, CH_KEY + i] = 1.0
        wk[i, CH_IS_QNIAH] = -SELF_SUPPRESS
    wk[dh, CH_IS_PAIR] = 1.0

    wv = np.zeros((kvd, d), np.float32)
    for i in range(N_VALS):
        wv[i, CH_VAL + i] = 1.0
        wv[dh + i, CH_VAL + i] = 1.0

    wo = np.zeros((d, qd), np.float32)
    for h in range(8):
        gain = ALPHA_R / 4.0 if h < 4 else ALPHA_F / 4.0
        for i in range(N_VALS):
            wo[CH_OUT + i, h * dh + i] = gain

    lm_head = np.zeros((V, d), np.float32)
    for v in range(N_VALS):
        lm_head[answer(v), CH_OUT + v] = 1.0

    layer = dict(
        wq=wq,
        wk=wk,
        wv=wv,
        wo=wo,
        w1=np.zeros((cfg["d_ff"], d), np.float32),
        w2=np.zeros((d, cfg["d_ff"]), np.float32),
        ln1=np.ones(d, np.float32),
        ln2=np.ones(d, np.float32),
    )
    return dict(
        embed=embed,
        lm_head=lm_head,
        final_norm=np.ones(d, np.float32),
        layers=[layer],
    )
