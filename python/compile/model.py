"""L2: the JAX model — charlm's forward pass (training + decode), kept in
exact correspondence with the Rust-native forward (`rust/src/model/mod.rs`):
same RoPE pairing, RMSNorm, tanh-GELU, and projection layouts (weights are
`[out, in]`, applied as `h @ W.T`). `rust/tests/hlo_parity.rs` asserts the
two agree through the HLO interchange.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

CHARLM_CONFIG = dict(
    name="charlm",
    vocab_size=64,
    d_model=128,
    n_layers=4,
    n_heads=8,
    n_kv_heads=8,
    head_dim=16,
    d_ff=512,
    use_rope=True,
    rope_theta=10000.0,
    use_norm=True,
    norm_eps=1e-5,
    max_ctx=2048,
)


def init_params(cfg, seed=0):
    """Initialize charlm parameters (numpy, f32)."""
    rng = np.random.default_rng(seed)
    d = cfg["d_model"]
    qd = cfg["n_heads"] * cfg["head_dim"]
    kvd = cfg["n_kv_heads"] * cfg["head_dim"]

    def w(shape, std):
        return rng.normal(0.0, std, size=shape).astype(np.float32)

    std = 0.02
    layers = []
    for _ in range(cfg["n_layers"]):
        layers.append(
            dict(
                wq=w((qd, d), std),
                wk=w((kvd, d), std),
                wv=w((kvd, d), std),
                wo=w((d, qd), std / np.sqrt(2 * cfg["n_layers"])),
                w1=w((cfg["d_ff"], d), std),
                w2=w((d, cfg["d_ff"]), std / np.sqrt(2 * cfg["n_layers"])),
                ln1=np.ones(d, np.float32),
                ln2=np.ones(d, np.float32),
            )
        )
    return dict(
        embed=w((cfg["vocab_size"], d), 0.5),
        lm_head=w((cfg["vocab_size"], d), std),
        final_norm=np.ones(d, np.float32),
        layers=layers,
    )


def rmsnorm(x, w, eps):
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * w / jnp.sqrt(ms + eps)


def rope(x, pos, theta):
    """x: [..., d] with pairs (2i, 2i+1); pos broadcastable to x[..., 0]."""
    d = x.shape[-1]
    i = jnp.arange(d // 2, dtype=jnp.float32)
    freq = theta ** (-2.0 * i / d)  # [d/2]
    ang = pos[..., None] * freq  # [..., d/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x2 = x.reshape(x.shape[:-1] + (d // 2, 2))
    a, b = x2[..., 0], x2[..., 1]
    rot = jnp.stack([a * cos - b * sin, a * sin + b * cos], axis=-1)
    return rot.reshape(x.shape)


def _split_heads(x, n_heads, head_dim):
    return x.reshape(x.shape[:-1] + (n_heads, head_dim))


def forward_train(params, tokens, cfg):
    """Full-sequence causal forward. tokens: [B, S] int32 → logits [B, S, V]."""
    B, S = tokens.shape
    d = cfg["d_model"]
    H, Hkv, dh = cfg["n_heads"], cfg["n_kv_heads"], cfg["head_dim"]
    group = H // Hkv
    x = jnp.asarray(params["embed"])[tokens]  # [B, S, d]
    pos = jnp.arange(S, dtype=jnp.float32)[None, :]  # [1, S]
    causal = jnp.tril(jnp.ones((S, S), bool))
    for lw in params["layers"]:
        h = rmsnorm(x, lw["ln1"], cfg["norm_eps"]) if cfg["use_norm"] else x
        q = _split_heads(h @ jnp.asarray(lw["wq"]).T, H, dh)  # [B,S,H,dh]
        k = _split_heads(h @ jnp.asarray(lw["wk"]).T, Hkv, dh)
        v = _split_heads(h @ jnp.asarray(lw["wv"]).T, Hkv, dh)
        if cfg["use_rope"]:
            q = rope(q, jnp.broadcast_to(pos[..., None], (B, S, H)), cfg["rope_theta"])
            k = rope(k, jnp.broadcast_to(pos[..., None], (B, S, Hkv)), cfg["rope_theta"])
        # GQA: expand kv heads to query heads.
        k_exp = jnp.repeat(k, group, axis=2)  # [B,S,H,dh]
        v_exp = jnp.repeat(v, group, axis=2)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k_exp) / jnp.sqrt(dh)
        logits = jnp.where(causal[None, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1)
        attn = jnp.einsum("bhqk,bkhd->bqhd", w, v_exp).reshape(B, S, H * dh)
        x = x + attn @ jnp.asarray(lw["wo"]).T
        h = rmsnorm(x, lw["ln2"], cfg["norm_eps"]) if cfg["use_norm"] else x
        x = x + jax.nn.gelu(h @ jnp.asarray(lw["w1"]).T, approximate=True) @ jnp.asarray(lw["w2"]).T
    if cfg["use_norm"]:
        x = rmsnorm(x, params["final_norm"], cfg["norm_eps"])
    return x @ jnp.asarray(params["lm_head"]).T


def forward_prefill(params, tokens, cfg):
    """Single-sequence causal forward: tokens [S] → logits [S, V]. The
    graph exported as `charlm_prefill_*.hlo.txt`."""
    return forward_train(params, tokens[None], cfg)[0]


def decode_step(params, tok, pos, k_cache, v_cache, cur_len, cfg):
    """One decode step against a fixed-capacity cache (the HLO decode
    graph). tok, pos, cur_len: int32 scalars; k_cache/v_cache:
    [L, N, Hkv, dh] with rows >= cur_len undefined. Returns
    (logits [V], k_new [L, Hkv, dh], v_new [L, Hkv, dh])."""
    d = cfg["d_model"]
    H, Hkv, dh = cfg["n_heads"], cfg["n_kv_heads"], cfg["head_dim"]
    group = H // Hkv
    N = k_cache.shape[1]
    x = jnp.asarray(params["embed"])[tok]  # [d]
    posf = jnp.asarray(pos, jnp.float32)
    valid = jnp.arange(N) < cur_len  # [N]
    k_news, v_news = [], []
    for li, lw in enumerate(params["layers"]):
        h = rmsnorm(x, lw["ln1"], cfg["norm_eps"]) if cfg["use_norm"] else x
        q = (h @ jnp.asarray(lw["wq"]).T).reshape(H, dh)
        k = (h @ jnp.asarray(lw["wk"]).T).reshape(Hkv, dh)
        v = (h @ jnp.asarray(lw["wv"]).T).reshape(Hkv, dh)
        if cfg["use_rope"]:
            q = rope(q, jnp.broadcast_to(posf, (H,)), cfg["rope_theta"])
            k = rope(k, jnp.broadcast_to(posf, (Hkv,)), cfg["rope_theta"])
        k_news.append(k)
        v_news.append(v)
        kc = k_cache[li]  # [N, Hkv, dh]
        vc = v_cache[li]
        outs = []
        for hh in range(H):
            kvh = hh // group
            logits = kc[:, kvh] @ q[hh] / jnp.sqrt(dh)  # [N]
            logits = jnp.where(valid, logits, -1e30)
            self_logit = jnp.dot(k[kvh], q[hh]) / jnp.sqrt(dh)
            all_logits = jnp.concatenate([logits, self_logit[None]])
            w = jax.nn.softmax(all_logits)
            out = w[:-1] @ vc[:, kvh] + w[-1] * v[kvh]
            outs.append(out)
        attn = jnp.concatenate(outs)
        x = x + attn @ jnp.asarray(lw["wo"]).T
        h = rmsnorm(x, lw["ln2"], cfg["norm_eps"]) if cfg["use_norm"] else x
        x = x + jax.nn.gelu(h @ jnp.asarray(lw["w1"]).T, approximate=True) @ jnp.asarray(lw["w2"]).T
    if cfg["use_norm"]:
        x = rmsnorm(x, params["final_norm"], cfg["norm_eps"])
    logits = x @ jnp.asarray(params["lm_head"]).T
    return logits, jnp.stack(k_news), jnp.stack(v_news)


@functools.partial(jax.jit, static_argnames=("cfg_key",))
def _loss_jit(params, tokens, cfg_key):
    cfg = dict(cfg_key)
    logits = forward_train(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def loss_fn(params, tokens, cfg):
    """Mean next-token NLL over a [B, S] batch."""
    return _loss_jit(params, tokens, tuple(sorted(cfg.items())))
