//! Zero-allocation contract of the pruned-attention hot path.
//!
//! A counting global allocator wraps `System` and tallies every
//! `alloc`/`alloc_zeroed`/`realloc`. The single test (one `#[test]` so no
//! concurrent test pollutes the counter) then pins, in order:
//!
//! 1. the select → prune → attend work unit performs **zero** heap
//!    allocations once its `AttnScratch` arena is warm — in the default
//!    pipeline *and* in hier-pages mode;
//! 2. a warmed engine's decode steps allocate a **constant** amount
//!    (step-scoped bookkeeping only): consecutive mid-page steps count
//!    identically, and
//! 3. the per-step count is **independent of context length** — a 2×
//!    longer context (2× the candidates per pruned call) changes no
//!    count, proving nothing per-candidate escapes the arena.
//!
//! Steps that cross a page boundary are excluded on purpose: sealing a
//! page legitimately quantizes a fresh mirror block (one allocation per
//! 16 tokens — amortized, not per-call), and the recall probe
//! (1 per 64 sparse calls) legitimately allocates its dense re-score.
//!
//! 4. span tracing holds the same contract: with `TWILIGHT_TRACE`-style
//!    recording enabled, a warmed engine's decode steps allocate exactly
//!    what they do with tracing off — each span event is four atomic
//!    stores into a pre-sized per-thread ring (the ring itself is one
//!    warm-up allocation).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use twilight::attention::sparse::group_varlen_with;
use twilight::coordinator::engine::Engine;
use twilight::coordinator::SparseConfig;
use twilight::kvcache::{CacheConfig, PagedKvCache, SeqCache};
use twilight::model::retrieval::build_retrieval_model;
use twilight::pruner::{prune_group_into, AttnScratch, PrunerConfig};
use twilight::selector::quest::QuestSelector;
use twilight::selector::{SelectorKind, TokenSelector};
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

fn allocs() -> u64 {
    ALLOCS.load(Ordering::SeqCst)
}

/// One select → prune → attend work unit, borrowing buffers exactly the
/// way the engine does (take/restore around the pruner call).
fn work_unit(
    cache: &PagedKvCache,
    seq: &SeqCache,
    q: &[f32],
    cfg: &PrunerConfig,
    selector: &mut QuestSelector,
    scratch: &mut AttnScratch,
    out: &mut [f32],
) -> usize {
    let mut cands = std::mem::take(&mut scratch.candidates);
    selector.select_into(cache, seq, 0, q, 1, 128, &mut cands);
    prune_group_into(cfg, cache, seq, 0, q, 1, &cands, scratch);
    let kept = std::mem::take(&mut scratch.union);
    group_varlen_with(
        cache,
        seq,
        0,
        q,
        1,
        &kept,
        &mut scratch.attn_m,
        &mut scratch.attn_denom,
        out,
    );
    let n = kept.len();
    scratch.union = kept;
    scratch.candidates = cands;
    n
}

fn prune_unit_is_zero_alloc(cfg: &PrunerConfig, label: &str) {
    let d = 32;
    let mut cache = PagedKvCache::new(CacheConfig::new(1, d, 40));
    let mut seq = SeqCache::default();
    let mut r = Rng::new(42);
    for _ in 0..512 {
        let k: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        cache.append(&mut seq, &k, &k).unwrap();
    }
    let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
    let mut selector = QuestSelector::new();
    let mut scratch = AttnScratch::default();
    let mut out = vec![0.0f32; d];
    // Warm the arena (two rounds: first grows buffers, second proves the
    // shapes repeat).
    for _ in 0..3 {
        let kept = work_unit(&cache, &seq, &q, cfg, &mut selector, &mut scratch, &mut out);
        assert!(kept > 0, "{label}: the unit must actually keep tokens");
    }
    let before = allocs();
    for _ in 0..100 {
        work_unit(&cache, &seq, &q, cfg, &mut selector, &mut scratch, &mut out);
    }
    let delta = allocs() - before;
    assert_eq!(
        delta, 0,
        "{label}: steady-state select→prune→attend must not allocate \
         (got {delta} allocations over 100 calls)"
    );
}

/// Decode one token and return how many allocations the step performed.
fn step_allocs(e: &mut Engine, tok: u32) -> u64 {
    let before = allocs();
    let _ = e.decode(0, tok).unwrap();
    allocs() - before
}

/// Build a warmed single-sequence engine at the given prompt length:
/// threads=1 (the sequential reference path — no pool wakeups in the
/// count), sparse from 16 tokens, 3 warm decode steps.
fn warmed_engine(ctx: usize) -> (Engine, u32) {
    let model = std::sync::Arc::new(build_retrieval_model(RetrievalVocab::DEFAULT, 1 << 13));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let mut e = Engine::new(model, cfg, 1 << 13);
    e.set_threads(1);
    let mut r = Rng::new(7);
    let g = gen_niah(&mut r, RetrievalVocab::DEFAULT, ctx);
    let tok = g.prompt[0];
    let _ = e.prefill(0, &g.prompt).unwrap();
    for _ in 0..3 {
        let _ = e.decode(0, tok).unwrap();
    }
    (e, tok)
}

#[test]
fn hot_path_allocation_budget() {
    // Pin tracing off for the baseline parts regardless of environment
    // (the CI traced leg exports TWILIGHT_TRACE=1).
    twilight::obs::trace::set_enabled(false);
    // Resolve the kernel dispatch table before counting: the first
    // `active()` call reads TWILIGHT_KERNEL and registers the backend
    // gauge, both of which allocate. Scalar also keeps the counts
    // backend-independent across the CI kernel legs.
    twilight::tensor::kernels::force_scalar();

    // --- (1) the pruned work unit: zero allocations, both modes -------
    prune_unit_is_zero_alloc(&PrunerConfig { p: 0.9, ..Default::default() }, "default");
    prune_unit_is_zero_alloc(
        &PrunerConfig { p: 0.9, hier_pages: true, hier_eps: 0.02, ..Default::default() },
        "hier-pages",
    );

    // --- (2) engine decode: constant per-step allocation count --------
    // gen_niah(ctx=199) yields a 200-token prompt → decode appends start
    // at slot 8: warm steps land at slots 8-10, the four measured steps
    // at slots 11-14 — no page allocation, no seal, and (2 kv-heads × 1
    // layer ⇒ ≤ 16 sparse calls total) no recall probe (cadence 64).
    let (mut e, tok) = warmed_engine(199);
    let counts: Vec<u64> = (0..4).map(|_| step_allocs(&mut e, tok)).collect();
    assert!(
        counts.windows(2).all(|w| w[0] == w[1]),
        "decode steps must allocate a constant amount once warm: {counts:?}"
    );

    // --- (3) per-step count is context-length independent -------------
    // 392 ≡ 200 (mod 16): identical slot schedule, ~2× the candidates.
    // Every per-candidate buffer lives in the arena, so the counts must
    // match exactly.
    let (mut e2, tok2) = warmed_engine(391);
    let c2 = step_allocs(&mut e2, tok2);
    assert_eq!(
        counts[0], c2,
        "per-step allocations grew with context length ({} @ ctx=199 vs {} @ ctx=391): \
         a per-candidate buffer escaped the scratch arena",
        counts[0], c2
    );

    // --- (4) span tracing adds zero per-step allocations --------------
    // The thread's span ring (and any metric-handle OnceLock) is created
    // during the warm steps; after that every recorded span is four
    // atomic stores. The measured steps must be constant AND equal to
    // the tracing-off counts from part (2).
    twilight::obs::trace::set_enabled(true);
    let (mut e3, tok3) = warmed_engine(199);
    let traced: Vec<u64> = (0..4).map(|_| step_allocs(&mut e3, tok3)).collect();
    twilight::obs::trace::set_enabled(false);
    assert!(
        traced.windows(2).all(|w| w[0] == w[1]),
        "traced decode steps must allocate a constant amount once warm: {traced:?}"
    );
    assert_eq!(
        traced[0], counts[0],
        "tracing must be allocation-free per event once the ring is warm \
         ({} traced vs {} untraced allocations per step)",
        traced[0], counts[0]
    );
}
