//! SIMD-vs-scalar parity battery for the kernel dispatch table
//! (DESIGN.md §11).
//!
//! The scalar backend is the bit-exact reference (the verbatim
//! historical loop bodies, pinned by the golden decode trace); this
//! battery pins what every *other* backend owes it:
//!
//! * reductions (`dot`, `dot_strict`, `axpy`, the fused `dot_q_*`
//!   widths, `dot_f16`, rmsnorm's sum of squares) — eps-bounded, with
//!   an O(√n·ε) relative bound, over **every** length 0..=257 so every
//!   remainder-tail shape of every vector width is exercised;
//! * value-exact entries (`unpack_*`, `f16_slice`) — bit-identical;
//! * `softmax` — bit-identical (exact max + sequential exp/sum);
//! * within one backend, `dot_strict` over widened halves must equal
//!   `dot_f16` over the packed bytes bit-for-bit (the invariant the
//!   tiled-SpGEMV bit-equality tests lean on);
//! * end-to-end: a governed multi-step decode run under `auto` must
//!   produce logits within a loose epsilon of the scalar run when fed
//!   the same token stream (sampled ids are NOT asserted — a top-p cut
//!   may legitimately flip a tail token under reassociation).
//!
//! On a host whose best backend IS scalar, every comparison degenerates
//! to scalar-vs-scalar and the battery simply proves `auto` resolves
//! without panicking — the required fallback behavior.
//!
//! Tests that touch the process-global backend selection (`install` /
//! `force_scalar`) serialize on `BACKEND_LOCK`; the pure comparisons go
//! through `kernels::table()` and never mutate the global.

use std::sync::Mutex;

use twilight::tensor::kernels::{self, Backend, Kernels, Select};
use twilight::tensor::quant::{dequantize_into, quantize, QuantBits};
use twilight::util::rng::Rng;

/// Serializes the tests that mutate the global backend selection.
static BACKEND_LOCK: Mutex<()> = Mutex::new(());

fn scalar() -> &'static Kernels {
    kernels::table(Backend::Scalar).expect("scalar table is always available")
}

/// The host's best table — scalar on hosts without SIMD, in which case
/// the comparisons below are trivially exact (and still worth running:
/// they prove the dispatch surface works there too).
fn best() -> &'static Kernels {
    kernels::table(kernels::detect()).expect("detected backend must have a table")
}

/// Eps bound for a reassociated length-`n` reduction whose exact
/// per-term magnitude sum is `ref_abs`: O(√n·ε) relative, with headroom
/// (32×) for the FMA/4-lane structure differences, plus an absolute
/// floor for near-cancelling sums.
fn reduction_tol(ref_abs: f32, n: usize) -> f32 {
    ref_abs * (n as f32).sqrt() * 32.0 * f32::EPSILON + 1e-6
}

fn random_vec(r: &mut Rng, n: usize, std: f32) -> Vec<f32> {
    (0..n).map(|_| r.normal_f32(0.0, std)).collect()
}

#[test]
fn dot_parity_every_tail_length() {
    let (s, b) = (scalar(), best());
    let mut r = Rng::new(0x51D0);
    for n in 0..=257usize {
        let x = random_vec(&mut r, n, 1.0);
        let y = random_vec(&mut r, n, 1.0);
        let ref_abs: f32 = x.iter().zip(&y).map(|(a, c)| (a * c).abs()).sum();
        let tol = reduction_tol(ref_abs, n);
        let want = (s.dot)(&x, &y);
        let got = (b.dot)(&x, &y);
        assert!((want - got).abs() <= tol, "dot n={n}: {want} vs {got} (tol {tol})");
        let want = (s.dot_strict)(&x, &y);
        let got = (b.dot_strict)(&x, &y);
        assert!((want - got).abs() <= tol, "dot_strict n={n}: {want} vs {got} (tol {tol})");
    }
}

#[test]
fn axpy_parity_every_tail_length() {
    let (s, b) = (scalar(), best());
    let mut r = Rng::new(0xA417);
    for n in 0..=257usize {
        let x = random_vec(&mut r, n, 1.0);
        let base = random_vec(&mut r, n, 1.0);
        let a = r.normal_f32(0.0, 1.0);
        let mut want = base.clone();
        let mut got = base.clone();
        (s.axpy)(a, &x, &mut want);
        (b.axpy)(a, &x, &mut got);
        // axpy is elementwise (one multiply-add per lane): the only
        // divergence is FMA vs separate rounding — a couple of ulps.
        for i in 0..n {
            let tol = 4.0 * f32::EPSILON * (base[i].abs() + (a * x[i]).abs()) + 1e-7;
            assert!(
                (want[i] - got[i]).abs() <= tol,
                "axpy n={n} i={i}: {} vs {} (tol {tol})",
                want[i],
                got[i]
            );
        }
    }
}

#[test]
fn fused_quant_dot_parity_every_width_and_tail() {
    let (s, b) = (scalar(), best());
    let mut r = Rng::new(0x0D07);
    for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
        for n in 0..=257usize {
            let xs = random_vec(&mut r, n, 1.5);
            let q = random_vec(&mut r, n, 1.0);
            let blk = quantize(&xs, bits);
            let (want, got) = match bits {
                QuantBits::Fp16 => ((s.dot_f16)(&q, &blk.packed), (b.dot_f16)(&q, &blk.packed)),
                QuantBits::Int8 => (
                    (s.dot_q_i8)(&q, &blk.packed, blk.zero, blk.scale),
                    (b.dot_q_i8)(&q, &blk.packed, blk.zero, blk.scale),
                ),
                QuantBits::Int4 => (
                    (s.dot_q_i4)(&q, &blk.packed, blk.zero, blk.scale),
                    (b.dot_q_i4)(&q, &blk.packed, blk.zero, blk.scale),
                ),
                QuantBits::Int2 => (
                    (s.dot_q_i2)(&q, &blk.packed, blk.zero, blk.scale),
                    (b.dot_q_i2)(&q, &blk.packed, blk.zero, blk.scale),
                ),
            };
            // Internal magnitudes: scale·codes up to the top level plus
            // the zero·Σq term — bound with the per-term sum of both.
            let top = blk.scale * (bits.levels() - 1) as f32;
            let ref_abs: f32 =
                q.iter().map(|v| v.abs() * (blk.zero.abs() + top + 1.0)).sum();
            let tol = reduction_tol(ref_abs, n.max(1)) + 1e-5;
            assert!(
                (want - got).abs() <= tol,
                "dot_q {bits:?} n={n}: {want} vs {got} (tol {tol})"
            );
        }
    }
}

#[test]
fn fused_quant_dot_matches_dequant_reference() {
    // Beyond scalar parity: every backend's fused dot must agree with
    // the explicit dequantize-then-dot reference.
    let b = best();
    let mut r = Rng::new(0xDE0A);
    for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
        for n in [1usize, 3, 31, 32, 33, 128, 255] {
            let xs = random_vec(&mut r, n, 1.0);
            let q = random_vec(&mut r, n, 1.0);
            let blk = quantize(&xs, bits);
            let mut deq = vec![0.0; n];
            dequantize_into(&blk, &mut deq);
            let want: f64 = q.iter().zip(&deq).map(|(a, c)| *a as f64 * *c as f64).sum();
            let got = match bits {
                QuantBits::Fp16 => (b.dot_f16)(&q, &blk.packed),
                QuantBits::Int8 => (b.dot_q_i8)(&q, &blk.packed, blk.zero, blk.scale),
                QuantBits::Int4 => (b.dot_q_i4)(&q, &blk.packed, blk.zero, blk.scale),
                QuantBits::Int2 => (b.dot_q_i2)(&q, &blk.packed, blk.zero, blk.scale),
            };
            assert!(
                (want - got as f64).abs() < 1e-3 * n as f64,
                "{bits:?} n={n}: ref {want} vs fused {got}"
            );
        }
    }
}

#[test]
fn unpack_entries_are_value_exact() {
    let (s, b) = (scalar(), best());
    let mut r = Rng::new(0x0421);
    for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
        // Alignment granularity of the width (Int4 windows are even,
        // Int2 windows are multiples of 4 — the tile preconditions).
        let step = match bits {
            QuantBits::Int2 => 4,
            QuantBits::Int4 => 2,
            _ => 1,
        };
        for n in (0..=64usize).step_by(step).chain([256]) {
            let xs = random_vec(&mut r, n, 2.0);
            let blk = quantize(&xs, bits);
            let mut want = vec![0.0f32; n];
            let mut got = vec![7.0f32; n];
            let (sw, bw) = match bits {
                QuantBits::Fp16 => (s.unpack_f16, b.unpack_f16),
                QuantBits::Int8 => (s.unpack_i8, b.unpack_i8),
                QuantBits::Int4 => (s.unpack_i4, b.unpack_i4),
                QuantBits::Int2 => (s.unpack_i2, b.unpack_i2),
            };
            sw(&blk.packed[..bits.bytes_for(n)], &mut want);
            bw(&blk.packed[..bits.bytes_for(n)], &mut got);
            for i in 0..n {
                assert_eq!(
                    want[i].to_bits(),
                    got[i].to_bits(),
                    "unpack {bits:?} n={n} i={i}: {} vs {}",
                    want[i],
                    got[i]
                );
            }
        }
    }
}

#[test]
fn f16_slice_is_value_exact() {
    let (s, b) = (scalar(), best());
    let mut r = Rng::new(0xF16A);
    for n in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 31, 100, 257] {
        // Random finite half patterns (NaN payloads are the documented
        // carve-out: hardware converts may quiet them).
        let hs: Vec<u16> = (0..n)
            .map(|_| loop {
                let h = (r.next_u64() & 0xFFFF) as u16;
                if (h & 0x7C00) != 0x7C00 || (h & 0x03FF) == 0 {
                    break h; // finite or ±inf
                }
            })
            .collect();
        let mut want = vec![0.0f32; n];
        let mut got = vec![0.0f32; n];
        (s.f16_slice)(&hs, &mut want);
        (b.f16_slice)(&hs, &mut got);
        for i in 0..n {
            assert_eq!(
                want[i].to_bits(),
                got[i].to_bits(),
                "f16_slice n={n} i={i}: half {:#06x}",
                hs[i]
            );
        }
    }
}

#[test]
fn softmax_is_bit_identical() {
    let (s, b) = (scalar(), best());
    let mut r = Rng::new(0x50F7);
    for n in (0..=64usize).chain([100, 257]) {
        let base = random_vec(&mut r, n, 3.0);
        let mut want = base.clone();
        let mut got = base.clone();
        let wm = (s.softmax)(&mut want);
        let gm = (b.softmax)(&mut got);
        assert_eq!(wm.to_bits(), gm.to_bits(), "softmax max n={n}");
        for i in 0..n {
            assert_eq!(want[i].to_bits(), got[i].to_bits(), "softmax n={n} i={i}");
        }
    }
}

#[test]
fn rmsnorm_parity() {
    let (s, b) = (scalar(), best());
    let mut r = Rng::new(0x4151);
    for n in [1usize, 7, 8, 9, 31, 32, 33, 256, 257] {
        let x = random_vec(&mut r, n, 1.0);
        let w = random_vec(&mut r, n, 1.0);
        let mut want = vec![0.0f32; n];
        let mut got = vec![0.0f32; n];
        (s.rmsnorm)(&x, &w, 1e-5, &mut want);
        (b.rmsnorm)(&x, &w, 1e-5, &mut got);
        // The sum of squares is the only reduction; the normalize is
        // elementwise. A loose relative bound per element suffices.
        for i in 0..n {
            let tol = want[i].abs() * 1e-4 + 1e-6;
            assert!(
                (want[i] - got[i]).abs() <= tol,
                "rmsnorm n={n} i={i}: {} vs {}",
                want[i],
                got[i]
            );
        }
    }
}

#[test]
fn dot_strict_matches_dot_f16_within_each_backend() {
    // The invariant the tiled-SpGEMV bit-equality tests rely on: within
    // ONE backend, a dot over widened halves reproduces the packed-f16
    // dot bit-for-bit (shared accumulation structure).
    let mut r = Rng::new(0x16F0);
    for table in [scalar(), best()] {
        for n in [0usize, 1, 5, 8, 13, 16, 64, 129, 257] {
            let xs = random_vec(&mut r, n, 1.0);
            let q = random_vec(&mut r, n, 1.0);
            let blk = quantize(&xs, QuantBits::Fp16);
            let mut widened = vec![0.0f32; n];
            (table.f16_slice)(
                &blk.packed
                    .chunks_exact(2)
                    .map(|c| u16::from_le_bytes([c[0], c[1]]))
                    .collect::<Vec<u16>>(),
                &mut widened,
            );
            let a = (table.dot_strict)(&q, &widened);
            let d = (table.dot_f16)(&q, &blk.packed);
            assert_eq!(
                a.to_bits(),
                d.to_bits(),
                "backend {} n={n}: dot_strict {a} != dot_f16 {d}",
                table.backend.name()
            );
        }
    }
}

#[test]
fn install_rejects_unsupported_backend_without_panicking() {
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // A backend the build target does not carry must be a clean Err
    // that leaves the active selection usable.
    let foreign = if cfg!(target_arch = "x86_64") { Select::Neon } else { Select::Avx2 };
    let before = kernels::active_name();
    assert!(kernels::install(foreign).is_err(), "foreign backend must not install");
    assert_eq!(kernels::active_name(), before, "failed install must not change the selection");
    // Auto always succeeds (worst case: scalar), and so does scalar.
    assert!(kernels::install(Select::Auto).is_ok());
    kernels::force_scalar();
    assert_eq!(kernels::active_name(), "scalar");
    assert!(kernels::install(Select::Auto).is_ok());
}

/// Governed multi-step decode, returning the per-step logits (prefill's
/// included) under a fixed token stream. When `tokens_in` is `None` the
/// stream is generated by sampling (scalar reference run) and returned;
/// otherwise the given stream is replayed (backend-under-test run).
fn decode_logit_trace(tokens_in: Option<&[u32]>) -> (Vec<Vec<f32>>, Vec<u32>) {
    use twilight::coordinator::engine::Engine;
    use twilight::coordinator::SparseConfig;
    use twilight::model::retrieval::build_retrieval_model;
    use twilight::model::sampler::{sample, SamplingParams};
    use twilight::selector::SelectorKind;
    use twilight::workload::{gen_niah, RetrievalVocab};

    const STEPS: usize = 8;
    let model = std::sync::Arc::new(build_retrieval_model(RetrievalVocab::DEFAULT, 1 << 13));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let mut e = Engine::new(model, cfg, 1 << 13);
    e.set_threads(1);
    let mut wl = Rng::new(0xBEEF);
    let g = gen_niah(&mut wl, RetrievalVocab::DEFAULT, 300);
    let mut srng = Rng::new(0x5EED);
    let params = SamplingParams { temperature: 0.8, top_p: 0.9 };
    let mut logits_trace = Vec::new();
    let mut tokens = Vec::new();
    let logits = e.prefill(0, &g.prompt).expect("prefill fits");
    let mut tok = match tokens_in {
        Some(ts) => ts[0],
        None => sample(&logits, &params, &mut srng),
    };
    tokens.push(tok);
    logits_trace.push(logits);
    for step in 0..STEPS {
        let logits = e.decode(0, tok).expect("decode fits");
        tok = match tokens_in {
            Some(ts) => ts[step + 1],
            None => sample(&logits, &params, &mut srng),
        };
        tokens.push(tok);
        logits_trace.push(logits);
    }
    (logits_trace, tokens)
}

#[test]
fn engine_decode_auto_tracks_scalar_logits() {
    let _g = BACKEND_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Reference pass on the bit-exact scalar backend; its sampled token
    // stream is then replayed under `auto` so both runs walk identical
    // KV states and the logits are directly comparable step by step.
    kernels::force_scalar();
    let (want, tokens) = decode_logit_trace(None);
    kernels::install(Select::Auto).expect("auto install cannot fail");
    let (got, _) = decode_logit_trace(Some(&tokens));
    assert_eq!(want.len(), got.len());
    for (step, (w, g)) in want.iter().zip(&got).enumerate() {
        assert_eq!(w.len(), g.len(), "step {step}: logit width changed");
        let maxabs = w.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        let tol = 2e-2 * maxabs + 2e-2;
        for (i, (a, c)) in w.iter().zip(g).enumerate() {
            assert!(
                (a - c).abs() <= tol,
                "step {step} logit {i}: scalar {a} vs {} {c} (tol {tol})",
                kernels::active_name()
            );
        }
    }
    // Leave the process on auto (matches the env default for any later
    // test in this binary).
    kernels::install(Select::Auto).expect("auto install cannot fail");
}
