//! Stress/determinism battery for the persistent attention worker pool.
//!
//! The pool's contract (see `util/threadpool.rs`): every index of every
//! round executes exactly once; worker panics re-raise on the caller
//! without poisoning the pool; zero-item rounds are no-ops; and —
//! the point of the rewrite — resident workers are created once per
//! pool, not once per round (`spawned_threads` is the instrumentation
//! hook that makes reuse observable).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use twilight::util::rng::Rng;
use twilight::util::threadpool::ThreadPool;

/// 10k rounds of mixed (n, chunk): every index in `0..n` is hit exactly
/// once per round, nothing outside it is ever touched, and the resident
/// worker set never grows after the first round that needs it.
#[test]
fn soak_mixed_rounds_cover_every_index_exactly_once() {
    const MAX_N: usize = 256;
    let pool = ThreadPool::new(4);
    let mut rng = Rng::new(0x57E55);
    let hits: Vec<AtomicUsize> = (0..MAX_N).map(|_| AtomicUsize::new(0)).collect();
    let mut spawned_high_water = 0;
    for round in 0..10_000 {
        // n in 0..=MAX_N (zero-item rounds included), chunk in 1..=16.
        let n = rng.below(MAX_N + 1);
        let chunk = 1 + rng.below(16);
        pool.run(n, chunk, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            let count = h.swap(0, Ordering::Relaxed);
            let want = usize::from(i < n);
            assert_eq!(
                count, want,
                "round {round} (n={n}, chunk={chunk}): index {i} ran {count} times"
            );
        }
        let spawned = pool.spawned_threads();
        assert!(
            spawned >= spawned_high_water,
            "spawn counter must be monotonic: {spawned} after {spawned_high_water}"
        );
        assert!(spawned <= 3, "threads=4 may never hold more than 3 residents: {spawned}");
        spawned_high_water = spawned;
    }
    assert!(
        spawned_high_water >= 1,
        "10k mixed rounds must have engaged the pool at least once"
    );
}

/// The reuse assertion in isolation: resident workers are created by the
/// first parallel round and *never again*, no matter how many rounds
/// follow — a spawn-per-round regression makes `spawned_threads` grow
/// linearly and fails immediately.
#[test]
fn workers_spawn_once_not_per_round() {
    let pool = ThreadPool::new(4);
    assert_eq!(pool.spawned_threads(), 0, "construction must not spawn (lazy growth)");
    assert_eq!(pool.rounds(), 0);
    pool.run(256, 2, |_| {});
    let after_first = pool.spawned_threads();
    assert_eq!(after_first, 3, "threads=4 ⇒ 3 resident workers (the caller drains too)");
    let extra_rounds = 1_000u64;
    for _ in 0..extra_rounds {
        pool.run(64, 1, |_| {});
    }
    assert_eq!(
        pool.spawned_threads(),
        after_first,
        "threads must be created once per pool, not per round"
    );
    assert_eq!(pool.rounds(), 1 + extra_rounds, "every parallel round is generation-stamped");
}

/// A panic inside a work item must surface on the caller with its
/// payload intact — and the pool must keep serving rounds afterwards
/// with the same resident workers (no poisoning, no respawn).
#[test]
fn worker_panic_propagates_without_poisoning_the_pool() {
    let pool = ThreadPool::new(4);
    pool.run(64, 1, |_| {}); // warm: residents up before the panic round
    let spawned = pool.spawned_threads();
    let caught = catch_unwind(AssertUnwindSafe(|| {
        pool.run(64, 1, |i| {
            if i == 13 {
                panic!("boom at ticket {i}");
            }
        });
    }));
    let payload = caught.expect_err("worker panic must re-raise on the caller");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .unwrap_or_default();
    assert!(msg.contains("boom at ticket 13"), "panic payload lost: {msg:?}");
    // The pool survives: full coverage on the very next rounds, with the
    // same residents.
    for _ in 0..10 {
        let sum = AtomicUsize::new(0);
        pool.run(100, 3, |i| {
            sum.fetch_add(i, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950, "post-panic round lost indices");
    }
    assert_eq!(pool.spawned_threads(), spawned, "panic must not cost the pool its workers");
}

/// Zero-item rounds are no-ops: the work function never runs and no
/// thread is ever spawned for them.
#[test]
fn zero_item_rounds_are_noops() {
    let pool = ThreadPool::new(8);
    for _ in 0..100 {
        pool.run(0, 4, |_| panic!("zero-item round executed work"));
    }
    assert_eq!(pool.spawned_threads(), 0, "zero-item rounds must not spawn");
    assert_eq!(pool.rounds(), 0, "zero-item rounds run inline, not through the pool");
}

/// `threads == 1` is the sequential bit-exactness reference: the caller
/// thread runs the plain loop and the pool machinery is never engaged.
#[test]
fn single_thread_pool_runs_inline() {
    let pool = ThreadPool::new(1);
    let order = std::sync::Mutex::new(Vec::new());
    pool.run(1000, 7, |i| order.lock().unwrap().push(i));
    let order = order.into_inner().unwrap();
    assert_eq!(order, (0..1000).collect::<Vec<_>>(), "inline path must be in-order");
    assert_eq!(pool.spawned_threads(), 0);
    assert_eq!(pool.rounds(), 0);
}

/// `set_threads` growth is lazy (next round spawns the difference) and
/// shrinking parks residents instead of tearing them down — parked
/// means parked: a shrunk round admits at most `threads - 1` residents
/// to the ticket queue, so observed parallelism tracks the target.
#[test]
fn set_threads_grows_lazily_and_never_tears_down() {
    let pool = ThreadPool::new(2);
    pool.run(64, 1, |_| {});
    assert_eq!(pool.spawned_threads(), 1);
    pool.set_threads(6);
    assert_eq!(pool.spawned_threads(), 1, "growth must wait for the next round");
    pool.run(64, 1, |_| {});
    assert_eq!(pool.spawned_threads(), 5);
    pool.set_threads(2);
    pool.run(64, 1, |_| {});
    assert_eq!(pool.spawned_threads(), 5, "shrinking parks residents, never joins them");
    // Full coverage still holds after the shrink, and the surplus
    // residents really are parked: at most `threads` distinct threads
    // (caller + admitted residents) ever touch the work.
    let sum = AtomicUsize::new(0);
    let participants = std::sync::Mutex::new(std::collections::HashSet::new());
    pool.run(100, 1, |i| {
        sum.fetch_add(i, Ordering::Relaxed);
        participants.lock().unwrap().insert(std::thread::current().id());
    });
    assert_eq!(sum.load(Ordering::Relaxed), 4950);
    let distinct = participants.into_inner().unwrap().len();
    assert!(
        distinct <= 2,
        "threads=2 round must admit at most 1 resident (saw {distinct} participants)"
    );
}
