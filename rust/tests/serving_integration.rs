//! Cross-module integration: full serving stack over the retrieval and
//! charlm models, the Table-2/3 accuracy shapes, the §4.3 cost-model
//! cross-check, and the offload path.

use std::sync::Arc;
use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::{AttnVariant, SparseConfig};
use twilight::evalsuite::{run_accuracy, suite_requests};
use twilight::governor::slo::SloConfig;
use twilight::governor::{BudgetDirective, Governor, GovernorConfig};
use twilight::model::retrieval::build_retrieval_model;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, poissonize, RetrievalVocab, TaskKind};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;

fn model(ctx: usize) -> Arc<twilight::model::Model> {
    Arc::new(build_retrieval_model(V, ctx))
}

/// Table 2/5 shape: each base algorithm + Twilight matches the best
/// fixed-budget variant of that algorithm at a much smaller final budget.
#[test]
fn twilight_matches_best_fixed_budget_with_fraction_of_tokens() {
    let ctx = 2048;
    let m = model(ctx * 2);
    let reqs = suite_requests(7, ctx, 4);
    let cap = (ctx + 64) * 2;
    for sel in [SelectorKind::Quest, SelectorKind::DoubleSparsity] {
        let mut big = SparseConfig::baseline(sel, ctx / 2);
        big.skip_layers = 0;
        let big_r = run_accuracy(m.clone(), &big, &reqs, cap);
        let mut twi = SparseConfig::twilight(sel, 0.95);
        twi.skip_layers = 0;
        let twi_r = run_accuracy(m.clone(), &twi, &reqs, cap);
        assert!(
            twi_r.overall() >= big_r.overall() - 0.1,
            "{sel:?}: twilight {} vs best-fixed {}",
            twi_r.overall(),
            big_r.overall()
        );
        // On NIAH specifically the pruned budget must be a small fraction
        // of the conservative candidate set (the "98% pruned" claim shape).
        assert!(
            twi_r.prune_ratio > 0.15,
            "{sel:?}: prune ratio {}",
            twi_r.prune_ratio
        );
    }
}

/// Table 3 shape: small fixed budgets break NIAH at long contexts while
/// Twilight holds; token-dropping (StreamingLLM) collapses (Table 6).
#[test]
fn long_context_accuracy_ordering() {
    let ctx = 8192;
    let m = model(ctx * 2);
    let reqs = suite_requests(13, ctx, 3);
    let cap = (ctx + 64) * 2;
    let mut tiny = SparseConfig::baseline(SelectorKind::Quest, 64);
    tiny.skip_layers = 0;
    let tiny_r = run_accuracy(m.clone(), &tiny, &reqs, cap);
    let mut twi = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    twi.skip_layers = 0;
    let twi_r = run_accuracy(m.clone(), &twi, &reqs, cap);
    let mut drop = SparseConfig::baseline(SelectorKind::StreamingLlm, 512);
    drop.skip_layers = 0;
    let drop_r = run_accuracy(m.clone(), &drop, &reqs, cap);
    assert!(twi_r.overall() > 0.85, "twilight {}", twi_r.overall());
    // FWE starves under a tiny budget.
    assert!(
        tiny_r.task_accuracy(TaskKind::Fwe) < twi_r.task_accuracy(TaskKind::Fwe) + 1e-9,
        "tiny fwe {} vs twi {}",
        tiny_r.task_accuracy(TaskKind::Fwe),
        twi_r.task_accuracy(TaskKind::Fwe)
    );
    // StreamingLLM drops the needle whenever it falls outside the window.
    assert!(
        drop_r.task_accuracy(TaskKind::Niah) < 0.6,
        "streaming niah {}",
        drop_r.task_accuracy(TaskKind::Niah)
    );
}

/// The three kernel packings must agree numerically (Fig. 13 is about
/// speed, not semantics).
#[test]
fn attn_variants_agree() {
    let ctx = 1024;
    let m = model(ctx * 2);
    let mut rng = Rng::new(5);
    let g = gen_niah(&mut rng, V, ctx);
    let mut logits = Vec::new();
    for variant in [AttnVariant::GroupVarlen, AttnVariant::HeadVarlen, AttnVariant::Padded] {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.attn = variant;
        let mut e = Engine::new(m.clone(), cfg, ctx + 64);
        logits.push(e.prefill(0, &g.prompt).unwrap());
    }
    for v in 1..3 {
        for (a, b) in logits[0].iter().zip(&logits[v]) {
            assert!((a - b).abs() < 1e-4, "variant {v} disagrees");
        }
    }
}

/// §4.3 cost-model cross-check: measured stage shares follow the
/// byte-level model (attend shrinks, prune appears, select fixed).
#[test]
fn cost_model_shape_holds() {
    let ctx = 8192;
    let m = model(ctx * 2);
    let mut rng = Rng::new(9);
    let g = gen_niah(&mut rng, V, ctx);
    let run = |cfg: SparseConfig| {
        let mut e = Engine::new(m.clone(), cfg, ctx + 64);
        let _ = e.prefill(0, &g.prompt).unwrap();
        e.reset_stats();
        for _ in 0..8 {
            let _ = e.decode(0, g.prompt[0]).unwrap();
        }
        e.stats.clone()
    };
    let mut base = SparseConfig::baseline(SelectorKind::Quest, ctx / 4);
    base.skip_layers = 0;
    let s_base = run(base);
    let mut twi = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    twi.skip_layers = 0;
    let s_twi = run(twi);
    // Twilight's attention time must be far below the base's.
    assert!(
        s_twi.t_attend < s_base.t_attend * 0.85,
        "attend {} vs {}",
        s_twi.t_attend,
        s_base.t_attend
    );
    // And the measured speedup direction matches the byte model.
    let bytes_base = s_base.est_bytes_select + s_base.est_bytes_prune + s_base.est_bytes_attend;
    let bytes_twi = s_twi.est_bytes_select + s_twi.est_bytes_prune + s_twi.est_bytes_attend;
    assert!(bytes_twi < bytes_base, "byte model: {bytes_twi} !< {bytes_base}");
}

/// Offload path (Table 7 substrate): selected-token loading through the
/// slow arena matches the in-memory result.
#[test]
fn offload_arena_matches_resident() {
    use twilight::kvcache::offload::OffloadArena;
    let d = 32;
    let n = 512;
    let mut rng = Rng::new(11);
    let mut arena = OffloadArena::new(d, 4);
    let mut k_all = Vec::new();
    let mut v_all = Vec::new();
    for _ in 0..n {
        let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        arena.push(&k, &v);
        k_all.extend(k);
        v_all.extend(v);
    }
    let sel: Vec<usize> = vec![3, 77, 200, 511];
    let mut k_out = vec![0.0; sel.len() * d];
    let mut v_out = vec![0.0; sel.len() * d];
    arena.load_tokens(&sel, &mut k_out, &mut v_out);
    let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut out_arena = vec![0.0; d];
    twilight::attention::full::contiguous_full(&q, &k_out, &v_out, &mut out_arena);
    // Same computation from resident memory.
    let mut k_res = Vec::new();
    let mut v_res = Vec::new();
    for &t in &sel {
        k_res.extend_from_slice(&k_all[t * d..(t + 1) * d]);
        v_res.extend_from_slice(&v_all[t * d..(t + 1) * d]);
    }
    let mut out_res = vec![0.0; d];
    twilight::attention::full::contiguous_full(&q, &k_res, &v_res, &mut out_res);
    assert_eq!(out_arena, out_res);
}

/// The governed scheduler under a bursty trace on an undersized page
/// pool: the AIMD policy must tighten p / B0 against the (unattainable)
/// TPOT SLO, the pressure ladder must engage as the pool drains, every
/// directive must respect the safety clamps, and the run must complete
/// cleanly despite preemption.
#[test]
fn governed_scheduler_adapts_under_bursty_load() {
    let m = model(1 << 14);
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    // ~188 pages per layer pool; one 512-token burst wants ~198 — the
    // second burst runs straight into the pressure ladder.
    let engine = Engine::new(m, cfg, 3000);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig { max_batch: 8, admit_headroom_pages: 0, ..Default::default() },
    );
    let gcfg = GovernorConfig {
        slo: SloConfig { target_tpot_s: 1e-9, margin: 0.2 },
        ..Default::default()
    };
    sched.attach_governor(Governor::new("aimd", gcfg).unwrap());
    let mut rng = Rng::new(31);
    let mut id = 0u64;
    for burst in 0..2 {
        for _ in 0..6 {
            let g = gen_niah(&mut rng, V, 512);
            let mut r = Request::new(id, g.prompt.clone(), 4);
            r.arrival = burst as f64 * 0.25;
            sched.submit(r);
            id += 1;
        }
    }
    let rep = sched.run_to_completion();
    assert_eq!(rep.requests.len(), 12, "every bursty request must complete");
    assert_eq!(sched.engine.num_seqs(), 0, "pages leaked");
    let trace = &rep.governor;
    assert!(!trace.is_empty(), "governed run must trace decisions");
    // The SLO is unattainable: the budget must have been cut.
    assert!(
        trace.iter().any(|e| e.budget_scale < 1.0),
        "AIMD never tightened under a 1ns TPOT SLO"
    );
    assert!(trace.iter().any(|e| e.p_scale < 1.0));
    // The undersized pool must have engaged the pressure ladder.
    assert!(
        trace.iter().any(|e| e.degrade_level >= 1),
        "pressure ladder never engaged on an undersized pool"
    );
    // Safety: every recorded directive inside the hard clamps.
    for e in trace {
        assert!(e.p_scale >= BudgetDirective::P_SCALE_RANGE.0);
        assert!(e.p_scale <= BudgetDirective::P_SCALE_RANGE.1);
        assert!(e.budget_scale >= BudgetDirective::BUDGET_SCALE_RANGE.0);
        assert!(e.budget_scale <= BudgetDirective::BUDGET_SCALE_RANGE.1);
        assert!(e.degrade_level <= 3);
    }
    // Telemetry flowed: captured-mass signal is live and sane.
    assert!(trace.last().unwrap().mean_mass > 0.0);
    assert!(trace.last().unwrap().mean_mass <= 1.0 + 1e-4);
}

/// Serving under load with mixed context lengths and arrivals: everything
/// completes, answers are right, no pages leak.
#[test]
fn mixed_length_poisson_serving() {
    let m = model(1 << 14);
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    let engine = Engine::new(m, cfg, 1 << 14);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig { max_batch: 4, ..Default::default() },
    );
    let mut rng = Rng::new(21);
    let mut gens = Vec::new();
    for i in 0..10u64 {
        let ctx = [256usize, 512, 1024][rng.below(3)];
        let g = gen_niah(&mut rng, V, ctx);
        gens.push(g);
        let _ = i;
    }
    poissonize(&mut gens, 22, 200.0);
    for (i, g) in gens.iter().enumerate() {
        let mut r = Request::new(i as u64, g.prompt.clone(), 1);
        r.arrival = g.arrival;
        sched.submit(r);
    }
    let report = sched.run_to_completion();
    assert_eq!(report.requests.len(), 10);
    let correct = sched
        .finished_requests()
        .iter()
        .filter(|f| f.output.first() == Some(&gens[f.id as usize].answer))
        .count();
    assert!(correct >= 9, "{correct}/10");
    assert_eq!(sched.engine.num_seqs(), 0);
}
