//! Three-layer composition tests: the HLO artifacts produced by the
//! python compile path (L2 graphs embedding the L1 Pallas kernels) must
//! agree with the Rust-native implementations when executed through the
//! PJRT runtime — proving the layers compose.
//!
//! These tests need `make artifacts`; they skip (with a loud message)
//! when the artifact directory is absent so `cargo test` stays green on
//! a fresh checkout.

use std::sync::Arc;

use twilight::model::weights;
use twilight::model::DenseBackend;
use twilight::runtime::{f32_scalar, i32_scalar, i32_vec, tensor_to_literal, Runtime};
use twilight::tensor::Tensor;
use twilight::util::rng::Rng;

fn artifacts() -> Option<String> {
    if !twilight::runtime::available() {
        eprintln!("SKIP: built without the `pjrt` feature (see Cargo.toml)");
        return None;
    }
    let dir = std::env::var("TWILIGHT_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

#[test]
fn charlm_prefill_hlo_matches_native() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let model = Arc::new(weights::load_model(&dir, "charlm").unwrap());
    let corpus = twilight::workload::load_corpus(&format!("{dir}/corpus_eval.bin")).unwrap();
    let toks: Vec<i32> = corpus[..128].iter().map(|&t| t as i32).collect();
    let outs = rt
        .execute_f32("charlm_prefill_128", &[i32_vec(&toks, &[128]).unwrap()])
        .unwrap();
    let logits_hlo = &outs[0];
    assert_eq!(logits_hlo.shape, vec![128, model.cfg.vocab_size]);
    // Native teacher-forced decode.
    let mut backend = DenseBackend::new(&model.cfg);
    let mut worst = 0.0f32;
    for (pos, &t) in toks.iter().enumerate() {
        let native = model.decode_step(t as u32, pos, &mut backend);
        for (a, b) in native.iter().zip(logits_hlo.row(pos)) {
            worst = worst.max((a - b).abs());
        }
    }
    assert!(worst < 2e-2, "prefill parity worst abs diff {worst}");
}

#[test]
fn charlm_decode_step_hlo_matches_native() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let model = Arc::new(weights::load_model(&dir, "charlm").unwrap());
    let c = &model.cfg;
    let corpus = twilight::workload::load_corpus(&format!("{dir}/corpus_eval.bin")).unwrap();
    let n_steps = 24;
    let cap = 512usize;
    let cache_shape = [c.n_layers, cap, c.n_kv_heads, c.head_dim];
    let mut kc = Tensor::zeros(&cache_shape);
    let mut vc = Tensor::zeros(&cache_shape);
    let mut backend = DenseBackend::new(c);
    let mut worst = 0.0f32;
    for pos in 0..n_steps {
        let tok = corpus[pos] as u32;
        let native = model.decode_step(tok, pos, &mut backend);
        // Outputs: (logits, k_new, v_new).
        let outs = rt
            .execute(
                "charlm_step_512",
                &[
                    i32_scalar(tok as i32),
                    i32_scalar(pos as i32),
                    i32_scalar(pos as i32),
                    tensor_to_literal(&kc).unwrap(),
                    tensor_to_literal(&vc).unwrap(),
                ],
            )
            .unwrap();
        let mut it = outs.into_iter();
        let logits = twilight::runtime::literal_to_tensor(it.next().unwrap()).unwrap();
        let k_new = twilight::runtime::literal_to_tensor(it.next().unwrap()).unwrap();
        let v_new = twilight::runtime::literal_to_tensor(it.next().unwrap()).unwrap();
        for (a, b) in native.iter().zip(&logits.data) {
            worst = worst.max((a - b).abs());
        }
        // Write k_new/v_new into the cache tensors at slot `pos`.
        let kvh = c.n_kv_heads * c.head_dim;
        for l in 0..c.n_layers {
            let dst = (l * cap + pos) * kvh;
            let src = l * kvh;
            kc.data[dst..dst + kvh].copy_from_slice(&k_new.data[src..src + kvh]);
            vc.data[dst..dst + kvh].copy_from_slice(&v_new.data[src..src + kvh]);
        }
    }
    assert!(worst < 2e-2, "decode-step parity worst abs diff {worst}");
}

#[test]
fn twilight_attn_hlo_self_consistent_and_close_to_dense() {
    let Some(dir) = artifacts() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let (h, hkv, n, d, group) = (8usize, 2usize, 1024usize, 32usize, 4usize);
    let mut rng = Rng::new(99);
    // Sharpened queries → focused distributions → real pruning.
    let q = Tensor::from_vec((0..h * d).map(|_| rng.normal_f32(0.0, 3.0)).collect(), &[h, d]);
    let k = Tensor::from_vec(
        (0..hkv * n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        &[hkv, n, d],
    );
    let v = Tensor::from_vec(
        (0..hkv * n * d).map(|_| rng.normal_f32(0.0, 1.0)).collect(),
        &[hkv, n, d],
    );
    let outs = rt
        .execute_f32(
            "twilight_attn_1024",
            &[
                tensor_to_literal(&q).unwrap(),
                tensor_to_literal(&k).unwrap(),
                tensor_to_literal(&v).unwrap(),
                f32_scalar(0.9),
            ],
        )
        .unwrap();
    let out = &outs[0];
    let mask = &outs[1];
    assert_eq!(out.shape, vec![h, d]);
    assert_eq!(mask.shape, vec![h, n]);
    // (1) The artifact must have pruned a nontrivial fraction.
    let kept: f32 = mask.data.iter().sum();
    assert!(kept < (h * n) as f32 * 0.8, "kept {kept} of {}", h * n);
    assert!(kept > 0.0);
    // (2) Masked attention recomputed natively from the artifact's own
    //     mask must reproduce the artifact's output (kernel correctness
    //     through the HLO interchange).
    // (3) The output must stay close to dense attention (p=0.9 bound).
    let scale = 1.0 / (d as f32).sqrt();
    let mut worst_masked = 0.0f32;
    let mut worst_dense = 0.0f32;
    for qh in 0..h {
        let kvh = qh / group;
        let qrow = &q.data[qh * d..(qh + 1) * d];
        let krows = &k.data[kvh * n * d..(kvh + 1) * n * d];
        let vrows = &v.data[kvh * n * d..(kvh + 1) * n * d];
        let logits: Vec<f32> =
            (0..n).map(|t| twilight::tensor::dot(qrow, &krows[t * d..(t + 1) * d]) * scale).collect();
        let attend = |keep: &dyn Fn(usize) -> bool| -> Vec<f32> {
            let m = (0..n)
                .filter(|&t| keep(t))
                .map(|t| logits[t])
                .fold(f32::NEG_INFINITY, f32::max);
            let mut denom = 0.0;
            let mut out = vec![0.0f32; d];
            for t in 0..n {
                if keep(t) {
                    let w = (logits[t] - m).exp();
                    denom += w;
                    twilight::tensor::axpy(w, &vrows[t * d..(t + 1) * d], &mut out);
                }
            }
            for o in out.iter_mut() {
                *o /= denom;
            }
            out
        };
        let masked = attend(&|t| mask.data[qh * n + t] > 0.0);
        let dense = attend(&|_| true);
        for i in 0..d {
            worst_masked = worst_masked.max((masked[i] - out.data[qh * d + i]).abs());
            worst_dense = worst_dense.max((dense[i] - out.data[qh * d + i]).abs());
        }
    }
    assert!(worst_masked < 1e-3, "mask-consistency diff {worst_masked}");
    assert!(worst_dense < 0.5, "dense-vs-pruned diff {worst_dense}");
}

#[test]
fn retrieval_weights_parity_python_vs_rust() {
    let Some(dir) = artifacts() else { return };
    let from_py = weights::load_model(&dir, "retrieval").unwrap();
    let native = twilight::model::retrieval::build_retrieval_model(
        twilight::workload::RetrievalVocab::DEFAULT,
        from_py.cfg.max_ctx,
    );
    assert_eq!(from_py.cfg.vocab_size, native.cfg.vocab_size);
    assert_eq!(from_py.embed, native.embed, "embed mismatch");
    assert_eq!(from_py.lm_head, native.lm_head, "lm_head mismatch");
    for (a, b) in from_py.layers.iter().zip(&native.layers) {
        assert_eq!(a.wq, b.wq, "wq mismatch");
        assert_eq!(a.wk, b.wk, "wk mismatch");
        assert_eq!(a.wv, b.wv, "wv mismatch");
        assert_eq!(a.wo, b.wo, "wo mismatch");
    }
}
