//! Parallel batched-decode correctness: the LPT-scheduled multi-threaded
//! attention phase must be *bit-exact* with sequential execution, and the
//! scheduler must make progress for many concurrent requests through the
//! batched step.

use std::sync::Arc;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::model::retrieval::build_retrieval_model;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;

/// Run the same multi-sequence decode trace with `threads` attention
/// workers; return every step's logits plus the budget counters.
fn run_trace(threads: usize) -> (Vec<Vec<f32>>, u64, u64) {
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let mut e = Engine::new(model, cfg, 1 << 14);
    e.set_threads(threads);
    let mut rng = Rng::new(71);
    let mut toks = Vec::new();
    for i in 0..3u64 {
        // Mixed context lengths → skewed per-head budgets for the LPT.
        let g = gen_niah(&mut rng, V, 256 * (i as usize + 1));
        let _ = e.prefill(i, &g.prompt).unwrap();
        toks.push(g.prompt[0]);
    }
    let mut all = Vec::new();
    for _ in 0..8 {
        let batch = DecodeBatch::new((0..3u64).map(|i| (i, toks[i as usize])).collect());
        for res in e.step_batch(&batch) {
            all.push(res.unwrap());
        }
    }
    (all, e.stats.kept_sum, e.stats.candidates_sum)
}

#[test]
fn batched_decode_bit_exact_across_worker_counts() {
    let (logits_1, kept_1, cand_1) = run_trace(1);
    let (logits_4, kept_4, cand_4) = run_trace(4);
    assert_eq!(kept_1, kept_4, "kept_sum must not depend on worker count");
    assert_eq!(cand_1, cand_4, "candidates_sum must not depend on worker count");
    assert_eq!(logits_1.len(), logits_4.len());
    for (step, (a, b)) in logits_1.iter().zip(&logits_4).enumerate() {
        // Bit-exact: the work items are independent and merged in
        // flattened order, so no float op order can differ.
        assert_eq!(a, b, "logits diverged at step-result {step}");
    }
}

#[test]
fn worker_count_does_not_change_telemetry() {
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let run = |threads: usize| {
        let mut e = Engine::new(model.clone(), cfg.clone(), 1 << 14);
        e.set_threads(threads);
        let mut rng = Rng::new(72);
        let g = gen_niah(&mut rng, V, 1024);
        let _ = e.prefill(0, &g.prompt).unwrap();
        for _ in 0..4 {
            let _ = e.decode(0, g.prompt[0]).unwrap();
        }
        (
            e.stats.sparse_calls,
            e.signals.probes(),
            e.signals.mean_mass(),
            e.signals.probe_recall(),
        )
    };
    let (calls_1, probes_1, mass_1, recall_1) = run(1);
    let (calls_4, probes_4, mass_4, recall_4) = run(4);
    assert_eq!(calls_1, calls_4);
    assert_eq!(probes_1, probes_4, "probe cadence must use precomputed call indices");
    assert_eq!(mass_1, mass_4, "signal rings must merge deterministically");
    assert_eq!(recall_1, recall_4);
}

#[test]
fn engine_reuses_pool_workers_across_steps() {
    // The persistent pool must spawn its resident workers at most once
    // per engine — not once per layer per step. Ten batched steps after
    // the first must not create a single additional thread.
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let mut e = Engine::new(model, cfg, 1 << 14);
    e.set_threads(4);
    let mut rng = Rng::new(74);
    let mut toks = Vec::new();
    for i in 0..4u64 {
        let g = gen_niah(&mut rng, V, 256);
        let _ = e.prefill(i, &g.prompt).unwrap();
        toks.push((i, g.prompt[0]));
    }
    let batch = DecodeBatch::new(toks);
    for res in e.step_batch(&batch) {
        res.unwrap();
    }
    let spawned = e.pool().spawned_threads();
    assert!(
        spawned >= 1 && spawned <= 3,
        "threads=4 must run at most 3 resident workers (caller participates), got {spawned}"
    );
    for _ in 0..10 {
        for res in e.step_batch(&batch) {
            res.unwrap();
        }
    }
    assert_eq!(
        e.pool().spawned_threads(),
        spawned,
        "pool must reuse resident workers across steps, not respawn per round"
    );
}

#[test]
fn scheduler_progresses_many_concurrent_requests_in_parallel() {
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    let mut engine = Engine::new(model, cfg, 1 << 16);
    engine.set_threads(4);
    let mut s = Scheduler::new(engine, SchedulerConfig::default());
    let mut rng = Rng::new(73);
    let mut answers = Vec::new();
    for i in 0..8u64 {
        let g = gen_niah(&mut rng, V, 256);
        answers.push(g.answer);
        s.submit(Request::new(i, g.prompt, 1));
    }
    let rep = s.run_to_completion();
    assert_eq!(rep.requests.len(), 8, "all concurrent requests must finish");
    let correct = s
        .finished_requests()
        .iter()
        .filter(|r| r.output.first() == Some(&answers[r.id as usize]))
        .count();
    assert!(correct >= 7, "{correct}/8 answers under 4-worker batched decode");
    assert_eq!(s.engine.num_seqs(), 0, "pages leaked");
}
