//! Tiered-offload correctness: decode under a capped resident set must
//! be *bit-exact* with the fully-resident engine for every resident
//! fraction and worker count, because faulting a page back restores the
//! exact fp32 bytes the write-through spilled at seal time and the
//! select/prune stages only ever read always-resident state (the INT4
//! mirror, the minmax summaries, and the unsealed fp32 tail).
//!
//! Fault accounting is deterministic too: the per-step faulted set is
//! `demand ∪ planned`, both derived from the deterministic pruned page
//! set and the serial prefetch plan, so totals cannot depend on how many
//! workers raced to serve them. Only the demand/prefetch *split* is
//! timing-dependent, and nothing here pins it.
//!
//! Every run pins its residency explicitly via `set_resident_frac` (1.0
//! detaches), so the battery is immune to `TWILIGHT_RESIDENT_FRAC` being
//! exported by the offloaded CI leg.

use std::sync::Arc;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::model::retrieval::build_retrieval_model;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;

/// Small page pool so fractional caps actually bind: three sequences at
/// 256/512/768 tokens plus decode growth use ~97 of the 128 pages, so
/// frac 0.5 (cap 64) already forces evictions and frac 0.1 (cap 13)
/// thrashes hard.
const CAPACITY: usize = 2048;

struct TraceOut {
    logits: Vec<Vec<f32>>,
    faults: u64,
    evictions: u64,
    bytes_faulted: u64,
}

/// Replay the same 3-sequence, 8-step decode trace with `threads`
/// attention workers and the given resident fraction (1.0 = no tier).
fn run_trace(threads: usize, frac: f64) -> TraceOut {
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let mut e = Engine::new(model, cfg, CAPACITY);
    e.set_threads(threads);
    e.set_resident_frac(frac);
    let mut rng = Rng::new(71);
    let mut toks = Vec::new();
    for i in 0..3u64 {
        // Mixed context lengths → skewed budgets and uneven page counts.
        let g = gen_niah(&mut rng, V, 256 * (i as usize + 1));
        let _ = e.prefill(i, &g.prompt).unwrap();
        toks.push(g.prompt[0]);
    }
    let mut logits = Vec::new();
    for _ in 0..8 {
        let batch = DecodeBatch::new((0..3u64).map(|i| (i, toks[i as usize])).collect());
        for res in e.step_batch(&batch) {
            logits.push(res.unwrap());
        }
    }
    TraceOut {
        logits,
        faults: e.stats.offload_faults,
        evictions: e.stats.offload_evictions,
        bytes_faulted: e.stats.offload_bytes_faulted,
    }
}

#[test]
fn offloaded_decode_bit_exact_vs_fully_resident() {
    let baseline = run_trace(1, 1.0);
    assert_eq!(baseline.faults, 0, "fully-resident run must never fault");
    for &frac in &[1.0, 0.5, 0.25, 0.1] {
        for &threads in &[1usize, 4, 8] {
            let out = run_trace(threads, frac);
            assert_eq!(baseline.logits.len(), out.logits.len());
            for (step, (a, b)) in baseline.logits.iter().zip(&out.logits).enumerate() {
                assert_eq!(
                    a, b,
                    "logits diverged at step-result {step} (frac={frac}, threads={threads})"
                );
            }
        }
    }
}

#[test]
fn fault_totals_are_thread_invariant_and_capped_runs_actually_fault() {
    // The faulted set per step is demand ∪ planned, both deterministic,
    // so the totals must be identical no matter how many workers race.
    let t1 = run_trace(1, 0.25);
    let t4 = run_trace(4, 0.25);
    let t8 = run_trace(8, 0.25);
    assert!(t1.faults > 0, "cap 32 of ~97 in-use pages must force faults");
    assert!(t1.evictions > 0, "over-cap residency must evict");
    assert_eq!(t1.faults, t4.faults, "fault totals must not depend on worker count");
    assert_eq!(t1.faults, t8.faults, "fault totals must not depend on worker count");
    assert_eq!(t1.evictions, t4.evictions);
    assert_eq!(t1.evictions, t8.evictions);
    assert_eq!(t1.bytes_faulted, t4.bytes_faulted);
    // Every fault moves exactly one page of K plus one page of V, so the
    // byte counter is an exact multiple of the per-fault transfer.
    assert_eq!(t1.bytes_faulted % t1.faults, 0);
    assert!(t1.bytes_faulted / t1.faults > 0);
}

#[test]
fn tighter_caps_fault_no_less() {
    // Shrinking the resident cap can only grow (or hold) the fault
    // count: a page resident at cap C is at least as likely resident at
    // any C' > C under the same LRU trace.
    let half = run_trace(1, 0.5);
    let tenth = run_trace(1, 0.1);
    assert!(
        tenth.faults >= half.faults,
        "frac 0.1 faulted {} < frac 0.5's {}",
        tenth.faults,
        half.faults
    );
}

#[test]
fn serving_report_carries_offload_accounting() {
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let mut engine = Engine::new(model, cfg, CAPACITY);
    engine.set_threads(4);
    engine.set_resident_frac(0.25);
    let mut s = Scheduler::new(engine, SchedulerConfig::default());
    let mut rng = Rng::new(73);
    let mut answers = Vec::new();
    for i in 0..3u64 {
        let g = gen_niah(&mut rng, V, 256 * (i as usize + 1));
        answers.push(g.answer);
        s.submit(Request::new(i, g.prompt, 4));
    }
    let rep = s.run_to_completion();
    assert_eq!(rep.requests.len(), 3);
    assert!((rep.resident_frac - 0.25).abs() < 1e-12);
    assert!(rep.offload_faults > 0, "capped serve must fault pages back in");
    assert!(rep.offload_faults >= rep.offload_prefetched);
    let overlap = rep.offload_overlap_frac();
    assert!((0.0..=1.0).contains(&overlap), "overlap frac out of range: {overlap}");
    let j = rep.to_json();
    assert!(j.get_f64("offload_overlap_frac").is_some());
    assert_eq!(j.get_usize("offload_faults"), Some(rep.offload_faults as usize));
    // Offload must not cost correctness: retrieval answers still land.
    let correct = s
        .finished_requests()
        .iter()
        .filter(|r| r.output.first() == Some(&answers[r.id as usize]))
        .count();
    assert!(correct >= 2, "{correct}/3 retrieval answers under offloaded decode");
}
