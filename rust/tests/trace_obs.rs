//! Determinism-under-observation: the span tracer must be purely
//! observational. One fixed-seed governed decode run (the golden_decode
//! workload: mixed-length prefills, governed decode steps, a chunked
//! admission segment) executes twice — tracing off, then tracing on —
//! and the sampled tokens, budget counters, and telemetry must be
//! bit-identical. The traced run's rings must then hold a consistent
//! record: no wrap drops, a valid Chrome export, per-thread span
//! nesting, and per-stage totals that reconcile with `EngineStats`
//! (span and stat durations are the same `Instant::elapsed()` by
//! construction, so they must agree to float-rounding).
//!
//! This file is a single test in its own binary on purpose: the span
//! registry is process-global, and a lone test sees only its own runs.

use std::sync::Arc;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::SparseConfig;
use twilight::governor::{Governor, GovernorConfig};
use twilight::model::retrieval::build_retrieval_model;
use twilight::model::sampler::{sample, SamplingParams};
use twilight::obs::trace::{self, Stage, ThreadSpans};
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;
const SEQS: u64 = 3;
const DECODE_STEPS: u64 = 12;
const CHUNK_PROMPT_CTX: usize = 96;
const CHUNK_SPAN: usize = 32;
const THREADS: usize = 4;

/// Everything determinism pins, floats as bit patterns (exact compare).
#[derive(Clone, Debug, PartialEq)]
struct Trace {
    tokens: Vec<u32>,
    kept_sum: u64,
    candidates_sum: u64,
    sparse_calls: u64,
    steps: u64,
    prefill_tokens: u64,
    probes: u64,
    mean_mass_bits: u64,
    probe_recall_bits: u64,
    p_scale_bits: u32,
    budget_scale_bits: u32,
}

/// Timing stats of the run, for reconciling against span totals.
struct StatTimes {
    t_select: f64,
    t_prune: f64,
    t_attend: f64,
    t_dense: f64,
    t_sprefill: f64,
}

/// The golden_decode workload (same seeds, same virtual-time governor,
/// same chunked admission) at a fixed worker count.
fn run_trace() -> (Trace, StatTimes) {
    let model = Arc::new(build_retrieval_model(V, 1 << 13));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    if let Some(t) = cfg.twilight.as_mut() {
        t.hier_pages = false;
    }
    let mut e = Engine::new(model, cfg, 1 << 13);
    e.set_threads(THREADS);
    let mut gov = Governor::new("mass", GovernorConfig::default()).expect("mass policy exists");
    let mut wl_rng = Rng::new(0xD0_6E);
    let mut sample_rng = Rng::new(0x5A11);
    let params = SamplingParams { temperature: 0.8, top_p: 0.9 };
    let mut tokens = Vec::new();
    let mut frontier: Vec<(u64, u32)> = Vec::new();
    for i in 0..SEQS {
        let g = gen_niah(&mut wl_rng, V, 192 + 128 * i as usize);
        let logits = e.prefill(i, &g.prompt).expect("prefill fits the page pool");
        let tok = sample(&logits, &params, &mut sample_rng);
        tokens.push(tok);
        frontier.push((i, tok));
    }
    for step in 0..DECODE_STEPS {
        let free_frac = e.free_pages() as f64 / e.total_pages().max(1) as f64;
        let snap = gov.snapshot(
            step as f64 * 0.01,
            &e.signals,
            free_frac,
            0,
            frontier.len(),
            e.stats.steps,
        );
        let d = gov.step(&snap);
        e.apply_directive(d);
        let batch = DecodeBatch::new(frontier.clone());
        let results = e.step_batch(&batch);
        for (slot, res) in frontier.iter_mut().zip(results) {
            let logits = res.expect("trace must not OOM");
            let tok = sample(&logits, &params, &mut sample_rng);
            tokens.push(tok);
            slot.1 = tok;
        }
    }
    let g3 = gen_niah(&mut wl_rng, V, CHUNK_PROMPT_CTX);
    e.start_empty(SEQS);
    let mut cursor = 0;
    while cursor < g3.prompt.len() {
        let end = (cursor + CHUNK_SPAN).min(g3.prompt.len());
        let mut batch = DecodeBatch::default();
        for &(id, tok) in frontier.iter() {
            batch.push_decode(id, tok);
        }
        batch.push_chunk(SEQS, g3.prompt[cursor..end].to_vec(), end == g3.prompt.len());
        let mut results = e.step_batch(&batch).into_iter();
        for slot in frontier.iter_mut() {
            let logits = results.next().unwrap().expect("trace must not OOM");
            let tok = sample(&logits, &params, &mut sample_rng);
            tokens.push(tok);
            slot.1 = tok;
        }
        let chunk_logits = results.next().unwrap().expect("trace must not OOM");
        cursor = end;
        if cursor == g3.prompt.len() {
            let tok = sample(&chunk_logits, &params, &mut sample_rng);
            tokens.push(tok);
        }
    }
    let d = e.directive();
    (
        Trace {
            tokens,
            kept_sum: e.stats.kept_sum,
            candidates_sum: e.stats.candidates_sum,
            sparse_calls: e.stats.sparse_calls,
            steps: e.stats.steps,
            prefill_tokens: e.stats.prefill_tokens,
            probes: e.signals.probes(),
            mean_mass_bits: e.signals.mean_mass().to_bits(),
            probe_recall_bits: e.signals.probe_recall().to_bits(),
            p_scale_bits: d.p_scale.to_bits(),
            budget_scale_bits: d.budget_scale.to_bits(),
        },
        StatTimes {
            t_select: e.stats.t_select,
            t_prune: e.stats.t_prune,
            t_attend: e.stats.t_attend,
            t_dense: e.stats.t_dense,
            t_sprefill: e.stats.t_sprefill,
        },
    )
}

/// abs 1e-5 s or rel 1e-3: span durations and stat durations come from
/// the same `elapsed()` value, so only float-rounding separates them.
fn close(span_total: f64, stat: f64, what: &str) {
    let diff = (span_total - stat).abs();
    assert!(
        diff < 1e-5 || diff < stat.abs() * 1e-3,
        "{what}: span total {span_total} vs stat {stat} (diff {diff})"
    );
}

/// Inner spans must nest inside some same-thread outer-stage span.
/// Outer spans on one thread never overlap (sequential execution), so
/// the candidate container is the last outer begun at-or-before the
/// inner's begin. `eps` absorbs the clock-read skew between a span's
/// real end and the `now_ns()` its record call reconstructs begin from.
fn assert_nested(t: &ThreadSpans, inner: Stage, outer: Stage) {
    const EPS_NS: u64 = 10_000; // 10 µs
    let mut outers: Vec<(u64, u64)> = t
        .spans
        .iter()
        .filter(|s| s.stage == outer)
        .map(|s| (s.begin_ns, s.begin_ns + s.dur_ns))
        .collect();
    outers.sort_unstable();
    for s in t.spans.iter().filter(|s| s.stage == inner) {
        let begin = s.begin_ns;
        let end = s.begin_ns + s.dur_ns;
        let idx = outers.partition_point(|&(ob, _)| ob <= begin + EPS_NS);
        let ok = idx > 0 && {
            let (ob, oe) = outers[idx - 1];
            begin + EPS_NS >= ob && end <= oe + EPS_NS
        };
        assert!(
            ok,
            "{:?} span [{begin},{end}] on tid {} ({}) not nested in any {:?} span",
            inner, t.tid, t.label, outer
        );
    }
}

#[test]
fn tracing_is_observational_and_reconciles() {
    // --- run A: tracing off (explicit: the CI traced leg exports
    // TWILIGHT_TRACE=1, which set_enabled overrides) -------------------
    trace::set_enabled(false);
    let (t_off, _) = run_trace();
    let (held, _) = trace::event_counts();
    assert_eq!(held, 0, "disabled run must record nothing");

    // --- run B: tracing on --------------------------------------------
    trace::reset();
    trace::set_enabled(true);
    let (t_on, stats) = run_trace();
    trace::set_enabled(false);

    // (1) Bit-exactness: tokens, counters, telemetry, and the governor's
    // final directive are identical with tracing on.
    assert_eq!(t_off, t_on, "tracing changed the decode trace");

    // (2) The rings held everything (no wrap) and saw the whole pipeline.
    let threads = trace::snapshot();
    let (held, dropped) = trace::event_counts();
    assert_eq!(dropped, 0, "ring wrapped: raise TWILIGHT_TRACE_CAP for this workload");
    assert!(held > 0);
    let count_stage = |st: Stage| -> usize {
        threads.iter().map(|t| t.spans.iter().filter(|s| s.stage == st).count()).sum()
    };
    for st in [
        Stage::Select,
        Stage::Prune,
        Stage::Spgemv,
        Stage::ToppSearch,
        Stage::SparseAttend,
        Stage::Append,
        Stage::Unembed,
        Stage::Step,
    ] {
        assert!(count_stage(st) > 0, "no {st:?} spans recorded");
    }
    assert!(
        count_stage(Stage::PoolRound) > 0,
        "threads={THREADS} with per-bucket tickets must take the pooled path"
    );
    assert!(count_stage(Stage::Step) as u64 >= DECODE_STEPS);
    assert_eq!(count_stage(Stage::HierPages), 0, "hier off: no hier spans");

    // (3) Spans nest: the pruner's sub-phases sit inside a Prune span on
    // the same thread, and per-layer appends inside the step umbrella.
    for t in &threads {
        assert_nested(t, Stage::Spgemv, Stage::Prune);
        assert_nested(t, Stage::ToppSearch, Stage::Prune);
    }
    // Step spans live on the engine (main) thread; Append/Unembed do too.
    let main_t = threads
        .iter()
        .find(|t| t.spans.iter().any(|s| s.stage == Stage::Step))
        .expect("some thread recorded Step spans");
    assert_nested(main_t, Stage::Append, Stage::Step);
    assert_nested(main_t, Stage::Unembed, Stage::Step);

    // (4) Stage totals reconcile with EngineStats: same measurements.
    let totals = trace::stage_totals();
    close(totals[Stage::Select as usize], stats.t_select, "select");
    close(totals[Stage::Prune as usize], stats.t_prune, "prune");
    close(totals[Stage::SparseAttend as usize], stats.t_attend, "sparse_attend");
    close(totals[Stage::DenseAttend as usize], stats.t_dense, "dense_attend");
    // 0 ≈ 0 in the default run; exact when TWILIGHT_SPARSE_PREFILL=1
    // flips the constructors' env-read default for the traced CI leg.
    close(totals[Stage::SparsePrefill as usize], stats.t_sprefill, "sparse_prefill");
    // Sub-phases are strict subsets of the prune umbrella.
    let sub = totals[Stage::Spgemv as usize] + totals[Stage::ToppSearch as usize];
    assert!(
        sub <= stats.t_prune * 1.001 + 1e-4,
        "spgemv+topp_search ({sub}) exceed the prune umbrella ({})",
        stats.t_prune
    );

    // (5) The Chrome export is valid JSON with well-formed events and
    // carries the tags the pipeline set.
    let rendered = trace::render_chrome();
    let parsed = twilight::util::json::Json::parse(&rendered).expect("chrome JSON parses");
    let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(events.len() as u64 >= held, "every held span exports (plus metadata)");
    let mut tagged = 0usize;
    for ev in events {
        let ph = ev.get_str("ph").unwrap();
        assert!(ph == "X" || ph == "M", "unexpected event phase {ph}");
        if ph == "X" {
            assert!(ev.get_f64("ts").is_some() && ev.get_f64("dur").is_some());
            assert!(ev.get_str("name").is_some());
            if let Some(args) = ev.get("args") {
                if args.get_f64("layer").is_some() {
                    tagged += 1;
                    assert_eq!(args.get_f64("layer"), Some(0.0), "1-layer model");
                }
            }
        }
    }
    assert!(tagged > 0, "no layer-tagged spans in the export");
}
