//! Chunked-prefill correctness battery: the unified mixed step must make
//! chunking *invisible* to the numerics —
//!
//! 1. prefill at spans 1 / 7 / 16 / 64 / monolithic produces bit-exact
//!    logits and KV (witnessed through subsequent decode logits) at any
//!    worker count, for dense and sparse pipelines, including stateful
//!    (observing) selectors;
//! 2. a mixed step (running decodes + a co-scheduled prefill chunk)
//!    leaves the decode items' logits bit-identical to a decode-only
//!    step;
//! 3. the scheduler's chunked admission completes long prompts across
//!    steps, and prompt-size-aware admission rejects prompts the pool
//!    can never hold (counted in the serving report);
//! 4. bound-guided sparse prefill (`--sparse-prefill`) is sound: off it
//!    never runs, at eps=0 it visits everything and matches the dense
//!    kernel, at working eps the logit drift stays mass-bounded, and its
//!    skip telemetry is thread- and span-invariant where defined.

use std::sync::Arc;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::{SparseConfig, SparsePrefillCfg};
use twilight::model::{Model, ModelConfig};
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;

/// A small multi-layer random model: the single-layer retrieval model
/// takes the O(n) embedding-KV fast path, which bypasses the chunk
/// machinery this battery exists to pin.
fn deep_model(seed: u64) -> Arc<Model> {
    let cfg = ModelConfig {
        name: "chunktest".into(),
        vocab_size: 32,
        d_model: 24,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 6,
        d_ff: 32,
        use_rope: true,
        rope_theta: 10000.0,
        use_norm: true,
        norm_eps: 1e-5,
        max_ctx: 512,
    };
    Arc::new(Model::random(&cfg, seed))
}

fn random_prompt(seed: u64, len: usize, vocab: usize) -> Vec<u32> {
    let mut r = Rng::new(seed);
    (0..len).map(|_| r.below(vocab) as u32).collect()
}

/// Telemetry fingerprint: everything the governor steers on, as exact
/// bits (chunking must be invisible to a governed deployment too).
#[derive(Debug, PartialEq)]
struct Telemetry {
    sparse_calls: u64,
    kept_sum: u64,
    candidates_sum: u64,
    probes: u64,
    mean_mass_bits: u64,
    probe_recall_bits: u64,
}

/// Prefill with the given chunk span + 3 decode steps; returns every
/// logits vector plus the telemetry fingerprint.
fn run_spans(
    model: &Arc<Model>,
    cfg: &SparseConfig,
    prompt: &[u32],
    span: usize,
    threads: usize,
) -> (Vec<Vec<f32>>, Telemetry) {
    let mut e = Engine::new(model.clone(), cfg.clone(), 4096);
    e.set_threads(threads);
    e.set_prefill_chunk(span);
    let mut all = vec![e.prefill(0, prompt).unwrap()];
    for _ in 0..3 {
        all.push(e.decode(0, prompt[0]).unwrap());
    }
    let t = Telemetry {
        sparse_calls: e.stats.sparse_calls,
        kept_sum: e.stats.kept_sum,
        candidates_sum: e.stats.candidates_sum,
        probes: e.signals.probes(),
        mean_mass_bits: e.signals.mean_mass().to_bits(),
        probe_recall_bits: e.signals.probe_recall().to_bits(),
    };
    (all, t)
}

#[test]
fn chunked_prefill_bit_exact_across_spans_dense() {
    let model = deep_model(1);
    let prompt = random_prompt(2, 100, 32);
    let mut cfg = SparseConfig::dense();
    // Bound-guided sparse prefill amortizes one envelope over the whole
    // chunk span, so its output is intentionally span-*sensitive*; the
    // invariance batteries pin the dense reference regardless of the
    // TWILIGHT_SPARSE_PREFILL env default.
    cfg.sparse_prefill = None;
    let (reference, ..) = run_spans(&model, &cfg, &prompt, 1, 1);
    for threads in [1usize, 4] {
        for span in [1usize, 7, 16, 64, 1000] {
            let (got, ..) = run_spans(&model, &cfg, &prompt, span, threads);
            assert_eq!(
                reference, got,
                "dense logits diverged at span={span} threads={threads}"
            );
        }
    }
}

#[test]
fn chunked_prefill_bit_exact_across_spans_sparse() {
    // The full Select-then-Prune pipeline, with the dense_below boundary
    // crossing *inside* chunks (early sub-calls dense, later ones
    // sparse) — the hardest invariance case: Quest page scores, the
    // pruner's SpGEMV, and the kept sets must all be pure functions of
    // each query's visible prefix.
    let model = deep_model(3);
    let prompt = random_prompt(4, 150, 32);
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 8;
    cfg.sparse_prefill = None; // span-invariance battery: see dense test
    let (reference, telemetry) = run_spans(&model, &cfg, &prompt, 1, 1);
    assert!(telemetry.sparse_calls > 0, "the battery must exercise the pruned path");
    assert!(telemetry.probes > 0, "the battery must exercise the recall probe");
    for threads in [1usize, 4, 8] {
        for span in [1usize, 7, 16, 64, 1000] {
            let (got, t2) = run_spans(&model, &cfg, &prompt, span, threads);
            assert_eq!(
                reference, got,
                "sparse logits diverged at span={span} threads={threads}"
            );
            // Token-major call indexing + token-major telemetry merge:
            // probe cadence and SignalHub contents — what a governor
            // steers on — must be bit-identical too, not just the
            // logits.
            assert_eq!(telemetry, t2, "telemetry diverged at span={span} threads={threads}");
        }
    }
}

#[test]
fn chunked_prefill_bit_exact_with_stateful_selector() {
    // SnapKV observes the attention it computed and selects from that
    // state: chunking must preserve the per-(seq, layer, kv-head) call
    // order exactly (sub-calls run serially, in chunk order, on one
    // worker) or the selector state — and then everything — drifts.
    let model = deep_model(5);
    let prompt = random_prompt(6, 120, 32);
    let mut cfg = SparseConfig::twilight(SelectorKind::SnapKv, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 8;
    cfg.sparse_prefill = None; // span-invariance battery: see dense test
    let (reference, ..) = run_spans(&model, &cfg, &prompt, 1, 1);
    for threads in [1usize, 4] {
        for span in [1usize, 16, 33] {
            let (got, ..) = run_spans(&model, &cfg, &prompt, span, threads);
            assert_eq!(
                reference, got,
                "snapkv logits diverged at span={span} threads={threads}"
            );
        }
    }
}

#[test]
fn mixed_step_leaves_decode_logits_unchanged() {
    // Co-scheduling a prefill chunk with running decodes must not change
    // the decode items' logits by a single bit: work items are
    // independent and merged in flattened order.
    let model = deep_model(7);
    let p0 = random_prompt(8, 90, 32);
    let p1 = random_prompt(9, 117, 32);
    let p2 = random_prompt(10, 80, 32);
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 8;
    cfg.sparse_prefill = None; // span-invariance battery: see dense test
    let mk = |threads: usize| {
        let mut e = Engine::new(model.clone(), cfg.clone(), 4096);
        e.set_threads(threads);
        let _ = e.prefill(0, &p0).unwrap();
        let _ = e.prefill(1, &p1).unwrap();
        e
    };
    for threads in [1usize, 4] {
        let mut a = mk(threads);
        let decode_only = DecodeBatch::new(vec![(0, p0[0]), (1, p1[0])]);
        let ra: Vec<Vec<f32>> =
            a.step_batch(&decode_only).into_iter().map(|r| r.unwrap()).collect();
        let mut b = mk(threads);
        b.start_empty(2);
        let mut mixed = DecodeBatch::new(vec![(0, p0[0]), (1, p1[0])]);
        mixed.push_chunk(2, p2[..64].to_vec(), false);
        let rb: Vec<Vec<f32>> = b
            .step_batch(&mixed)
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        assert_eq!(ra[0], rb[0], "decode 0 perturbed by a co-scheduled chunk (threads={threads})");
        assert_eq!(ra[1], rb[1], "decode 1 perturbed by a co-scheduled chunk (threads={threads})");
        assert_eq!(b.seq_len(2), Some(64), "chunk must advance the prefilling sequence");
        // Finish the interrupted prompt and check it against an
        // uninterrupted chunked prefill on a fresh engine.
        let tail: Vec<Vec<f32>> = b
            .step_batch(&DecodeBatch::chunk(2, p2[64..].to_vec(), true))
            .into_iter()
            .map(|r| r.unwrap())
            .collect();
        let mut solo = Engine::new(model.clone(), cfg.clone(), 4096);
        solo.set_threads(threads);
        solo.set_prefill_chunk(64);
        let want = solo.prefill(2, &p2).unwrap();
        assert_eq!(tail[0], want, "interleaved chunks diverged from solo prefill");
    }
}

/// `run_spans` plus the sparse-prefill skip counters.
fn run_sprefill(
    model: &Arc<Model>,
    cfg: &SparseConfig,
    prompt: &[u32],
    span: usize,
    threads: usize,
) -> (Vec<Vec<f32>>, (u64, u64)) {
    let mut e = Engine::new(model.clone(), cfg.clone(), 4096);
    e.set_threads(threads);
    e.set_prefill_chunk(span);
    let mut all = vec![e.prefill(0, prompt).unwrap()];
    for _ in 0..3 {
        all.push(e.decode(0, prompt[0]).unwrap());
    }
    (all, (e.stats.prefill_blocks_skipped, e.stats.prefill_blocks_total))
}

#[test]
fn sparse_prefill_eps_zero_matches_dense_reference() {
    // eps = 0 makes the early-stop test `rem*(1-eps) <= eps*ssum`
    // unsatisfiable while any suffix mass remains, so every gated page is
    // visited: the streaming-softmax path must then agree with the dense
    // kernel to accumulation-order rounding, and skip nothing. With the
    // flag off the path must not even be entered (counters stay zero).
    let model = deep_model(17);
    let prompt = random_prompt(18, 200, 32);
    let mut cfg = SparseConfig::dense();
    cfg.sparse_prefill = None;
    let (reference, (off_skipped, off_total)) = run_sprefill(&model, &cfg, &prompt, 64, 1);
    assert_eq!((off_skipped, off_total), (0, 0), "flag off must not touch the counters");
    cfg.sparse_prefill = Some(SparsePrefillCfg { eps: 0.0, window: 1 });
    let (got, (skipped, total)) = run_sprefill(&model, &cfg, &prompt, 64, 1);
    assert!(total > 0, "the deep-model prompt must gate pages");
    assert_eq!(skipped, 0, "eps=0 must visit every gated page");
    assert_eq!(reference.len(), got.len());
    for (r, g) in reference.iter().zip(&got) {
        for (a, b) in r.iter().zip(g) {
            assert!(
                (a - b).abs() < 1e-4,
                "eps=0 sparse prefill drifted from dense: {a} vs {b}"
            );
        }
    }
}

#[test]
fn sparse_prefill_keeps_mass_within_eps_of_dense() {
    // With a working eps the per-query kept softmax mass is >= 1-eps of
    // the dense total, which bounds the attention-output perturbation;
    // witnessed end-to-end through two layers and the unembed.
    let model = deep_model(19);
    let prompt = random_prompt(20, 256, 32);
    let mut cfg = SparseConfig::dense();
    cfg.sparse_prefill = None;
    let (reference, ..) = run_sprefill(&model, &cfg, &prompt, 64, 4);
    cfg.sparse_prefill = Some(SparsePrefillCfg { eps: 0.02, window: 16 });
    let (got, (_, total)) = run_sprefill(&model, &cfg, &prompt, 64, 4);
    assert!(total > 0, "sparse prefill must have run");
    let mut worst = 0.0f32;
    for (r, g) in reference.iter().zip(&got) {
        for (a, b) in r.iter().zip(g) {
            worst = worst.max((a - b).abs());
        }
    }
    assert!(worst < 0.25, "eps=0.02 logit drift too large: {worst}");
}

#[test]
fn sparse_prefill_skip_telemetry_is_thread_and_span_invariant() {
    // On the single-layer retrieval model only the final prompt token
    // routes through attend, so the sparse-prefill call sees the same
    // lone query whatever the chunk span or worker count: logits, the
    // retrieved answer, and the skip counters must all be identical —
    // and the peaked NIAH cache must actually skip pages.
    let model = Arc::new(twilight::model::retrieval::build_retrieval_model(V, 8192));
    let mut r = Rng::new(23);
    let g = gen_niah(&mut r, V, 1024);
    let mut cfg = SparseConfig::dense();
    cfg.sparse_prefill = Some(SparsePrefillCfg::default());
    let mut run = |span: usize, threads: usize| {
        let mut e = Engine::new(model.clone(), cfg.clone(), 1 << 13);
        e.set_threads(threads);
        e.set_prefill_chunk(span);
        let logits = e.prefill(0, &g.prompt).unwrap();
        (logits, (e.stats.prefill_blocks_skipped, e.stats.prefill_blocks_total))
    };
    let (ref_logits, ref_counters) = run(64, 1);
    assert!(ref_counters.1 > 0, "NIAH@1024 must gate pages");
    assert!(ref_counters.0 > 0, "a peaked cache must skip pages");
    let argmax = |v: &[f32]| {
        v.iter().enumerate().fold((0usize, f32::MIN), |best, (i, &x)| {
            if x > best.1 {
                (i, x)
            } else {
                best
            }
        })
    };
    assert_eq!(argmax(&ref_logits).0 as u32, g.answer, "sparse prefill broke retrieval");
    for (span, threads) in [(64, 4), (64, 8), (256, 1), (1000, 4)] {
        let (logits, counters) = run(span, threads);
        assert_eq!(ref_logits, logits, "logits diverged at span={span} threads={threads}");
        assert_eq!(ref_counters, counters, "skip counters diverged at span={span} threads={threads}");
    }
}

#[test]
fn engine_prefill_chunk_knob_clamps() {
    let model = deep_model(11);
    let mut e = Engine::new(model, SparseConfig::dense(), 1024);
    e.set_prefill_chunk(0);
    assert_eq!(e.prefill_chunk(), 1, "span must clamp to >= 1");
    e.set_prefill_chunk(128);
    assert_eq!(e.prefill_chunk(), 128);
}

#[test]
fn scheduler_rejects_never_fitting_prompt() {
    // A prompt larger than the whole page pool used to be admitted, fail
    // mid-prefill, and bounce forever; it must now be rejected up front
    // and counted, while well-sized requests keep flowing.
    let model = Arc::new(twilight::model::retrieval::build_retrieval_model(V, 8192));
    let engine = Engine::new(model, SparseConfig::dense(), 256); // 17 pages
    let mut s = Scheduler::new(engine, SchedulerConfig::default());
    let mut r = Rng::new(12);
    let big = gen_niah(&mut r, V, 512); // 32 pages: can never fit
    let small = gen_niah(&mut r, V, 64); // 4 pages: fits comfortably
    s.submit(Request::new(0, big.prompt.clone(), 1));
    s.submit(Request::new(1, small.prompt.clone(), 1));
    let rep = s.run_to_completion();
    assert_eq!(rep.requests.len(), 2);
    assert_eq!(rep.rejected(), 1, "oversized prompt must be rejected");
    let small_done = s
        .finished_requests()
        .iter()
        .find(|q| q.id == 1)
        .expect("small request must finish");
    assert_eq!(small_done.output.first(), Some(&small.answer));
    assert_eq!(s.engine.num_seqs(), 0, "pages leaked");
}

#[test]
fn preempted_request_readmits_without_rejection() {
    // A preempted request's folded prompt (original prompt + generated
    // tokens) may cross the admission-policy headroom bound; it must be
    // parked and re-admitted on the true feasibility bound — never
    // terminally rejected, which would discard already-served work the
    // pool can still hold.
    let model = Arc::new(twilight::model::retrieval::build_retrieval_model(V, 8192));
    let engine = Engine::new(model, SparseConfig::dense(), 256); // 17 pages
    let mut s = Scheduler::new(
        engine,
        SchedulerConfig { admit_headroom_pages: 8, ..Default::default() },
    );
    let mut r = Rng::new(14);
    for i in 0..2 {
        let g = gen_niah(&mut r, V, 100);
        let mut req = Request::new(i, g.prompt, 60);
        req.stop_token = None;
        s.submit(req);
    }
    let rep = s.run_to_completion();
    assert_eq!(rep.requests.len(), 2);
    assert!(rep.preemptions() > 0, "the undersized pool must actually preempt");
    assert_eq!(rep.rejected(), 0, "preempted work must be re-admitted, not rejected");
    for q in s.finished_requests() {
        assert_eq!(q.output.len(), 60, "request {} truncated", q.id);
    }
    assert_eq!(s.engine.num_seqs(), 0, "pages leaked");
}

#[test]
fn scheduler_chunks_long_admission_across_steps() {
    // A long prompt admitted among running decodes prefills across
    // multiple mixed steps under the per-step token budget, while the
    // short requests keep decoding every step.
    let model = Arc::new(twilight::model::retrieval::build_retrieval_model(V, 8192));
    let mut engine = Engine::new(model, SparseConfig::twilight(SelectorKind::Quest, 0.9), 1 << 14);
    engine.set_prefill_chunk(64);
    let mut s = Scheduler::new(
        engine,
        SchedulerConfig { max_batch: 8, max_prefill_tokens_per_step: 128, ..Default::default() },
    );
    let mut r = Rng::new(13);
    for i in 0..4 {
        let g = gen_niah(&mut r, V, 128);
        let mut req = Request::new(i, g.prompt, 16);
        req.stop_token = None;
        s.submit(req);
    }
    let long = gen_niah(&mut r, V, 2048);
    s.submit(Request::new(4, long.prompt.clone(), 1));
    let rep = s.run_to_completion();
    assert_eq!(rep.requests.len(), 5, "everything must complete");
    assert_eq!(rep.rejected(), 0);
    // The long prompt cannot fit one step's budget: chunked admission
    // must have spanned multiple steps.
    assert!(
        s.engine.stats.prefill_chunks as usize >= 2048 / 64,
        "expected many chunks, got {}",
        s.engine.stats.prefill_chunks
    );
    let long_done = s.finished_requests().iter().find(|q| q.id == 4).unwrap();
    assert_eq!(long_done.output.first(), Some(&long.answer), "chunked prefill broke retrieval");
    let lm = rep.requests.iter().find(|m| m.id == 4).unwrap();
    assert!(lm.prefill_time() >= 0.0);
    assert!(lm.ttft() >= lm.prefill_time() - 1e-9, "ttft must cover queue + prefill");
    assert_eq!(s.engine.num_seqs(), 0, "pages leaked");
}
