//! Golden-trace regression for the batched decode path: a fixed-seed
//! end-to-end run (prefill + governed decode steps + a chunked-admission
//! segment where a fourth sequence prefills in 32-token chunks
//! co-scheduled with the running decodes) whose sampled token ids,
//! budget counters, and telemetry are (1) bit-identical for any worker
//! count — the persistent pool's determinism contract — and (2) pinned
//! against a checked-in golden so *future* PRs cannot change decode (or
//! mixed-step) behavior silently.
//!
//! Everything in the trace is deterministic by construction: workload
//! and sampling use fixed `util::rng` seeds, the governor runs the
//! `mass` policy (it steers on prune-mass/recall telemetry, which is
//! worker-count invariant) at virtual timestamps, and no wall-clock
//! quantity is snapshotted. Floats are stored as IEEE-754 bit patterns
//! so the comparison is exact, not epsilon.
//!
//! Golden lifecycle: the file bootstraps on the first run (written to
//! `rust/tests/golden/`, commit it), compares on every run after, and
//! regenerates with `TWILIGHT_UPDATE_GOLDEN=1`. Until the bootstrapped
//! file is committed, cross-PR drift is NOT pinned — a bootstrap run in
//! CI emits a loud warning annotation saying so. Within one CI workflow
//! run the pin is still real: the TWILIGHT_THREADS=1 leg bootstraps and
//! the =4/=8/release legs then compare against that file, so
//! worker-count- or optimization-dependent divergence fails the run
//! either way.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::SparseConfig;
use twilight::governor::{Governor, GovernorConfig};
use twilight::model::retrieval::build_retrieval_model;
use twilight::model::sampler::{sample, SamplingParams};
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::util::threadpool;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;
const SEQS: u64 = 3;
const DECODE_STEPS: u64 = 12;
/// Chunked-admission segment: the 4th sequence's prompt (96 + 1 query
/// token) enters in 32-token chunks → 4 mixed steps.
const CHUNK_PROMPT_CTX: usize = 96;
const CHUNK_SPAN: usize = 32;
const CHUNK_STEPS: u64 = (CHUNK_PROMPT_CTX as u64 + 1).div_ceil(CHUNK_SPAN as u64);

/// Everything the golden pins. Floats live here as bit patterns so
/// `PartialEq` is exact equality, matching the render format.
#[derive(Clone, Debug, PartialEq)]
struct Trace {
    /// Sampled token ids, step-major then sequence-major (prefill's
    /// first sampled token per sequence comes first).
    tokens: Vec<u32>,
    kept_sum: u64,
    candidates_sum: u64,
    sparse_calls: u64,
    steps: u64,
    prefill_tokens: u64,
    probes: u64,
    est_bytes_select: u64,
    est_bytes_prune: u64,
    est_bytes_attend: u64,
    mean_mass_bits: u64,
    probe_recall_bits: u64,
    /// Final governor directive (proves the control loop itself is
    /// worker-count invariant).
    p_scale_bits: u32,
    budget_scale_bits: u32,
}

impl Trace {
    fn render(&self) -> String {
        let toks: Vec<String> = self.tokens.iter().map(|t| t.to_string()).collect();
        // The `prefill_steps` wire label is the historical name of what
        // is now `EngineStats::prefill_tokens` — kept literal so the
        // checked-in golden bytes stay stable across the rename.
        format!(
            "twilight golden decode trace v1\n\
             tokens {}\n\
             kept_sum {}\n\
             candidates_sum {}\n\
             sparse_calls {}\n\
             steps {}\n\
             prefill_steps {}\n\
             probes {}\n\
             est_bytes_select {}\n\
             est_bytes_prune {}\n\
             est_bytes_attend {}\n\
             mean_mass {:016x}\n\
             probe_recall {:016x}\n\
             p_scale {:08x}\n\
             budget_scale {:08x}\n",
            toks.join(" "),
            self.kept_sum,
            self.candidates_sum,
            self.sparse_calls,
            self.steps,
            self.prefill_tokens,
            self.probes,
            self.est_bytes_select,
            self.est_bytes_prune,
            self.est_bytes_attend,
            self.mean_mass_bits,
            self.probe_recall_bits,
            self.p_scale_bits,
            self.budget_scale_bits,
        )
    }
}

/// Run the fixed-seed governed decode trace with `threads` attention
/// workers.
fn run_trace(threads: usize) -> Trace {
    let model = Arc::new(build_retrieval_model(V, 1 << 13));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    // This trace pins the *default* pipeline: force the opt-in hier-pages
    // pre-prune off so the TWILIGHT_HIER_PAGES=1 CI leg (which flips the
    // env-read default in SparseConfig::twilight) compares against the
    // same checked-in golden.
    if let Some(t) = cfg.twilight.as_mut() {
        t.hier_pages = false;
    }
    // Same reasoning for the opt-in sparse-prefill path: the
    // TWILIGHT_SPARSE_PREFILL=1 CI leg flips the constructors' env-read
    // default, and the envelope bound depends on the chunk span, so the
    // golden pins the dense prefill reference explicitly.
    cfg.sparse_prefill = None;
    let mut e = Engine::new(model, cfg, 1 << 13);
    e.set_threads(threads);
    // Governor on: the mass policy steers p from prune-mass telemetry
    // and the dense recall probe — both deterministic and merged in
    // flattened item order, so its decisions are too.
    let mut gov = Governor::new("mass", GovernorConfig::default()).expect("mass policy exists");
    let mut wl_rng = Rng::new(0xD0_6E);
    let mut sample_rng = Rng::new(0x5A11);
    let params = SamplingParams { temperature: 0.8, top_p: 0.9 };
    let mut tokens = Vec::new();
    let mut frontier: Vec<(u64, u32)> = Vec::new();
    for i in 0..SEQS {
        // Mixed context lengths → skewed per-head budgets for the LPT.
        let g = gen_niah(&mut wl_rng, V, 192 + 128 * i as usize);
        let logits = e.prefill(i, &g.prompt).expect("prefill fits the page pool");
        let tok = sample(&logits, &params, &mut sample_rng);
        tokens.push(tok);
        frontier.push((i, tok));
    }
    for step in 0..DECODE_STEPS {
        // Virtual time: governor decisions must not read the wall clock.
        let free_frac = e.free_pages() as f64 / e.total_pages().max(1) as f64;
        let snap = gov.snapshot(
            step as f64 * 0.01,
            &e.signals,
            free_frac,
            0,
            frontier.len(),
            e.stats.steps,
        );
        let d = gov.step(&snap);
        e.apply_directive(d);
        let batch = DecodeBatch::new(frontier.clone());
        let results = e.step_batch(&batch);
        for (slot, res) in frontier.iter_mut().zip(results) {
            let logits = res.expect("golden trace must not OOM");
            let tok = sample(&logits, &params, &mut sample_rng);
            tokens.push(tok);
            slot.1 = tok;
        }
    }
    // --- chunked-admission segment ------------------------------------
    // A 4th sequence prefills in CHUNK_SPAN-token chunks co-scheduled
    // with the frontier decodes: mixed steps, one chunk per step. The
    // decodes keep sampling every step; the newcomer samples its first
    // token after its final chunk. Pins mixed-step determinism and the
    // decode-isolation contract into the golden.
    let g3 = gen_niah(&mut wl_rng, V, CHUNK_PROMPT_CTX);
    e.start_empty(SEQS);
    let mut cursor = 0;
    while cursor < g3.prompt.len() {
        let end = (cursor + CHUNK_SPAN).min(g3.prompt.len());
        let mut batch = DecodeBatch::default();
        for &(id, tok) in frontier.iter() {
            batch.push_decode(id, tok);
        }
        batch.push_chunk(SEQS, g3.prompt[cursor..end].to_vec(), end == g3.prompt.len());
        let mut results = e.step_batch(&batch).into_iter();
        for slot in frontier.iter_mut() {
            let logits = results.next().unwrap().expect("golden trace must not OOM");
            let tok = sample(&logits, &params, &mut sample_rng);
            tokens.push(tok);
            slot.1 = tok;
        }
        let chunk_logits = results.next().unwrap().expect("golden trace must not OOM");
        cursor = end;
        if cursor == g3.prompt.len() {
            let tok = sample(&chunk_logits, &params, &mut sample_rng);
            tokens.push(tok);
        }
    }
    let d = e.directive();
    Trace {
        tokens,
        kept_sum: e.stats.kept_sum,
        candidates_sum: e.stats.candidates_sum,
        sparse_calls: e.stats.sparse_calls,
        steps: e.stats.steps,
        prefill_tokens: e.stats.prefill_tokens,
        probes: e.signals.probes(),
        est_bytes_select: e.stats.est_bytes_select,
        est_bytes_prune: e.stats.est_bytes_prune,
        est_bytes_attend: e.stats.est_bytes_attend,
        mean_mass_bits: e.signals.mean_mass().to_bits(),
        probe_recall_bits: e.signals.probe_recall().to_bits(),
        p_scale_bits: d.p_scale.to_bits(),
        budget_scale_bits: d.budget_scale.to_bits(),
    }
}

fn golden_path() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("rust/tests/golden/decode_trace_v1.txt")
}

#[test]
fn golden_decode_trace_pinned_across_worker_counts() {
    // The golden was recorded on the scalar kernel backend (the verbatim
    // historical loop bodies); pin it so the TWILIGHT_KERNEL=auto CI leg
    // compares against the same checked-in bytes. SIMD-vs-scalar parity
    // is covered separately (eps-bounded) in rust/tests/simd_parity.rs.
    twilight::tensor::kernels::force_scalar();
    let t1 = run_trace(1);
    // Decode steps + the mixed (decode + chunk) steps of the admission
    // segment all advance decode items, so all count as steps.
    assert_eq!(t1.steps, DECODE_STEPS + CHUNK_STEPS);
    // Per sequence: one prefill token + DECODE_STEPS + CHUNK_STEPS decode
    // tokens; plus the newcomer's single first token.
    assert_eq!(
        t1.tokens.len() as u64,
        SEQS * (DECODE_STEPS + CHUNK_STEPS + 1) + 1
    );
    // Chunked admission pushed the whole 4th prompt through the forward
    // pass (the first three prompts ride the 1-layer fast path: one
    // token each).
    assert_eq!(t1.prefill_tokens, SEQS + CHUNK_PROMPT_CTX as u64 + 1);
    assert!(t1.sparse_calls > 0, "the trace must exercise the pruned path");
    assert!(t1.probes > 0, "the trace must exercise the recall probe");
    // (1) Bit-exactness across worker counts — the pool contract. The
    // CI matrix additionally runs this whole test under
    // TWILIGHT_THREADS=1/4/8, covered by the env-default run below.
    for threads in [4usize, 8] {
        let tn = run_trace(threads);
        assert_eq!(t1, tn, "decode trace diverged at threads={threads}");
    }
    let tdef = run_trace(threadpool::default_threads());
    assert_eq!(t1, tdef, "env-sized default pool diverged from the sequential reference");
    // (2) The checked-in golden pins the trace against future behavior
    // drift (bootstraps on first run; TWILIGHT_UPDATE_GOLDEN=1 refreshes).
    let rendered = t1.render();
    let path = golden_path();
    let update = std::env::var("TWILIGHT_UPDATE_GOLDEN").is_ok_and(|v| v == "1");
    match std::fs::read_to_string(&path) {
        Ok(golden) if !update => {
            assert_eq!(
                golden.trim(),
                rendered.trim(),
                "decode trace diverged from the checked-in golden at {}.\n\
                 If this change is intentional, regenerate with\n\
                 TWILIGHT_UPDATE_GOLDEN=1 cargo test --test golden_decode\n\
                 and commit the refreshed file.",
                path.display()
            );
        }
        _ => {
            std::fs::create_dir_all(path.parent().expect("golden dir"))
                .expect("create golden dir");
            std::fs::write(&path, rendered.as_bytes()).expect("write golden");
            eprintln!("golden_decode: wrote {} — commit this file", path.display());
            if !update && std::env::var("CI").is_ok() {
                // GitHub annotation: a missing golden in CI means this
                // run pinned nothing across PRs (later legs of the same
                // run do compare against this bootstrap, so worker-count
                // drift is still caught). Keep it green but loud.
                println!(
                    "::warning file=rust/tests/golden_decode.rs::golden decode trace was \
                     bootstrapped in CI — commit rust/tests/golden/decode_trace_v1.txt to pin \
                     decode behavior across PRs"
                );
            }
        }
    }
}
