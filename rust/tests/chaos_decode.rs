//! Fault-domain hardening soak (DESIGN.md §14): decode under seeded
//! chaos injection must never crash the process, must contain every
//! fault to the owning request, and must keep survivors *bit-exact*
//! with the fault-free run — a transient tier error heals through the
//! retry ladder by restoring the exact spilled bytes, and an exhausted
//! ladder kills exactly one sequence (its pages reclaimed, surfaced as
//! `CacheError::PageLost`) while its neighbors never see a byte move.
//!
//! Determinism contract: fault decisions are pure hashes of
//! `(seed, op, page, attempt-ordinal)`, and the set of tier reads per
//! run is deterministic (one read per page per eviction epoch under the
//! step-clock LRU), so the injected-fault counters and the *positions*
//! of contained errors are invariant across worker counts. Only
//! latency is allowed to vary with threads.
//!
//! Every run pins residency and chaos explicitly, so the battery is
//! immune to `TWILIGHT_RESIDENT_FRAC` / `TWILIGHT_CHAOS` being exported
//! by the CI chaos leg.

use std::sync::Arc;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::kvcache::offload::ChaosConfig;
use twilight::model::retrieval::build_retrieval_model;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;

/// Same pool shape as `offload_decode.rs`: 3 sequences at 256/512/768
/// tokens use ~97 of the 128 pages, so frac 0.25 (cap 32) forces
/// evictions and therefore tier reads for every run.
const CAPACITY: usize = 2048;

struct ChaosOut {
    /// Per (step, seq) decode result: `None` = contained fault.
    logits: Vec<Option<Vec<f32>>>,
    read_errors: u64,
    write_errors: u64,
    retries: u64,
    pages_lost: u64,
}

/// Replay the fixed 3-sequence, 8-step decode trace with `threads`
/// attention workers. Prefill runs fully resident (the pin below
/// neutralizes any CI-leg env *before* the prompt phase); the
/// (optionally chaos-wrapped) tier attaches afterwards at frac 0.25,
/// so every injected fault lands in the decode phase all variants
/// share.
fn run_chaos_trace(threads: usize, chaos: Option<ChaosConfig>) -> ChaosOut {
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    let mut e = Engine::new(model, cfg, CAPACITY);
    e.set_threads(threads);
    e.set_resident_frac(1.0);
    e.set_chaos(None);
    let mut rng = Rng::new(71);
    let mut toks = Vec::new();
    for i in 0..3u64 {
        let g = gen_niah(&mut rng, V, 256 * (i as usize + 1));
        let _ = e.prefill(i, &g.prompt).unwrap();
        toks.push(g.prompt[0]);
    }
    e.set_chaos(chaos);
    e.set_resident_frac(0.25);
    // A sequence whose retry ladder exhausts fails once — the engine
    // releases it (pages reclaimed) and the scheduler would retire the
    // request — so it drops out of later batches while its neighbors
    // keep decoding undisturbed.
    let mut failed = [false; 3];
    let mut logits = Vec::new();
    for _ in 0..8 {
        let mut batch = DecodeBatch::default();
        for i in 0..3u64 {
            if !failed[i as usize] {
                batch.push_decode(i, toks[i as usize]);
            }
        }
        let mut results = e.step_batch(&batch).into_iter();
        for i in 0..3usize {
            if failed[i] {
                logits.push(None);
                continue;
            }
            match results.next().unwrap() {
                Ok(l) => logits.push(Some(l)),
                Err(_) => {
                    failed[i] = true;
                    logits.push(None);
                }
            }
        }
    }
    ChaosOut {
        logits,
        read_errors: e.stats.tier_read_errors,
        write_errors: e.stats.tier_write_errors,
        retries: e.stats.tier_retries,
        pages_lost: e.stats.pages_lost,
    }
}

/// Moderate fault rates: plenty of transient read errors (healed by the
/// retry ladder), occasional torn writes (pin pages resident), and a
/// small panic rate to exercise the in-funnel `catch_unwind`.
const SOAK: ChaosConfig = ChaosConfig { seed: 7, p_read: 0.5, p_write: 0.1, p_panic: 0.05 };

#[test]
fn chaos_survivors_bit_exact_and_counters_thread_invariant() {
    let baseline = run_chaos_trace(1, None);
    assert!(
        baseline.logits.iter().all(|l| l.is_some()),
        "fault-free run must complete every decode"
    );
    assert_eq!(baseline.read_errors, 0);
    assert_eq!(baseline.pages_lost, 0);

    let t1 = run_chaos_trace(1, Some(SOAK));
    let t4 = run_chaos_trace(4, Some(SOAK));
    assert_eq!(t1.logits.len(), baseline.logits.len());
    assert_eq!(t4.logits.len(), baseline.logits.len());
    // Injected faults actually happened (seeded, so this is a fixed
    // property of the trace, not a flake).
    assert!(t1.read_errors > 0, "soak must inject read faults");
    assert!(t1.retries > 0, "retry ladder must engage");
    // Counters are pure functions of (seed, page, attempt-ordinal), so
    // the worker count must not move them.
    assert_eq!(t1.read_errors, t4.read_errors, "read-error count varied with threads");
    assert_eq!(t1.write_errors, t4.write_errors, "write-error count varied with threads");
    assert_eq!(t1.retries, t4.retries, "retry count varied with threads");
    assert_eq!(t1.pages_lost, t4.pages_lost, "lost-page count varied with threads");
    for (i, (a, b)) in t1.logits.iter().zip(&t4.logits).enumerate() {
        // Same contained-error positions at any thread count…
        assert_eq!(a.is_some(), b.is_some(), "error position {i} varied with threads");
        // …and every survivor is bit-exact with the fault-free run:
        // healed retries restored the exact spilled bytes.
        if let (Some(chaos_l), Some(base_l)) = (a, &baseline.logits[i]) {
            assert_eq!(chaos_l, base_l, "surviving logits diverged at position {i}");
        }
        if let (Some(chaos_l), Some(base_l)) = (b, &baseline.logits[i]) {
            assert_eq!(chaos_l, base_l, "surviving logits diverged at position {i} (t4)");
        }
    }
}

/// `p_read = 1.0`: every tier read exhausts its retry ladder, so any
/// sequence that needs a faulted page terminally fails with `PageLost`.
/// The scheduler must contain that — failed requests accounted in the
/// report, their pages reclaimed, the rest served — at any thread
/// count, with identical failure counts.
#[test]
fn lethal_chaos_fails_requests_loudly_and_reclaims_pages() {
    let mut seen: Option<(usize, u64)> = None;
    for &threads in &[1usize, 4] {
        let model = Arc::new(build_retrieval_model(V, 1 << 14));
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut engine = Engine::new(model, cfg, CAPACITY);
        engine.set_threads(threads);
        engine.set_chaos(Some(ChaosConfig { seed: 11, p_read: 1.0, p_write: 0.0, p_panic: 0.0 }));
        engine.set_resident_frac(0.25);
        let mut s = Scheduler::new(engine, SchedulerConfig::default());
        let mut rng = Rng::new(73);
        for i in 0..3u64 {
            let g = gen_niah(&mut rng, V, 256 * (i as usize + 1));
            s.submit(Request::new(i, g.prompt, 4));
        }
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 3);
        // The 768-token request alone overflows the cap-32 resident
        // set, so at least one request must hit a lost page.
        assert!(rep.failed() >= 1, "lethal chaos must fail a request (threads={threads})");
        assert!(rep.completion_rate() < 1.0);
        assert!(rep.pages_lost >= 1);
        assert!(rep.tier_read_errors >= 1);
        let j = rep.to_json();
        assert_eq!(j.get_f64("failed"), Some(rep.failed() as f64));
        assert!(j.get_f64("failed_page_lost").unwrap() >= 1.0);
        assert!(j.get_f64("completion_rate").unwrap() < 1.0);
        // Containment: every page came back — failed requests released
        // theirs — and the engine holds no sequences.
        assert_eq!(
            s.engine.free_pages(),
            s.engine.total_pages(),
            "failed requests must release their pages (threads={threads})"
        );
        // Failure accounting is thread-invariant (determinism contract).
        match seen {
            None => seen = Some((rep.failed(), rep.pages_lost)),
            Some(prev) => assert_eq!(
                prev,
                (rep.failed(), rep.pages_lost),
                "failure accounting varied with threads"
            ),
        }
    }
}
