//! Property-based tests (via the in-crate `util::prop` harness) on the
//! coordinator's core invariants: quantization error bounds, top-p mass,
//! page-allocator safety, selector contracts, scheduler conservation,
//! and JSON round-tripping.

use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::{BudgetSpec, SparseConfig};
use twilight::kvcache::{CacheConfig, PagedKvCache, SeqCache};
use twilight::model::retrieval::build_retrieval_model;
use twilight::pruner::topp::{topp_binary_search, topp_sort};
use twilight::pruner::{prune_group, prune_head, PrunerConfig, PrunerScratch};
use twilight::selector::SelectorKind;
use twilight::tensor::quant::{self, QuantBits};
use twilight::tensor::softmax_inplace;
use twilight::util::json::Json;
use twilight::util::prop::{check, check_default, Config};
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

#[test]
fn prop_quant_roundtrip_bounded_all_widths() {
    check_default("quant-roundtrip", |rng| {
        let n = rng.range(1, 200);
        let std = 0.1 + rng.f32() * 5.0;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, std)).collect();
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8] {
            let b = quant::quantize(&xs, bits);
            let mut out = vec![0.0; n];
            quant::dequantize_into(&b, &mut out);
            let bound = quant::max_error(&b) + 1e-5;
            for (a, c) in xs.iter().zip(&out) {
                if (a - c).abs() > bound {
                    return Err(format!("{bits:?}: |{a} - {c}| > {bound}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_topp_mass_invariant() {
    check_default("topp-mass", |rng| {
        let n = rng.range(2, 2000);
        let sharp = 0.2 + rng.f32() * 8.0;
        let mut w: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, sharp)).collect();
        softmax_inplace(&mut w);
        let p = 0.3 + rng.f32() * 0.69;
        let r = topp_binary_search(&w, p, 1e-6);
        if r.mass < p - 1e-3 {
            return Err(format!("mass {} < p {p}", r.mass));
        }
        // Never larger than the oracle by more than threshold ties.
        let o = topp_sort(&w, p);
        if r.indices.len() + 0 < o.indices.len().saturating_sub(1) {
            return Err("binary search kept fewer than the minimal set".into());
        }
        Ok(())
    });
}

/// Random cache with `n` tokens on one KV head (keys = values).
fn random_head_cache(rng: &mut Rng, d: usize, n: usize) -> (PagedKvCache, SeqCache) {
    let mut cache = PagedKvCache::new(CacheConfig::new(1, d, n / 16 + 2));
    let mut seq = SeqCache::default();
    for _ in 0..n {
        let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        cache.append(&mut seq, &k, &k).unwrap();
    }
    (cache, seq)
}

/// Threshold dominance: every kept weight must be ≥ every dropped
/// weight (ties may be split only by the mass guard, which widens in
/// descending order — equality is allowed).
fn check_dominance(w: &[f32], kept: &[usize]) -> Result<(), String> {
    let mut is_kept = vec![false; w.len()];
    for &i in kept {
        is_kept[i] = true;
    }
    let min_kept =
        kept.iter().map(|&i| w[i]).fold(f32::INFINITY, f32::min);
    let max_dropped = w
        .iter()
        .zip(&is_kept)
        .filter(|(_, &k)| !k)
        .map(|(&x, _)| x)
        .fold(f32::NEG_INFINITY, f32::max);
    if max_dropped > min_kept + 1e-6 {
        return Err(format!(
            "dropped weight {max_dropped} exceeds kept weight {min_kept}"
        ));
    }
    Ok(())
}

#[test]
fn prop_pruner_min_keep_floor_edge_cases() {
    check(
        "pruner-min-keep-edges",
        Config { cases: 24, ..Default::default() },
        |rng| {
            let d = 16;
            let n = rng.range(4, 120);
            let (cache, seq) = random_head_cache(rng, d, n);
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let candidates: Vec<usize> = (0..n).collect();
            let mut scratch = PrunerScratch::default();
            // min_keep >= n: the pruner must short-circuit to keep-all
            // with full mass and *empty* weights (nothing was scored —
            // the documented fall-back-to-exact contract).
            let cfg = PrunerConfig { p: 0.5, min_keep: n + rng.below(10), ..Default::default() };
            let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
            if out.kept != candidates {
                return Err(format!("min_keep>=n must keep all: kept {}", out.kept.len()));
            }
            if out.mass != 1.0 {
                return Err(format!("short-circuit mass must be 1.0, got {}", out.mass));
            }
            if !out.weights.is_empty() {
                return Err("short-circuit must not fabricate weights".into());
            }
            let (union, outs) =
                prune_group(&cfg, &cache, &seq, 0, &q, 1, &candidates, &mut scratch);
            if union != candidates || outs[0].kept != candidates || !outs[0].weights.is_empty() {
                return Err("group path must share the short-circuit contract".into());
            }
            // min_keep just below n with a near-zero p: the floor rules,
            // and the weights stay aligned with the truthful (recomputed)
            // mass of the floored set.
            let cfg = PrunerConfig { p: 1e-4, min_keep: n - 1, ..Default::default() };
            let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
            if out.kept.len() != n - 1 {
                return Err(format!("floor must keep n-1={} tokens, got {}", n - 1, out.kept.len()));
            }
            if out.weights.len() != out.kept.len() {
                return Err("floored weights must align with kept".into());
            }
            let sum: f32 = out.weights.iter().sum();
            if (sum - out.mass).abs() > 1e-3 {
                return Err(format!("floored weights sum {sum} vs mass {}", out.mass));
            }
            if out.mass > 1.0 + 1e-4 {
                return Err(format!("mass {} exceeds 1", out.mass));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_topp_p_one_boundary_with_ties() {
    check_default("topp-p1-ties", |rng| {
        // A handful of distinct raw values → heavy ties, including at
        // whatever cutoff top-p lands on.
        let n = rng.range(4, 400);
        let levels = 1 + rng.below(4);
        let vals: Vec<f32> = (0..levels).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let mut w: Vec<f32> = (0..n).map(|_| *rng.choose(&vals)).collect();
        softmax_inplace(&mut w);
        // p at the 1.0 boundary: the kept mass must be (fp-)complete and
        // the threshold rule must not keep a smaller weight over a
        // bigger dropped one.
        let r = topp_binary_search(&w, 1.0, 1e-6);
        if r.mass < 1.0 - 1e-3 {
            return Err(format!("p=1.0 kept mass {} (n={n}, levels={levels})", r.mass));
        }
        check_dominance(&w, &r.indices)?;
        let o = topp_sort(&w, 1.0);
        if o.mass < 1.0 - 1e-3 {
            return Err(format!("sort oracle p=1.0 kept mass {}", o.mass));
        }
        // Interior p with exact ties at the cutoff: mass invariant and
        // dominance must both survive the tie group.
        let p = 0.3 + rng.f32() * 0.69;
        let r = topp_binary_search(&w, p, 1e-7);
        if r.mass < p - 1e-3 {
            return Err(format!("tied cutoff: mass {} < p {p}", r.mass));
        }
        check_dominance(&w, &r.indices)?;
        if r.indices.windows(2).any(|x| x[0] >= x[1]) {
            return Err("indices must be strictly ascending".into());
        }
        Ok(())
    });
}

#[test]
fn prop_prune_outcome_weights_invariants() {
    check(
        "prune-weights",
        Config { cases: 24, ..Default::default() },
        |rng| {
            let d = 16;
            let n = rng.range(24, 220);
            let (cache, seq) = random_head_cache(rng, d, n);
            let group = 1 + rng.below(4);
            let qs: Vec<f32> = (0..group * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let candidates: Vec<usize> = (0..n).filter(|_| rng.chance(0.7)).collect();
            let min_keep = 1 + rng.below(8);
            if candidates.len() <= min_keep + 4 {
                return Ok(()); // short-circuit regime covered elsewhere
            }
            let p = 0.3 + rng.f32() * 0.69;
            let cfg = PrunerConfig { p, min_keep, ..Default::default() };
            let mut scratch = PrunerScratch::default();
            let (union, outs) =
                prune_group(&cfg, &cache, &seq, 0, &qs, group, &candidates, &mut scratch);
            let mut rebuilt: Vec<usize> = Vec::new();
            for (g, o) in outs.iter().enumerate() {
                if o.weights.len() != o.kept.len() {
                    return Err(format!(
                        "head {g}: weights {} misaligned with kept {}",
                        o.weights.len(),
                        o.kept.len()
                    ));
                }
                let sum: f32 = o.weights.iter().sum();
                if (sum - o.mass).abs() > 1e-3 {
                    return Err(format!("head {g}: weights sum {sum} vs mass {}", o.mass));
                }
                if o.weights.iter().any(|&x| x <= 0.0) {
                    return Err(format!("head {g}: non-positive weight"));
                }
                if o.kept.windows(2).any(|x| x[0] >= x[1]) {
                    return Err(format!("head {g}: kept not strictly ascending"));
                }
                for t in &o.kept {
                    if candidates.binary_search(t).is_err() {
                        return Err(format!("head {g}: kept token {t} not a candidate"));
                    }
                    if union.binary_search(t).is_err() {
                        return Err(format!("head {g}: kept token {t} missing from union"));
                    }
                }
                if o.mass < p - 1e-3 || o.mass > 1.0 + 1e-3 {
                    return Err(format!("head {g}: mass {} outside [p, 1]", o.mass));
                }
                rebuilt.extend_from_slice(&o.kept);
            }
            rebuilt.sort_unstable();
            rebuilt.dedup();
            if rebuilt != union {
                return Err("union must be exactly the dedup of per-head keeps".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_allocator_never_double_allocates() {
    check_default("allocator", |rng| {
        let pages = rng.range(2, 20);
        let mut cache = PagedKvCache::new(CacheConfig::new(1, 4, pages));
        let mut seqs: Vec<SeqCache> = Vec::new();
        for _ in 0..rng.range(10, 60) {
            if seqs.is_empty() || rng.chance(0.6) {
                //

                let mut s = SeqCache::default();
                let toks = rng.range(1, 24);
                let mut ok = true;
                for _ in 0..toks {
                    if cache.append(&mut s, &[1.0; 4], &[1.0; 4]).is_err() {
                        ok = false;
                        break;
                    }
                }
                if ok || !s.pages.is_empty() {
                    seqs.push(s);
                } else {
                    cache.release(&s);
                }
            } else {
                let i = rng.below(seqs.len());
                let s = seqs.swap_remove(i);
                cache.release(&s);
            }
            // Invariant: no page owned by two live sequences (refcount 1
            // without sharing), and used+free == total.
            let mut owned = std::collections::HashSet::new();
            for s in &seqs {
                for &p in &s.pages {
                    if !owned.insert(p) {
                        return Err(format!("page {p} owned twice"));
                    }
                }
            }
            if cache.used_pages() + cache.free_pages() != pages {
                return Err("page accounting broken".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_selectors_return_sorted_valid_indices() {
    check(
        "selector-contract",
        Config { cases: 24, ..Default::default() },
        |rng| {
            let n = rng.range(20, 300);
            let d = 16;
            let mut cache = PagedKvCache::new(CacheConfig::new(1, d, n / 16 + 2));
            let mut seq = SeqCache::default();
            for _ in 0..n {
                let k: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                cache.append(&mut seq, &k, &k).unwrap();
            }
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let budget = rng.range(1, n + 10);
            for kind in [
                SelectorKind::Full,
                SelectorKind::Quest,
                SelectorKind::DoubleSparsity,
                SelectorKind::MagicPig,
                SelectorKind::StreamingLlm,
                SelectorKind::SnapKv,
                SelectorKind::H2O,
                SelectorKind::Oracle,
            ] {
                let mut sel = kind.build(d, 1);
                let got = sel.select(&cache, &seq, 0, &q, 1, budget);
                if got.windows(2).any(|w| w[0] >= w[1]) {
                    return Err(format!("{kind:?}: unsorted/duplicated output"));
                }
                if got.iter().any(|&t| t >= n) {
                    return Err(format!("{kind:?}: out-of-range token"));
                }
                if got.is_empty() {
                    return Err(format!("{kind:?}: empty selection"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_scheduler_conserves_requests_and_pages() {
    check(
        "scheduler-conservation",
        Config { cases: 8, ..Default::default() },
        |rng| {
            let v = RetrievalVocab::DEFAULT;
            let model = std::sync::Arc::new(build_retrieval_model(v, 4096));
            let capacity = rng.range(400, 2000);
            let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
            cfg.skip_layers = 0;
            let engine = Engine::new(model, cfg, capacity);
            let total_pages = engine.free_pages();
            let mut sched = Scheduler::new(
                engine,
                SchedulerConfig {
                    max_batch: rng.range(1, 6),
                    admit_headroom_pages: 0,
                    max_prefills_per_step: 2,
                    ..Default::default()
                },
            );
            let nreq = rng.range(2, 8);
            for i in 0..nreq {
                let ctx = rng.range(32, 180);
                let g = gen_niah(rng, v, ctx);
                sched.submit(Request::new(i as u64, g.prompt, rng.range(1, 5)));
            }
            let report = sched.run_to_completion();
            if report.requests.len() != nreq {
                return Err(format!("{} of {nreq} finished", report.requests.len()));
            }
            if sched.engine.num_seqs() != 0 {
                return Err("sequences leaked".into());
            }
            if sched.engine.free_pages() != total_pages {
                return Err(format!(
                    "pages leaked: {} != {total_pages}",
                    sched.engine.free_pages()
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn random_json(rng: &mut Rng, depth: usize) -> Json {
        match if depth > 2 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal() * 100.0 * 8.0).round() / 8.0),
            3 => {
                let n = rng.below(8);
                Json::Str((0..n).map(|_| *rng.choose(&['a', 'é', '"', '\\', 'z', '\n'])).collect())
            }
            4 => Json::Arr((0..rng.below(4)).map(|_| random_json(rng, depth + 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), random_json(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    check_default("json-roundtrip", |rng| {
        let v = random_json(rng, 0);
        let text = v.to_string();
        let back = Json::parse(&text).map_err(|e| format!("{e} on {text}"))?;
        if back != v {
            return Err(format!("{back:?} != {v:?}"));
        }
        let pretty = Json::parse(&v.pretty()).map_err(|e| e.to_string())?;
        if pretty != v {
            return Err("pretty roundtrip failed".into());
        }
        Ok(())
    });
}

#[test]
fn prop_budget_spec_resolve_in_range() {
    check_default("budget-spec", |rng| {
        let ctx = rng.range(1, 100_000);
        let frac = rng.f32();
        let b = BudgetSpec::Fraction(frac).resolve(ctx);
        if b < 1 || b > ctx {
            return Err(format!("fraction resolve {b} out of [1, {ctx}]"));
        }
        let fixed = rng.range(0, 200_000);
        let b = BudgetSpec::Fixed(fixed).resolve(ctx);
        if b > ctx {
            return Err("fixed resolve exceeded ctx".into());
        }
        Ok(())
    });
}
