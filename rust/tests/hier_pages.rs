//! Hierarchical page-level top-p pre-prune (`--hier-pages`) battery:
//!
//! 1. the mass guarantee — for any query shape, the kept set captures
//!    ≥ p − hier_eps of the *full-candidate* estimated softmax mass;
//! 2. engine-level: retrieval accuracy holds under hier mode, skipped
//!    pages are reported in `EngineStats`/`SignalHub`, and the
//!    `BudgetDirective::hier_pages_override` knob switches the mode on
//!    without touching the static config;
//! 3. determinism: hier mode stays bit-exact across worker counts and
//!    prefill chunk sizes (page bounds read only sealed metadata, so the
//!    sealing contract carries over);
//! 4. serving: the scheduler's report and live stats carry the
//!    skipped-page telemetry.

use std::sync::Arc;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::governor::BudgetDirective;
use twilight::kvcache::{CacheConfig, PagedKvCache, SeqCache};
use twilight::model::retrieval::build_retrieval_model;
use twilight::model::sampler::greedy;
use twilight::model::{Model, ModelConfig};
use twilight::pruner::{prune_group_into, AttnScratch, PrunerConfig};
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;

/// Force hier mode regardless of the TWILIGHT_HIER_PAGES env default.
fn hier_cfg(p: f32) -> SparseConfig {
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, p);
    cfg.skip_layers = 0;
    cfg.dense_below = 16;
    if let Some(t) = cfg.twilight.as_mut() {
        t.hier_pages = true;
        t.hier_eps = 0.02;
    }
    cfg
}

fn base_cfg(p: f32) -> SparseConfig {
    let mut cfg = hier_cfg(p);
    if let Some(t) = cfg.twilight.as_mut() {
        t.hier_pages = false;
    }
    cfg
}

/// A small multi-layer random model (the 1-layer retrieval model takes
/// the embedding-KV fast path, which bypasses the chunk machinery).
fn deep_model(seed: u64) -> Arc<Model> {
    let cfg = ModelConfig {
        name: "hiertest".into(),
        vocab_size: 32,
        d_model: 24,
        n_layers: 2,
        n_heads: 4,
        n_kv_heads: 2,
        head_dim: 6,
        d_ff: 32,
        use_rope: true,
        rope_theta: 10000.0,
        use_norm: true,
        norm_eps: 1e-5,
        max_ctx: 512,
    };
    Arc::new(Model::random(&cfg, seed))
}

#[test]
fn mass_guarantee_across_query_shapes() {
    // Sweep query/key sharpness: from diffuse (nothing skippable) to
    // strongly peaked (most pages skipped). In every regime the kept
    // set's mass under the FULL-candidate estimated softmax must stay
    // ≥ p − hier_eps (small fp slack).
    let d = 32;
    let p = 0.9f32;
    let eps = 0.02f32;
    let cfg = PrunerConfig { p, hier_pages: true, hier_eps: eps, ..Default::default() };
    let mut scratch = AttnScratch::default();
    let mut skipped_any = 0u32;
    for (seed, sharp) in
        [(1u64, 0.0f32), (2, 1.0), (3, 2.0), (4, 4.0), (5, 8.0), (6, 0.5), (7, 3.0)]
    {
        let mut cache = PagedKvCache::new(CacheConfig::new(1, d, 40));
        let mut seq = SeqCache::default();
        let mut r = Rng::new(100 + seed);
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        for i in 0..512 {
            // One aligned key per 64 tokens, strength `sharp`.
            let k: Vec<f32> = if i % 64 == 17 {
                q.iter().map(|x| x * sharp).collect()
            } else {
                (0..d).map(|_| r.normal_f32(0.0, 0.4)).collect()
            };
            cache.append(&mut seq, &k, &k).unwrap();
        }
        let candidates: Vec<usize> = (0..512).collect();
        let info = prune_group_into(&cfg, &cache, &seq, 0, &q, 1, &candidates, &mut scratch);
        skipped_any += info.pages_skipped;
        assert_eq!(info.pages_total, 32);
        let out = &scratch.outcomes[0];
        assert!(out.kept.windows(2).all(|w| w[0] < w[1]), "kept must be ascending");
        assert!(out.kept.iter().all(|t| *t < 512));
        // Full-candidate softmax from the row-major reference.
        let mut est = vec![0.0; 512];
        twilight::attention::spgemv::estimate_scores_rowmajor(
            &cache, &seq, 0, &q, &candidates, &mut est,
        );
        let s = 1.0 / (d as f32).sqrt();
        for x in est.iter_mut() {
            *x *= s;
        }
        twilight::tensor::softmax_inplace(&mut est);
        let full_mass: f32 = out.kept.iter().map(|&t| est[t]).sum();
        assert!(
            full_mass >= p - eps - 2e-3,
            "seed {seed} sharp {sharp}: kept mass {full_mass} < p − δ = {}",
            p - eps
        );
    }
    assert!(skipped_any > 0, "the sweep must exercise actual page skipping");
}

#[test]
fn hier_engine_answers_niah_and_reports_skips() {
    let model = Arc::new(build_retrieval_model(V, 8192));
    let mut e = Engine::new(model, hier_cfg(0.9), 16384);
    let mut r = Rng::new(2);
    let mut correct = 0;
    for i in 0..8 {
        let g = gen_niah(&mut r, V, 1024);
        let logits = e.prefill(i, &g.prompt).unwrap();
        if greedy(&logits) == g.answer {
            correct += 1;
        }
        e.release(i);
    }
    assert!(correct >= 7, "hier-pages NIAH accuracy {correct}/8");
    assert!(e.stats.sparse_calls > 0);
    assert!(e.stats.hier_pages_total > 0, "hier mode must report page accounting");
    assert!(e.stats.hier_pages_skipped <= e.stats.hier_pages_total);
    assert_eq!(e.signals.hier_pages_total(), e.stats.hier_pages_total);
    assert_eq!(e.signals.hier_pages_skipped(), e.stats.hier_pages_skipped);
}

#[test]
fn directive_override_switches_hier_on() {
    // Static config off, governor directive on: the knob must flip the
    // pre-prune (visible through the page accounting) without any
    // config rebuild.
    let model = Arc::new(build_retrieval_model(V, 8192));
    let mut e = Engine::new(model, base_cfg(0.9), 16384);
    let mut r = Rng::new(3);
    let g = gen_niah(&mut r, V, 512);
    let _ = e.prefill(0, &g.prompt).unwrap();
    assert_eq!(e.stats.hier_pages_total, 0, "hier off: no page accounting");
    e.apply_directive(BudgetDirective {
        hier_pages_override: Some(true),
        ..BudgetDirective::NEUTRAL
    });
    let _ = e.decode(0, g.prompt[0]).unwrap();
    assert!(e.stats.hier_pages_total > 0, "override must enable the pre-prune");
    // And Some(false) forces it back off even if the config says on.
    let mut e2 = Engine::new(
        Arc::new(build_retrieval_model(V, 8192)),
        hier_cfg(0.9),
        16384,
    );
    e2.apply_directive(BudgetDirective {
        hier_pages_override: Some(false),
        ..BudgetDirective::NEUTRAL
    });
    let mut r = Rng::new(4);
    let g = gen_niah(&mut r, V, 512);
    let _ = e2.prefill(0, &g.prompt).unwrap();
    assert_eq!(e2.stats.hier_pages_total, 0, "override must disable the pre-prune");
}

#[test]
fn hier_bit_exact_across_threads() {
    // The pre-prune is per-call-local: worker count must not change a
    // bit of the logits or the page accounting.
    let model = Arc::new(build_retrieval_model(V, 8192));
    let run = |threads: usize| {
        let mut e = Engine::new(model.clone(), hier_cfg(0.9), 16384);
        e.set_threads(threads);
        let mut r = Rng::new(5);
        let g0 = gen_niah(&mut r, V, 300);
        let g1 = gen_niah(&mut r, V, 452);
        let _ = e.prefill(0, &g0.prompt).unwrap();
        let _ = e.prefill(1, &g1.prompt).unwrap();
        let mut all = Vec::new();
        for _ in 0..4 {
            let batch = DecodeBatch::new(vec![(0, g0.prompt[0]), (1, g1.prompt[0])]);
            for res in e.step_batch(&batch) {
                all.push(res.unwrap());
            }
        }
        (all, e.stats.hier_pages_total, e.stats.hier_pages_skipped)
    };
    let (l1, t1, s1) = run(1);
    for threads in [4usize, 8] {
        let (ln, tn, sn) = run(threads);
        assert_eq!(l1, ln, "hier logits diverged at threads={threads}");
        assert_eq!((t1, s1), (tn, sn), "hier accounting diverged at threads={threads}");
    }
    assert!(t1 > 0 && s1 <= t1);
}

#[test]
fn hier_bit_exact_across_chunk_spans() {
    // Page bounds read only sealed min/max + sealed mirror blocks and the
    // unsealed tail is scored exactly, so hier selection is a pure
    // function of the visible prefix — chunk-size invariant like the
    // rest of the pipeline.
    let model = deep_model(11);
    let mut r = Rng::new(12);
    let prompt: Vec<u32> = (0..150).map(|_| r.below(32) as u32).collect();
    let mut cfg = hier_cfg(0.9);
    cfg.dense_below = 8;
    let run = |span: usize, threads: usize| {
        let mut e = Engine::new(model.clone(), cfg.clone(), 4096);
        e.set_threads(threads);
        e.set_prefill_chunk(span);
        let mut all = vec![e.prefill(0, &prompt).unwrap()];
        for _ in 0..3 {
            all.push(e.decode(0, prompt[0]).unwrap());
        }
        (all, e.stats.hier_pages_total, e.stats.hier_pages_skipped)
    };
    let reference = run(1, 1);
    assert!(reference.1 > 0, "the battery must exercise the hier path");
    for threads in [1usize, 4] {
        for span in [1usize, 7, 64, 1000] {
            let got = run(span, threads);
            assert_eq!(
                reference, got,
                "hier diverged at span={span} threads={threads}"
            );
        }
    }
}

#[test]
fn serving_report_carries_hier_telemetry() {
    let model = Arc::new(build_retrieval_model(V, 8192));
    let engine = Engine::new(model, hier_cfg(0.9), 1 << 16);
    let mut s = Scheduler::new(engine, SchedulerConfig::default());
    let mut r = Rng::new(6);
    for i in 0..4 {
        let g = gen_niah(&mut r, V, 256);
        s.submit(Request::new(i, g.prompt, 4));
    }
    let rep = s.run_to_completion();
    assert_eq!(rep.requests.len(), 4);
    assert!(rep.hier_pages_total > 0, "report must carry the page accounting");
    assert!(rep.hier_skip_frac() >= 0.0 && rep.hier_skip_frac() <= 1.0);
    let j = rep.to_json();
    assert!(j.get_f64("hier_pages_total").unwrap() > 0.0);
    assert!(j.get_f64("hier_skip_frac").is_some());
    let live = s.live_stats_json();
    assert!(live.get_f64("hier_skip_frac").is_some());
    assert!(live.get_f64("hier_pages_skipped").is_some());
}
