//! Fig. 13 — padded vs head-varlen vs group-varlen attention under a
//! *real* Twilight budget distribution (collected from a retrieval run),
//! plus the LPT vs round-robin load-balance makespan (§4.2).

mod common;

use std::time::Duration;
use twilight::attention::sparse;
use twilight::coordinator::balance::{lpt_partition, makespan, round_robin_partition, WorkItem};
use twilight::pruner::{prune_head, PrunerConfig, PrunerScratch};
use twilight::util::stats::bench;

fn main() {
    common::header("Figure 13", "varlen attention packings under head-dynamic budgets");
    let d = 64;
    let n = 16384;
    let group = 4;
    let (cache, seq) = common::structured_cache(3, 1, d, n);
    // Real per-head budgets: prune each query head separately at p=0.9,
    // mixing focused (sharp q) and diffuse (flat q) heads like Fig. 11.
    let mut kept: Vec<Vec<usize>> = Vec::new();
    let mut qs = Vec::new();
    let pc = PrunerConfig { p: 0.9, ..Default::default() };
    let mut scratch = PrunerScratch::default();
    let all: Vec<usize> = (0..n).collect();
    for g in 0..group {
        let sharp = if g % 2 == 0 { 3.0 } else { 0.2 }; // focused vs diffuse
        let q = common::queries(40 + g as u64, 1, d, sharp);
        let out = prune_head(&pc, &cache, &seq, 0, &q, &all, &mut scratch);
        kept.push(out.kept);
        qs.extend(q);
    }
    let budgets: Vec<usize> = kept.iter().map(|k| k.len()).collect();
    let max_budget = *budgets.iter().max().unwrap();
    println!("per-head budgets: {budgets:?} (max {max_budget})\n");

    let mut out = vec![0.0f32; group * d];
    let warm = Duration::from_millis(50);
    let meas = Duration::from_millis(400);
    // Padded: every head pays max_budget.
    let r_pad = bench("padded", warm, meas, 3, || {
        for g in 0..group {
            sparse::padded(&cache, &seq, 0, &qs[g * d..(g + 1) * d], &kept[g], max_budget,
                &mut out[g * d..(g + 1) * d]);
        }
    });
    // Head-varlen: exact per-head work, but under GQA K/V re-read per head.
    let r_head = bench("head-varlen", warm, meas, 3, || {
        for g in 0..group {
            sparse::head_varlen(&cache, &seq, 0, &qs[g * d..(g + 1) * d], &kept[g],
                &mut out[g * d..(g + 1) * d]);
        }
    });
    // Group-varlen: union indices, one K/V load per group.
    let mut union: Vec<usize> = kept.iter().flatten().copied().collect();
    union.sort_unstable();
    union.dedup();
    let mut sm_m: Vec<f32> = Vec::new();
    let mut sm_d: Vec<f32> = Vec::new();
    let r_group = bench("group-varlen", warm, meas, 3, || {
        sparse::group_varlen_with(&cache, &seq, 0, &qs, group, &union, &mut sm_m, &mut sm_d,
            &mut out);
    });
    // KV bytes each packing must stream (the GPU-bandwidth metric; on a
    // cache-resident CPU run, compute dominates instead — DESIGN.md §2).
    let row_bytes = d * 2 * 2; // K+V fp16
    let bytes_pad = group * max_budget * row_bytes;
    let bytes_head: usize = budgets.iter().map(|b| b * row_bytes).sum();
    let bytes_group = union.len() * row_bytes;
    println!("{:<14} {:>12} {:>14}", "packing", "ms/step", "KV-MB-touched");
    for (r, bytes) in [(&r_pad, bytes_pad), (&r_head, bytes_head), (&r_group, bytes_group)] {
        println!("{:<14} {:>12.3} {:>14.2}", r.name, r.secs.mean * 1e3, bytes as f64 / 1e6);
    }

    // Load-balance makespan with these budgets over simulated workers.
    println!("\nload balancing (32 sequences × {group} heads, same budget mix):");
    let items: Vec<WorkItem> = (0..32)
        .flat_map(|s| {
            budgets.iter().enumerate().map(move |(h, &b)| WorkItem {
                seq: s as u32,
                kv_head: h as u32,
                budget: b,
            })
        })
        .collect();
    for workers in [4usize, 8, 16] {
        let lpt = makespan(&lpt_partition(&items, workers));
        let rr = makespan(&round_robin_partition(&items, workers));
        println!(
            "  {workers:>2} workers: LPT makespan {lpt:>8}  round-robin {rr:>8}  ({:.2}x better)",
            rr as f64 / lpt as f64
        );
    }
}
