//! Fig. 1/3 — focused vs diffuse attention-weight distributions, on both
//! models: the constructed retrieval heads and trained charlm heads.

mod common;

use twilight::evalsuite::distributions::{entropy, final_position_weights};
use twilight::pruner::topp::oracle_budget;
use twilight::util::rng::Rng;
use twilight::util::stats::Histogram;
use twilight::workload::{gen_niah, load_corpus, RetrievalVocab};

fn main() {
    common::header("Figure 1/3", "attention weight distributions: focused vs diffuse");
    let v = RetrievalVocab::DEFAULT;
    let ctx = 2048;
    let model = common::retrieval_model(ctx * 2);
    let mut rng = Rng::new(2);
    let g = gen_niah(&mut rng, v, ctx);
    let ws = final_position_weights(&model, &g.prompt, 0);
    println!("retrieval model, NIAH query, {ctx} tokens:");
    println!("{:>5} {:<12} {:>10} {:>12} {:>14}", "head", "kind", "entropy", "p90-budget", "weight-profile");
    for (h, w) in ws.iter().enumerate() {
        let mut hist = Histogram::new(0.0, 1.0, 24);
        let max = w.iter().cloned().fold(0.0f32, f32::max);
        for &x in w.iter() {
            hist.add((x / max) as f64);
        }
        println!(
            "{:>5} {:<12} {:>10.2} {:>12} {:>24}",
            h,
            if h < 4 { "retrieval" } else { "aggregation" },
            entropy(w),
            oracle_budget(w, 0.9),
            hist.sparkline(),
        );
    }
    if let Some(charlm) = common::charlm() {
        let corpus = load_corpus("artifacts/corpus_eval.bin").expect("corpus");
        let prompt: Vec<u32> = corpus[..512].to_vec();
        println!("\ncharlm (trained), 512-token corpus window, layer 2:");
        println!("{:>5} {:>10} {:>12}", "head", "entropy", "p90-budget");
        let ws = final_position_weights(&charlm, &prompt, 2);
        let mut budgets: Vec<usize> = Vec::new();
        for (h, w) in ws.iter().enumerate() {
            let b = oracle_budget(w, 0.9);
            budgets.push(b);
            println!("{:>5} {:>10.2} {:>12}", h, entropy(w), b);
        }
        let min = budgets.iter().min().unwrap();
        let max = budgets.iter().max().unwrap();
        println!("budget spread across heads: min {min}, max {max} ({}x)", max / min.max(&1));
    } else {
        println!("\n(charlm artifacts missing — run `make artifacts` for the trained-head panel)");
    }
}
