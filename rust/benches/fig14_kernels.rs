//! Fig. 14 (repo-local) — SIMD kernel backend speedups (DESIGN.md §11).
//!
//! * 14a — primitive panel: scalar vs the best detected backend for the
//!   hot kernels (`dot`, `axpy`, and the fused dequant-dots at INT4 /
//!   INT8 / FP16) across lengths 64..16384. These are the inner loops
//!   of the SpGEMV estimator and the sparse attention kernels, so the
//!   per-primitive ratio bounds what the end-to-end path can gain.
//! * 14b — the paged group score estimator (`estimate_scores_group`,
//!   the pruner's actual hot path) end to end under the scalar vs the
//!   auto-selected backend, switched via the global dispatch table.
//!
//! Besides the console table, the results land in `BENCH_kernels.json`
//! at the repo root (uploaded as a CI artifact) so backend regressions
//! are diffable across runs. On a host whose best backend is scalar the
//! ratios are ≈1 and the panel degrades to a dispatch-overhead check.

mod common;

use std::hint::black_box;
use std::time::Duration;
use twilight::attention::spgemv::{estimate_scores_group, SpgemvScratch};
use twilight::kvcache::{CacheConfig, PagedKvCache, SeqCache};
use twilight::tensor::kernels::{self, Backend, Kernels, Select};
use twilight::tensor::quant::{quantize, QuantBits};
use twilight::util::json::{self, Json};
use twilight::util::rng::Rng;
use twilight::util::stats::bench;

const LENS: [usize; 5] = [64, 256, 1024, 4096, 16384];

fn timed<F: FnMut()>(name: &str, f: F) -> f64 {
    bench(name, Duration::from_millis(50), Duration::from_millis(200), 3, f).secs.mean
}

fn quant_dot(t: &'static Kernels, bits: QuantBits) -> impl Fn(&[f32], &[u8], f32, f32) -> f32 {
    move |q, packed, zero, scale| match bits {
        QuantBits::Fp16 => (t.dot_f16)(q, packed),
        QuantBits::Int8 => (t.dot_q_i8)(q, packed, zero, scale),
        QuantBits::Int4 => (t.dot_q_i4)(q, packed, zero, scale),
        QuantBits::Int2 => (t.dot_q_i2)(q, packed, zero, scale),
    }
}

fn panel_primitives(scalar: &'static Kernels, best: &'static Kernels) -> Vec<Json> {
    println!("-- 14a: primitives, scalar vs {} --", best.backend.name());
    println!("{:>12} {:>7} {:>12} {:>12} {:>8}", "op", "n", "scalar us", "simd us", "speedup");
    let mut rows = Vec::new();
    let mut r = Rng::new(14);
    for n in LENS {
        let x: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let y: Vec<f32> = (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let mut acc = vec![0.0f32; n];
        // (op label, scalar seconds, simd seconds)
        let mut emit = |op: &str, s_us: f64, b_us: f64| {
            println!(
                "{:>12} {:>7} {:>12.3} {:>12.3} {:>7.2}x",
                op,
                n,
                s_us * 1e6,
                b_us * 1e6,
                s_us / b_us
            );
            rows.push(json::obj(vec![
                ("op", Json::Str(op.to_string())),
                ("n", Json::Num(n as f64)),
                ("scalar_us", Json::Num(s_us * 1e6)),
                ("simd_us", Json::Num(b_us * 1e6)),
                ("speedup", Json::Num(s_us / b_us)),
            ]));
        };
        let s = timed("dot/scalar", || {
            black_box((scalar.dot)(black_box(&x), black_box(&y)));
        });
        let b = timed("dot/simd", || {
            black_box((best.dot)(black_box(&x), black_box(&y)));
        });
        emit("dot", s, b);
        let s = timed("axpy/scalar", || (scalar.axpy)(black_box(0.5), black_box(&x), &mut acc));
        let b = timed("axpy/simd", || (best.axpy)(black_box(0.5), black_box(&x), &mut acc));
        emit("axpy", s, b);
        for bits in [QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
            let blk = quantize(&y, bits);
            let sdot = quant_dot(scalar, bits);
            let bdot = quant_dot(best, bits);
            let s = timed("dot_q/scalar", || {
                black_box(sdot(black_box(&x), black_box(&blk.packed), blk.zero, blk.scale));
            });
            let b = timed("dot_q/simd", || {
                black_box(bdot(black_box(&x), black_box(&blk.packed), blk.zero, blk.scale));
            });
            emit(&format!("dot_q_{}", bits.bits()), s, b);
        }
    }
    rows
}

fn panel_spgemv(best: Backend) -> Vec<Json> {
    println!("\n-- 14b: paged group estimator (group=4), scalar vs auto backend --");
    println!("{:>7} {:>6} {:>12} {:>12} {:>8}", "ctx", "bits", "scalar us", "simd us", "speedup");
    let d = 128;
    let group = 4;
    let mut rows = Vec::new();
    for n in [4096usize, 16384] {
        for bits in [QuantBits::Int4, QuantBits::Fp16] {
            let mut cfg = CacheConfig::new(1, d, n.div_ceil(16) + 2);
            cfg.mirror_bits = bits;
            let mut cache = PagedKvCache::new(cfg);
            let mut seq = SeqCache::default();
            let mut r = Rng::new(20 + n as u64);
            for _ in 0..n {
                let k: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
                cache.append(&mut seq, &k, &k).unwrap();
            }
            let qs: Vec<f32> = (0..group * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let tokens: Vec<usize> = (0..n).collect();
            let mut out = vec![0.0f32; group * n];
            let mut sc = SpgemvScratch::default();
            // The estimator reads the process-global table, so this
            // panel really does switch the dispatch the engine would use.
            kernels::force_scalar();
            let s = timed("spgemv/scalar", || {
                estimate_scores_group(&cache, &seq, 0, &qs, group, &tokens, &mut out, &mut sc);
            });
            kernels::install(Select::Auto).expect("auto install cannot fail");
            let b = timed("spgemv/auto", || {
                estimate_scores_group(&cache, &seq, 0, &qs, group, &tokens, &mut out, &mut sc);
            });
            println!(
                "{:>7} {:>6} {:>12.1} {:>12.1} {:>7.2}x",
                n,
                bits.bits(),
                s * 1e6,
                b * 1e6,
                s / b
            );
            rows.push(json::obj(vec![
                ("op", Json::Str("estimate_scores_group".to_string())),
                ("bits", Json::Num(bits.bits() as f64)),
                ("ctx", Json::Num(n as f64)),
                ("group", Json::Num(group as f64)),
                ("scalar_us", Json::Num(s * 1e6)),
                ("simd_us", Json::Num(b * 1e6)),
                ("speedup", Json::Num(s / b)),
                ("backend", Json::Str(best.name().to_string())),
            ]));
        }
    }
    rows
}

fn main() {
    common::header(
        "Figure 14",
        "SIMD kernel backend: scalar vs runtime-detected, per primitive and end-to-end",
    );
    let scalar = kernels::table(Backend::Scalar).expect("scalar table");
    let detected = kernels::detect();
    let best = kernels::table(detected).expect("detected table");
    println!("host best backend: {}\n", detected.name());
    let prim = panel_primitives(scalar, best);
    let spg = panel_spgemv(detected);
    let doc = json::obj(vec![
        ("bench", Json::Str("fig14_kernels".to_string())),
        ("backend", Json::Str(detected.name().to_string())),
        ("arch", Json::Str(std::env::consts::ARCH.to_string())),
        ("primitives", Json::Arr(prim)),
        ("spgemv", Json::Arr(spg)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_kernels.json");
    match std::fs::write(&path, doc.pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
