//! Governor figure — closed-loop budget control under bursty load.
//!
//! Three serving runs over the identical bursty trace (alternating
//! request bursts and quiet gaps) on an engine whose page pool is sized
//! so bursts create real memory pressure:
//!
//! * `ungoverned`  — static p / B0 (the paper's deployment, no control)
//! * `gov-static`  — governor attached, identity policy: only the
//!                   memory-pressure ladder acts (isolates its effect on
//!                   preemptions)
//! * `gov-aimd`    — full AIMD closed loop against a TPOT SLO derived
//!                   from the ungoverned run (80% of its mean TPOT, i.e.
//!                   a target the static config cannot meet)
//!
//! Reported per run: p50/p95 TPOT, throughput, mean prune ratio,
//! preemptions, and the governor's p/budget trace extrema — the
//! acceptance shape is `gov-aimd` beating `ungoverned` p95 TPOT at an
//! equal-or-better prune ratio, and the governed runs preempting less.
//!
//! ```bash
//! cargo bench --bench fig_governor [-- <ctx> <reqs-per-burst>]
//! ```

mod common;

use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::governor::slo::SloConfig;
use twilight::governor::{Governor, GovernorConfig};
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::util::stats::percentile;
use twilight::workload::{gen_niah, GenRequest, RetrievalVocab};

const BURSTS: usize = 3;
const GAP_S: f64 = 0.15;

fn bursty_trace(seed: u64, ctx: usize, per_burst: usize) -> Vec<GenRequest> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    for burst in 0..BURSTS {
        for _ in 0..per_burst {
            let mut g = gen_niah(&mut rng, RetrievalVocab::DEFAULT, ctx);
            g.arrival = burst as f64 * GAP_S + rng.f64() * 0.005;
            g.max_new_tokens = 6;
            out.push(g);
        }
        out.sort_by(|a, b| a.arrival.partial_cmp(&b.arrival).unwrap());
    }
    out
}

struct RunResult {
    label: &'static str,
    tpot_p50_ms: f64,
    tpot_p95_ms: f64,
    tok_s: f64,
    prune_ratio: f64,
    preemptions: u32,
    p_scale_range: (f32, f32),
    budget_scale_range: (f32, f32),
    max_degrade: u8,
}

fn run(
    label: &'static str,
    trace: &[GenRequest],
    ctx: usize,
    governor: Option<Governor>,
) -> RunResult {
    let model = common::retrieval_model(ctx * 2);
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    cfg.skip_layers = 0;
    // Pool sized to ~60% of one burst's demand: bursts overlap and
    // pressure is unavoidable without admission control.
    let per_burst = trace.len() / BURSTS;
    let capacity = (ctx + 128) * per_burst * 6 / 10;
    let engine = Engine::new(model, cfg, capacity);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig { max_batch: per_burst * 2, ..Default::default() },
    );
    if let Some(g) = governor {
        sched.attach_governor(g);
    }
    for (i, g) in trace.iter().enumerate() {
        let mut r = Request::new(i as u64, g.prompt.clone(), g.max_new_tokens);
        r.arrival = g.arrival;
        sched.submit(r);
    }
    let rep = sched.run_to_completion();
    let tpots: Vec<f64> = rep
        .requests
        .iter()
        .filter(|r| r.output_len > 1)
        .map(|r| r.tpot() * 1e3)
        .collect();
    let (mut pmin, mut pmax) = (1.0f32, 1.0f32);
    let (mut bmin, mut bmax) = (1.0f32, 1.0f32);
    let mut max_degrade = 0u8;
    for e in &rep.governor {
        pmin = pmin.min(e.p_scale);
        pmax = pmax.max(e.p_scale);
        bmin = bmin.min(e.budget_scale);
        bmax = bmax.max(e.budget_scale);
        max_degrade = max_degrade.max(e.degrade_level);
    }
    RunResult {
        label,
        tpot_p50_ms: percentile(&tpots, 50.0),
        tpot_p95_ms: percentile(&tpots, 95.0),
        tok_s: rep.throughput_tok_s(),
        prune_ratio: sched.engine.stats.prune_ratio(),
        preemptions: rep.preemptions(),
        p_scale_range: (pmin, pmax),
        budget_scale_range: (bmin, bmax),
        max_degrade,
    }
}

fn main() {
    common::header("Governor", "closed-loop budget control under bursty load");
    let mut args = std::env::args().skip(1);
    let ctx: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(2048);
    let per_burst: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(6);
    let trace = bursty_trace(41, ctx, per_burst);
    println!(
        "trace: {} bursts x {per_burst} reqs, ctx={ctx}, gap={GAP_S}s\n",
        BURSTS
    );

    // Baseline first: its mean TPOT calibrates the SLO for the AIMD run.
    let base = run("ungoverned", &trace, ctx, None);
    let slo_ms = base.tpot_p50_ms * 0.8;

    let static_gov = Governor::new("static", GovernorConfig::default()).unwrap();
    let lad = run("gov-static", &trace, ctx, Some(static_gov));

    let aimd_cfg = GovernorConfig {
        slo: SloConfig { target_tpot_s: slo_ms / 1e3, ..Default::default() },
        ..Default::default()
    };
    let aimd = run("gov-aimd", &trace, ctx, Some(Governor::new("aimd", aimd_cfg).unwrap()));

    println!("TPOT SLO for gov-aimd: {slo_ms:.2} ms (80% of ungoverned p50)\n");
    println!(
        "{:<12} {:>9} {:>9} {:>9} {:>7} {:>8} {:>13} {:>13} {:>4}",
        "run", "p50-ms", "p95-ms", "tok/s", "prune", "preempt", "p-scale", "B0-scale", "deg"
    );
    for r in [&base, &lad, &aimd] {
        println!(
            "{:<12} {:>9.2} {:>9.2} {:>9.1} {:>6.1}% {:>8} {:>6.2}-{:<6.2} {:>6.2}-{:<6.2} {:>4}",
            r.label,
            r.tpot_p50_ms,
            r.tpot_p95_ms,
            r.tok_s,
            r.prune_ratio * 100.0,
            r.preemptions,
            r.p_scale_range.0,
            r.p_scale_range.1,
            r.budget_scale_range.0,
            r.budget_scale_range.1,
            r.max_degrade,
        );
    }
    println!();
    let verdicts = [
        ("aimd p95 TPOT < ungoverned", aimd.tpot_p95_ms < base.tpot_p95_ms),
        ("aimd prune ratio >= ungoverned", aimd.prune_ratio >= base.prune_ratio - 1e-6),
        ("aimd trace moved p/B0", aimd.budget_scale_range.0 < 1.0),
        (
            "pressure ladder cut preemptions",
            lad.preemptions <= base.preemptions && aimd.preemptions <= base.preemptions,
        ),
    ];
    for (what, ok) in verdicts {
        println!("  [{}] {}", if ok { "ok" } else { "MISS" }, what);
    }
}
