//! Fig. 12 — SpGEMV (score estimation) latency vs quantization width.
//! The kernel is memory-bound, so latency should track bytes streamed:
//! INT2 < INT4 < INT8 < FP16.

mod common;

use std::time::Duration;
use twilight::attention::spgemv::QuantizedK;
use twilight::tensor::quant::QuantBits;
use twilight::util::rng::Rng;
use twilight::util::stats::bench;

fn main() {
    common::header("Figure 12", "SpGEMV latency vs quantization bits");
    let d = 128;
    println!("{:>7} {:>6} {:>12} {:>12} {:>10}", "N", "bits", "us/call", "MB", "GB/s");
    for n in [4096usize, 16384, 65536] {
        let mut r = Rng::new(1);
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0f32; n];
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
            let qk = QuantizedK::from_rows(&k, d, bits, 16);
            let res = bench(
                "spgemv",
                Duration::from_millis(60),
                Duration::from_millis(400),
                3,
                || qk.gemv(&q, &mut out),
            );
            let bytes = qk.bytes() as f64;
            println!(
                "{:>7} {:>6} {:>12.1} {:>12.2} {:>10.2}",
                n,
                bits.bits(),
                res.secs.mean * 1e6,
                bytes / 1e6,
                bytes / res.secs.mean / 1e9,
            );
        }
    }
}
