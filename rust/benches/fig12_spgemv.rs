//! Fig. 12 — SpGEMV (score estimation) latency vs quantization width,
//! extended with the page-major hot-path panels:
//!
//! * 12a — standalone GEMV, row-major (fused dequant-dot per row) vs
//!   block-tiled (codes unpacked once per block): both are memory-bound,
//!   so latency tracks bytes streamed (INT2 < INT4 < INT8 < FP16), and
//!   the tiled walk amortizes the unpack pass across the block's rows.
//! * 12b — the *paged* group estimator (`estimate_scores_group`):
//!   row-major reference vs the page-tiled hot path at GQA group 4 —
//!   the tile is amortized across rows × heads.
//! * 12c — hierarchical page top-p pre-prune: full scoring vs
//!   bound-ordered early stop on peaked and diffuse query shapes, with
//!   the fraction of candidate pages skipped.

mod common;

use std::time::Duration;
use twilight::attention::spgemv::{
    estimate_scores_group, estimate_scores_group_rowmajor, QuantizedK, SpgemvScratch,
};
use twilight::kvcache::{CacheConfig, PagedKvCache, SeqCache};
use twilight::pruner::{prune_group_into, AttnScratch, PrunerConfig};
use twilight::tensor::quant::QuantBits;
use twilight::util::rng::Rng;
use twilight::util::stats::bench;

const ALL_BITS: [QuantBits; 4] =
    [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16];

fn paged_cache(n: usize, d: usize, bits: QuantBits, seed: u64) -> (PagedKvCache, SeqCache) {
    let mut cfg = CacheConfig::new(1, d, n.div_ceil(16) + 2);
    cfg.mirror_bits = bits;
    let mut cache = PagedKvCache::new(cfg);
    let mut seq = SeqCache::default();
    let mut r = Rng::new(seed);
    for _ in 0..n {
        let k: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        cache.append(&mut seq, &k, &k).unwrap();
    }
    (cache, seq)
}

fn panel_a() {
    println!("-- 12a: standalone GEMV, row-major vs block-tiled --");
    let d = 128;
    println!(
        "{:>7} {:>6} {:>12} {:>12} {:>8} {:>12} {:>10}",
        "N", "bits", "row us", "tiled us", "speedup", "MB", "GB/s(tiled)"
    );
    for n in [4096usize, 16384, 65536] {
        let mut r = Rng::new(1);
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0f32; n];
        for bits in ALL_BITS {
            let qk = QuantizedK::from_rows(&k, d, bits, 16);
            let row = bench(
                "gemv",
                Duration::from_millis(60),
                Duration::from_millis(300),
                3,
                || qk.gemv(&q, &mut out),
            );
            let mut tile = Vec::new();
            let tiled = bench(
                "gemv_tiled",
                Duration::from_millis(60),
                Duration::from_millis(300),
                3,
                || qk.gemv_tiled(&q, &mut tile, &mut out),
            );
            let bytes = qk.bytes() as f64;
            println!(
                "{:>7} {:>6} {:>12.1} {:>12.1} {:>7.2}x {:>12.2} {:>10.2}",
                n,
                bits.bits(),
                row.secs.mean * 1e6,
                tiled.secs.mean * 1e6,
                row.secs.mean / tiled.secs.mean,
                bytes / 1e6,
                bytes / tiled.secs.mean / 1e9,
            );
        }
    }
}

fn panel_b() {
    println!("\n-- 12b: paged group estimator (group=4), row-major vs page-tiled --");
    let d = 128;
    let group = 4;
    println!(
        "{:>7} {:>6} {:>12} {:>12} {:>8}",
        "ctx", "bits", "row us", "tiled us", "speedup"
    );
    for n in [4096usize, 16384] {
        for bits in ALL_BITS {
            let (cache, seq) = paged_cache(n, d, bits, 2);
            let mut r = Rng::new(3);
            let qs: Vec<f32> = (0..group * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let tokens: Vec<usize> = (0..n).collect();
            let mut out = vec![0.0f32; group * n];
            let row = bench(
                "rowmajor",
                Duration::from_millis(60),
                Duration::from_millis(300),
                3,
                || estimate_scores_group_rowmajor(&cache, &seq, 0, &qs, group, &tokens, &mut out),
            );
            let mut sc = SpgemvScratch::default();
            let tiled = bench(
                "tiled",
                Duration::from_millis(60),
                Duration::from_millis(300),
                3,
                || estimate_scores_group(&cache, &seq, 0, &qs, group, &tokens, &mut out, &mut sc),
            );
            println!(
                "{:>7} {:>6} {:>12.1} {:>12.1} {:>7.2}x",
                n,
                bits.bits(),
                row.secs.mean * 1e6,
                tiled.secs.mean * 1e6,
                row.secs.mean / tiled.secs.mean,
            );
        }
    }
}

fn panel_c() {
    println!("\n-- 12c: hier page pre-prune (p=0.95, eps=0.02), full vs bound-ordered --");
    let d = 128;
    println!(
        "{:>7} {:>9} {:>12} {:>12} {:>8} {:>10}",
        "ctx", "shape", "full us", "hier us", "speedup", "skip frac"
    );
    for n in [4096usize, 8192] {
        for (shape, sharp) in [("diffuse", 0.0f32), ("peaked", 4.0)] {
            // Peaked: a handful of keys aligned with q concentrate the
            // softmax, letting the bound-ordered walk stop early.
            let mut cfg = CacheConfig::new(1, d, n.div_ceil(16) + 2);
            cfg.mirror_bits = QuantBits::Int4;
            let mut cache = PagedKvCache::new(cfg);
            let mut seq = SeqCache::default();
            let mut r = Rng::new(5);
            let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
            for i in 0..n {
                let k: Vec<f32> = if sharp > 0.0 && i % 512 == 100 {
                    q.iter().map(|x| x * sharp).collect()
                } else {
                    (0..d).map(|_| r.normal_f32(0.0, 0.4)).collect()
                };
                cache.append(&mut seq, &k, &k).unwrap();
            }
            let tokens: Vec<usize> = (0..n).collect();
            let base = PrunerConfig { p: 0.95, ..Default::default() };
            let hier = PrunerConfig { hier_pages: true, hier_eps: 0.02, ..base };
            let mut scratch = AttnScratch::default();
            let full = bench(
                "full",
                Duration::from_millis(60),
                Duration::from_millis(300),
                3,
                || {
                    prune_group_into(&base, &cache, &seq, 0, &q, 1, &tokens, &mut scratch);
                },
            );
            let mut info = twilight::pruner::HierPruneInfo::default();
            let hier_res = bench(
                "hier",
                Duration::from_millis(60),
                Duration::from_millis(300),
                3,
                || {
                    info = prune_group_into(&hier, &cache, &seq, 0, &q, 1, &tokens, &mut scratch);
                },
            );
            let frac = if info.pages_total == 0 {
                0.0
            } else {
                info.pages_skipped as f64 / info.pages_total as f64
            };
            println!(
                "{:>7} {:>9} {:>12.1} {:>12.1} {:>7.2}x {:>10.3}",
                n,
                shape,
                full.secs.mean * 1e6,
                hier_res.secs.mean * 1e6,
                full.secs.mean / hier_res.secs.mean,
                frac,
            );
        }
    }
}

fn main() {
    common::header(
        "Figure 12",
        "SpGEMV latency: quantization bits x row-major/page-tiled/hier-pages",
    );
    panel_a();
    panel_b();
    panel_c();
}
