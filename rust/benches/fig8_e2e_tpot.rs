//! Fig. 8 — end-to-end decoding TPOT across batch sizes, through the
//! full coordinator (queue → continuous batcher → engine); plus the
//! chunked-prefill panels: TTFT vs chunk span, a mixed-load comparison
//! of serial (chunk=1) vs chunked prefill while a steady decode set is
//! running, and the sparse-prefill panel (dense prefill vs bound-guided
//! page skipping, TTFT vs context with the skip fraction and a NIAH
//! recall pin). The sparse-prefill panel also lands in
//! `BENCH_prefill.json` at the crate root (uploaded as a CI artifact)
//! so prefill regressions are diffable across runs.

mod common;

use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::{SparseConfig, SparsePrefillCfg};
use twilight::selector::SelectorKind;
use twilight::util::json::{self, Json};
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

/// A small *multi-layer* random model for the chunked-prefill panels:
/// the 1-layer retrieval model's prefill chunks take the algebraic
/// attend-skip (layer-0 K/V needs no attention), so only a deeper model
/// exercises the multi-query attention work the panels measure.
fn deep_model(seed: u64) -> std::sync::Arc<twilight::model::Model> {
    use twilight::model::{Model, ModelConfig};
    let cfg = ModelConfig {
        name: "fig8-deep".into(),
        vocab_size: 256,
        d_model: 64,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 4,
        head_dim: 16,
        d_ff: 128,
        use_rope: true,
        rope_theta: 10000.0,
        use_norm: true,
        norm_eps: 1e-5,
        max_ctx: 1 << 15,
    };
    std::sync::Arc::new(Model::random(&cfg, seed))
}

/// TTFT / TPOT / preemptions for one chunked-prefill serving run over
/// the multi-layer model (prompts are random tokens — the panels
/// measure latency shape, not retrieval accuracy).
fn chunked_run(
    ctx: usize,
    chunk: usize,
    threads: usize,
    steady: usize,
    long_arrivals: usize,
) -> (f64, f64, f64, usize) {
    let model = deep_model(11);
    let vocab = model.cfg.vocab_size;
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    cfg.skip_layers = 0;
    let mut engine = Engine::new(model, cfg, (ctx + 80) * (steady + long_arrivals + 1));
    engine.set_threads(threads);
    engine.set_prefill_chunk(chunk);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig { max_batch: steady + long_arrivals, ..Default::default() },
    );
    let mut rng = Rng::new(17);
    let mut prompt = |len: usize| -> Vec<u32> {
        (0..len).map(|_| rng.below(vocab) as u32).collect()
    };
    // Steady decoders: short prompts, long generations.
    for i in 0..steady {
        let mut req = Request::new(i as u64, prompt(128), 48);
        req.stop_token = None;
        sched.submit(req);
    }
    // Long-prompt arrivals land once the steady set is decoding.
    for i in 0..long_arrivals {
        let mut req = Request::new((steady + i) as u64, prompt(ctx), 4);
        req.arrival = 0.005 * (i + 1) as f64;
        req.stop_token = None;
        sched.submit(req);
    }
    let rep = sched.run_to_completion();
    let long_ttft: Vec<f64> = rep
        .requests
        .iter()
        .filter(|r| r.id >= steady as u64 && !r.rejected)
        .map(|r| r.ttft())
        .collect();
    let ttft = long_ttft.iter().sum::<f64>() / long_ttft.len().max(1) as f64;
    (ttft, rep.tpot_summary().p99, rep.throughput_tok_s(), rep.preemptions() as usize)
}

fn main() {
    common::header("Figure 8", "end-to-end TPOT vs batch size");
    let ctx = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4096usize);
    let model = common::retrieval_model(ctx * 2);
    let v = RetrievalVocab::DEFAULT;
    println!(
        "{:>6} {:<18} {:>10} {:>12} {:>10}",
        "batch", "method", "tpot-ms", "tok/s", "vs-dense"
    );
    for batch in [4usize, 16, 32] {
        let mut dense_tpot = 0.0;
        for (label, cfg) in [
            ("FlashInfer(dense)", SparseConfig::dense()),
            ("Quest B=N/4", {
                let mut c = SparseConfig::baseline(SelectorKind::Quest, ctx / 4);
                c.skip_layers = 0;
                c
            }),
            ("Quest-Twi p=0.95", {
                let mut c = SparseConfig::twilight(SelectorKind::Quest, 0.95);
                c.skip_layers = 0;
                c
            }),
        ] {
            let engine = Engine::new(model.clone(), cfg, (ctx + 80) * (batch + 1));
            let mut sched = Scheduler::new(
                engine,
                SchedulerConfig { max_batch: batch, ..Default::default() },
            );
            let mut rng = Rng::new(9);
            for i in 0..batch {
                let g = gen_niah(&mut rng, v, ctx);
                sched.submit(Request::new(i as u64, g.prompt, 6));
            }
            let rep = sched.run_to_completion();
            let tpot = rep.tpot_summary().mean;
            if label.starts_with("FlashInfer") {
                dense_tpot = tpot;
            }
            println!(
                "{:>6} {:<18} {:>10.2} {:>12.1} {:>9.2}x",
                batch,
                label,
                tpot * 1e3,
                rep.throughput_tok_s(),
                dense_tpot / tpot,
            );
        }
    }

    // --- Part 2: TTFT vs prefill chunk span ---------------------------
    // One long-prompt arrival against a steady decode set, per span and
    // worker count, on a 4-layer model (whose chunk queries really run
    // the multi-query attention work list): chunked prefill rides the
    // LPT-balanced pool, so prefill wall-clock drops with workers while
    // chunk=1 serializes.
    let pctx = ctx.min(2048); // multi-layer CPU prefill: keep panels brisk
    println!();
    common::header("Figure 8b", "TTFT vs prefill chunk span (long arrival over steady decodes)");
    println!(
        "{:>7} {:>8} {:>12} {:>12} {:>12}",
        "threads", "chunk", "ttft-ms", "tpot-p99-ms", "tok/s"
    );
    for threads in [1usize, 4] {
        for chunk in [1usize, 16, 64, 256] {
            let (ttft, tpot_p99, tok_s, _) = chunked_run(pctx, chunk, threads, 4, 1);
            println!(
                "{:>7} {:>8} {:>12.2} {:>12.2} {:>12.1}",
                threads,
                chunk,
                ttft * 1e3,
                tpot_p99 * 1e3,
                tok_s
            );
        }
    }

    // --- Part 3: mixed load, serial vs chunked prefill ----------------
    // A burst of long prompts during steady decode: serial admission
    // (chunk=1) head-of-line-blocks every decode for whole prompts;
    // chunked admission bounds the stall by the per-step token budget.
    println!();
    common::header("Figure 8c", "mixed load: serial (chunk=1) vs chunked prefill");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>8}",
        "mode", "ttft-ms", "tpot-p99-ms", "tok/s", "preempt"
    );
    for (label, chunk) in [("serial", 1usize), ("chunked", 64)] {
        let (ttft, tpot_p99, tok_s, preempt) = chunked_run(pctx, chunk, 4, 8, 3);
        println!(
            "{:>10} {:>12.2} {:>12.2} {:>12.1} {:>8}",
            label,
            ttft * 1e3,
            tpot_p99 * 1e3,
            tok_s,
            preempt
        );
    }

    // --- Part 4: sparse prefill — TTFT vs context ---------------------
    // Dense chunked prefill vs `--sparse-prefill` (bound-guided page
    // skipping) on the 4-layer model: same prompt, same spans, the only
    // difference is whether chunk queries walk every sealed page or only
    // the bound-ordered prefix the hier top-p test keeps. The skip
    // fraction is the fraction of *gated* pages (beyond the local
    // window) the early stop never visited.
    println!();
    common::header("Figure 8d", "sparse prefill: TTFT vs context (dense vs top-p page skip)");
    println!(
        "{:>7} {:>12} {:>13} {:>9} {:>10}",
        "ctx", "dense-ms", "sparse-ms", "speedup", "skip-frac"
    );
    let model4 = deep_model(11);
    let vocab4 = model4.cfg.vocab_size;
    let mut sp_rows = Vec::new();
    for c in [pctx / 4, pctx / 2, pctx] {
        let mut rng = Rng::new(29);
        let prompt: Vec<u32> = (0..c).map(|_| rng.below(vocab4) as u32).collect();
        let mut run = |sparse: bool| {
            let mut cfg = SparseConfig::dense();
            cfg.sparse_prefill = sparse.then(SparsePrefillCfg::default);
            let mut e = Engine::new(model4.clone(), cfg, c + 128);
            e.set_threads(4);
            e.set_prefill_chunk(64);
            let t0 = std::time::Instant::now();
            e.prefill(0, &prompt).unwrap();
            let skip = if e.stats.prefill_blocks_total == 0 {
                0.0
            } else {
                e.stats.prefill_blocks_skipped as f64 / e.stats.prefill_blocks_total as f64
            };
            (t0.elapsed().as_secs_f64(), skip)
        };
        let (t_dense, _) = run(false);
        let (t_sparse, skip_frac) = run(true);
        println!(
            "{:>7} {:>12.2} {:>13.2} {:>8.2}x {:>10.3}",
            c,
            t_dense * 1e3,
            t_sparse * 1e3,
            t_dense / t_sparse,
            skip_frac
        );
        sp_rows.push(json::obj(vec![
            ("ctx", Json::Num(c as f64)),
            ("dense_ms", Json::Num(t_dense * 1e3)),
            ("sparse_ms", Json::Num(t_sparse * 1e3)),
            ("speedup", Json::Num(t_dense / t_sparse)),
            ("skip_frac", Json::Num(skip_frac)),
        ]));
    }
    // Recall pin: skipping must not lose the needle. The retrieval
    // model's peaked NIAH caches are exactly the regime the bound order
    // exploits, so the skip is aggressive *and* the answer must survive.
    let mut rng = Rng::new(31);
    let mut correct = 0usize;
    let trials = 8usize;
    let mut pin_skip = (0u64, 0u64);
    for _ in 0..trials {
        let g = gen_niah(&mut rng, v, pctx);
        let mut cfg = SparseConfig::dense();
        cfg.sparse_prefill = Some(SparsePrefillCfg::default());
        let mut e = Engine::new(model.clone(), cfg, pctx + 128);
        e.set_threads(4);
        e.set_prefill_chunk(64);
        let logits = e.prefill(0, &g.prompt).unwrap();
        let argmax = logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i as u32);
        correct += usize::from(argmax == Some(g.answer));
        pin_skip.0 += e.stats.prefill_blocks_skipped;
        pin_skip.1 += e.stats.prefill_blocks_total;
    }
    let pin_frac = if pin_skip.1 == 0 { 0.0 } else { pin_skip.0 as f64 / pin_skip.1 as f64 };
    println!(
        "recall pin: NIAH@{pctx} answered {correct}/{trials} with skip-frac {pin_frac:.3}"
    );
    let doc = json::obj(vec![
        ("bench", Json::Str("fig8_sparse_prefill".to_string())),
        ("arch", Json::Str(std::env::consts::ARCH.to_string())),
        ("ttft", Json::Arr(sp_rows)),
        ("recall_correct", Json::Num(correct as f64)),
        ("recall_trials", Json::Num(trials as f64)),
        ("recall_skip_frac", Json::Num(pin_frac)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_prefill.json");
    match std::fs::write(&path, doc.pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
