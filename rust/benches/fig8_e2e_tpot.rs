//! Fig. 8 — end-to-end decoding TPOT across batch sizes, through the
//! full coordinator (queue → continuous batcher → engine); plus the
//! chunked-prefill panels: TTFT vs chunk span, and a mixed-load
//! comparison of serial (chunk=1) vs chunked prefill while a steady
//! decode set is running.

mod common;

use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

/// A small *multi-layer* random model for the chunked-prefill panels:
/// the 1-layer retrieval model's prefill chunks take the algebraic
/// attend-skip (layer-0 K/V needs no attention), so only a deeper model
/// exercises the multi-query attention work the panels measure.
fn deep_model(seed: u64) -> std::sync::Arc<twilight::model::Model> {
    use twilight::model::{Model, ModelConfig};
    let cfg = ModelConfig {
        name: "fig8-deep".into(),
        vocab_size: 256,
        d_model: 64,
        n_layers: 4,
        n_heads: 8,
        n_kv_heads: 4,
        head_dim: 16,
        d_ff: 128,
        use_rope: true,
        rope_theta: 10000.0,
        use_norm: true,
        norm_eps: 1e-5,
        max_ctx: 1 << 15,
    };
    std::sync::Arc::new(Model::random(&cfg, seed))
}

/// TTFT / TPOT / preemptions for one chunked-prefill serving run over
/// the multi-layer model (prompts are random tokens — the panels
/// measure latency shape, not retrieval accuracy).
fn chunked_run(
    ctx: usize,
    chunk: usize,
    threads: usize,
    steady: usize,
    long_arrivals: usize,
) -> (f64, f64, f64, usize) {
    let model = deep_model(11);
    let vocab = model.cfg.vocab_size;
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    cfg.skip_layers = 0;
    let mut engine = Engine::new(model, cfg, (ctx + 80) * (steady + long_arrivals + 1));
    engine.set_threads(threads);
    engine.set_prefill_chunk(chunk);
    let mut sched = Scheduler::new(
        engine,
        SchedulerConfig { max_batch: steady + long_arrivals, ..Default::default() },
    );
    let mut rng = Rng::new(17);
    let mut prompt = |len: usize| -> Vec<u32> {
        (0..len).map(|_| rng.below(vocab) as u32).collect()
    };
    // Steady decoders: short prompts, long generations.
    for i in 0..steady {
        let mut req = Request::new(i as u64, prompt(128), 48);
        req.stop_token = None;
        sched.submit(req);
    }
    // Long-prompt arrivals land once the steady set is decoding.
    for i in 0..long_arrivals {
        let mut req = Request::new((steady + i) as u64, prompt(ctx), 4);
        req.arrival = 0.005 * (i + 1) as f64;
        req.stop_token = None;
        sched.submit(req);
    }
    let rep = sched.run_to_completion();
    let long_ttft: Vec<f64> = rep
        .requests
        .iter()
        .filter(|r| r.id >= steady as u64 && !r.rejected)
        .map(|r| r.ttft())
        .collect();
    let ttft = long_ttft.iter().sum::<f64>() / long_ttft.len().max(1) as f64;
    (ttft, rep.tpot_summary().p99, rep.throughput_tok_s(), rep.preemptions() as usize)
}

fn main() {
    common::header("Figure 8", "end-to-end TPOT vs batch size");
    let ctx = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4096usize);
    let model = common::retrieval_model(ctx * 2);
    let v = RetrievalVocab::DEFAULT;
    println!(
        "{:>6} {:<18} {:>10} {:>12} {:>10}",
        "batch", "method", "tpot-ms", "tok/s", "vs-dense"
    );
    for batch in [4usize, 16, 32] {
        let mut dense_tpot = 0.0;
        for (label, cfg) in [
            ("FlashInfer(dense)", SparseConfig::dense()),
            ("Quest B=N/4", {
                let mut c = SparseConfig::baseline(SelectorKind::Quest, ctx / 4);
                c.skip_layers = 0;
                c
            }),
            ("Quest-Twi p=0.95", {
                let mut c = SparseConfig::twilight(SelectorKind::Quest, 0.95);
                c.skip_layers = 0;
                c
            }),
        ] {
            let engine = Engine::new(model.clone(), cfg, (ctx + 80) * (batch + 1));
            let mut sched = Scheduler::new(
                engine,
                SchedulerConfig { max_batch: batch, ..Default::default() },
            );
            let mut rng = Rng::new(9);
            for i in 0..batch {
                let g = gen_niah(&mut rng, v, ctx);
                sched.submit(Request::new(i as u64, g.prompt, 6));
            }
            let rep = sched.run_to_completion();
            let tpot = rep.tpot_summary().mean;
            if label.starts_with("FlashInfer") {
                dense_tpot = tpot;
            }
            println!(
                "{:>6} {:<18} {:>10.2} {:>12.1} {:>9.2}x",
                batch,
                label,
                tpot * 1e3,
                rep.throughput_tok_s(),
                dense_tpot / tpot,
            );
        }
    }

    // --- Part 2: TTFT vs prefill chunk span ---------------------------
    // One long-prompt arrival against a steady decode set, per span and
    // worker count, on a 4-layer model (whose chunk queries really run
    // the multi-query attention work list): chunked prefill rides the
    // LPT-balanced pool, so prefill wall-clock drops with workers while
    // chunk=1 serializes.
    let pctx = ctx.min(2048); // multi-layer CPU prefill: keep panels brisk
    println!();
    common::header("Figure 8b", "TTFT vs prefill chunk span (long arrival over steady decodes)");
    println!(
        "{:>7} {:>8} {:>12} {:>12} {:>12}",
        "threads", "chunk", "ttft-ms", "tpot-p99-ms", "tok/s"
    );
    for threads in [1usize, 4] {
        for chunk in [1usize, 16, 64, 256] {
            let (ttft, tpot_p99, tok_s, _) = chunked_run(pctx, chunk, threads, 4, 1);
            println!(
                "{:>7} {:>8} {:>12.2} {:>12.2} {:>12.1}",
                threads,
                chunk,
                ttft * 1e3,
                tpot_p99 * 1e3,
                tok_s
            );
        }
    }

    // --- Part 3: mixed load, serial vs chunked prefill ----------------
    // A burst of long prompts during steady decode: serial admission
    // (chunk=1) head-of-line-blocks every decode for whole prompts;
    // chunked admission bounds the stall by the per-step token budget.
    println!();
    common::header("Figure 8c", "mixed load: serial (chunk=1) vs chunked prefill");
    println!(
        "{:>10} {:>12} {:>12} {:>12} {:>8}",
        "mode", "ttft-ms", "tpot-p99-ms", "tok/s", "preempt"
    );
    for (label, chunk) in [("serial", 1usize), ("chunked", 64)] {
        let (ttft, tpot_p99, tok_s, preempt) = chunked_run(pctx, chunk, 4, 8, 3);
        println!(
            "{:>10} {:>12.2} {:>12.2} {:>12.1} {:>8}",
            label,
            ttft * 1e3,
            tpot_p99 * 1e3,
            tok_s,
            preempt
        );
    }
}
