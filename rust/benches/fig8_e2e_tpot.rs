//! Fig. 8 — end-to-end decoding TPOT across batch sizes, through the
//! full coordinator (queue → continuous batcher → engine).

mod common;

use twilight::coordinator::engine::Engine;
use twilight::coordinator::request::Request;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::SparseConfig;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

fn main() {
    common::header("Figure 8", "end-to-end TPOT vs batch size");
    let ctx = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4096usize);
    let model = common::retrieval_model(ctx * 2);
    let v = RetrievalVocab::DEFAULT;
    println!(
        "{:>6} {:<18} {:>10} {:>12} {:>10}",
        "batch", "method", "tpot-ms", "tok/s", "vs-dense"
    );
    for batch in [4usize, 16, 32] {
        let mut dense_tpot = 0.0;
        for (label, cfg) in [
            ("FlashInfer(dense)", SparseConfig::dense()),
            ("Quest B=N/4", {
                let mut c = SparseConfig::baseline(SelectorKind::Quest, ctx / 4);
                c.skip_layers = 0;
                c
            }),
            ("Quest-Twi p=0.95", {
                let mut c = SparseConfig::twilight(SelectorKind::Quest, 0.95);
                c.skip_layers = 0;
                c
            }),
        ] {
            let engine = Engine::new(model.clone(), cfg, (ctx + 80) * (batch + 1));
            let mut sched = Scheduler::new(
                engine,
                SchedulerConfig { max_batch: batch, ..Default::default() },
            );
            let mut rng = Rng::new(9);
            for i in 0..batch {
                let g = gen_niah(&mut rng, v, ctx);
                sched.submit(Request::new(i as u64, g.prompt, 6));
            }
            let rep = sched.run_to_completion();
            let tpot = rep.tpot_summary().mean;
            if label.starts_with("FlashInfer") {
                dense_tpot = tpot;
            }
            println!(
                "{:>6} {:<18} {:>10.2} {:>12.1} {:>9.2}x",
                batch,
                label,
                tpot * 1e3,
                rep.throughput_tok_s(),
                dense_tpot / tpot,
            );
        }
    }
}
