//! Fig. 10 — time breakdown of the Select-then-Prune pipeline vs the
//! Quest baseline, at several batch sizes on a long-retrieval workload.
//! Cross-checks the §4.3 cost model. A final panel measures the span
//! tracer's overhead on the decode hot path (target: < 3%).

mod common;

use twilight::coordinator::engine::Engine;
use twilight::coordinator::SparseConfig;
use twilight::selector::SelectorKind;
use twilight::sim;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, RetrievalVocab};

fn main() {
    common::header("Figure 10", "time breakdown: selector / pruner / attention");
    let ctx = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(16384usize);
    let model = common::retrieval_model(ctx * 2);
    let v = RetrievalVocab::DEFAULT;
    println!(
        "{:>6} {:<16} {:>10} {:>9} {:>9} {:>9} {:>10}",
        "batch", "method", "ms/step", "select%", "prune%", "attend%", "avg-budget"
    );
    for batch in [1usize, 8, 32] {
        for (label, cfg) in [
            ("Quest B=N/4", {
                let mut c = SparseConfig::baseline(SelectorKind::Quest, ctx / 4);
                c.skip_layers = 0;
                c
            }),
            ("Quest-Twi", {
                let mut c = SparseConfig::twilight(SelectorKind::Quest, 0.95);
                c.skip_layers = 0;
                c
            }),
        ] {
            let mut e = Engine::new(model.clone(), cfg, (ctx + 64) * batch + 64);
            let mut rng = Rng::new(5);
            for i in 0..batch {
                let g = gen_niah(&mut rng, v, ctx);
                let _ = e.prefill(i as u64, &g.prompt).unwrap();
            }
            e.reset_stats();
            let steps = 4;
            let t0 = std::time::Instant::now();
            for _ in 0..steps {
                for i in 0..batch {
                    let _ = e.decode(i as u64, 3).unwrap();
                }
            }
            let total = t0.elapsed().as_secs_f64();
            let s = &e.stats;
            println!(
                "{:>6} {:<16} {:>10.2} {:>8.1}% {:>8.1}% {:>8.1}% {:>10.1}",
                batch,
                label,
                total / steps as f64 * 1e3,
                100.0 * s.t_select / total,
                100.0 * s.t_prune / total,
                100.0 * (s.t_attend + s.t_dense) / total,
                s.avg_kept(),
            );
        }
    }
    // §4.3 closed form for reference.
    let b0 = ctx as f64 / 4.0;
    println!(
        "\n§4.3 theoretical speedup at B0=N/4, B1=N/64: {:.2}x",
        sim::theoretical_speedup(ctx as f64, b0, ctx as f64 / 64.0)
    );

    // --- tracing overhead panel ---------------------------------------
    // Same warmed engine, same decode loop, span recorder off vs on
    // (DESIGN.md §10: a span is four relaxed atomic stores into a
    // pre-sized per-thread ring). Decode order is identical either way —
    // tracing is purely observational — so the delta is the recorder.
    println!("\ntracing overhead (span recorder, ctx={ctx}, batch=8):");
    let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
    cfg.skip_layers = 0;
    let batch = 8usize;
    let mut e = Engine::new(model, cfg, (ctx + 64) * batch + 64);
    let mut rng = Rng::new(5);
    for i in 0..batch {
        let g = gen_niah(&mut rng, v, ctx);
        let _ = e.prefill(i as u64, &g.prompt).unwrap();
    }
    let steps = 8;
    let mut time_decode = |traced: bool| -> f64 {
        twilight::obs::trace::set_enabled(traced);
        // One warm pass: lets the traced leg create its span rings off
        // the clock (a one-time allocation per thread).
        for i in 0..batch {
            let _ = e.decode(i as u64, 3).unwrap();
        }
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            for i in 0..batch {
                let _ = e.decode(i as u64, 3).unwrap();
            }
        }
        let dt = t0.elapsed().as_secs_f64();
        twilight::obs::trace::set_enabled(false);
        dt / steps as f64
    };
    let off = time_decode(false);
    let on = time_decode(true);
    let overhead = (on / off - 1.0) * 100.0;
    println!(
        "{:>10} {:>10} {:>9}\n{:>10.2} {:>10.2} {:>8.1}%  (target < 3%)",
        "off-ms", "on-ms", "overhead",
        off * 1e3,
        on * 1e3,
        overhead,
    );
    let (held, dropped) = twilight::obs::trace::event_counts();
    println!("spans held {held}, dropped {dropped}");
}
