//! Fig. 4 — cumulative attention mass vs budget on one real attention
//! head, with the under-/over-selection points (B=16, B=1024) and the
//! adaptive top-p point (p=0.8).

mod common;

use twilight::evalsuite::distributions::{cumulative_mass, final_position_weights};
use twilight::pruner::topp::oracle_budget;
use twilight::util::rng::Rng;
use twilight::workload::{gen_fwe, RetrievalVocab};

fn main() {
    common::header("Figure 4", "cumulative attention mass vs budget");
    let v = RetrievalVocab::DEFAULT;
    let ctx = 4096;
    let model = common::retrieval_model(ctx * 2);
    let mut rng = Rng::new(4);
    // A *mixed* head profile: FWE prompt viewed by the aggregation head
    // yields a semi-diffuse distribution like the paper's example.
    let g = gen_fwe(&mut rng, v, ctx, 6.0);
    let ws = final_position_weights(&model, &g.prompt, 0);
    for (head, label) in [(0usize, "retrieval (focused)"), (4, "aggregation (diffuse)")] {
        let cum = cumulative_mass(&ws[head]);
        println!("\nhead {head} — {label}");
        println!("{:>8} {:>12}", "budget", "cum-mass");
        for b in [1usize, 4, 16, 64, 97, 256, 1024, 4096] {
            let b = b.min(cum.len());
            println!("{:>8} {:>12.4}", b, cum[b - 1]);
        }
        let b80 = oracle_budget(&ws[head], 0.8);
        println!("top-p p=0.8 selects budget {b80} (mass {:.4})", cum[b80.saturating_sub(1).min(cum.len() - 1)]);
    }
    println!(
        "\nReading: B=16 under-selects the diffuse head; B=1024 over-selects\n\
         the focused head; p=0.8 adapts to each (the Fig. 4 argument)."
    );
}
