//! Fig. 2 — held-out perplexity vs KV-cache budget for the top-k
//! methods (charlm on the synthetic corpus; requires `make artifacts`).
//! The paper's shape: each method needs a *different* budget to approach
//! full-attention ppl, and the oracle needs the least.

mod common;

use twilight::coordinator::SparseConfig;
use twilight::evalsuite::ppl::eval_ppl;
use twilight::selector::SelectorKind;
use twilight::workload::load_corpus;

fn main() {
    common::header("Figure 2", "perplexity vs budget per top-k method (charlm)");
    let Some(model) = common::charlm() else {
        println!("SKIP: charlm artifacts missing (run `make artifacts`)");
        return;
    };
    let corpus = load_corpus("artifacts/corpus_eval.bin").expect("corpus artifact");
    let windows = 2;
    let wlen = 384;
    let burn = 48;
    let full = eval_ppl(model.clone(), &SparseConfig::dense(), &corpus, windows, wlen, burn);
    println!("full attention ppl = {:.3}\n", full.ppl);
    println!("{:>9} {:>10} {:>10} {:>10} {:>10}", "budget", "oracle", "quest", "ds", "streaming");
    for budget in [8usize, 16, 32, 64, 128, 256] {
        let mut row = format!("{budget:>9}");
        for sel in [
            SelectorKind::Oracle,
            SelectorKind::Quest,
            SelectorKind::DoubleSparsity,
            SelectorKind::StreamingLlm,
        ] {
            let mut cfg = SparseConfig::baseline(sel, budget);
            cfg.skip_layers = 2; // paper: first two layers dense
            cfg.dense_below = budget;
            let r = eval_ppl(model.clone(), &cfg, &corpus, windows, wlen, burn);
            row.push_str(&format!(" {:>10.3}", r.ppl));
        }
        println!("{row}");
    }
    println!("\n(lower is better; oracle should reach full-ppl at the smallest budget)");
}
