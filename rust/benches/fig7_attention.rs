//! Fig. 7 — self-attention operator latency and speedup across sequence
//! lengths and batch sizes.
//!
//! Methods (CPU analogs, DESIGN.md §2): FA2 = contiguous dense;
//! FlashInfer = paged dense; FlashInfer-Twi = Full selector + Twilight;
//! Quest = page top-k at B=N/4; Quest-Twi = Quest + Twilight. Reported:
//! measured per-(seq × kv-head × step) latency, speedup vs FA2, and the
//! byte-model estimated-A100 latency.

mod common;

use std::time::Duration;
use twilight::attention::{full, sparse};
use twilight::pruner::{prune_group_into, PrunerConfig, PrunerScratch};
use twilight::selector::{quest::QuestSelector, TokenSelector};
use twilight::sim;
use twilight::util::stats::bench;

fn main() {
    common::header("Figure 7", "self-attention latency vs seqlen × batch");
    let d = 64;
    let kv_heads = 1;
    let group = 4; // 4 query heads per kv head (GQA)
    // Optional comma-separated lens in argv (cargo bench also passes
    // flags like `--bench`; ignore anything non-numeric).
    let mut lens: Vec<usize> = std::env::args()
        .skip(1)
        .flat_map(|s| s.split(',').filter_map(|t| t.parse().ok()).collect::<Vec<_>>())
        .collect();
    if lens.is_empty() {
        lens = vec![4096, 8192, 16384, 32768];
    }
    let batches = [1usize, 8];
    println!(
        "{:>7} {:>6} {:<16} {:>12} {:>9} {:>12}",
        "seqlen", "batch", "method", "ms/step", "vs-FA2", "est-A100-us"
    );
    for &n in &lens {
        for &b in &batches {
            let caches: Vec<_> =
                (0..b).map(|i| common::structured_cache(100 + i as u64, kv_heads, d, n)).collect();
            let qs: Vec<Vec<f32>> = (0..b)
                .map(|i| common::focused_queries(7 + i as u64, &caches[i].0, &caches[i].1, 0, group, 2.0))
                .collect();
            // Contiguous copies for the FA2 analog.
            let flat: Vec<(Vec<f32>, Vec<f32>)> = caches
                .iter()
                .map(|(c, s)| {
                    let mut k = Vec::with_capacity(n * d);
                    let mut v = Vec::with_capacity(n * d);
                    for t in 0..s.len {
                        let (p, sl) = s.locate(t, 16);
                        k.extend_from_slice(c.k_at(p, 0, sl));
                        v.extend_from_slice(c.v_at(p, 0, sl));
                    }
                    (k, v)
                })
                .collect();
            let mut out = vec![0.0f32; group * d];
            // Reused streaming-softmax state for the group-varlen calls
            // (engine parity: the hot path never allocates these).
            let mut sm_m: Vec<f32> = Vec::new();
            let mut sm_d: Vec<f32> = Vec::new();
            let warm = Duration::from_millis(50);
            let meas = Duration::from_millis(300);

            let mut results = Vec::new();
            // FA2 analog.
            let r = bench("fa2", warm, meas, 3, || {
                for i in 0..b {
                    for g in 0..group {
                        full::contiguous_full(
                            &qs[i][g * d..(g + 1) * d],
                            &flat[i].0,
                            &flat[i].1,
                            &mut out[g * d..(g + 1) * d],
                        );
                    }
                }
            });
            let fa2 = r.secs.mean;
            results.push(("FA2", fa2, sim::full_stage_bytes(n, d)));
            // FlashInfer analog (paged streaming).
            let r = bench("flashinfer", warm, meas, 3, || {
                for i in 0..b {
                    for g in 0..group {
                        full::paged_full(
                            &caches[i].0,
                            &caches[i].1,
                            0,
                            &qs[i][g * d..(g + 1) * d],
                            &mut out[g * d..(g + 1) * d],
                        );
                    }
                }
            });
            results.push(("FlashInfer", r.secs.mean, sim::full_stage_bytes(n, d)));
            // FlashInfer-Twi: prune the full context then sparse-attend.
            let pc = PrunerConfig { p: 0.9, ..Default::default() };
            let all: Vec<usize> = (0..n).collect();
            let mut scratch = PrunerScratch::default();
            // The engine-parity _into path: results stay in the scratch
            // arena (timing the cloning wrapper would charge the panel a
            // per-call deep copy the engine never pays).
            let r = bench("flashinfer-twi", warm, meas, 3, || {
                for i in 0..b {
                    prune_group_into(
                        &pc, &caches[i].0, &caches[i].1, 0, &qs[i], group, &all, &mut scratch,
                    );
                    sparse::group_varlen_with(
                        &caches[i].0, &caches[i].1, 0, &qs[i], group, &scratch.union,
                        &mut sm_m, &mut sm_d, &mut out,
                    );
                }
            });
            let b1 = {
                prune_group_into(&pc, &caches[0].0, &caches[0].1, 0, &qs[0], group, &all, &mut scratch);
                scratch.union.len()
            };
            results.push((
                "FlashInfer-Twi",
                r.secs.mean,
                sim::quest_twilight_stage_bytes(n, d, 16, n, b1),
            ));
            // Quest B=N/4.
            let budget = n / 4;
            let mut selectors: Vec<QuestSelector> = (0..b).map(|_| QuestSelector::new()).collect();
            let r = bench("quest", warm, meas, 3, || {
                for i in 0..b {
                    let cand = selectors[i].select(&caches[i].0, &caches[i].1, 0, &qs[i], group, budget);
                    sparse::group_varlen_with(
                        &caches[i].0, &caches[i].1, 0, &qs[i], group, &cand, &mut sm_m, &mut sm_d,
                        &mut out,
                    );
                }
            });
            results.push(("Quest", r.secs.mean, sim::quest_stage_bytes(n, d, 16, budget)));
            // Quest-Twi.
            let r = bench("quest-twi", warm, meas, 3, || {
                for i in 0..b {
                    let cand = selectors[i].select(&caches[i].0, &caches[i].1, 0, &qs[i], group, budget);
                    prune_group_into(&pc, &caches[i].0, &caches[i].1, 0, &qs[i], group, &cand, &mut scratch);
                    sparse::group_varlen_with(
                        &caches[i].0, &caches[i].1, 0, &qs[i], group, &scratch.union,
                        &mut sm_m, &mut sm_d, &mut out,
                    );
                }
            });
            let b1q = {
                let cand = selectors[0].select(&caches[0].0, &caches[0].1, 0, &qs[0], group, budget);
                prune_group_into(&pc, &caches[0].0, &caches[0].1, 0, &qs[0], group, &cand, &mut scratch);
                scratch.union.len()
            };
            results.push((
                "Quest-Twi",
                r.secs.mean,
                sim::quest_twilight_stage_bytes(n, d, 16, budget, b1q),
            ));
            for (name, secs, bytes) in &results {
                // Batched-kernel estimate: per-seq bytes scale with batch,
                // kernel launches do not.
                let stages = [bytes.selector, bytes.pruner, bytes.attention]
                    .iter()
                    .filter(|&&x| x > 0)
                    .count() as f64;
                let est = (bytes.total() * b) as f64 / sim::A100.mem_bw
                    + stages * sim::A100.launch_overhead;
                println!(
                    "{:>7} {:>6} {:<16} {:>12.3} {:>8.1}x {:>12.1}",
                    n,
                    b,
                    name,
                    secs * 1e3,
                    fa2 / secs,
                    est * 1e6,
                );
            }
        }
    }
}
