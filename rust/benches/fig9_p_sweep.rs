//! Fig. 9 — sensitivity to the threshold p: charlm perplexity (accuracy)
//! and sparse-attention step latency (efficiency) across p values.

mod common;

use twilight::coordinator::engine::Engine;
use twilight::coordinator::SparseConfig;
use twilight::evalsuite::ppl::eval_ppl;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::workload::{gen_niah, load_corpus, RetrievalVocab};

fn main() {
    common::header("Figure 9", "accuracy & latency vs threshold p");
    let ps = [0.5f32, 0.7, 0.8, 0.85, 0.9, 0.95, 0.99];
    // Latency side: retrieval model at 16k context.
    let ctx = 16384;
    let model = common::retrieval_model(ctx * 2);
    let mut rng = Rng::new(1);
    let g = gen_niah(&mut rng, RetrievalVocab::DEFAULT, ctx);
    println!("{:>6} {:>14} {:>12} {:>12}", "p", "attn-ms/step", "avg-budget", "charlm-ppl");
    let charlm = common::charlm();
    let corpus = load_corpus("artifacts/corpus_eval.bin").ok();
    for &p in &ps {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, p);
        cfg.skip_layers = 0;
        let mut e = Engine::new(model.clone(), cfg, ctx + 64);
        let _ = e.prefill(0, &g.prompt).unwrap();
        e.reset_stats();
        let steps = 6;
        let t0 = std::time::Instant::now();
        for _ in 0..steps {
            let _ = e.decode(0, 3).unwrap();
        }
        let ms = t0.elapsed().as_secs_f64() / steps as f64 * 1e3;
        let ppl = match (&charlm, &corpus) {
            (Some(m), Some(c)) => {
                let mut cc = SparseConfig::twilight(SelectorKind::Quest, p);
                cc.skip_layers = 2;
                format!("{:>12.3}", eval_ppl(m.clone(), &cc, c, 2, 256, 32).ppl)
            }
            _ => format!("{:>12}", "n/a"),
        };
        println!("{:>6.2} {:>14.2} {:>12.1} {}", p, ms, e.stats.avg_kept(), ppl);
    }
    println!("\n(the knee — good ppl at low latency — should sit near p≈0.85-0.95)");
}
