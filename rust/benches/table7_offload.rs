//! Table 7 — attention latency in CPU-offload scenarios, two panels:
//!
//! **Operator panel.** Single attention-operator latency when the KV
//! cache lives behind a slow link; Quest must load B0 = N/4 tokens
//! through it, Quest-Twi loads only the pruned B1 (its INT4 mirror stays
//! resident).
//!
//! **Engine panel.** End-to-end decode TPOT with the tiered KV cache
//! (DESIGN.md §12) at shrinking resident fractions: sealed pages spill
//! to the simulated slow tier, hier-bound prefetch faults back only the
//! pages that can still carry top-p mass, and fault I/O overlaps
//! attention on resident pages. The headline number is the TPOT ratio
//! vs fully resident — the pruned working set keeps it **sublinear** in
//! 1/frac (the acceptance bar is ≤ 2x at 25% resident).
//!
//! Besides the console tables, results land in `BENCH_offload.json` at
//! the repo root (uploaded as a CI artifact) so offload regressions are
//! diffable across runs.

mod common;

use std::sync::Arc;
use std::time::{Duration, Instant};
use twilight::attention::full::contiguous_full;
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::SparseConfig;
use twilight::kvcache::offload::OffloadArena;
use twilight::model::retrieval::build_retrieval_model;
use twilight::pruner::{prune_group_into, PrunerConfig, PrunerScratch};
use twilight::selector::{quest::QuestSelector, SelectorKind, TokenSelector};
use twilight::util::json::{self, Json};
use twilight::util::rng::Rng;
use twilight::util::stats::bench;
use twilight::workload::{gen_niah, RetrievalVocab};

fn panel_operator() -> Vec<Json> {
    let d = 64;
    let mut rows = Vec::new();
    println!("{:>7} {:>14} {:>14} {:>9}", "tokens", "Quest-us", "Quest-Twi-us", "speedup");
    for n in [10_240usize, 20_480, 30_720] {
        let (cache, seq) = common::structured_cache(7, 1, d, n);
        // Offload arena mirrors the cache contents behind a slow link.
        let mut arena = OffloadArena::new(d, 8);
        for t in 0..n {
            let (p, s) = seq.locate(t, 16);
            arena.push(cache.k_at(p, 0, s), cache.v_at(p, 0, s));
        }
        // Focused-head queries (retrieval regime — where offloading bites).
        let q = common::focused_queries(9, &cache, &seq, 0, 1, 2.0);
        let budget = n / 4;
        let mut selector = QuestSelector::new();
        let pc = PrunerConfig { p: 0.9, ..Default::default() };
        let mut scratch = PrunerScratch::default();
        let mut out = vec![0.0f32; d];
        let mut kbuf = vec![0.0f32; budget * d];
        let mut vbuf = vec![0.0f32; budget * d];
        let warm = Duration::from_millis(40);
        let meas = Duration::from_millis(300);
        // Quest: select pages (metadata resident), then *load* B0 tokens
        // through the link and attend.
        let r_quest = bench("quest-offload", warm, meas, 2, || {
            let cand = selector.select(&cache, &seq, 0, &q, 1, budget);
            arena.load_tokens(&cand, &mut kbuf[..cand.len() * d], &mut vbuf[..cand.len() * d]);
            contiguous_full(&q, &kbuf[..cand.len() * d], &vbuf[..cand.len() * d], &mut out);
        });
        // Quest-Twi: same selection; pruner reads the resident INT4
        // mirror; only B1 tokens cross the link.
        let r_twi = bench("quest-twi-offload", warm, meas, 2, || {
            let cand = selector.select(&cache, &seq, 0, &q, 1, budget);
            // Engine-parity _into path (no per-call outcome clone).
            prune_group_into(&pc, &cache, &seq, 0, &q, 1, &cand, &mut scratch);
            let b1 = scratch.union.len();
            arena.load_tokens(&scratch.union, &mut kbuf[..b1 * d], &mut vbuf[..b1 * d]);
            contiguous_full(&q, &kbuf[..b1 * d], &vbuf[..b1 * d], &mut out);
        });
        println!(
            "{:>7} {:>14.1} {:>14.1} {:>8.1}x",
            n,
            r_quest.secs.mean * 1e6,
            r_twi.secs.mean * 1e6,
            r_quest.secs.mean / r_twi.secs.mean
        );
        rows.push(json::obj(vec![
            ("tokens", Json::Num(n as f64)),
            ("quest_us", Json::Num(r_quest.secs.mean * 1e6)),
            ("quest_twi_us", Json::Num(r_twi.secs.mean * 1e6)),
            ("speedup", Json::Num(r_quest.secs.mean / r_twi.secs.mean)),
        ]));
    }
    rows
}

/// Decode TPOT at shrinking resident fractions. The page pool (4096
/// tokens = 256 pages) holds a ~197-page working set, so frac 0.5 (cap
/// 128) already forces the tier onto the hot path and frac 0.1 (cap 26)
/// thrashes; the hier-bound prefetch plan is what keeps the ratio
/// sublinear.
fn panel_engine() -> Vec<Json> {
    const V: RetrievalVocab = RetrievalVocab::DEFAULT;
    const CAPACITY: usize = 4096;
    const WARM_STEPS: usize = 3;
    const MEAS_STEPS: usize = 24;
    println!(
        "\n{:>6} {:>12} {:>8} {:>9} {:>11} {:>9}",
        "frac", "tpot-ms", "ratio", "faults", "prefetched", "overlap"
    );
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut rows = Vec::new();
    let mut base_tpot = 0.0f64;
    for &frac in &[1.0f64, 0.5, 0.25, 0.1] {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = Engine::new(model.clone(), cfg, CAPACITY);
        e.set_threads(4);
        e.set_resident_frac(frac);
        let mut rng = Rng::new(41);
        let mut toks = Vec::new();
        for i in 0..3u64 {
            let g = gen_niah(&mut rng, V, 512 * (i as usize + 1));
            let _ = e.prefill(i, &g.prompt).expect("prompt fits the page pool");
            toks.push((i, g.prompt[0]));
        }
        for _ in 0..WARM_STEPS {
            for r in e.step_batch(&DecodeBatch::new(toks.clone())) {
                r.expect("warm decode fits");
            }
        }
        let faults0 = e.stats.offload_faults;
        let t0 = Instant::now();
        for _ in 0..MEAS_STEPS {
            for r in e.step_batch(&DecodeBatch::new(toks.clone())) {
                r.expect("measured decode fits");
            }
        }
        // Per-token: each step advances all 3 sequences by one token.
        let tpot = t0.elapsed().as_secs_f64() / (MEAS_STEPS * toks.len()) as f64;
        if frac >= 1.0 {
            base_tpot = tpot;
        }
        let ratio = if base_tpot > 0.0 { tpot / base_tpot } else { 1.0 };
        let faults = e.stats.offload_faults - faults0;
        let prefetched = e.stats.offload_prefetched;
        let overlap = if e.stats.offload_faults == 0 {
            0.0
        } else {
            prefetched as f64 / e.stats.offload_faults as f64
        };
        println!(
            "{:>6.2} {:>12.3} {:>7.2}x {:>9} {:>11} {:>9.2}",
            frac,
            tpot * 1e3,
            ratio,
            faults,
            prefetched,
            overlap
        );
        rows.push(json::obj(vec![
            ("resident_frac", Json::Num(frac)),
            ("tpot_ms", Json::Num(tpot * 1e3)),
            ("tpot_ratio", Json::Num(ratio)),
            ("measured_faults", Json::Num(faults as f64)),
            ("total_faults", Json::Num(e.stats.offload_faults as f64)),
            ("prefetched", Json::Num(prefetched as f64)),
            ("evictions", Json::Num(e.stats.offload_evictions as f64)),
            ("overlap_frac", Json::Num(overlap)),
        ]));
    }
    rows
}

/// Resilience panel (DESIGN.md §14): serve a fixed request mix through
/// the scheduler at frac 0.25 while the chaos injector degrades the
/// tier, sweeping the injected read-fault probability. Reported per
/// rate: completion rate (1.0 when the retry ladder heals everything),
/// survivor TPOT p50 (failed requests are excluded from latency, so the
/// ladder's retry cost shows up here, not as skew), and the fault /
/// lost-page counters. The acceptance bar is qualitative: completion
/// degrades gracefully with the fault rate and the run never crashes.
fn panel_resilience() -> Vec<Json> {
    use twilight::coordinator::request::Request;
    use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
    use twilight::kvcache::offload::ChaosConfig;
    const V: RetrievalVocab = RetrievalVocab::DEFAULT;
    const CAPACITY: usize = 4096;
    println!(
        "{:>8} {:>11} {:>12} {:>8} {:>9} {:>8}",
        "p_fault", "complete", "tpot-p50-ms", "failed", "retries", "lost"
    );
    let model = Arc::new(build_retrieval_model(V, 1 << 14));
    let mut rows = Vec::new();
    for &p_fault in &[0.0f64, 0.05, 0.2, 0.5] {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut engine = Engine::new(model.clone(), cfg, CAPACITY);
        engine.set_threads(4);
        engine.set_chaos((p_fault > 0.0).then_some(ChaosConfig {
            seed: 7,
            p_read: p_fault,
            p_write: p_fault / 2.0,
            p_panic: 0.0,
        }));
        engine.set_resident_frac(0.25);
        let mut s = Scheduler::new(engine, SchedulerConfig::default());
        let mut rng = Rng::new(41);
        for i in 0..6u64 {
            let g = gen_niah(&mut rng, V, 256 * (i as usize % 3 + 1));
            s.submit(Request::new(i, g.prompt, 8));
        }
        let rep = s.run_to_completion();
        let tpot = rep.tpot_summary();
        println!(
            "{:>8.2} {:>11.3} {:>12.3} {:>8} {:>9} {:>8}",
            p_fault,
            rep.completion_rate(),
            tpot.p50 * 1e3,
            rep.failed(),
            rep.tier_retries,
            rep.pages_lost
        );
        rows.push(json::obj(vec![
            ("p_fault", Json::Num(p_fault)),
            ("completion_rate", Json::Num(rep.completion_rate())),
            ("tpot_p50_ms", Json::Num(tpot.p50 * 1e3)),
            ("failed", Json::Num(rep.failed() as f64)),
            ("tier_read_errors", Json::Num(rep.tier_read_errors as f64)),
            ("tier_retries", Json::Num(rep.tier_retries as f64)),
            ("pages_lost", Json::Num(rep.pages_lost as f64)),
        ]));
    }
    rows
}

fn main() {
    common::header("Table 7", "attention latency with offloaded KV (us)");
    let operator = panel_operator();
    common::header("Table 7b", "tiered decode TPOT vs resident fraction");
    let engine = panel_engine();
    common::header("Table 7c", "completion & TPOT vs injected tier-fault rate");
    let resilience = panel_resilience();
    let doc = json::obj(vec![
        ("bench", Json::Str("table7_offload".to_string())),
        ("arch", Json::Str(std::env::consts::ARCH.to_string())),
        ("operator", Json::Arr(operator)),
        ("engine", Json::Arr(engine)),
        ("resilience", Json::Arr(resilience)),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_offload.json");
    match std::fs::write(&path, doc.pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", path.display()),
    }
}
