//! Table 7 — single attention-operator latency in CPU-offload scenarios:
//! the KV cache lives behind a slow link; Quest must load B0 = N/4 tokens
//! through it, Quest-Twi loads only the pruned B1 (its INT4 mirror stays
//! resident).

mod common;

use std::time::Duration;
use twilight::attention::full::contiguous_full;
use twilight::kvcache::offload::OffloadArena;
use twilight::pruner::{prune_group_into, PrunerConfig, PrunerScratch};
use twilight::selector::{quest::QuestSelector, TokenSelector};
use twilight::util::rng::Rng;
use twilight::util::stats::bench;

fn main() {
    common::header("Table 7", "attention latency with offloaded KV (us)");
    let d = 64;
    println!("{:>7} {:>14} {:>14} {:>9}", "tokens", "Quest-us", "Quest-Twi-us", "speedup");
    for n in [10_240usize, 20_480, 30_720] {
        let (cache, seq) = common::structured_cache(7, 1, d, n);
        // Offload arena mirrors the cache contents behind a slow link.
        let mut arena = OffloadArena::new(d, 8);
        for t in 0..n {
            let (p, s) = seq.locate(t, 16);
            arena.push(cache.k_at(p, 0, s), cache.v_at(p, 0, s));
        }
        // Focused-head queries (retrieval regime — where offloading bites).
        let q = common::focused_queries(9, &cache, &seq, 0, 1, 2.0);
        let budget = n / 4;
        let mut selector = QuestSelector::new();
        let pc = PrunerConfig { p: 0.9, ..Default::default() };
        let mut scratch = PrunerScratch::default();
        let mut out = vec![0.0f32; d];
        let mut kbuf = vec![0.0f32; budget * d];
        let mut vbuf = vec![0.0f32; budget * d];
        let warm = Duration::from_millis(40);
        let meas = Duration::from_millis(300);
        // Quest: select pages (metadata resident), then *load* B0 tokens
        // through the link and attend.
        let r_quest = bench("quest-offload", warm, meas, 2, || {
            let cand = selector.select(&cache, &seq, 0, &q, 1, budget);
            arena.load_tokens(&cand, &mut kbuf[..cand.len() * d], &mut vbuf[..cand.len() * d]);
            contiguous_full(&q, &kbuf[..cand.len() * d], &vbuf[..cand.len() * d], &mut out);
        });
        // Quest-Twi: same selection; pruner reads the resident INT4
        // mirror; only B1 tokens cross the link.
        let r_twi = bench("quest-twi-offload", warm, meas, 2, || {
            let cand = selector.select(&cache, &seq, 0, &q, 1, budget);
            // Engine-parity _into path (no per-call outcome clone).
            prune_group_into(&pc, &cache, &seq, 0, &q, 1, &cand, &mut scratch);
            let b1 = scratch.union.len();
            arena.load_tokens(&scratch.union, &mut kbuf[..b1 * d], &mut vbuf[..b1 * d]);
            contiguous_full(&q, &kbuf[..b1 * d], &vbuf[..b1 * d], &mut out);
        });
        println!(
            "{:>7} {:>14.1} {:>14.1} {:>8.1}x",
            n,
            r_quest.secs.mean * 1e6,
            r_twi.secs.mean * 1e6,
            r_quest.secs.mean / r_twi.secs.mean
        );
        let mut rng = Rng::new(0);
        let _ = rng.f32();
    }
}
