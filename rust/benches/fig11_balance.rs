//! Fig. 11 — load balancing with awareness of head dynamism (§4.2).
//!
//! Part 1: LPT vs round-robin makespan over a Twilight-skewed budget
//! distribution (many focused heads with tiny budgets, a few diffuse
//! heads near N) — the allocation strawman the paper argues against.
//!
//! Part 2: thread scaling of the *real* batched decode step: the engine
//! flattens (sequence × kv-head) items, LPT-partitions them, and drains
//! the buckets on its persistent `ThreadPool`.
//!
//! Part 3: spawn amortization — persistent pool vs spawn-per-round
//! scoped threads over many tiny rounds (the `layers × steps` regime of
//! a small batch, where per-item work is nearly nothing and framework
//! fixed costs decide the curve). Ends with the bit-exactness check
//! (threads=1 vs threads=4 logits must be identical).

mod common;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;
use twilight::coordinator::balance::{
    lpt_partition, makespan, round_robin_partition, WorkItem,
};
use twilight::coordinator::engine::{DecodeBatch, Engine};
use twilight::coordinator::SparseConfig;
use twilight::selector::SelectorKind;
use twilight::util::rng::Rng;
use twilight::util::threadpool::ThreadPool;
use twilight::workload::{gen_niah, RetrievalVocab};

const V: RetrievalVocab = RetrievalVocab::DEFAULT;

/// The pre-pool implementation: scoped threads spawned per call — the
/// fixed cost Part 3 measures against the persistent pool.
fn scoped_parallel_for<F: Fn(usize) + Sync>(threads: usize, n: usize, chunk: usize, work: F) {
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    work(i);
                }
            });
        }
    });
}

/// Twilight-like budget skew: ~15% diffuse heads (budget near N), the
/// rest focused (tens of tokens).
fn skewed_items(seed: u64, seqs: usize, heads: usize, n: usize) -> Vec<WorkItem> {
    let mut r = Rng::new(seed);
    let mut items = Vec::with_capacity(seqs * heads);
    for s in 0..seqs {
        for h in 0..heads {
            let budget =
                if r.chance(0.15) { r.range(n / 4, n) } else { r.range(8, 128.min(n)) };
            items.push(WorkItem { seq: s as u32, kv_head: h as u32, budget });
        }
    }
    items
}

fn main() {
    common::header("Figure 11", "LPT vs round-robin + batched decode thread scaling");

    // --- Part 1: makespan on skewed budgets ----------------------------
    println!("makespan on skewed budgets (32 seqs × 8 kv-heads, N=16384):");
    println!("{:<10} {:>12} {:>14} {:>10}", "workers", "LPT", "round-robin", "ratio");
    let items = skewed_items(11, 32, 8, 16384);
    let mut lpt_never_worse = true;
    for workers in [2usize, 4, 8, 16] {
        let lpt = makespan(&lpt_partition(&items, workers));
        let rr = makespan(&round_robin_partition(&items, workers));
        lpt_never_worse &= lpt <= rr;
        println!("{workers:<10} {lpt:>12} {rr:>14} {:>9.2}x", rr as f64 / lpt as f64);
    }
    println!(
        "LPT ≤ round-robin on every worker count: {}",
        if lpt_never_worse { "OK" } else { "VIOLATED" }
    );

    // --- Part 2: thread scaling of the real batched step ---------------
    let nseqs = 8;
    let ctx = 2048;
    let steps = 12;
    let build = |threads: usize| -> (Engine, DecodeBatch) {
        let model = Arc::new(twilight::model::retrieval::build_retrieval_model(V, 1 << 15));
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = Engine::new(model, cfg, (ctx + 64) * nseqs * 2);
        e.set_threads(threads);
        let mut r = Rng::new(5);
        let mut toks = Vec::new();
        for i in 0..nseqs as u64 {
            let g = gen_niah(&mut r, V, ctx);
            let _ = e.prefill(i, &g.prompt).unwrap();
            toks.push((i, g.prompt[0]));
        }
        (e, DecodeBatch::new(toks))
    };
    println!("\nbatched decode, {nseqs} seqs × {ctx} ctx (quest+twi p=0.9):");
    println!("{:<10} {:>12} {:>10}", "threads", "ms/step", "speedup");
    let mut base_ms = 0.0;
    for threads in [1usize, 2, 4, 8] {
        let (mut e, batch) = build(threads);
        // Warm.
        for _ in 0..2 {
            let _ = e.step_batch(&batch);
        }
        let t0 = Instant::now();
        for _ in 0..steps {
            for res in e.step_batch(&batch) {
                res.expect("OOM in bench");
            }
        }
        let ms = t0.elapsed().as_secs_f64() * 1e3 / steps as f64;
        if threads == 1 {
            base_ms = ms;
        }
        println!("{threads:<10} {ms:>12.3} {:>9.2}x", base_ms / ms);
    }

    // --- Part 3: spawn amortization, persistent vs scoped --------------
    // The engine runs one pool round per layer per decode step; at small
    // batch the per-round work is tiny, so the old spawn-per-round cost
    // scaled with layers × steps. Simulate that regime directly: many
    // rounds of a few buckets with near-zero work each.
    let rounds = 3000usize; // ≈ 32 layers × ~94 steps
    let buckets = 8usize;
    let work_per_bucket = 64usize;
    let sink = AtomicU64::new(0);
    let bucket_work = |w: usize| {
        let mut acc = 0u64;
        for k in 0..work_per_bucket {
            acc = acc.wrapping_add((w * 31 + k) as u64);
        }
        sink.fetch_add(acc, Ordering::Relaxed);
    };
    let pool = ThreadPool::new(buckets);
    pool.run(buckets, 1, &bucket_work); // warm: residents spawn here
    let spawned_after_warm = pool.spawned_threads();
    let t0 = Instant::now();
    for _ in 0..rounds {
        pool.run(buckets, 1, &bucket_work);
    }
    let pooled_us = t0.elapsed().as_secs_f64() * 1e6 / rounds as f64;
    let t0 = Instant::now();
    for _ in 0..rounds {
        scoped_parallel_for(buckets, buckets, 1, &bucket_work);
    }
    let scoped_us = t0.elapsed().as_secs_f64() * 1e6 / rounds as f64;
    println!(
        "\nspawn amortization ({rounds} rounds × {buckets} buckets × {work_per_bucket} adds):"
    );
    println!("{:<12} {:>12}", "variant", "us/round");
    println!("{:<12} {:>12.2}", "persistent", pooled_us);
    println!("{:<12} {:>12.2}", "scoped", scoped_us);
    println!("scoped/persistent: {:.2}x", scoped_us / pooled_us);
    assert_eq!(
        pool.spawned_threads(),
        spawned_after_warm,
        "persistent pool must not spawn after warm-up"
    );
    assert!(pool.spawned_threads() < buckets, "caller participates in every round");
    let _ = std::hint::black_box(sink.load(Ordering::Relaxed));

    // --- Bit-exactness: threads=1 ≡ threads=4 --------------------------
    let run = |threads: usize| -> Vec<Vec<f32>> {
        let (mut e, batch) = build(threads);
        let mut out = Vec::new();
        for _ in 0..4 {
            for res in e.step_batch(&batch) {
                out.push(res.expect("OOM in parity run"));
            }
        }
        out
    };
    let parity = run(1) == run(4);
    let verdict = if parity { "OK" } else { "FAILED" };
    println!("\nbit-exact parity (threads=1 vs threads=4): {verdict}");
    assert!(lpt_never_worse, "LPT makespan exceeded round-robin");
    assert!(parity, "multi-threaded decode diverged from sequential");
}
