//! Fig. 6 — kept attention mass at p=0.85 when the pruner estimates
//! weights from INT2 / INT4 / INT8 / FP16 mirrors, scored under the
//! exact (FP32) weights. The paper's finding: INT2 collapses, INT4 ≈ INT8.

mod common;

use twilight::attention::spgemv::QuantizedK;
use twilight::pruner::topp::topp_binary_search;
use twilight::tensor::quant::QuantBits;
use twilight::tensor::{dot, softmax_inplace};
use twilight::util::rng::Rng;
use twilight::util::stats::mean;

fn main() {
    common::header("Figure 6", "true attention mass captured at p=0.85 per quant width");
    let d = 128;
    let n = 4096;
    let p = 0.85f32;
    let trials = 12;
    println!("{:>6} {:>14} {:>12}", "bits", "kept-mass", "avg-budget");
    for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
        let mut masses = Vec::new();
        let mut budgets = Vec::new();
        for t in 0..trials {
            let mut r = Rng::new(100 + t);
            let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 2.0)).collect();
            // Exact weights.
            let scale = 1.0 / (d as f32).sqrt();
            let mut exact: Vec<f32> =
                (0..n).map(|i| dot(&q, &k[i * d..(i + 1) * d]) * scale).collect();
            softmax_inplace(&mut exact);
            // Estimated weights from the quantized mirror.
            let qk = QuantizedK::from_rows(&k, d, bits, 16);
            let mut est = vec![0.0f32; n];
            qk.gemv(&q, &mut est);
            for e in est.iter_mut() {
                *e *= scale;
            }
            softmax_inplace(&mut est);
            let sel = topp_binary_search(&est, p, 1e-5);
            // Score: how much *true* mass the estimated selection kept.
            let kept: f32 = sel.indices.iter().map(|&i| exact[i]).sum();
            masses.push(kept as f64);
            budgets.push(sel.indices.len() as f64);
        }
        println!("{:>6} {:>14.4} {:>12.1}", bits.bits(), mean(&masses), mean(&budgets));
    }
    println!("\n(INT2 should fall visibly below p; INT4 and INT8 should both hold ≈p or above)");
}
