#![allow(dead_code)]
//! Shared helpers for the figure benches (harness = false; criterion is
//! not in the offline crate set — timing comes from `util::stats::bench`).

use std::sync::Arc;
use twilight::kvcache::{CacheConfig, PagedKvCache, SeqCache};
use twilight::model::Model;
use twilight::util::rng::Rng;

/// Build a paged cache with `n` tokens whose keys have page-coherent
/// structure (per-page centroids + noise) — the locality real KV caches
/// exhibit and Quest exploits.
pub fn structured_cache(seed: u64, kv_heads: usize, d: usize, n: usize) -> (PagedKvCache, SeqCache) {
    let mut cache = PagedKvCache::new(CacheConfig::new(kv_heads, d, n / 16 + 2));
    let mut seq = SeqCache::default();
    let mut r = Rng::new(seed);
    let mut centroid: Vec<f32> = (0..kv_heads * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
    for t in 0..n {
        if t % 16 == 0 {
            centroid = (0..kv_heads * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        }
        let k: Vec<f32> = centroid.iter().map(|&c| c + r.normal_f32(0.0, 0.3)).collect();
        let v: Vec<f32> = (0..kv_heads * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        cache.append(&mut seq, &k, &v).unwrap();
    }
    (cache, seq)
}

/// Random query heads `[h * d]`, sharpened so attention is focused.
pub fn queries(seed: u64, h: usize, d: usize, sharp: f32) -> Vec<f32> {
    let mut r = Rng::new(seed);
    (0..h * d).map(|_| r.normal_f32(0.0, sharp)).collect()
}

/// Attention-realistic queries: each head's query is a sharpened copy of
/// a real key from the cache plus noise — the focused-head regime
/// (retrieval heads) where sparse attention pays off. Random queries
/// orthogonal to all keys would give maximally-diffuse attention that
/// *nothing* can prune; real LLM heads are not like that (Fig. 3).
pub fn focused_queries(
    seed: u64,
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    h: usize,
    gain: f32,
) -> Vec<f32> {
    let mut r = Rng::new(seed);
    let d = cache.cfg.head_dim;
    let mut out = Vec::with_capacity(h * d);
    for _ in 0..h {
        let t = r.below(seq.len);
        let (page, slot) = seq.locate(t, cache.cfg.page_size);
        let k = cache.k_at(page, kv_head, slot);
        out.extend(k.iter().map(|&x| gain * x + r.normal_f32(0.0, 0.3)));
    }
    out
}

/// The retrieval model shared by the engine-level benches.
pub fn retrieval_model(max_ctx: usize) -> Arc<Model> {
    Arc::new(twilight::model::retrieval::build_retrieval_model(
        twilight::workload::RetrievalVocab::DEFAULT,
        max_ctx,
    ))
}

/// Charlm from artifacts, if built.
pub fn charlm() -> Option<Arc<Model>> {
    twilight::model::weights::load_model("artifacts", "charlm").ok().map(Arc::new)
}

/// Print a bench header with the exhibit it reproduces.
pub fn header(exhibit: &str, what: &str) {
    println!("=== {exhibit} — {what} ===");
}
