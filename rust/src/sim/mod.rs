//! Bytes-touched memory cost model (DESIGN.md §2).
//!
//! Every attention method in the paper is memory-bandwidth-bound during
//! decode: its latency is (bytes streamed from HBM) / (achieved HBM
//! bandwidth), plus small fixed overheads. This module accounts the bytes
//! each pipeline stage must touch and converts them to estimated latency
//! under a hardware profile, so benches can report an estimated-A100
//! number next to the measured-CPU number and the §4.3 theoretical
//! speedup can be cross-checked in tests.

use crate::tensor::quant::QuantBits;

/// A memory system profile.
#[derive(Clone, Copy, Debug)]
pub struct HwProfile {
    pub name: &'static str,
    /// Achievable main-memory bandwidth, bytes/sec.
    pub mem_bw: f64,
    /// Fixed per-kernel launch overhead, seconds.
    pub launch_overhead: f64,
}

/// NVIDIA A100-80G SXM: ~2.0 TB/s peak, ~1.6 TB/s achieved.
pub const A100: HwProfile =
    HwProfile { name: "a100", mem_bw: 1.6e12, launch_overhead: 6e-6 };

/// Single CPU core with DDR: ~10 GB/s achieved streaming.
pub const CPU1: HwProfile =
    HwProfile { name: "cpu-1core", mem_bw: 1.0e10, launch_overhead: 1e-7 };

/// Element width of the main KV cache (the paper's caches are FP16).
pub const KV_BYTES: usize = 2;

/// Traffic (bytes) for one decode-step attention over `tokens` tokens of
/// one KV head: K and V rows.
pub fn attn_bytes(tokens: usize, d: usize) -> usize {
    tokens * d * KV_BYTES * 2
}

/// Traffic for Quest page metadata: min+max per page.
pub fn quest_meta_bytes(n: usize, d: usize, page: usize) -> usize {
    n.div_ceil(page) * 2 * d * KV_BYTES
}

/// Traffic for the label cache of Double Sparsity (r channels at int4).
pub fn ds_label_bytes(n: usize, r: usize) -> usize {
    n * r / 2
}

/// Traffic for the Twilight SpGEMV over `candidates` at `bits`.
pub fn spgemv_bytes(candidates: usize, d: usize, bits: QuantBits) -> usize {
    bits.bytes_for(candidates * d)
}

/// Per-stage byte counts of one decode step for one KV head.
#[derive(Clone, Debug, Default)]
pub struct StageBytes {
    pub selector: usize,
    pub pruner: usize,
    pub attention: usize,
}

impl StageBytes {
    pub fn total(&self) -> usize {
        self.selector + self.pruner + self.attention
    }

    /// Estimated latency on `hw`, counting one kernel launch per non-zero
    /// stage.
    pub fn latency(&self, hw: &HwProfile) -> f64 {
        let stages =
            [self.selector, self.pruner, self.attention].iter().filter(|&&b| b > 0).count();
        self.total() as f64 / hw.mem_bw + stages as f64 * hw.launch_overhead
    }
}

/// The paper's §4.3 configurations, for one head over context `n`:
/// traffic for a base top-k method with budget `b0`, with and without
/// the Twilight pruner reducing the final budget to `b1`.
pub fn quest_stage_bytes(n: usize, d: usize, page: usize, b0: usize) -> StageBytes {
    StageBytes {
        selector: quest_meta_bytes(n, d, page),
        pruner: 0,
        attention: attn_bytes(b0, d),
    }
}

pub fn quest_twilight_stage_bytes(
    n: usize,
    d: usize,
    page: usize,
    b0: usize,
    b1: usize,
) -> StageBytes {
    StageBytes {
        selector: quest_meta_bytes(n, d, page),
        pruner: spgemv_bytes(b0, d, QuantBits::Int4),
        attention: attn_bytes(b1, d),
    }
}

pub fn full_stage_bytes(n: usize, d: usize) -> StageBytes {
    StageBytes { selector: 0, pruner: 0, attention: attn_bytes(n, d) }
}

/// §4.3 closed-form speedup: `(N/16 + B0) / (N/16 + B0/4 + B1)`.
/// (Selector estimation at 1/16 traffic; pruner reads B0 at INT4 = 1/4 of
/// FP16; final attention over B1.)
pub fn theoretical_speedup(n: f64, b0: f64, b1: f64) -> f64 {
    (n / 16.0 + b0) / (n / 16.0 + b0 / 4.0 + b1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_example_speedup_is_about_2x() {
        // §4.3: B0 = N/4, B1 = N/64 → ≈ 2×.
        let n = 32768.0;
        let s = theoretical_speedup(n, n / 4.0, n / 64.0);
        assert!((s - 2.0).abs() < 0.3, "s={s}");
    }

    #[test]
    fn stage_bytes_match_closed_form_ratio() {
        // The byte-level model should agree with the closed form when
        // metadata ≈ N/16 FP16 traffic and pruner reads INT4.
        let n = 32768;
        let d = 128;
        let b0 = n / 4;
        let b1 = n / 64;
        let base = quest_stage_bytes(n, d, 16, b0);
        let twi = quest_twilight_stage_bytes(n, d, 16, b0, b1);
        let ratio = base.total() as f64 / twi.total() as f64;
        // K+V for attention vs K-only metadata shifts constants; the
        // closed form in the paper tracks K-traffic. Accept the band.
        let cf = theoretical_speedup(n as f64, b0 as f64, b1 as f64);
        assert!((ratio / cf - 1.0).abs() < 0.5, "ratio={ratio} cf={cf}");
        assert!(ratio > 1.5, "twilight must win: {ratio}");
    }

    #[test]
    fn latency_monotone_in_tokens() {
        let a = full_stage_bytes(1000, 128).latency(&A100);
        let b = full_stage_bytes(10_000, 128).latency(&A100);
        assert!(b > a);
    }

    #[test]
    fn spgemv_bytes_scale_with_bits() {
        let b4 = spgemv_bytes(1024, 128, QuantBits::Int4);
        let b16 = spgemv_bytes(1024, 128, QuantBits::Fp16);
        assert_eq!(b16, b4 * 4);
    }
}
