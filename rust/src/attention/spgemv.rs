//! Score-estimation SpGEMV (paper §4.2, Appendix B.1).
//!
//! Computes `q · K̂ᵀ` over the *quantized mirror* K cache for a set of
//! candidate tokens ("sparse" = paged/indexed access, matching the
//! paper's FlashInfer-derived kernel where the INT4 K pages are gathered
//! by page table). The fused dequant-dot never materializes K̂: the
//! integer codes are multiplied directly and scale/zero are applied once
//! per row — the CPU analog of unpacking INT4 in shared memory.
//!
//! Rows in the *unsealed* tail page (tokens at or past
//! `⌊seq.len / page_size⌋ · page_size` when the tail is partial) have no
//! mirror block yet — the cache only quantizes a page when it seals —
//! and are scored exactly from fp32 K. Besides matching the paper's
//! quantize-on-page-close schedule, this makes the estimate a pure
//! function of the visible prefix: a chunked-prefill query that sees a
//! truncated view of its sequence scores the same whether the chunk
//! appended 1 or 256 tokens behind it.

use crate::kvcache::{quant_dot_row, quant_dot_row_qsum, PagedKvCache, SeqCache};
use crate::tensor::dot;
use crate::tensor::quant::{quantize, QuantBits, QuantBlock};

/// First token of the visibly-partial tail page (== `seq.len` when the
/// visible tail page is full, i.e. every visible row is sealed).
#[inline]
fn sealed_limit(seq: &SeqCache, page_size: usize) -> usize {
    seq.len - seq.len % page_size
}

/// Estimate logits (unscaled by 1/sqrt(d)) for `tokens` from the mirror
/// cache into `out`; unsealed tail rows are scored exactly.
pub fn estimate_scores(
    cache: &PagedKvCache,
    seq: &SeqCache,
    head: usize,
    q: &[f32],
    tokens: &[usize],
    out: &mut [f32],
) {
    debug_assert_eq!(tokens.len(), out.len());
    let d = cache.cfg.head_dim;
    let ps = cache.cfg.page_size;
    let sealed = sealed_limit(seq, ps);
    let qsum: f32 = q.iter().sum();
    for (o, &t) in out.iter_mut().zip(tokens) {
        let (page, slot) = seq.locate(t, ps);
        if t < sealed {
            let block = cache.mirror_at(page, head).expect("sealed page missing mirror");
            *o = quant_dot_row_qsum(q, qsum, block, slot * d, d);
        } else {
            *o = dot(q, cache.k_at(page, head, slot));
        }
    }
}

/// Estimate logits for a whole GQA group in one pass over the mirror:
/// each packed row is unpacked once and contracted with every query head
/// (§Perf); unsealed tail rows are scored exactly. `out` is
/// `[group][tokens.len()]` flattened row-major.
pub fn estimate_scores_group(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    tokens: &[usize],
    out: &mut [f32],
) {
    let d = cache.cfg.head_dim;
    let ps = cache.cfg.page_size;
    debug_assert_eq!(out.len(), group * tokens.len());
    let sealed = sealed_limit(seq, ps);
    let qsums: Vec<f32> =
        (0..group).map(|g| qs[g * d..(g + 1) * d].iter().sum()).collect();
    let n = tokens.len();
    let mut row = vec![0.0f32; group];
    for (i, &t) in tokens.iter().enumerate() {
        let (page, slot) = seq.locate(t, ps);
        if t < sealed {
            let block = cache.mirror_at(page, kv_head).expect("sealed page missing mirror");
            crate::kvcache::quant_dot_row_group(qs, &qsums, block, slot * d, d, &mut row);
        } else {
            let k = cache.k_at(page, kv_head, slot);
            for (g, r) in row.iter_mut().enumerate() {
                *r = dot(&qs[g * d..(g + 1) * d], k);
            }
        }
        for g in 0..group {
            out[g * n + i] = row[g];
        }
    }
}

/// A standalone quantized K matrix (contiguous, one head) for kernels and
/// benches that do not need the paged pool — e.g. the Fig. 12 SpGEMV
/// latency ablation across bit widths.
pub struct QuantizedK {
    pub d: usize,
    pub n: usize,
    pub bits: QuantBits,
    /// One block per group of `group_rows` rows (per-block scale/zero).
    pub blocks: Vec<QuantBlock>,
    pub group_rows: usize,
}

impl QuantizedK {
    /// Quantize `k` (`[n, d]` row-major) at `bits`, `group_rows` rows per
    /// scale/zero group (the paper uses one page = 16 rows).
    pub fn from_rows(k: &[f32], d: usize, bits: QuantBits, group_rows: usize) -> QuantizedK {
        let n = k.len() / d;
        let mut blocks = Vec::with_capacity(n.div_ceil(group_rows));
        let mut i = 0;
        while i < n {
            let rows = group_rows.min(n - i);
            blocks.push(quantize(&k[i * d..(i + rows) * d], bits));
            i += rows;
        }
        QuantizedK { d, n, bits, blocks, group_rows }
    }

    /// Total packed bytes (the memory the kernel must stream).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.packed.len() + 8).sum()
    }

    /// `out[i] = q · K̂[rows[i]]`.
    pub fn spgemv(&self, q: &[f32], rows: &[usize], out: &mut [f32]) {
        debug_assert_eq!(q.len(), self.d);
        for (o, &r) in out.iter_mut().zip(rows) {
            let block = &self.blocks[r / self.group_rows];
            let slot = r % self.group_rows;
            *o = quant_dot_row(q, block, slot * self.d, self.d);
        }
    }

    /// Dense GEMV over all rows: `out[i] = q · K̂[i]`.
    pub fn gemv(&self, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n);
        let mut row = 0;
        for block in &self.blocks {
            let rows = block.n / self.d;
            for s in 0..rows {
                out[row] = quant_dot_row(q, block, s * self.d, self.d);
                row += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};
    use crate::tensor::dot;
    use crate::util::rng::Rng;

    #[test]
    fn estimate_close_to_exact_int4() {
        let (cache, seq) = random_cache(31, 1, 32, 128);
        let q = random_q(32, 32);
        let toks: Vec<usize> = (0..128).collect();
        let mut est = vec![0.0; 128];
        estimate_scores(&cache, &seq, 0, &q, &toks, &mut est);
        let mut worst = 0.0f32;
        for (&t, &e) in toks.iter().zip(&est) {
            let exact = cache.exact_score(&seq, 0, &q, t);
            worst = worst.max((exact - e).abs());
        }
        // INT4 per-page groups over N(0,1) keys, d=32: per-element error is
        // ~scale/2 ≈ 0.2, so dot error concentrates near 0.2·sqrt(32)·σ_q;
        // the observed worst case sits well under 2 while logits span ±15.
        assert!(worst < 2.0, "worst abs err {worst}");
    }

    #[test]
    fn unsealed_tail_scored_exactly() {
        // 2 sealed pages + an 8-row unsealed tail: sealed rows go through
        // the mirror, tail rows must be exact fp32 — bit-for-bit, since
        // chunk invariance rides on this being a pure function of the
        // visible prefix.
        let (cache, seq) = random_cache(33, 1, 16, 40);
        let q = random_q(34, 16);
        let toks: Vec<usize> = vec![0, 31, 32, 39];
        let mut est = vec![0.0; toks.len()];
        estimate_scores(&cache, &seq, 0, &q, &toks, &mut est);
        for (&t, &e) in toks.iter().zip(&est) {
            if t >= 32 {
                assert_eq!(e, cache.exact_score(&seq, 0, &q, t), "tail row {t} not exact");
            }
        }
        // The group path must agree with the single-head path.
        let mut grp = vec![0.0; toks.len()];
        estimate_scores_group(&cache, &seq, 0, &q, 1, &toks, &mut grp);
        assert_eq!(est, grp);
        // A truncated view (chunked prefill mid-chunk) relies only on
        // sealed pages + exact tail: same call, shorter visible length.
        let view = SeqCache { pages: seq.pages[..2].to_vec(), len: 20 };
        let vtoks: Vec<usize> = vec![15, 16, 19];
        let mut vest = vec![0.0; vtoks.len()];
        estimate_scores(&cache, &view, 0, &q, &vtoks, &mut vest);
        assert_eq!(vest[1], cache.exact_score(&view, 0, &q, 16));
        assert_eq!(vest[2], cache.exact_score(&view, 0, &q, 19));
    }

    #[test]
    fn rank_correlation_int4_beats_int2() {
        let mut r = Rng::new(77);
        let d = 64;
        let n = 256;
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let exact: Vec<f32> = (0..n).map(|i| dot(&q, &k[i * d..(i + 1) * d])).collect();
        let top_exact = top_set(&exact, 32);
        let overlap = |bits: QuantBits| {
            let qk = QuantizedK::from_rows(&k, d, bits, 16);
            let mut est = vec![0.0; n];
            qk.gemv(&q, &mut est);
            let top_est = top_set(&est, 32);
            top_exact.iter().filter(|t| top_est.contains(t)).count()
        };
        let o2 = overlap(QuantBits::Int2);
        let o4 = overlap(QuantBits::Int4);
        let o8 = overlap(QuantBits::Int8);
        assert!(o4 > o2, "int4 {o4} <= int2 {o2}");
        assert!(o8 >= o4, "int8 {o8} < int4 {o4}");
        assert!(o4 >= 28, "int4 overlap too low: {o4}/32");
    }

    fn top_set(xs: &[f32], k: usize) -> std::collections::HashSet<usize> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
        idx.into_iter().take(k).collect()
    }

    #[test]
    fn spgemv_subset_matches_gemv() {
        let mut r = Rng::new(5);
        let d = 16;
        let n = 64;
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let qk = QuantizedK::from_rows(&k, d, QuantBits::Int4, 16);
        let mut dense = vec![0.0; n];
        qk.gemv(&q, &mut dense);
        let rows = vec![0usize, 7, 16, 63];
        let mut sparse = vec![0.0; rows.len()];
        qk.spgemv(&q, &rows, &mut sparse);
        for (i, &row) in rows.iter().enumerate() {
            assert_eq!(sparse[i], dense[row]);
        }
    }

    #[test]
    fn bytes_scale_with_bits() {
        let k = vec![0.5f32; 128 * 64];
        let b2 = QuantizedK::from_rows(&k, 64, QuantBits::Int2, 16).bytes();
        let b4 = QuantizedK::from_rows(&k, 64, QuantBits::Int4, 16).bytes();
        let b8 = QuantizedK::from_rows(&k, 64, QuantBits::Int8, 16).bytes();
        let b16 = QuantizedK::from_rows(&k, 64, QuantBits::Fp16, 16).bytes();
        assert!(b2 < b4 && b4 < b8 && b8 < b16);
        // Ratio roughly 2:4:8:16.
        assert!((b16 as f64 / b4 as f64 - 4.0).abs() < 0.2);
    }
}
