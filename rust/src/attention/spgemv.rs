//! Score-estimation SpGEMV (paper §4.2, Appendix B.1) — **page-tiled**.
//!
//! Computes `q · K̂ᵀ` over the *quantized mirror* K cache for a set of
//! candidate tokens ("sparse" = paged/indexed access, matching the
//! paper's FlashInfer-derived kernel where the INT4 K pages are gathered
//! by page table).
//!
//! The hot path walks the candidate list as **per-page runs** (candidates
//! arrive ascending from the selectors, so runs are contiguous), unpacks
//! each mirror `QuantBlock`'s code window **once per run** into a
//! reusable scratch tile ([`SpgemvScratch::tile`]), and then contracts
//! every row of the run against every query head of the GQA group — the
//! unpack pass, which dominates the fused dequant-dot on CPU, is
//! amortized across both the rows of the run and the heads of the group.
//! Per-row dot values are bit-identical to the historical row-major fused
//! path ([`estimate_scores_rowmajor`], kept as the reference): the tile
//! holds exactly the f32 code values the per-row stack buffer held, and
//! each row is contracted by the same `tensor::dot` (or, for the
//! single-head Fp16 case, the same sequential accumulation), so logits —
//! and everything downstream: top-p sets, telemetry, golden traces — do
//! not move.
//!
//! Rows in the *unsealed* tail page (tokens at or past
//! `⌊seq.len / page_size⌋ · page_size` when the tail is partial) have no
//! mirror block yet — the cache only quantizes a page when it seals —
//! and are scored exactly from fp32 K. Besides matching the paper's
//! quantize-on-page-close schedule, this makes the estimate a pure
//! function of the visible prefix: a chunked-prefill query that sees a
//! truncated view of its sequence scores the same whether the chunk
//! appended 1 or 256 tokens behind it.

use crate::kvcache::{quant_dot_row_group, quant_dot_row_qsum, PagedKvCache, SeqCache};
use crate::tensor::dot;
use crate::tensor::kernels::{self, Kernels};
use crate::tensor::quant::{self, quantize, QuantBits, QuantBlock};

/// First token of the visibly-partial tail page (== `seq.len` when the
/// visible tail page is full, i.e. every visible row is sealed).
#[inline]
pub(crate) fn sealed_limit(seq: &SeqCache, page_size: usize) -> usize {
    seq.len - seq.len % page_size
}

/// First index past the per-page candidate run starting at `i`: a
/// maximal stretch of tokens on one sealed page, or of unsealed-tail
/// tokens. The single definition shared by both tiled estimators and the
/// pruner's hierarchical pre-prune — whose correctness argument needs
/// its run boundaries to coincide exactly with the tiler's.
#[inline]
pub(crate) fn run_end(tokens: &[usize], i: usize, sealed: usize, ps: usize) -> usize {
    let n = tokens.len();
    let t0 = tokens[i];
    let mut j = i + 1;
    if t0 >= sealed {
        while j < n && tokens[j] >= sealed {
            j += 1;
        }
    } else {
        let pg = t0 / ps;
        while j < n && tokens[j] < sealed && tokens[j] / ps == pg {
            j += 1;
        }
    }
    j
}

/// Reusable buffers for the tiled SpGEMV (one per worker, embedded in the
/// pruner's `AttnScratch`): the per-run code tile, the per-head `sum(q)`
/// hoists, and the per-row group-score staging row. Capacity only ever
/// grows, so steady-state calls perform zero heap allocations.
#[derive(Default)]
pub struct SpgemvScratch {
    /// Unpacked f32 codes for the current run's slot window.
    pub tile: Vec<f32>,
    /// Per-head `sum(q)` (row-invariant factor of the fused dequant-dot).
    pub qsums: Vec<f32>,
    /// Per-head staging for one row's scores (single-row fallback path).
    pub row: Vec<f32>,
}

/// Score one tile row against one query head, matching the row-major
/// fused path bit for bit: integer widths use
/// `zero·qsum + scale·dot(q, codes)` with the backend's throughput
/// `dot`; Fp16 group rows also use `dot` (as `quant_dot_row_group`
/// does). `kn` is fetched once per estimator call and threaded in.
#[inline]
fn tile_row_score(q: &[f32], qsum: f32, b: &QuantBlock, row: &[f32], kn: &Kernels) -> f32 {
    match b.bits {
        QuantBits::Fp16 => (kn.dot)(q, row),
        _ => b.zero * qsum + b.scale * (kn.dot)(q, row),
    }
}

/// Single-head variant: the `quant_dot_row_qsum` Fp16 path is the fused
/// packed-f16 dot, whose accumulation structure each backend's
/// `dot_strict` mirrors — so the tiled path reproduces it bit-for-bit
/// over the widened row (sequential in scalar, paired SIMD otherwise).
#[inline]
fn tile_row_score_single(q: &[f32], qsum: f32, b: &QuantBlock, row: &[f32], kn: &Kernels) -> f32 {
    match b.bits {
        QuantBits::Fp16 => (kn.dot_strict)(q, row),
        _ => b.zero * qsum + b.scale * (kn.dot)(q, row),
    }
}

/// Estimate logits (unscaled by 1/sqrt(d)) for `tokens` from the mirror
/// cache into `out`; unsealed tail rows are scored exactly. Page-tiled:
/// consecutive tokens on one sealed page unpack the mirror block's slot
/// window once. Bit-identical to [`estimate_scores_rowmajor`] for any
/// token order (runs degrade gracefully to single rows).
pub fn estimate_scores(
    cache: &PagedKvCache,
    seq: &SeqCache,
    head: usize,
    q: &[f32],
    tokens: &[usize],
    out: &mut [f32],
    scratch: &mut SpgemvScratch,
) {
    debug_assert_eq!(tokens.len(), out.len());
    let d = cache.cfg.head_dim;
    let ps = cache.cfg.page_size;
    let sealed = sealed_limit(seq, ps);
    let qsum: f32 = q.iter().sum();
    let kn = kernels::active();
    let n = tokens.len();
    let mut i = 0;
    while i < n {
        let t0 = tokens[i];
        let j = run_end(tokens, i, sealed, ps);
        if t0 >= sealed {
            // Unsealed tail rows: exact fp32 (no mirror yet).
            for (r, &t) in tokens[i..j].iter().enumerate() {
                let (page, slot) = seq.locate(t, ps);
                out[i + r] = (kn.dot)(q, cache.k_at(page, head, slot));
            }
            i = j;
            continue;
        }
        let page = seq.pages[t0 / ps];
        let block = cache.mirror_at(page, head).expect("sealed page missing mirror");
        let (mut lo, mut hi) = (t0 % ps, t0 % ps);
        for &t in &tokens[i + 1..j] {
            let s = t % ps;
            lo = lo.min(s);
            hi = hi.max(s);
        }
        let rows = j - i;
        let window = hi - lo + 1;
        if rows == 1 || window >= rows * 2 {
            // Single row, or a run sparse within its slot window: the
            // fused row path widens only the rows actually scored —
            // cheaper than unpacking the whole window (bit-identical
            // either way, so the threshold is purely a cost choice).
            for (r, &t) in tokens[i..j].iter().enumerate() {
                out[i + r] = quant_dot_row_qsum(q, qsum, block, (t % ps) * d, d);
            }
        } else {
            scratch.tile.resize(window * d, 0.0);
            quant::unpack_codes_into(block, lo * d, &mut scratch.tile);
            for (r, &t) in tokens[i..j].iter().enumerate() {
                let s = t % ps;
                let row = &scratch.tile[(s - lo) * d..(s - lo + 1) * d];
                out[i + r] = tile_row_score_single(q, qsum, block, row, kn);
            }
        }
        i = j;
    }
}

/// Estimate logits for a whole GQA group in one pass over the mirror:
/// each per-page run's codes are unpacked once into the scratch tile and
/// contracted with every query head of the group (§Perf — the unpack is
/// amortized rows × heads); unsealed tail rows are scored exactly. `out`
/// is `[group][tokens.len()]` flattened row-major. Bit-identical to
/// [`estimate_scores_group_rowmajor`].
#[allow(clippy::too_many_arguments)]
pub fn estimate_scores_group(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    tokens: &[usize],
    out: &mut [f32],
    scratch: &mut SpgemvScratch,
) {
    let d = cache.cfg.head_dim;
    scratch.qsums.clear();
    scratch
        .qsums
        .extend((0..group).map(|g| qs[g * d..(g + 1) * d].iter().sum::<f32>()));
    estimate_scores_group_with_qsums(cache, seq, kv_head, qs, group, tokens, out, scratch);
}

/// Core of [`estimate_scores_group`] that trusts `scratch.qsums` to hold
/// the `group` per-head `sum(q)` values already: the hier pre-prune
/// fills them once per prune call and then scores many per-page runs
/// without recomputing the query reductions.
#[allow(clippy::too_many_arguments)]
pub fn estimate_scores_group_with_qsums(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    tokens: &[usize],
    out: &mut [f32],
    scratch: &mut SpgemvScratch,
) {
    let d = cache.cfg.head_dim;
    let ps = cache.cfg.page_size;
    debug_assert_eq!(out.len(), group * tokens.len());
    debug_assert_eq!(scratch.qsums.len(), group);
    let sealed = sealed_limit(seq, ps);
    scratch.row.resize(group, 0.0);
    let kn = kernels::active();
    let n = tokens.len();
    let mut i = 0;
    while i < n {
        let t0 = tokens[i];
        let j = run_end(tokens, i, sealed, ps);
        if t0 >= sealed {
            for (r, &t) in tokens[i..j].iter().enumerate() {
                let (page, slot) = seq.locate(t, ps);
                let k = cache.k_at(page, kv_head, slot);
                for g in 0..group {
                    out[g * n + i + r] = (kn.dot)(&qs[g * d..(g + 1) * d], k);
                }
            }
            i = j;
            continue;
        }
        let page = seq.pages[t0 / ps];
        let block = cache.mirror_at(page, kv_head).expect("sealed page missing mirror");
        let (mut lo, mut hi) = (t0 % ps, t0 % ps);
        for &t in &tokens[i + 1..j] {
            let s = t % ps;
            lo = lo.min(s);
            hi = hi.max(s);
        }
        let rows = j - i;
        let window = hi - lo + 1;
        if rows == 1 || window >= rows * 2 {
            // Sparse-within-window run: per-row fused path widens only
            // the scored rows (bit-identical; see the single-head note).
            for (r, &t) in tokens[i..j].iter().enumerate() {
                quant_dot_row_group(
                    qs,
                    &scratch.qsums,
                    block,
                    (t % ps) * d,
                    d,
                    &mut scratch.row,
                );
                for g in 0..group {
                    out[g * n + i + r] = scratch.row[g];
                }
            }
        } else {
            scratch.tile.resize(window * d, 0.0);
            quant::unpack_codes_into(block, lo * d, &mut scratch.tile);
            for (r, &t) in tokens[i..j].iter().enumerate() {
                let s = t % ps;
                let row = &scratch.tile[(s - lo) * d..(s - lo + 1) * d];
                for g in 0..group {
                    out[g * n + i + r] =
                        tile_row_score(&qs[g * d..(g + 1) * d], scratch.qsums[g], block, row, kn);
                }
            }
        }
        i = j;
    }
}

/// The historical row-major estimator, kept as the bit-exactness
/// reference for the tiled hot path (tests) and as the baseline panel of
/// the Fig. 12-style SpGEMV ablation (benches). Scores each candidate
/// independently via the fused dequant-dot.
pub fn estimate_scores_rowmajor(
    cache: &PagedKvCache,
    seq: &SeqCache,
    head: usize,
    q: &[f32],
    tokens: &[usize],
    out: &mut [f32],
) {
    debug_assert_eq!(tokens.len(), out.len());
    let d = cache.cfg.head_dim;
    let ps = cache.cfg.page_size;
    let sealed = sealed_limit(seq, ps);
    let qsum: f32 = q.iter().sum();
    for (o, &t) in out.iter_mut().zip(tokens) {
        let (page, slot) = seq.locate(t, ps);
        if t < sealed {
            let block = cache.mirror_at(page, head).expect("sealed page missing mirror");
            *o = quant_dot_row_qsum(q, qsum, block, slot * d, d);
        } else {
            *o = dot(q, cache.k_at(page, head, slot));
        }
    }
}

/// Row-major GQA-group reference (see [`estimate_scores_rowmajor`]): each
/// packed row is unpacked once per *row* (not per run) and contracted
/// with every query head.
pub fn estimate_scores_group_rowmajor(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    tokens: &[usize],
    out: &mut [f32],
) {
    let d = cache.cfg.head_dim;
    let ps = cache.cfg.page_size;
    debug_assert_eq!(out.len(), group * tokens.len());
    let sealed = sealed_limit(seq, ps);
    let qsums: Vec<f32> = (0..group).map(|g| qs[g * d..(g + 1) * d].iter().sum()).collect();
    let n = tokens.len();
    let mut row = vec![0.0f32; group];
    for (i, &t) in tokens.iter().enumerate() {
        let (page, slot) = seq.locate(t, ps);
        if t < sealed {
            let block = cache.mirror_at(page, kv_head).expect("sealed page missing mirror");
            quant_dot_row_group(qs, &qsums, block, slot * d, d, &mut row);
        } else {
            let k = cache.k_at(page, kv_head, slot);
            for (g, r) in row.iter_mut().enumerate() {
                *r = dot(&qs[g * d..(g + 1) * d], k);
            }
        }
        for g in 0..group {
            out[g * n + i] = row[g];
        }
    }
}

/// A standalone quantized K matrix (contiguous, one head) for kernels and
/// benches that do not need the paged pool — e.g. the Fig. 12 SpGEMV
/// latency ablation across bit widths.
pub struct QuantizedK {
    pub d: usize,
    pub n: usize,
    pub bits: QuantBits,
    /// One block per group of `group_rows` rows (per-block scale/zero).
    pub blocks: Vec<QuantBlock>,
    pub group_rows: usize,
}

impl QuantizedK {
    /// Quantize `k` (`[n, d]` row-major) at `bits`, `group_rows` rows per
    /// scale/zero group (the paper uses one page = 16 rows).
    pub fn from_rows(k: &[f32], d: usize, bits: QuantBits, group_rows: usize) -> QuantizedK {
        let n = k.len() / d;
        let mut blocks = Vec::with_capacity(n.div_ceil(group_rows));
        let mut i = 0;
        while i < n {
            let rows = group_rows.min(n - i);
            blocks.push(quantize(&k[i * d..(i + rows) * d], bits));
            i += rows;
        }
        QuantizedK { d, n, bits, blocks, group_rows }
    }

    /// Total packed bytes (the memory the kernel must stream).
    pub fn bytes(&self) -> usize {
        self.blocks.iter().map(|b| b.packed.len() + 8).sum()
    }

    /// `out[i] = q · K̂[rows[i]]`. The row-invariant `sum(q)` is hoisted
    /// out of the row loop (the paged path always did this; the
    /// standalone Fig. 12 path recomputed it per row).
    pub fn spgemv(&self, q: &[f32], rows: &[usize], out: &mut [f32]) {
        debug_assert_eq!(q.len(), self.d);
        let qsum: f32 = q.iter().sum();
        for (o, &r) in out.iter_mut().zip(rows) {
            let block = &self.blocks[r / self.group_rows];
            let slot = r % self.group_rows;
            *o = quant_dot_row_qsum(q, qsum, block, slot * self.d, self.d);
        }
    }

    /// Dense GEMV over all rows: `out[i] = q · K̂[i]` (qsum hoisted).
    pub fn gemv(&self, q: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n);
        let qsum: f32 = q.iter().sum();
        let mut row = 0;
        for block in &self.blocks {
            let rows = block.n / self.d;
            for s in 0..rows {
                out[row] = quant_dot_row_qsum(q, qsum, block, s * self.d, self.d);
                row += 1;
            }
        }
    }

    /// Block-tiled dense GEMV: each block's codes are unpacked once into
    /// `tile`, then every row is a plain f32 dot — the standalone analog
    /// of the paged tiled path, for the Fig. 12 row-major-vs-tiled panel.
    /// Bit-identical to [`QuantizedK::gemv`].
    pub fn gemv_tiled(&self, q: &[f32], tile: &mut Vec<f32>, out: &mut [f32]) {
        debug_assert_eq!(out.len(), self.n);
        let qsum: f32 = q.iter().sum();
        let kn = kernels::active();
        let mut row = 0;
        for block in &self.blocks {
            let rows = block.n / self.d;
            tile.resize(block.n, 0.0);
            quant::unpack_codes_into(block, 0, tile);
            for s in 0..rows {
                let r = &tile[s * self.d..(s + 1) * self.d];
                out[row] = tile_row_score_single(q, qsum, block, r, kn);
                row += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};
    use crate::kvcache::CacheConfig;
    use crate::tensor::dot;
    use crate::util::rng::Rng;

    #[test]
    fn estimate_close_to_exact_int4() {
        let (cache, seq) = random_cache(31, 1, 32, 128);
        let q = random_q(32, 32);
        let toks: Vec<usize> = (0..128).collect();
        let mut est = vec![0.0; 128];
        let mut sc = SpgemvScratch::default();
        estimate_scores(&cache, &seq, 0, &q, &toks, &mut est, &mut sc);
        let mut worst = 0.0f32;
        for (&t, &e) in toks.iter().zip(&est) {
            let exact = cache.exact_score(&seq, 0, &q, t);
            worst = worst.max((exact - e).abs());
        }
        // INT4 per-page groups over N(0,1) keys, d=32: per-element error is
        // ~scale/2 ≈ 0.2, so dot error concentrates near 0.2·sqrt(32)·σ_q;
        // the observed worst case sits well under 2 while logits span ±15.
        assert!(worst < 2.0, "worst abs err {worst}");
    }

    #[test]
    fn tiled_bit_exact_vs_rowmajor_all_widths() {
        // The tiled hot path must reproduce the row-major reference to
        // the bit: across bit widths, scattered/contiguous candidate
        // shapes, group sizes, and the sealed/unsealed-tail boundary.
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
            let d = 32;
            let n = 72; // 4 sealed pages + an 8-row unsealed tail
            let mut cache = crate::kvcache::PagedKvCache::new({
                let mut c = CacheConfig::new(2, d, 8);
                c.mirror_bits = bits;
                c
            });
            let mut seq = crate::kvcache::SeqCache::default();
            let mut r = Rng::new(900 + bits.bits() as u64);
            for _ in 0..n {
                let k: Vec<f32> = (0..2 * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
                cache.append(&mut seq, &k, &k).unwrap();
            }
            let shapes: Vec<Vec<usize>> = vec![
                (0..n).collect(),                       // every row, crossing the tail
                (0..n).step_by(3).collect(),            // gaps within pages
                vec![5],                                // single row (fallback path)
                vec![0, 1, 2, 3, 17, 40, 41, 64, 71],   // mixed runs + tail
                vec![15, 16, 31, 32, 63, 64],           // page-boundary straddles
            ];
            for kv_head in 0..2 {
                for group in [1usize, 4] {
                    let mut qs = Vec::new();
                    for g in 0..group {
                        qs.extend(random_q(70 + g as u64, d));
                    }
                    for toks in &shapes {
                        let mut want = vec![0.0; group * toks.len()];
                        estimate_scores_group_rowmajor(
                            &cache, &seq, kv_head, &qs, group, toks, &mut want,
                        );
                        let mut got = vec![0.0; group * toks.len()];
                        let mut sc = SpgemvScratch::default();
                        estimate_scores_group(
                            &cache, &seq, kv_head, &qs, group, toks, &mut got, &mut sc,
                        );
                        assert_eq!(
                            want, got,
                            "group tiled != rowmajor (bits={bits:?} group={group} toks={toks:?})"
                        );
                        if group == 1 {
                            let mut w1 = vec![0.0; toks.len()];
                            estimate_scores_rowmajor(&cache, &seq, kv_head, &qs, toks, &mut w1);
                            let mut g1 = vec![0.0; toks.len()];
                            estimate_scores(
                                &cache, &seq, kv_head, &qs, toks, &mut g1, &mut sc,
                            );
                            assert_eq!(
                                w1, g1,
                                "single-head tiled != rowmajor (bits={bits:?} toks={toks:?})"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn unsealed_tail_scored_exactly() {
        // 2 sealed pages + an 8-row unsealed tail: sealed rows go through
        // the mirror, tail rows must be exact fp32 — bit-for-bit, since
        // chunk invariance rides on this being a pure function of the
        // visible prefix.
        let (cache, seq) = random_cache(33, 1, 16, 40);
        let q = random_q(34, 16);
        let toks: Vec<usize> = vec![0, 31, 32, 39];
        let mut est = vec![0.0; toks.len()];
        let mut sc = SpgemvScratch::default();
        estimate_scores(&cache, &seq, 0, &q, &toks, &mut est, &mut sc);
        for (&t, &e) in toks.iter().zip(&est) {
            if t >= 32 {
                assert_eq!(e, cache.exact_score(&seq, 0, &q, t), "tail row {t} not exact");
            }
        }
        // The group path must agree with the single-head path.
        let mut grp = vec![0.0; toks.len()];
        estimate_scores_group(&cache, &seq, 0, &q, 1, &toks, &mut grp, &mut sc);
        assert_eq!(est, grp);
        // A truncated view (chunked prefill mid-chunk) relies only on
        // sealed pages + exact tail: same call, shorter visible length.
        let view = SeqCache { pages: seq.pages[..2].to_vec(), len: 20 };
        let vtoks: Vec<usize> = vec![15, 16, 19];
        let mut vest = vec![0.0; vtoks.len()];
        estimate_scores(&cache, &view, 0, &q, &vtoks, &mut vest, &mut sc);
        assert_eq!(vest[1], cache.exact_score(&view, 0, &q, 16));
        assert_eq!(vest[2], cache.exact_score(&view, 0, &q, 19));
    }

    #[test]
    fn rank_correlation_int4_beats_int2() {
        let mut r = Rng::new(77);
        let d = 64;
        let n = 256;
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let exact: Vec<f32> = (0..n).map(|i| dot(&q, &k[i * d..(i + 1) * d])).collect();
        let top_exact = top_set(&exact, 32);
        let overlap = |bits: QuantBits| {
            let qk = QuantizedK::from_rows(&k, d, bits, 16);
            let mut est = vec![0.0; n];
            qk.gemv(&q, &mut est);
            let top_est = top_set(&est, 32);
            top_exact.iter().filter(|t| top_est.contains(t)).count()
        };
        let o2 = overlap(QuantBits::Int2);
        let o4 = overlap(QuantBits::Int4);
        let o8 = overlap(QuantBits::Int8);
        assert!(o4 > o2, "int4 {o4} <= int2 {o2}");
        assert!(o8 >= o4, "int8 {o8} < int4 {o4}");
        assert!(o4 >= 28, "int4 overlap too low: {o4}/32");
    }

    fn top_set(xs: &[f32], k: usize) -> std::collections::HashSet<usize> {
        let mut idx: Vec<usize> = (0..xs.len()).collect();
        idx.sort_by(|&a, &b| xs[b].partial_cmp(&xs[a]).unwrap());
        idx.into_iter().take(k).collect()
    }

    #[test]
    fn spgemv_subset_matches_gemv() {
        let mut r = Rng::new(5);
        let d = 16;
        let n = 64;
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let qk = QuantizedK::from_rows(&k, d, QuantBits::Int4, 16);
        let mut dense = vec![0.0; n];
        qk.gemv(&q, &mut dense);
        let rows = vec![0usize, 7, 16, 63];
        let mut sparse = vec![0.0; rows.len()];
        qk.spgemv(&q, &rows, &mut sparse);
        for (i, &row) in rows.iter().enumerate() {
            assert_eq!(sparse[i], dense[row]);
        }
    }

    #[test]
    fn gemv_tiled_bit_exact() {
        let mut r = Rng::new(6);
        let d = 32;
        let n = 100; // non-multiple of group_rows: partial final block
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let q: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        for bits in [QuantBits::Int2, QuantBits::Int4, QuantBits::Int8, QuantBits::Fp16] {
            let qk = QuantizedK::from_rows(&k, d, bits, 16);
            let mut a = vec![0.0; n];
            qk.gemv(&q, &mut a);
            let mut b = vec![0.0; n];
            let mut tile = Vec::new();
            qk.gemv_tiled(&q, &mut tile, &mut b);
            assert_eq!(a, b, "tiled gemv diverged at bits={bits:?}");
        }
    }

    #[test]
    fn bytes_scale_with_bits() {
        let k = vec![0.5f32; 128 * 64];
        let b2 = QuantizedK::from_rows(&k, 64, QuantBits::Int2, 16).bytes();
        let b4 = QuantizedK::from_rows(&k, 64, QuantBits::Int4, 16).bytes();
        let b8 = QuantizedK::from_rows(&k, 64, QuantBits::Int8, 16).bytes();
        let b16 = QuantizedK::from_rows(&k, 64, QuantBits::Fp16, 16).bytes();
        assert!(b2 < b4 && b4 < b8 && b8 < b16);
        // Ratio roughly 2:4:8:16.
        assert!((b16 as f64 / b4 as f64 - 4.0).abs() < 0.2);
    }
}
