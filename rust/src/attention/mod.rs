//! Attention compute kernels (the CPU analogs of the paper's CUDA/Triton
//! kernels — see DESIGN.md §2 for the hardware mapping).
//!
//! * [`full`] — dense baselines: contiguous (SDPA/FlashAttention2 analog)
//!   and paged streaming-softmax (FlashInfer analog).
//! * [`sparse`] — index-list sparse attention with the three varlen
//!   packings of Appendix B.2 (padded / head-varlen / group-varlen).
//! * [`prefill`] — bound-guided page skipping for chunked-prefill
//!   queries (DESIGN.md §13): sealed pages below the local window are
//!   visited in descending envelope-bound order with streaming softmax
//!   and the hier top-p early-stop test, so long-prompt TTFT stops
//!   paying the dense O(n²) walk while keeping ≥ 1 − eps of each row's
//!   softmax mass.
//! * [`spgemv`] — the score-estimation SpGEMV over the quantized mirror
//!   K cache (Appendix B.1), at INT2/4/8/FP16 — page-tiled: per-page
//!   candidate runs unpack each mirror block once and amortize the
//!   dequant across rows × GQA heads (bit-identical to the row-major
//!   reference; DESIGN.md §9).
//!
//! All kernels are single-(kv-)head primitives (plus the multi-query
//! causal chunk kernel [`full::paged_full_causal`], which stacks the
//! visible-prefix walk [`full::paged_full_limit`] per chunk offset).
//! Batching happens one level up, in the engine's unified mixed step
//! ([`crate::coordinator::engine::Engine::step_batch`]): each layer runs
//! as three phases — (a) serial QKV projection + KV append for every
//! query token (decode items *and* prefill chunks), (b) a flattened
//! (item × kv-head) attention work list whose per-item cost is the
//! resolved stage-1 budget summed over the item's span (≈ span × context
//! for a chunk), LPT-partitioned by
//! [`crate::coordinator::balance::lpt_partition`] and drained by the
//! engine's persistent [`crate::util::threadpool::ThreadPool`]
//! (FlashInfer's flattened head-dimension load balancing with resident
//! balanced workers, §4.2 — threads are created once per engine and
//! parked between rounds, not spawned per layer), and (c) serial
//! rest-of-layer — with per-worker stats merged deterministically at
//! each phase barrier so any worker count is bit-exact with sequential
//! execution. A chunk item's queries run serially on one worker, each
//! over a truncated visible-prefix view of its sequence cache, so the
//! same kernels serve decode and chunked prefill and the results are
//! bit-exact for any chunk size.

pub mod full;
pub mod prefill;
pub mod sparse;
pub mod spgemv;

use crate::kvcache::{PagedKvCache, SeqCache};

/// Scale factor `1/sqrt(d)` shared by every kernel.
#[inline]
pub fn scale(d: usize) -> f32 {
    1.0 / (d as f32).sqrt()
}

/// Compute exact attention logits `q·K[tok]/sqrt(d)` for a token range.
/// Utility for tests and the oracle selector.
pub fn exact_logits(cache: &PagedKvCache, seq: &SeqCache, head: usize, q: &[f32]) -> Vec<f32> {
    let s = scale(q.len());
    (0..seq.len).map(|t| cache.exact_score(seq, head, q, t) * s).collect()
}

/// Full softmax attention weights for a head (normalized). Tests/oracles.
pub fn exact_weights(cache: &PagedKvCache, seq: &SeqCache, head: usize, q: &[f32]) -> Vec<f32> {
    let mut w = exact_logits(cache, seq, head, q);
    crate::tensor::softmax_inplace(&mut w);
    w
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::kvcache::CacheConfig;
    use crate::util::rng::Rng;

    /// Build a cache with `n` random tokens for `kv_heads` heads of dim `d`.
    pub fn random_cache(seed: u64, kv_heads: usize, d: usize, n: usize) -> (PagedKvCache, SeqCache) {
        let pages = n.div_ceil(16) + 2;
        let mut cache = PagedKvCache::new(CacheConfig::new(kv_heads, d, pages));
        let mut seq = SeqCache::default();
        let mut r = Rng::new(seed);
        for _ in 0..n {
            let k: Vec<f32> = (0..kv_heads * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
            let v: Vec<f32> = (0..kv_heads * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
            cache.append(&mut seq, &k, &v).unwrap();
        }
        (cache, seq)
    }

    pub fn random_q(seed: u64, d: usize) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect()
    }

    /// Naive reference attention over an explicit index set.
    pub fn naive_sparse(
        cache: &PagedKvCache,
        seq: &SeqCache,
        head: usize,
        q: &[f32],
        idx: &[usize],
    ) -> Vec<f32> {
        let d = q.len();
        let s = scale(d);
        let mut logits: Vec<f32> = idx
            .iter()
            .map(|&t| cache.exact_score(seq, head, q, t) * s)
            .collect();
        crate::tensor::softmax_inplace(&mut logits);
        let mut out = vec![0.0; d];
        for (&t, &w) in idx.iter().zip(&logits) {
            let (page, slot) = seq.locate(t, cache.cfg.page_size);
            crate::tensor::axpy(w, cache.v_at(page, head, slot), &mut out);
        }
        out
    }
}
