//! Sparse attention over explicit index lists, with the three varlen
//! packings compared in Appendix B.2 / Fig. 13:
//!
//! * **padded** — every head computes over `max_budget` slots, reading
//!   masked garbage for short heads (uniform resource allocation, the
//!   strawman traditional kernels use);
//! * **head-varlen** — each query head walks exactly its own index list;
//!   under GQA this re-reads the shared KV head once per query head;
//! * **group-varlen** — Twilight's design: the query-head group shares the
//!   union index list, loading each KV row once per *group* and applying
//!   it to all query heads in the group.
//!
//! The kernels are exact (softmax over the selected logits), matching
//! Definition 3.1 with Λ restricted to the index set.
//!
//! Under chunked prefill these same kernels serve each chunk query's
//! sub-call: the engine passes a truncated visible-prefix `SeqCache`
//! view, and the selector/pruner guarantee every index is `< view.len`,
//! so causality within the chunk is enforced by construction — no mask
//! argument needed (the index list *is* the mask).

use super::scale;
use crate::kvcache::{PagedKvCache, SeqCache};
use crate::tensor::kernels;

/// Sparse attention for one (query-)head over `idx` (logical token ids).
/// `out` is `[d]`.
pub fn head_varlen(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    q: &[f32],
    idx: &[usize],
    out: &mut [f32],
) {
    let d = q.len();
    let s = scale(d);
    let ps = cache.cfg.page_size;
    let kn = kernels::active();
    // Streaming softmax over the index list: one pass, no logits buffer.
    let mut m = f32::NEG_INFINITY;
    let mut denom = 0.0f32;
    out.fill(0.0);
    for &t in idx {
        let (page, slot) = seq.locate(t, ps);
        let logit = (kn.dot)(q, cache.k_at(page, kv_head, slot)) * s;
        if logit > m {
            if m.is_finite() {
                let corr = (m - logit).exp();
                denom *= corr;
                for o in out.iter_mut() {
                    *o *= corr;
                }
            }
            m = logit;
        }
        let w = (logit - m).exp();
        denom += w;
        (kn.axpy)(w, cache.v_at(page, kv_head, slot), out);
    }
    if denom > 0.0 {
        let inv = 1.0 / denom;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

/// Padded variant: computes over `idx` padded to `max_budget` by
/// re-reading `idx[0]` with a `-inf` mask — the wasted loads are real, as
/// in a uniformly-provisioned kernel. Result identical to `head_varlen`.
pub fn padded(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    q: &[f32],
    idx: &[usize],
    max_budget: usize,
    out: &mut [f32],
) {
    let d = q.len();
    let s = scale(d);
    let ps = cache.cfg.page_size;
    let kn = kernels::active();
    let mut m = f32::NEG_INFINITY;
    let mut denom = 0.0f32;
    out.fill(0.0);
    let pad_tok = idx.first().copied().unwrap_or(0);
    for i in 0..max_budget.max(idx.len()) {
        let (t, masked) = if i < idx.len() { (idx[i], false) } else { (pad_tok, true) };
        let (page, slot) = seq.locate(t, ps);
        // The load happens regardless of the mask (that is the point).
        let kval = cache.k_at(page, kv_head, slot);
        let logit = if masked { f32::NEG_INFINITY } else { (kn.dot)(q, kval) * s };
        if logit > m {
            if m.is_finite() {
                let corr = (m - logit).exp();
                denom *= corr;
                for o in out.iter_mut() {
                    *o *= corr;
                }
            }
            m = logit;
        }
        let w = if logit.is_finite() { (logit - m).exp() } else { 0.0 };
        denom += w;
        if w > 0.0 {
            (kn.axpy)(w, cache.v_at(page, kv_head, slot), out);
        } else {
            // Masked slot: still touch V to model the wasted read.
            std::hint::black_box(cache.v_at(page, kv_head, slot)[0]);
        }
    }
    if denom > 0.0 {
        let inv = 1.0 / denom;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

/// Group-varlen (GQA) variant: `qs` holds `group` query heads (`[g][d]`),
/// all mapped to `kv_head`, sharing the union index list `idx`. Each KV
/// row is loaded once and applied to every query head in the group.
/// `outs` is `[g][d]` flattened. Convenience wrapper over
/// [`group_varlen_with`] that allocates its own streaming-softmax state.
pub fn group_varlen(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    idx: &[usize],
    outs: &mut [f32],
) {
    let mut m = Vec::new();
    let mut denom = Vec::new();
    group_varlen_with(cache, seq, kv_head, qs, group, idx, &mut m, &mut denom, outs);
}

/// Allocation-free core of [`group_varlen`]: the per-head streaming
/// max/denominator state comes from caller-owned buffers (part of the
/// per-worker `AttnScratch` arena in the engine), so steady-state decode
/// performs no heap allocation here. Bit-identical to the wrapper.
#[allow(clippy::too_many_arguments)]
pub fn group_varlen_with(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    idx: &[usize],
    m: &mut Vec<f32>,
    denom: &mut Vec<f32>,
    outs: &mut [f32],
) {
    let d = qs.len() / group;
    let s = scale(d);
    let ps = cache.cfg.page_size;
    let kn = kernels::active();
    m.clear();
    m.resize(group, f32::NEG_INFINITY);
    denom.clear();
    denom.resize(group, 0.0f32);
    outs.fill(0.0);
    for &t in idx {
        let (page, slot) = seq.locate(t, ps);
        let kval = cache.k_at(page, kv_head, slot); // single load per token
        let vval = cache.v_at(page, kv_head, slot);
        for g in 0..group {
            let q = &qs[g * d..(g + 1) * d];
            let out = &mut outs[g * d..(g + 1) * d];
            let logit = (kn.dot)(q, kval) * s;
            if logit > m[g] {
                if m[g].is_finite() {
                    let corr = (m[g] - logit).exp();
                    denom[g] *= corr;
                    for o in out.iter_mut() {
                        *o *= corr;
                    }
                }
                m[g] = logit;
            }
            let w = (logit - m[g]).exp();
            denom[g] += w;
            (kn.axpy)(w, vval, out);
        }
    }
    for g in 0..group {
        if denom[g] > 0.0 {
            let inv = 1.0 / denom[g];
            for o in outs[g * d..(g + 1) * d].iter_mut() {
                *o *= inv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{naive_sparse, random_cache, random_q};

    #[test]
    fn head_varlen_matches_naive() {
        let (cache, seq) = random_cache(11, 2, 16, 100);
        let q = random_q(12, 16);
        let idx = vec![0usize, 5, 17, 31, 64, 99];
        for head in 0..2 {
            let mut out = vec![0.0; 16];
            head_varlen(&cache, &seq, head, &q, &idx, &mut out);
            let want = naive_sparse(&cache, &seq, head, &q, &idx);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn padded_equals_head_varlen() {
        let (cache, seq) = random_cache(13, 1, 8, 64);
        let q = random_q(14, 8);
        let idx = vec![3usize, 9, 40];
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        head_varlen(&cache, &seq, 0, &q, &idx, &mut a);
        padded(&cache, &seq, 0, &q, &idx, 32, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }

    #[test]
    fn group_varlen_equals_per_head() {
        let (cache, seq) = random_cache(15, 1, 8, 80);
        let group = 4;
        let mut qs = Vec::new();
        for g in 0..group {
            qs.extend(random_q(20 + g as u64, 8));
        }
        let idx = vec![1usize, 2, 30, 55, 79];
        let mut outs = vec![0.0; group * 8];
        group_varlen(&cache, &seq, 0, &qs, group, &idx, &mut outs);
        for g in 0..group {
            let mut want = vec![0.0; 8];
            head_varlen(&cache, &seq, 0, &qs[g * 8..(g + 1) * 8], &idx, &mut want);
            for (a, b) in outs[g * 8..(g + 1) * 8].iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "group {g}");
            }
        }
    }

    #[test]
    fn group_varlen_with_reused_scratch_bit_exact() {
        let (cache, seq) = random_cache(25, 1, 8, 80);
        let group = 4;
        let mut qs = Vec::new();
        for g in 0..group {
            qs.extend(random_q(30 + g as u64, 8));
        }
        let mut m = Vec::new();
        let mut denom = Vec::new();
        for idx in [vec![1usize, 2, 30, 55, 79], vec![0usize], vec![5usize, 6, 7]] {
            let mut a = vec![0.0; group * 8];
            group_varlen(&cache, &seq, 0, &qs, group, &idx, &mut a);
            let mut b = vec![1.0; group * 8]; // dirty output buffer
            group_varlen_with(&cache, &seq, 0, &qs, group, &idx, &mut m, &mut denom, &mut b);
            assert_eq!(a, b, "scratch reuse changed the kernel result");
        }
    }

    #[test]
    fn sparse_with_full_index_set_equals_dense() {
        let (cache, seq) = random_cache(17, 1, 16, 48);
        let q = random_q(18, 16);
        let all: Vec<usize> = (0..seq.len).collect();
        let mut sparse_out = vec![0.0; 16];
        head_varlen(&cache, &seq, 0, &q, &all, &mut sparse_out);
        let mut dense_out = vec![0.0; 16];
        crate::attention::full::paged_full(&cache, &seq, 0, &q, &mut dense_out);
        for (a, b) in sparse_out.iter().zip(&dense_out) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn empty_index_list_is_zero() {
        let (cache, seq) = random_cache(19, 1, 8, 16);
        let q = random_q(21, 8);
        let mut out = vec![1.0; 8];
        head_varlen(&cache, &seq, 0, &q, &[], &mut out);
        assert_eq!(out, vec![0.0; 8]);
    }

    #[test]
    fn unsorted_indices_give_same_result() {
        let (cache, seq) = random_cache(23, 1, 8, 64);
        let q = random_q(24, 8);
        let idx1 = vec![5usize, 10, 20, 40];
        let idx2 = vec![40usize, 5, 20, 10];
        let mut a = vec![0.0; 8];
        let mut b = vec![0.0; 8];
        head_varlen(&cache, &seq, 0, &q, &idx1, &mut a);
        head_varlen(&cache, &seq, 0, &q, &idx2, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5);
        }
    }
}
