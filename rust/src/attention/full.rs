//! Dense attention baselines.
//!
//! `contiguous_full` is the SDPA/FlashAttention2 analog: K/V as flat
//! `[n, d]` slices, two-pass softmax. `paged_full` is the FlashInfer
//! analog: iterates the paged KV cache with a streaming (online) softmax
//! so pages are visited exactly once — the same single-pass structure as
//! flash decoding, which is what makes it bandwidth-optimal.
//! `paged_full_limit` is the same walk truncated to a visible prefix,
//! and `paged_full_causal` stacks it into the multi-query causal kernel
//! a prefill *chunk* needs: query `c` of the chunk attends to tokens
//! `0..=start+c`. The causal kernel deliberately iterates query-outer /
//! pages-inner (not the page-outer tiling a GPU kernel would use): each
//! query's accumulation order is then identical to a lone decode step at
//! the same position, which is what makes chunked prefill bit-exact with
//! token-at-a-time processing for any chunk size.

use super::scale;
use crate::kvcache::{PagedKvCache, SeqCache};
use crate::tensor::kernels;

/// Dense attention over contiguous K/V (`[n, d]` row-major): out `[d]`.
pub fn contiguous_full(q: &[f32], k: &[f32], v: &[f32], out: &mut [f32]) {
    let d = q.len();
    let n = k.len() / d;
    debug_assert_eq!(k.len(), n * d);
    debug_assert_eq!(v.len(), n * d);
    let s = scale(d);
    let kn = kernels::active();
    let mut logits = vec![0.0f32; n];
    for (i, l) in logits.iter_mut().enumerate() {
        *l = (kn.dot)(q, &k[i * d..(i + 1) * d]) * s;
    }
    (kn.softmax)(&mut logits);
    out.fill(0.0);
    for (i, &w) in logits.iter().enumerate() {
        (kn.axpy)(w, &v[i * d..(i + 1) * d], out);
    }
}

/// Streaming-softmax dense attention over the paged cache for one head.
/// Visits each page once; numerically identical (up to fp error) to the
/// two-pass version.
pub fn paged_full(cache: &PagedKvCache, seq: &SeqCache, head: usize, q: &[f32], out: &mut [f32]) {
    paged_full_limit(cache, seq, head, q, seq.len, out)
}

/// `paged_full` over the first `limit` tokens only — the visible-prefix
/// primitive chunked prefill is built from. `limit == seq.len`
/// reproduces `paged_full` exactly (same cells, same order).
pub fn paged_full_limit(
    cache: &PagedKvCache,
    seq: &SeqCache,
    head: usize,
    q: &[f32],
    limit: usize,
    out: &mut [f32],
) {
    let d = q.len();
    let s = scale(d);
    let ps = cache.cfg.page_size;
    let npages = limit.div_ceil(ps);
    let kn = kernels::active();
    let mut m = f32::NEG_INFINITY; // running max
    let mut denom = 0.0f32; // running sum of exp
    out.fill(0.0);
    for (pi, &page) in seq.pages[..npages].iter().enumerate() {
        let fill = (limit - pi * ps).min(ps);
        for slot in 0..fill {
            let logit = (kn.dot)(q, cache.k_at(page, head, slot)) * s;
            if logit > m {
                // Rescale accumulated state.
                let corr = (m - logit).exp();
                if m.is_finite() {
                    denom *= corr;
                    for o in out.iter_mut() {
                        *o *= corr;
                    }
                }
                m = logit;
            }
            let w = (logit - m).exp();
            denom += w;
            (kn.axpy)(w, cache.v_at(page, head, slot), out);
        }
    }
    if denom > 0.0 {
        let inv = 1.0 / denom;
        for o in out.iter_mut() {
            *o *= inv;
        }
    }
}

/// Multi-query causal dense attention for a prefill chunk, one KV head.
/// `qs` holds the chunk's query rows: the query for chunk offset `c`,
/// group head `g` lives at `qs[c * q_stride + g * d ..][..d]` (the
/// engine passes its flattened step buffer with `q_stride = q_dim`).
/// Query `c` sits at sequence position `start + c` and attends to tokens
/// `0..=start+c` — decode semantics, self included. `outs` is
/// `[span * group * d]`, chunk-offset-major. Bit-exact with running
/// `paged_full` once per token at the matching position.
#[allow(clippy::too_many_arguments)]
pub fn paged_full_causal(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    q_stride: usize,
    group: usize,
    start: usize,
    outs: &mut [f32],
) {
    let d = cache.cfg.head_dim;
    let span = outs.len() / (group * d);
    debug_assert!(start + span <= seq.len);
    for c in 0..span {
        for g in 0..group {
            paged_full_limit(
                cache,
                seq,
                kv_head,
                &qs[c * q_stride + g * d..c * q_stride + (g + 1) * d],
                start + c + 1,
                &mut outs[(c * group + g) * d..(c * group + g + 1) * d],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{naive_sparse, random_cache, random_q};
    use crate::tensor::{axpy, dot};

    #[test]
    fn contiguous_matches_naive() {
        let d = 16;
        let n = 37;
        let q = random_q(1, d);
        let mut r = crate::util::rng::Rng::new(2);
        let k: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let v: Vec<f32> = (0..n * d).map(|_| r.normal_f32(0.0, 1.0)).collect();
        let mut out = vec![0.0; d];
        contiguous_full(&q, &k, &v, &mut out);
        // Naive: weights then weighted sum.
        let s = scale(d);
        let mut w: Vec<f32> = (0..n).map(|i| dot(&q, &k[i * d..(i + 1) * d]) * s).collect();
        crate::tensor::softmax_inplace(&mut w);
        let mut want = vec![0.0; d];
        for i in 0..n {
            axpy(w[i], &v[i * d..(i + 1) * d], &mut want);
        }
        for (a, b) in out.iter().zip(&want) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn paged_matches_all_indices_sparse() {
        let (cache, seq) = random_cache(3, 2, 16, 53);
        let q = random_q(4, 16);
        for head in 0..2 {
            let mut out = vec![0.0; 16];
            paged_full(&cache, &seq, head, &q, &mut out);
            let all: Vec<usize> = (0..seq.len).collect();
            let want = naive_sparse(&cache, &seq, head, &q, &all);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "head {head}: {out:?} vs {want:?}");
            }
        }
    }

    #[test]
    fn paged_single_token() {
        let (cache, seq) = random_cache(5, 1, 8, 1);
        let q = random_q(6, 8);
        let mut out = vec![0.0; 8];
        paged_full(&cache, &seq, 0, &q, &mut out);
        // With one token, output == its V row.
        let v = cache.v_at(seq.pages[0], 0, 0);
        for (a, b) in out.iter().zip(v) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn causal_chunk_matches_per_token_decode() {
        // The chunk kernel at span S must be bit-identical to S lone
        // decode-position calls — the chunked-prefill exactness contract.
        let d = 16;
        let group = 2;
        let (cache, seq) = random_cache(7, 1, d, 53);
        let start = 21;
        let span = 19; // crosses a page boundary, ends mid-page
        let mut qs = Vec::new();
        for c in 0..span {
            for g in 0..group {
                qs.extend(random_q(100 + (c * group + g) as u64, d));
            }
        }
        let q_stride = group * d;
        let mut outs = vec![0.0; span * group * d];
        paged_full_causal(&cache, &seq, 0, &qs, q_stride, group, start, &mut outs);
        for c in 0..span {
            for g in 0..group {
                let mut want = vec![0.0; d];
                paged_full_limit(
                    &cache,
                    &seq,
                    0,
                    &qs[c * q_stride + g * d..c * q_stride + (g + 1) * d],
                    start + c + 1,
                    &mut want,
                );
                assert_eq!(&outs[(c * group + g) * d..(c * group + g + 1) * d], &want[..]);
            }
        }
        // And the limit at the full length reproduces paged_full exactly.
        let q = random_q(8, d);
        let mut a = vec![0.0; d];
        let mut b = vec![0.0; d];
        paged_full(&cache, &seq, 0, &q, &mut a);
        paged_full_limit(&cache, &seq, 0, &q, seq.len, &mut b);
        assert_eq!(a, b);
    }

    #[test]
    fn streaming_softmax_stability_with_large_logits() {
        // Huge-magnitude keys stress the running-max rescale.
        let d = 8;
        let mut cache =
            crate::kvcache::PagedKvCache::new(crate::kvcache::CacheConfig::new(1, d, 8));
        let mut seq = crate::kvcache::SeqCache::default();
        for i in 0..32 {
            let k = vec![if i == 17 { 40.0 } else { -40.0 }; d];
            let v = vec![i as f32; d];
            cache.append(&mut seq, &k, &v).unwrap();
        }
        let q = vec![1.0; d];
        let mut out = vec![0.0; d];
        paged_full(&cache, &seq, 0, &q, &mut out);
        assert!(out.iter().all(|x| x.is_finite()));
        assert!((out[0] - 17.0).abs() < 1e-3, "{out:?}"); // token 17 dominates
    }
}
