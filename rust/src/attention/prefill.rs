//! Sparse *prefill* attention: bound-guided page skipping for the
//! chunked context phase (DESIGN.md §13).
//!
//! Decode got its sparsity from Select-then-Prune; prefill chunk queries
//! still walked the dense visible prefix (`full::paged_full_causal`), so
//! TTFT stayed O(n²) in prompt length. This kernel upgrades the
//! Skip-Softmax idea — compare a block's bound on max(QKᵀ) against the
//! running softmax state and skip blocks that cannot contribute mass —
//! into the same provable top-p form the hier decode path uses
//! (`pruner::hier_prune_group`):
//!
//! * Every query of the span always attends **exactly** to a *mandatory
//!   region*: the local window before the first active query, the
//!   chunk's own tokens, and the unsealed fp32 tail (none of which have
//!   sealed metadata anyway). That seeds the streaming (M, S) state.
//! * Sealed pages strictly below the window (`gated` pages) get one
//!   shared upper logit bound per (item, kv-head): the Quest min/max
//!   ub + quantization slack·Σ|q| formula, evaluated over the
//!   *coordinate envelope* `[qmin, qmax]` of all the span's query rows —
//!   one bound pass amortized across the whole span, so the skip
//!   decision itself is O(pages·d), not O(span·pages·d).
//! * Pages are visited in descending bound order with streaming softmax
//!   accumulation of the **exact** fp32 scores; before each page, every
//!   query checks the hier early-stop test `R·(1−eps) ≤ eps·S` against
//!   the shared suffix-sum of remaining bound mass and drops out once
//!   the pages it has not visited cannot carry an eps-fraction of its
//!   softmax mass.
//!
//! Soundness (per query row, per head): every unvisited token's exact
//! logit is ≤ its page's envelope bound (its q lies inside the
//! envelope, and the slack covers the metadata the bound was built
//! from), so the true remaining mass is ≤ R = suffix·exp(bmax − M).
//! Stopping when R(1−eps) ≤ eps·S therefore leaves at most an eps
//! fraction of the *full dense* softmax mass unattended — the kept mass
//! is ≥ 1 − eps of the dense reference, with all visited scores exact
//! (top-p with p = 1, the prefill analog of the pruner's mass ≥ p − eps
//! guarantee). With the feature off the engine never calls this path
//! and the dense walk stays the bit-exact reference.

use super::scale;
use crate::kvcache::{PagedKvCache, SeqCache};
use crate::tensor::kernels;

/// Aggregate counters of one multi-query sparse-prefill call.
#[derive(Clone, Copy, Debug, Default)]
pub struct SparsePrefillStats {
    /// Sealed pages below the window gate (per query-row denominator —
    /// multiply by live rows for the total opportunity).
    pub gated_pages: usize,
    /// Σ over (query, group head) of gated pages *not* visited.
    pub pages_skipped: u64,
    /// Σ over (query, group head) of gated pages considered.
    pub pages_total: u64,
}

/// Reused buffers for [`sparse_prefill_causal`] — engine workers hold
/// one per scratch arena so the steady-state call allocates nothing
/// once the buffers have grown to the working-set size.
#[derive(Default)]
pub struct SparsePrefillScratch {
    /// Active chunk offsets (filled by the engine before the call;
    /// taken out for the call itself).
    pub active: Vec<usize>,
    /// Coordinate envelope over the active query rows, `[d]` each.
    qmin: Vec<f32>,
    qmax: Vec<f32>,
    /// Scaled envelope bound per gated page.
    bounds: Vec<f64>,
    /// Gated page indices sorted by bound (descending, id-ascending).
    pub order: Vec<u32>,
    /// `suffix[oi] = Σ_{o ≥ oi} page_size · exp(bound[order[o]] − bmax)`.
    suffix: Vec<f64>,
    /// Streaming softmax state per (active query × group head).
    m: Vec<f64>,
    ssum: Vec<f64>,
    live: Vec<bool>,
    /// Gated pages actually visited per (active query × group head),
    /// indexed `ai * group + g` — prefixes of `order` (tests reconstruct
    /// the visited set from these two).
    pub visited: Vec<u32>,
}

/// Multi-query sparse prefill for one KV head of a chunk item. Query
/// layout matches [`full::paged_full_causal`]: the row for chunk offset
/// `c`, group head `g` is `qs[c * q_stride + g * d ..][..d]`, its output
/// goes to `outs[(c * group + g) * d ..][..d]`, and it attends causally
/// over tokens `0..=start+c`. Only the rows named by `active`
/// (ascending chunk offsets) are computed; other rows are untouched.
/// `eps` is the top-p slack (clamped to [0, 0.5]); `window` is the
/// always-dense local window (clamped to ≥ 1 so the self token is
/// always exact).
#[allow(clippy::too_many_arguments)]
pub fn sparse_prefill_causal(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    q_stride: usize,
    group: usize,
    start: usize,
    active: &[usize],
    eps: f32,
    window: usize,
    outs: &mut [f32],
    scratch: &mut SparsePrefillScratch,
) -> SparsePrefillStats {
    let mut stats = SparsePrefillStats::default();
    if active.is_empty() {
        return stats;
    }
    let d = cache.cfg.head_dim;
    let ps = cache.cfg.page_size;
    let s = scale(d);
    let kn = kernels::active();
    let eps = eps.clamp(0.0, 0.5) as f64;
    let window = window.max(1);
    let nq = active.len() * group;
    debug_assert!(active.windows(2).all(|w| w[0] < w[1]), "active offsets ascending");
    debug_assert!(start + active[active.len() - 1] < seq.len);
    // Gate: pages wholly below the first active query's local window are
    // candidates for skipping. They are < the visible prefix of *every*
    // active query, fully filled, hence sealed (mirror + minmax valid).
    let gate_tok = (start + active[0] + 1).saturating_sub(window);
    let gated = gate_tok / ps;
    stats.gated_pages = gated;
    stats.pages_total = (gated * nq) as u64;
    // --- streaming state + mandatory region (exact, always attended) --
    scratch.m.clear();
    scratch.m.resize(nq, f64::NEG_INFINITY);
    scratch.ssum.clear();
    scratch.ssum.resize(nq, 0.0);
    scratch.live.clear();
    scratch.live.resize(nq, true);
    scratch.visited.clear();
    scratch.visited.resize(nq, gated as u32);
    for &c in active {
        outs[c * group * d..(c + 1) * group * d].fill(0.0);
    }
    // Page-outer / slot / query-inner over tokens [gated·ps, n_c): the
    // K/V rows load once per slot for the whole span. Per-query
    // causality is the `tok < n_c` check.
    let n_max = start + active[active.len() - 1] + 1;
    for pi in gated..n_max.div_ceil(ps) {
        let page = seq.pages[pi];
        let fill = (n_max - pi * ps).min(ps);
        for slot in 0..fill {
            let tok = pi * ps + slot;
            let krow = cache.k_at(page, kv_head, slot);
            let vrow = cache.v_at(page, kv_head, slot);
            for (ai, &c) in active.iter().enumerate() {
                if tok > start + c {
                    continue; // not visible to this query yet (causality)
                }
                for g in 0..group {
                    let q = &qs[c * q_stride + g * d..c * q_stride + (g + 1) * d];
                    let out = &mut outs[(c * group + g) * d..(c * group + g + 1) * d];
                    let qi = ai * group + g;
                    stream_token(kn, q, krow, vrow, s, qi, scratch, out);
                }
            }
        }
    }
    if gated == 0 {
        normalize(active, group, d, scratch, outs);
        return stats;
    }
    // --- one amortized bound pass over the gated pages -----------------
    // Coordinate envelope of every active query row: any q in the span
    // satisfies qmin[i] ≤ q[i] ≤ qmax[i], so one interval-arithmetic
    // bound per page is sound for all of them at once.
    scratch.qmin.clear();
    scratch.qmin.resize(d, f32::INFINITY);
    scratch.qmax.clear();
    scratch.qmax.resize(d, f32::NEG_INFINITY);
    for &c in active {
        for g in 0..group {
            let q = &qs[c * q_stride + g * d..c * q_stride + (g + 1) * d];
            for i in 0..d {
                scratch.qmin[i] = scratch.qmin[i].min(q[i]);
                scratch.qmax[i] = scratch.qmax[i].max(q[i]);
            }
        }
    }
    let qabs_sum: f32 =
        scratch.qmin.iter().zip(&scratch.qmax).map(|(a, b)| a.abs().max(b.abs())).sum();
    scratch.bounds.clear();
    let mut bmax = f64::NEG_INFINITY;
    for &page in &seq.pages[..gated] {
        let b = (s * cache.envelope_page_bound(page, kv_head, &scratch.qmin, &scratch.qmax, qabs_sum))
            as f64;
        scratch.bounds.push(b);
        bmax = bmax.max(b);
    }
    // Visit order: best bound first, page-id ties ascending (sort keys
    // are finite, so total_cmp is a strict weak order and the order —
    // hence every skip decision — is deterministic).
    scratch.order.clear();
    scratch.order.extend(0..gated as u32);
    let bounds = &scratch.bounds;
    scratch
        .order
        .sort_unstable_by(|&a, &b| bounds[b as usize].total_cmp(&bounds[a as usize]).then(a.cmp(&b)));
    // Suffix sums of remaining bound mass (a page contributes at most
    // page_size tokens at its bound).
    scratch.suffix.clear();
    scratch.suffix.resize(gated + 1, 0.0);
    for oi in (0..gated).rev() {
        scratch.suffix[oi] =
            scratch.suffix[oi + 1] + ps as f64 * (scratch.bounds[scratch.order[oi] as usize] - bmax).exp();
    }
    // --- descending-bound visit with per-query early stop --------------
    let mut n_live = nq;
    for oi in 0..gated {
        for qi in 0..nq {
            if !scratch.live[qi] || scratch.ssum[qi] <= 0.0 {
                continue;
            }
            // True remaining mass of this query ≤ R (every unvisited
            // logit ≤ its page bound ≤ bmax-relative suffix term).
            let rem = scratch.suffix[oi] * (bmax - scratch.m[qi]).exp();
            if rem * (1.0 - eps) <= eps * scratch.ssum[qi] {
                scratch.live[qi] = false;
                scratch.visited[qi] = oi as u32;
                n_live -= 1;
            }
        }
        if n_live == 0 {
            break;
        }
        let page = seq.pages[scratch.order[oi] as usize];
        for slot in 0..ps {
            let krow = cache.k_at(page, kv_head, slot);
            let vrow = cache.v_at(page, kv_head, slot);
            for (ai, &c) in active.iter().enumerate() {
                for g in 0..group {
                    let qi = ai * group + g;
                    if !scratch.live[qi] {
                        continue;
                    }
                    let q = &qs[c * q_stride + g * d..c * q_stride + (g + 1) * d];
                    let out = &mut outs[(c * group + g) * d..(c * group + g + 1) * d];
                    stream_token(kn, q, krow, vrow, s, qi, scratch, out);
                }
            }
        }
    }
    for qi in 0..nq {
        stats.pages_skipped += (gated as u32 - scratch.visited[qi]) as u64;
    }
    normalize(active, group, d, scratch, outs);
    stats
}

/// One streaming-softmax update: exact logit, running-max rescale of the
/// f32 accumulator, f64 (M, S) state for the early-stop test.
#[inline]
fn stream_token(
    kn: &kernels::Kernels,
    q: &[f32],
    krow: &[f32],
    vrow: &[f32],
    s: f32,
    qi: usize,
    scratch: &mut SparsePrefillScratch,
    out: &mut [f32],
) {
    let logit = ((kn.dot)(q, krow) * s) as f64;
    let m = scratch.m[qi];
    if logit > m {
        if m.is_finite() {
            let corr = (m - logit).exp();
            scratch.ssum[qi] *= corr;
            let cf = corr as f32;
            for o in out.iter_mut() {
                *o *= cf;
            }
        }
        scratch.m[qi] = logit;
    }
    let w = (logit - scratch.m[qi]).exp();
    scratch.ssum[qi] += w;
    (kn.axpy)(w as f32, vrow, out);
}

fn normalize(
    active: &[usize],
    group: usize,
    d: usize,
    scratch: &SparsePrefillScratch,
    outs: &mut [f32],
) {
    for (ai, &c) in active.iter().enumerate() {
        for g in 0..group {
            let denom = scratch.ssum[ai * group + g];
            if denom > 0.0 {
                let inv = (1.0 / denom) as f32;
                for o in outs[(c * group + g) * d..(c * group + g + 1) * d].iter_mut() {
                    *o *= inv;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::full::paged_full_limit;
    use crate::attention::testutil::{random_cache, random_q};
    use crate::kvcache::{CacheConfig, SeqCache};
    use crate::util::rng::Rng;

    /// Peaked retrieval-style cache: most keys are small noise, a few
    /// "needle" tokens align with the query direction — the regime where
    /// bound-guided skipping should drop most gated pages.
    fn peaked_cache(seed: u64, d: usize, n: usize, needles: &[usize]) -> (PagedKvCache, SeqCache) {
        let pages = n.div_ceil(16) + 2;
        let mut cache = PagedKvCache::new(CacheConfig::new(1, d, pages));
        let mut seq = SeqCache::default();
        let mut r = Rng::new(seed);
        for t in 0..n {
            let mut k: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 0.2)).collect();
            if needles.contains(&t) {
                for x in k.iter_mut() {
                    *x += 2.0;
                }
            }
            let v: Vec<f32> = (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect();
            cache.append(&mut seq, &k, &v).unwrap();
        }
        (cache, seq)
    }

    fn dense_reference(
        cache: &PagedKvCache,
        seq: &SeqCache,
        q: &[f32],
        limit: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        // Exact softmax weights over the visible prefix + dense output.
        let s = scale(q.len());
        let mut w: Vec<f32> =
            (0..limit).map(|t| cache.exact_score(seq, 0, q, t) * s).collect();
        crate::tensor::softmax_inplace(&mut w);
        let mut out = vec![0.0; q.len()];
        paged_full_limit(cache, seq, 0, q, limit, &mut out);
        (w, out)
    }

    #[test]
    fn matches_dense_when_nothing_gated() {
        // Context shorter than the window: the kernel is a pure
        // mandatory-region walk and must match the dense reference.
        let d = 16;
        let (cache, seq) = random_cache(11, 1, d, 40);
        let start = 30;
        let active = [1usize, 5, 9];
        let mut qs = Vec::new();
        for c in 0..10 {
            qs.extend(random_q(300 + c, d));
        }
        let mut outs = vec![0.0f32; 10 * d];
        let mut scratch = SparsePrefillScratch::default();
        let st = sparse_prefill_causal(
            &cache, &seq, 0, &qs, d, 1, start, &active, 0.05, 64, &mut outs, &mut scratch,
        );
        assert_eq!(st.gated_pages, 0);
        assert_eq!(st.pages_skipped, 0);
        for &c in &active {
            let (_, want) = dense_reference(&cache, &seq, &qs[c * d..(c + 1) * d], start + c + 1);
            for (a, b) in outs[c * d..(c + 1) * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "offset {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn skips_pages_and_keeps_mass_on_peaked_cache() {
        // The soundness property (DESIGN.md §13): per query row, the
        // softmax mass of the visited set — measured against the *full
        // dense* softmax — is ≥ 1 − eps, while most gated pages are
        // skipped in this peaked regime. Visited sets are reconstructed
        // from the scratch's (order, visited) prefixes.
        let d = 32;
        let n = 1024;
        let ps = 16;
        let eps = 0.05f32;
        let window = 64;
        let (cache, seq) = peaked_cache(21, d, n, &[200, 201, 530]);
        let start = n - 8 - 1; // span of 8 queries ending at token n-1
        let span = 8;
        let mut r = Rng::new(22);
        let mut qs = Vec::new();
        for _ in 0..span {
            // Needle-aligned queries with noise — the retrieval regime.
            qs.extend((0..d).map(|_| 1.0 + r.normal_f32(0.0, 0.3)));
        }
        let active: Vec<usize> = (0..span).collect();
        let mut outs = vec![0.0f32; span * d];
        let mut scratch = SparsePrefillScratch::default();
        let st = sparse_prefill_causal(
            &cache, &seq, 0, &qs, d, 1, start, &active, eps, window, &mut outs, &mut scratch,
        );
        assert!(st.gated_pages > 40, "gate must cover most of the context");
        assert!(
            st.pages_skipped as f64 > 0.5 * st.pages_total as f64,
            "peaked cache must skip most gated pages: {}/{}",
            st.pages_skipped,
            st.pages_total
        );
        for (ai, &c) in active.iter().enumerate() {
            let limit = start + c + 1;
            let q = &qs[c * d..(c + 1) * d];
            let (w, want) = dense_reference(&cache, &seq, q, limit);
            // Visited tokens: the mandatory region plus the visited
            // order-prefix of gated pages.
            let mut mass = w[st.gated_pages * ps..limit].iter().sum::<f32>();
            for &pi in &scratch.order[..scratch.visited[ai] as usize] {
                let lo = pi as usize * ps;
                mass += w[lo..lo + ps].iter().sum::<f32>();
            }
            assert!(
                mass >= 1.0 - eps - 1e-4,
                "offset {c}: kept mass {mass} < 1 - eps"
            );
            // And the output should be close to dense (the skipped tail
            // carries ≤ eps mass).
            for (a, b) in outs[c * d..(c + 1) * d].iter().zip(&want) {
                assert!((a - b).abs() < 0.1, "offset {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn eps_zero_visits_everything_and_matches_dense() {
        // eps = 0 makes the stop test unsatisfiable while any bound mass
        // remains, so every page is visited and the result matches the
        // dense reference to fp tolerance (different accumulation order).
        let d = 16;
        let n = 400;
        let (cache, seq) = random_cache(31, 1, d, n);
        let start = n - 4 - 1;
        let active = [0usize, 3];
        let mut qs = Vec::new();
        for c in 0..4 {
            qs.extend(random_q(500 + c, d));
        }
        let mut outs = vec![0.0f32; 4 * d];
        let mut scratch = SparsePrefillScratch::default();
        let st = sparse_prefill_causal(
            &cache, &seq, 0, &qs, d, 1, start, &active, 0.0, 8, &mut outs, &mut scratch,
        );
        assert!(st.gated_pages > 10);
        assert_eq!(st.pages_skipped, 0, "eps=0 must visit every gated page");
        for &c in &active {
            let (_, want) = dense_reference(&cache, &seq, &qs[c * d..(c + 1) * d], start + c + 1);
            for (a, b) in outs[c * d..(c + 1) * d].iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "offset {c}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn random_cache_soundness_property() {
        // Diffuse random keys: little skipping is expected (the adaptive
        // regime), but the mass property must hold regardless.
        let d = 24;
        let n = 600;
        let ps = 16;
        let eps = 0.1f32;
        let (cache, seq) = random_cache(41, 1, d, n);
        let start = n - 6 - 1;
        let active: Vec<usize> = vec![0, 2, 5];
        let mut qs = Vec::new();
        for c in 0..6 {
            qs.extend(random_q(700 + c, d));
        }
        let mut outs = vec![0.0f32; 6 * d];
        let mut scratch = SparsePrefillScratch::default();
        let st = sparse_prefill_causal(
            &cache, &seq, 0, &qs, d, 1, start, &active, eps, 32, &mut outs, &mut scratch,
        );
        for (ai, &c) in active.iter().enumerate() {
            let limit = start + c + 1;
            let (w, _) = dense_reference(&cache, &seq, &qs[c * d..(c + 1) * d], limit);
            let mut mass = w[st.gated_pages * ps..limit].iter().sum::<f32>();
            for &pi in &scratch.order[..scratch.visited[ai] as usize] {
                mass += w[pi as usize * ps..(pi as usize + 1) * ps].iter().sum::<f32>();
            }
            assert!(mass >= 1.0 - eps - 1e-4, "offset {c}: kept mass {mass}");
        }
    }
}
