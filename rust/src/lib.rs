//! # Twilight — Adaptive Attention Sparsity with Hierarchical Top-p Pruning
//!
//! A production-shaped reproduction of *"Twilight: Adaptive Attention
//! Sparsity with Hierarchical Top-p Pruning"* (NeurIPS 2025) as a
//! three-layer Rust + JAX + Pallas serving stack:
//!
//! * **L3 (this crate)** — the serving coordinator: request queue,
//!   continuous batcher, paged KV cache, token selectors (Quest, Double
//!   Sparsity, MagicPIG, StreamingLLM, SnapKV, H2O), the **Twilight
//!   pruner** (INT4 SpGEMV estimation → softmax → top-p binary search),
//!   the **budget governor** (runtime control plane closing the loop on
//!   p / B0 against accuracy, latency, and memory signals), varlen
//!   sparse-attention kernels, metrics, and the CLI launcher.
//! * **L2 (JAX, build time)** — the decode-layer compute graph, lowered
//!   once to HLO text and executed from Rust via PJRT (`runtime/`).
//! * **L1 (Pallas, build time)** — the SpGEMV / top-p / sparse-attention
//!   kernels, lowered (interpret mode) into the same HLO and validated
//!   against pure-jnp oracles.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a module and bench target.

pub mod attention;
pub mod coordinator;
pub mod evalsuite;
pub mod governor;
pub mod kvcache;
pub mod model;
pub mod obs;
pub mod pruner;
pub mod runtime;
pub mod selector;
pub mod sim;
pub mod tensor;
pub mod util;
pub mod workload;

/// Crate version string reported by the CLI and the server banner.
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
