//! `twilight` — the serving-framework launcher.
//!
//! ```text
//! twilight serve   --model retrieval --addr 127.0.0.1:7070 --selector quest --p 0.95
//!                  [--governor static|aimd|mass --slo-tpot-ms 25]
//!                  [--prefill-chunk 64 --prefill-budget 512]
//! twilight eval    --suite longbench --ctx 2048 --n 5
//! twilight ppl     --budgets 16,32,64,128,256 --selector quest
//! twilight bench   --ctx 4096 --steps 20            (quick latency check)
//! twilight inspect --artifacts artifacts            (PJRT graphs)
//! ```
//!
//! `--prefill-chunk` sets the chunked-prefill span (also
//! `TWILIGHT_PREFILL_CHUNK`; bit-exact for any value — it only shapes
//! latency), `--prefill-budget` the per-step prompt-token budget shared
//! by all co-scheduled chunks of a mixed step.
//!
//! `--hier-pages` (also `TWILIGHT_HIER_PAGES=1`) enables the pruner's
//! hierarchical page-level top-p pre-prune: candidate pages are scored
//! in descending Quest-bound order and cold pages are skipped once they
//! provably cannot shift the top-p mass by more than `--hier-eps`
//! (default 0.02; kept mass stays ≥ p − hier_eps). Skipped-page counts
//! appear in `stats` / serving reports.
//!
//! `--sparse-prefill` (also `TWILIGHT_SPARSE_PREFILL=1`) enables
//! bound-guided page skipping for the chunked context phase: prefill
//! chunk queries visit sealed pages in descending envelope-bound order
//! and early-stop once the rest provably carries < `--prefill-eps`
//! (default 0.02) of their softmax mass; the last `--prefill-window`
//! (default 64) tokens always attend exactly. Either tuning flag
//! implies the mode. Skipped-block counts appear in `stats` / serving
//! reports as `prefill_blocks_*`.
//!
//! `--governor` attaches the adaptive budget governor (DESIGN.md §8):
//! it closes the loop on p / B0 against prune-mass telemetry, the
//! `--slo-tpot-ms` latency target, and KV page-pool pressure.
//!
//! `--kernel auto|scalar|avx2|neon` (also `TWILIGHT_KERNEL`) picks the
//! SIMD compute-kernel backend (DESIGN.md §11). The default `auto`
//! resolves the best backend the host supports; `scalar` pins the
//! bit-exact reference path. An explicitly named backend the host does
//! not support is a hard error here (the env-var path only warns and
//! falls back).
//!
//! `--resident-frac F` (also `TWILIGHT_RESIDENT_FRAC`) caps the
//! fully-resident KV page pool at `ceil(F * num_pages)` pages per layer
//! and spills the rest to the simulated slow tier (DESIGN.md §12);
//! hier-bound prefetch faults pages back on demand. `F >= 1` (the
//! default) keeps everything resident.
//!
//! `--chaos seed:p_read:p_write[:p_panic]` (also `TWILIGHT_CHAOS`)
//! wraps the slow tier in the deterministic fault injector (DESIGN.md
//! §14): seeded per-(page, attempt) read/write failures, latency
//! spikes, torn writes, and optional in-read panics. Faulted requests
//! fail with a contained reason while neighbors stay bit-exact. The
//! flag beats the env var; `--chaos off` disables injection entirely.
//!
//! Observability (DESIGN.md §10): `--trace` (also `TWILIGHT_TRACE=1`)
//! turns on the per-stage span recorder; `--trace-out trace.json` (also
//! `TWILIGHT_TRACE_OUT`) writes the collected spans as Chrome
//! trace-event JSON at exit — open in `chrome://tracing` / Perfetto.
//! `--log-json` (also `TWILIGHT_LOG_JSON=1`) switches log lines to
//! JSON-lines. `--snapshot-every N` makes the scheduler emit one
//! structured `obs snapshot` log line every N steps. The Prometheus
//! scrape (`{"cmd":"metrics"}`) and flight-recorder dump
//! (`{"cmd":"dump"}`) are always live on the serve socket.

use std::sync::Arc;

use twilight::coordinator::engine::Engine;
use twilight::coordinator::scheduler::{Scheduler, SchedulerConfig};
use twilight::coordinator::{server, SparseConfig};
use twilight::evalsuite::{ppl, render_table, run_accuracy, suite_requests};
use twilight::governor::slo::SloConfig;
use twilight::governor::{Governor, GovernorConfig};
use twilight::model::retrieval::build_retrieval_model;
use twilight::model::weights;
use twilight::selector::SelectorKind;
use twilight::util::cli::Args;
use twilight::util::logging;
use twilight::workload::{load_corpus, RetrievalVocab};

fn usage() -> ! {
    eprintln!(
        "usage: twilight <serve|eval|ppl|bench|inspect> [--help]\n\
         run with a subcommand; see README.md for options"
    );
    std::process::exit(2)
}

fn sparse_config_from_args(a: &Args) -> SparseConfig {
    let selector = SelectorKind::parse(&a.str_or("selector", "quest")).unwrap_or_else(|| {
        eprintln!("unknown selector");
        std::process::exit(2)
    });
    let mut cfg = if a.flag("no-twilight") {
        SparseConfig::baseline(selector, a.usize_or("budget", 1024))
    } else {
        SparseConfig::twilight(selector, a.f64_or("p", 0.95) as f32)
    };
    if let Some(b) = a.get("budget") {
        if let Some(spec) = twilight::coordinator::BudgetSpec::parse(b) {
            cfg.budget = spec;
        }
    }
    // Hierarchical page-level top-p pre-prune (also TWILIGHT_HIER_PAGES=1).
    if a.flag("hier-pages") {
        if let Some(t) = cfg.twilight.as_mut() {
            t.hier_pages = true;
        }
    }
    if let Some(e) = a.get("hier-eps") {
        if let (Some(t), Ok(eps)) = (cfg.twilight.as_mut(), e.parse::<f32>()) {
            t.hier_eps = eps.clamp(0.0, 0.5);
        }
    }
    cfg.skip_layers =
        a.usize_or("skip-layers", if a.str_or("model", "retrieval") == "retrieval" { 0 } else { 2 });
    cfg.dense_below = a.usize_or("dense-below", 64);
    // Bound-guided sparse prefill (also TWILIGHT_SPARSE_PREFILL=1, which
    // the SparseConfig constructors already honor). `--prefill-eps` /
    // `--prefill-window` imply the flag and tune the kernel.
    if a.flag("sparse-prefill") {
        cfg.sparse_prefill.get_or_insert_with(Default::default);
    }
    if let Some(e) = a.get("prefill-eps") {
        if let Ok(eps) = e.parse::<f32>() {
            cfg.sparse_prefill.get_or_insert_with(Default::default).eps = eps.clamp(0.0, 0.5);
        }
    }
    if let Some(w) = a.get("prefill-window") {
        if let Ok(win) = w.parse::<usize>() {
            cfg.sparse_prefill.get_or_insert_with(Default::default).window = win.max(1);
        }
    }
    cfg
}

fn load_model_arg(a: &Args) -> Arc<twilight::model::Model> {
    let dir = a.str_or("artifacts", "artifacts");
    match a.str_or("model", "retrieval").as_str() {
        "retrieval" => {
            // Prefer the artifact (parity with the python-built weights);
            // fall back to the in-crate builder.
            match weights::load_model(&dir, "retrieval") {
                Ok(m) => Arc::new(m),
                Err(_) => Arc::new(build_retrieval_model(RetrievalVocab::DEFAULT, 1 << 17)),
            }
        }
        name => Arc::new(weights::load_model(&dir, name).unwrap_or_else(|e| {
            eprintln!("failed to load model '{name}': {e}");
            std::process::exit(1)
        })),
    }
}

/// `--resident-frac F` (also `TWILIGHT_RESIDENT_FRAC`, which
/// `Engine::new` already honors) attaches the simulated slow tier with a
/// page-cap of `ceil(num_pages * F)`. The flag beats the env var; a
/// value outside (0, 1) means fully resident. A malformed value is a
/// hard error, matching the `--kernel` contract.
fn apply_resident_frac(a: &Args, engine: &mut Engine) {
    if let Some(f) = a.get("resident-frac") {
        match f.parse::<f64>() {
            Ok(frac) if frac.is_finite() && frac > 0.0 => engine.set_resident_frac(frac),
            _ => {
                eprintln!("bad --resident-frac '{f}' (want a fraction in (0, 1], e.g. 0.25)");
                std::process::exit(2);
            }
        }
    }
}

/// `--chaos seed:p_read:p_write[:p_panic]` (also `TWILIGHT_CHAOS`,
/// which `Engine::new` already honors) installs deterministic tier
/// fault injection; `--chaos off`/`none` clears an env-set default.
/// The flag beats the env var; a malformed value is a hard error,
/// matching the `--kernel` / `--resident-frac` contract. Call before
/// [`apply_resident_frac`] so freshly attached tiers wrap once.
fn apply_chaos(a: &Args, engine: &mut Engine) {
    if let Some(c) = a.get("chaos") {
        match c.as_str() {
            "off" | "none" | "0" => engine.set_chaos(None),
            spec => match twilight::kvcache::offload::ChaosConfig::parse(spec) {
                Some(cfg) => engine.set_chaos(Some(cfg)),
                None => {
                    eprintln!(
                        "bad --chaos '{spec}' (want seed:p_read:p_write[:p_panic], \
                         e.g. 7:0.05:0.02, or 'off')"
                    );
                    std::process::exit(2);
                }
            },
        }
    }
}

fn cmd_serve(a: &Args) {
    let model = load_model_arg(a);
    let cfg = sparse_config_from_args(a);
    let capacity = a.usize_or("capacity", 1 << 20);
    let mut engine = Engine::new(model.clone(), cfg.clone(), capacity);
    engine.set_threads(a.usize_or("threads", engine.threads()));
    engine.set_prefill_chunk(a.usize_or("prefill-chunk", engine.prefill_chunk()));
    apply_chaos(a, &mut engine);
    apply_resident_frac(a, &mut engine);
    twilight::log_info!(
        "model={} ({} params), pipeline={}, capacity={} tokens, threads={}, prefill_chunk={}, \
         kernel={}, resident_frac={}, chaos={}",
        model.cfg.name,
        model.param_count(),
        cfg.label(),
        capacity,
        engine.threads(),
        engine.prefill_chunk(),
        twilight::tensor::kernels::active_name(),
        engine.resident_frac(),
        match engine.chaos() {
            Some(c) => format!("{}:{}:{}:{}", c.seed, c.p_read, c.p_write, c.p_panic),
            None => "off".to_string(),
        }
    );
    let sched_cfg = SchedulerConfig {
        max_batch: a.usize_or("max-batch", 64),
        max_prefill_tokens_per_step: a
            .usize_or("prefill-budget", SchedulerConfig::default().max_prefill_tokens_per_step),
        snapshot_every_steps: a.usize_or("snapshot-every", 0),
        ..Default::default()
    };
    let mut sched = Scheduler::new(engine, sched_cfg);
    let gov_name = a.str_or("governor", "none");
    if gov_name != "none" {
        let slo_ms = a.f64_or("slo-tpot-ms", 0.0);
        let gcfg = GovernorConfig {
            slo: SloConfig { target_tpot_s: slo_ms / 1e3, ..Default::default() },
            ..Default::default()
        };
        match Governor::new(&gov_name, gcfg) {
            Some(g) => {
                twilight::log_info!(
                    "governor={gov_name} slo_tpot={}",
                    if slo_ms > 0.0 { format!("{slo_ms}ms") } else { "off".to_string() }
                );
                sched.attach_governor(g);
            }
            None => {
                eprintln!("unknown governor '{gov_name}' (use static, aimd, or mass)");
                std::process::exit(2);
            }
        }
    }
    let addr = a.str_or("addr", "127.0.0.1:7070");
    if let Err(e) = server::serve(sched, &addr) {
        eprintln!("server error: {e}");
        std::process::exit(1);
    }
}

fn cmd_eval(a: &Args) {
    let model = load_model_arg(a);
    let ctx = a.usize_or("ctx", 2048);
    let n = a.usize_or("n", 5);
    let seed = a.u64_or("seed", 42);
    let capacity = (ctx + 64) * 2;
    let reqs = suite_requests(seed, ctx, n);
    let suite = a.str_or("suite", "longbench");
    let mut results = Vec::new();
    let budgets: Vec<usize> = a.usize_list_or("budgets", &[256, 1024]);
    let selectors: Vec<SelectorKind> = a
        .str_or("selectors", "quest,ds")
        .split(',')
        .filter_map(SelectorKind::parse)
        .collect();
    // Full baseline.
    results.push(run_accuracy(model.clone(), &SparseConfig::dense(), &reqs, capacity));
    for sel in &selectors {
        for &b in &budgets {
            let mut c = SparseConfig::baseline(*sel, b);
            c.skip_layers = 0;
            results.push(run_accuracy(model.clone(), &c, &reqs, capacity));
        }
        let mut c = SparseConfig::twilight(*sel, a.f64_or("p", 0.95) as f32);
        c.skip_layers = 0;
        results.push(run_accuracy(model.clone(), &c, &reqs, capacity));
    }
    println!("{}", render_table(&format!("{suite} (ctx={ctx}, n={n} per task)"), &results));
}

fn cmd_ppl(a: &Args) {
    let dir = a.str_or("artifacts", "artifacts");
    let model = Arc::new(weights::load_model(&dir, "charlm").unwrap_or_else(|e| {
        eprintln!("charlm artifacts missing ({e}); run `make artifacts`");
        std::process::exit(1)
    }));
    let corpus = load_corpus(&format!("{dir}/corpus_eval.bin")).unwrap_or_else(|e| {
        eprintln!("corpus missing: {e}");
        std::process::exit(1)
    });
    let windows = a.usize_or("windows", 4);
    let wlen = a.usize_or("window-len", 512);
    let selector = SelectorKind::parse(&a.str_or("selector", "quest")).unwrap();
    println!("{:<22} {:>10} {:>12}", "method", "ppl", "avg-budget");
    let dense = ppl::eval_ppl(model.clone(), &SparseConfig::dense(), &corpus, windows, wlen, 32);
    println!("{:<22} {:>10.3} {:>12.1}", "full", dense.ppl, wlen as f64);
    for b in a.usize_list_or("budgets", &[16, 32, 64, 128, 256]) {
        let mut c = SparseConfig::baseline(selector, b);
        c.skip_layers = 2;
        let r = ppl::eval_ppl(model.clone(), &c, &corpus, windows, wlen, 32);
        println!("{:<22} {:>10.3} {:>12.1}", r.label, r.ppl, r.avg_budget);
    }
    let mut c = SparseConfig::twilight(selector, a.f64_or("p", 0.95) as f32);
    c.skip_layers = 2;
    let r = ppl::eval_ppl(model.clone(), &c, &corpus, windows, wlen, 32);
    println!("{:<22} {:>10.3} {:>12.1}", r.label, r.ppl, r.avg_budget);
}

fn cmd_bench(a: &Args) {
    // Quick smoke latency check; the full figure benches live in benches/.
    let model = load_model_arg(a);
    let ctx = a.usize_or("ctx", 4096);
    let mut rng = twilight::util::rng::Rng::new(7);
    let g = twilight::workload::gen_niah(&mut rng, RetrievalVocab::DEFAULT, ctx);
    for (label, cfg) in [
        ("full", SparseConfig::dense()),
        ("quest(B=N/4)", {
            let mut c = SparseConfig::baseline(SelectorKind::Quest, ctx / 4);
            c.skip_layers = 0;
            c
        }),
        ("quest+twi(p=0.95)", {
            let mut c = SparseConfig::twilight(SelectorKind::Quest, 0.95);
            c.skip_layers = 0;
            c
        }),
    ] {
        let mut e = Engine::new(model.clone(), cfg, ctx * 2 + 128);
        e.set_threads(a.usize_or("threads", e.threads()));
        e.set_prefill_chunk(a.usize_or("prefill-chunk", e.prefill_chunk()));
        apply_chaos(a, &mut e);
        apply_resident_frac(a, &mut e);
        let _ = e.prefill(0, &g.prompt).unwrap();
        e.reset_stats();
        let t0 = std::time::Instant::now();
        let steps = a.usize_or("steps", 20);
        for _ in 0..steps {
            let _ = e.decode(0, g.prompt[0]).unwrap();
        }
        let total = t0.elapsed().as_secs_f64();
        let dt = total / steps as f64;
        println!(
            "{label:<20} {:.3} ms/step (select {:.0}% prune {:.0}% attend {:.0}%)",
            dt * 1e3,
            100.0 * e.stats.t_select / total,
            100.0 * e.stats.t_prune / total,
            100.0 * (e.stats.t_attend + e.stats.t_dense) / total,
        );
    }
}

fn cmd_inspect(a: &Args) {
    let dir = a.str_or("artifacts", "artifacts");
    match twilight::runtime::Runtime::open(&dir) {
        Ok(rt) => {
            println!("platform: {}", rt.platform());
            for g in rt.graphs() {
                println!("graph: {g}");
            }
        }
        Err(e) => {
            eprintln!("{e:#}");
            std::process::exit(1);
        }
    }
}

/// Write the collected spans as Chrome trace-event JSON if a destination
/// was given (`--trace-out` or `TWILIGHT_TRACE_OUT`). No-op otherwise.
fn maybe_export_trace(a: &Args) {
    let path = a
        .get("trace-out")
        .map(|s| s.to_string())
        .or_else(|| std::env::var("TWILIGHT_TRACE_OUT").ok().filter(|s| !s.is_empty()));
    if let Some(path) = path {
        match twilight::obs::trace::export_chrome(&path) {
            Ok(()) => twilight::log_info!("wrote Chrome trace to {path}"),
            Err(e) => twilight::log_warn!("trace export to {path} failed: {e}"),
        }
    }
}

fn main() {
    logging::init();
    let all: Vec<String> = std::env::args().skip(1).collect();
    if all.is_empty() {
        usage();
    }
    let cmd = all[0].clone();
    let a = Args::parse(
        all.into_iter().skip(1),
        &["no-twilight", "help", "hier-pages", "sparse-prefill", "trace", "log-json"],
    );
    logging::set_level(logging::level_from_str(&a.str_or("log", "info")));
    if a.flag("log-json") || std::env::var("TWILIGHT_LOG_JSON").is_ok_and(|v| v == "1") {
        logging::set_json(true);
    }
    // Reads TWILIGHT_TRACE and installs the flight-recorder panic hook.
    twilight::obs::init_from_env();
    if a.flag("trace") {
        twilight::obs::trace::set_enabled(true);
    }
    // Kernel backend: --kernel beats TWILIGHT_KERNEL. Unlike the env
    // path (which warns and degrades to auto), a bad flag is fatal.
    if let Some(k) = a.get("kernel") {
        match twilight::tensor::kernels::Select::parse(k) {
            Some(sel) => {
                if let Err(e) = twilight::tensor::kernels::install(sel) {
                    eprintln!("{e}");
                    std::process::exit(2);
                }
            }
            None => {
                eprintln!("unknown kernel backend '{k}' (use auto, scalar, avx2, or neon)");
                std::process::exit(2);
            }
        }
    }
    match cmd.as_str() {
        "serve" => cmd_serve(&a),
        "eval" => cmd_eval(&a),
        "ppl" => cmd_ppl(&a),
        "bench" => cmd_bench(&a),
        "inspect" => cmd_inspect(&a),
        "version" | "--version" => println!("twilight {}", twilight::VERSION),
        _ => usage(),
    }
    maybe_export_trace(&a);
}
