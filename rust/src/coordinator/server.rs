//! Line-delimited JSON TCP server — the `twilight serve` front end.
//!
//! Protocol (one JSON object per line):
//! ```text
//! → {"prompt": [1,2,3], "max_new_tokens": 4}
//! ← {"id": 0, "output": [17,3,3,9], "ttft_s": 0.01, "tpot_s": 0.002}
//! → {"cmd": "stats"}
//! ← {"requests": ..., "throughput_tok_s": ...}
//! → {"cmd": "shutdown"}
//! ```
//!
//! Connections are handled by an acceptor thread each; requests funnel
//! through an mpsc channel into the single scheduler thread that owns the
//! engine (the same single-writer design vLLM's engine loop uses).

use super::request::Request;
use super::scheduler::Scheduler;
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// A request travelling from a connection thread to the engine loop.
struct Inflight {
    req: Request,
    reply: mpsc::Sender<Json>,
    submitted: Instant,
}

/// Serve forever (or until a `shutdown` command) on `addr`.
pub fn serve(mut sched: Scheduler, addr: &str) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    crate::log_info!("listening on {addr}");
    let (tx, rx) = mpsc::channel::<Inflight>();
    let shutdown = Arc::new(AtomicBool::new(false));
    let next_id = Arc::new(AtomicU64::new(0));

    let mut pending: Vec<(u64, mpsc::Sender<Json>, Instant)> = Vec::new();
    let t0 = Instant::now();
    loop {
        if shutdown.load(Ordering::Relaxed) && pending.is_empty() && sched.running() == 0 {
            crate::log_info!("shutdown complete");
            return Ok(());
        }
        // Accept new connections (non-blocking).
        match listener.accept() {
            Ok((stream, peer)) => {
                crate::log_info!("connection from {peer}");
                let tx = tx.clone();
                let shutdown = shutdown.clone();
                let next_id = next_id.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx, shutdown, next_id);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => return Err(e),
        }
        // Drain newly-submitted requests into the scheduler.
        while let Ok(inf) = rx.try_recv() {
            pending.push((inf.req.id, inf.reply, inf.submitted));
            sched.submit(inf.req);
        }
        // Drive the engine.
        let now = t0.elapsed().as_secs_f64();
        sched.step(now);
        // Reply to finished requests.
        let finished: Vec<(u64, Vec<u32>, f64, f64)> = sched
            .finished_requests()
            .iter()
            .filter(|r| pending.iter().any(|(id, _, _)| *id == r.id))
            .map(|r| {
                let ttft = r.first_token_at.unwrap_or(0.0) - r.arrival;
                let tpot = if r.output.len() > 1 {
                    (r.finished_at.unwrap_or(now) - r.first_token_at.unwrap_or(now))
                        / (r.output.len() - 1) as f64
                } else {
                    0.0
                };
                (r.id, r.output.clone(), ttft, tpot)
            })
            .collect();
        for (id, output, ttft, tpot) in finished {
            if let Some(pos) = pending.iter().position(|(pid, _, _)| *pid == id) {
                let (_, reply, _) = pending.remove(pos);
                let msg = json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("output", Json::Arr(output.iter().map(|&t| Json::Num(t as f64)).collect())),
                    ("ttft_s", Json::Num(ttft)),
                    ("tpot_s", Json::Num(tpot)),
                ]);
                let _ = reply.send(msg);
            }
        }
        if sched.running() == 0 && sched.pending() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<Inflight>,
    shutdown: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", json::obj(vec![("error", json::s(&e.to_string()))]).to_string())?;
                continue;
            }
        };
        if parsed.get_str("cmd") == Some("shutdown") {
            shutdown.store(true, Ordering::Relaxed);
            writeln!(writer, "{}", json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
            return Ok(());
        }
        let Some(prompt) = parsed.get("prompt").and_then(|p| p.as_arr()).map(|a| {
            a.iter().filter_map(|v| v.as_usize()).map(|v| v as u32).collect::<Vec<u32>>()
        }) else {
            writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("missing 'prompt'"))]).to_string()
            )?;
            continue;
        };
        if prompt.is_empty() {
            writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("empty prompt"))]).to_string()
            )?;
            continue;
        }
        let max_new = parsed.get_usize("max_new_tokens").unwrap_or(16);
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::new(id, prompt, max_new);
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(Inflight { req, reply: reply_tx, submitted: Instant::now() })
            .map_err(|_| std::io::Error::new(std::io::ErrorKind::BrokenPipe, "engine gone"))?;
        // Block this connection thread until the engine replies.
        match reply_rx.recv() {
            Ok(msg) => writeln!(writer, "{}", msg.to_string())?,
            Err(_) => {
                writeln!(
                    writer,
                    "{}",
                    json::obj(vec![("error", json::s("engine dropped request"))]).to_string()
                )?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::coordinator::SparseConfig;
    use crate::model::retrieval::build_retrieval_model;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_niah, RetrievalVocab};
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn end_to_end_over_tcp() {
        let v = RetrievalVocab::DEFAULT;
        let model = std::sync::Arc::new(build_retrieval_model(v, 8192));
        let engine = Engine::new(model, SparseConfig::twilight(SelectorKind::Quest, 0.9), 1 << 14);
        let sched = Scheduler::new(engine, SchedulerConfig::default());
        // Pick a free port by binding then immediately reusing.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(sched, &addr2));
        // Wait for the listener.
        let mut stream = None;
        for _ in 0..200 {
            match std::net::TcpStream::connect(&addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
            }
        }
        let stream = stream.expect("server did not come up");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut r = Rng::new(1);
        let g = gen_niah(&mut r, v, 128);
        let prompt_json: Vec<String> = g.prompt.iter().map(|t| t.to_string()).collect();
        writeln!(
            &stream,
            "{{\"prompt\": [{}], \"max_new_tokens\": 1}}",
            prompt_json.join(",")
        )
        .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        let out = resp.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out[0].as_usize(), Some(g.answer as usize));
        // Shutdown.
        writeln!(&stream, "{{\"cmd\": \"shutdown\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        h.join().unwrap().unwrap();
    }
}
