//! Line-delimited JSON TCP server — the `twilight serve` front end.
//!
//! Protocol (one JSON object per line):
//! ```text
//! → {"prompt": [1,2,3], "max_new_tokens": 4}
//! ← {"id": 0, "output": [17,3,3,9], "ttft_s": 0.01, "tpot_s": 0.002}
//! → {"cmd": "stats"}
//! ← {"pending": 0, "running": 1, "prune_ratio": ..., "governor": {...}}
//! → {"cmd": "slo", "tpot_ms": 25}
//! ← {"ok": true, "tpot_ms": 25}
//! → {"cmd": "metrics"}
//! ← # HELP twilight_steps_total …      (Prometheus text, ends "# EOF")
//! → {"cmd": "dump"}
//! ← {"records": [{"step": …, "step_s": …, "anomaly": "none"}, …]}
//! → {"cmd": "shutdown"}
//! ```
//!
//! Requests that die to a contained fault (lost KV page, quarantined
//! worker panic, non-finite logits — DESIGN.md §14) get a structured
//! error reply naming the reason instead of an output:
//! `{"id": 3, "error": "request failed", "reason": "page_lost", ...}`.
//! Rejected admissions are reported the same way. Latency fields are
//! only emitted when the request actually produced a first token.
//!
//! Request lines are capped at [`MAX_LINE_BYTES`]; an oversized line is
//! drained in constant memory, answered with a structured error, and
//! the connection stays up — a client bug can't OOM the server.
//!
//! `stats` reports live scheduler/engine counters plus governor state;
//! `slo` retunes the governor's TPOT target at runtime (fails with
//! `ok: false` when the scheduler is ungoverned).
//!
//! `metrics` replies with the global [`crate::obs::metrics`] registry in
//! Prometheus text format — a multi-line raw body (not line-JSON),
//! terminated by a `# EOF` line so a plain TCP scrape
//! (`echo '{"cmd":"metrics"}' | nc host port`) knows where it ends.
//! `dump` replies with one JSON line holding the
//! [`crate::obs::recorder`] flight-recorder ring (the last N step
//! summaries with timings, directives, and anomalies). Both read global
//! observability state, so they answer on the connection thread without
//! a round-trip through the engine loop.
//!
//! Connections are handled by an acceptor thread each; requests and
//! control commands funnel through an mpsc channel into the single
//! scheduler thread that owns the engine (the same single-writer design
//! vLLM's engine loop uses).

use super::request::{Request, RequestState};
use super::scheduler::Scheduler;
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// A request travelling from a connection thread to the engine loop.
struct Inflight {
    req: Request,
    reply: mpsc::Sender<Json>,
    submitted: Instant,
}

/// Anything a connection thread can ask of the engine loop.
enum ToEngine {
    Submit(Inflight),
    /// Reply with live scheduler/governor stats.
    Stats(mpsc::Sender<Json>),
    /// Set the governor's TPOT SLO (seconds).
    Slo(f64, mpsc::Sender<Json>),
}

/// Serve forever (or until a `shutdown` command) on `addr`.
pub fn serve(mut sched: Scheduler, addr: &str) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    crate::log_info!("listening on {addr}");
    let (tx, rx) = mpsc::channel::<ToEngine>();
    let shutdown = Arc::new(AtomicBool::new(false));
    let next_id = Arc::new(AtomicU64::new(0));

    let mut pending: Vec<(u64, mpsc::Sender<Json>, Instant)> = Vec::new();
    let t0 = Instant::now();
    loop {
        if shutdown.load(Ordering::Relaxed) && pending.is_empty() && sched.running() == 0 {
            crate::log_info!("shutdown complete");
            return Ok(());
        }
        // Accept new connections (non-blocking).
        match listener.accept() {
            Ok((stream, peer)) => {
                crate::log_info!("connection from {peer}");
                let tx = tx.clone();
                let shutdown = shutdown.clone();
                let next_id = next_id.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx, shutdown, next_id);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => return Err(e),
        }
        // Drain newly-submitted requests and control commands.
        while let Ok(msg) = rx.try_recv() {
            match msg {
                ToEngine::Submit(inf) => {
                    pending.push((inf.req.id, inf.reply, inf.submitted));
                    sched.submit(inf.req);
                }
                ToEngine::Stats(reply) => {
                    let _ = reply.send(sched.live_stats_json());
                }
                ToEngine::Slo(target_s, reply) => {
                    let ok = sched.set_slo_tpot(target_s);
                    let msg = if ok {
                        json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("tpot_ms", Json::Num(target_s * 1e3)),
                        ])
                    } else {
                        json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", json::s("scheduler has no governor")),
                        ])
                    };
                    let _ = reply.send(msg);
                }
            }
        }
        // Drive the engine.
        let now = t0.elapsed().as_secs_f64();
        sched.step(now);
        // Reply to finished requests: served ones with outputs and
        // latency, terminally-failed/rejected ones with a structured
        // error naming the contained fault.
        let finished: Vec<(u64, Json)> = sched
            .finished_requests()
            .iter()
            .filter(|r| pending.iter().any(|(id, _, _)| *id == r.id))
            .map(|r| (r.id, reply_json(r, now)))
            .collect();
        for (id, msg) in finished {
            if let Some(pos) = pending.iter().position(|(pid, _, _)| *pid == id) {
                let (_, reply, _) = pending.remove(pos);
                let _ = reply.send(msg);
            }
        }
        if sched.running() == 0 && sched.pending() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}

/// Build the per-request reply line for a finished request.
///
/// Latency fields are computed only from timestamps that actually exist:
/// `first_token_at` is `None` for requests that died before producing a
/// token (rejected, or failed during prefill), and the old
/// `unwrap_or(0.0) - arrival` fabricated a large negative TTFT for
/// them. Such requests now get an error reply with no latency fields.
fn reply_json(r: &Request, now: f64) -> Json {
    match r.state {
        RequestState::Failed { reason } => json::obj(vec![
            ("id", Json::Num(r.id as f64)),
            ("error", json::s("request failed")),
            ("reason", json::s(reason.label())),
            ("partial_tokens", Json::Num(r.output.len() as f64)),
        ]),
        RequestState::Rejected => json::obj(vec![
            ("id", Json::Num(r.id as f64)),
            ("error", json::s("request rejected")),
            ("reason", json::s("prompt cannot fit page pool")),
        ]),
        _ => {
            let mut kv = vec![
                ("id", Json::Num(r.id as f64)),
                (
                    "output",
                    Json::Arr(r.output.iter().map(|&t| Json::Num(t as f64)).collect()),
                ),
            ];
            if let Some(first) = r.first_token_at {
                kv.push(("ttft_s", Json::Num(first - r.arrival)));
                let tpot = if r.output.len() > 1 {
                    (r.finished_at.unwrap_or(now) - first) / (r.output.len() - 1) as f64
                } else {
                    0.0
                };
                kv.push(("tpot_s", Json::Num(tpot)));
            }
            json::obj(kv)
        }
    }
}

/// Hard cap on one request line. A line that would buffer more than
/// this is drained to its newline in constant memory and answered with
/// an error — an unbounded `read_line` would let one client OOM the
/// whole server with a newline-free stream.
const MAX_LINE_BYTES: usize = 1 << 20;

/// Read one `\n`-terminated line into `buf` (newline excluded),
/// buffering at most [`MAX_LINE_BYTES`]. Returns `Ok(None)` at clean
/// EOF, `Ok(Some(oversized))` otherwise; an oversized line leaves `buf`
/// empty. A partial final line (EOF before `\n`) is handed up like
/// `BufRead::lines` would.
fn read_bounded_line(
    reader: &mut BufReader<TcpStream>,
    buf: &mut Vec<u8>,
) -> std::io::Result<Option<bool>> {
    buf.clear();
    let mut oversized = false;
    loop {
        let (used, done) = {
            let chunk = reader.fill_buf()?;
            if chunk.is_empty() {
                if buf.is_empty() && !oversized {
                    return Ok(None);
                }
                (0, true)
            } else if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
                if !oversized && buf.len() + pos <= MAX_LINE_BYTES {
                    buf.extend_from_slice(&chunk[..pos]);
                } else {
                    oversized = true;
                }
                (pos + 1, true)
            } else {
                if !oversized && buf.len() + chunk.len() <= MAX_LINE_BYTES {
                    buf.extend_from_slice(chunk);
                } else {
                    oversized = true;
                }
                (chunk.len(), false)
            }
        };
        reader.consume(used);
        if done {
            if oversized {
                buf.clear();
            }
            return Ok(Some(oversized));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<ToEngine>,
    shutdown: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let mut reader = BufReader::new(stream);
    let mut buf: Vec<u8> = Vec::new();
    loop {
        let Some(oversized) = read_bounded_line(&mut reader, &mut buf)? else {
            return Ok(());
        };
        if oversized {
            writeln!(
                writer,
                "{}",
                json::obj(vec![(
                    "error",
                    json::s(&format!("request line exceeds {MAX_LINE_BYTES} bytes")),
                )])
                .to_string()
            )?;
            continue;
        }
        let line = String::from_utf8_lossy(&buf);
        if line.trim().is_empty() {
            continue;
        }
        let parsed = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", json::obj(vec![("error", json::s(&e.to_string()))]).to_string())?;
                continue;
            }
        };
        match parsed.get_str("cmd") {
            Some("shutdown") => {
                shutdown.store(true, Ordering::Relaxed);
                writeln!(writer, "{}", json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
                return Ok(());
            }
            Some("stats") => {
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(ToEngine::Stats(reply_tx)).map_err(engine_gone)?;
                let msg = reply_rx.recv().map_err(|_| engine_gone(()))?;
                writeln!(writer, "{}", msg.to_string())?;
                continue;
            }
            Some("slo") => {
                let Some(ms) = parsed.get_f64("tpot_ms").filter(|m| *m > 0.0) else {
                    writeln!(
                        writer,
                        "{}",
                        json::obj(vec![("error", json::s("slo needs positive 'tpot_ms'"))])
                            .to_string()
                    )?;
                    continue;
                };
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(ToEngine::Slo(ms / 1e3, reply_tx)).map_err(engine_gone)?;
                let msg = reply_rx.recv().map_err(|_| engine_gone(()))?;
                writeln!(writer, "{}", msg.to_string())?;
                continue;
            }
            Some("metrics") => {
                // Raw Prometheus text (already newline-terminated and
                // ending with "# EOF\n" — the scrape framing marker).
                writer.write_all(crate::obs::metrics::render_prometheus().as_bytes())?;
                writer.flush()?;
                continue;
            }
            Some("dump") => {
                writeln!(writer, "{}", crate::obs::recorder::to_json().to_string())?;
                continue;
            }
            Some(other) => {
                writeln!(
                    writer,
                    "{}",
                    json::obj(vec![("error", json::s(&format!("unknown cmd '{other}'")))])
                        .to_string()
                )?;
                continue;
            }
            None => {}
        }
        let Some(prompt) = parsed.get("prompt").and_then(|p| p.as_arr()).map(|a| {
            a.iter().filter_map(|v| v.as_usize()).map(|v| v as u32).collect::<Vec<u32>>()
        }) else {
            writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("missing 'prompt'"))]).to_string()
            )?;
            continue;
        };
        if prompt.is_empty() {
            writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("empty prompt"))]).to_string()
            )?;
            continue;
        }
        let max_new = parsed.get_usize("max_new_tokens").unwrap_or(16);
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::new(id, prompt, max_new);
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(ToEngine::Submit(Inflight { req, reply: reply_tx, submitted: Instant::now() }))
            .map_err(engine_gone)?;
        // Block this connection thread until the engine replies.
        match reply_rx.recv() {
            Ok(msg) => writeln!(writer, "{}", msg.to_string())?,
            Err(_) => {
                writeln!(
                    writer,
                    "{}",
                    json::obj(vec![("error", json::s("engine dropped request"))]).to_string()
                )?;
            }
        }
    }
}

fn engine_gone<T>(_: T) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::BrokenPipe, "engine gone")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::coordinator::SparseConfig;
    use crate::model::retrieval::build_retrieval_model;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_niah, RetrievalVocab};
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn reply_json_latency_and_fault_shapes() {
        use crate::coordinator::request::FailReason;
        let mut r = Request::new(5, vec![1, 2], 4);
        r.arrival = 10.0;
        // Never-started requests must not fabricate latency fields (the
        // old unwrap_or(0.0) yielded ttft_s = -arrival).
        r.state = RequestState::Finished;
        let j = reply_json(&r, 11.0);
        assert!(j.get("ttft_s").is_none(), "{}", j.to_string());
        assert!(j.get("output").is_some());
        // Served: latency present and sane.
        r.first_token_at = Some(10.5);
        r.finished_at = Some(11.0);
        r.output = vec![7, 8, 9];
        let j = reply_json(&r, 11.0);
        assert_eq!(j.get_f64("ttft_s"), Some(0.5));
        assert_eq!(j.get_f64("tpot_s"), Some(0.25));
        // Contained fault: structured error naming the reason, no output.
        r.state = RequestState::Failed { reason: FailReason::PageLost };
        let j = reply_json(&r, 11.0);
        assert_eq!(j.get_str("error"), Some("request failed"));
        assert_eq!(j.get_str("reason"), Some("page_lost"));
        assert_eq!(j.get_f64("partial_tokens"), Some(3.0));
        assert!(j.get("output").is_none());
    }

    #[test]
    fn end_to_end_over_tcp() {
        use crate::governor::{Governor, GovernorConfig};
        let v = RetrievalVocab::DEFAULT;
        let model = std::sync::Arc::new(build_retrieval_model(v, 8192));
        let engine = Engine::new(model, SparseConfig::twilight(SelectorKind::Quest, 0.9), 1 << 14);
        let mut sched = Scheduler::new(engine, SchedulerConfig::default());
        sched.attach_governor(Governor::new("aimd", GovernorConfig::default()).unwrap());
        // Pick a free port by binding then immediately reusing.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(sched, &addr2));
        // Wait for the listener.
        let mut stream = None;
        for _ in 0..200 {
            match std::net::TcpStream::connect(&addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
            }
        }
        let stream = stream.expect("server did not come up");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut r = Rng::new(1);
        let g = gen_niah(&mut r, v, 128);
        let prompt_json: Vec<String> = g.prompt.iter().map(|t| t.to_string()).collect();
        writeln!(
            &stream,
            "{{\"prompt\": [{}], \"max_new_tokens\": 1}}",
            prompt_json.join(",")
        )
        .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        let out = resp.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out[0].as_usize(), Some(g.answer as usize));
        // Live stats: counters plus governor state.
        writeln!(&stream, "{{\"cmd\": \"stats\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let stats = Json::parse(&line).unwrap();
        assert!(stats.get("steps").is_some(), "stats missing counters: {line}");
        assert_eq!(stats.get("governor").unwrap().get_str("policy"), Some("aimd"));
        // Runtime SLO retune.
        writeln!(&stream, "{{\"cmd\": \"slo\", \"tpot_ms\": 25}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let slo = Json::parse(&line).unwrap();
        assert_eq!(slo.get_bool("ok"), Some(true), "{line}");
        writeln!(&stream, "{{\"cmd\": \"slo\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("error").is_some());
        // Unknown commands are rejected, connection stays up.
        writeln!(&stream, "{{\"cmd\": \"nope\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("error").is_some());
        // Prometheus metrics scrape: multi-line text body ending "# EOF".
        writeln!(&stream, "{{\"cmd\": \"metrics\"}}").unwrap();
        let mut body = String::new();
        loop {
            line.clear();
            assert!(reader.read_line(&mut line).unwrap() > 0, "metrics body truncated");
            body.push_str(&line);
            if line.trim_end() == "# EOF" {
                break;
            }
        }
        assert!(
            body.lines().any(|l| l.starts_with("twilight_steps_total ")),
            "metrics scrape missing scheduler counters:\n{body}"
        );
        assert!(body.contains("# TYPE twilight_ttft_seconds histogram"), "{body}");
        // Flight-recorder dump: one JSON line with the step-record ring.
        writeln!(&stream, "{{\"cmd\": \"dump\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let dump = Json::parse(&line).unwrap();
        let records = dump.get("records").unwrap().as_arr().unwrap();
        assert!(!records.is_empty(), "served steps must leave flight records");
        assert!(records[0].get_f64("step_s").is_some());
        // Oversized request lines are refused in constant memory and the
        // connection survives to serve the next command.
        let big = vec![b'x'; MAX_LINE_BYTES + 16];
        (&stream).write_all(&big).unwrap();
        writeln!(&stream).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let err = Json::parse(&line).unwrap();
        assert!(err.get_str("error").unwrap().contains("exceeds"), "{line}");
        writeln!(&stream, "{{\"cmd\": \"stats\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("pending").is_some(), "{line}");
        // Shutdown.
        writeln!(&stream, "{{\"cmd\": \"shutdown\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        h.join().unwrap().unwrap();
    }
}
