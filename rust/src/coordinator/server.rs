//! Line-delimited JSON TCP server — the `twilight serve` front end.
//!
//! Protocol (one JSON object per line):
//! ```text
//! → {"prompt": [1,2,3], "max_new_tokens": 4}
//! ← {"id": 0, "output": [17,3,3,9], "ttft_s": 0.01, "tpot_s": 0.002}
//! → {"cmd": "stats"}
//! ← {"pending": 0, "running": 1, "prune_ratio": ..., "governor": {...}}
//! → {"cmd": "slo", "tpot_ms": 25}
//! ← {"ok": true, "tpot_ms": 25}
//! → {"cmd": "metrics"}
//! ← # HELP twilight_steps_total …      (Prometheus text, ends "# EOF")
//! → {"cmd": "dump"}
//! ← {"records": [{"step": …, "step_s": …, "anomaly": "none"}, …]}
//! → {"cmd": "shutdown"}
//! ```
//!
//! `stats` reports live scheduler/engine counters plus governor state;
//! `slo` retunes the governor's TPOT target at runtime (fails with
//! `ok: false` when the scheduler is ungoverned).
//!
//! `metrics` replies with the global [`crate::obs::metrics`] registry in
//! Prometheus text format — a multi-line raw body (not line-JSON),
//! terminated by a `# EOF` line so a plain TCP scrape
//! (`echo '{"cmd":"metrics"}' | nc host port`) knows where it ends.
//! `dump` replies with one JSON line holding the
//! [`crate::obs::recorder`] flight-recorder ring (the last N step
//! summaries with timings, directives, and anomalies). Both read global
//! observability state, so they answer on the connection thread without
//! a round-trip through the engine loop.
//!
//! Connections are handled by an acceptor thread each; requests and
//! control commands funnel through an mpsc channel into the single
//! scheduler thread that owns the engine (the same single-writer design
//! vLLM's engine loop uses).

use super::request::Request;
use super::scheduler::Scheduler;
use crate::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// A request travelling from a connection thread to the engine loop.
struct Inflight {
    req: Request,
    reply: mpsc::Sender<Json>,
    submitted: Instant,
}

/// Anything a connection thread can ask of the engine loop.
enum ToEngine {
    Submit(Inflight),
    /// Reply with live scheduler/governor stats.
    Stats(mpsc::Sender<Json>),
    /// Set the governor's TPOT SLO (seconds).
    Slo(f64, mpsc::Sender<Json>),
}

/// Serve forever (or until a `shutdown` command) on `addr`.
pub fn serve(mut sched: Scheduler, addr: &str) -> std::io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    crate::log_info!("listening on {addr}");
    let (tx, rx) = mpsc::channel::<ToEngine>();
    let shutdown = Arc::new(AtomicBool::new(false));
    let next_id = Arc::new(AtomicU64::new(0));

    let mut pending: Vec<(u64, mpsc::Sender<Json>, Instant)> = Vec::new();
    let t0 = Instant::now();
    loop {
        if shutdown.load(Ordering::Relaxed) && pending.is_empty() && sched.running() == 0 {
            crate::log_info!("shutdown complete");
            return Ok(());
        }
        // Accept new connections (non-blocking).
        match listener.accept() {
            Ok((stream, peer)) => {
                crate::log_info!("connection from {peer}");
                let tx = tx.clone();
                let shutdown = shutdown.clone();
                let next_id = next_id.clone();
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, tx, shutdown, next_id);
                });
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {}
            Err(e) => return Err(e),
        }
        // Drain newly-submitted requests and control commands.
        while let Ok(msg) = rx.try_recv() {
            match msg {
                ToEngine::Submit(inf) => {
                    pending.push((inf.req.id, inf.reply, inf.submitted));
                    sched.submit(inf.req);
                }
                ToEngine::Stats(reply) => {
                    let _ = reply.send(sched.live_stats_json());
                }
                ToEngine::Slo(target_s, reply) => {
                    let ok = sched.set_slo_tpot(target_s);
                    let msg = if ok {
                        json::obj(vec![
                            ("ok", Json::Bool(true)),
                            ("tpot_ms", Json::Num(target_s * 1e3)),
                        ])
                    } else {
                        json::obj(vec![
                            ("ok", Json::Bool(false)),
                            ("error", json::s("scheduler has no governor")),
                        ])
                    };
                    let _ = reply.send(msg);
                }
            }
        }
        // Drive the engine.
        let now = t0.elapsed().as_secs_f64();
        sched.step(now);
        // Reply to finished requests.
        let finished: Vec<(u64, Vec<u32>, f64, f64)> = sched
            .finished_requests()
            .iter()
            .filter(|r| pending.iter().any(|(id, _, _)| *id == r.id))
            .map(|r| {
                let ttft = r.first_token_at.unwrap_or(0.0) - r.arrival;
                let tpot = if r.output.len() > 1 {
                    (r.finished_at.unwrap_or(now) - r.first_token_at.unwrap_or(now))
                        / (r.output.len() - 1) as f64
                } else {
                    0.0
                };
                (r.id, r.output.clone(), ttft, tpot)
            })
            .collect();
        for (id, output, ttft, tpot) in finished {
            if let Some(pos) = pending.iter().position(|(pid, _, _)| *pid == id) {
                let (_, reply, _) = pending.remove(pos);
                let msg = json::obj(vec![
                    ("id", Json::Num(id as f64)),
                    ("output", Json::Arr(output.iter().map(|&t| Json::Num(t as f64)).collect())),
                    ("ttft_s", Json::Num(ttft)),
                    ("tpot_s", Json::Num(tpot)),
                ]);
                let _ = reply.send(msg);
            }
        }
        if sched.running() == 0 && sched.pending() == 0 {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
    }
}

fn handle_conn(
    stream: TcpStream,
    tx: mpsc::Sender<ToEngine>,
    shutdown: Arc<AtomicBool>,
    next_id: Arc<AtomicU64>,
) -> std::io::Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let parsed = match Json::parse(&line) {
            Ok(j) => j,
            Err(e) => {
                writeln!(writer, "{}", json::obj(vec![("error", json::s(&e.to_string()))]).to_string())?;
                continue;
            }
        };
        match parsed.get_str("cmd") {
            Some("shutdown") => {
                shutdown.store(true, Ordering::Relaxed);
                writeln!(writer, "{}", json::obj(vec![("ok", Json::Bool(true))]).to_string())?;
                return Ok(());
            }
            Some("stats") => {
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(ToEngine::Stats(reply_tx)).map_err(engine_gone)?;
                let msg = reply_rx.recv().map_err(|_| engine_gone(()))?;
                writeln!(writer, "{}", msg.to_string())?;
                continue;
            }
            Some("slo") => {
                let Some(ms) = parsed.get_f64("tpot_ms").filter(|m| *m > 0.0) else {
                    writeln!(
                        writer,
                        "{}",
                        json::obj(vec![("error", json::s("slo needs positive 'tpot_ms'"))])
                            .to_string()
                    )?;
                    continue;
                };
                let (reply_tx, reply_rx) = mpsc::channel();
                tx.send(ToEngine::Slo(ms / 1e3, reply_tx)).map_err(engine_gone)?;
                let msg = reply_rx.recv().map_err(|_| engine_gone(()))?;
                writeln!(writer, "{}", msg.to_string())?;
                continue;
            }
            Some("metrics") => {
                // Raw Prometheus text (already newline-terminated and
                // ending with "# EOF\n" — the scrape framing marker).
                writer.write_all(crate::obs::metrics::render_prometheus().as_bytes())?;
                writer.flush()?;
                continue;
            }
            Some("dump") => {
                writeln!(writer, "{}", crate::obs::recorder::to_json().to_string())?;
                continue;
            }
            Some(other) => {
                writeln!(
                    writer,
                    "{}",
                    json::obj(vec![("error", json::s(&format!("unknown cmd '{other}'")))])
                        .to_string()
                )?;
                continue;
            }
            None => {}
        }
        let Some(prompt) = parsed.get("prompt").and_then(|p| p.as_arr()).map(|a| {
            a.iter().filter_map(|v| v.as_usize()).map(|v| v as u32).collect::<Vec<u32>>()
        }) else {
            writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("missing 'prompt'"))]).to_string()
            )?;
            continue;
        };
        if prompt.is_empty() {
            writeln!(
                writer,
                "{}",
                json::obj(vec![("error", json::s("empty prompt"))]).to_string()
            )?;
            continue;
        }
        let max_new = parsed.get_usize("max_new_tokens").unwrap_or(16);
        let id = next_id.fetch_add(1, Ordering::Relaxed);
        let req = Request::new(id, prompt, max_new);
        let (reply_tx, reply_rx) = mpsc::channel();
        tx.send(ToEngine::Submit(Inflight { req, reply: reply_tx, submitted: Instant::now() }))
            .map_err(engine_gone)?;
        // Block this connection thread until the engine replies.
        match reply_rx.recv() {
            Ok(msg) => writeln!(writer, "{}", msg.to_string())?,
            Err(_) => {
                writeln!(
                    writer,
                    "{}",
                    json::obj(vec![("error", json::s("engine dropped request"))]).to_string()
                )?;
            }
        }
    }
    Ok(())
}

fn engine_gone<T>(_: T) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::BrokenPipe, "engine gone")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::Engine;
    use crate::coordinator::scheduler::SchedulerConfig;
    use crate::coordinator::SparseConfig;
    use crate::model::retrieval::build_retrieval_model;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_niah, RetrievalVocab};
    use std::io::{BufRead, BufReader, Write};

    #[test]
    fn end_to_end_over_tcp() {
        use crate::governor::{Governor, GovernorConfig};
        let v = RetrievalVocab::DEFAULT;
        let model = std::sync::Arc::new(build_retrieval_model(v, 8192));
        let engine = Engine::new(model, SparseConfig::twilight(SelectorKind::Quest, 0.9), 1 << 14);
        let mut sched = Scheduler::new(engine, SchedulerConfig::default());
        sched.attach_governor(Governor::new("aimd", GovernorConfig::default()).unwrap());
        // Pick a free port by binding then immediately reusing.
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap().to_string();
        drop(probe);
        let addr2 = addr.clone();
        let h = std::thread::spawn(move || serve(sched, &addr2));
        // Wait for the listener.
        let mut stream = None;
        for _ in 0..200 {
            match std::net::TcpStream::connect(&addr) {
                Ok(s) => {
                    stream = Some(s);
                    break;
                }
                Err(_) => std::thread::sleep(std::time::Duration::from_millis(10)),
            }
        }
        let stream = stream.expect("server did not come up");
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut r = Rng::new(1);
        let g = gen_niah(&mut r, v, 128);
        let prompt_json: Vec<String> = g.prompt.iter().map(|t| t.to_string()).collect();
        writeln!(
            &stream,
            "{{\"prompt\": [{}], \"max_new_tokens\": 1}}",
            prompt_json.join(",")
        )
        .unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(&line).unwrap();
        let out = resp.get("output").unwrap().as_arr().unwrap();
        assert_eq!(out[0].as_usize(), Some(g.answer as usize));
        // Live stats: counters plus governor state.
        writeln!(&stream, "{{\"cmd\": \"stats\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let stats = Json::parse(&line).unwrap();
        assert!(stats.get("steps").is_some(), "stats missing counters: {line}");
        assert_eq!(stats.get("governor").unwrap().get_str("policy"), Some("aimd"));
        // Runtime SLO retune.
        writeln!(&stream, "{{\"cmd\": \"slo\", \"tpot_ms\": 25}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let slo = Json::parse(&line).unwrap();
        assert_eq!(slo.get_bool("ok"), Some(true), "{line}");
        writeln!(&stream, "{{\"cmd\": \"slo\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("error").is_some());
        // Unknown commands are rejected, connection stays up.
        writeln!(&stream, "{{\"cmd\": \"nope\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert!(Json::parse(&line).unwrap().get("error").is_some());
        // Prometheus metrics scrape: multi-line text body ending "# EOF".
        writeln!(&stream, "{{\"cmd\": \"metrics\"}}").unwrap();
        let mut body = String::new();
        loop {
            line.clear();
            assert!(reader.read_line(&mut line).unwrap() > 0, "metrics body truncated");
            body.push_str(&line);
            if line.trim_end() == "# EOF" {
                break;
            }
        }
        assert!(
            body.lines().any(|l| l.starts_with("twilight_steps_total ")),
            "metrics scrape missing scheduler counters:\n{body}"
        );
        assert!(body.contains("# TYPE twilight_ttft_seconds histogram"), "{body}");
        // Flight-recorder dump: one JSON line with the step-record ring.
        writeln!(&stream, "{{\"cmd\": \"dump\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        let dump = Json::parse(&line).unwrap();
        let records = dump.get("records").unwrap().as_arr().unwrap();
        assert!(!records.is_empty(), "served steps must leave flight records");
        assert!(records[0].get_f64("step_s").is_some());
        // Shutdown.
        writeln!(&stream, "{{\"cmd\": \"shutdown\"}}").unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        h.join().unwrap().unwrap();
    }
}
