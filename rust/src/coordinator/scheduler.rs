//! Continuous-batching scheduler (vLLM-style) over the decode [`Engine`].
//!
//! Each scheduler *step* interleaves: (1) admitting arrived requests when
//! the page pool has headroom (prefill), (2) **one batched decode step**
//! ([`Engine::step_batch`]) advancing every running request a token —
//! the engine flattens the batch into LPT-balanced (sequence × kv-head)
//! attention work items drained by its persistent worker pool (resident
//! across every scheduler step) — and (3) preemption of the youngest request
//! when the pool runs dry (its pages are released; it re-prefills later —
//! recompute-style preemption, the same policy vLLM defaults to). Only
//! the decode phase feeds the governor's latency tracker, so step time ≙
//! TPOT genuinely holds for the batch (prefill is accounted separately).
//!
//! Time is virtual when replaying a trace (`now` advances with the
//! wall-clock of actual compute), so arrival patterns interact with
//! compute latency exactly as in a live server.

use super::engine::{DecodeBatch, Engine};
use super::metrics::{RequestMetrics, ServingReport};
use super::request::{Request, RequestState};
use crate::governor::Governor;
use crate::model::sampler::sample;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::time::Instant;

/// Scheduler limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently-decoding requests.
    pub max_batch: usize,
    /// Keep at least this many pages free before admitting a request
    /// (headroom for running decodes).
    pub admit_headroom_pages: usize,
    /// Max prefills per scheduler step (bounds head-of-line blocking).
    pub max_prefills_per_step: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig { max_batch: 64, admit_headroom_pages: 8, max_prefills_per_step: 4 }
    }
}

/// The coordinator's scheduler: admission queue + running set.
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    pub engine: Engine,
    queue: VecDeque<Request>,
    running: Vec<Request>,
    rng: Rng,
    finished: Vec<Request>,
    /// Optional budget governor; when present it decides a
    /// [`crate::governor::BudgetDirective`] at the top of every step.
    governor: Option<Governor>,
}

impl Scheduler {
    pub fn new(engine: Engine, cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            engine,
            queue: VecDeque::new(),
            running: Vec::new(),
            rng: Rng::new(0xBA7C4),
            finished: Vec::new(),
            governor: None,
        }
    }

    /// Attach a governor (replaces any previous one).
    pub fn attach_governor(&mut self, g: Governor) {
        self.governor = Some(g);
    }

    pub fn governor(&self) -> Option<&Governor> {
        self.governor.as_ref()
    }

    /// Update the governor's TPOT SLO; false when ungoverned.
    pub fn set_slo_tpot(&mut self, target_tpot_s: f64) -> bool {
        match self.governor.as_mut() {
            Some(g) => {
                g.set_slo_tpot(target_tpot_s);
                true
            }
            None => false,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Pages a prompt will need across all layers.
    fn pages_needed(&self, prompt_len: usize) -> usize {
        let layers = self.engine.model.cfg.n_layers;
        prompt_len.div_ceil(16) * layers
    }

    /// One scheduler iteration at virtual time `now`. Returns the number
    /// of output tokens produced.
    pub fn step(&mut self, now: f64) -> usize {
        // --- governor -------------------------------------------------
        // Decide before admitting: the directive shapes both this step's
        // decode work and (via the degrade level) admission below.
        if let Some(gov) = self.governor.as_mut() {
            let total = self.engine.total_pages();
            let free_frac = if total == 0 {
                1.0
            } else {
                self.engine.free_pages() as f64 / total as f64
            };
            let snap = gov.snapshot(
                now,
                &self.engine.signals,
                free_frac,
                self.queue.len(),
                self.running.len(),
                self.engine.stats.steps,
            );
            let d = gov.step(&snap);
            self.engine.apply_directive(d);
        }
        let degrade = self.engine.directive().degrade_level;
        // --- admission ------------------------------------------------
        // Staged degradation: widen the required headroom as pressure
        // mounts, and freeze admission entirely at level 3 unless the
        // engine is idle (nothing running can ever deadlock admission).
        let admit_headroom = self.cfg.admit_headroom_pages * (1 + degrade as usize);
        let max_prefills = if degrade >= 3 && !self.running.is_empty() {
            0
        } else {
            self.cfg.max_prefills_per_step
        };
        let mut prefills = 0;
        while prefills < max_prefills && self.running.len() < self.cfg.max_batch {
            let Some(front) = self.queue.front() else { break };
            if front.arrival > now {
                break;
            }
            let need = self.pages_needed(front.prompt.len()) / self.engine.model.cfg.n_layers
                + admit_headroom;
            if self.engine.free_pages() < need {
                break;
            }
            let mut req = self.queue.pop_front().unwrap();
            req.state = RequestState::Prefilling;
            match self.engine.prefill(req.id, &req.prompt) {
                Ok(logits) => {
                    let tok = sample(&logits, &req.params, &mut self.rng);
                    req.output.push(tok);
                    req.first_token_at = req.first_token_at.or(Some(now));
                    req.state = RequestState::Decoding;
                    if req.is_done() {
                        self.engine.release(req.id);
                        self.finish(req, now);
                    } else {
                        self.running.push(req);
                    }
                    prefills += 1;
                }
                Err(_) => {
                    // Not enough pages after all: back to the queue head.
                    req.state = RequestState::Queued;
                    self.queue.push_front(req);
                    break;
                }
            }
        }
        // --- decode ----------------------------------------------------
        // Preempt (youngest-first) until the batch's page demand fits:
        // every sequence on a page boundary needs one fresh page in each
        // layer pool, and `free_pages` is the min across pools.
        while !self.running.is_empty() {
            let boundary = self.running.iter().filter(|r| self.engine.needs_page(r.id)).count();
            if boundary <= self.engine.free_pages() {
                break;
            }
            let victim = self.running.pop().unwrap();
            self.engine.release(victim.id);
            self.requeue_preempted(victim);
        }
        // One batched decode step advances the whole running set: the
        // engine flattens it into LPT-balanced (seq × kv-head) items.
        let mut produced = 0;
        let decode_start = Instant::now();
        if !self.running.is_empty() {
            let batch = DecodeBatch::new(
                self.running.iter().map(|r| (r.id, *r.output.last().unwrap())).collect(),
            );
            let results = self.engine.step_batch(&batch);
            let mut kept = Vec::with_capacity(self.running.len());
            let mut victims = Vec::new();
            for (mut req, res) in self.running.drain(..).zip(results) {
                match res {
                    Ok(logits) => {
                        let tok = sample(&logits, &req.params, &mut self.rng);
                        req.output.push(tok);
                        produced += 1;
                        kept.push(req);
                    }
                    // OOM mid-step (engine released the sequence):
                    // recompute-preempt this request.
                    Err(_) => victims.push(req),
                }
            }
            self.running = kept;
            for victim in victims {
                self.requeue_preempted(victim);
            }
        }
        let decode_secs = decode_start.elapsed().as_secs_f64();
        // --- completion --------------------------------------------------
        let mut j = 0;
        while j < self.running.len() {
            if self.running[j].is_done() {
                let req = self.running.remove(j);
                self.engine.release(req.id);
                self.finish(req, now);
            } else {
                j += 1;
            }
        }
        if let Some(gov) = self.governor.as_mut() {
            // Decode-phase wall time only: under continuous batching the
            // batched step duration *is* TPOT; admission/prefill work
            // must not skew the SLO tracker.
            gov.observe_step(decode_secs, produced);
        }
        produced
    }

    /// Recompute-style preemption: fold the generated tokens back into
    /// the prompt and push the request to the queue head (its pages must
    /// already be released).
    fn requeue_preempted(&mut self, mut req: Request) {
        req.state = RequestState::Preempted;
        req.preemptions += 1;
        req.prompt.extend_from_slice(&req.output);
        req.output.clear();
        req.first_token_at = None;
        self.queue.push_front(req);
    }

    fn finish(&mut self, mut req: Request, now: f64) {
        req.state = RequestState::Finished;
        req.finished_at = Some(now);
        self.finished.push(req);
    }

    /// Drive the scheduler until all submitted requests finish; returns
    /// the serving report. Virtual time = accumulated wall-clock compute.
    pub fn run_to_completion(&mut self) -> ServingReport {
        let t0 = Instant::now();
        let mut guard = 0u64;
        while !self.queue.is_empty() || !self.running.is_empty() {
            let now = t0.elapsed().as_secs_f64();
            self.step(now);
            guard += 1;
            assert!(guard < 10_000_000, "scheduler livelock");
        }
        let duration = t0.elapsed().as_secs_f64();
        let requests = self
            .finished
            .iter()
            .map(|r| RequestMetrics {
                id: r.id,
                prompt_len: r.prompt.len(),
                output_len: r.output.len(),
                arrival: r.arrival,
                first_token_at: r.first_token_at.unwrap_or(r.arrival),
                finished_at: r.finished_at.unwrap_or(duration),
                preemptions: r.preemptions,
            })
            .collect();
        let governor = self.governor.as_mut().map(|g| g.take_trace()).unwrap_or_default();
        ServingReport { requests, duration, governor }
    }

    /// Finished requests (for output inspection).
    pub fn finished_requests(&self) -> &[Request] {
        &self.finished
    }

    /// Live state for the server's `stats` command (the run is still in
    /// flight, so this reports counters rather than a final report).
    pub fn live_stats_json(&self) -> Json {
        let s = &self.engine.stats;
        let mut kv: Vec<(&str, Json)> = vec![
            ("pending", Json::Num(self.queue.len() as f64)),
            ("running", Json::Num(self.running.len() as f64)),
            ("finished", Json::Num(self.finished.len() as f64)),
            ("threads", Json::Num(self.engine.threads() as f64)),
            ("steps", Json::Num(s.steps as f64)),
            ("prefill_steps", Json::Num(s.prefill_steps as f64)),
            ("avg_candidates", Json::Num(s.avg_candidates())),
            ("avg_kept", Json::Num(s.avg_kept())),
            ("prune_ratio", Json::Num(s.prune_ratio())),
            ("free_pages", Json::Num(self.engine.free_pages() as f64)),
            ("total_pages", Json::Num(self.engine.total_pages() as f64)),
            ("mean_mass", Json::Num(self.engine.signals.mean_mass())),
            ("probe_recall", Json::Num(self.engine.signals.probe_recall())),
        ];
        if let Some(g) = &self.governor {
            kv.push(("governor", g.state_json()));
        }
        json::obj(kv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SparseConfig;
    use crate::model::retrieval::build_retrieval_model;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_niah, RetrievalVocab};
    use std::sync::Arc;

    const V: RetrievalVocab = RetrievalVocab::DEFAULT;

    fn sched(capacity: usize, cfg: SparseConfig) -> Scheduler {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let engine = Engine::new(model, cfg, capacity);
        Scheduler::new(engine, SchedulerConfig::default())
    }

    #[test]
    fn completes_batch_and_answers() {
        let mut s = sched(1 << 16, SparseConfig::twilight(SelectorKind::Quest, 0.9));
        let mut r = Rng::new(1);
        let mut answers = Vec::new();
        for i in 0..6 {
            let g = gen_niah(&mut r, V, 256);
            let req = Request::new(i, g.prompt.clone(), 1);
            answers.push(g.answer);
            s.submit(req);
        }
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 6);
        let mut correct = 0;
        for (req, want) in s.finished_requests().iter().zip(&answers) {
            if req.output.first() == Some(want) {
                correct += 1;
            }
        }
        assert!(correct >= 5, "{correct}/6");
        // All pages returned.
        assert_eq!(s.engine.num_seqs(), 0);
    }

    #[test]
    fn respects_max_batch() {
        let mut s = sched(1 << 16, SparseConfig::dense());
        s.cfg.max_batch = 2;
        let mut r = Rng::new(2);
        for i in 0..5 {
            let g = gen_niah(&mut r, V, 64);
            let mut req = Request::new(i, g.prompt, 8);
            req.stop_token = None;
            s.submit(req);
        }
        s.step(0.0);
        assert!(s.running() <= 2);
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 5);
    }

    #[test]
    fn preempts_under_memory_pressure_and_recovers() {
        // Pool sized so 3 long decodes cannot coexist.
        let mut s = sched(700, SparseConfig::dense());
        s.cfg.admit_headroom_pages = 0;
        let mut r = Rng::new(3);
        for i in 0..3 {
            let g = gen_niah(&mut r, V, 192);
            s.submit(Request::new(i, g.prompt, 64));
        }
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 3);
        let total_preempt: u32 = rep.requests.iter().map(|r| r.preemptions).sum();
        assert!(total_preempt > 0, "expected at least one preemption");
        assert_eq!(s.engine.num_seqs(), 0);
    }

    #[test]
    fn governed_scheduler_traces_and_completes() {
        use crate::governor::slo::SloConfig;
        use crate::governor::{BudgetDirective, Governor, GovernorConfig};
        let mut s = sched(1 << 16, SparseConfig::twilight(SelectorKind::Quest, 0.9));
        // An unattainably tight SLO forces the AIMD policy to tighten.
        let cfg = GovernorConfig {
            slo: SloConfig { target_tpot_s: 1e-9, margin: 0.2 },
            ..Default::default()
        };
        s.attach_governor(Governor::new("aimd", cfg).unwrap());
        let mut r = Rng::new(17);
        for i in 0..4 {
            let g = gen_niah(&mut r, V, 256);
            s.submit(Request::new(i, g.prompt, 8));
        }
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 4);
        assert!(!rep.governor.is_empty(), "governed run must record a trace");
        assert!(
            rep.governor.last().unwrap().budget_scale < 1.0,
            "unattainable SLO must tighten the budget"
        );
        for e in &rep.governor {
            assert!(
                e.p_scale >= BudgetDirective::P_SCALE_RANGE.0
                    && e.p_scale <= BudgetDirective::P_SCALE_RANGE.1,
                "p_scale {} outside safe range",
                e.p_scale
            );
            assert!(
                e.budget_scale >= BudgetDirective::BUDGET_SCALE_RANGE.0
                    && e.budget_scale <= BudgetDirective::BUDGET_SCALE_RANGE.1
            );
        }
        assert_eq!(s.engine.num_seqs(), 0);
        let j = s.live_stats_json();
        assert!(j.get("governor").is_some());
    }

    #[test]
    fn concurrent_requests_progress_through_step_batch() {
        // Every running request must gain exactly one token per scheduler
        // step (the batched decode advances the whole set at once).
        let mut s = sched(1 << 16, SparseConfig::twilight(SelectorKind::Quest, 0.9));
        let mut r = Rng::new(9);
        for i in 0..4 {
            let g = gen_niah(&mut r, V, 128);
            let mut req = Request::new(i, g.prompt, 6);
            req.stop_token = None;
            s.submit(req);
        }
        // Step 1 admits (prefill samples one token each) and decodes the
        // admitted set once.
        let produced = s.step(0.0);
        let running = s.running();
        assert!(running >= 2, "expected concurrent decodes, got {running}");
        assert_eq!(produced, running, "each running request gains one token per step");
        let decode_steps_before = s.engine.stats.steps;
        let produced2 = s.step(0.0);
        assert_eq!(produced2, s.running());
        // One batched engine step per scheduler step, regardless of batch size.
        assert_eq!(s.engine.stats.steps, decode_steps_before + 1);
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 4);
        assert_eq!(s.engine.num_seqs(), 0);
    }

    #[test]
    fn arrivals_respected() {
        let mut s = sched(1 << 14, SparseConfig::dense());
        let mut r = Rng::new(4);
        let g = gen_niah(&mut r, V, 64);
        let mut req = Request::new(0, g.prompt, 1);
        req.arrival = 1e9; // far future
        s.submit(req);
        assert_eq!(s.step(0.0), 0);
        assert_eq!(s.pending(), 1);
        s.step(2e9);
        assert_eq!(s.pending(), 0);
    }
}
