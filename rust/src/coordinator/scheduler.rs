//! Continuous-batching scheduler (vLLM-style continuous batching +
//! Sarathi-style chunked-prefill co-scheduling) over the [`Engine`].
//!
//! Each scheduler *step* builds **one mixed engine step**: every running
//! request contributes a decode item, and every request in the
//! `Prefilling` state contributes the next chunk of its prompt (at most
//! [`Engine::prefill_chunk`] tokens, shrunk by the governor's pressure
//! ladder, all chunks together capped by
//! [`SchedulerConfig::max_prefill_tokens_per_step`]). The engine
//! flattens the batch into LPT-balanced (item × kv-head) attention work
//! items drained by its persistent worker pool, so prompt processing
//! rides the same parallel machinery as decode — TTFT scales with
//! workers instead of serializing behind one token-at-a-time loop, and a
//! long admission can no longer head-of-line-block the running set for
//! its whole prompt.
//!
//! Admission is prompt-size aware: a prompt the pool can *never* hold is
//! rejected up front (counted in [`ServingReport`]); one that merely
//! does not fit *now* stays parked in the queue. Under memory pressure
//! the scheduler first defers/trims prefill chunks (they are behind the
//! decode items in the batch, so the engine's page allocator also favors
//! decodes within the step), then recompute-preempts the youngest
//! running request (pages released; it re-prefills later — the policy
//! vLLM defaults to). Only the decode share of the mixed step's
//! wall-clock ([`Engine::last_step_timing`]) feeds the governor's
//! latency tracker, so step time ≙ TPOT genuinely holds for the batch.
//!
//! Time is virtual when replaying a trace (`now` advances with the
//! wall-clock of actual compute), so arrival patterns interact with
//! compute latency exactly as in a live server.

use super::engine::{DecodeBatch, Engine};
use super::metrics::{RequestMetrics, ServingReport};
use super::request::{FailReason, Request, RequestState};
use crate::governor::Governor;
use crate::kvcache::CacheError;
use crate::model::sampler::sample;
use crate::obs::metrics::{counter, gauge, histogram, Counter, Gauge, LogHist};
use crate::obs::recorder::{self, Anomaly, StepRecord};
use crate::util::json::{self, Json};
use crate::util::logging;
use crate::util::rng::Rng;
use std::collections::VecDeque;
use std::time::Instant;

/// Scheduler limits.
#[derive(Clone, Copy, Debug)]
pub struct SchedulerConfig {
    /// Max concurrently-active requests (decoding + prefilling).
    pub max_batch: usize,
    /// Keep at least this many pages free before admitting a request
    /// (headroom for running decodes).
    pub admit_headroom_pages: usize,
    /// Max new admissions per scheduler step (bounds queue-pop work; the
    /// token budget below bounds the actual prefill compute).
    pub max_prefills_per_step: usize,
    /// Per-step prompt-token budget shared by all prefill chunks of a
    /// mixed step (Sarathi-style): bounds how much a wave of admissions
    /// can stall the co-scheduled decodes, i.e. bounds TPOT inflation.
    pub max_prefill_tokens_per_step: usize,
    /// Emit one obs snapshot log line (queue depth, TPOT EMA, kept
    /// budget, utilization fields) every this many scheduler steps
    /// (0 = off; `--snapshot-every` on the CLI).
    pub snapshot_every_steps: usize,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            max_batch: 64,
            admit_headroom_pages: 8,
            max_prefills_per_step: 4,
            max_prefill_tokens_per_step: 512,
            snapshot_every_steps: 0,
        }
    }
}

/// A request whose prompt is partway through chunked prefill.
struct PrefillEntry {
    req: Request,
    /// Prompt tokens already appended to the engine.
    cursor: usize,
}

/// The scheduler's observability state: pre-resolved `'static` metric
/// handles (so the per-step path never touches the registry lock) plus
/// the previous-step counter values the deltas are computed from.
struct SchedObs {
    steps: &'static Counter,
    tokens: &'static Counter,
    prefill_tokens: &'static Counter,
    preempt: &'static Counter,
    reject: &'static Counter,
    failed: &'static Counter,
    queue_depth: &'static Gauge,
    running: &'static Gauge,
    prefilling: &'static Gauge,
    free_pages: &'static Gauge,
    hier_skip: &'static Gauge,
    sprefill_skip: &'static Gauge,
    probe_recall: &'static Gauge,
    p_scale: &'static Gauge,
    budget_scale: &'static Gauge,
    ttft: &'static LogHist,
    tpot: &'static LogHist,
    step_seconds: &'static LogHist,
    kept_budget: &'static LogHist,
    topp_mass: &'static LogHist,
    /// Scheduler steps observed (drives the snapshot-line cadence; not
    /// the engine's `stats.steps`, which skips chunk-only steps).
    sched_steps: u64,
    /// Previous-step engine counter values (delta baselines).
    last_kept: u64,
    last_candidates: u64,
    last_sparse_calls: u64,
    last_prefill_tokens: u64,
    /// Cumulative local event counts (bumped by `requeue_preempted` /
    /// `reject`) and their previous-step baselines.
    preempt_events: u64,
    reject_events: u64,
    failed_events: u64,
    last_preempt: u64,
    last_reject: u64,
    last_failed: u64,
    /// SLO-breach edge detector: the flight recorder dumps once per
    /// entry into breach, not every breached step.
    in_breach: bool,
}

impl SchedObs {
    fn new() -> SchedObs {
        SchedObs {
            steps: counter("twilight_steps_total", "scheduler steps executed"),
            tokens: counter("twilight_tokens_generated_total", "decode tokens sampled"),
            prefill_tokens: counter(
                "twilight_prefill_tokens_total",
                "prompt tokens pushed through prefill chunks",
            ),
            preempt: counter("twilight_preemptions_total", "recompute preemptions"),
            reject: counter("twilight_rejected_total", "admissions terminally refused"),
            failed: counter(
                "twilight_failed_total",
                "requests terminally failed by contained faults (lost pages, \
                 quarantined panics, non-finite logits)",
            ),
            queue_depth: gauge("twilight_queue_depth", "requests waiting for admission"),
            running: gauge("twilight_running", "requests in the decode set"),
            prefilling: gauge("twilight_prefilling", "requests partway through chunked prefill"),
            free_pages: gauge("twilight_free_pages", "min free pages across layer pools"),
            hier_skip: gauge(
                "twilight_hier_skip_frac",
                "fraction of candidate pages skipped by the hier pre-prune",
            ),
            sprefill_skip: gauge(
                "twilight_prefill_block_skip_frac",
                "fraction of gated pages skipped by bound-guided sparse prefill",
            ),
            probe_recall: gauge("twilight_probe_recall", "dense recall-probe EMA"),
            p_scale: gauge("twilight_p_scale", "governor top-p multiplier in force"),
            budget_scale: gauge("twilight_budget_scale", "governor stage-1 budget multiplier"),
            ttft: histogram("twilight_ttft_seconds", "time to first token per request"),
            tpot: histogram("twilight_tpot_seconds", "time per output token per request"),
            step_seconds: histogram("twilight_step_seconds", "wall seconds per mixed engine step"),
            kept_budget: histogram(
                "twilight_kept_budget",
                "mean kept tokens per pruned attention call, per step",
            ),
            topp_mass: histogram(
                "twilight_topp_mass",
                "per-layer windowed mean of captured top-p mass",
            ),
            sched_steps: 0,
            last_kept: 0,
            last_candidates: 0,
            last_sparse_calls: 0,
            last_prefill_tokens: 0,
            preempt_events: 0,
            reject_events: 0,
            failed_events: 0,
            last_preempt: 0,
            last_reject: 0,
            last_failed: 0,
            in_breach: false,
        }
    }
}

/// The coordinator's scheduler: admission queue + prefilling set +
/// running set.
pub struct Scheduler {
    pub cfg: SchedulerConfig,
    pub engine: Engine,
    queue: VecDeque<Request>,
    /// Admitted requests still pushing prompt chunks through mixed steps.
    prefilling: Vec<PrefillEntry>,
    running: Vec<Request>,
    rng: Rng,
    finished: Vec<Request>,
    /// Optional budget governor; when present it decides a
    /// [`crate::governor::BudgetDirective`] at the top of every step.
    governor: Option<Governor>,
    /// Metrics handles + delta baselines (see [`SchedObs`]).
    obs: SchedObs,
    /// Cumulative tier faults (read + write errors + lost pages) seen at
    /// the last governed step, for the per-step delta.
    tier_faults_seen: u64,
    /// Engine step count when the fault EMA last advanced — the EMA only
    /// moves on real engine steps, never on idle scheduler spins.
    tier_fault_last_steps: u64,
    /// Smoothed tier faults/step fed to the governor's pressure ladder
    /// (DESIGN.md §14); decays back to 0 when the tier heals.
    tier_fault_ema: f64,
}

impl Scheduler {
    pub fn new(engine: Engine, cfg: SchedulerConfig) -> Scheduler {
        Scheduler {
            cfg,
            engine,
            queue: VecDeque::new(),
            prefilling: Vec::new(),
            running: Vec::new(),
            rng: Rng::new(0xBA7C4),
            finished: Vec::new(),
            governor: None,
            obs: SchedObs::new(),
            tier_faults_seen: 0,
            tier_fault_last_steps: 0,
            tier_fault_ema: 0.0,
        }
    }

    /// Attach a governor (replaces any previous one).
    pub fn attach_governor(&mut self, g: Governor) {
        self.governor = Some(g);
    }

    pub fn governor(&self) -> Option<&Governor> {
        self.governor.as_ref()
    }

    /// Update the governor's TPOT SLO; false when ungoverned.
    pub fn set_slo_tpot(&mut self, target_tpot_s: f64) -> bool {
        match self.governor.as_mut() {
            Some(g) => {
                g.set_slo_tpot(target_tpot_s);
                true
            }
            None => false,
        }
    }

    pub fn submit(&mut self, req: Request) {
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn running(&self) -> usize {
        self.running.len()
    }

    /// Requests partway through chunked prefill.
    pub fn prefilling(&self) -> usize {
        self.prefilling.len()
    }

    /// One scheduler iteration at virtual time `now`: admission, chunk
    /// planning, and **one mixed engine step** (decodes + prefill
    /// chunks). Returns the number of decode tokens produced.
    pub fn step(&mut self, now: f64) -> usize {
        // --- governor -------------------------------------------------
        // Decide before admitting: the directive shapes both this step's
        // decode work and (via the degrade level) admission below.
        if let Some(gov) = self.governor.as_mut() {
            let total = self.engine.total_pages();
            let free_frac = if total == 0 {
                1.0
            } else {
                self.engine.free_pages() as f64 / total as f64
            };
            // Advance the tier-fault EMA only when the engine actually
            // stepped: idle scheduler spins must not decay the signal.
            if self.engine.stats.steps != self.tier_fault_last_steps {
                let s = &self.engine.stats;
                let total_faults = s.tier_read_errors + s.tier_write_errors + s.pages_lost;
                let steps_delta = s.steps.saturating_sub(self.tier_fault_last_steps).max(1);
                let per_step =
                    total_faults.saturating_sub(self.tier_faults_seen) as f64 / steps_delta as f64;
                self.tier_fault_ema = 0.8 * self.tier_fault_ema + 0.2 * per_step;
                self.tier_fault_last_steps = s.steps;
                self.tier_faults_seen = total_faults;
            }
            let snap = gov.snapshot(
                now,
                &self.engine.signals,
                free_frac,
                self.queue.len(),
                self.running.len() + self.prefilling.len(),
                self.tier_fault_ema,
                self.engine.stats.steps,
            );
            let d = gov.step(&snap);
            self.engine.apply_directive(d);
        }
        let directive = self.engine.directive();
        let degrade = directive.degrade_level;
        // --- admission (into Prefilling; prompt-size-aware) -----------
        // Staged degradation: widen the required headroom as pressure
        // mounts, and freeze *new* admission entirely at level 3 unless
        // the engine is idle (in-flight prefills keep draining — they
        // already hold pages, and stalling them can only deadlock).
        let admit_headroom = self.cfg.admit_headroom_pages * (1 + degrade as usize);
        let frozen = degrade >= 3 && !(self.running.is_empty() && self.prefilling.is_empty());
        let ps = self.engine.page_size();
        let mut admitted = 0;
        while !frozen
            && admitted < self.cfg.max_prefills_per_step
            && self.running.len() + self.prefilling.len() < self.cfg.max_batch
        {
            let Some(front) = self.queue.front() else { break };
            if front.arrival > now {
                break;
            }
            let prompt_pages = front.prompt.len().div_ceil(ps);
            // A preempted request's prompt holds folded-back generated
            // tokens, so it gets the true feasibility bound (no headroom):
            // rejecting it on the admission-policy bound would discard
            // already-served work that the pool can still hold, and
            // parking it behind an unreachable headroom would wedge the
            // queue head forever.
            let policy_headroom =
                if front.preemptions > 0 { 0 } else { self.cfg.admit_headroom_pages };
            if prompt_pages + policy_headroom > self.engine.total_pages() {
                // No schedule can ever serve this (for a re-admission:
                // the folded sequence itself outgrew the pool): admitting
                // would only fail mid-prefill and release — refuse up
                // front and count it.
                let req = self.queue.pop_front().unwrap();
                self.reject(req, now);
                continue;
            }
            let want =
                if front.preemptions > 0 { prompt_pages } else { prompt_pages + admit_headroom };
            if self.engine.free_pages() < want {
                break; // parked: retried when pages free up
            }
            let mut req = self.queue.pop_front().unwrap();
            req.state = RequestState::Prefilling;
            req.admitted_at = req.admitted_at.or(Some(now));
            self.engine.start_empty(req.id);
            self.prefilling.push(PrefillEntry { req, cursor: 0 });
            admitted += 1;
        }
        // --- decode preemption ----------------------------------------
        // Preempt (youngest-first) until the decode set's page demand
        // fits: every sequence on a page boundary needs one fresh page in
        // each layer pool, and `free_pages` is the min across pools.
        while !self.running.is_empty() {
            let boundary = self.running.iter().filter(|r| self.engine.needs_page(r.id)).count();
            if boundary <= self.engine.free_pages() {
                break;
            }
            let victim = self.running.pop().unwrap();
            self.engine.release(victim.id);
            self.requeue_preempted(victim);
        }
        let decode_pages =
            self.running.iter().filter(|r| self.engine.needs_page(r.id)).count();
        // --- prefill chunk planning -----------------------------------
        // Each prefilling request contributes at most one chunk (the
        // pressure ladder shrinks the span before freezing admission);
        // all chunks share the per-step token budget, and chunks are
        // *deferred or trimmed* — never the decodes preempted — when the
        // remaining pages cannot take them (chunk-aware preemption
        // ordering: prefill work is always the cheaper thing to delay).
        let chunk = (self.engine.prefill_chunk() / directive.chunk_divisor()).max(1);
        let mut token_budget = self.cfg.max_prefill_tokens_per_step.max(1);
        let mut free_for_chunks = self.engine.free_pages().saturating_sub(decode_pages);
        let mut plan: Vec<(usize, usize)> = Vec::new(); // (prefilling idx, span)
        for (pi, p) in self.prefilling.iter().enumerate() {
            if token_budget == 0 {
                break;
            }
            let remaining = p.req.prompt.len() - p.cursor;
            // Tokens that fit the pages still free: slack on the current
            // page plus whole fresh pages.
            let max_fit = (ps - p.cursor % ps) % ps + free_for_chunks * ps;
            let span = chunk.min(remaining).min(token_budget).min(max_fit);
            if span == 0 {
                continue; // deferred: no pages for this chunk right now
            }
            free_for_chunks -= self.engine.new_pages_for(p.req.id, span);
            token_budget -= span;
            plan.push((pi, span));
        }
        if plan.is_empty() && self.running.is_empty() && !self.prefilling.is_empty() {
            // Wedged: partial prompts hold every page and none can take
            // another chunk. Recompute-preempt the youngest so the rest
            // can make progress.
            let p = self.prefilling.pop().unwrap();
            self.engine.release(p.req.id);
            self.requeue_preempted(p.req);
        }
        // --- one mixed engine step ------------------------------------
        // Decode items first (page pressure inside the step lands on the
        // chunks), then the planned chunks, all flattened by the engine
        // into LPT-balanced (item × kv-head) attention work.
        let mut produced = 0;
        if !self.running.is_empty() || !plan.is_empty() {
            let mut batch = DecodeBatch::default();
            for r in &self.running {
                batch.push_decode(r.id, *r.output.last().unwrap());
            }
            for &(pi, span) in &plan {
                let p = &self.prefilling[pi];
                batch.push_chunk(
                    p.req.id,
                    p.req.prompt[p.cursor..p.cursor + span].to_vec(),
                    p.cursor + span == p.req.prompt.len(),
                );
            }
            let mut results = self.engine.step_batch(&batch).into_iter();
            // Decode results, in batch order. Per-request fate mapping
            // (DESIGN.md §14): OutOfPages is transient (recompute-preempt
            // and requeue — pressure clears); PageLost / WorkerPanic are
            // terminal faults the engine already contained (pages
            // released) — fail the request, never the process. Non-finite
            // logits fail the request too: sampling from NaN scores would
            // emit garbage tokens that *look* like service.
            let mut kept = Vec::with_capacity(self.running.len());
            let mut victims = Vec::new();
            let mut failures: Vec<(Request, FailReason)> = Vec::new();
            for mut req in self.running.drain(..) {
                match results.next().unwrap() {
                    Ok(logits) => {
                        if logits.iter().all(|v| v.is_finite()) {
                            let tok = sample(&logits, &req.params, &mut self.rng);
                            req.output.push(tok);
                            produced += 1;
                            kept.push(req);
                        } else {
                            // Engine still holds the sequence on Ok.
                            self.engine.release(req.id);
                            failures.push((req, FailReason::NonFiniteLogits));
                        }
                    }
                    Err(CacheError::OutOfPages) => victims.push(req),
                    Err(CacheError::PageLost) => failures.push((req, FailReason::PageLost)),
                    Err(CacheError::WorkerPanic) => {
                        failures.push((req, FailReason::WorkerPanic))
                    }
                }
            }
            self.running = kept;
            // Chunk results, in plan order; the same fate mapping.
            let mut retire: Vec<usize> = Vec::new();
            for &(pi, span) in &plan {
                let p = &mut self.prefilling[pi];
                match results.next().unwrap() {
                    Ok(logits) => {
                        p.cursor += span;
                        if p.cursor == p.req.prompt.len() {
                            if logits.iter().all(|v| v.is_finite()) {
                                // TTFT is stamped here, at the first
                                // *sampled* token — not at admission.
                                let tok = sample(&logits, &p.req.params, &mut self.rng);
                                p.req.output.push(tok);
                                p.req.first_token_at = Some(now);
                                p.req.state = RequestState::Decoding;
                            } else {
                                p.req.state = RequestState::Failed {
                                    reason: FailReason::NonFiniteLogits,
                                };
                            }
                            retire.push(pi);
                        }
                    }
                    Err(CacheError::OutOfPages) => {
                        // Engine released the sequence mid-chunk: the
                        // whole prompt re-prefills later.
                        p.req.state = RequestState::Preempted;
                        retire.push(pi);
                    }
                    Err(CacheError::PageLost) => {
                        p.req.state = RequestState::Failed { reason: FailReason::PageLost };
                        retire.push(pi);
                    }
                    Err(CacheError::WorkerPanic) => {
                        p.req.state =
                            RequestState::Failed { reason: FailReason::WorkerPanic };
                        retire.push(pi);
                    }
                }
            }
            for &pi in retire.iter().rev() {
                let p = self.prefilling.remove(pi);
                match p.req.state {
                    RequestState::Decoding => {
                        if p.req.is_done() {
                            self.engine.release(p.req.id);
                            self.finish(p.req, now);
                        } else {
                            self.running.push(p.req);
                        }
                    }
                    RequestState::Failed { reason } => {
                        // No-op when the engine already released the
                        // sequence (the Err paths); reclaims the pages
                        // for the non-finite-logits path.
                        self.engine.release(p.req.id);
                        self.fail(p.req, reason, now);
                    }
                    _ => self.requeue_preempted(p.req),
                }
            }
            for victim in victims {
                self.requeue_preempted(victim);
            }
            for (req, reason) in failures {
                self.fail(req, reason, now);
            }
        }
        // --- completion -----------------------------------------------
        let mut j = 0;
        while j < self.running.len() {
            if self.running[j].is_done() {
                let req = self.running.remove(j);
                self.engine.release(req.id);
                self.finish(req, now);
            } else {
                j += 1;
            }
        }
        if let Some(gov) = self.governor.as_mut() {
            // Only the decode *share* of the mixed step feeds the SLO
            // tracker: under continuous batching the decode share ≙ TPOT
            // for the batch; co-scheduled prefill chunks must not skew it
            // (their cost is bounded by the per-step token budget and
            // reported via EngineStats::t_prefill instead).
            gov.observe_step(self.engine.last_step_timing().decode, produced);
        }
        self.observe_step_obs(now, produced);
        produced
    }

    /// Purely-observational end-of-step hook: update the metrics
    /// registry, append a flight-recorder record, dump on an SLO-breach
    /// rising edge, and emit the periodic snapshot log line. Nothing
    /// here feeds back into scheduling.
    fn observe_step_obs(&mut self, now: f64, produced: usize) {
        self.obs.sched_steps += 1;
        let timing = self.engine.last_step_timing();
        let stats = &self.engine.stats;
        let directive = self.engine.directive();
        // Counters (deltas against the previous step's baselines).
        self.obs.steps.inc();
        self.obs.tokens.add(produced as u64);
        let prefill_delta = stats.prefill_tokens - self.obs.last_prefill_tokens;
        self.obs.prefill_tokens.add(prefill_delta);
        self.obs.last_prefill_tokens = stats.prefill_tokens;
        let preempt_delta = self.obs.preempt_events - self.obs.last_preempt;
        self.obs.preempt.add(preempt_delta);
        self.obs.last_preempt = self.obs.preempt_events;
        let reject_delta = self.obs.reject_events - self.obs.last_reject;
        self.obs.reject.add(reject_delta);
        self.obs.last_reject = self.obs.reject_events;
        let failed_delta = self.obs.failed_events - self.obs.last_failed;
        self.obs.failed.add(failed_delta);
        self.obs.last_failed = self.obs.failed_events;
        // Gauges.
        self.obs.queue_depth.set(self.queue.len() as f64);
        self.obs.running.set(self.running.len() as f64);
        self.obs.prefilling.set(self.prefilling.len() as f64);
        self.obs.free_pages.set(self.engine.free_pages() as f64);
        self.obs.hier_skip.set(self.engine.signals.hier_skip_frac());
        self.obs.sprefill_skip.set(if stats.prefill_blocks_total == 0 {
            0.0
        } else {
            stats.prefill_blocks_skipped as f64 / stats.prefill_blocks_total as f64
        });
        self.obs.probe_recall.set(self.engine.signals.probe_recall());
        self.obs.p_scale.set(directive.p_scale as f64);
        self.obs.budget_scale.set(directive.budget_scale as f64);
        // Histograms.
        if timing.total > 0.0 {
            self.obs.step_seconds.observe(timing.total);
        }
        let kept_delta = stats.kept_sum - self.obs.last_kept;
        let candidates_delta = stats.candidates_sum - self.obs.last_candidates;
        let calls_delta = stats.sparse_calls - self.obs.last_sparse_calls;
        if calls_delta > 0 {
            self.obs.kept_budget.observe(kept_delta as f64 / calls_delta as f64);
        }
        self.obs.last_kept = stats.kept_sum;
        self.obs.last_candidates = stats.candidates_sum;
        self.obs.last_sparse_calls = stats.sparse_calls;
        for layer in 0..self.engine.signals.n_layers() {
            let mass = self.engine.signals.layer_mass(layer);
            if mass > 0.0 {
                self.obs.topp_mass.observe(mass);
            }
        }
        // Anomaly classification (most severe wins) + breach detection.
        let tpot_ema = self.governor.as_ref().map(|g| g.tpot_ema()).unwrap_or(0.0);
        let breach = self.governor.as_ref().is_some_and(|g| {
            let target = g.slo_tpot();
            target > 0.0 && g.tpot_ema() > 4.0 * target
        });
        let mut anomaly = Anomaly::None;
        if preempt_delta > 0 {
            anomaly = Anomaly::Preempt;
        }
        if reject_delta > 0 {
            anomaly = Anomaly::Reject;
        }
        if breach {
            anomaly = Anomaly::SloBreach;
        }
        if failed_delta > 0 {
            // Most severe: service was lost, not merely degraded.
            anomaly = Anomaly::Failed;
        }
        recorder::record(StepRecord {
            step: self.obs.sched_steps,
            now,
            step_s: timing.total,
            decode_s: timing.decode,
            prefill_s: timing.prefill,
            produced: produced as u32,
            queue: self.queue.len() as u32,
            running: self.running.len() as u32,
            prefilling: self.prefilling.len() as u32,
            free_pages: self.engine.free_pages() as u32,
            kept_delta,
            candidates_delta,
            p_scale: directive.p_scale,
            budget_scale: directive.budget_scale,
            degrade: directive.degrade_level,
            anomaly,
        });
        // Dump once per *entry* into breach (governed tests run with
        // deliberately unattainable SLOs — every step breaches — so an
        // unedged dump would spam stderr for the whole run).
        if breach && !self.obs.in_breach {
            recorder::dump_stderr("TPOT SLO breach (tpot_ema > 4x target)", 16);
        }
        self.obs.in_breach = breach;
        if self.cfg.snapshot_every_steps > 0
            && self.obs.sched_steps % self.cfg.snapshot_every_steps as u64 == 0
        {
            logging::log_kv(
                logging::Level::Info,
                "obs",
                "snapshot",
                &[
                    ("step", self.obs.sched_steps as f64),
                    ("queue", self.queue.len() as f64),
                    ("running", self.running.len() as f64),
                    ("prefilling", self.prefilling.len() as f64),
                    ("free_pages", self.engine.free_pages() as f64),
                    ("step_s", timing.total),
                    ("tpot_ema_s", tpot_ema),
                    ("p_scale", directive.p_scale as f64),
                    ("budget_scale", directive.budget_scale as f64),
                    ("hier_skip_frac", self.engine.signals.hier_skip_frac()),
                    ("probe_recall", self.engine.signals.probe_recall()),
                ],
            );
        }
    }

    /// Terminally refuse service: a fresh prompt the admission policy can
    /// never hold, or a preempted request whose folded prompt+output
    /// sequence outgrew the whole pool (unservable by any schedule — the
    /// report's `preemptions` field distinguishes the two).
    fn reject(&mut self, mut req: Request, now: f64) {
        req.state = RequestState::Rejected;
        req.finished_at = Some(now);
        self.obs.reject_events += 1;
        self.finished.push(req);
    }

    /// Terminal fault: the request died to a contained failure (lost KV
    /// page, quarantined worker panic, non-finite logits). Its pages are
    /// already reclaimed by the caller; neighbors were never touched.
    /// Partial output is kept for diagnostics but the request reports as
    /// failed, not served.
    fn fail(&mut self, mut req: Request, reason: FailReason, now: f64) {
        req.state = RequestState::Failed { reason };
        req.finished_at = Some(now);
        self.obs.failed_events += 1;
        self.finished.push(req);
    }

    /// Recompute-style preemption: fold the generated tokens back into
    /// the prompt and push the request to the queue head (its pages must
    /// already be released). Also used for prefilling requests evicted
    /// mid-prompt — their whole prompt re-prefills on re-admission.
    fn requeue_preempted(&mut self, mut req: Request) {
        req.state = RequestState::Preempted;
        req.preemptions += 1;
        self.obs.preempt_events += 1;
        req.prompt.extend_from_slice(&req.output);
        req.output.clear();
        req.first_token_at = None;
        req.admitted_at = None;
        self.queue.push_front(req);
    }

    fn finish(&mut self, mut req: Request, now: f64) {
        req.state = RequestState::Finished;
        req.finished_at = Some(now);
        // Per-request latency histograms (virtual time — consistent with
        // the ServingReport's definitions in coordinator/metrics.rs).
        if let Some(first) = req.first_token_at {
            self.obs.ttft.observe((first - req.arrival).max(0.0));
            if req.output.len() > 1 {
                let gen_t = (req.finished_at.unwrap_or(now) - first).max(0.0);
                self.obs.tpot.observe(gen_t / (req.output.len() - 1) as f64);
            }
        }
        self.finished.push(req);
    }

    /// Drive the scheduler until all submitted requests finish; returns
    /// the serving report. Virtual time = accumulated wall-clock compute.
    pub fn run_to_completion(&mut self) -> ServingReport {
        let t0 = Instant::now();
        let mut guard = 0u64;
        while !self.queue.is_empty() || !self.running.is_empty() || !self.prefilling.is_empty() {
            let now = t0.elapsed().as_secs_f64();
            self.step(now);
            guard += 1;
            assert!(guard < 10_000_000, "scheduler livelock");
        }
        let duration = t0.elapsed().as_secs_f64();
        let requests = self
            .finished
            .iter()
            .map(|r| RequestMetrics {
                id: r.id,
                prompt_len: r.prompt.len(),
                output_len: r.output.len(),
                arrival: r.arrival,
                admitted_at: r.admitted_at.unwrap_or(r.arrival),
                // A placeholder for never-started requests; `started`
                // gates every summary that would read it.
                first_token_at: r.first_token_at.unwrap_or(r.arrival),
                finished_at: r.finished_at.unwrap_or(duration),
                preemptions: r.preemptions,
                rejected: r.state == RequestState::Rejected,
                started: r.first_token_at.is_some(),
                fail_reason: match r.state {
                    RequestState::Failed { reason } => Some(reason),
                    _ => None,
                },
            })
            .collect();
        let governor = self.governor.as_mut().map(|g| g.take_trace()).unwrap_or_default();
        ServingReport {
            requests,
            duration,
            governor,
            hier_pages_skipped: self.engine.signals.hier_pages_skipped(),
            hier_pages_total: self.engine.signals.hier_pages_total(),
            prefill_blocks_skipped: self.engine.stats.prefill_blocks_skipped,
            prefill_blocks_total: self.engine.stats.prefill_blocks_total,
            kernel_backend: crate::tensor::kernels::active_name().to_string(),
            offload_faults: self.engine.stats.offload_faults,
            offload_prefetched: self.engine.stats.offload_prefetched,
            offload_evictions: self.engine.stats.offload_evictions,
            offload_bytes_faulted: self.engine.stats.offload_bytes_faulted,
            resident_frac: self.engine.resident_frac(),
            tier_read_errors: self.engine.stats.tier_read_errors,
            tier_write_errors: self.engine.stats.tier_write_errors,
            tier_retries: self.engine.stats.tier_retries,
            pages_lost: self.engine.stats.pages_lost,
            worker_panics: self.engine.stats.worker_panics,
        }
    }

    /// Finished requests (for output inspection).
    pub fn finished_requests(&self) -> &[Request] {
        &self.finished
    }

    /// Live state for the server's `stats` command (the run is still in
    /// flight, so this reports counters rather than a final report).
    pub fn live_stats_json(&self) -> Json {
        let s = &self.engine.stats;
        let rejected = self
            .finished
            .iter()
            .filter(|r| r.state == RequestState::Rejected)
            .count();
        let failed = self
            .finished
            .iter()
            .filter(|r| matches!(r.state, RequestState::Failed { .. }))
            .count();
        let mut kv: Vec<(&str, Json)> = vec![
            ("pending", Json::Num(self.queue.len() as f64)),
            ("prefilling", Json::Num(self.prefilling.len() as f64)),
            ("running", Json::Num(self.running.len() as f64)),
            // Served to completion; refusals and contained faults are
            // counted separately so the three fields never overlap.
            ("finished", Json::Num((self.finished.len() - rejected - failed) as f64)),
            ("rejected", Json::Num(rejected as f64)),
            ("failed", Json::Num(failed as f64)),
            ("pages_lost", Json::Num(s.pages_lost as f64)),
            ("tier_read_errors", Json::Num(s.tier_read_errors as f64)),
            ("tier_write_errors", Json::Num(s.tier_write_errors as f64)),
            ("tier_retries", Json::Num(s.tier_retries as f64)),
            ("worker_panics", Json::Num(s.worker_panics as f64)),
            ("threads", Json::Num(self.engine.threads() as f64)),
            ("prefill_chunk", Json::Num(self.engine.prefill_chunk() as f64)),
            ("kernel_backend", Json::Str(crate::tensor::kernels::active_name().to_string())),
            ("steps", Json::Num(s.steps as f64)),
            ("prefill_tokens", Json::Num(s.prefill_tokens as f64)),
            ("prefill_chunks", Json::Num(s.prefill_chunks as f64)),
            ("t_prefill_s", Json::Num(s.t_prefill)),
            ("t_sprefill_s", Json::Num(s.t_sprefill)),
            ("prefill_blocks_skipped", Json::Num(s.prefill_blocks_skipped as f64)),
            ("prefill_blocks_total", Json::Num(s.prefill_blocks_total as f64)),
            ("avg_candidates", Json::Num(s.avg_candidates())),
            ("avg_kept", Json::Num(s.avg_kept())),
            ("prune_ratio", Json::Num(s.prune_ratio())),
            ("free_pages", Json::Num(self.engine.free_pages() as f64)),
            ("total_pages", Json::Num(self.engine.total_pages() as f64)),
            ("mean_mass", Json::Num(self.engine.signals.mean_mass())),
            ("probe_recall", Json::Num(self.engine.signals.probe_recall())),
            ("hier_pages_skipped", Json::Num(self.engine.signals.hier_pages_skipped() as f64)),
            ("hier_skip_frac", Json::Num(self.engine.signals.hier_skip_frac())),
            ("resident_frac", Json::Num(self.engine.resident_frac())),
            ("offload_faults", Json::Num(s.offload_faults as f64)),
            ("offload_prefetched", Json::Num(s.offload_prefetched as f64)),
            ("offload_evictions", Json::Num(s.offload_evictions as f64)),
        ];
        if let Some(g) = &self.governor {
            kv.push(("governor", g.state_json()));
        }
        json::obj(kv)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::SparseConfig;
    use crate::model::retrieval::build_retrieval_model;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_niah, RetrievalVocab};
    use std::sync::Arc;

    const V: RetrievalVocab = RetrievalVocab::DEFAULT;

    fn sched(capacity: usize, cfg: SparseConfig) -> Scheduler {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let engine = Engine::new(model, cfg, capacity);
        Scheduler::new(engine, SchedulerConfig::default())
    }

    #[test]
    fn completes_batch_and_answers() {
        let mut s = sched(1 << 16, SparseConfig::twilight(SelectorKind::Quest, 0.9));
        let mut r = Rng::new(1);
        let mut answers = Vec::new();
        for i in 0..6 {
            let g = gen_niah(&mut r, V, 256);
            let req = Request::new(i, g.prompt.clone(), 1);
            answers.push(g.answer);
            s.submit(req);
        }
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 6);
        let mut correct = 0;
        for (req, want) in s.finished_requests().iter().zip(&answers) {
            if req.output.first() == Some(want) {
                correct += 1;
            }
        }
        assert!(correct >= 5, "{correct}/6");
        // All pages returned.
        assert_eq!(s.engine.num_seqs(), 0);
    }

    #[test]
    fn respects_max_batch() {
        let mut s = sched(1 << 16, SparseConfig::dense());
        s.cfg.max_batch = 2;
        let mut r = Rng::new(2);
        for i in 0..5 {
            let g = gen_niah(&mut r, V, 64);
            let mut req = Request::new(i, g.prompt, 8);
            req.stop_token = None;
            s.submit(req);
        }
        s.step(0.0);
        assert!(s.running() <= 2);
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 5);
    }

    #[test]
    fn preempts_under_memory_pressure_and_recovers() {
        // Pool sized so 3 long decodes cannot coexist.
        let mut s = sched(700, SparseConfig::dense());
        s.cfg.admit_headroom_pages = 0;
        let mut r = Rng::new(3);
        for i in 0..3 {
            let g = gen_niah(&mut r, V, 192);
            s.submit(Request::new(i, g.prompt, 64));
        }
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 3);
        let total_preempt: u32 = rep.requests.iter().map(|r| r.preemptions).sum();
        assert!(total_preempt > 0, "expected at least one preemption");
        assert_eq!(s.engine.num_seqs(), 0);
    }

    #[test]
    fn governed_scheduler_traces_and_completes() {
        use crate::governor::slo::SloConfig;
        use crate::governor::{BudgetDirective, Governor, GovernorConfig};
        let mut s = sched(1 << 16, SparseConfig::twilight(SelectorKind::Quest, 0.9));
        // An unattainably tight SLO forces the AIMD policy to tighten.
        let cfg = GovernorConfig {
            slo: SloConfig { target_tpot_s: 1e-9, margin: 0.2 },
            ..Default::default()
        };
        s.attach_governor(Governor::new("aimd", cfg).unwrap());
        let mut r = Rng::new(17);
        for i in 0..4 {
            let g = gen_niah(&mut r, V, 256);
            s.submit(Request::new(i, g.prompt, 8));
        }
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 4);
        assert!(!rep.governor.is_empty(), "governed run must record a trace");
        assert!(
            rep.governor.last().unwrap().budget_scale < 1.0,
            "unattainable SLO must tighten the budget"
        );
        for e in &rep.governor {
            assert!(
                e.p_scale >= BudgetDirective::P_SCALE_RANGE.0
                    && e.p_scale <= BudgetDirective::P_SCALE_RANGE.1,
                "p_scale {} outside safe range",
                e.p_scale
            );
            assert!(
                e.budget_scale >= BudgetDirective::BUDGET_SCALE_RANGE.0
                    && e.budget_scale <= BudgetDirective::BUDGET_SCALE_RANGE.1
            );
        }
        assert_eq!(s.engine.num_seqs(), 0);
        let j = s.live_stats_json();
        assert!(j.get("governor").is_some());
    }

    #[test]
    fn concurrent_requests_progress_through_step_batch() {
        // Every running request must gain exactly one token per scheduler
        // step (the batched decode advances the whole set at once); new
        // admissions prefill across steps in chunks first.
        let mut s = sched(1 << 16, SparseConfig::twilight(SelectorKind::Quest, 0.9));
        let mut r = Rng::new(9);
        for i in 0..4 {
            let g = gen_niah(&mut r, V, 128);
            let mut req = Request::new(i, g.prompt, 6);
            req.stop_token = None;
            s.submit(req);
        }
        // Chunked admission: all four requests move through Prefilling
        // (possibly over several steps, depending on the chunk span) and
        // into the running set.
        let mut guard = 0;
        while s.running() < 4 {
            s.step(0.0);
            guard += 1;
            assert!(guard < 1 << 12, "admission never completed");
        }
        assert_eq!(s.prefilling(), 0);
        let decode_steps_before = s.engine.stats.steps;
        let produced = s.step(0.0);
        assert_eq!(produced, s.running(), "each running request gains one token per step");
        // One batched engine step per scheduler step, regardless of batch size.
        assert_eq!(s.engine.stats.steps, decode_steps_before + 1);
        let rep = s.run_to_completion();
        assert_eq!(rep.requests.len(), 4);
        assert_eq!(rep.rejected(), 0);
        assert_eq!(s.engine.num_seqs(), 0);
    }

    #[test]
    fn arrivals_respected() {
        let mut s = sched(1 << 14, SparseConfig::dense());
        let mut r = Rng::new(4);
        let g = gen_niah(&mut r, V, 64);
        let mut req = Request::new(0, g.prompt, 1);
        req.arrival = 1e9; // far future
        s.submit(req);
        assert_eq!(s.step(0.0), 0);
        assert_eq!(s.pending(), 1);
        s.step(2e9);
        assert_eq!(s.pending(), 0);
    }
}
