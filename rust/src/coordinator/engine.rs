//! The serving engine: wires the model forward pass to the paged KV
//! cache, Token Selector, Twilight Pruner, and varlen attention kernels —
//! the per-step pipeline of Fig. 5 — and keeps the Fig. 10 time breakdown.
//!
//! The step is a *unified mixed step* (paper §4.2 batching + Sarathi-style
//! chunked prefill): the scheduler hands the engine one [`DecodeBatch`]
//! whose items are decode steps (one token) **and prefill chunks** (a
//! span of prompt tokens), and every layer executes as three phases —
//!
//! 1. **append** — QKV projection + KV append for every query token,
//!    serial, item-major (appends mutate the shared page pools; decode
//!    items come first in a scheduler batch, so memory pressure defers
//!    chunks rather than starving running decodes);
//! 2. **attend** — the (item × kv-head) pairs are flattened into one work
//!    list and LPT-partitioned across workers
//!    ([`super::balance::lpt_partition`]), drained by the engine's
//!    persistent [`crate::util::threadpool::ThreadPool`]. A decode item
//!    costs its resolved stage-1 budget (context length when dense); a
//!    chunk item is *multi-query* — its sub-calls run serially on one
//!    worker, each attending causally over the visible prefix through a
//!    truncated [`SeqCache`] view — and costs the sum over its span
//!    (≈ span × context). Each worker runs select → prune →
//!    varlen-attend per sub-call with its own [`AttnScratch`] arena
//!    (every per-candidate buffer — candidate list, SpGEMV tiles, top-p
//!    active set, keep-set union, streaming-softmax state — is reused,
//!    so a steady-state work unit performs zero heap allocations; see
//!    DESIGN.md §9), read-only cache access, and exclusive access to
//!    its items' per-sequence selector state;
//! 3. **rest-of-layer** — output projection + MLP for every query token.
//!
//! **Chunk invariance.** A chunk appends its whole span before attending,
//! so a sub-call at position `p` must not see anything a lone decode step
//! at `p` would not have seen. Exact K/V rows are written once per slot;
//! the INT4 mirror and Quest min/max of a page are only consulted once
//! the page *seals* (see the sealing contract in `kvcache`), and the
//! visibly-partial tail is scored exactly. Logits and KV are therefore
//! bit-exact for **any** chunk size (`TWILIGHT_PREFILL_CHUNK=1` ≡ `=N`).
//! The telemetry plane holds too: sub-call plans and sparse-call labels
//! are pre-resolved per step in (item, token, layer) order, per-call
//! records merge token-major, and recall probes are replayed into the
//! EMA in that same order — so [`SignalHub`] contents (what a governor
//! steers on) are also chunk-size invariant for a fixed step
//! composition. All pinned by `rust/tests/chunked_prefill.rs`. (A
//! *scheduler*-driven run still legitimately differs across chunk knobs:
//! admission spans more or fewer steps, so a governor decides at
//! different boundaries — that is scheduling, not numerics.)
//!
//! Workers record stats and governor telemetry into per-item accumulators
//! that are merged *in flattened item order* (sub-calls in chunk order
//! within an item) at the phase barrier, so [`EngineStats`], [`SignalHub`]
//! contents, and the logits are bit-exact for any worker count
//! (`TWILIGHT_THREADS=1` ≡ `TWILIGHT_THREADS=N`).

use super::{balance, AttnVariant, SparseConfig};
use crate::governor::signals::SignalHub;
use crate::governor::BudgetDirective;
use crate::kvcache::offload::{
    ChaosConfig, ChaosTier, PrefetchPlan, SimTier, DEFAULT_SLOWDOWN, PREFETCH_EPS_FRAC,
};
use crate::kvcache::{CacheConfig, CacheError, PageId, PagedKvCache, SeqCache};
use crate::model::{BatchBackend, Model, ModelConfig, SpanRef};
use crate::obs::trace;
use crate::pruner::{prune_group_into, AttnScratch, PrunerConfig};
use crate::selector::{SelectorKind, TokenSelector};
use crate::util::stats::Histogram;
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Engine-internal sequence id (the coordinator maps RequestId → SeqId).
pub type SeqId = u64;

/// Default prefill chunk span (`TWILIGHT_PREFILL_CHUNK` / `--prefill-chunk`
/// override it). Chunking only changes wall-clock shape — logits and KV
/// are bit-exact for any span.
pub const DEFAULT_PREFILL_CHUNK: usize = 64;

fn default_prefill_chunk() -> usize {
    std::env::var("TWILIGHT_PREFILL_CHUNK")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_PREFILL_CHUNK)
}

/// `TWILIGHT_RESIDENT_FRAC` (0, 1): attach a simulated slow tier at
/// engine construction, keeping that fraction of each layer's page pool
/// resident. Absent / out-of-range values mean fully resident.
fn default_resident_frac() -> Option<f64> {
    std::env::var("TWILIGHT_RESIDENT_FRAC")
        .ok()
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|&f| f > 0.0 && f < 1.0)
}

/// One item of a mixed step: a sequence advancing by `toks`.
#[derive(Clone, Debug)]
pub struct StepItem {
    pub id: SeqId,
    /// One token = a decode step; a longer span = a prefill chunk. The
    /// whole span appends in phase (a), then each token attends causally
    /// over its own prefix.
    pub toks: Vec<u32>,
    /// Prompt processing (chunk) rather than decode: accounted to
    /// `EngineStats::prefill_tokens`, excluded from the decode share of
    /// [`StepTiming`], and — for single-layer models — eligible for the
    /// algebraic attend-skip (see [`Engine::prefill`]).
    pub prefill: bool,
    /// Final chunk of its prompt: the item's logits will be sampled.
    pub last: bool,
}

/// One batched mixed step: decode items plus prefill chunks. Ids must be
/// distinct within a batch; the scheduler puts decode items first so
/// page-pool pressure lands on chunks, never on running decodes.
#[derive(Clone, Debug, Default)]
pub struct DecodeBatch {
    pub items: Vec<StepItem>,
}

impl DecodeBatch {
    /// A decode-only batch (back-compat constructor).
    pub fn new(items: Vec<(SeqId, u32)>) -> DecodeBatch {
        DecodeBatch {
            items: items
                .into_iter()
                .map(|(id, tok)| StepItem { id, toks: vec![tok], prefill: false, last: true })
                .collect(),
        }
    }

    pub fn single(id: SeqId, tok: u32) -> DecodeBatch {
        DecodeBatch::new(vec![(id, tok)])
    }

    /// A batch holding one prefill chunk.
    pub fn chunk(id: SeqId, toks: Vec<u32>, last: bool) -> DecodeBatch {
        let mut b = DecodeBatch::default();
        b.push_chunk(id, toks, last);
        b
    }

    pub fn push_decode(&mut self, id: SeqId, tok: u32) {
        self.items.push(StepItem { id, toks: vec![tok], prefill: false, last: true });
    }

    /// Append a prefill chunk; `last` marks the final chunk of a prompt
    /// (whose logits the caller will sample).
    pub fn push_chunk(&mut self, id: SeqId, toks: Vec<u32>, last: bool) {
        assert!(!toks.is_empty(), "empty prefill chunk");
        self.items.push(StepItem { id, toks, prefill: true, last });
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    /// Total query tokens across all items.
    pub fn query_tokens(&self) -> usize {
        self.items.iter().map(|it| it.toks.len()).sum()
    }
}

/// Wall-clock attribution of the last mixed step: the decode share feeds
/// the governor's TPOT tracker, the prefill share is reported separately
/// (a mixed step is *not* TPOT for its chunk tokens). Shares split the
/// measured total by each side's attention work (Σ visible context per
/// query token — the bandwidth cost model that also drives the LPT).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    pub total: f64,
    pub decode: f64,
    pub prefill: f64,
}

/// Accumulated timing and budget statistics (Fig. 10 / Table budgets).
#[derive(Clone, Debug)]
pub struct EngineStats {
    /// Seconds in the Token Selector across all steps.
    pub t_select: f64,
    /// Seconds in the Twilight Pruner.
    pub t_prune: f64,
    /// Seconds in the sparse attention kernel.
    pub t_attend: f64,
    /// Seconds in dense attention (skip layers / short contexts).
    pub t_dense: f64,
    /// Seconds in the bound-guided sparse-prefill kernel
    /// (`attention::prefill`; 0 unless sparse prefill ran).
    pub t_sprefill: f64,
    /// Seconds in everything else (projections, MLP, norms, sampling).
    pub t_other: f64,
    /// Batched steps that advanced at least one decode item (a batch of
    /// any size counts once: under continuous batching, step time ≙ TPOT).
    /// Chunk-only admission steps do not count.
    pub steps: u64,
    /// Prompt tokens pushed through the forward pass (chunked prefill
    /// appends whole spans, so this counts *tokens*, not forward passes —
    /// the single-layer fast path pushes only the final prompt token).
    /// Kept separate from `steps` so TPOT-style per-step averages are not
    /// skewed by prompt processing. (Named `prefill_steps` before the
    /// chunked-prefill rework made it a token count; the serving report's
    /// wire label keeps the historical name for golden stability.)
    pub prefill_tokens: u64,
    /// Prefill chunk items executed (spans of any size count once).
    pub prefill_chunks: u64,
    /// Cumulative wall-clock attributed to the prefill share of mixed
    /// steps (see [`StepTiming`]). An attribution overlay over the same
    /// wall-clock the `t_*` stage fields decompose — not an extra stage.
    pub t_prefill: f64,
    /// Sum of stage-1 candidate budgets (per kv-head per step).
    pub candidates_sum: u64,
    /// Sum of final kept budgets.
    pub kept_sum: u64,
    /// Number of (step × kv-head) sparse attention invocations.
    pub sparse_calls: u64,
    /// Hier-pages mode: cumulative candidate page runs skipped unscored
    /// by the page-level pre-prune (0 unless `--hier-pages` ran).
    pub hier_pages_skipped: u64,
    /// Hier-pages mode: cumulative candidate page runs seen.
    pub hier_pages_total: u64,
    /// Sparse prefill: cumulative gated (sealed, below-window) pages
    /// skipped unvisited across (prefill query × group head) rows
    /// (0 unless the sparse-prefill path ran).
    pub prefill_blocks_skipped: u64,
    /// Sparse prefill: cumulative gated pages considered (denominator).
    pub prefill_blocks_total: u64,
    /// Histogram of final per-head budgets.
    pub kept_hist: Histogram,
    /// Bytes the pipeline *would* stream on a GPU (sim cost model).
    pub est_bytes_select: u64,
    pub est_bytes_prune: u64,
    pub est_bytes_attend: u64,
    /// Tiered offload (0 unless a slow tier is attached; cumulative
    /// totals, refreshed from the per-layer `TierState` counters after
    /// every batched step): pages faulted in (demand + prefetch).
    pub offload_faults: u64,
    /// Faults performed by hier-bound prefetch tickets (⊆ faults; the
    /// prefetch/demand *split* is timing-dependent, the total is not).
    pub offload_prefetched: u64,
    /// Sealed pages evicted to the tier.
    pub offload_evictions: u64,
    /// Bytes copied back from the tier by faults.
    pub offload_bytes_faulted: u64,
    /// Pages written through to the tier (seals + attach-time spills).
    pub offload_spilled_pages: u64,
    /// Fault-domain counters (0 unless faults occur; cumulative, refreshed
    /// from per-layer `TierState` like the offload counters): tier read
    /// ops that returned an error (every retry attempt counts).
    pub tier_read_errors: u64,
    /// Tier write ops that returned an error (every retry attempt counts).
    pub tier_write_errors: u64,
    /// Retry-ladder re-attempts after a tier error (reads + writes).
    pub tier_retries: u64,
    /// Pages declared lost after read-retry exhaustion (terminal; the
    /// owning request fails with `CacheError::PageLost`).
    pub pages_lost: u64,
    /// Attention work items quarantined after an in-item panic (the
    /// owning request fails with `CacheError::WorkerPanic`).
    pub worker_panics: u64,
}

impl Default for EngineStats {
    fn default() -> Self {
        EngineStats {
            t_select: 0.0,
            t_prune: 0.0,
            t_attend: 0.0,
            t_dense: 0.0,
            t_sprefill: 0.0,
            t_other: 0.0,
            steps: 0,
            prefill_tokens: 0,
            prefill_chunks: 0,
            t_prefill: 0.0,
            candidates_sum: 0,
            kept_sum: 0,
            sparse_calls: 0,
            hier_pages_skipped: 0,
            hier_pages_total: 0,
            prefill_blocks_skipped: 0,
            prefill_blocks_total: 0,
            kept_hist: Histogram::new(0.0, 4096.0, 64),
            est_bytes_select: 0,
            est_bytes_prune: 0,
            est_bytes_attend: 0,
            offload_faults: 0,
            offload_prefetched: 0,
            offload_evictions: 0,
            offload_bytes_faulted: 0,
            offload_spilled_pages: 0,
            tier_read_errors: 0,
            tier_write_errors: 0,
            tier_retries: 0,
            pages_lost: 0,
            worker_panics: 0,
        }
    }
}

impl EngineStats {
    /// Mean final budget per sparse head-call.
    pub fn avg_kept(&self) -> f64 {
        if self.sparse_calls == 0 {
            0.0
        } else {
            self.kept_sum as f64 / self.sparse_calls as f64
        }
    }

    pub fn avg_candidates(&self) -> f64 {
        if self.sparse_calls == 0 {
            0.0
        } else {
            self.candidates_sum as f64 / self.sparse_calls as f64
        }
    }

    /// Fraction of stage-1 candidates pruned away by Twilight.
    pub fn prune_ratio(&self) -> f64 {
        if self.candidates_sum == 0 {
            0.0
        } else {
            1.0 - self.kept_sum as f64 / self.candidates_sum as f64
        }
    }
}

/// Per-sequence engine state.
struct SeqState {
    caches: Vec<SeqCache>,
    /// One selector per (layer × kv_head), lazily constructed.
    selectors: Vec<Box<dyn TokenSelector>>,
    pos: usize,
}

/// The decode engine. One per model; holds the physical page pools (one
/// per layer) and all live sequences.
pub struct Engine {
    pub model: Arc<Model>,
    pub cfg: SparseConfig,
    caches: Vec<PagedKvCache>,
    seqs: HashMap<SeqId, SeqState>,
    pub stats: EngineStats,
    /// Governor telemetry: per-layer prune rings + recall-probe EMA.
    pub signals: SignalHub,
    /// Runtime override from the governor; neutral when ungoverned.
    directive: BudgetDirective,
    /// Persistent attention worker pool, created once per engine
    /// (`TWILIGHT_THREADS`-sized by default) and reused for every layer
    /// of every batched step; `threads == 1` bypasses it entirely and
    /// reproduces strictly sequential execution bit for bit.
    pool: ThreadPool,
    /// Per-worker attention scratch arenas (selection buffer, SpGEMV
    /// tiles, top-p active set, keep-set union, recycled outcomes,
    /// streaming-softmax state), reused across steps so every
    /// per-candidate/per-context-length buffer only ever grows: the
    /// steady-state pruned attention call performs zero heap
    /// allocations. The attention phase still allocates step-scoped
    /// bookkeeping (work list, LPT buckets) each layer; those are small
    /// and proportional to batch × kv-heads, not to context length.
    scratches: Vec<AttnScratch>,
    /// Recycled per-work-item output buffers (`AttnItemOut::out`) and
    /// per-call telemetry vectors: popped before each attention phase,
    /// pushed back after the merge, so the per-(item × kv-head) result
    /// buffers stop allocating once warm.
    out_pool: Vec<Vec<f32>>,
    call_pool: Vec<Vec<CallOut>>,
    /// Prefill chunk span used by [`Engine::prefill`] (the scheduler
    /// reads it as the base span for its own chunk planning).
    prefill_chunk: usize,
    /// Attribution of the most recent mixed step.
    last_timing: StepTiming,
    /// Monotonic batched-step ordinal used as the `step` span tag
    /// (unlike `stats.steps` it also counts chunk-only steps, so every
    /// recorded span maps to exactly one `run_batch` call).
    step_seq: u64,
    /// Recycled prefetch-plan buffers (tiered offload): popped per item
    /// before the attention phase, reserved to the pool's page count, and
    /// pushed back after — steady-state prefetch planning is alloc-free.
    plan_pool: Vec<PrefetchPlan>,
    /// Cross-item fault batch (tiered offload): the union of every
    /// item's planned pages for one (step, layer) phase, offset-sorted
    /// and deduped, dispatched as ONE prefetch ticket — a single
    /// ascending sweep over the backing tier instead of per-item
    /// ticket bursts seeking independently.
    fault_batch: Vec<PageId>,
    /// Fraction of each layer pool kept resident (1.0 = no tier).
    resident_frac: f64,
    /// Chaos fault injection (`TWILIGHT_CHAOS` / `--chaos`): when set,
    /// every tier attached by [`Engine::set_resident_frac`] is wrapped
    /// in a seeded [`ChaosTier`]. `None` (the default) leaves every
    /// byte of behavior unchanged — the golden trace pins this.
    chaos: Option<ChaosConfig>,
    /// `(layer, page)` pairs whose bytes were lost while *no* tier was
    /// attached to record the loss (a failed `detach_tier` read):
    /// checked by the end-of-step lost-page scan, pruned when the
    /// owning sequence releases its pages.
    pending_lost: Vec<(usize, PageId)>,
}

impl Engine {
    /// `capacity_tokens` sizes each layer's page pool.
    pub fn new(model: Arc<Model>, cfg: SparseConfig, capacity_tokens: usize) -> Engine {
        let c = &model.cfg;
        let pages = capacity_tokens.div_ceil(16) + 1;
        let caches = (0..c.n_layers)
            .map(|_| PagedKvCache::new(CacheConfig::new(c.n_kv_heads, c.head_dim, pages)))
            .collect();
        let n_layers = model.cfg.n_layers;
        let mut e = Engine {
            model,
            cfg,
            caches,
            seqs: HashMap::new(),
            stats: EngineStats::default(),
            signals: SignalHub::new(n_layers),
            directive: BudgetDirective::NEUTRAL,
            pool: ThreadPool::with_default_threads(),
            scratches: Vec::new(),
            out_pool: Vec::new(),
            call_pool: Vec::new(),
            prefill_chunk: default_prefill_chunk(),
            last_timing: StepTiming::default(),
            step_seq: 0,
            plan_pool: Vec::new(),
            fault_batch: Vec::new(),
            resident_frac: 1.0,
            chaos: ChaosConfig::from_env(),
            pending_lost: Vec::new(),
        };
        if let Some(f) = default_resident_frac() {
            e.set_resident_frac(f);
        }
        e
    }

    /// Prefill chunk span ([`DEFAULT_PREFILL_CHUNK`] unless overridden by
    /// `TWILIGHT_PREFILL_CHUNK` / [`Engine::set_prefill_chunk`]).
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Retarget the prefill chunk span (clamped to ≥ 1). Purely a
    /// latency-shape knob: logits and KV are bit-exact for any value.
    pub fn set_prefill_chunk(&mut self, span: usize) {
        self.prefill_chunk = span.max(1);
    }

    /// Wall-clock attribution of the most recent mixed step.
    pub fn last_step_timing(&self) -> StepTiming {
        self.last_timing
    }

    /// Attention-phase parallelism (caller thread included).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Retarget the attention worker pool. Growth is lazy (resident
    /// workers spawn on the next batched step that needs them, then stay
    /// parked between rounds); 1 selects the sequential reference path.
    pub fn set_threads(&mut self, threads: usize) {
        self.pool.set_threads(threads);
    }

    /// The persistent attention worker pool (instrumentation/tests).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Fraction of each layer's page pool kept resident (1.0 = no tier).
    pub fn resident_frac(&self) -> f64 {
        self.resident_frac
    }

    /// Attach (or retarget) a simulated slow tier on every layer pool,
    /// capping the resident in-use set at `frac` of the pool's pages —
    /// `frac >= 1.0` detaches the tier and faults everything back in.
    /// Safe mid-life: already-sealed pages spill at attach, so logits
    /// stay bit-exact vs the fully-resident baseline either way (the
    /// residency-invariance battery in `rust/tests/offload_decode.rs`).
    pub fn set_resident_frac(&mut self, frac: f64) {
        // Detaching faults every evicted in-use page back in; a detach
        // read that exhausts its retries loses the page's bytes. Each
        // layer's losses are re-marked on its replacement tier (or
        // parked in `pending_lost` when going fully resident) so the
        // owning request still fails loudly instead of decoding zeros.
        let mut lost_by_layer: Vec<Vec<PageId>> = Vec::with_capacity(self.caches.len());
        for c in &mut self.caches {
            lost_by_layer.push(c.detach_tier());
        }
        if !frac.is_finite() || frac <= 0.0 || frac >= 1.0 {
            self.resident_frac = 1.0;
            for (layer, lost) in lost_by_layer.into_iter().enumerate() {
                self.pending_lost.extend(lost.into_iter().map(|p| (layer, p)));
            }
            self.pending_lost.sort_unstable();
            self.pending_lost.dedup();
            return;
        }
        for (c, lost) in self.caches.iter_mut().zip(lost_by_layer) {
            let fpp = c.cfg.kv_heads * c.cfg.page_size * c.cfg.head_dim;
            let cap = ((c.cfg.num_pages as f64 * frac).ceil() as usize).max(1);
            let inner = Box::new(SimTier::new(fpp, c.cfg.num_pages, DEFAULT_SLOWDOWN));
            let tier: Box<dyn crate::kvcache::offload::Tier> = match self.chaos {
                Some(cfg) => Box::new(ChaosTier::new(inner, cfg, c.cfg.num_pages)),
                None => inner,
            };
            c.attach_tier(tier, cap);
            c.mark_pages_lost(&lost);
        }
        self.resident_frac = frac;
    }

    /// Install (or clear) chaos fault injection. Tiers attached by
    /// future [`Engine::set_resident_frac`] calls are wrapped with the
    /// new config; a tier already live is re-attached at the current
    /// fraction so the change takes effect immediately (this is how
    /// `--chaos` overrides a `TWILIGHT_CHAOS` env default that
    /// `Engine::new` already applied).
    pub fn set_chaos(&mut self, cfg: Option<ChaosConfig>) {
        if self.chaos == cfg {
            return;
        }
        self.chaos = cfg;
        let frac = self.resident_frac;
        if frac < 1.0 {
            self.set_resident_frac(frac);
        }
    }

    /// The chaos configuration in force (`None` = no injection).
    pub fn chaos(&self) -> Option<ChaosConfig> {
        self.chaos
    }

    /// Install the governor's directive for subsequent decode steps.
    /// Clamped defensively: the engine never trusts the caller's ranges.
    pub fn apply_directive(&mut self, d: BudgetDirective) {
        self.directive = d.clamped();
    }

    /// The directive currently in force (NEUTRAL when ungoverned).
    pub fn directive(&self) -> BudgetDirective {
        self.directive
    }

    /// Physical pages per layer pool.
    pub fn total_pages(&self) -> usize {
        self.caches.first().map(|c| c.cfg.num_pages).unwrap_or(0)
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn free_pages(&self) -> usize {
        self.caches.iter().map(|c| c.free_pages()).min().unwrap_or(0)
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.pos)
    }

    fn new_state(&self) -> SeqState {
        let c = &self.model.cfg;
        let mut selectors: Vec<Box<dyn TokenSelector>> = Vec::new();
        for layer in 0..c.n_layers {
            for kvh in 0..c.n_kv_heads {
                selectors.push(
                    self.cfg.selector.build(c.head_dim, (layer * 131 + kvh) as u64),
                );
            }
        }
        SeqState { caches: vec![SeqCache::default(); c.n_layers], selectors, pos: 0 }
    }

    /// Register an empty sequence (used by teacher-forced evaluation,
    /// where every token goes through `decode`).
    pub fn start_empty(&mut self, id: SeqId) {
        let st = self.new_state();
        self.seqs.insert(id, st);
    }

    /// Tokens per physical page (uniform across the layer pools).
    pub fn page_size(&self) -> usize {
        self.caches.first().map(|c| c.cfg.page_size).unwrap_or(16)
    }

    /// Fresh pages (per layer pool) a span of `span` tokens starting at
    /// the sequence's current position will allocate. The scheduler sums
    /// this over a planned mixed batch to size chunk deferral.
    pub fn new_pages_for(&self, id: SeqId, span: usize) -> usize {
        let ps = self.page_size();
        match self.seqs.get(&id) {
            None => 0,
            Some(st) => (st.pos + span).div_ceil(ps) - st.pos.div_ceil(ps),
        }
    }

    /// True if a decode step for `id` cannot run out of pages.
    pub fn can_step(&self, id: SeqId) -> bool {
        match self.seqs.get(&id) {
            None => false,
            Some(st) => {
                let needs_page = st.pos % self.page_size() == 0;
                !needs_page || self.caches.iter().all(|c| c.free_pages() >= 1)
            }
        }
    }

    /// True when the next decode step for `id` must allocate a fresh page
    /// in every layer pool (the sequence sits on a page boundary). The
    /// scheduler sums this over a batch to size its preemption decision.
    pub fn needs_page(&self, id: SeqId) -> bool {
        self.seqs.get(&id).map(|s| s.pos % self.page_size() == 0).unwrap_or(false)
    }

    /// Admit a sequence and prefill its prompt; returns the logits after
    /// the final prompt token (for sampling the first output token).
    ///
    /// Single-layer models use the O(n) embedding-KV fast path (layer-0
    /// K/V is a pure function of the embedding, so only the final token
    /// needs the forward pass); deeper models run the prompt through
    /// [`Engine::step_batch`] in [`Engine::prefill_chunk`]-sized chunks —
    /// bit-exact for any chunk size. Either way the work is accounted to
    /// `stats.prefill_tokens`, not `stats.steps`, so decode step
    /// counts and the governor's TPOT view stay truthful.
    pub fn prefill(&mut self, id: SeqId, prompt: &[u32]) -> Result<Vec<f32>, CacheError> {
        assert!(!prompt.is_empty());
        let st = self.new_state();
        self.seqs.insert(id, st);
        if self.model.cfg.n_layers == 1 {
            // One map lookup and one pool borrow for the whole prompt
            // (these were per-token lookups before the loop was hoisted).
            let mut failed = None;
            {
                let st = self.seqs.get_mut(&id).expect("just inserted");
                let cache = &mut self.caches[0];
                for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate() {
                    let (k, v) = self.model.kv_from_embedding(tok, pos);
                    if let Err(e) = cache.append(&mut st.caches[0], &k, &v) {
                        failed = Some(e);
                        break;
                    }
                    st.pos = pos + 1;
                }
            }
            if let Some(e) = failed {
                self.release(id);
                return Err(e);
            }
            return self.step_chunk(id, &prompt[prompt.len() - 1..], true);
        }
        let chunk = self.prefill_chunk.max(1);
        let mut logits = Vec::new();
        let mut i = 0;
        while i < prompt.len() {
            let end = (i + chunk).min(prompt.len());
            logits = self.step_chunk(id, &prompt[i..end], end == prompt.len())?;
            i = end;
        }
        Ok(logits)
    }

    /// One decode step for a single sequence: process `tok` at the
    /// sequence's current position, return logits. A batch of one.
    pub fn decode(&mut self, id: SeqId, tok: u32) -> Result<Vec<f32>, CacheError> {
        self.run_batch(&DecodeBatch::single(id, tok)).pop().unwrap()
    }

    /// One prefill chunk through the mixed step (batch of one).
    fn step_chunk(&mut self, id: SeqId, toks: &[u32], last: bool) -> Result<Vec<f32>, CacheError> {
        self.run_batch(&DecodeBatch::chunk(id, toks.to_vec(), last)).pop().unwrap()
    }

    /// One batched mixed step: advance every item in `batch` by its span.
    /// Per-item results are returned in batch order (the logits of each
    /// item's final token); an item that runs out of pages mid-step gets
    /// `Err` and its sequence is released (the others are unaffected).
    pub fn step_batch(&mut self, batch: &DecodeBatch) -> Vec<Result<Vec<f32>, CacheError>> {
        self.run_batch(batch)
    }

    fn run_batch(&mut self, batch: &DecodeBatch) -> Vec<Result<Vec<f32>, CacheError>> {
        if batch.is_empty() {
            return Vec::new();
        }
        let model = self.model.clone();
        // Single-layer algebraic shortcut: a 1-layer model's logits only
        // ever read the *last* token's attention output, so non-final
        // chunk tokens (and every token of a non-final chunk) can skip
        // phase (b) entirely — the unified-step form of the historical
        // O(n) serial fast path, exact for n_layers == 1 only.
        let attend_skip: Vec<AttendSkip> = if model.cfg.n_layers == 1 {
            batch
                .items
                .iter()
                .map(|it| {
                    if !it.prefill {
                        AttendSkip::None
                    } else if it.last {
                        AttendSkip::AllButLast
                    } else {
                        AttendSkip::All
                    }
                })
                .collect()
        } else {
            vec![AttendSkip::None; batch.len()]
        };
        // Pull every sequence's state out of the map for the step: the
        // attention workers need disjoint per-sequence selector state.
        let mut sts: Vec<SeqState> = Vec::with_capacity(batch.len());
        // (start position, span) per item, plus the query-token offset of
        // each item in the step's flattened buffers.
        let mut spans: Vec<(usize, usize)> = Vec::with_capacity(batch.len());
        let mut offs: Vec<usize> = Vec::with_capacity(batch.len());
        // Attention-work proxy (Σ visible context per attended query
        // token — the same bandwidth model as the LPT; attend-skipped
        // tokens only pay context-independent projection work and count
        // 1) for decode/prefill wall-clock attribution.
        let mut decode_cost = 0u64;
        let mut prefill_cost = 0u64;
        let mut total_q = 0usize;
        for (idx, it) in batch.items.iter().enumerate() {
            let st = self.seqs.remove(&it.id).expect("unknown sequence");
            let span = it.toks.len();
            let cost: u64 = match attend_skip[idx] {
                AttendSkip::None => (0..span).map(|c| (st.pos + c + 1) as u64).sum(),
                AttendSkip::AllButLast => (span as u64 - 1) + (st.pos + span) as u64,
                AttendSkip::All => span as u64,
            };
            if it.prefill {
                prefill_cost += cost;
            } else {
                decode_cost += cost;
            }
            spans.push((st.pos, span));
            offs.push(total_q);
            total_q += span;
            sts.push(st);
        }
        let model_spans: Vec<SpanRef<'_>> = batch
            .items
            .iter()
            .zip(&spans)
            .map(|(it, &(pos, _))| SpanRef {
                toks: &it.toks,
                pos,
                need_logits: it.last || !it.prefill,
            })
            .collect();
        let directive = self.directive;
        // Pre-resolve every sub-call's attention plan for every layer,
        // serially, in (item, token, layer) order — the order a
        // token-at-a-time run visits them — so the dense/sparse
        // decisions, the budgets, and the global sparse-call labels
        // (which drive the recall-probe cadence) are identical for any
        // chunk size and any worker count. One SubSpec + call label per
        // (layer, query token); a sparse token owns `n_kv_heads`
        // consecutive labels per layer.
        let n_layers = model.cfg.n_layers;
        let kvn = model.cfg.n_kv_heads;
        let dense_below = directive.dense_below_override.unwrap_or(self.cfg.dense_below);
        // Sparse prefill: config opt-in, overridable either way by the
        // governor (the pressure ladder forces it on at level ≥ 2).
        let sp_enabled =
            directive.sparse_prefill_override.unwrap_or(self.cfg.sparse_prefill.is_some());
        // Stateful (dropping) selectors feed on the observation stream of
        // their sparse calls: only their would-be-dense sub-calls convert
        // to sparse prefill, so the selector sees the same call sequence.
        let sp_stateful = selector_wants_observation(self.cfg.selector);
        let blank = SubSpec { n: 0, dense: true, budget: 0, skip: true, sprefill: false };
        let mut subspecs: Vec<Vec<SubSpec>> =
            (0..n_layers).map(|_| vec![blank; total_q]).collect();
        let mut call_bases: Vec<Vec<u64>> = (0..n_layers).map(|_| vec![0u64; total_q]).collect();
        let mut call_idx = self.stats.sparse_calls;
        for (i, &(start, span)) in spans.iter().enumerate() {
            for cidx in 0..span {
                let n = start + cidx + 1;
                let skip = match attend_skip[i] {
                    AttendSkip::None => false,
                    AttendSkip::AllButLast => cidx + 1 != span,
                    AttendSkip::All => true,
                };
                for (l, (specs, bases)) in
                    subspecs.iter_mut().zip(call_bases.iter_mut()).enumerate()
                {
                    let dense = l < self.cfg.skip_layers
                        || n <= dense_below
                        || (self.cfg.selector == SelectorKind::Full
                            && self.cfg.twilight.is_none());
                    // Bound-guided sparse prefill replaces the dense
                    // context walk of a chunk query (and, for stateless
                    // selectors, the Select-then-Prune pipeline too).
                    // Short contexts stay dense: the gate would cover
                    // nothing and the envelope pass is pure overhead.
                    let sprefill = sp_enabled
                        && batch.items[i].prefill
                        && !skip
                        && n > dense_below
                        && (dense || !sp_stateful);
                    let mut budget = 0;
                    if !dense && !skip && !sprefill {
                        budget = self.cfg.budget.resolve(n);
                        if directive.budget_scale != 1.0 {
                            budget = ((budget as f32 * directive.budget_scale).round()
                                as usize)
                                .clamp(1, n);
                        }
                        bases[offs[i] + cidx] = call_idx;
                        call_idx += kvn as u64;
                    }
                    specs[offs[i] + cidx] =
                        SubSpec { n, dense: dense && !sprefill, budget, skip, sprefill };
                }
            }
        }
        let threads = self.pool.threads();
        if self.scratches.len() < threads {
            self.scratches.resize_with(threads, AttnScratch::default);
        }
        let staged_before = self.stats.t_select
            + self.stats.t_prune
            + self.stats.t_attend
            + self.stats.t_dense
            + self.stats.t_sprefill;
        let step = self.step_seq;
        self.step_seq += 1;
        // Tiered offload: advance the deterministic LRU clock (step
        // ordinal + 1 so a first-step touch differs from "never").
        for c in &self.caches {
            c.set_clock(step + 1);
        }
        let step_mark = trace::mark();
        let t0 = Instant::now();
        let probe_interval = self.signals.probe_interval();
        let mut backend = BatchStepBackend {
            caches: &mut self.caches,
            sts: &mut sts,
            errors: vec![None; batch.len()],
            cfg: &self.cfg,
            model: &model,
            stats: &mut self.stats,
            signals: &mut self.signals,
            directive,
            scratches: &mut self.scratches,
            out_pool: &mut self.out_pool,
            call_pool: &mut self.call_pool,
            plan_pool: &mut self.plan_pool,
            fault_batch: &mut self.fault_batch,
            pool: &self.pool,
            probe_interval,
            step,
            spans: &spans,
            offs: &offs,
            subspecs: &subspecs,
            call_bases: &call_bases,
            probes: Vec::new(),
        };
        let logits = model.decode_batch(&model_spans, &mut backend);
        let mut errors = backend.errors;
        self.stats.worker_panics +=
            errors.iter().filter(|e| **e == Some(CacheError::WorkerPanic)).count() as u64;
        // Replay buffered recall probes into the EMA in (token, layer,
        // kv-head) order — token-at-a-time order — instead of the
        // (layer, token) order the per-layer phase barriers produced
        // them in, so the probe EMA is chunk-size invariant too.
        let mut probes = backend.probes;
        probes.sort_unstable_by_key(|&(tok, layer, kvh, _)| (tok, layer, kvh));
        for &(_, _, _, recall) in &probes {
            self.signals.record_probe(recall);
        }
        // Tiered offload: evict down to the (pressure-scaled) residency
        // cap and refresh the cumulative offload totals from the
        // per-layer counters. Victim order is deterministic (step-clock
        // LRU), so the resident set entering the next step — and hence
        // that step's fault count — is thread-count invariant.
        let degrade = self.directive.degrade_level;
        let mut any_tier = false;
        let (mut faults, mut prefetched, mut evictions) = (0u64, 0u64, 0u64);
        let (mut bytes_faulted, mut spilled) = (0u64, 0u64);
        let (mut read_errs, mut write_errs, mut retries, mut lost) = (0u64, 0u64, 0u64, 0u64);
        for c in self.caches.iter_mut() {
            c.enforce_residency(degrade);
            if let Some(ts) = c.tier_state() {
                use std::sync::atomic::Ordering::Relaxed;
                any_tier = true;
                faults += ts.faults.load(Relaxed);
                prefetched += ts.prefetched.load(Relaxed);
                evictions += ts.evictions.load(Relaxed);
                bytes_faulted += ts.bytes_faulted.load(Relaxed);
                spilled += ts.spilled_writes.load(Relaxed);
                read_errs += ts.read_errors.load(Relaxed);
                write_errs += ts.write_errors.load(Relaxed);
                retries += ts.retries.load(Relaxed);
                lost += ts.lost_pages.load(Relaxed);
            }
        }
        if any_tier {
            self.stats.offload_faults = faults;
            self.stats.offload_prefetched = prefetched;
            self.stats.offload_evictions = evictions;
            self.stats.offload_bytes_faulted = bytes_faulted;
            self.stats.offload_spilled_pages = spilled;
            self.stats.tier_read_errors = read_errs;
            self.stats.tier_write_errors = write_errs;
            self.stats.tier_retries = retries;
            self.stats.pages_lost = lost;
            use std::sync::OnceLock;
            static FAULTS: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
            static EVICT: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
            static OVERLAP: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
            FAULTS
                .get_or_init(|| {
                    crate::obs::metrics::gauge(
                        "twilight_offload_faults",
                        "pages faulted in from the slow KV tier (cumulative)",
                    )
                })
                .set(faults as f64);
            EVICT
                .get_or_init(|| {
                    crate::obs::metrics::gauge(
                        "twilight_offload_evictions",
                        "sealed pages evicted to the slow KV tier (cumulative)",
                    )
                })
                .set(evictions as f64);
            OVERLAP
                .get_or_init(|| {
                    crate::obs::metrics::gauge(
                        "twilight_offload_overlap",
                        "fraction of tier faults performed by prefetch tickets \
                         (overlapped with attention) rather than demand reads",
                    )
                })
                .set(if faults == 0 { 0.0 } else { prefetched as f64 / faults as f64 });
            // Fault-domain gauges: registered only once a fault has
            // actually occurred, so fault-free runs (and their metric
            // dumps) are byte-identical to the pre-chaos engine.
            if read_errs + write_errs + retries + lost > 0 {
                static RERR: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
                static WERR: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
                static RETRY: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
                static LOST: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
                RERR.get_or_init(|| {
                    crate::obs::metrics::gauge(
                        "twilight_tier_read_errors",
                        "failed tier page reads, every retry attempt counted (cumulative)",
                    )
                })
                .set(read_errs as f64);
                WERR.get_or_init(|| {
                    crate::obs::metrics::gauge(
                        "twilight_tier_write_errors",
                        "failed tier page writes, every retry attempt counted (cumulative)",
                    )
                })
                .set(write_errs as f64);
                RETRY
                    .get_or_init(|| {
                        crate::obs::metrics::gauge(
                            "twilight_tier_retries",
                            "retry-ladder re-attempts after tier errors (cumulative)",
                        )
                    })
                    .set(retries as f64);
                LOST.get_or_init(|| {
                    crate::obs::metrics::gauge(
                        "twilight_pages_lost",
                        "KV pages declared lost after read-retry exhaustion (cumulative)",
                    )
                })
                .set(lost as f64);
            }
        }
        let total = t0.elapsed().as_secs_f64();
        trace::record_since(
            step_mark,
            trace::Stage::Step,
            trace::Tags { step: step as u32, ..trace::Tags::NONE },
        );
        // Mixed-step attribution: split the measured wall-clock by each
        // side's attention-work share.
        let cost_sum = decode_cost + prefill_cost;
        let decode_frac = if cost_sum == 0 { 0.0 } else { decode_cost as f64 / cost_sum as f64 };
        self.last_timing = StepTiming {
            total,
            decode: total * decode_frac,
            prefill: total * (1.0 - decode_frac),
        };
        self.stats.t_prefill += self.last_timing.prefill;
        if decode_cost > 0 {
            self.stats.steps += 1;
        }
        for it in &batch.items {
            if it.prefill {
                self.stats.prefill_tokens += it.toks.len() as u64;
                self.stats.prefill_chunks += 1;
            }
        }
        // Everything not attributed to a stage is "other" (projections,
        // MLP, norms, unembedding).
        let staged_after = self.stats.t_select
            + self.stats.t_prune
            + self.stats.t_attend
            + self.stats.t_dense
            + self.stats.t_sprefill;
        self.stats.t_other += (total - (staged_after - staged_before)).max(0.0);
        let mut results = Vec::with_capacity(batch.len());
        for (i, (mut st, lg)) in sts.into_iter().zip(logits).enumerate() {
            // Lost-page scan: a page can go LOST on a *prefetch* ticket
            // (no attention item ever reads it, so no error surfaced
            // inline) or during a tier detach (`pending_lost`). Any
            // sequence touching such a page must fail — decoding over a
            // zero-filled page would be silently wrong.
            if errors[i].is_none() {
                let hit = st.caches.iter().enumerate().any(|(layer, sc)| {
                    self.caches[layer].has_lost_page(sc)
                        || (!self.pending_lost.is_empty()
                            && sc.pages.iter().any(|p| {
                                self.pending_lost.binary_search(&(layer, *p)).is_ok()
                            }))
                });
                if hit {
                    errors[i] = Some(CacheError::PageLost);
                }
            }
            match errors[i].take() {
                Some(e) => {
                    // The sequence is already out of the map; return its
                    // pages to the pools.
                    for (layer, sc) in st.caches.iter().enumerate() {
                        self.prune_pending_lost(layer, sc);
                        self.caches[layer].release(sc);
                    }
                    results.push(Err(e));
                }
                None => {
                    st.pos += spans[i].1;
                    self.seqs.insert(batch.items[i].id, st);
                    results.push(Ok(lg));
                }
            }
        }
        results
    }

    /// Release a sequence's pages and state.
    pub fn release(&mut self, id: SeqId) {
        if let Some(st) = self.seqs.remove(&id) {
            for (layer, sc) in st.caches.iter().enumerate() {
                self.prune_pending_lost(layer, sc);
                self.caches[layer].release(sc);
            }
        }
    }

    /// Drop `pending_lost` entries owned by a sequence being released:
    /// the pages return to the free pool and their next allocation
    /// starts clean (mirrors `alloc_page` resetting `PAGE_LOST`).
    fn prune_pending_lost(&mut self, layer: usize, sc: &SeqCache) {
        if self.pending_lost.is_empty() {
            return;
        }
        self.pending_lost
            .retain(|&(l, p)| l != layer || !sc.pages.contains(&p));
    }

    /// Reset statistics (between bench phases).
    pub fn reset_stats(&mut self) {
        self.stats = EngineStats::default();
    }
}

/// Which phase-(b) sub-calls of an item the single-layer algebraic
/// shortcut elides (see [`Engine::prefill`]; `None` for deep models).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum AttendSkip {
    /// Attend every sub-call (decode items, all multi-layer items).
    None,
    /// Final chunk of a 1-layer prompt: only the last token's logits are
    /// read, so only its sub-call attends.
    AllButLast,
    /// Non-final chunk of a 1-layer prompt: no token's attention output
    /// is ever read — skip the whole item.
    All,
}

/// The batched per-step attention backend: implements the three-phase
/// Select-then-Prune pipeline for every layer of one mixed step.
struct BatchStepBackend<'a> {
    caches: &'a mut [PagedKvCache],
    sts: &'a mut [SeqState],
    errors: Vec<Option<CacheError>>,
    cfg: &'a SparseConfig,
    model: &'a Model,
    stats: &'a mut EngineStats,
    signals: &'a mut SignalHub,
    directive: BudgetDirective,
    scratches: &'a mut [AttnScratch],
    /// Recycled work-item output / telemetry buffers (engine-owned).
    out_pool: &'a mut Vec<Vec<f32>>,
    call_pool: &'a mut Vec<Vec<CallOut>>,
    /// Recycled prefetch-plan buffers (engine-owned, tiered offload).
    plan_pool: &'a mut Vec<PrefetchPlan>,
    /// Cross-item fault batch (engine-owned; see [`Engine::fault_batch`]).
    fault_batch: &'a mut Vec<PageId>,
    pool: &'a ThreadPool,
    probe_interval: u64,
    /// Engine step ordinal — the `step` span tag for this batch's spans.
    step: u64,
    /// (start position, span) per batch item.
    spans: &'a [(usize, usize)],
    /// Query-token offset of each item in the flattened step buffers.
    offs: &'a [usize],
    /// Pre-resolved sub-call plans, `[layer][query token]` (built by
    /// `run_batch` in (item, token, layer) order).
    subspecs: &'a [Vec<SubSpec>],
    /// Global sparse-call label of each (layer, query token)'s kvn-block.
    call_bases: &'a [Vec<u64>],
    /// Recall probes buffered across the step's layers, keyed
    /// `(query token, layer, kv-head, recall)`; `run_batch` replays them
    /// into the EMA in token-major order at the end of the step.
    probes: Vec<(usize, usize, usize, f64)>,
}

/// Per-sub-call attention plan for one query token of an item, resolved
/// serially up front (see `run_batch`) so the dense/sparse decision,
/// the budget, and the probe cadence are identical for any worker count
/// *and* any chunk size.
#[derive(Clone, Copy, Debug)]
struct SubSpec {
    /// Visible context length for this query (its own position + 1).
    n: usize,
    dense: bool,
    /// Resolved stage-1 budget (sparse sub-calls only).
    budget: usize,
    /// Elided by the single-layer algebraic shortcut.
    skip: bool,
    /// Bound-guided sparse-prefill sub-call (`attention::prefill`):
    /// mutually exclusive with `dense`, consumes no sparse-call label.
    sprefill: bool,
}

/// One unit of phase-(b) attention work: an (item, kv-head) pair —
/// multi-query when the item is a prefill chunk. Sub-calls execute
/// serially on one worker, in chunk order (the selector state is
/// stateful and order-sensitive).
struct AttnItem<'a> {
    /// Flattened index (`item * n_kv_heads + kv_head`): the deterministic
    /// merge order at the phase barrier.
    flat: usize,
    seq: usize,
    kv_head: usize,
    layer: usize,
    /// Position of the first query token (visible context of sub-call
    /// `c` is `start + c + 1`).
    start: usize,
    /// One entry per query token of the span.
    subs: &'a [SubSpec],
    /// Per-sub-call global sparse-call labels (kvn-block bases, aligned
    /// with `subs`; this head adds its own offset). Assigned serially in
    /// (item, token, layer) order by `run_batch`, so the recall-probe
    /// cadence is identical for any worker count and any chunk size.
    call_bases: &'a [u64],
    selector: &'a mut Box<dyn TokenSelector>,
    cache: &'a PagedKvCache,
    seq_cache: &'a SeqCache,
    /// The item's query rows, `[span * q_dim]` (the worker slices out
    /// this KV group per sub-call).
    qs: &'a [f32],
    /// Recycled output buffer (pre-sized `span * group * d`, zeroed) —
    /// becomes `AttnItemOut::out` and returns to the engine's pool after
    /// the merge.
    out: Vec<f32>,
    /// Recycled per-call telemetry buffer (cleared).
    calls: Vec<CallOut>,
}

/// Per-sparse-sub-call record, re-ordered token-major at the barrier.
#[derive(Clone, Copy)]
struct CallOut {
    /// Chunk offset of the sub-call within its item.
    cidx: usize,
    candidates: usize,
    kept: usize,
    /// `(layer, mean mass, keep ratio)` when the pruner ran.
    prune_record: Option<(usize, f64, f64)>,
    probe: Option<f64>,
    /// Hier-pages accounting: candidate page runs skipped / seen (0/0
    /// when the pre-prune is off).
    hier_skipped: u32,
    hier_total: u32,
    /// Sparse-prefill sub-call: routed to the `prefill_blocks_*`
    /// counters only — it is *not* a sparse call (no candidates/kept
    /// telemetry, no label, no probe).
    sprefill: bool,
    /// Gated pages skipped / considered, summed over the group's heads.
    sp_skipped: u32,
    sp_total: u32,
}

/// The result of one attention work item, merged at the phase barrier in
/// `flat` order (sub-calls in chunk order) so stats and telemetry are
/// deterministic under any worker count.
struct AttnItemOut {
    flat: usize,
    seq: usize,
    kv_head: usize,
    /// `[span * group * head_dim]`, chunk-offset-major.
    out: Vec<f32>,
    t_select: f64,
    t_prune: f64,
    t_attend: f64,
    t_dense: f64,
    t_sprefill: f64,
    bytes_select: u64,
    bytes_prune: u64,
    bytes_attend: u64,
    calls: Vec<CallOut>,
}

/// Per-worker execution state: the items LPT assigned to this worker,
/// its private attention scratch arena, and the results it produced.
struct WorkerCell<'a> {
    items: Vec<AttnItem<'a>>,
    scratch: AttnScratch,
    /// `Ok` = the item's attention output; `Err((flat, seq))` = the item
    /// panicked mid-run and was quarantined — the merge fails sequence
    /// `seq` with `CacheError::WorkerPanic` while every sibling item's
    /// result lands normally.
    results: Vec<Result<AttnItemOut, (usize, usize)>>,
}

impl BatchBackend for BatchStepBackend<'_> {
    fn append_kv(&mut self, layer: usize, idx: usize, k: &[f32], v: &[f32]) {
        if self.errors[idx].is_some() {
            return;
        }
        if let Err(e) = self.caches[layer].append(&mut self.sts[idx].caches[layer], k, v) {
            self.errors[idx] = Some(e);
        }
    }

    fn is_failed(&self, idx: usize) -> bool {
        self.errors[idx].is_some()
    }

    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32]) {
        let c = &self.model.cfg;
        let d = c.head_dim;
        let group = c.group();
        let kvn = c.n_kv_heads;
        let qd = c.q_dim();
        out.fill(0.0); // failed and attend-skipped tokens stay zero
        // --- flatten (item × kv-head) work items, item-major -----------
        // Sub-call plans and call labels were pre-resolved by `run_batch`
        // in (item, token, layer) order; this phase only slices its
        // layer's tables.
        let specs = &self.subspecs[layer];
        let bases = &self.call_bases[layer];
        let mut flat_items: Vec<Option<AttnItem<'_>>> =
            Vec::with_capacity(self.sts.len() * kvn);
        let mut work: Vec<balance::WorkItem> = Vec::with_capacity(self.sts.len() * kvn);
        // Tiered offload: one hier-bound prefetch plan per item (the
        // bound maxes over every kv/group head, so the plan covers all
        // of the item's work units). Built serially before the phase so
        // the planned set is a pure function of deterministic state.
        let mut plans: Vec<PrefetchPlan> = Vec::new();
        let cache = &self.caches[layer];
        let tiered = cache.tier_state().is_some();
        let ps = cache.cfg.page_size;
        // Sparse-prefill LPT weight pieces: one shared bound/envelope
        // pass (≈ window + a page of suffix bookkeeping) per item, plus
        // the expected visited fraction of each sub-call's context
        // (documented ¼ — the same scale the decode budget fraction
        // uses; exact visit counts are data-dependent and unknowable
        // before the kernel runs).
        let sp_w = self.cfg.sparse_prefill.unwrap_or_default().window;
        for (i, st) in self.sts.iter_mut().enumerate() {
            if self.errors[i].is_some() {
                flat_items.extend((0..kvn).map(|_| None));
                continue;
            }
            let (start, span) = self.spans[i];
            let subs = &specs[self.offs[i]..self.offs[i] + span];
            if subs.iter().all(|s| s.skip) {
                flat_items.extend((0..kvn).map(|_| None));
                continue;
            }
            let item_bases = &bases[self.offs[i]..self.offs[i] + span];
            let seq_cache = &st.caches[layer];
            if tiered {
                // Rank this item's non-resident sealed pages by the last
                // attended token's hier bound; a dense sub-call reads
                // everything, so it lifts the mass floor to 0.
                if let Some(cidx) = (0..span).rev().find(|&cc| !subs[cc].skip) {
                    let eps = if subs.iter().any(|s| !s.skip && s.dense) {
                        0.0
                    } else {
                        PREFETCH_EPS_FRAC
                    };
                    let mut plan = self.plan_pool.pop().unwrap_or_default();
                    plan.reserve(cache.cfg.num_pages, kvn * group);
                    let qtok =
                        &qs[(self.offs[i] + cidx) * qd..(self.offs[i] + cidx + 1) * qd];
                    cache.plan_prefetch_into(seq_cache, qtok, group, eps, &mut plan);
                    if plan.pages.is_empty() {
                        self.plan_pool.push(plan);
                    } else {
                        plans.push(plan);
                    }
                }
            }
            // Cost model: the kernels are bandwidth-bound, so the token
            // count to stream — summed over the chunk's sub-calls
            // (≈ span × context) — is the LPT weight.
            let cost: usize = subs
                .iter()
                .filter(|s| !s.skip)
                .map(|s| {
                    if s.sprefill {
                        sp_w + ps + s.n / 4
                    } else if s.dense {
                        s.n
                    } else {
                        s.budget
                    }
                })
                .sum();
            let sel_base = layer * kvn;
            for (kvh, selector) in st.selectors[sel_base..sel_base + kvn].iter_mut().enumerate() {
                let flat = i * kvn + kvh;
                work.push(balance::WorkItem {
                    seq: i as u32,
                    kv_head: kvh as u32,
                    budget: cost,
                });
                // Recycled result buffers: popped here, pushed back after
                // the merge — steady state allocates nothing per item.
                let mut out_buf = self.out_pool.pop().unwrap_or_default();
                out_buf.clear();
                out_buf.resize(span * group * d, 0.0);
                let mut calls_buf = self.call_pool.pop().unwrap_or_default();
                calls_buf.clear();
                flat_items.push(Some(AttnItem {
                    flat,
                    seq: i,
                    kv_head: kvh,
                    layer,
                    start,
                    subs,
                    call_bases: item_bases,
                    selector,
                    cache,
                    seq_cache,
                    qs: &qs[self.offs[i] * qd..(self.offs[i] + span) * qd],
                    out: out_buf,
                    calls: calls_buf,
                }));
            }
        }
        let n_items = flat_items.len();
        // --- LPT partition over the worker pool ------------------------
        let workers = self.pool.threads().min(work.len()).max(1);
        let loads = balance::lpt_partition(&work, workers);
        let mut cells: Vec<Mutex<WorkerCell<'_>>> = Vec::with_capacity(loads.len());
        for (w, load) in loads.iter().enumerate() {
            let mut items = Vec::with_capacity(load.items.len());
            for wi in &load.items {
                let flat = wi.seq as usize * kvn + wi.kv_head as usize;
                items.push(flat_items[flat].take().expect("work item double-assigned"));
            }
            cells.push(Mutex::new(WorkerCell {
                items,
                scratch: std::mem::take(&mut self.scratches[w]),
                results: Vec::new(),
            }));
        }
        // --- parallel execution (worker w drains exactly cell w) -------
        let cfg = self.cfg;
        let mcfg = c;
        let directive = self.directive;
        let probe_interval = self.probe_interval;
        let step = self.step;
        // Caller-thread span context: pool-round spans recorded inside
        // `ThreadPool::run` inherit the (step, layer) tags.
        trace::set_ctx(trace::Tags {
            step: step as u32,
            layer: layer as u16,
            ..trace::Tags::NONE
        });
        let phase_t0 = Instant::now();
        // One pool round per layer: the resident workers (spawned once,
        // on the engine's first parallel round) wake, drain exactly one
        // bucket each (chunk = 1, one ticket per LPT bucket), and park
        // again — the spawn/join cost that used to scale with
        // layers × steps is amortized to zero here.
        //
        // The prefetch ticket goes FIRST: with a tier attached, the
        // planned non-resident pages start faulting before (and
        // concurrently with) the attention buckets, so tier I/O overlaps
        // attention on already-resident pages. At threads == 1 the
        // inline path runs it sequentially ahead of the buckets — the
        // reference order. Either way the step's *resident set* ends
        // identical: demand reads fault whatever prefetch has not
        // finished (the CAS admits exactly one loader per page), so only
        // the prefetch/demand split is timing-dependent, never the
        // faulted set.
        //
        // Cross-item fault batching: every item's planned pages fuse
        // into ONE offset-sorted, deduped batch dispatched as a single
        // ticket — the backing tier sees one ascending positional sweep
        // per (step, layer) instead of per-item ticket bursts seeking
        // independently. (Cross-*layer* batching is impossible: layer
        // l+1's queries depend on layer l's outputs, so its plans cannot
        // exist yet.) Per-page CAS semantics are unchanged.
        self.fault_batch.clear();
        for plan in &plans {
            self.fault_batch.extend_from_slice(&plan.pages);
        }
        self.fault_batch.sort_unstable();
        self.fault_batch.dedup();
        let batch_pages = self.fault_batch.as_slice();
        let n_tickets = usize::from(!batch_pages.is_empty());
        self.pool.run(n_tickets + cells.len(), 1, |w| {
            if w < n_tickets {
                cache.prefetch_pages(batch_pages);
                return;
            }
            let w = w - n_tickets;
            let mut guard = cells[w].lock().expect("attention worker poisoned");
            let WorkerCell { items, scratch, results } = &mut *guard;
            results.reserve(items.len());
            for item in items.drain(..) {
                // Per-item failure containment: a panic inside one work
                // item (poisoned request state, injected chaos panic
                // escaping the fault funnel) quarantines that item only —
                // siblings in the same bucket keep running and the pool
                // round completes normally. The scratch arena is safe to
                // reuse after an unwind: every run_attn_item clears or
                // resizes each buffer before reading it.
                let (flat, seq) = (item.flat, item.seq);
                let out = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    run_attn_item(cfg, mcfg, directive, probe_interval, step, item, scratch)
                }));
                results.push(out.map_err(|_| (flat, seq)));
            }
        });
        for plan in plans {
            self.plan_pool.push(plan);
        }
        let phase_wall = phase_t0.elapsed().as_secs_f64();
        // --- deterministic merge at the phase barrier ------------------
        let mut merged: Vec<Option<AttnItemOut>> = (0..n_items).map(|_| None).collect();
        for (w, cell) in cells.into_iter().enumerate() {
            let cell = cell.into_inner().expect("attention worker poisoned");
            self.scratches[w] = cell.scratch;
            for r in cell.results {
                match r {
                    Ok(r) => {
                        let flat = r.flat;
                        merged[flat] = Some(r);
                    }
                    Err((_, seq)) => {
                        // First error wins (matches append_kv's policy);
                        // the item's recycled buffers died with the
                        // unwind — the pools just re-allocate later.
                        if self.errors[seq].is_none() {
                            self.errors[seq] = Some(CacheError::WorkerPanic);
                        }
                    }
                }
            }
        }
        let mut calls_by_flat: Vec<Vec<CallOut>> = (0..n_items).map(|_| Vec::new()).collect();
        let mut busy = 0.0f64;
        for r in merged.into_iter().flatten() {
            // Scatter the item's sub-call outputs back into the step's
            // token-major buffer; time/byte sums merge in flat order.
            let span = r.out.len() / (group * d);
            for cidx in 0..span {
                let base = (self.offs[r.seq] + cidx) * qd + r.kv_head * group * d;
                out[base..base + group * d]
                    .copy_from_slice(&r.out[cidx * group * d..(cidx + 1) * group * d]);
            }
            self.out_pool.push(r.out);
            self.stats.t_select += r.t_select;
            self.stats.t_prune += r.t_prune;
            self.stats.t_attend += r.t_attend;
            self.stats.t_dense += r.t_dense;
            self.stats.t_sprefill += r.t_sprefill;
            busy += r.t_select + r.t_prune + r.t_attend + r.t_dense + r.t_sprefill;
            self.stats.est_bytes_select += r.bytes_select;
            self.stats.est_bytes_prune += r.bytes_prune;
            self.stats.est_bytes_attend += r.bytes_attend;
            calls_by_flat[r.flat] = r.calls;
        }
        // Worker utilization of this attention phase: staged busy time
        // over workers × wall (an estimate — per-item overhead outside
        // the staged timers counts as idle). Last-write-wins gauge; a
        // scrape sees the most recent layer round.
        if phase_wall > 0.0 && workers > 0 {
            use std::sync::OnceLock;
            static UTIL: OnceLock<&'static crate::obs::metrics::Gauge> = OnceLock::new();
            let g = UTIL.get_or_init(|| {
                crate::obs::metrics::gauge(
                    "twilight_worker_utilization",
                    "staged busy time / (workers x wall) of the latest attention phase",
                )
            });
            g.set((busy / (workers as f64 * phase_wall)).min(1.0));
        }
        // Per-call telemetry records in (item, token, kv-head) order —
        // the same sequence token-at-a-time processing produces, so the
        // per-layer SignalHub rings (and hence a governor steering on
        // them) are chunk-size invariant, not just worker-count
        // invariant. Every kv-head of an item shares one sub-call plan,
        // so the per-head call counts line up by construction. Recall
        // probes are only *buffered* here (keyed by token/layer/head);
        // `run_batch` replays them into the global EMA in token-major
        // order once every layer has run.
        for i in 0..self.sts.len() {
            let ncalls =
                (0..kvn).map(|k| calls_by_flat[i * kvn + k].len()).max().unwrap_or(0);
            for cc in 0..ncalls {
                for k in 0..kvn {
                    let Some(&call) = calls_by_flat[i * kvn + k].get(cc) else { continue };
                    if call.sprefill {
                        // Sparse-prefill sub-calls feed only the block
                        // counters — they are not sparse calls, consume
                        // no labels, and must not skew kept/candidate
                        // telemetry or the kept histogram.
                        self.stats.prefill_blocks_skipped += call.sp_skipped as u64;
                        self.stats.prefill_blocks_total += call.sp_total as u64;
                        continue;
                    }
                    self.stats.sparse_calls += 1;
                    self.stats.candidates_sum += call.candidates as u64;
                    self.stats.kept_sum += call.kept as u64;
                    self.stats.kept_hist.add(call.kept as f64);
                    if call.hier_total > 0 {
                        self.stats.hier_pages_skipped += call.hier_skipped as u64;
                        self.stats.hier_pages_total += call.hier_total as u64;
                        self.signals
                            .record_hier(call.hier_skipped as u64, call.hier_total as u64);
                    }
                    if let Some((lay, mass, ratio)) = call.prune_record {
                        self.signals.record_prune(lay, mass, ratio);
                    }
                    if let Some(recall) = call.probe {
                        self.probes.push((self.offs[i] + call.cidx, layer, k, recall));
                    }
                }
            }
        }
        // Return the per-call telemetry vectors to the recycle pool
        // (capacity-0 vectors never allocated; dropping them is free).
        for calls in calls_by_flat {
            if calls.capacity() > 0 {
                self.call_pool.push(calls);
            }
        }
    }
}

/// Execute one (item, kv-head) attention work item. Each sub-call runs
/// dense paged attention (skip-layers / short visible contexts) or the
/// full select → prune → varlen-attend pipeline, over the sub-call's
/// *visible prefix* (a truncated [`SeqCache`] view for mid-chunk
/// queries — the final sub-call sees the real per-sequence cache, so
/// pure decode items never clone). Runs on a worker thread with
/// read-only cache access; everything mutable is item-private.
fn run_attn_item(
    cfg: &SparseConfig,
    c: &ModelConfig,
    directive: BudgetDirective,
    probe_interval: u64,
    step: u64,
    item: AttnItem<'_>,
    scratch: &mut AttnScratch,
) -> AttnItemOut {
    let AttnItem {
        flat,
        seq: seq_idx,
        kv_head,
        layer,
        start,
        subs,
        call_bases,
        selector,
        cache,
        seq_cache,
        qs,
        out: item_out,
        calls: item_calls,
    } = item;
    let d = c.head_dim;
    let group = c.group();
    let qd = c.q_dim();
    let span = subs.len();
    debug_assert_eq!(item_out.len(), span * group * d);
    // Worker-thread span context: every stage span this item records
    // (here and inside the pruner) carries the full tag set.
    trace::set_ctx(trace::Tags {
        step: step as u32,
        seq: seq_idx as u32,
        layer: layer as u16,
        kv_head: kv_head as u16,
    });
    let mut r = AttnItemOut {
        flat,
        seq: seq_idx,
        kv_head,
        out: item_out,
        t_select: 0.0,
        t_prune: 0.0,
        t_attend: 0.0,
        t_dense: 0.0,
        t_sprefill: 0.0,
        bytes_select: 0,
        bytes_prune: 0,
        bytes_attend: 0,
        calls: item_calls,
    };
    // Whole-item dense fast path: one multi-query causal kernel call
    // (bit-exact with the per-sub-call loop below — same walk, same
    // order — it just skips the per-call dispatch).
    if subs.iter().all(|s| s.dense && !s.skip) {
        let t = Instant::now();
        crate::attention::full::paged_full_causal(
            cache,
            seq_cache,
            kv_head,
            &qs[kv_head * group * d..],
            qd,
            group,
            start,
            &mut r.out,
        );
        let el = t.elapsed();
        r.t_dense = el.as_secs_f64();
        trace::record_ctx(trace::Stage::DenseAttend, el);
        r.bytes_attend = subs.iter().map(|s| crate::sim::attn_bytes(s.n, d) as u64).sum();
        return r;
    }
    let ps = cache.cfg.page_size;
    // --- bound-guided sparse prefill ----------------------------------
    // All flagged sub-calls of this (item, kv-head) run as ONE kernel
    // call sharing a single envelope/bound pass (DESIGN.md §13): the
    // per-page upper bound is evaluated once over the coordinate
    // envelope of every active query row, then each query early-stops
    // independently on the hier top-p test.
    if subs.iter().any(|s| s.sprefill) {
        let sp = cfg.sparse_prefill.unwrap_or_default();
        let mut active = std::mem::take(&mut scratch.sprefill.active);
        active.clear();
        active.extend(
            subs.iter().enumerate().filter(|(_, s)| s.sprefill).map(|(cc, _)| cc),
        );
        let t = Instant::now();
        let sps = crate::attention::prefill::sparse_prefill_causal(
            cache,
            seq_cache,
            kv_head,
            &qs[kv_head * group * d..],
            qd,
            group,
            start,
            &active,
            sp.eps,
            sp.window,
            &mut r.out,
            &mut scratch.sprefill,
        );
        let el = t.elapsed();
        r.t_sprefill += el.as_secs_f64();
        trace::record_ctx(trace::Stage::SparsePrefill, el);
        let gated = sps.gated_pages;
        for (ai, &cc) in active.iter().enumerate() {
            let vis = &scratch.sprefill.visited[ai * group..(ai + 1) * group];
            // The group's visited sets are prefixes of one shared page
            // order, so their union is the longest prefix (max).
            let vmax = vis.iter().copied().max().unwrap_or(0) as usize;
            let skipped: u32 = vis.iter().map(|&v| gated as u32 - v).sum();
            r.bytes_attend +=
                crate::sim::attn_bytes(subs[cc].n - gated * ps + vmax * ps, d) as u64;
            r.calls.push(CallOut {
                cidx: cc,
                candidates: 0,
                kept: 0,
                prune_record: None,
                probe: None,
                hier_skipped: 0,
                hier_total: 0,
                sprefill: true,
                sp_skipped: skipped,
                sp_total: (gated * group) as u32,
            });
        }
        scratch.sprefill.active = active;
    }
    // Truncated visible-prefix view for mid-chunk sub-calls, built
    // lazily and grown monotonically (sub-calls see increasing n).
    let mut view: Option<SeqCache> = None;
    for (cidx, spec) in subs.iter().enumerate() {
        if spec.skip || spec.sprefill {
            continue;
        }
        let n = spec.n;
        let qs_group = &qs[cidx * qd + kv_head * group * d..cidx * qd + (kv_head + 1) * group * d];
        let out = &mut r.out[cidx * group * d..(cidx + 1) * group * d];
        if spec.dense {
            let t = Instant::now();
            for g in 0..group {
                crate::attention::full::paged_full_limit(
                    cache,
                    seq_cache,
                    kv_head,
                    &qs_group[g * d..(g + 1) * d],
                    n,
                    &mut out[g * d..(g + 1) * d],
                );
            }
            let el = t.elapsed();
            r.t_dense += el.as_secs_f64();
            trace::record_ctx(trace::Stage::DenseAttend, el);
            r.bytes_attend += crate::sim::attn_bytes(n, d) as u64;
            continue;
        }
        // Selectors and the pruner read `seq.len` / `seq.pages`: hand
        // them the visible prefix only. With the sealing contract this
        // makes every sub-call a pure function of that prefix — chunk-
        // size invariant.
        let seq: &SeqCache = if n == seq_cache.len {
            seq_cache
        } else {
            let v = view.get_or_insert_with(|| SeqCache {
                pages: Vec::with_capacity(seq_cache.pages.len()),
                len: 0,
            });
            v.len = n;
            let np = n.div_ceil(ps);
            while v.pages.len() < np {
                v.pages.push(seq_cache.pages[v.pages.len()]);
            }
            &*v
        };
        let budget = spec.budget;
        // Pre-assigned token-major label: sparse token `c` owns a block
        // of kvn consecutive labels, this head takes its slot within it.
        let call_idx = call_bases[cidx] + kv_head as u64;
        let mut call = CallOut {
            cidx,
            candidates: 0,
            kept: 0,
            prune_record: None,
            probe: None,
            hier_skipped: 0,
            hier_total: 0,
            sprefill: false,
            sp_skipped: 0,
            sp_total: 0,
        };
        // --- stage 1: Token Selector (black box, conservative) --------
        // Candidates land in the arena's reused buffer (taken out for
        // the duration of this sub-call so the pruner can borrow the
        // rest of the arena).
        let mut cands = std::mem::take(&mut scratch.candidates);
        let t = Instant::now();
        selector.select_into(cache, seq, kv_head, qs_group, group, budget, &mut cands);
        let el = t.elapsed();
        r.t_select += el.as_secs_f64();
        trace::record_ctx(trace::Stage::Select, el);
        r.bytes_select += selector_bytes(cfg.selector, n, d) as u64;
        // --- stage 2: Twilight Pruner ---------------------------------
        // Results stay in the arena: `scratch.union` (keep-set union)
        // and `scratch.outcomes` (per-head, buffers recycled).
        let mut pruned = false;
        if let Some(pc) = &cfg.twilight {
            // The governor's p multiplier, clamped so even a
            // maximally-degraded directive keeps a real top-p; the
            // hier-pages override toggles the page-level pre-prune.
            let pc = PrunerConfig {
                p: (pc.p * directive.p_scale).clamp(0.05, 0.999),
                hier_pages: directive.hier_pages_override.unwrap_or(pc.hier_pages),
                ..*pc
            };
            let t = Instant::now();
            let info =
                prune_group_into(&pc, cache, seq, kv_head, qs_group, group, &cands, scratch);
            let el = t.elapsed();
            r.t_prune += el.as_secs_f64();
            trace::record_ctx(trace::Stage::Prune, el);
            r.bytes_prune +=
                crate::sim::spgemv_bytes(cands.len(), d, cache.cfg.mirror_bits) as u64;
            call.hier_skipped = info.pages_skipped;
            call.hier_total = info.pages_total;
            // Governor telemetry: per-layer captured mass and keep
            // ratio, plus the periodic dense recall probe on the
            // group's first query head (cadence from the call label
            // pre-assigned in token-major order by run_batch).
            if !cands.is_empty() {
                let mean_mass = scratch.outcomes.iter().map(|o| o.mass as f64).sum::<f64>()
                    / scratch.outcomes.len().max(1) as f64;
                let keep_ratio = scratch.union.len() as f64 / cands.len() as f64;
                call.prune_record = Some((layer, mean_mass, keep_ratio));
                if probe_interval > 0 && call_idx % probe_interval == 0 {
                    call.probe = Some(probe_recall(
                        cache,
                        seq,
                        kv_head,
                        &qs_group[..d],
                        &cands,
                        &scratch.outcomes[0].kept,
                        pc.p,
                    ));
                }
            }
            pruned = true;
        }
        let kept_union = std::mem::take(&mut scratch.union);
        let kept: &[usize] = if pruned { &kept_union } else { &cands };
        call.candidates = cands.len();
        call.kept = kept.len();
        // --- stage 3: sparse attention kernel -------------------------
        let t = Instant::now();
        match cfg.attn {
            AttnVariant::GroupVarlen => {
                crate::attention::sparse::group_varlen_with(
                    cache,
                    seq,
                    kv_head,
                    qs_group,
                    group,
                    kept,
                    &mut scratch.attn_m,
                    &mut scratch.attn_denom,
                    out,
                );
            }
            AttnVariant::HeadVarlen => {
                for g in 0..group {
                    crate::attention::sparse::head_varlen(
                        cache,
                        seq,
                        kv_head,
                        &qs_group[g * d..(g + 1) * d],
                        kept,
                        &mut out[g * d..(g + 1) * d],
                    );
                }
            }
            AttnVariant::Padded => {
                let max_budget = budget.max(kept.len());
                for g in 0..group {
                    crate::attention::sparse::padded(
                        cache,
                        seq,
                        kv_head,
                        &qs_group[g * d..(g + 1) * d],
                        kept,
                        max_budget,
                        &mut out[g * d..(g + 1) * d],
                    );
                }
            }
        }
        let el = t.elapsed();
        r.t_attend += el.as_secs_f64();
        trace::record_ctx(trace::Stage::SparseAttend, el);
        r.bytes_attend += crate::sim::attn_bytes(kept.len(), d) as u64;
        // --- feedback for stateful (dropping) selectors ---------------
        if selector_wants_observation(cfg.selector) {
            // Reuse the pruner's estimated per-head weights instead of
            // re-scoring in exact fp32: every kept (union) token is
            // observed with its group-aggregated estimated attention, so
            // a token any query head attends to stays visible to the
            // dropping selector. Fall back to exact scores only when no
            // pruner ran (baseline mode) or it short-circuited without
            // scoring (candidates ≤ min_keep, where the exact pass is a
            // handful of dot products).
            let scored = pruned
                && scratch.outcomes.iter().all(|o| o.weights.len() == o.kept.len())
                && scratch.outcomes.iter().any(|o| !o.weights.is_empty());
            if scored {
                scratch.obs_w.clear();
                scratch.obs_w.resize(kept.len(), 0.0);
                for o in scratch.outcomes.iter() {
                    for (t, &x) in o.kept.iter().zip(&o.weights) {
                        if let Ok(j) = kept.binary_search(t) {
                            scratch.obs_w[j] += x;
                        }
                    }
                }
                let sum: f32 = scratch.obs_w.iter().sum();
                if sum > 0.0 {
                    let inv = 1.0 / sum;
                    for x in scratch.obs_w.iter_mut() {
                        *x *= inv;
                    }
                }
                selector.observe(kept, &scratch.obs_w);
            } else {
                scratch.obs_w.clear();
                scratch.obs_w.extend(kept.iter().map(|&t| {
                    cache.exact_score(seq, kv_head, &qs_group[..d], t)
                        * crate::attention::scale(d)
                }));
                crate::tensor::softmax_inplace(&mut scratch.obs_w);
                selector.observe(kept, &scratch.obs_w);
            }
        }
        // Return the taken buffers to the arena for the next sub-call.
        scratch.union = kept_union;
        scratch.candidates = cands;
        r.calls.push(call);
    }
    r
}

/// Estimated selector metadata traffic (bytes) for the sim cost model.
fn selector_bytes(kind: SelectorKind, n: usize, d: usize) -> usize {
    match kind {
        SelectorKind::Quest => crate::sim::quest_meta_bytes(n, d, 16),
        SelectorKind::DoubleSparsity => crate::sim::ds_label_bytes(n, d / 4),
        SelectorKind::MagicPig => n * 8, // signature table
        SelectorKind::Oracle | SelectorKind::Full => crate::sim::attn_bytes(n, d) / 2,
        SelectorKind::StreamingLlm | SelectorKind::SnapKv | SelectorKind::H2O => 0,
    }
}

fn selector_wants_observation(kind: SelectorKind) -> bool {
    matches!(kind, SelectorKind::SnapKv | SelectorKind::H2O)
}

/// The governor's periodic accuracy probe: re-score one pruned head
/// *densely* (exact fp32 scores over the candidate set, via
/// `PagedKvCache::exact_score`), compute the true top-p set, and report
/// which fraction of it survived the estimated prune — estimated-vs-true
/// top-p recall. Runs once per [`SignalHub::probe_interval`] sparse
/// calls, so the extra O(B0·d) dot products are amortized to noise.
fn probe_recall(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    q: &[f32],
    candidates: &[usize],
    kept: &[usize],
    p: f32,
) -> f64 {
    let s = crate::attention::scale(q.len());
    let mut scores: Vec<f32> = candidates
        .iter()
        .map(|&t| cache.exact_score(seq, kv_head, q, t) * s)
        .collect();
    crate::tensor::softmax_inplace(&mut scores);
    let truth = crate::pruner::topp::topp_sort(&scores, p);
    if truth.indices.is_empty() {
        return 1.0;
    }
    let hits = truth
        .indices
        .iter()
        .filter(|&&i| kept.binary_search(&candidates[i]).is_ok())
        .count();
    hits as f64 / truth.indices.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::build_retrieval_model;
    use crate::model::sampler::greedy;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_fwe, gen_niah, RetrievalVocab};

    const V: RetrievalVocab = RetrievalVocab::DEFAULT;

    fn engine(cfg: SparseConfig) -> Engine {
        let model = Arc::new(build_retrieval_model(V, 8192));
        Engine::new(model, cfg, 16384)
    }

    #[test]
    fn dense_engine_answers_niah() {
        let mut e = engine(SparseConfig::dense());
        let mut r = Rng::new(1);
        for i in 0..5 {
            let g = gen_niah(&mut r, V, 512);
            let logits = e.prefill(i, &g.prompt).unwrap();
            assert_eq!(greedy(&logits), g.answer);
            e.release(i);
        }
        assert_eq!(e.free_pages(), 16384 / 16 + 1);
    }

    #[test]
    fn quest_twilight_answers_niah_with_tiny_budget() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        let mut r = Rng::new(2);
        let mut correct = 0;
        for i in 0..8 {
            let g = gen_niah(&mut r, V, 1024);
            let logits = e.prefill(i, &g.prompt).unwrap();
            if greedy(&logits) == g.answer {
                correct += 1;
            }
            e.release(i);
        }
        assert!(correct >= 7, "quest+twilight NIAH accuracy {correct}/8");
        assert!(e.stats.sparse_calls > 0);
        // Twilight must have pruned hard on the focused retrieval head.
        assert!(e.stats.prune_ratio() > 0.2, "prune ratio {}", e.stats.prune_ratio());
    }

    #[test]
    fn fwe_needs_diffuse_mass() {
        // With Twilight at high p, FWE stays accurate; a tiny fixed top-k
        // budget breaks it.
        let mut twi = SparseConfig::twilight(SelectorKind::Full, 0.95);
        twi.skip_layers = 0;
        twi.dense_below = 16;
        let mut small = SparseConfig::baseline(SelectorKind::Oracle, 8);
        small.skip_layers = 0;
        small.dense_below = 8;
        let mut correct_twi = 0;
        let mut correct_small = 0;
        for trial in 0..6u64 {
            let mut r = Rng::new(100 + trial);
            let g = gen_fwe(&mut r, V, 1024, 3.0);
            let mut e1 = engine(twi.clone());
            let l1 = e1.prefill(0, &g.prompt).unwrap();
            if greedy(&l1) == g.answer {
                correct_twi += 1;
            }
            let mut e2 = engine(small.clone());
            let l2 = e2.prefill(0, &g.prompt).unwrap();
            if greedy(&l2) == g.answer {
                correct_small += 1;
            }
        }
        assert!(correct_twi >= 5, "twilight FWE {correct_twi}/6");
        assert!(correct_small <= 3, "B=8 top-k should break FWE, got {correct_small}/6");
    }

    #[test]
    fn oom_reported_and_sequence_released() {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 64);
        let mut r = Rng::new(3);
        let g = gen_niah(&mut r, V, 256);
        let err = e.prefill(0, &g.prompt);
        assert!(err.is_err());
        assert_eq!(e.num_seqs(), 0);
        assert_eq!(e.free_pages(), 64 / 16 + 1);
    }

    #[test]
    fn can_step_tracks_page_boundaries() {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 64);
        let mut r = Rng::new(4);
        let g = gen_niah(&mut r, V, 30);
        let _ = e.prefill(0, &g.prompt).unwrap();
        assert!(e.can_step(0));
        assert!(!e.can_step(99));
        assert!(!e.needs_page(99));
    }

    #[test]
    fn directive_scales_budget_and_records_signals() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut r = Rng::new(11);
        let g = gen_niah(&mut r, V, 1024);
        let mut e1 = engine(cfg.clone());
        let _ = e1.prefill(0, &g.prompt).unwrap();
        let base_candidates = e1.stats.avg_candidates();
        assert!(e1.signals.has_prune_data(), "pruned run must record telemetry");
        assert!(e1.signals.probes() >= 1, "first sparse call runs the recall probe");
        let m = e1.signals.mean_mass();
        assert!(m > 0.0 && m <= 1.0 + 1e-4, "mass telemetry out of range: {m}");

        let mut e2 = engine(cfg);
        e2.apply_directive(BudgetDirective {
            p_scale: 0.6,
            budget_scale: 0.5,
            ..BudgetDirective::NEUTRAL
        });
        let _ = e2.prefill(0, &g.prompt).unwrap();
        assert!(
            e2.stats.avg_candidates() < base_candidates * 0.7,
            "budget_scale=0.5 must shrink B0: {} vs {}",
            e2.stats.avg_candidates(),
            base_candidates
        );
        assert!(e2.stats.avg_kept() <= e1.stats.avg_kept() + 1e-9);
    }

    #[test]
    fn directive_dense_below_override_forces_dense() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        e.apply_directive(BudgetDirective {
            dense_below_override: Some(1 << 20),
            ..BudgetDirective::NEUTRAL
        });
        let mut r = Rng::new(12);
        let g = gen_niah(&mut r, V, 512);
        let logits = e.prefill(0, &g.prompt).unwrap();
        assert_eq!(greedy(&logits), g.answer);
        assert_eq!(e.stats.sparse_calls, 0, "override must force the dense path");
        assert!(e.stats.t_dense > 0.0);
    }

    #[test]
    fn stats_accumulate_breakdown() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        let mut r = Rng::new(5);
        let g = gen_niah(&mut r, V, 512);
        let _ = e.prefill(0, &g.prompt).unwrap();
        let s = &e.stats;
        assert!(s.t_select > 0.0);
        assert!(s.t_prune > 0.0);
        assert!(s.t_attend > 0.0);
        assert!(s.avg_kept() > 0.0);
        assert!(s.avg_candidates() >= s.avg_kept());
    }

    #[test]
    fn prefill_tokens_counted_separately_from_decode_steps() {
        // prefill_tokens counts prompt tokens pushed through the forward
        // pass. Single-layer fast path: only the final prompt token.
        let mut e = engine(SparseConfig::dense());
        let mut r = Rng::new(6);
        let g = gen_niah(&mut r, V, 128);
        let _ = e.prefill(0, &g.prompt).unwrap();
        assert_eq!(e.stats.steps, 0, "prefill must not count as decode");
        assert_eq!(e.stats.prefill_tokens, 1);
        assert_eq!(e.stats.prefill_chunks, 1);
        let _ = e.decode(0, g.prompt[0]).unwrap();
        assert_eq!(e.stats.steps, 1);
        assert_eq!(e.stats.prefill_tokens, 1);
        // Multi-layer path: every prompt token, whatever the chunking.
        let cfg = crate::model::testutil::tiny_config();
        let m = Arc::new(crate::model::testutil::random_model(&cfg, 2));
        let mut e2 = Engine::new(m, SparseConfig::dense(), 1024);
        let _ = e2.prefill(0, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(e2.stats.prefill_tokens, 5);
        assert_eq!(e2.stats.steps, 0);
        assert!(e2.stats.prefill_chunks >= 1);
        // Mixed-step timing attribution: a pure-decode step is all decode.
        let _ = e2.decode(0, 1).unwrap();
        let t = e2.last_step_timing();
        assert!(t.total > 0.0);
        assert!((t.decode - t.total).abs() < 1e-12 && t.prefill == 0.0);
    }

    #[test]
    fn sparse_prefill_answers_niah_and_skips_blocks() {
        // Sparse prefill on a dense config: the single-layer retrieval
        // model routes each prompt's final token through the
        // bound-guided kernel (AllButLast), which must still find the
        // needle (≥ 1 − eps mass kept) while skipping most gated pages.
        let mut cfg = SparseConfig::dense();
        cfg.sparse_prefill = Some(crate::coordinator::SparsePrefillCfg::default());
        let mut e = engine(cfg);
        let mut r = Rng::new(21);
        let mut correct = 0;
        for i in 0..8 {
            let g = gen_niah(&mut r, V, 1024);
            let logits = e.prefill(i, &g.prompt).unwrap();
            if greedy(&logits) == g.answer {
                correct += 1;
            }
            e.release(i);
        }
        assert!(correct >= 7, "sparse-prefill NIAH accuracy {correct}/8");
        assert!(e.stats.prefill_blocks_total > 0, "gated pages must be considered");
        assert!(
            e.stats.prefill_blocks_skipped > 0,
            "retrieval prompts must skip some gated pages"
        );
        assert!(e.stats.t_sprefill > 0.0);
        // Sprefill sub-calls are not sparse calls: no labels, no
        // kept/candidate telemetry.
        assert_eq!(e.stats.sparse_calls, 0);

        // Governor force-enable: config off, directive on — the ladder's
        // level ≥ 2 override must activate the path the same way.
        let mut e2 = engine(SparseConfig::dense());
        e2.apply_directive(BudgetDirective {
            sparse_prefill_override: Some(true),
            ..BudgetDirective::NEUTRAL
        });
        let mut r = Rng::new(22);
        let g = gen_niah(&mut r, V, 1024);
        let logits = e2.prefill(0, &g.prompt).unwrap();
        assert_eq!(greedy(&logits), g.answer);
        assert!(e2.stats.prefill_blocks_total > 0, "override must enable the path");
    }

    #[test]
    fn batched_step_matches_serial_decode() {
        // Two independent sequences advanced through step_batch must get
        // bit-identical logits to one-at-a-time decode.
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut r = Rng::new(7);
        let g0 = gen_niah(&mut r, V, 256);
        let g1 = gen_niah(&mut r, V, 384);
        let run = |batched: bool| -> Vec<Vec<f32>> {
            let mut e = engine(cfg.clone());
            let _ = e.prefill(0, &g0.prompt).unwrap();
            let _ = e.prefill(1, &g1.prompt).unwrap();
            let mut all = Vec::new();
            for _ in 0..4 {
                if batched {
                    let batch = DecodeBatch::new(vec![(0, g0.prompt[0]), (1, g1.prompt[0])]);
                    for res in e.step_batch(&batch) {
                        all.push(res.unwrap());
                    }
                } else {
                    all.push(e.decode(0, g0.prompt[0]).unwrap());
                    all.push(e.decode(1, g1.prompt[0]).unwrap());
                }
            }
            all
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn step_batch_reports_oom_per_sequence() {
        // Pool sized so two growing sequences eventually exhaust pages:
        // the failing sequence gets Err and is released, the other keeps
        // decoding.
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 160);
        let mut r = Rng::new(8);
        let ga = gen_niah(&mut r, V, 64);
        let gb = gen_niah(&mut r, V, 64);
        let _ = e.prefill(0, &ga.prompt).unwrap();
        let _ = e.prefill(1, &gb.prompt).unwrap();
        let mut saw_err = false;
        for _ in 0..64 {
            let ids: Vec<(SeqId, u32)> =
                e.seqs.keys().copied().map(|id| (id, ga.prompt[0])).collect();
            if ids.is_empty() {
                break;
            }
            let mut sorted = ids;
            sorted.sort_unstable();
            let results = e.step_batch(&DecodeBatch::new(sorted));
            if results.iter().any(|x| x.is_err()) {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "pool of 160 tokens must eventually OOM");
        assert!(e.num_seqs() <= 1, "failed sequence must be released");
    }
}
