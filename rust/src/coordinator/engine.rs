//! The decode engine: wires the model forward pass to the paged KV cache,
//! Token Selector, Twilight Pruner, and varlen attention kernels — the
//! per-step pipeline of Fig. 5 — and keeps the Fig. 10 time breakdown.
//!
//! Decoding is *batched* (paper §4.2, "Load Balancing with Awareness of
//! Head Dynamism"): the scheduler hands the engine its whole running set
//! as one [`DecodeBatch`], and every layer executes as three phases —
//!
//! 1. **append** — QKV projection + KV append for all sequences, serial
//!    (appends mutate the shared page pools);
//! 2. **attend** — the (sequence × kv-head) pairs are flattened into one
//!    work list whose per-item cost is the resolved stage-1 budget,
//!    LPT-partitioned across workers ([`super::balance::lpt_partition`])
//!    and drained by the engine's persistent
//!    [`crate::util::threadpool::ThreadPool`] (resident workers created
//!    once per engine and reused across every layer of every step); each
//!    worker runs select → prune → varlen-attend with its own
//!    [`PrunerScratch`], read-only cache access, and exclusive access to
//!    its items' per-sequence selector state;
//! 3. **rest-of-layer** — output projection + MLP for all sequences.
//!
//! Workers record stats and governor telemetry into per-item accumulators
//! that are merged *in flattened item order* at the phase barrier, so
//! [`EngineStats`], [`SignalHub`] contents, and the logits are bit-exact
//! for any worker count (`TWILIGHT_THREADS=1` ≡ `TWILIGHT_THREADS=N`).

use super::{balance, AttnVariant, SparseConfig};
use crate::governor::signals::SignalHub;
use crate::governor::BudgetDirective;
use crate::kvcache::{CacheConfig, CacheError, PagedKvCache, SeqCache};
use crate::model::{BatchBackend, Model, ModelConfig};
use crate::pruner::{prune_group, PrunerConfig, PrunerScratch};
use crate::selector::{SelectorKind, TokenSelector};
use crate::util::stats::Histogram;
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Engine-internal sequence id (the coordinator maps RequestId → SeqId).
pub type SeqId = u64;

/// One batched decode step: every entry advances one running sequence by
/// one token. Ids must be distinct within a batch.
#[derive(Clone, Debug, Default)]
pub struct DecodeBatch {
    pub items: Vec<(SeqId, u32)>,
}

impl DecodeBatch {
    pub fn new(items: Vec<(SeqId, u32)>) -> DecodeBatch {
        DecodeBatch { items }
    }

    pub fn single(id: SeqId, tok: u32) -> DecodeBatch {
        DecodeBatch { items: vec![(id, tok)] }
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

/// Accumulated timing and budget statistics (Fig. 10 / Table budgets).
#[derive(Clone, Debug)]
pub struct EngineStats {
    /// Seconds in the Token Selector across all steps.
    pub t_select: f64,
    /// Seconds in the Twilight Pruner.
    pub t_prune: f64,
    /// Seconds in the sparse attention kernel.
    pub t_attend: f64,
    /// Seconds in dense attention (skip layers / short contexts).
    pub t_dense: f64,
    /// Seconds in everything else (projections, MLP, norms, sampling).
    pub t_other: f64,
    /// Batched decode steps executed (a batch of any size counts once:
    /// under continuous batching, step time ≙ TPOT).
    pub steps: u64,
    /// Prefill steps (one per prompt token pushed through the forward
    /// pass). Kept separate from `steps` so TPOT-style per-step averages
    /// are not skewed by prompt processing.
    pub prefill_steps: u64,
    /// Sum of stage-1 candidate budgets (per kv-head per step).
    pub candidates_sum: u64,
    /// Sum of final kept budgets.
    pub kept_sum: u64,
    /// Number of (step × kv-head) sparse attention invocations.
    pub sparse_calls: u64,
    /// Histogram of final per-head budgets.
    pub kept_hist: Histogram,
    /// Bytes the pipeline *would* stream on a GPU (sim cost model).
    pub est_bytes_select: u64,
    pub est_bytes_prune: u64,
    pub est_bytes_attend: u64,
}

impl Default for EngineStats {
    fn default() -> Self {
        EngineStats {
            t_select: 0.0,
            t_prune: 0.0,
            t_attend: 0.0,
            t_dense: 0.0,
            t_other: 0.0,
            steps: 0,
            prefill_steps: 0,
            candidates_sum: 0,
            kept_sum: 0,
            sparse_calls: 0,
            kept_hist: Histogram::new(0.0, 4096.0, 64),
            est_bytes_select: 0,
            est_bytes_prune: 0,
            est_bytes_attend: 0,
        }
    }
}

impl EngineStats {
    /// Mean final budget per sparse head-call.
    pub fn avg_kept(&self) -> f64 {
        if self.sparse_calls == 0 {
            0.0
        } else {
            self.kept_sum as f64 / self.sparse_calls as f64
        }
    }

    pub fn avg_candidates(&self) -> f64 {
        if self.sparse_calls == 0 {
            0.0
        } else {
            self.candidates_sum as f64 / self.sparse_calls as f64
        }
    }

    /// Fraction of stage-1 candidates pruned away by Twilight.
    pub fn prune_ratio(&self) -> f64 {
        if self.candidates_sum == 0 {
            0.0
        } else {
            1.0 - self.kept_sum as f64 / self.candidates_sum as f64
        }
    }
}

/// Per-sequence engine state.
struct SeqState {
    caches: Vec<SeqCache>,
    /// One selector per (layer × kv_head), lazily constructed.
    selectors: Vec<Box<dyn TokenSelector>>,
    pos: usize,
}

/// The decode engine. One per model; holds the physical page pools (one
/// per layer) and all live sequences.
pub struct Engine {
    pub model: Arc<Model>,
    pub cfg: SparseConfig,
    caches: Vec<PagedKvCache>,
    seqs: HashMap<SeqId, SeqState>,
    pub stats: EngineStats,
    /// Governor telemetry: per-layer prune rings + recall-probe EMA.
    pub signals: SignalHub,
    /// Runtime override from the governor; neutral when ungoverned.
    directive: BudgetDirective,
    /// Persistent attention worker pool, created once per engine
    /// (`TWILIGHT_THREADS`-sized by default) and reused for every layer
    /// of every batched step; `threads == 1` bypasses it entirely and
    /// reproduces strictly sequential execution bit for bit.
    pool: ThreadPool,
    /// Per-worker pruner scratch, reused across steps so the score
    /// buffers (the large per-call allocations) only ever grow. The
    /// attention phase still allocates step-scoped bookkeeping (work
    /// list, per-item outputs) each layer; those are small and
    /// proportional to batch × kv-heads, not to context length.
    scratches: Vec<PrunerScratch>,
}

impl Engine {
    /// `capacity_tokens` sizes each layer's page pool.
    pub fn new(model: Arc<Model>, cfg: SparseConfig, capacity_tokens: usize) -> Engine {
        let c = &model.cfg;
        let pages = capacity_tokens.div_ceil(16) + 1;
        let caches = (0..c.n_layers)
            .map(|_| PagedKvCache::new(CacheConfig::new(c.n_kv_heads, c.head_dim, pages)))
            .collect();
        let n_layers = model.cfg.n_layers;
        Engine {
            model,
            cfg,
            caches,
            seqs: HashMap::new(),
            stats: EngineStats::default(),
            signals: SignalHub::new(n_layers),
            directive: BudgetDirective::NEUTRAL,
            pool: ThreadPool::with_default_threads(),
            scratches: Vec::new(),
        }
    }

    /// Attention-phase parallelism (caller thread included).
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Retarget the attention worker pool. Growth is lazy (resident
    /// workers spawn on the next batched step that needs them, then stay
    /// parked between rounds); 1 selects the sequential reference path.
    pub fn set_threads(&mut self, threads: usize) {
        self.pool.set_threads(threads);
    }

    /// The persistent attention worker pool (instrumentation/tests).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// Install the governor's directive for subsequent decode steps.
    /// Clamped defensively: the engine never trusts the caller's ranges.
    pub fn apply_directive(&mut self, d: BudgetDirective) {
        self.directive = d.clamped();
    }

    /// The directive currently in force (NEUTRAL when ungoverned).
    pub fn directive(&self) -> BudgetDirective {
        self.directive
    }

    /// Physical pages per layer pool.
    pub fn total_pages(&self) -> usize {
        self.caches.first().map(|c| c.cfg.num_pages).unwrap_or(0)
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn free_pages(&self) -> usize {
        self.caches.iter().map(|c| c.free_pages()).min().unwrap_or(0)
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.pos)
    }

    fn new_state(&self) -> SeqState {
        let c = &self.model.cfg;
        let mut selectors: Vec<Box<dyn TokenSelector>> = Vec::new();
        for layer in 0..c.n_layers {
            for kvh in 0..c.n_kv_heads {
                selectors.push(
                    self.cfg.selector.build(c.head_dim, (layer * 131 + kvh) as u64),
                );
            }
        }
        SeqState { caches: vec![SeqCache::default(); c.n_layers], selectors, pos: 0 }
    }

    /// Register an empty sequence (used by teacher-forced evaluation,
    /// where every token goes through `decode`).
    pub fn start_empty(&mut self, id: SeqId) {
        let st = self.new_state();
        self.seqs.insert(id, st);
    }

    /// Tokens per physical page (uniform across the layer pools).
    fn page_size(&self) -> usize {
        self.caches.first().map(|c| c.cfg.page_size).unwrap_or(16)
    }

    /// True if a decode step for `id` cannot run out of pages.
    pub fn can_step(&self, id: SeqId) -> bool {
        match self.seqs.get(&id) {
            None => false,
            Some(st) => {
                let needs_page = st.pos % self.page_size() == 0;
                !needs_page || self.caches.iter().all(|c| c.free_pages() >= 1)
            }
        }
    }

    /// True when the next decode step for `id` must allocate a fresh page
    /// in every layer pool (the sequence sits on a page boundary). The
    /// scheduler sums this over a batch to size its preemption decision.
    pub fn needs_page(&self, id: SeqId) -> bool {
        self.seqs.get(&id).map(|s| s.pos % self.page_size() == 0).unwrap_or(false)
    }

    /// Admit a sequence and prefill its prompt; returns the logits after
    /// the final prompt token (for sampling the first output token).
    ///
    /// Single-layer models use the O(n) embedding-KV fast path; deeper
    /// models run a dense decode pass per token. Either way the work is
    /// accounted to `stats.prefill_steps`, not `stats.steps`, so decode
    /// step counts and the governor's TPOT view stay truthful.
    pub fn prefill(&mut self, id: SeqId, prompt: &[u32]) -> Result<Vec<f32>, CacheError> {
        assert!(!prompt.is_empty());
        let st = self.new_state();
        self.seqs.insert(id, st);
        let single_layer = self.model.cfg.n_layers == 1;
        let model = self.model.clone();
        if single_layer {
            for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate() {
                let (k, v) = model.kv_from_embedding(tok, pos);
                let st = self.seqs.get_mut(&id).unwrap();
                let res = self.caches[0].append(&mut st.caches[0], &k, &v);
                if let Err(e) = res {
                    self.release(id);
                    return Err(e);
                }
                self.seqs.get_mut(&id).unwrap().pos = pos + 1;
            }
            self.prefill_step(id, prompt[prompt.len() - 1])
        } else {
            let mut logits = Vec::new();
            for &tok in prompt {
                logits = self.prefill_step(id, tok)?;
            }
            Ok(logits)
        }
    }

    /// One decode step for a single sequence: process `tok` at the
    /// sequence's current position, return logits. A batch of one.
    pub fn decode(&mut self, id: SeqId, tok: u32) -> Result<Vec<f32>, CacheError> {
        self.run_batch(&DecodeBatch::single(id, tok), false).pop().unwrap()
    }

    /// One prompt token through the forward pass (accounted as prefill).
    fn prefill_step(&mut self, id: SeqId, tok: u32) -> Result<Vec<f32>, CacheError> {
        self.run_batch(&DecodeBatch::single(id, tok), true).pop().unwrap()
    }

    /// One batched decode step: advance every sequence in `batch` by one
    /// token. Per-sequence results are returned in batch order; a
    /// sequence that runs out of pages mid-step gets `Err` and is
    /// released (the others are unaffected).
    pub fn step_batch(&mut self, batch: &DecodeBatch) -> Vec<Result<Vec<f32>, CacheError>> {
        self.run_batch(batch, false)
    }

    fn run_batch(
        &mut self,
        batch: &DecodeBatch,
        prefill: bool,
    ) -> Vec<Result<Vec<f32>, CacheError>> {
        if batch.is_empty() {
            return Vec::new();
        }
        let model = self.model.clone();
        // Pull every sequence's state out of the map for the step: the
        // attention workers need disjoint per-sequence selector state.
        let mut sts: Vec<SeqState> = Vec::with_capacity(batch.len());
        let mut toks: Vec<(u32, usize)> = Vec::with_capacity(batch.len());
        for &(id, tok) in &batch.items {
            let st = self.seqs.remove(&id).expect("unknown sequence");
            toks.push((tok, st.pos));
            sts.push(st);
        }
        let threads = self.pool.threads();
        if self.scratches.len() < threads {
            self.scratches.resize_with(threads, PrunerScratch::default);
        }
        let staged_before =
            self.stats.t_select + self.stats.t_prune + self.stats.t_attend + self.stats.t_dense;
        let t0 = Instant::now();
        let directive = self.directive;
        let probe_interval = self.signals.probe_interval();
        let mut backend = BatchStepBackend {
            caches: &mut self.caches,
            sts: &mut sts,
            errors: vec![None; batch.len()],
            cfg: &self.cfg,
            model: &model,
            stats: &mut self.stats,
            signals: &mut self.signals,
            directive,
            scratches: &mut self.scratches,
            pool: &self.pool,
            probe_interval,
        };
        let logits = model.decode_batch(&toks, &mut backend);
        let mut errors = backend.errors;
        let total = t0.elapsed().as_secs_f64();
        if prefill {
            self.stats.prefill_steps += 1;
        } else {
            self.stats.steps += 1;
        }
        // Everything not attributed to a stage is "other" (projections,
        // MLP, norms, unembedding).
        let staged_after =
            self.stats.t_select + self.stats.t_prune + self.stats.t_attend + self.stats.t_dense;
        self.stats.t_other += (total - (staged_after - staged_before)).max(0.0);
        let mut results = Vec::with_capacity(batch.len());
        for (i, (mut st, lg)) in sts.into_iter().zip(logits).enumerate() {
            match errors[i].take() {
                Some(e) => {
                    // The sequence is already out of the map; return its
                    // pages to the pools.
                    for (layer, sc) in st.caches.iter().enumerate() {
                        self.caches[layer].release(sc);
                    }
                    results.push(Err(e));
                }
                None => {
                    st.pos += 1;
                    self.seqs.insert(batch.items[i].0, st);
                    results.push(Ok(lg));
                }
            }
        }
        results
    }

    /// Release a sequence's pages and state.
    pub fn release(&mut self, id: SeqId) {
        if let Some(st) = self.seqs.remove(&id) {
            for (layer, sc) in st.caches.iter().enumerate() {
                self.caches[layer].release(sc);
            }
        }
    }

    /// Reset statistics (between bench phases).
    pub fn reset_stats(&mut self) {
        self.stats = EngineStats::default();
    }
}

/// The batched per-step attention backend: implements the three-phase
/// Select-then-Prune pipeline for every layer of one batched decode step.
struct BatchStepBackend<'a> {
    caches: &'a mut [PagedKvCache],
    sts: &'a mut [SeqState],
    errors: Vec<Option<CacheError>>,
    cfg: &'a SparseConfig,
    model: &'a Model,
    stats: &'a mut EngineStats,
    signals: &'a mut SignalHub,
    directive: BudgetDirective,
    scratches: &'a mut [PrunerScratch],
    pool: &'a ThreadPool,
    probe_interval: u64,
}

/// One unit of phase-(b) attention work: a (sequence, kv-head) pair.
struct AttnItem<'a> {
    /// Flattened index (`seq * n_kv_heads + kv_head`): the deterministic
    /// merge order at the phase barrier.
    flat: usize,
    seq: usize,
    kv_head: usize,
    layer: usize,
    /// Context length (tokens in this sequence's cache).
    n: usize,
    dense: bool,
    /// Resolved stage-1 budget (sparse items only).
    budget: usize,
    /// Global sparse-call index, assigned serially at flatten time so
    /// the recall-probe cadence is identical for any worker count.
    call_idx: u64,
    selector: &'a mut Box<dyn TokenSelector>,
    cache: &'a PagedKvCache,
    seq_cache: &'a SeqCache,
    /// This KV group's query heads, `[group * head_dim]`.
    qs: &'a [f32],
}

/// The result of one attention work item, merged at the phase barrier in
/// `flat` order so stats and telemetry are deterministic under any
/// worker count.
struct AttnItemOut {
    flat: usize,
    seq: usize,
    kv_head: usize,
    out: Vec<f32>,
    t_select: f64,
    t_prune: f64,
    t_attend: f64,
    t_dense: f64,
    bytes_select: u64,
    bytes_prune: u64,
    bytes_attend: u64,
    sparse: bool,
    candidates: usize,
    kept: usize,
    /// `(layer, mean mass, keep ratio)` when the pruner ran.
    prune_record: Option<(usize, f64, f64)>,
    probe: Option<f64>,
}

/// Per-worker execution state: the items LPT assigned to this worker,
/// its private pruner scratch, and the results it produced.
struct WorkerCell<'a> {
    items: Vec<AttnItem<'a>>,
    scratch: PrunerScratch,
    results: Vec<AttnItemOut>,
}

impl BatchBackend for BatchStepBackend<'_> {
    fn append_kv(&mut self, layer: usize, idx: usize, k: &[f32], v: &[f32]) {
        if self.errors[idx].is_some() {
            return;
        }
        if let Err(e) = self.caches[layer].append(&mut self.sts[idx].caches[layer], k, v) {
            self.errors[idx] = Some(e);
        }
    }

    fn is_failed(&self, idx: usize) -> bool {
        self.errors[idx].is_some()
    }

    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32]) {
        let c = &self.model.cfg;
        let d = c.head_dim;
        let group = c.group();
        let kvn = c.n_kv_heads;
        let qd = c.q_dim();
        out.fill(0.0); // failed sequences stay zero
        // --- flatten (seq × kv-head) work items, sequence-major --------
        let dense_below = self.directive.dense_below_override.unwrap_or(self.cfg.dense_below);
        let mut call_idx = self.stats.sparse_calls;
        let mut flat_items: Vec<Option<AttnItem<'_>>> =
            Vec::with_capacity(self.sts.len() * kvn);
        let mut work: Vec<balance::WorkItem> = Vec::with_capacity(self.sts.len() * kvn);
        let cache = &self.caches[layer];
        for (i, st) in self.sts.iter_mut().enumerate() {
            if self.errors[i].is_some() {
                flat_items.extend((0..kvn).map(|_| None));
                continue;
            }
            let seq_cache = &st.caches[layer];
            let n = seq_cache.len;
            let dense = layer < self.cfg.skip_layers
                || n <= dense_below
                || (self.cfg.selector == SelectorKind::Full && self.cfg.twilight.is_none());
            let mut budget = 0;
            if !dense {
                budget = self.cfg.budget.resolve(n);
                if self.directive.budget_scale != 1.0 {
                    budget = ((budget as f32 * self.directive.budget_scale).round() as usize)
                        .clamp(1, n);
                }
            }
            let sel_base = layer * kvn;
            for (kvh, selector) in st.selectors[sel_base..sel_base + kvn].iter_mut().enumerate() {
                let flat = i * kvn + kvh;
                // Cost model: the kernels are bandwidth-bound, so the
                // token count to stream is the LPT weight.
                let cost = if dense { n } else { budget };
                work.push(balance::WorkItem {
                    seq: i as u32,
                    kv_head: kvh as u32,
                    budget: cost,
                });
                let this_call = if dense {
                    0
                } else {
                    call_idx += 1;
                    call_idx - 1
                };
                flat_items.push(Some(AttnItem {
                    flat,
                    seq: i,
                    kv_head: kvh,
                    layer,
                    n,
                    dense,
                    budget,
                    call_idx: this_call,
                    selector,
                    cache,
                    seq_cache,
                    qs: &qs[i * qd + kvh * group * d..i * qd + (kvh + 1) * group * d],
                }));
            }
        }
        let n_items = flat_items.len();
        // --- LPT partition over the worker pool ------------------------
        let workers = self.pool.threads().min(work.len()).max(1);
        let loads = balance::lpt_partition(&work, workers);
        let mut cells: Vec<Mutex<WorkerCell<'_>>> = Vec::with_capacity(loads.len());
        for (w, load) in loads.iter().enumerate() {
            let mut items = Vec::with_capacity(load.items.len());
            for wi in &load.items {
                let flat = wi.seq as usize * kvn + wi.kv_head as usize;
                items.push(flat_items[flat].take().expect("work item double-assigned"));
            }
            cells.push(Mutex::new(WorkerCell {
                items,
                scratch: std::mem::take(&mut self.scratches[w]),
                results: Vec::new(),
            }));
        }
        // --- parallel execution (worker w drains exactly cell w) -------
        let cfg = self.cfg;
        let mcfg = c;
        let directive = self.directive;
        let probe_interval = self.probe_interval;
        // One pool round per layer: the resident workers (spawned once,
        // on the engine's first parallel round) wake, drain exactly one
        // bucket each (chunk = 1, one ticket per LPT bucket), and park
        // again — the spawn/join cost that used to scale with
        // layers × steps is amortized to zero here.
        self.pool.run(cells.len(), 1, |w| {
            let mut guard = cells[w].lock().expect("attention worker poisoned");
            let WorkerCell { items, scratch, results } = &mut *guard;
            results.reserve(items.len());
            for item in items.drain(..) {
                results.push(run_attn_item(cfg, mcfg, directive, probe_interval, item, scratch));
            }
        });
        // --- deterministic merge at the phase barrier ------------------
        let mut merged: Vec<Option<AttnItemOut>> = (0..n_items).map(|_| None).collect();
        for (w, cell) in cells.into_iter().enumerate() {
            let cell = cell.into_inner().expect("attention worker poisoned");
            self.scratches[w] = cell.scratch;
            for r in cell.results {
                let flat = r.flat;
                merged[flat] = Some(r);
            }
        }
        for r in merged.into_iter().flatten() {
            let base = r.seq * qd + r.kv_head * group * d;
            out[base..base + group * d].copy_from_slice(&r.out);
            self.stats.t_select += r.t_select;
            self.stats.t_prune += r.t_prune;
            self.stats.t_attend += r.t_attend;
            self.stats.t_dense += r.t_dense;
            self.stats.est_bytes_select += r.bytes_select;
            self.stats.est_bytes_prune += r.bytes_prune;
            self.stats.est_bytes_attend += r.bytes_attend;
            if r.sparse {
                self.stats.sparse_calls += 1;
                self.stats.candidates_sum += r.candidates as u64;
                self.stats.kept_sum += r.kept as u64;
                self.stats.kept_hist.add(r.kept as f64);
            }
            if let Some((lay, mass, ratio)) = r.prune_record {
                self.signals.record_prune(lay, mass, ratio);
            }
            if let Some(recall) = r.probe {
                self.signals.record_probe(recall);
            }
        }
    }
}

/// Execute one (sequence, kv-head) attention work item: dense paged
/// attention for skip-layers / short contexts, or the full select →
/// prune → varlen-attend pipeline. Runs on a worker thread with
/// read-only cache access; everything mutable is item-private.
fn run_attn_item(
    cfg: &SparseConfig,
    c: &ModelConfig,
    directive: BudgetDirective,
    probe_interval: u64,
    item: AttnItem<'_>,
    scratch: &mut PrunerScratch,
) -> AttnItemOut {
    let AttnItem {
        flat,
        seq: seq_idx,
        kv_head,
        layer,
        n,
        dense,
        budget,
        call_idx,
        selector,
        cache,
        seq_cache: seq,
        qs: qs_group,
    } = item;
    let d = c.head_dim;
    let group = c.group();
    let mut r = AttnItemOut {
        flat,
        seq: seq_idx,
        kv_head,
        out: vec![0.0; group * d],
        t_select: 0.0,
        t_prune: 0.0,
        t_attend: 0.0,
        t_dense: 0.0,
        bytes_select: 0,
        bytes_prune: 0,
        bytes_attend: 0,
        sparse: !dense,
        candidates: 0,
        kept: 0,
        prune_record: None,
        probe: None,
    };
    if dense {
        let t = Instant::now();
        for g in 0..group {
            crate::attention::full::paged_full(
                cache,
                seq,
                kv_head,
                &qs_group[g * d..(g + 1) * d],
                &mut r.out[g * d..(g + 1) * d],
            );
        }
        r.t_dense = t.elapsed().as_secs_f64();
        r.bytes_attend = crate::sim::attn_bytes(n, d) as u64;
        return r;
    }
    // --- stage 1: Token Selector (black box, conservative) ------------
    let t = Instant::now();
    let candidates = selector.select(cache, seq, kv_head, qs_group, group, budget);
    r.t_select = t.elapsed().as_secs_f64();
    r.bytes_select = selector_bytes(cfg.selector, n, d) as u64;
    // --- stage 2: Twilight Pruner --------------------------------------
    let (kept, outcomes) = match &cfg.twilight {
        Some(pc) => {
            // The governor's p multiplier, clamped so even a
            // maximally-degraded directive keeps a real top-p.
            let pc = PrunerConfig {
                p: (pc.p * directive.p_scale).clamp(0.05, 0.999),
                ..*pc
            };
            let t = Instant::now();
            let (union, outs) =
                prune_group(&pc, cache, seq, kv_head, qs_group, group, &candidates, scratch);
            r.t_prune = t.elapsed().as_secs_f64();
            r.bytes_prune =
                crate::sim::spgemv_bytes(candidates.len(), d, cache.cfg.mirror_bits) as u64;
            // Governor telemetry: per-layer captured mass and keep ratio,
            // plus the periodic dense recall probe on the group's first
            // query head (cadence from the precomputed call index).
            if !candidates.is_empty() {
                let mean_mass = outs.iter().map(|o| o.mass as f64).sum::<f64>()
                    / outs.len().max(1) as f64;
                let keep_ratio = union.len() as f64 / candidates.len() as f64;
                r.prune_record = Some((layer, mean_mass, keep_ratio));
                if probe_interval > 0 && call_idx % probe_interval == 0 {
                    r.probe = Some(probe_recall(
                        cache,
                        seq,
                        kv_head,
                        &qs_group[..d],
                        &candidates,
                        &outs[0].kept,
                        pc.p,
                    ));
                }
            }
            (union, Some(outs))
        }
        None => (candidates.clone(), None),
    };
    r.candidates = candidates.len();
    r.kept = kept.len();
    // --- stage 3: sparse attention kernel ------------------------------
    let t = Instant::now();
    match cfg.attn {
        AttnVariant::GroupVarlen => {
            crate::attention::sparse::group_varlen(
                cache, seq, kv_head, qs_group, group, &kept, &mut r.out,
            );
        }
        AttnVariant::HeadVarlen => {
            for g in 0..group {
                crate::attention::sparse::head_varlen(
                    cache,
                    seq,
                    kv_head,
                    &qs_group[g * d..(g + 1) * d],
                    &kept,
                    &mut r.out[g * d..(g + 1) * d],
                );
            }
        }
        AttnVariant::Padded => {
            let max_budget = budget.max(kept.len());
            for g in 0..group {
                crate::attention::sparse::padded(
                    cache,
                    seq,
                    kv_head,
                    &qs_group[g * d..(g + 1) * d],
                    &kept,
                    max_budget,
                    &mut r.out[g * d..(g + 1) * d],
                );
            }
        }
    }
    r.t_attend = t.elapsed().as_secs_f64();
    r.bytes_attend = crate::sim::attn_bytes(kept.len(), d) as u64;
    // --- feedback for stateful (dropping) selectors --------------------
    if selector_wants_observation(cfg.selector) {
        // Reuse the pruner's estimated per-head weights instead of
        // re-scoring in exact fp32: every kept (union) token is observed
        // with its group-aggregated estimated attention, so a token any
        // query head attends to stays visible to the dropping selector.
        // Fall back to exact scores only when no pruner ran (baseline
        // mode) or it short-circuited without scoring (candidates ≤
        // min_keep, where the exact pass is a handful of dot products).
        let scored = outcomes.as_ref().filter(|outs| {
            outs.iter().all(|o| o.weights.len() == o.kept.len())
                && outs.iter().any(|o| !o.weights.is_empty())
        });
        match scored {
            Some(outs) => {
                let mut w = vec![0.0f32; kept.len()];
                for o in outs {
                    for (t, &x) in o.kept.iter().zip(&o.weights) {
                        if let Ok(j) = kept.binary_search(t) {
                            w[j] += x;
                        }
                    }
                }
                let sum: f32 = w.iter().sum();
                if sum > 0.0 {
                    let inv = 1.0 / sum;
                    for x in w.iter_mut() {
                        *x *= inv;
                    }
                }
                selector.observe(&kept, &w);
            }
            None => {
                let mut w: Vec<f32> = kept
                    .iter()
                    .map(|&t| {
                        cache.exact_score(seq, kv_head, &qs_group[..d], t)
                            * crate::attention::scale(d)
                    })
                    .collect();
                crate::tensor::softmax_inplace(&mut w);
                selector.observe(&kept, &w);
            }
        }
    }
    r
}

/// Estimated selector metadata traffic (bytes) for the sim cost model.
fn selector_bytes(kind: SelectorKind, n: usize, d: usize) -> usize {
    match kind {
        SelectorKind::Quest => crate::sim::quest_meta_bytes(n, d, 16),
        SelectorKind::DoubleSparsity => crate::sim::ds_label_bytes(n, d / 4),
        SelectorKind::MagicPig => n * 8, // signature table
        SelectorKind::Oracle | SelectorKind::Full => crate::sim::attn_bytes(n, d) / 2,
        SelectorKind::StreamingLlm | SelectorKind::SnapKv | SelectorKind::H2O => 0,
    }
}

fn selector_wants_observation(kind: SelectorKind) -> bool {
    matches!(kind, SelectorKind::SnapKv | SelectorKind::H2O)
}

/// The governor's periodic accuracy probe: re-score one pruned head
/// *densely* (exact fp32 scores over the candidate set, via
/// `PagedKvCache::exact_score`), compute the true top-p set, and report
/// which fraction of it survived the estimated prune — estimated-vs-true
/// top-p recall. Runs once per [`SignalHub::probe_interval`] sparse
/// calls, so the extra O(B0·d) dot products are amortized to noise.
fn probe_recall(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    q: &[f32],
    candidates: &[usize],
    kept: &[usize],
    p: f32,
) -> f64 {
    let s = crate::attention::scale(q.len());
    let mut scores: Vec<f32> = candidates
        .iter()
        .map(|&t| cache.exact_score(seq, kv_head, q, t) * s)
        .collect();
    crate::tensor::softmax_inplace(&mut scores);
    let truth = crate::pruner::topp::topp_sort(&scores, p);
    if truth.indices.is_empty() {
        return 1.0;
    }
    let hits = truth
        .indices
        .iter()
        .filter(|&&i| kept.binary_search(&candidates[i]).is_ok())
        .count();
    hits as f64 / truth.indices.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::build_retrieval_model;
    use crate::model::sampler::greedy;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_fwe, gen_niah, RetrievalVocab};

    const V: RetrievalVocab = RetrievalVocab::DEFAULT;

    fn engine(cfg: SparseConfig) -> Engine {
        let model = Arc::new(build_retrieval_model(V, 8192));
        Engine::new(model, cfg, 16384)
    }

    #[test]
    fn dense_engine_answers_niah() {
        let mut e = engine(SparseConfig::dense());
        let mut r = Rng::new(1);
        for i in 0..5 {
            let g = gen_niah(&mut r, V, 512);
            let logits = e.prefill(i, &g.prompt).unwrap();
            assert_eq!(greedy(&logits), g.answer);
            e.release(i);
        }
        assert_eq!(e.free_pages(), 16384 / 16 + 1);
    }

    #[test]
    fn quest_twilight_answers_niah_with_tiny_budget() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        let mut r = Rng::new(2);
        let mut correct = 0;
        for i in 0..8 {
            let g = gen_niah(&mut r, V, 1024);
            let logits = e.prefill(i, &g.prompt).unwrap();
            if greedy(&logits) == g.answer {
                correct += 1;
            }
            e.release(i);
        }
        assert!(correct >= 7, "quest+twilight NIAH accuracy {correct}/8");
        assert!(e.stats.sparse_calls > 0);
        // Twilight must have pruned hard on the focused retrieval head.
        assert!(e.stats.prune_ratio() > 0.2, "prune ratio {}", e.stats.prune_ratio());
    }

    #[test]
    fn fwe_needs_diffuse_mass() {
        // With Twilight at high p, FWE stays accurate; a tiny fixed top-k
        // budget breaks it.
        let mut twi = SparseConfig::twilight(SelectorKind::Full, 0.95);
        twi.skip_layers = 0;
        twi.dense_below = 16;
        let mut small = SparseConfig::baseline(SelectorKind::Oracle, 8);
        small.skip_layers = 0;
        small.dense_below = 8;
        let mut correct_twi = 0;
        let mut correct_small = 0;
        for trial in 0..6u64 {
            let mut r = Rng::new(100 + trial);
            let g = gen_fwe(&mut r, V, 1024, 3.0);
            let mut e1 = engine(twi.clone());
            let l1 = e1.prefill(0, &g.prompt).unwrap();
            if greedy(&l1) == g.answer {
                correct_twi += 1;
            }
            let mut e2 = engine(small.clone());
            let l2 = e2.prefill(0, &g.prompt).unwrap();
            if greedy(&l2) == g.answer {
                correct_small += 1;
            }
        }
        assert!(correct_twi >= 5, "twilight FWE {correct_twi}/6");
        assert!(correct_small <= 3, "B=8 top-k should break FWE, got {correct_small}/6");
    }

    #[test]
    fn oom_reported_and_sequence_released() {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 64);
        let mut r = Rng::new(3);
        let g = gen_niah(&mut r, V, 256);
        let err = e.prefill(0, &g.prompt);
        assert!(err.is_err());
        assert_eq!(e.num_seqs(), 0);
        assert_eq!(e.free_pages(), 64 / 16 + 1);
    }

    #[test]
    fn can_step_tracks_page_boundaries() {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 64);
        let mut r = Rng::new(4);
        let g = gen_niah(&mut r, V, 30);
        let _ = e.prefill(0, &g.prompt).unwrap();
        assert!(e.can_step(0));
        assert!(!e.can_step(99));
        assert!(!e.needs_page(99));
    }

    #[test]
    fn directive_scales_budget_and_records_signals() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut r = Rng::new(11);
        let g = gen_niah(&mut r, V, 1024);
        let mut e1 = engine(cfg.clone());
        let _ = e1.prefill(0, &g.prompt).unwrap();
        let base_candidates = e1.stats.avg_candidates();
        assert!(e1.signals.has_prune_data(), "pruned run must record telemetry");
        assert!(e1.signals.probes() >= 1, "first sparse call runs the recall probe");
        let m = e1.signals.mean_mass();
        assert!(m > 0.0 && m <= 1.0 + 1e-4, "mass telemetry out of range: {m}");

        let mut e2 = engine(cfg);
        e2.apply_directive(BudgetDirective {
            p_scale: 0.6,
            budget_scale: 0.5,
            ..BudgetDirective::NEUTRAL
        });
        let _ = e2.prefill(0, &g.prompt).unwrap();
        assert!(
            e2.stats.avg_candidates() < base_candidates * 0.7,
            "budget_scale=0.5 must shrink B0: {} vs {}",
            e2.stats.avg_candidates(),
            base_candidates
        );
        assert!(e2.stats.avg_kept() <= e1.stats.avg_kept() + 1e-9);
    }

    #[test]
    fn directive_dense_below_override_forces_dense() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        e.apply_directive(BudgetDirective {
            dense_below_override: Some(1 << 20),
            ..BudgetDirective::NEUTRAL
        });
        let mut r = Rng::new(12);
        let g = gen_niah(&mut r, V, 512);
        let logits = e.prefill(0, &g.prompt).unwrap();
        assert_eq!(greedy(&logits), g.answer);
        assert_eq!(e.stats.sparse_calls, 0, "override must force the dense path");
        assert!(e.stats.t_dense > 0.0);
    }

    #[test]
    fn stats_accumulate_breakdown() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        let mut r = Rng::new(5);
        let g = gen_niah(&mut r, V, 512);
        let _ = e.prefill(0, &g.prompt).unwrap();
        let s = &e.stats;
        assert!(s.t_select > 0.0);
        assert!(s.t_prune > 0.0);
        assert!(s.t_attend > 0.0);
        assert!(s.avg_kept() > 0.0);
        assert!(s.avg_candidates() >= s.avg_kept());
    }

    #[test]
    fn prefill_steps_counted_separately_from_decode_steps() {
        // Single-layer fast path: the whole prompt is one prefill step.
        let mut e = engine(SparseConfig::dense());
        let mut r = Rng::new(6);
        let g = gen_niah(&mut r, V, 128);
        let _ = e.prefill(0, &g.prompt).unwrap();
        assert_eq!(e.stats.steps, 0, "prefill must not count as decode");
        assert_eq!(e.stats.prefill_steps, 1);
        let _ = e.decode(0, g.prompt[0]).unwrap();
        assert_eq!(e.stats.steps, 1);
        assert_eq!(e.stats.prefill_steps, 1);
        // Multi-layer path: one prefill step per prompt token.
        let cfg = crate::model::testutil::tiny_config();
        let m = Arc::new(crate::model::testutil::random_model(&cfg, 2));
        let mut e2 = Engine::new(m, SparseConfig::dense(), 1024);
        let _ = e2.prefill(0, &[1, 2, 3, 4, 5]).unwrap();
        assert_eq!(e2.stats.prefill_steps, 5);
        assert_eq!(e2.stats.steps, 0);
    }

    #[test]
    fn batched_step_matches_serial_decode() {
        // Two independent sequences advanced through step_batch must get
        // bit-identical logits to one-at-a-time decode.
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut r = Rng::new(7);
        let g0 = gen_niah(&mut r, V, 256);
        let g1 = gen_niah(&mut r, V, 384);
        let run = |batched: bool| -> Vec<Vec<f32>> {
            let mut e = engine(cfg.clone());
            let _ = e.prefill(0, &g0.prompt).unwrap();
            let _ = e.prefill(1, &g1.prompt).unwrap();
            let mut all = Vec::new();
            for _ in 0..4 {
                if batched {
                    let batch = DecodeBatch::new(vec![(0, g0.prompt[0]), (1, g1.prompt[0])]);
                    for res in e.step_batch(&batch) {
                        all.push(res.unwrap());
                    }
                } else {
                    all.push(e.decode(0, g0.prompt[0]).unwrap());
                    all.push(e.decode(1, g1.prompt[0]).unwrap());
                }
            }
            all
        };
        assert_eq!(run(false), run(true));
    }

    #[test]
    fn step_batch_reports_oom_per_sequence() {
        // Pool sized so two growing sequences eventually exhaust pages:
        // the failing sequence gets Err and is released, the other keeps
        // decoding.
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 160);
        let mut r = Rng::new(8);
        let ga = gen_niah(&mut r, V, 64);
        let gb = gen_niah(&mut r, V, 64);
        let _ = e.prefill(0, &ga.prompt).unwrap();
        let _ = e.prefill(1, &gb.prompt).unwrap();
        let mut saw_err = false;
        for _ in 0..64 {
            let ids: Vec<(SeqId, u32)> =
                e.seqs.keys().copied().map(|id| (id, ga.prompt[0])).collect();
            if ids.is_empty() {
                break;
            }
            let mut sorted = ids;
            sorted.sort_unstable();
            let results = e.step_batch(&DecodeBatch::new(sorted));
            if results.iter().any(|x| x.is_err()) {
                saw_err = true;
                break;
            }
        }
        assert!(saw_err, "pool of 160 tokens must eventually OOM");
        assert!(e.num_seqs() <= 1, "failed sequence must be released");
    }
}
