//! The decode engine: wires the model forward pass to the paged KV cache,
//! Token Selector, Twilight Pruner, and varlen attention kernels — the
//! per-step pipeline of Fig. 5 — and keeps the Fig. 10 time breakdown.

use super::{AttnVariant, SparseConfig};
use crate::governor::signals::SignalHub;
use crate::governor::BudgetDirective;
use crate::kvcache::{CacheConfig, CacheError, PagedKvCache, SeqCache};
use crate::model::{LayerBackend, Model};
use crate::pruner::{prune_group, PruneOutcome, PrunerConfig, PrunerScratch};
use crate::selector::{SelectorKind, TokenSelector};
use crate::util::stats::Histogram;
use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

/// Engine-internal sequence id (the coordinator maps RequestId → SeqId).
pub type SeqId = u64;

/// Accumulated timing and budget statistics (Fig. 10 / Table budgets).
#[derive(Clone, Debug)]
pub struct EngineStats {
    /// Seconds in the Token Selector across all steps.
    pub t_select: f64,
    /// Seconds in the Twilight Pruner.
    pub t_prune: f64,
    /// Seconds in the sparse attention kernel.
    pub t_attend: f64,
    /// Seconds in dense attention (skip layers / short contexts).
    pub t_dense: f64,
    /// Seconds in everything else (projections, MLP, norms, sampling).
    pub t_other: f64,
    /// Decode steps executed.
    pub steps: u64,
    /// Sum of stage-1 candidate budgets (per kv-head per step).
    pub candidates_sum: u64,
    /// Sum of final kept budgets.
    pub kept_sum: u64,
    /// Number of (step × kv-head) sparse attention invocations.
    pub sparse_calls: u64,
    /// Histogram of final per-head budgets.
    pub kept_hist: Histogram,
    /// Bytes the pipeline *would* stream on a GPU (sim cost model).
    pub est_bytes_select: u64,
    pub est_bytes_prune: u64,
    pub est_bytes_attend: u64,
}

impl Default for EngineStats {
    fn default() -> Self {
        EngineStats {
            t_select: 0.0,
            t_prune: 0.0,
            t_attend: 0.0,
            t_dense: 0.0,
            t_other: 0.0,
            steps: 0,
            candidates_sum: 0,
            kept_sum: 0,
            sparse_calls: 0,
            kept_hist: Histogram::new(0.0, 4096.0, 64),
            est_bytes_select: 0,
            est_bytes_prune: 0,
            est_bytes_attend: 0,
        }
    }
}

impl EngineStats {
    /// Mean final budget per sparse head-call.
    pub fn avg_kept(&self) -> f64 {
        if self.sparse_calls == 0 {
            0.0
        } else {
            self.kept_sum as f64 / self.sparse_calls as f64
        }
    }

    pub fn avg_candidates(&self) -> f64 {
        if self.sparse_calls == 0 {
            0.0
        } else {
            self.candidates_sum as f64 / self.sparse_calls as f64
        }
    }

    /// Fraction of stage-1 candidates pruned away by Twilight.
    pub fn prune_ratio(&self) -> f64 {
        if self.candidates_sum == 0 {
            0.0
        } else {
            1.0 - self.kept_sum as f64 / self.candidates_sum as f64
        }
    }
}

/// Per-sequence engine state.
struct SeqState {
    caches: Vec<SeqCache>,
    /// One selector per (layer × kv_head), lazily constructed.
    selectors: Vec<Box<dyn TokenSelector>>,
    pos: usize,
}

/// The decode engine. One per model; holds the physical page pools (one
/// per layer) and all live sequences.
pub struct Engine {
    pub model: Arc<Model>,
    pub cfg: SparseConfig,
    caches: Vec<PagedKvCache>,
    seqs: HashMap<SeqId, SeqState>,
    pub stats: EngineStats,
    /// Governor telemetry: per-layer prune rings + recall-probe EMA.
    pub signals: SignalHub,
    /// Runtime override from the governor; neutral when ungoverned.
    directive: BudgetDirective,
    scratch: PrunerScratch,
}

impl Engine {
    /// `capacity_tokens` sizes each layer's page pool.
    pub fn new(model: Arc<Model>, cfg: SparseConfig, capacity_tokens: usize) -> Engine {
        let c = &model.cfg;
        let pages = capacity_tokens.div_ceil(16) + 1;
        let caches = (0..c.n_layers)
            .map(|_| PagedKvCache::new(CacheConfig::new(c.n_kv_heads, c.head_dim, pages)))
            .collect();
        let n_layers = model.cfg.n_layers;
        Engine {
            model,
            cfg,
            caches,
            seqs: HashMap::new(),
            stats: EngineStats::default(),
            signals: SignalHub::new(n_layers),
            directive: BudgetDirective::NEUTRAL,
            scratch: PrunerScratch::default(),
        }
    }

    /// Install the governor's directive for subsequent decode steps.
    /// Clamped defensively: the engine never trusts the caller's ranges.
    pub fn apply_directive(&mut self, d: BudgetDirective) {
        self.directive = d.clamped();
    }

    /// The directive currently in force (NEUTRAL when ungoverned).
    pub fn directive(&self) -> BudgetDirective {
        self.directive
    }

    /// Physical pages per layer pool.
    pub fn total_pages(&self) -> usize {
        self.caches.first().map(|c| c.cfg.num_pages).unwrap_or(0)
    }

    pub fn num_seqs(&self) -> usize {
        self.seqs.len()
    }

    pub fn free_pages(&self) -> usize {
        self.caches.iter().map(|c| c.free_pages()).min().unwrap_or(0)
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.pos)
    }

    fn new_state(&self) -> SeqState {
        let c = &self.model.cfg;
        let mut selectors: Vec<Box<dyn TokenSelector>> = Vec::new();
        for layer in 0..c.n_layers {
            for kvh in 0..c.n_kv_heads {
                selectors.push(
                    self.cfg.selector.build(c.head_dim, (layer * 131 + kvh) as u64),
                );
            }
        }
        SeqState { caches: vec![SeqCache::default(); c.n_layers], selectors, pos: 0 }
    }

    /// Register an empty sequence (used by teacher-forced evaluation,
    /// where every token goes through `decode`).
    pub fn start_empty(&mut self, id: SeqId) {
        let st = self.new_state();
        self.seqs.insert(id, st);
    }

    /// True if a decode step for `id` cannot run out of pages.
    pub fn can_step(&self, id: SeqId) -> bool {
        match self.seqs.get(&id) {
            None => false,
            Some(st) => {
                let needs_page = st.pos % 16 == 0;
                !needs_page || self.caches.iter().all(|c| c.free_pages() >= 1)
            }
        }
    }

    /// Admit a sequence and prefill its prompt; returns the logits after
    /// the final prompt token (for sampling the first output token).
    ///
    /// Single-layer models use the O(n) embedding-KV fast path; deeper
    /// models run a dense decode pass per token.
    pub fn prefill(&mut self, id: SeqId, prompt: &[u32]) -> Result<Vec<f32>, CacheError> {
        assert!(!prompt.is_empty());
        let st = self.new_state();
        self.seqs.insert(id, st);
        let single_layer = self.model.cfg.n_layers == 1;
        let model = self.model.clone();
        if single_layer {
            for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate() {
                let (k, v) = model.kv_from_embedding(tok, pos);
                let st = self.seqs.get_mut(&id).unwrap();
                let res = self.caches[0].append(&mut st.caches[0], &k, &v);
                if let Err(e) = res {
                    self.release(id);
                    return Err(e);
                }
                self.seqs.get_mut(&id).unwrap().pos = pos + 1;
            }
            self.decode(id, prompt[prompt.len() - 1])
        } else {
            let mut logits = Vec::new();
            for &tok in prompt {
                logits = self.decode(id, tok)?;
            }
            Ok(logits)
        }
    }

    /// One decode step: process `tok` at the sequence's current position,
    /// return logits.
    pub fn decode(&mut self, id: SeqId, tok: u32) -> Result<Vec<f32>, CacheError> {
        let mut st = self.seqs.remove(&id).expect("unknown sequence");
        let pos = st.pos;
        let model = self.model.clone();
        let staged_before =
            self.stats.t_select + self.stats.t_prune + self.stats.t_attend + self.stats.t_dense;
        let t0 = Instant::now();
        let directive = self.directive;
        let result = {
            let mut backend = StepBackend {
                caches: &mut self.caches,
                st: &mut st,
                cfg: &self.cfg,
                model: &model,
                stats: &mut self.stats,
                signals: &mut self.signals,
                directive,
                scratch: &mut self.scratch,
                error: None,
            };
            let logits = model.decode_step(tok, pos, &mut backend);
            match backend.error.take() {
                Some(e) => Err(e),
                None => Ok(logits),
            }
        };
        let total = t0.elapsed().as_secs_f64();
        st.pos = pos + 1;
        self.stats.steps += 1;
        self.seqs.insert(id, st);
        if result.is_ok() {
            // Everything not attributed to a stage is "other"
            // (projections, MLP, norms, unembedding).
            let staged_after = self.stats.t_select
                + self.stats.t_prune
                + self.stats.t_attend
                + self.stats.t_dense;
            self.stats.t_other += (total - (staged_after - staged_before)).max(0.0);
        } else {
            self.release(id);
        }
        result
    }

    /// Release a sequence's pages and state.
    pub fn release(&mut self, id: SeqId) {
        if let Some(st) = self.seqs.remove(&id) {
            for (layer, sc) in st.caches.iter().enumerate() {
                self.caches[layer].release(sc);
            }
        }
    }

    /// Reset statistics (between bench phases).
    pub fn reset_stats(&mut self) {
        self.stats = EngineStats::default();
    }
}

/// The per-step attention backend: implements the Select-then-Prune
/// pipeline for every layer of one decode step.
struct StepBackend<'a> {
    caches: &'a mut [PagedKvCache],
    st: &'a mut SeqState,
    cfg: &'a SparseConfig,
    model: &'a Model,
    stats: &'a mut EngineStats,
    signals: &'a mut SignalHub,
    directive: BudgetDirective,
    scratch: &'a mut PrunerScratch,
    error: Option<CacheError>,
}

impl<'a> LayerBackend for StepBackend<'a> {
    fn append_kv(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        if self.error.is_some() {
            return;
        }
        if let Err(e) = self.caches[layer].append(&mut self.st.caches[layer], k, v) {
            self.error = Some(e);
        }
    }

    fn attend(&mut self, layer: usize, qs: &[f32]) -> Vec<f32> {
        let c = &self.model.cfg;
        let d = c.head_dim;
        let group = c.group();
        let mut out = vec![0.0; c.q_dim()];
        if self.error.is_some() {
            return out;
        }
        let cache = &self.caches[layer];
        let seq = &self.st.caches[layer];
        let n = seq.len;
        let dense_below = self.directive.dense_below_override.unwrap_or(self.cfg.dense_below);
        let dense = layer < self.cfg.skip_layers
            || n <= dense_below
            || (self.cfg.selector == SelectorKind::Full && self.cfg.twilight.is_none());
        if dense {
            let t = Instant::now();
            for h in 0..c.n_heads {
                let kvh = h / group;
                crate::attention::full::paged_full(
                    cache,
                    seq,
                    kvh,
                    &qs[h * d..(h + 1) * d],
                    &mut out[h * d..(h + 1) * d],
                );
            }
            self.stats.t_dense += t.elapsed().as_secs_f64();
            self.stats.est_bytes_attend +=
                (c.n_kv_heads * crate::sim::attn_bytes(n, d)) as u64;
            return out;
        }
        let mut budget = self.cfg.budget.resolve(n);
        if self.directive.budget_scale != 1.0 {
            budget = ((budget as f32 * self.directive.budget_scale).round() as usize).clamp(1, n);
        }
        for kvh in 0..c.n_kv_heads {
            let qs_group = &qs[kvh * group * d..(kvh + 1) * group * d];
            // --- stage 1: Token Selector (black box, conservative) ------
            let t = Instant::now();
            let sel = &mut self.st.selectors[layer * c.n_kv_heads + kvh];
            let candidates = sel.select(cache, seq, kvh, qs_group, group, budget);
            self.stats.t_select += t.elapsed().as_secs_f64();
            self.stats.est_bytes_select += selector_bytes(self.cfg.selector, n, d) as u64;
            // --- stage 2: Twilight Pruner -------------------------------
            let (kept, outcomes): (Vec<usize>, Option<Vec<PruneOutcome>>) =
                match &self.cfg.twilight {
                    Some(pc) => {
                        // The governor's p multiplier, clamped so even a
                        // maximally-degraded directive keeps a real top-p.
                        let pc = PrunerConfig {
                            p: (pc.p * self.directive.p_scale).clamp(0.05, 0.999),
                            ..*pc
                        };
                        let t = Instant::now();
                        let (union, outs) = prune_group(
                            &pc, cache, seq, kvh, qs_group, group, &candidates, self.scratch,
                        );
                        self.stats.t_prune += t.elapsed().as_secs_f64();
                        self.stats.est_bytes_prune += crate::sim::spgemv_bytes(
                            candidates.len(),
                            d,
                            cache.cfg.mirror_bits,
                        ) as u64;
                        // Governor telemetry: per-layer captured mass and
                        // keep ratio, plus the periodic dense recall probe
                        // on the group's first query head.
                        if !candidates.is_empty() {
                            let mean_mass = outs.iter().map(|o| o.mass as f64).sum::<f64>()
                                / outs.len().max(1) as f64;
                            let keep_ratio = union.len() as f64 / candidates.len() as f64;
                            self.signals.record_prune(layer, mean_mass, keep_ratio);
                            if self.signals.probe_due(self.stats.sparse_calls) {
                                let recall = probe_recall(
                                    cache,
                                    seq,
                                    kvh,
                                    &qs_group[..d],
                                    &candidates,
                                    &outs[0].kept,
                                    pc.p,
                                );
                                self.signals.record_probe(recall);
                            }
                        }
                        (union, Some(outs))
                    }
                    None => (candidates.clone(), None),
                };
            self.stats.sparse_calls += 1;
            self.stats.candidates_sum += candidates.len() as u64;
            self.stats.kept_sum += kept.len() as u64;
            self.stats.kept_hist.add(kept.len() as f64);
            let _ = outcomes;
            // --- stage 3: sparse attention kernel -----------------------
            let t = Instant::now();
            let outs = &mut out[kvh * group * d..(kvh + 1) * group * d];
            match self.cfg.attn {
                AttnVariant::GroupVarlen => {
                    crate::attention::sparse::group_varlen(
                        cache, seq, kvh, qs_group, group, &kept, outs,
                    );
                }
                AttnVariant::HeadVarlen => {
                    for g in 0..group {
                        crate::attention::sparse::head_varlen(
                            cache,
                            seq,
                            kvh,
                            &qs_group[g * d..(g + 1) * d],
                            &kept,
                            &mut outs[g * d..(g + 1) * d],
                        );
                    }
                }
                AttnVariant::Padded => {
                    let max_budget = budget.max(kept.len());
                    for g in 0..group {
                        crate::attention::sparse::padded(
                            cache,
                            seq,
                            kvh,
                            &qs_group[g * d..(g + 1) * d],
                            &kept,
                            max_budget,
                            &mut outs[g * d..(g + 1) * d],
                        );
                    }
                }
            }
            self.stats.t_attend += t.elapsed().as_secs_f64();
            self.stats.est_bytes_attend += crate::sim::attn_bytes(kept.len(), d) as u64;
            // --- feedback for stateful (dropping) selectors -------------
            let sel = &mut self.st.selectors[layer * c.n_kv_heads + kvh];
            if selector_wants_observation(self.cfg.selector) {
                let mut w: Vec<f32> = kept
                    .iter()
                    .map(|&t| {
                        cache.exact_score(seq, kvh, &qs_group[..d], t)
                            * crate::attention::scale(d)
                    })
                    .collect();
                crate::tensor::softmax_inplace(&mut w);
                sel.observe(&kept, &w);
            }
        }
        out
    }
}

/// Estimated selector metadata traffic (bytes) for the sim cost model.
fn selector_bytes(kind: SelectorKind, n: usize, d: usize) -> usize {
    match kind {
        SelectorKind::Quest => crate::sim::quest_meta_bytes(n, d, 16),
        SelectorKind::DoubleSparsity => crate::sim::ds_label_bytes(n, d / 4),
        SelectorKind::MagicPig => n * 8, // signature table
        SelectorKind::Oracle | SelectorKind::Full => crate::sim::attn_bytes(n, d) / 2,
        SelectorKind::StreamingLlm | SelectorKind::SnapKv | SelectorKind::H2O => 0,
    }
}

fn selector_wants_observation(kind: SelectorKind) -> bool {
    matches!(kind, SelectorKind::SnapKv | SelectorKind::H2O)
}

/// The governor's periodic accuracy probe: re-score one pruned head
/// *densely* (exact fp32 scores over the candidate set, via
/// `PagedKvCache::exact_score`), compute the true top-p set, and report
/// which fraction of it survived the estimated prune — estimated-vs-true
/// top-p recall. Runs once per [`SignalHub::probe_due`] cadence, so the
/// extra O(B0·d) dot products are amortized to noise.
fn probe_recall(
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    q: &[f32],
    candidates: &[usize],
    kept: &[usize],
    p: f32,
) -> f64 {
    let s = crate::attention::scale(q.len());
    let mut scores: Vec<f32> = candidates
        .iter()
        .map(|&t| cache.exact_score(seq, kv_head, q, t) * s)
        .collect();
    crate::tensor::softmax_inplace(&mut scores);
    let truth = crate::pruner::topp::topp_sort(&scores, p);
    if truth.indices.is_empty() {
        return 1.0;
    }
    let hits = truth
        .indices
        .iter()
        .filter(|&&i| kept.binary_search(&candidates[i]).is_ok())
        .count();
    hits as f64 / truth.indices.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::retrieval::build_retrieval_model;
    use crate::model::sampler::greedy;
    use crate::selector::SelectorKind;
    use crate::util::rng::Rng;
    use crate::workload::{gen_fwe, gen_niah, RetrievalVocab};

    const V: RetrievalVocab = RetrievalVocab::DEFAULT;

    fn engine(cfg: SparseConfig) -> Engine {
        let model = Arc::new(build_retrieval_model(V, 8192));
        Engine::new(model, cfg, 16384)
    }

    #[test]
    fn dense_engine_answers_niah() {
        let mut e = engine(SparseConfig::dense());
        let mut r = Rng::new(1);
        for i in 0..5 {
            let g = gen_niah(&mut r, V, 512);
            let logits = e.prefill(i, &g.prompt).unwrap();
            assert_eq!(greedy(&logits), g.answer);
            e.release(i);
        }
        assert_eq!(e.free_pages(), 16384 / 16 + 1);
    }

    #[test]
    fn quest_twilight_answers_niah_with_tiny_budget() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        let mut r = Rng::new(2);
        let mut correct = 0;
        for i in 0..8 {
            let g = gen_niah(&mut r, V, 1024);
            let logits = e.prefill(i, &g.prompt).unwrap();
            if greedy(&logits) == g.answer {
                correct += 1;
            }
            e.release(i);
        }
        assert!(correct >= 7, "quest+twilight NIAH accuracy {correct}/8");
        assert!(e.stats.sparse_calls > 0);
        // Twilight must have pruned hard on the focused retrieval head.
        assert!(e.stats.prune_ratio() > 0.2, "prune ratio {}", e.stats.prune_ratio());
    }

    #[test]
    fn fwe_needs_diffuse_mass() {
        // With Twilight at high p, FWE stays accurate; a tiny fixed top-k
        // budget breaks it.
        let mut twi = SparseConfig::twilight(SelectorKind::Full, 0.95);
        twi.skip_layers = 0;
        twi.dense_below = 16;
        let mut small = SparseConfig::baseline(SelectorKind::Oracle, 8);
        small.skip_layers = 0;
        small.dense_below = 8;
        let mut correct_twi = 0;
        let mut correct_small = 0;
        for trial in 0..6u64 {
            let mut r = Rng::new(100 + trial);
            let g = gen_fwe(&mut r, V, 1024, 3.0);
            let mut e1 = engine(twi.clone());
            let l1 = e1.prefill(0, &g.prompt).unwrap();
            if greedy(&l1) == g.answer {
                correct_twi += 1;
            }
            let mut e2 = engine(small.clone());
            let l2 = e2.prefill(0, &g.prompt).unwrap();
            if greedy(&l2) == g.answer {
                correct_small += 1;
            }
        }
        assert!(correct_twi >= 5, "twilight FWE {correct_twi}/6");
        assert!(correct_small <= 3, "B=8 top-k should break FWE, got {correct_small}/6");
    }

    #[test]
    fn oom_reported_and_sequence_released() {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 64);
        let mut r = Rng::new(3);
        let g = gen_niah(&mut r, V, 256);
        let err = e.prefill(0, &g.prompt);
        assert!(err.is_err());
        assert_eq!(e.num_seqs(), 0);
        assert_eq!(e.free_pages(), 64 / 16 + 1);
    }

    #[test]
    fn can_step_tracks_page_boundaries() {
        let model = Arc::new(build_retrieval_model(V, 8192));
        let mut e = Engine::new(model, SparseConfig::dense(), 64);
        let mut r = Rng::new(4);
        let g = gen_niah(&mut r, V, 30);
        let _ = e.prefill(0, &g.prompt).unwrap();
        assert!(e.can_step(0));
        assert!(!e.can_step(99));
    }

    #[test]
    fn directive_scales_budget_and_records_signals() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut r = Rng::new(11);
        let g = gen_niah(&mut r, V, 1024);
        let mut e1 = engine(cfg.clone());
        let _ = e1.prefill(0, &g.prompt).unwrap();
        let base_candidates = e1.stats.avg_candidates();
        assert!(e1.signals.has_prune_data(), "pruned run must record telemetry");
        assert!(e1.signals.probes() >= 1, "first sparse call runs the recall probe");
        let m = e1.signals.mean_mass();
        assert!(m > 0.0 && m <= 1.0 + 1e-4, "mass telemetry out of range: {m}");

        let mut e2 = engine(cfg);
        e2.apply_directive(BudgetDirective {
            p_scale: 0.6,
            budget_scale: 0.5,
            ..BudgetDirective::NEUTRAL
        });
        let _ = e2.prefill(0, &g.prompt).unwrap();
        assert!(
            e2.stats.avg_candidates() < base_candidates * 0.7,
            "budget_scale=0.5 must shrink B0: {} vs {}",
            e2.stats.avg_candidates(),
            base_candidates
        );
        assert!(e2.stats.avg_kept() <= e1.stats.avg_kept() + 1e-9);
    }

    #[test]
    fn directive_dense_below_override_forces_dense() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.95);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        e.apply_directive(BudgetDirective {
            dense_below_override: Some(1 << 20),
            ..BudgetDirective::NEUTRAL
        });
        let mut r = Rng::new(12);
        let g = gen_niah(&mut r, V, 512);
        let logits = e.prefill(0, &g.prompt).unwrap();
        assert_eq!(greedy(&logits), g.answer);
        assert_eq!(e.stats.sparse_calls, 0, "override must force the dense path");
        assert!(e.stats.t_dense > 0.0);
    }

    #[test]
    fn stats_accumulate_breakdown() {
        let mut cfg = SparseConfig::twilight(SelectorKind::Quest, 0.9);
        cfg.skip_layers = 0;
        cfg.dense_below = 16;
        let mut e = engine(cfg);
        let mut r = Rng::new(5);
        let g = gen_niah(&mut r, V, 512);
        let _ = e.prefill(0, &g.prompt).unwrap();
        let s = &e.stats;
        assert!(s.t_select > 0.0);
        assert!(s.t_prune > 0.0);
        assert!(s.t_attend > 0.0);
        assert!(s.avg_kept() > 0.0);
        assert!(s.avg_candidates() >= s.avg_kept());
    }
}
