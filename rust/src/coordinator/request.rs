//! Request representation and lifecycle for the serving coordinator.
//!
//! Fault domains (DESIGN.md §14): [`RequestState::Failed`] is the
//! request-level terminal state for faults the engine contained below it
//! (a lost KV page, a quarantined worker panic) or the scheduler caught
//! above it (non-finite logits). A failed request releases its pages,
//! surfaces its [`FailReason`] in reports and the server error reply,
//! and never perturbs a neighbor's bytes.

use crate::model::sampler::SamplingParams;

/// Unique request id.
pub type RequestId = u64;

/// Why a request reached [`RequestState::Failed`]. One reason per
/// request (the first fault wins); carried through reports/metrics so
/// operators can tell tier loss from poisoned work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FailReason {
    /// A sealed KV page's bytes became unreachable (tier read-retry
    /// ladder exhausted — `CacheError::PageLost`).
    PageLost,
    /// The request's attention work item panicked on a pool thread and
    /// was quarantined (`CacheError::WorkerPanic`).
    WorkerPanic,
    /// The forward pass produced NaN/inf logits; failing beats sampling
    /// garbage tokens.
    NonFiniteLogits,
}

impl FailReason {
    /// Stable wire label (reports / metrics / server error replies).
    pub fn label(self) -> &'static str {
        match self {
            FailReason::PageLost => "page_lost",
            FailReason::WorkerPanic => "worker_panic",
            FailReason::NonFiniteLogits => "non_finite_logits",
        }
    }
}

/// Lifecycle states of a request inside the coordinator.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestState {
    /// In the admission queue.
    Queued,
    /// Prompt chunks are being pushed through mixed steps (spans multiple
    /// scheduler steps under chunked prefill).
    Prefilling,
    /// Generating tokens.
    Decoding,
    /// Evicted under memory pressure; will re-enter prefill.
    Preempted,
    /// Done (completed or cancelled).
    Finished,
    /// Refused at admission: the prompt can never fit the page pool.
    Rejected,
    /// Terminal fault: the request died (pages reclaimed, neighbors
    /// unaffected) for the contained reason.
    Failed { reason: FailReason },
}

/// A serving request plus its runtime bookkeeping.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub params: SamplingParams,
    /// Arrival time (seconds since trace start).
    pub arrival: f64,
    pub state: RequestState,
    pub output: Vec<u32>,
    /// Time admission began (first prefill chunk scheduled); cleared on
    /// preemption. `first_token_at - admitted_at` is the prefill time.
    pub admitted_at: Option<f64>,
    /// Time the first output token was produced.
    pub first_token_at: Option<f64>,
    /// Completion time.
    pub finished_at: Option<f64>,
    /// Stop decoding when this token is produced (optional).
    pub stop_token: Option<u32>,
    /// Preemption count (diagnostics).
    pub preemptions: u32,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<u32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            params: SamplingParams::default(),
            arrival: 0.0,
            state: RequestState::Queued,
            output: Vec::new(),
            admitted_at: None,
            first_token_at: None,
            finished_at: None,
            stop_token: None,
            preemptions: 0,
        }
    }

    /// Total sequence length right now (prompt + generated).
    pub fn seq_len(&self) -> usize {
        self.prompt.len() + self.output.len()
    }

    pub fn is_done(&self) -> bool {
        self.output.len() >= self.max_new_tokens
            || self
                .stop_token
                .map(|s| self.output.last() == Some(&s))
                .unwrap_or(false)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn done_by_length_and_stop() {
        let mut r = Request::new(1, vec![1, 2, 3], 2);
        assert!(!r.is_done());
        r.output.push(9);
        assert!(!r.is_done());
        r.output.push(9);
        assert!(r.is_done());

        let mut r = Request::new(2, vec![1], 100);
        r.stop_token = Some(7);
        r.output.push(3);
        assert!(!r.is_done());
        r.output.push(7);
        assert!(r.is_done());
    }

    #[test]
    fn seq_len_counts_output() {
        let mut r = Request::new(1, vec![1, 2, 3], 8);
        assert_eq!(r.seq_len(), 3);
        r.output.push(5);
        assert_eq!(r.seq_len(), 4);
    }
}
