//! The serving coordinator — Twilight's L3 system layer.
//!
//! ```text
//!  requests ──> queue ──> scheduler (continuous batching, preemption)
//!                            │ one DecodeBatch per step
//!                            v
//!                         engine (per batched decode step, per layer):
//!                            QKV + KV append (all seqs, serial)
//!                            flattened (seq × kv-head) work list:
//!                              Token Selector  ─┐ conservative budget B0
//!                              Twilight Pruner ─┤ INT4 SpGEMV → top-p → B1
//!                              varlen attention ┘ group-varlen kernel
//!                              (LPT-partitioned across workers)
//!                            rest-of-layer (all seqs, serial)
//!                            │
//!                            v
//!                         metrics (TTFT/TPOT/throughput/budget hists)
//! ```

pub mod balance;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod scheduler;
pub mod server;

use crate::pruner::PrunerConfig;
use crate::selector::SelectorKind;
use crate::util::json::Json;

/// How the conservative stage-1 budget B0 is derived from context length.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum BudgetSpec {
    /// Fixed token count.
    Fixed(usize),
    /// Fraction of the current context (paper: 1/4 for the selector).
    Fraction(f32),
}

impl BudgetSpec {
    pub fn resolve(&self, ctx_len: usize) -> usize {
        match *self {
            BudgetSpec::Fixed(n) => n.min(ctx_len),
            BudgetSpec::Fraction(f) => ((ctx_len as f32 * f) as usize).max(1).min(ctx_len),
        }
    }

    /// Parse `"256"` (fixed tokens), `"0.25"` / `"0.25f"` (context
    /// fraction). Rejects non-positive, non-finite, and >1.0 fractions
    /// and zero fixed budgets — an invalid spec silently resolving to an
    /// empty candidate set would disable attention.
    pub fn parse(s: &str) -> Option<BudgetSpec> {
        fn fraction(f: f32) -> Option<BudgetSpec> {
            (f.is_finite() && f > 0.0 && f <= 1.0).then_some(BudgetSpec::Fraction(f))
        }
        if let Some(frac) = s.strip_suffix('f') {
            return frac.parse::<f32>().ok().and_then(fraction);
        }
        if s.contains('.') {
            return s.parse::<f32>().ok().and_then(fraction);
        }
        s.parse::<usize>().ok().filter(|&n| n > 0).map(BudgetSpec::Fixed)
    }
}

/// Which sparse-attention kernel packing to use (Fig. 13 ablation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AttnVariant {
    GroupVarlen,
    HeadVarlen,
    Padded,
}

impl AttnVariant {
    pub fn parse(s: &str) -> Option<AttnVariant> {
        match s {
            "group" | "group-varlen" => Some(AttnVariant::GroupVarlen),
            "head" | "head-varlen" => Some(AttnVariant::HeadVarlen),
            "padded" => Some(AttnVariant::Padded),
            _ => None,
        }
    }
}

/// Sparse *prefill* knobs (DESIGN.md §13): chunked-prefill queries skip
/// sealed pages whose envelope bound cannot carry an `eps` fraction of
/// their softmax mass, always attending exactly to the last `window`
/// tokens (plus the chunk itself and the unsealed tail). Off by
/// default — the dense context walk stays the bit-exact reference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsePrefillCfg {
    /// Top-p slack: each query keeps ≥ 1 − eps of its dense softmax
    /// mass (clamped to [0, 0.5] by the kernel).
    pub eps: f32,
    /// Always-dense local window before the chunk (≥ 1: the self token
    /// is always scored exactly).
    pub window: usize,
}

impl Default for SparsePrefillCfg {
    fn default() -> Self {
        SparsePrefillCfg { eps: 0.02, window: 64 }
    }
}

/// `TWILIGHT_SPARSE_PREFILL=1` opts the constructors into sparse
/// prefill (the CLI flag / config file / governor override also work).
fn sparse_prefill_from_env() -> Option<SparsePrefillCfg> {
    std::env::var("TWILIGHT_SPARSE_PREFILL")
        .is_ok_and(|v| v == "1" || v == "true")
        .then(SparsePrefillCfg::default)
}

/// Full sparse-attention pipeline configuration for the engine.
#[derive(Clone, Debug)]
pub struct SparseConfig {
    /// The base algorithm (black-box Token Selector).
    pub selector: SelectorKind,
    /// Conservative stage-1 budget.
    pub budget: BudgetSpec,
    /// Twilight pruner; `None` runs the base algorithm alone.
    pub twilight: Option<PrunerConfig>,
    /// Dense attention for the first `skip_layers` layers (the paper
    /// leaves the first two layers dense).
    pub skip_layers: usize,
    /// Contexts shorter than this stay dense.
    pub dense_below: usize,
    /// Bound-guided page skipping for prefill chunk queries; `None`
    /// keeps prefill dense (bit-exact reference).
    pub sparse_prefill: Option<SparsePrefillCfg>,
    /// Kernel packing variant.
    pub attn: AttnVariant,
}

impl SparseConfig {
    /// Dense/full attention configuration.
    pub fn dense() -> SparseConfig {
        SparseConfig {
            selector: SelectorKind::Full,
            budget: BudgetSpec::Fraction(1.0),
            twilight: None,
            skip_layers: usize::MAX,
            dense_below: 0,
            sparse_prefill: sparse_prefill_from_env(),
            attn: AttnVariant::GroupVarlen,
        }
    }

    /// The paper's recommended deployment: base selector at 1/4 context
    /// plus the Twilight pruner at threshold `p`. The hierarchical
    /// page-level pre-prune is opt-in via `TWILIGHT_HIER_PAGES=1` (or
    /// `--hier-pages` / the config file / a governor directive); the
    /// default pipeline stays bit-exact with the historical path.
    pub fn twilight(selector: SelectorKind, p: f32) -> SparseConfig {
        let hier_pages =
            std::env::var("TWILIGHT_HIER_PAGES").is_ok_and(|v| v == "1" || v == "true");
        SparseConfig {
            selector,
            budget: BudgetSpec::Fraction(0.25),
            twilight: Some(PrunerConfig { p, hier_pages, ..Default::default() }),
            skip_layers: 2,
            dense_below: 64,
            sparse_prefill: sparse_prefill_from_env(),
            attn: AttnVariant::GroupVarlen,
        }
    }

    /// A fixed-budget top-k baseline without Twilight.
    pub fn baseline(selector: SelectorKind, budget: usize) -> SparseConfig {
        SparseConfig {
            selector,
            budget: BudgetSpec::Fixed(budget),
            twilight: None,
            skip_layers: 2,
            dense_below: 64,
            sparse_prefill: sparse_prefill_from_env(),
            attn: AttnVariant::GroupVarlen,
        }
    }

    /// Parse from a JSON object (the config-file path).
    pub fn from_json(j: &Json) -> Result<SparseConfig, String> {
        let selector = SelectorKind::parse(j.get_str("selector").unwrap_or("quest"))
            .ok_or("unknown selector")?;
        let budget = BudgetSpec::parse(j.get_str("budget").unwrap_or("0.25f"))
            .ok_or("bad budget spec")?;
        let twilight = match j.get("twilight") {
            Some(Json::Bool(false)) | None => None,
            Some(tw) => {
                let p = tw.get_f64("p").unwrap_or(0.95) as f32;
                let min_keep = tw.get_usize("min_keep").unwrap_or(4);
                let hier_pages = matches!(tw.get("hier_pages"), Some(Json::Bool(true)));
                let base = PrunerConfig::default();
                let hier_eps = tw.get_f64("hier_eps").unwrap_or(base.hier_eps as f64) as f32;
                Some(PrunerConfig { p, min_keep, hier_pages, hier_eps, ..base })
            }
        };
        let sparse_prefill = match j.get("sparse_prefill") {
            Some(Json::Bool(false)) => None,
            None => sparse_prefill_from_env(),
            Some(sp) => {
                let base = SparsePrefillCfg::default();
                Some(SparsePrefillCfg {
                    eps: sp.get_f64("eps").unwrap_or(base.eps as f64) as f32,
                    window: sp.get_usize("window").unwrap_or(base.window),
                })
            }
        };
        Ok(SparseConfig {
            selector,
            budget,
            twilight,
            skip_layers: j.get_usize("skip_layers").unwrap_or(2),
            dense_below: j.get_usize("dense_below").unwrap_or(64),
            sparse_prefill,
            attn: AttnVariant::parse(j.get_str("attn").unwrap_or("group"))
                .ok_or("bad attn variant")?,
        })
    }

    /// Short human-readable label for reports ("quest+twi(p=0.95)",
    /// "+hier" appended when the page pre-prune is on, "+sp" when
    /// sparse prefill is on).
    pub fn label(&self) -> String {
        let base = match &self.twilight {
            Some(t) if t.hier_pages => {
                format!("{}+twi(p={})+hier", self.selector.name(), t.p)
            }
            Some(t) => format!("{}+twi(p={})", self.selector.name(), t.p),
            None => match self.budget {
                BudgetSpec::Fixed(b) => format!("{}(B={b})", self.selector.name()),
                BudgetSpec::Fraction(f) => format!("{}(B={f}N)", self.selector.name()),
            },
        };
        if self.sparse_prefill.is_some() {
            format!("{base}+sp")
        } else {
            base
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn budget_spec_parse_and_resolve() {
        assert_eq!(BudgetSpec::parse("256"), Some(BudgetSpec::Fixed(256)));
        assert_eq!(BudgetSpec::parse("0.25f"), Some(BudgetSpec::Fraction(0.25)));
        assert_eq!(BudgetSpec::parse("0.25"), Some(BudgetSpec::Fraction(0.25)));
        assert_eq!(BudgetSpec::parse("1.0"), Some(BudgetSpec::Fraction(1.0)));
        assert_eq!(BudgetSpec::parse("1f"), Some(BudgetSpec::Fraction(1.0)));
        assert_eq!(BudgetSpec::Fixed(256).resolve(100), 100);
        assert_eq!(BudgetSpec::Fraction(0.25).resolve(1000), 250);
        assert_eq!(BudgetSpec::Fraction(0.5).resolve(1), 1);
    }

    #[test]
    fn budget_spec_rejects_nonsense() {
        for bad in [
            "0f",     // zero fraction: empty candidate set
            "0.0",    // ditto
            "-0.25",  // negative fraction
            "-0.25f", // negative fraction, suffixed
            "1.5",    // fraction above 1.0
            "2.0f",   // ditto, suffixed
            "nanf",   // non-finite
            "inff",   // non-finite
            "0",      // zero fixed budget
            "-3",     // negative fixed budget
            "abc",    // not a number
            "",       // empty
            "f",      // bare suffix
        ] {
            assert_eq!(BudgetSpec::parse(bad), None, "must reject {bad:?}");
        }
    }

    #[test]
    fn sparse_config_from_json() {
        let j = Json::parse(
            r#"{"selector":"quest","budget":"0.25f","twilight":{"p":0.85},
                "skip_layers":1,"attn":"group"}"#,
        )
        .unwrap();
        let c = SparseConfig::from_json(&j).unwrap();
        assert_eq!(c.selector, SelectorKind::Quest);
        assert!((c.twilight.unwrap().p - 0.85).abs() < 1e-6);
        assert_eq!(c.skip_layers, 1);
        assert_eq!(c.label(), "quest+twi(p=0.85)");
    }

    #[test]
    fn hier_pages_via_json_and_label() {
        let j = Json::parse(
            r#"{"selector":"quest","budget":"0.25f",
                "twilight":{"p":0.9,"hier_pages":true,"hier_eps":0.01}}"#,
        )
        .unwrap();
        let c = SparseConfig::from_json(&j).unwrap();
        let t = c.twilight.unwrap();
        assert!(t.hier_pages);
        assert!((t.hier_eps - 0.01).abs() < 1e-6);
        assert_eq!(c.label(), "quest+twi(p=0.9)+hier");
    }

    #[test]
    fn twilight_disabled_via_false() {
        let j = Json::parse(r#"{"selector":"ds","budget":"512","twilight":false}"#).unwrap();
        let c = SparseConfig::from_json(&j).unwrap();
        assert!(c.twilight.is_none());
        assert_eq!(c.label(), "ds(B=512)");
    }

    #[test]
    fn sparse_prefill_via_json_and_label() {
        let j = Json::parse(
            r#"{"selector":"quest","budget":"0.25f","twilight":{"p":0.9},
                "sparse_prefill":{"eps":0.05,"window":128}}"#,
        )
        .unwrap();
        let c = SparseConfig::from_json(&j).unwrap();
        let sp = c.sparse_prefill.unwrap();
        assert!((sp.eps - 0.05).abs() < 1e-6);
        assert_eq!(sp.window, 128);
        assert_eq!(c.label(), "quest+twi(p=0.9)+sp");

        // `true` opts in with defaults; `false` forces it off.
        let j = Json::parse(r#"{"selector":"full","budget":"1f","sparse_prefill":true}"#).unwrap();
        let c = SparseConfig::from_json(&j).unwrap();
        assert_eq!(c.sparse_prefill, Some(SparsePrefillCfg::default()));
        let j = Json::parse(r#"{"selector":"full","budget":"1f","sparse_prefill":false}"#).unwrap();
        assert!(SparseConfig::from_json(&j).unwrap().sparse_prefill.is_none());
    }
}
