//! Serving metrics: TTFT, TPOT, throughput, and budget distributions —
//! everything Fig. 8 and the tables report.

use crate::util::json::{self, Json};
use crate::util::stats::Summary;

/// Per-request timing record.
#[derive(Clone, Debug)]
pub struct RequestMetrics {
    pub id: u64,
    pub prompt_len: usize,
    pub output_len: usize,
    pub arrival: f64,
    pub first_token_at: f64,
    pub finished_at: f64,
    pub preemptions: u32,
}

impl RequestMetrics {
    /// Time to first token.
    pub fn ttft(&self) -> f64 {
        self.first_token_at - self.arrival
    }

    /// Time per output token after the first.
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            0.0
        } else {
            (self.finished_at - self.first_token_at) / (self.output_len - 1) as f64
        }
    }
}

/// Aggregated serving report.
#[derive(Clone, Debug, Default)]
pub struct ServingReport {
    pub requests: Vec<RequestMetrics>,
    /// Wall-clock duration of the run.
    pub duration: f64,
}

impl ServingReport {
    pub fn total_output_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.output_len).sum()
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.total_output_tokens() as f64 / self.duration
        }
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::from(&self.requests.iter().map(|r| r.ttft()).collect::<Vec<_>>())
    }

    pub fn tpot_summary(&self) -> Summary {
        Summary::from(
            &self
                .requests
                .iter()
                .filter(|r| r.output_len > 1)
                .map(|r| r.tpot())
                .collect::<Vec<_>>(),
        )
    }

    /// JSON for result files.
    pub fn to_json(&self) -> Json {
        let tpot = self.tpot_summary();
        let ttft = self.ttft_summary();
        json::obj(vec![
            ("requests", Json::Num(self.requests.len() as f64)),
            ("duration_s", Json::Num(self.duration)),
            ("output_tokens", Json::Num(self.total_output_tokens() as f64)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s())),
            ("ttft_mean_s", Json::Num(ttft.mean)),
            ("ttft_p99_s", Json::Num(ttft.p99)),
            ("tpot_mean_s", Json::Num(tpot.mean)),
            ("tpot_p50_s", Json::Num(tpot.p50)),
            ("tpot_p99_s", Json::Num(tpot.p99)),
            (
                "preemptions",
                Json::Num(self.requests.iter().map(|r| r.preemptions as f64).sum()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rm(arrival: f64, first: f64, fin: f64, out: usize) -> RequestMetrics {
        RequestMetrics {
            id: 0,
            prompt_len: 10,
            output_len: out,
            arrival,
            first_token_at: first,
            finished_at: fin,
            preemptions: 0,
        }
    }

    #[test]
    fn ttft_tpot() {
        let r = rm(1.0, 1.5, 2.5, 11);
        assert!((r.ttft() - 0.5).abs() < 1e-12);
        assert!((r.tpot() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn single_token_tpot_zero() {
        assert_eq!(rm(0.0, 0.1, 0.1, 1).tpot(), 0.0);
    }

    #[test]
    fn report_aggregates() {
        let rep = ServingReport {
            requests: vec![rm(0.0, 0.1, 1.1, 11), rm(0.0, 0.2, 2.2, 21)],
            duration: 2.2,
        };
        assert_eq!(rep.total_output_tokens(), 32);
        assert!((rep.throughput_tok_s() - 32.0 / 2.2).abs() < 1e-9);
        let j = rep.to_json();
        assert_eq!(j.get_usize("requests"), Some(2));
        assert!(j.get_f64("tpot_mean_s").unwrap() > 0.0);
    }
}
