//! Serving metrics: TTFT, TPOT, throughput, budget distributions —
//! everything Fig. 8 and the tables report — plus the governor's
//! decision trace when the run was governed.
//!
//! Attribution rules under chunked prefill: TTFT is stamped at the first
//! *sampled* token (after the final prompt chunk), not at admission;
//! `prefill_time` (`first_token_at - admitted_at`) isolates the chunked
//! prompt processing from queueing (`admitted_at - arrival`); TPOT spans
//! only the decode phase. Rejected requests (prompt can never fit the
//! page pool) and failed requests (terminal faults — DESIGN.md §14) are
//! counted separately and excluded from the latency summaries, as is
//! any request that never produced a first token (`started == false`:
//! its `first_token_at` is a placeholder, not a measurement — including
//! it would wash garbage TTFTs into the percentiles).

use super::request::FailReason;
use crate::governor::TraceEntry;
use crate::util::json::{self, Json};
use crate::util::stats::Summary;

/// Per-request timing record.
#[derive(Clone, Debug)]
pub struct RequestMetrics {
    pub id: u64,
    pub prompt_len: usize,
    pub output_len: usize,
    pub arrival: f64,
    /// When admission began (== `arrival` when never queued).
    pub admitted_at: f64,
    /// Meaningful only when `started` (a placeholder otherwise — never
    /// use it in a summary without checking `started`).
    pub first_token_at: f64,
    pub finished_at: f64,
    pub preemptions: u32,
    /// Refused at admission: the prompt can never fit the page pool.
    pub rejected: bool,
    /// The request actually produced a first token; false for requests
    /// rejected or failed before sampling anything.
    pub started: bool,
    /// Terminal fault (`RequestState::Failed`), with the contained
    /// reason; `None` for every other outcome.
    pub fail_reason: Option<FailReason>,
}

impl RequestMetrics {
    /// Time to first token.
    pub fn ttft(&self) -> f64 {
        self.first_token_at - self.arrival
    }

    /// Time spent queued before (final) admission.
    pub fn queue_time(&self) -> f64 {
        self.admitted_at - self.arrival
    }

    /// Time spent pushing prompt chunks through the engine.
    pub fn prefill_time(&self) -> f64 {
        self.first_token_at - self.admitted_at
    }

    /// Time per output token after the first.
    pub fn tpot(&self) -> f64 {
        if self.output_len <= 1 {
            0.0
        } else {
            (self.finished_at - self.first_token_at) / (self.output_len - 1) as f64
        }
    }
}

/// Aggregated serving report.
#[derive(Clone, Debug, Default)]
pub struct ServingReport {
    pub requests: Vec<RequestMetrics>,
    /// Wall-clock duration of the run.
    pub duration: f64,
    /// Governor decision trace (empty for ungoverned runs).
    pub governor: Vec<TraceEntry>,
    /// Hierarchical page pre-prune accounting (0/0 unless `--hier-pages`
    /// ran): candidate page runs skipped unscored / seen.
    pub hier_pages_skipped: u64,
    pub hier_pages_total: u64,
    /// Bound-guided sparse-prefill accounting (0/0 unless the
    /// `--sparse-prefill` path ran): gated pages skipped / considered
    /// across (prefill query × group head) rows.
    pub prefill_blocks_skipped: u64,
    pub prefill_blocks_total: u64,
    /// Active compute-kernel backend ("scalar", "avx2", "neon"; empty
    /// when the report was built without one resolved).
    pub kernel_backend: String,
    /// Tiered-offload accounting, summed over layers (all 0 when the run
    /// was fully resident): demand+prefetch page faults, faults served by
    /// prefetch tickets, evictions, and fp32 bytes faulted back in.
    pub offload_faults: u64,
    pub offload_prefetched: u64,
    pub offload_evictions: u64,
    pub offload_bytes_faulted: u64,
    /// Configured resident fraction (1.0 = no tier attached).
    pub resident_frac: f64,
    /// Fault-domain accounting (all 0 on a fault-free run): tier read /
    /// write errors (every retry attempt counted), retry-ladder
    /// re-attempts, pages declared lost, and quarantined worker panics.
    pub tier_read_errors: u64,
    pub tier_write_errors: u64,
    pub tier_retries: u64,
    pub pages_lost: u64,
    pub worker_panics: u64,
}

impl ServingReport {
    pub fn total_output_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.output_len).sum()
    }

    /// Prompt tokens across non-rejected requests (the tokens prefill
    /// actually processed; rejected prompts never enter the engine).
    pub fn total_prompt_tokens(&self) -> usize {
        self.requests.iter().filter(|r| !r.rejected).map(|r| r.prompt_len).sum()
    }

    pub fn throughput_tok_s(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.total_output_tokens() as f64 / self.duration
        }
    }

    /// Prefill-phase throughput: prompt tokens over the run's wall clock.
    /// Reported per phase next to [`Self::throughput_tok_s`] (decode) so
    /// result files separate the two regimes under chunked prefill.
    pub fn prefill_throughput_tok_s(&self) -> f64 {
        if self.duration <= 0.0 {
            0.0
        } else {
            self.total_prompt_tokens() as f64 / self.duration
        }
    }

    /// Requests whose latencies belong in the percentile summaries:
    /// served to completion AND actually started (a request that never
    /// sampled a token has no TTFT to measure — it is counted via
    /// [`Self::never_started`] instead of poisoning the percentiles).
    fn summarizable(r: &&RequestMetrics) -> bool {
        !r.rejected && r.fail_reason.is_none() && r.started
    }

    pub fn ttft_summary(&self) -> Summary {
        Summary::from(
            &self
                .requests
                .iter()
                .filter(Self::summarizable)
                .map(|r| r.ttft())
                .collect::<Vec<_>>(),
        )
    }

    /// Chunked-prompt processing time (admission → first sampled token).
    pub fn prefill_summary(&self) -> Summary {
        Summary::from(
            &self
                .requests
                .iter()
                .filter(Self::summarizable)
                .map(|r| r.prefill_time())
                .collect::<Vec<_>>(),
        )
    }

    pub fn tpot_summary(&self) -> Summary {
        Summary::from(
            &self
                .requests
                .iter()
                .filter(|r| Self::summarizable(r) && r.output_len > 1)
                .map(|r| r.tpot())
                .collect::<Vec<_>>(),
        )
    }

    /// Total preemptions across requests.
    pub fn preemptions(&self) -> u32 {
        self.requests.iter().map(|r| r.preemptions).sum()
    }

    /// Requests refused at admission (prompt can never fit the pool).
    pub fn rejected(&self) -> usize {
        self.requests.iter().filter(|r| r.rejected).count()
    }

    /// Requests that died to a contained fault (`RequestState::Failed`).
    pub fn failed(&self) -> usize {
        self.requests.iter().filter(|r| r.fail_reason.is_some()).count()
    }

    /// Failed requests with the given reason.
    pub fn failed_with(&self, reason: FailReason) -> usize {
        self.requests.iter().filter(|r| r.fail_reason == Some(reason)).count()
    }

    /// Requests that never produced a first token (rejected, or failed /
    /// still-queued at run end before sampling anything) — excluded from
    /// every latency summary, counted here instead.
    pub fn never_started(&self) -> usize {
        self.requests.iter().filter(|r| !r.started).count()
    }

    /// Fraction of non-rejected requests served to completion (the
    /// resilience panel's headline number: 1.0 on a fault-free run).
    pub fn completion_rate(&self) -> f64 {
        let attempted = self.requests.iter().filter(|r| !r.rejected).count();
        if attempted == 0 {
            return 1.0;
        }
        let completed = self
            .requests
            .iter()
            .filter(|r| !r.rejected && r.fail_reason.is_none())
            .count();
        completed as f64 / attempted as f64
    }

    /// Fraction of candidate pages the hier pre-prune skipped (0 when the
    /// mode never ran).
    pub fn hier_skip_frac(&self) -> f64 {
        if self.hier_pages_total == 0 {
            0.0
        } else {
            self.hier_pages_skipped as f64 / self.hier_pages_total as f64
        }
    }

    /// Fraction of gated pages the sparse-prefill kernel skipped (0 when
    /// the path never ran).
    pub fn prefill_blocks_skip_frac(&self) -> f64 {
        if self.prefill_blocks_total == 0 {
            0.0
        } else {
            self.prefill_blocks_skipped as f64 / self.prefill_blocks_total as f64
        }
    }

    /// Fraction of page faults served by prefetch tickets rather than
    /// demand reads inside the attention kernels (0 when nothing faulted,
    /// i.e. the run was fully resident or the working set fit the cap).
    pub fn offload_overlap_frac(&self) -> f64 {
        if self.offload_faults == 0 {
            0.0
        } else {
            self.offload_prefetched as f64 / self.offload_faults as f64
        }
    }

    /// JSON for result files.
    pub fn to_json(&self) -> Json {
        let tpot = self.tpot_summary();
        let ttft = self.ttft_summary();
        let prefill = self.prefill_summary();
        let mut kv: Vec<(&str, Json)> = vec![
            ("requests", Json::Num(self.requests.len() as f64)),
            ("duration_s", Json::Num(self.duration)),
            ("output_tokens", Json::Num(self.total_output_tokens() as f64)),
            ("prompt_tokens", Json::Num(self.total_prompt_tokens() as f64)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s())),
            ("prefill_throughput_tok_s", Json::Num(self.prefill_throughput_tok_s())),
            ("ttft_mean_s", Json::Num(ttft.mean)),
            ("ttft_p50_s", Json::Num(ttft.p50)),
            ("ttft_p90_s", Json::Num(ttft.p90)),
            ("ttft_p99_s", Json::Num(ttft.p99)),
            ("prefill_mean_s", Json::Num(prefill.mean)),
            ("prefill_p50_s", Json::Num(prefill.p50)),
            ("prefill_p90_s", Json::Num(prefill.p90)),
            ("prefill_p99_s", Json::Num(prefill.p99)),
            ("tpot_mean_s", Json::Num(tpot.mean)),
            ("tpot_p50_s", Json::Num(tpot.p50)),
            ("tpot_p90_s", Json::Num(tpot.p90)),
            ("tpot_p99_s", Json::Num(tpot.p99)),
            ("preemptions", Json::Num(self.preemptions() as f64)),
            ("rejected", Json::Num(self.rejected() as f64)),
            // Fault-domain keys are unconditional (0 on fault-free runs)
            // so resilience dashboards can key on them without probing.
            ("failed", Json::Num(self.failed() as f64)),
            ("failed_page_lost", Json::Num(self.failed_with(FailReason::PageLost) as f64)),
            ("failed_worker_panic", Json::Num(self.failed_with(FailReason::WorkerPanic) as f64)),
            (
                "failed_non_finite_logits",
                Json::Num(self.failed_with(FailReason::NonFiniteLogits) as f64),
            ),
            ("never_started", Json::Num(self.never_started() as f64)),
            ("completion_rate", Json::Num(self.completion_rate())),
            ("tier_read_errors", Json::Num(self.tier_read_errors as f64)),
            ("tier_write_errors", Json::Num(self.tier_write_errors as f64)),
            ("tier_retries", Json::Num(self.tier_retries as f64)),
            ("pages_lost", Json::Num(self.pages_lost as f64)),
            ("worker_panics", Json::Num(self.worker_panics as f64)),
            // Unconditional so downstream dashboards can key on them
            // without probing: 0/0/0.0 when --hier-pages never ran.
            ("hier_pages_skipped", Json::Num(self.hier_pages_skipped as f64)),
            ("hier_pages_total", Json::Num(self.hier_pages_total as f64)),
            ("hier_skip_frac", Json::Num(self.hier_skip_frac())),
            // Sparse-prefill keys are unconditional too: 0/0/0.0 when
            // the path never ran.
            ("prefill_blocks_skipped", Json::Num(self.prefill_blocks_skipped as f64)),
            ("prefill_blocks_total", Json::Num(self.prefill_blocks_total as f64)),
            ("prefill_blocks_skip_frac", Json::Num(self.prefill_blocks_skip_frac())),
            ("kernel_backend", Json::Str(self.kernel_backend.clone())),
            // Offload keys are unconditional too: all-zero (and
            // resident_frac as populated by the scheduler — 1.0 for a
            // fully-resident engine) when no tier was attached.
            ("offload_faults", Json::Num(self.offload_faults as f64)),
            ("offload_prefetched", Json::Num(self.offload_prefetched as f64)),
            ("offload_evictions", Json::Num(self.offload_evictions as f64)),
            ("offload_bytes_faulted", Json::Num(self.offload_bytes_faulted as f64)),
            ("offload_overlap_frac", Json::Num(self.offload_overlap_frac())),
            ("resident_frac", Json::Num(self.resident_frac)),
        ];
        if !self.governor.is_empty() {
            let pmin = self.governor.iter().map(|e| e.p_scale).fold(f32::INFINITY, f32::min);
            let pmax = self.governor.iter().map(|e| e.p_scale).fold(f32::NEG_INFINITY, f32::max);
            let bmin =
                self.governor.iter().map(|e| e.budget_scale).fold(f32::INFINITY, f32::min);
            let bmax =
                self.governor.iter().map(|e| e.budget_scale).fold(f32::NEG_INFINITY, f32::max);
            let dmax = self.governor.iter().map(|e| e.degrade_level).max().unwrap_or(0);
            kv.push(("governor_decisions", Json::Num(self.governor.len() as f64)));
            kv.push(("governor_p_scale_min", Json::Num(pmin as f64)));
            kv.push(("governor_p_scale_max", Json::Num(pmax as f64)));
            kv.push(("governor_budget_scale_min", Json::Num(bmin as f64)));
            kv.push(("governor_budget_scale_max", Json::Num(bmax as f64)));
            kv.push(("governor_max_degrade", Json::Num(dmax as f64)));
            kv.push(("governor_trace", self.governor_trace_json(64)));
        }
        json::obj(kv)
    }

    /// The decision trace as a JSON array, downsampled to roughly
    /// `max_points` entries (at most `max_points + 1`: the final entry —
    /// the run's ending directive — is always included even when the
    /// stride would skip it) so result files stay diffable.
    pub fn governor_trace_json(&self, max_points: usize) -> Json {
        let entry_json = |e: &TraceEntry| {
            json::obj(vec![
                ("t", Json::Num(e.t)),
                ("p_scale", Json::Num(e.p_scale as f64)),
                ("budget_scale", Json::Num(e.budget_scale as f64)),
                ("degrade", Json::Num(e.degrade_level as f64)),
                ("tpot_ema_ms", Json::Num(e.tpot_ema * 1e3)),
                ("free_frac", Json::Num(e.free_frac)),
                ("mean_mass", Json::Num(e.mean_mass)),
                ("keep_ratio", Json::Num(e.keep_ratio)),
            ])
        };
        let n = self.governor.len();
        let stride = n.div_ceil(max_points.max(1)).max(1);
        let mut arr: Vec<Json> = self.governor.iter().step_by(stride).map(entry_json).collect();
        if n > 0 && (n - 1) % stride != 0 {
            arr.push(entry_json(&self.governor[n - 1]));
        }
        Json::Arr(arr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rm(arrival: f64, first: f64, fin: f64, out: usize) -> RequestMetrics {
        RequestMetrics {
            id: 0,
            prompt_len: 10,
            output_len: out,
            arrival,
            admitted_at: arrival,
            first_token_at: first,
            finished_at: fin,
            preemptions: 0,
            rejected: false,
            started: out > 0,
            fail_reason: None,
        }
    }

    #[test]
    fn ttft_tpot() {
        let r = rm(1.0, 1.5, 2.5, 11);
        assert!((r.ttft() - 0.5).abs() < 1e-12);
        assert!((r.tpot() - 0.1).abs() < 1e-12);
        assert!((r.prefill_time() - 0.5).abs() < 1e-12);
        assert_eq!(r.queue_time(), 0.0);
    }

    #[test]
    fn queue_vs_prefill_split() {
        let mut r = rm(1.0, 2.0, 3.0, 11);
        r.admitted_at = 1.4;
        assert!((r.queue_time() - 0.4).abs() < 1e-12);
        assert!((r.prefill_time() - 0.6).abs() < 1e-12);
        assert!((r.ttft() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejected_excluded_from_latency_summaries() {
        let mut rej = rm(0.0, 0.0, 0.0, 0);
        rej.rejected = true;
        let rep = ServingReport {
            requests: vec![rm(0.0, 0.5, 1.5, 11), rej],
            duration: 1.5,
            ..Default::default()
        };
        assert_eq!(rep.rejected(), 1);
        assert!((rep.ttft_summary().mean - 0.5).abs() < 1e-12);
        // Rejected prompts never prefill: excluded from prompt_tokens too.
        assert_eq!(rep.total_prompt_tokens(), 10);
        let j = rep.to_json();
        assert_eq!(j.get_usize("rejected"), Some(1));
        assert!(j.get_f64("prefill_mean_s").is_some());
        assert_eq!(j.get_usize("prompt_tokens"), Some(10));
    }

    #[test]
    fn single_token_tpot_zero() {
        assert_eq!(rm(0.0, 0.1, 0.1, 1).tpot(), 0.0);
    }

    #[test]
    fn failed_and_never_started_excluded_from_summaries() {
        // One clean request, one failure mid-decode (started), one
        // failure before its first token (never started — its
        // `first_token_at` is a garbage placeholder the summaries must
        // never read).
        let mut failed_started = rm(0.0, 9.0, 9.5, 3);
        failed_started.fail_reason = Some(FailReason::PageLost);
        let mut failed_early = rm(0.0, 0.0, 0.2, 0);
        failed_early.fail_reason = Some(FailReason::WorkerPanic);
        failed_early.started = false;
        let rep = ServingReport {
            requests: vec![rm(0.0, 0.5, 1.5, 11), failed_started, failed_early],
            duration: 1.5,
            ..Default::default()
        };
        assert_eq!(rep.failed(), 2);
        assert_eq!(rep.failed_with(FailReason::PageLost), 1);
        assert_eq!(rep.failed_with(FailReason::WorkerPanic), 1);
        assert_eq!(rep.never_started(), 1);
        assert!((rep.completion_rate() - 1.0 / 3.0).abs() < 1e-12);
        // Only the clean request's latencies survive.
        assert!((rep.ttft_summary().mean - 0.5).abs() < 1e-12);
        assert!((rep.tpot_summary().mean - 0.1).abs() < 1e-12);
        let j = rep.to_json();
        assert_eq!(j.get_usize("failed"), Some(2));
        assert_eq!(j.get_usize("failed_page_lost"), Some(1));
        assert_eq!(j.get_usize("never_started"), Some(1));
        assert!(j.get_f64("completion_rate").is_some());
        assert_eq!(j.get_usize("pages_lost"), Some(0));
    }

    #[test]
    fn report_aggregates() {
        let rep = ServingReport {
            requests: vec![rm(0.0, 0.1, 1.1, 11), rm(0.0, 0.2, 2.2, 21)],
            duration: 2.2,
            ..Default::default()
        };
        assert_eq!(rep.total_output_tokens(), 32);
        assert!((rep.throughput_tok_s() - 32.0 / 2.2).abs() < 1e-9);
        assert!((rep.prefill_throughput_tok_s() - 20.0 / 2.2).abs() < 1e-9);
        let j = rep.to_json();
        assert_eq!(j.get_usize("requests"), Some(2));
        assert!(j.get_f64("tpot_mean_s").unwrap() > 0.0);
        // Full percentile set is always present, per phase.
        for key in [
            "ttft_p50_s",
            "ttft_p90_s",
            "ttft_p99_s",
            "prefill_p50_s",
            "prefill_p90_s",
            "tpot_p50_s",
            "tpot_p90_s",
            "prefill_throughput_tok_s",
        ] {
            assert!(j.get_f64(key).is_some(), "missing {key}");
        }
        // Hier fields are unconditional: 0 when the mode never ran.
        assert_eq!(j.get_f64("hier_skip_frac"), Some(0.0));
        assert_eq!(j.get_usize("hier_pages_total"), Some(0));
        // Sparse-prefill fields are unconditional: 0 when the path
        // never ran.
        assert_eq!(j.get_f64("prefill_blocks_skip_frac"), Some(0.0));
        assert_eq!(j.get_usize("prefill_blocks_total"), Some(0));
        // Kernel backend key is always present (empty when unresolved).
        assert_eq!(j.get_str("kernel_backend"), Some(""));
        // Offload keys are always present: zero for untiered runs.
        assert_eq!(j.get_usize("offload_faults"), Some(0));
        assert_eq!(j.get_f64("offload_overlap_frac"), Some(0.0));
        assert!(j.get_f64("resident_frac").is_some());
        assert!(j.get("governor_trace").is_none(), "ungoverned: no trace block");
    }

    #[test]
    fn governed_report_summarizes_trace() {
        let entry = |t: f64, p: f32, b: f32, lvl: u8| TraceEntry {
            t,
            p_scale: p,
            budget_scale: b,
            degrade_level: lvl,
            tpot_ema: 0.01,
            free_frac: 0.5,
            mean_mass: 0.9,
            keep_ratio: 0.2,
        };
        let rep = ServingReport {
            requests: vec![rm(0.0, 0.1, 1.1, 11)],
            duration: 1.1,
            governor: (0..200)
                .map(|i| entry(i as f64 * 0.01, 1.0 - i as f32 * 0.002, 1.0, (i / 100) as u8))
                .collect(),
            ..Default::default()
        };
        let j = rep.to_json();
        assert_eq!(j.get_usize("governor_decisions"), Some(200));
        assert!(j.get_f64("governor_p_scale_min").unwrap() < 1.0);
        assert_eq!(j.get_f64("governor_max_degrade"), Some(1.0));
        let trace = j.get("governor_trace").unwrap().as_arr().unwrap();
        assert!(trace.len() <= 65 && !trace.is_empty());
        assert!(trace[0].get_f64("p_scale").is_some());
        // The final decision must always survive downsampling.
        let last_t = trace.last().unwrap().get_f64("t").unwrap();
        assert!((last_t - 199.0 * 0.01).abs() < 1e-9, "last entry dropped: t={last_t}");
    }
}
