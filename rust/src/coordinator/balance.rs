//! Head-wise load balancing (paper §4.2, "Load Balancing with Awareness
//! of Head Dynamism").
//!
//! Twilight's per-head dynamic budgets make uniform per-head resource
//! allocation wasteful: a worker assigned a diffuse head (budget ≈ N)
//! stalls the step while workers with focused heads (budget ≈ 10) idle.
//! Following FlashInfer, the (sequence × kv-head) work items are
//! flattened into one list and scheduled longest-processing-time-first
//! (LPT) across workers. The same structure drives the Fig. 13 bench.

/// A unit of attention work: one (sequence, kv-head) pair with a known
/// token budget (= cost, since the kernels are bandwidth-bound).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkItem {
    pub seq: u32,
    pub kv_head: u32,
    pub budget: usize,
}

/// Assignment of items to a worker, with its total cost.
#[derive(Clone, Debug, Default)]
pub struct WorkerLoad {
    pub items: Vec<WorkItem>,
    pub cost: usize,
}

/// Greedy LPT partition of `items` over `workers` workers. Returns the
/// per-worker assignments; makespan = max cost.
pub fn lpt_partition(items: &[WorkItem], workers: usize) -> Vec<WorkerLoad> {
    let workers = workers.max(1);
    let mut sorted: Vec<WorkItem> = items.to_vec();
    sorted.sort_by(|a, b| b.budget.cmp(&a.budget));
    let mut loads = vec![WorkerLoad::default(); workers];
    for it in sorted {
        // Assign to the currently least-loaded worker.
        let w = loads
            .iter()
            .enumerate()
            .min_by_key(|(_, l)| l.cost)
            .map(|(i, _)| i)
            .unwrap();
        loads[w].cost += it.budget;
        loads[w].items.push(it);
    }
    loads
}

/// Naive round-robin partition (the "uniform allocation" strawman).
pub fn round_robin_partition(items: &[WorkItem], workers: usize) -> Vec<WorkerLoad> {
    let workers = workers.max(1);
    let mut loads = vec![WorkerLoad::default(); workers];
    for (i, it) in items.iter().enumerate() {
        let w = i % workers;
        loads[w].cost += it.budget;
        loads[w].items.push(*it);
    }
    loads
}

/// Makespan (max worker cost) of a partition.
pub fn makespan(loads: &[WorkerLoad]) -> usize {
    loads.iter().map(|l| l.cost).max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn items_skewed(seed: u64, n: usize) -> Vec<WorkItem> {
        // Budget distribution like Twilight's: many tiny (focused heads),
        // few huge (diffuse heads).
        let mut r = Rng::new(seed);
        (0..n)
            .map(|i| WorkItem {
                seq: (i / 8) as u32,
                kv_head: (i % 8) as u32,
                budget: if r.chance(0.15) { r.range(4000, 16000) } else { r.range(8, 128) },
            })
            .collect()
    }

    #[test]
    fn lpt_covers_all_items() {
        let items = items_skewed(1, 64);
        let loads = lpt_partition(&items, 4);
        let total: usize = loads.iter().map(|l| l.items.len()).sum();
        assert_eq!(total, 64);
        let cost_total: usize = loads.iter().map(|l| l.cost).sum();
        assert_eq!(cost_total, items.iter().map(|i| i.budget).sum::<usize>());
    }

    #[test]
    fn lpt_beats_round_robin_on_skew() {
        let items = items_skewed(2, 64);
        let lpt = makespan(&lpt_partition(&items, 8));
        let rr = makespan(&round_robin_partition(&items, 8));
        assert!(lpt <= rr, "lpt {lpt} > rr {rr}");
        // And is near the lower bound (total/workers or max item).
        let total: usize = items.iter().map(|i| i.budget).sum();
        let lower = (total / 8).max(items.iter().map(|i| i.budget).max().unwrap());
        assert!(lpt <= lower + lower / 2, "lpt {lpt} vs lower bound {lower}");
    }

    #[test]
    fn single_worker_is_total() {
        let items = items_skewed(3, 10);
        let total: usize = items.iter().map(|i| i.budget).sum();
        assert_eq!(makespan(&lpt_partition(&items, 1)), total);
    }

    #[test]
    fn empty_items() {
        assert_eq!(makespan(&lpt_partition(&[], 4)), 0);
    }
}
