//! MagicPIG [30]: LSH-sampling token selection (the paper's SOTA
//! *non-top-k* baseline).
//!
//! SimHash signatures: `L` tables × `K` random hyperplanes. A token is
//! sampled when its signature collides with the query's in at least one
//! table. There is no budget parameter — accuracy/cost is controlled by
//! (K, L), exactly as in the paper's evaluation (K=8/L=75, K=10/L=150).
//! We always union in a small recency window, mirroring MagicPIG's
//! treatment of local tokens (recent tokens are attended densely).

use super::TokenSelector;
use crate::kvcache::{PagedKvCache, SeqCache};
use crate::tensor::dot;
use crate::util::rng::Rng;

pub struct MagicPig {
    head_dim: usize,
    /// Bits per table.
    pub k: usize,
    /// Number of tables.
    pub l: usize,
    /// Random hyperplanes: `[l][k][d]` flattened.
    planes: Vec<f32>,
    /// Cached per-token signatures `[tok][l]`, filled incrementally.
    sigs: Vec<u64>,
    sig_len: usize,
    recent: usize,
}

impl MagicPig {
    pub fn new(head_dim: usize, k: usize, l: usize, seed: u64) -> MagicPig {
        let mut rng = Rng::new(seed ^ 0x9A61C9);
        let planes = (0..l * k * head_dim).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        MagicPig { head_dim, k: k.min(63), l, planes, sigs: Vec::new(), sig_len: 0, recent: 16 }
    }

    /// K-bit SimHash signature of `x` under table `t`.
    fn signature(&self, t: usize, x: &[f32]) -> u64 {
        let d = self.head_dim;
        let mut sig = 0u64;
        for b in 0..self.k {
            let plane = &self.planes[(t * self.k + b) * d..(t * self.k + b + 1) * d];
            if dot(plane, x) >= 0.0 {
                sig |= 1 << b;
            }
        }
        sig
    }

    /// Extend cached token signatures up to `seq.len`.
    fn extend_sigs(&mut self, cache: &PagedKvCache, seq: &SeqCache, kv_head: usize) {
        let ps = cache.cfg.page_size;
        while self.sig_len < seq.len {
            let t = self.sig_len;
            let (page, slot) = seq.locate(t, ps);
            let k = cache.k_at(page, kv_head, slot);
            for table in 0..self.l {
                self.sigs.push(self.signature(table, k));
            }
            self.sig_len += 1;
        }
    }
}

impl TokenSelector for MagicPig {
    fn name(&self) -> &'static str {
        "magicpig"
    }

    fn select(
        &mut self,
        cache: &PagedKvCache,
        seq: &SeqCache,
        kv_head: usize,
        qs: &[f32],
        group: usize,
        _budget: usize,
    ) -> Vec<usize> {
        if seq.len == 0 {
            return Vec::new();
        }
        self.extend_sigs(cache, seq, kv_head);
        let d = self.head_dim;
        // Query signatures per table, OR-ed over the group's heads.
        let mut out: Vec<usize> = Vec::new();
        let recent_from = seq.len.saturating_sub(self.recent);
        for t in 0..seq.len {
            if t >= recent_from {
                out.push(t);
                continue;
            }
            let mut hit = false;
            'tables: for table in 0..self.l {
                let ks = self.sigs[t * self.l + table];
                for g in 0..group {
                    let qsig = self.signature(table, &qs[g * d..(g + 1) * d]);
                    if qsig == ks {
                        hit = true;
                        break 'tables;
                    }
                }
            }
            if hit {
                out.push(t);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};
    use crate::kvcache::{CacheConfig, PagedKvCache, SeqCache};

    #[test]
    fn identical_key_always_collides() {
        let d = 32;
        let mut cache = PagedKvCache::new(CacheConfig::new(1, d, 8));
        let mut seq = SeqCache::default();
        let q = random_q(31, d);
        for i in 0..64 {
            let k: Vec<f32> = if i == 10 { q.clone() } else { random_q(100 + i, d) };
            cache.append(&mut seq, &k, &k).unwrap();
        }
        let mut s = MagicPig::new(d, 8, 16, 1);
        let got = s.select(&cache, &seq, 0, &q, 1, 0);
        assert!(got.contains(&10), "identical key must collide in every table");
    }

    #[test]
    fn recent_window_always_kept() {
        let (cache, seq) = random_cache(33, 1, 16, 100);
        let q = random_q(34, 16);
        let mut s = MagicPig::new(16, 10, 4, 2);
        let got = s.select(&cache, &seq, 0, &q, 1, 0);
        for t in 84..100 {
            assert!(got.contains(&t));
        }
    }

    #[test]
    fn more_tables_select_more() {
        let (cache, seq) = random_cache(35, 1, 16, 512);
        let q = random_q(36, 16);
        let n_small = MagicPig::new(16, 10, 8, 3).select(&cache, &seq, 0, &q, 1, 0).len();
        let n_big = MagicPig::new(16, 10, 64, 3).select(&cache, &seq, 0, &q, 1, 0).len();
        assert!(n_big >= n_small, "L=64 picked {n_big} < L=8 {n_small}");
    }

    #[test]
    fn signatures_cached_incrementally() {
        let (cache, seq) = random_cache(37, 1, 8, 40);
        let q = random_q(38, 8);
        let mut s = MagicPig::new(8, 6, 4, 4);
        let _ = s.select(&cache, &seq, 0, &q, 1, 0);
        assert_eq!(s.sig_len, 40);
        assert_eq!(s.sigs.len(), 40 * 4);
    }
}
