//! Double Sparsity [12]: token selection via a small "label cache" of the
//! top-r most salient K channels, offline-calibrated per head.
//!
//! DS observes that a few channels dominate the q·K inner product; it
//! stores those channels (quantized to INT4 in the original) and
//! estimates token importance from them alone, then takes the top-k
//! tokens. Our implementation calibrates channels online from the cache
//! contents (|K| channel magnitude — the same AWQ-style statistic the
//! paper's offline pass uses), re-deriving them lazily as the sequence
//! grows.

use super::{top_k_indices, TokenSelector};
use crate::kvcache::{PagedKvCache, SeqCache};

pub struct DoubleSparsity {
    head_dim: usize,
    /// Number of label channels r (paper default d/4 at INT4 ≈ 1/16 traffic).
    r: usize,
    /// Calibrated channel indices (descending salience).
    channels: Vec<usize>,
    /// Sequence length when channels were last calibrated.
    calibrated_at: usize,
}

impl DoubleSparsity {
    pub fn new(head_dim: usize, r: usize) -> DoubleSparsity {
        DoubleSparsity { head_dim, r: r.max(1), channels: Vec::new(), calibrated_at: 0 }
    }

    /// Pick the r channels with the largest mean |K| over the sequence —
    /// the outlier-channel statistic DS calibrates offline.
    fn calibrate(&mut self, cache: &PagedKvCache, seq: &SeqCache, kv_head: usize) {
        let d = self.head_dim;
        let mut mag = vec![0.0f32; d];
        let ps = cache.cfg.page_size;
        // Subsample for long sequences: every 4th token is plenty.
        let stride = if seq.len > 4096 { 4 } else { 1 };
        let mut count = 0u32;
        let mut t = 0;
        while t < seq.len {
            let (page, slot) = seq.locate(t, ps);
            let k = cache.k_at(page, kv_head, slot);
            for (m, &x) in mag.iter_mut().zip(k) {
                *m += x.abs();
            }
            count += 1;
            t += stride;
        }
        if count > 0 {
            for m in mag.iter_mut() {
                *m /= count as f32;
            }
        }
        self.channels = top_k_indices(&mag, self.r);
        self.calibrated_at = seq.len;
    }
}

impl TokenSelector for DoubleSparsity {
    fn name(&self) -> &'static str {
        "ds"
    }

    fn select(
        &mut self,
        cache: &PagedKvCache,
        seq: &SeqCache,
        kv_head: usize,
        qs: &[f32],
        group: usize,
        budget: usize,
    ) -> Vec<usize> {
        if seq.len == 0 {
            return Vec::new();
        }
        // Recalibrate when the sequence has grown substantially.
        if self.channels.is_empty() || seq.len > self.calibrated_at * 2 {
            self.calibrate(cache, seq, kv_head);
        }
        let d = self.head_dim;
        let ps = cache.cfg.page_size;
        // Label-cache score: dot over the r calibrated channels only,
        // max-reduced over the query group.
        let mut scores = vec![f32::NEG_INFINITY; seq.len];
        for g in 0..group {
            let q = &qs[g * d..(g + 1) * d];
            for (t, sc) in scores.iter_mut().enumerate() {
                let (page, slot) = seq.locate(t, ps);
                let k = cache.k_at(page, kv_head, slot);
                let mut s = 0.0f32;
                for &c in &self.channels {
                    s += q[c] * k[c];
                }
                if s > *sc {
                    *sc = s;
                }
            }
        }
        top_k_indices(&scores, budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn respects_budget() {
        let (cache, seq) = random_cache(21, 1, 16, 200);
        let q = random_q(22, 16);
        let mut s = DoubleSparsity::new(16, 4);
        let got = s.select(&cache, &seq, 0, &q, 1, 64);
        assert_eq!(got.len(), 64);
        assert!(got.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn finds_outlier_channel_match() {
        // Keys live mostly in channel 5; a token aligned with q there must
        // be selected even at a tiny budget.
        let d = 16;
        let mut cache =
            crate::kvcache::PagedKvCache::new(crate::kvcache::CacheConfig::new(1, d, 16));
        let mut seq = crate::kvcache::SeqCache::default();
        let mut q = vec![0.0; d];
        q[5] = 1.0;
        let mut r = crate::util::rng::Rng::new(23);
        for i in 0..128 {
            let mut k = vec![0.0f32; d];
            k[5] = if i == 77 { 5.0 } else { r.normal_f32(0.0, 0.5) };
            cache.append(&mut seq, &k, &k).unwrap();
        }
        let mut s = DoubleSparsity::new(d, 2);
        let got = s.select(&cache, &seq, 0, &q, 1, 8);
        assert!(got.contains(&77), "{got:?}");
    }

    #[test]
    fn recalibrates_as_sequence_grows() {
        let (cache, seq) = random_cache(25, 1, 8, 30);
        let q = random_q(26, 8);
        let mut s = DoubleSparsity::new(8, 2);
        let _ = s.select(&cache, &seq, 0, &q, 1, 8);
        let first = s.calibrated_at;
        assert!(first > 0);
        // Grow the cache beyond 2x and reselect.
        let (cache2, seq2) = random_cache(27, 1, 8, 100);
        let _ = s.select(&cache2, &seq2, 0, &q, 1, 8);
        assert!(s.calibrated_at > first);
    }
}
