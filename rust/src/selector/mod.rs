//! Token Selectors — the paper's black-box abstraction over existing
//! sparse-attention algorithms (§4.1).
//!
//! A selector answers: *given this query (group), which candidate tokens
//! should the attention kernel consider?* The Twilight pruner then
//! refines the candidate set with top-p. Every baseline the paper
//! evaluates is implemented here behind one trait:
//!
//! | Selector        | Paper ref        | Kind                      |
//! |-----------------|------------------|---------------------------|
//! | `FullSelector`  | "Full+Twilight"  | trivial (all tokens)      |
//! | `QuestSelector` | Quest [9]        | page min/max upper bound  |
//! | `DoubleSparsity`| DS [12]          | calibrated label channels |
//! | `MagicPig`      | MagicPIG [30]    | LSH sampling (non-top-k)  |
//! | `StreamingLlm`  | StreamingLLM [17]| sink + recency (dropping) |
//! | `SnapKv`        | SnapKV [18]      | pooled observed attention |
//! | `H2O`           | H2O [8]          | accumulated-score eviction|
//! | `OracleTopK`    | Definition 3.2   | exact top-k upper bound   |
//!
//! Selectors may be stateful per (sequence, layer, kv-head): dropping
//! methods (H2O/SnapKV) accumulate observed attention via [`TokenSelector::observe`].

pub mod double_sparsity;
pub mod full;
pub mod h2o;
pub mod magicpig;
pub mod oracle;
pub mod quest;
pub mod snapkv;
pub mod streaming_llm;

use crate::kvcache::{PagedKvCache, SeqCache};

/// The black-box Token Selector interface (paper §4.1). One instance per
/// (sequence, layer, kv-head group); `select` is called every decode step.
pub trait TokenSelector: Send {
    /// Algorithm name for reports.
    fn name(&self) -> &'static str;

    /// Choose candidate tokens for the current step.
    ///
    /// * `qs` — the query heads of this KV group, `[group * d]`.
    /// * `budget` — the conservative token budget (selector may return
    ///   fewer, e.g. when the context is short, or ignore it entirely for
    ///   budget-free methods like MagicPIG).
    ///
    /// Returns ascending logical token indices into `seq`.
    fn select(
        &mut self,
        cache: &PagedKvCache,
        seq: &SeqCache,
        kv_head: usize,
        qs: &[f32],
        group: usize,
        budget: usize,
    ) -> Vec<usize>;

    /// Allocation-aware variant: write the candidate set into a
    /// caller-reused buffer instead of returning a fresh `Vec`. The
    /// engine's zero-allocation decode path calls this; selectors that
    /// can select without allocating (Quest) override it, the rest fall
    /// back to [`TokenSelector::select`] (one transient allocation).
    #[allow(clippy::too_many_arguments)]
    fn select_into(
        &mut self,
        cache: &PagedKvCache,
        seq: &SeqCache,
        kv_head: usize,
        qs: &[f32],
        group: usize,
        budget: usize,
        out: &mut Vec<usize>,
    ) {
        let v = self.select(cache, seq, kv_head, qs, group, budget);
        out.clear();
        out.extend_from_slice(&v);
    }

    /// Feed back the attention weights actually computed this step
    /// (`weights[i]` corresponds to `tokens[i]`). Stateful (dropping)
    /// selectors use this; the default is a no-op.
    fn observe(&mut self, _tokens: &[usize], _weights: &[f32]) {}
}

/// Which selector to construct — parsed from configs/CLI.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelectorKind {
    Full,
    Quest,
    DoubleSparsity,
    MagicPig,
    StreamingLlm,
    SnapKv,
    H2O,
    Oracle,
}

impl SelectorKind {
    pub fn parse(s: &str) -> Option<SelectorKind> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Some(SelectorKind::Full),
            "quest" => Some(SelectorKind::Quest),
            "ds" | "double-sparsity" | "double_sparsity" => Some(SelectorKind::DoubleSparsity),
            "magicpig" | "pig" => Some(SelectorKind::MagicPig),
            "streaming" | "streamingllm" | "streaming-llm" => Some(SelectorKind::StreamingLlm),
            "snapkv" => Some(SelectorKind::SnapKv),
            "h2o" => Some(SelectorKind::H2O),
            "oracle" => Some(SelectorKind::Oracle),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SelectorKind::Full => "full",
            SelectorKind::Quest => "quest",
            SelectorKind::DoubleSparsity => "ds",
            SelectorKind::MagicPig => "magicpig",
            SelectorKind::StreamingLlm => "streaming",
            SelectorKind::SnapKv => "snapkv",
            SelectorKind::H2O => "h2o",
            SelectorKind::Oracle => "oracle",
        }
    }

    /// Construct a fresh selector instance (per seq × layer × kv-head).
    pub fn build(self, head_dim: usize, seed: u64) -> Box<dyn TokenSelector> {
        match self {
            SelectorKind::Full => Box::new(full::FullSelector),
            SelectorKind::Quest => Box::new(quest::QuestSelector::new()),
            SelectorKind::DoubleSparsity => {
                Box::new(double_sparsity::DoubleSparsity::new(head_dim, head_dim / 4))
            }
            SelectorKind::MagicPig => Box::new(magicpig::MagicPig::new(head_dim, 10, 150, seed)),
            SelectorKind::StreamingLlm => Box::new(streaming_llm::StreamingLlm::new(4)),
            SelectorKind::SnapKv => Box::new(snapkv::SnapKv::new(32, 7)),
            SelectorKind::H2O => Box::new(h2o::H2O::new(32)),
            SelectorKind::Oracle => Box::new(oracle::OracleTopK),
        }
    }
}

/// Max-score helper: group queries are reduced by max over the group, the
/// union semantics Quest/NSA use for GQA (B.2).
pub(crate) fn group_max_scores<F: Fn(&[f32], usize) -> f32>(
    qs: &[f32],
    group: usize,
    n: usize,
    score: F,
) -> Vec<f32> {
    let d = qs.len() / group;
    let mut out = vec![f32::NEG_INFINITY; n];
    for g in 0..group {
        let q = &qs[g * d..(g + 1) * d];
        for (t, o) in out.iter_mut().enumerate() {
            let s = score(q, t);
            if s > *o {
                *o = s;
            }
        }
    }
    out
}

/// Take the indices of the `k` largest scores, returned ascending.
pub(crate) fn top_k_indices(scores: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(scores.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    if k < scores.len() {
        idx.select_nth_unstable_by(k, |&a, &b| {
            scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    idx.sort_unstable();
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_kinds() {
        assert_eq!(SelectorKind::parse("quest"), Some(SelectorKind::Quest));
        assert_eq!(SelectorKind::parse("DS"), Some(SelectorKind::DoubleSparsity));
        assert_eq!(SelectorKind::parse("nope"), None);
    }

    #[test]
    fn top_k_indices_basic() {
        let s = vec![0.1, 0.9, 0.5, 0.7, 0.2];
        assert_eq!(top_k_indices(&s, 2), vec![1, 3]);
        assert_eq!(top_k_indices(&s, 5), vec![0, 1, 2, 3, 4]);
        assert_eq!(top_k_indices(&s, 9), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn all_kinds_build() {
        for k in [
            SelectorKind::Full,
            SelectorKind::Quest,
            SelectorKind::DoubleSparsity,
            SelectorKind::MagicPig,
            SelectorKind::StreamingLlm,
            SelectorKind::SnapKv,
            SelectorKind::H2O,
            SelectorKind::Oracle,
        ] {
            let s = k.build(64, 1);
            assert!(!s.name().is_empty());
        }
    }
}
