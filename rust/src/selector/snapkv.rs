//! SnapKV [18]: token importance from pooled attention observed over a
//! recent window of queries (token-dropping baseline, Appendix D).
//!
//! SnapKV originally compresses at prefill using the prompt's last
//! `obs_window` queries. In the decoding harness we maintain the same
//! statistic online: `observe` accumulates the attention mass each token
//! received over the trailing window, a 1-D max-pool smooths it (SnapKV's
//! "clustering" pooling), and selection keeps the top tokens plus the
//! recency window.

use super::{top_k_indices, TokenSelector};
use crate::kvcache::{PagedKvCache, SeqCache};
use std::collections::VecDeque;

pub struct SnapKv {
    /// Observation window: how many recent steps of weights to keep.
    pub obs_window: usize,
    /// Max-pool kernel size (odd).
    pub pool: usize,
    /// Ring of (tokens, weights) observations.
    history: VecDeque<(Vec<usize>, Vec<f32>)>,
    recent: usize,
}

impl SnapKv {
    pub fn new(obs_window: usize, pool: usize) -> SnapKv {
        SnapKv { obs_window, pool: pool | 1, history: VecDeque::new(), recent: 16 }
    }

    /// Accumulated, max-pooled importance per token.
    fn pooled_scores(&self, n: usize) -> Vec<f32> {
        let mut acc = vec![0.0f32; n];
        for (toks, ws) in &self.history {
            for (&t, &w) in toks.iter().zip(ws) {
                if t < n {
                    acc[t] += w;
                }
            }
        }
        // 1-D max pool.
        let r = self.pool / 2;
        let mut out = vec![0.0f32; n];
        for i in 0..n {
            let lo = i.saturating_sub(r);
            let hi = (i + r + 1).min(n);
            let mut m = 0.0f32;
            for &a in &acc[lo..hi] {
                m = m.max(a);
            }
            out[i] = m;
        }
        out
    }
}

impl TokenSelector for SnapKv {
    fn name(&self) -> &'static str {
        "snapkv"
    }

    fn select(
        &mut self,
        _cache: &PagedKvCache,
        seq: &SeqCache,
        _kv_head: usize,
        _qs: &[f32],
        _group: usize,
        budget: usize,
    ) -> Vec<usize> {
        let n = seq.len;
        if n == 0 {
            return Vec::new();
        }
        if self.history.is_empty() {
            // Cold start: recency fallback.
            let from = n.saturating_sub(budget);
            return (from..n).collect();
        }
        let scores = self.pooled_scores(n);
        let keep_recent = self.recent.min(n);
        let top_budget = budget.saturating_sub(keep_recent);
        let mut out = top_k_indices(&scores, top_budget);
        for t in n - keep_recent..n {
            if out.binary_search(&t).is_err() {
                out.push(t);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    fn observe(&mut self, tokens: &[usize], weights: &[f32]) {
        self.history.push_back((tokens.to_vec(), weights.to_vec()));
        while self.history.len() > self.obs_window {
            self.history.pop_front();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn cold_start_is_recency() {
        let (cache, seq) = random_cache(51, 1, 8, 100);
        let q = random_q(52, 8);
        let mut s = SnapKv::new(8, 7);
        let got = s.select(&cache, &seq, 0, &q, 1, 10);
        assert_eq!(got, (90..100).collect::<Vec<_>>());
    }

    #[test]
    fn observed_heavy_token_is_kept() {
        let (cache, seq) = random_cache(53, 1, 8, 200);
        let q = random_q(54, 8);
        let mut s = SnapKv::new(8, 3);
        // Token 42 repeatedly receives most of the attention.
        for _ in 0..5 {
            s.observe(&[10, 42, 150], &[0.1, 0.8, 0.1]);
        }
        let got = s.select(&cache, &seq, 0, &q, 1, 24);
        assert!(got.contains(&42), "{got:?}");
        // Recency window present too.
        assert!(got.contains(&199));
    }

    #[test]
    fn history_bounded() {
        let mut s = SnapKv::new(4, 3);
        for i in 0..20 {
            s.observe(&[i], &[1.0]);
        }
        assert_eq!(s.history.len(), 4);
    }

    #[test]
    fn pooling_spreads_importance() {
        let mut s = SnapKv::new(4, 5);
        s.observe(&[50], &[1.0]);
        let scores = s.pooled_scores(100);
        // Neighbors within the pool radius share the max.
        assert!(scores[48] > 0.0 && scores[52] > 0.0);
        assert_eq!(scores[40], 0.0);
    }
}
