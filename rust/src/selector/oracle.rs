//! Oracle top-k (Definition 3.2): exact logits, exact top-k. The
//! theoretical upper bound of all top-k methods — used by Fig. 2 and the
//! budget-dynamism analyses. Reads the full K cache (not deployable, by
//! construction).

use super::{group_max_scores, top_k_indices, TokenSelector};
use crate::kvcache::{PagedKvCache, SeqCache};

pub struct OracleTopK;

impl TokenSelector for OracleTopK {
    fn name(&self) -> &'static str {
        "oracle"
    }

    fn select(
        &mut self,
        cache: &PagedKvCache,
        seq: &SeqCache,
        kv_head: usize,
        qs: &[f32],
        group: usize,
        budget: usize,
    ) -> Vec<usize> {
        if seq.len == 0 {
            return Vec::new();
        }
        let scores = group_max_scores(qs, group, seq.len, |q, t| {
            cache.exact_score(seq, kv_head, q, t)
        });
        top_k_indices(&scores, budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn picks_exact_top_tokens() {
        let (cache, seq) = random_cache(71, 1, 16, 128);
        let q = random_q(72, 16);
        let logits = crate::attention::exact_logits(&cache, &seq, 0, &q);
        let mut s = OracleTopK;
        let got = s.select(&cache, &seq, 0, &q, 1, 8);
        assert_eq!(got.len(), 8);
        // Every selected token's logit >= every unselected token's logit.
        let min_sel = got.iter().map(|&t| logits[t]).fold(f32::INFINITY, f32::min);
        for t in 0..128 {
            if !got.contains(&t) {
                assert!(logits[t] <= min_sel + 1e-6);
            }
        }
    }
}
