//! H2O [8]: Heavy-Hitter Oracle — keep the tokens with the largest
//! *accumulated* attention scores plus a recency window (token-dropping
//! baseline).

use super::{top_k_indices, TokenSelector};
use crate::kvcache::{PagedKvCache, SeqCache};

pub struct H2O {
    /// Recency window always kept.
    pub recent: usize,
    /// Accumulated attention mass per token.
    acc: Vec<f32>,
}

impl H2O {
    pub fn new(recent: usize) -> H2O {
        H2O { recent, acc: Vec::new() }
    }
}

impl TokenSelector for H2O {
    fn name(&self) -> &'static str {
        "h2o"
    }

    fn select(
        &mut self,
        _cache: &PagedKvCache,
        seq: &SeqCache,
        _kv_head: usize,
        _qs: &[f32],
        _group: usize,
        budget: usize,
    ) -> Vec<usize> {
        let n = seq.len;
        if n == 0 {
            return Vec::new();
        }
        if self.acc.len() < n {
            self.acc.resize(n, 0.0);
        }
        let keep_recent = self.recent.min(n);
        let top_budget = budget.saturating_sub(keep_recent);
        let mut out = top_k_indices(&self.acc[..n], top_budget);
        for t in n - keep_recent..n {
            if out.binary_search(&t).is_err() {
                out.push(t);
            }
        }
        out.sort_unstable();
        out.dedup();
        out
    }

    fn observe(&mut self, tokens: &[usize], weights: &[f32]) {
        for (&t, &w) in tokens.iter().zip(weights) {
            if t >= self.acc.len() {
                self.acc.resize(t + 1, 0.0);
            }
            self.acc[t] += w;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn heavy_hitters_survive() {
        let (cache, seq) = random_cache(61, 1, 8, 100);
        let q = random_q(62, 8);
        let mut s = H2O::new(8);
        for _ in 0..3 {
            s.observe(&[7, 30], &[0.6, 0.4]);
        }
        let got = s.select(&cache, &seq, 0, &q, 1, 16);
        assert!(got.contains(&7));
        assert!(got.contains(&30));
        assert!(got.contains(&99)); // recency
        assert!(got.len() <= 16);
    }

    #[test]
    fn budget_zero_keeps_recent_only() {
        let (cache, seq) = random_cache(63, 1, 8, 50);
        let q = random_q(64, 8);
        let mut s = H2O::new(4);
        let got = s.select(&cache, &seq, 0, &q, 1, 4);
        assert_eq!(got, vec![46, 47, 48, 49]);
    }
}
