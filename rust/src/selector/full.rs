//! The trivial selector: keep every token. "Full + Twilight" in Table 2 —
//! the configuration that isolates the pruner's own effect.

use super::TokenSelector;
use crate::kvcache::{PagedKvCache, SeqCache};

pub struct FullSelector;

impl TokenSelector for FullSelector {
    fn name(&self) -> &'static str {
        "full"
    }

    fn select(
        &mut self,
        _cache: &PagedKvCache,
        seq: &SeqCache,
        _kv_head: usize,
        _qs: &[f32],
        _group: usize,
        _budget: usize,
    ) -> Vec<usize> {
        (0..seq.len).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn returns_everything() {
        let (cache, seq) = random_cache(1, 1, 8, 40);
        let q = random_q(2, 8);
        let mut s = FullSelector;
        let got = s.select(&cache, &seq, 0, &q, 1, 16);
        assert_eq!(got, (0..40).collect::<Vec<_>>());
    }
}
