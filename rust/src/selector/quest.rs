//! Quest [9]: query-aware page selection via per-page min/max metadata.
//!
//! For each page, Quest upper-bounds the attention logit any token in the
//! page can achieve: `ub = Σ_i max(q_i·min_i, q_i·max_i)` using the
//! elementwise min/max of K over the page (maintained by the cache on
//! append). The top pages by upper bound are selected until the token
//! budget is covered; all tokens of a chosen page are candidates (16
//! tokens/page granularity — precisely the layout constraint that makes
//! naive top-p-in-Quest impossible, motivating Twilight's hierarchy).
//!
//! The visibly-partial tail page is scored from its exact K rows (max
//! logit over the visible slots — the tightest possible bound) rather
//! than the page's min/max: the min/max keeps moving while the page
//! fills, and during chunked prefill it already includes tokens *behind*
//! the querying position. Exact tail scoring keeps the selection a pure
//! function of the visible prefix, so candidates are identical for any
//! prefill chunk size (see the sealing contract in `kvcache`).

use super::TokenSelector;
use crate::kvcache::{PagedKvCache, SeqCache};
use crate::tensor::dot;

pub struct QuestSelector {
    /// Scratch: page scores.
    scores: Vec<f32>,
    /// Scratch: page order for the top-pages partial selection.
    order: Vec<usize>,
}

impl QuestSelector {
    pub fn new() -> QuestSelector {
        QuestSelector { scores: Vec::new(), order: Vec::new() }
    }

    /// Quest's per-page upper bound for one query head.
    #[inline]
    fn page_ub(q: &[f32], mn: &[f32], mx: &[f32]) -> f32 {
        let mut s = 0.0f32;
        for i in 0..q.len() {
            s += (q[i] * mn[i]).max(q[i] * mx[i]);
        }
        s
    }
}

impl Default for QuestSelector {
    fn default() -> Self {
        Self::new()
    }
}

impl TokenSelector for QuestSelector {
    fn name(&self) -> &'static str {
        "quest"
    }

    fn select(
        &mut self,
        cache: &PagedKvCache,
        seq: &SeqCache,
        kv_head: usize,
        qs: &[f32],
        group: usize,
        budget: usize,
    ) -> Vec<usize> {
        let mut out = Vec::new();
        self.select_into(cache, seq, kv_head, qs, group, budget, &mut out);
        out
    }

    /// Allocation-free selection: page scores and the selection order
    /// live in selector-owned scratch, candidates land in the caller's
    /// reused buffer — the engine's zero-allocation decode path.
    fn select_into(
        &mut self,
        cache: &PagedKvCache,
        seq: &SeqCache,
        kv_head: usize,
        qs: &[f32],
        group: usize,
        budget: usize,
        out: &mut Vec<usize>,
    ) {
        out.clear();
        let ps = cache.cfg.page_size;
        let npages = seq.pages.len();
        if npages == 0 {
            return;
        }
        let d = qs.len() / group;
        self.scores.clear();
        self.scores.resize(npages, f32::NEG_INFINITY);
        for (pi, &page) in seq.pages.iter().enumerate() {
            let fill = if pi + 1 == npages { seq.len - pi * ps } else { ps };
            if fill < ps {
                // Unsealed tail: its min/max may already cover tokens past
                // this view's visible prefix — score the visible rows
                // exactly (max logit = the tightest upper bound).
                for slot in 0..fill {
                    let k = cache.k_at(page, kv_head, slot);
                    for g in 0..group {
                        let s = dot(&qs[g * d..(g + 1) * d], k);
                        if s > self.scores[pi] {
                            self.scores[pi] = s;
                        }
                    }
                }
                continue;
            }
            let (mn, mx) = cache.minmax_at(page, kv_head);
            // GQA: reduce by max over the group's query heads.
            for g in 0..group {
                let ub = Self::page_ub(&qs[g * d..(g + 1) * d], mn, mx);
                if ub > self.scores[pi] {
                    self.scores[pi] = ub;
                }
            }
        }
        // Pick pages by descending upper bound until the budget is covered.
        let budget_pages = budget.div_ceil(ps).max(1).min(npages);
        self.order.clear();
        self.order.extend(0..npages);
        if budget_pages < npages {
            let scores = &self.scores;
            self.order.select_nth_unstable_by(budget_pages, |&a, &b| {
                scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
            });
            self.order.truncate(budget_pages);
        }
        self.order.sort_unstable();
        for &pi in &self.order {
            let fill = if pi + 1 == npages { seq.len - pi * ps } else { ps };
            let base = pi * ps;
            out.extend(base..base + fill);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};
    use crate::kvcache::{CacheConfig, PagedKvCache, SeqCache};

    #[test]
    fn budget_respected_in_pages() {
        let (cache, seq) = random_cache(1, 1, 16, 160); // 10 pages
        let q = random_q(2, 16);
        let mut s = QuestSelector::new();
        let got = s.select(&cache, &seq, 0, &q, 1, 64);
        assert_eq!(got.len(), 64); // 4 pages * 16
        assert!(got.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn finds_the_hot_page() {
        // Tokens mostly tiny; page 3 holds a strongly-aligned key.
        let d = 16;
        let mut cache = PagedKvCache::new(CacheConfig::new(1, d, 16));
        let mut seq = SeqCache::default();
        let q = random_q(3, d);
        for i in 0..128 {
            let k: Vec<f32> = if i == 3 * 16 + 5 {
                q.iter().map(|x| x * 3.0).collect()
            } else {
                vec![0.01; d]
            };
            cache.append(&mut seq, &k, &k).unwrap();
        }
        let mut s = QuestSelector::new();
        let got = s.select(&cache, &seq, 0, &q, 1, 16);
        assert!(got.contains(&(3 * 16 + 5)), "{got:?}");
    }

    #[test]
    fn beats_recency_at_top_token_recall() {
        // Quest's upper bound is an over-approximation, so it cannot
        // guarantee top-1 recall at small page budgets — but it must
        // recall the exact top tokens far better than a recency window of
        // the same size (that gap is the whole point of query-aware
        // selection).
        // Keys with page-coherent structure (per-page centroid + noise) —
        // the locality Quest's page pooling exploits in real caches;
        // i.i.d. random keys would make every page's bound look alike.
        let d = 32;
        let mut quest_hits = 0usize;
        let mut recency_hits = 0usize;
        let mut total = 0usize;
        for seed in 0..8u64 {
            let mut r = crate::util::rng::Rng::new(700 + seed);
            let mut cache = PagedKvCache::new(CacheConfig::new(1, d, 32));
            let mut seq = SeqCache::default();
            let centroids: Vec<Vec<f32>> = (0..16)
                .map(|_| (0..d).map(|_| r.normal_f32(0.0, 1.0)).collect())
                .collect();
            for i in 0..256 {
                let c = &centroids[i / 16];
                let k: Vec<f32> = c.iter().map(|&x| x + r.normal_f32(0.0, 0.3)).collect();
                cache.append(&mut seq, &k, &k).unwrap();
            }
            let q = random_q(80 + seed, d);
            let logits = crate::attention::exact_logits(&cache, &seq, 0, &q);
            let top16 = crate::selector::top_k_indices(&logits, 16);
            let mut s = QuestSelector::new();
            let quest_sel = s.select(&cache, &seq, 0, &q, 1, 64);
            let recency: Vec<usize> = (256 - 64..256).collect();
            quest_hits += top16.iter().filter(|t| quest_sel.contains(t)).count();
            recency_hits += top16.iter().filter(|t| recency.contains(t)).count();
            total += 16;
        }
        assert!(
            quest_hits > recency_hits * 2,
            "quest {quest_hits}/{total} vs recency {recency_hits}/{total}"
        );
        assert!(quest_hits * 3 > total * 2, "quest recall too low: {quest_hits}/{total}");
    }

    #[test]
    fn partial_last_page() {
        let (cache, seq) = random_cache(9, 1, 8, 20); // 16 + 4
        let q = random_q(10, 8);
        let mut s = QuestSelector::new();
        let got = s.select(&cache, &seq, 0, &q, 1, 1000);
        assert_eq!(got.len(), 20);
    }

    #[test]
    fn gqa_group_reduction() {
        let (cache, seq) = random_cache(11, 1, 8, 64);
        let mut qs = random_q(12, 8);
        qs.extend(random_q(13, 8));
        let mut s = QuestSelector::new();
        let got = s.select(&cache, &seq, 0, &qs, 2, 32);
        assert_eq!(got.len(), 32);
    }
}
