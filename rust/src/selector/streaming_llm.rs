//! StreamingLLM [17]: attention sinks + recency window (query-agnostic
//! token dropping — Appendix D baseline).

use super::TokenSelector;
use crate::kvcache::{PagedKvCache, SeqCache};

pub struct StreamingLlm {
    /// Number of initial "sink" tokens always kept.
    pub sinks: usize,
}

impl StreamingLlm {
    pub fn new(sinks: usize) -> StreamingLlm {
        StreamingLlm { sinks }
    }
}

impl TokenSelector for StreamingLlm {
    fn name(&self) -> &'static str {
        "streaming"
    }

    fn select(
        &mut self,
        _cache: &PagedKvCache,
        seq: &SeqCache,
        _kv_head: usize,
        _qs: &[f32],
        _group: usize,
        budget: usize,
    ) -> Vec<usize> {
        let n = seq.len;
        let sinks = self.sinks.min(n);
        let window = budget.saturating_sub(sinks);
        let recent_from = n.saturating_sub(window).max(sinks);
        let mut out: Vec<usize> = (0..sinks).collect();
        out.extend(recent_from..n);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn sinks_plus_window() {
        let (cache, seq) = random_cache(41, 1, 8, 100);
        let q = random_q(42, 8);
        let mut s = StreamingLlm::new(4);
        let got = s.select(&cache, &seq, 0, &q, 1, 20);
        assert_eq!(got.len(), 20);
        assert_eq!(&got[..4], &[0, 1, 2, 3]);
        assert_eq!(got[4], 84);
        assert_eq!(*got.last().unwrap(), 99);
    }

    #[test]
    fn short_sequence_keeps_all() {
        let (cache, seq) = random_cache(43, 1, 8, 10);
        let q = random_q(44, 8);
        let mut s = StreamingLlm::new(4);
        let got = s.select(&cache, &seq, 0, &q, 1, 64);
        assert_eq!(got, (0..10).collect::<Vec<_>>());
    }
}
