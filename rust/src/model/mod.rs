//! Model substrate: transformer configuration, weights, and the
//! decode-step forward pass.
//!
//! The forward pass is written against the [`LayerBackend`] trait so the
//! *attention implementation is pluggable*: the coordinator engine wires
//! in the paged cache + Token Selector + Twilight Pruner + varlen kernel
//! pipeline, while tests plug a dense backend. Everything else (QKV
//! projections, RoPE, MLP, norms) is computed natively here — and the
//! same graph is exported to HLO by `python/compile/model.py` for the
//! PJRT path; the two are cross-validated in `rust/tests/`.

pub mod retrieval;
pub mod sampler;
pub mod weights;

use crate::tensor::{gemv, rmsnorm, rope_inplace};
use crate::util::json::Json;

/// Transformer architecture configuration (loaded from
/// `artifacts/<model>.json`, written by the python compile path).
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab_size: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub use_rope: bool,
    pub rope_theta: f32,
    pub use_norm: bool,
    pub norm_eps: f32,
    /// Maximum context length the model is rated for.
    pub max_ctx: usize,
}

impl ModelConfig {
    /// GQA group size (query heads per KV head).
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    pub fn from_json(j: &Json) -> Result<ModelConfig, String> {
        let need = |k: &str| j.get_usize(k).ok_or_else(|| format!("config missing '{k}'"));
        let cfg = ModelConfig {
            name: j.get_str("name").unwrap_or("model").to_string(),
            vocab_size: need("vocab_size")?,
            d_model: need("d_model")?,
            n_layers: need("n_layers")?,
            n_heads: need("n_heads")?,
            n_kv_heads: need("n_kv_heads")?,
            head_dim: need("head_dim")?,
            d_ff: need("d_ff")?,
            use_rope: j.get_bool("use_rope").unwrap_or(true),
            rope_theta: j.get_f64("rope_theta").unwrap_or(10000.0) as f32,
            use_norm: j.get_bool("use_norm").unwrap_or(true),
            norm_eps: j.get_f64("norm_eps").unwrap_or(1e-5) as f32,
            max_ctx: j.get_usize("max_ctx").unwrap_or(2048),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_heads % self.n_kv_heads != 0 {
            return Err("n_heads must be divisible by n_kv_heads".into());
        }
        if self.use_rope && self.head_dim % 2 != 0 {
            return Err("rope requires even head_dim".into());
        }
        if self.vocab_size == 0 || self.d_model == 0 || self.n_layers == 0 {
            return Err("zero-sized model dimension".into());
        }
        Ok(())
    }

    pub fn load(path: &str) -> Result<ModelConfig, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("{path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| format!("{path}: {e}"))?;
        ModelConfig::from_json(&j)
    }
}

/// Per-layer weight tensors (row-major, layout documented in
/// `weights.rs`).
pub struct LayerWeights {
    /// `[n_heads*head_dim, d_model]`
    pub wq: Vec<f32>,
    /// `[n_kv_heads*head_dim, d_model]`
    pub wk: Vec<f32>,
    pub wv: Vec<f32>,
    /// `[d_model, n_heads*head_dim]`
    pub wo: Vec<f32>,
    /// `[d_ff, d_model]`
    pub w1: Vec<f32>,
    /// `[d_model, d_ff]`
    pub w2: Vec<f32>,
    pub ln1: Vec<f32>,
    pub ln2: Vec<f32>,
}

/// A complete model: config + weights.
pub struct Model {
    pub cfg: ModelConfig,
    pub embed: Vec<f32>,
    pub lm_head: Vec<f32>,
    pub final_norm: Vec<f32>,
    pub layers: Vec<LayerWeights>,
}

/// The pluggable attention/cache backend for one *sequence*.
pub trait LayerBackend {
    /// Store the new token's K/V (`[n_kv_heads*head_dim]` each, already
    /// roped) for `layer`.
    fn append_kv(&mut self, layer: usize, k: &[f32], v: &[f32]);

    /// Attention output `[n_heads*head_dim]` for roped queries `qs`.
    fn attend(&mut self, layer: usize, qs: &[f32]) -> Vec<f32>;
}

/// One item of a batched *mixed* step: `toks` advances a sequence from
/// position `pos`. A single token is a decode step; a longer span is a
/// prefill **chunk**, whose tokens run through every phase in one pass
/// (the backend attends each chunk query causally over its own prefix).
#[derive(Clone, Copy, Debug)]
pub struct SpanRef<'a> {
    pub toks: &'a [u32],
    /// Sequence position of `toks[0]`.
    pub pos: usize,
    /// Whether the caller will read this item's logits. Decode items and
    /// final prompt chunks set it; a *non-final* prefill chunk clears it
    /// and skips the (full-vocab) unembedding entirely — its returned
    /// logits are all-zero.
    pub need_logits: bool,
}

impl SpanRef<'_> {
    pub fn len(&self) -> usize {
        self.toks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.toks.is_empty()
    }
}

/// The pluggable attention/cache backend for a whole *mixed batch*:
/// decode items and prefill chunks, each a [`SpanRef`].
/// [`Model::decode_batch`] drives every layer through three phases:
/// (a) per-token QKV projection + [`BatchBackend::append_kv`] (serial,
/// item-major then chunk-offset-major — appends mutate the shared page
/// pools), (b) one [`BatchBackend::attend_batch`] call covering every
/// query token of the batch (the serving engine flattens it into
/// (item × kv-head) work items — a chunk item is multi-query, attending
/// each of its tokens causally over the visible prefix — and drains them
/// on its persistent worker pool), then (c) per-token rest-of-layer.
pub trait BatchBackend {
    /// Phase (a): store item `idx`'s next K/V row for `layer`. Called
    /// once per token of the item's span, in chunk order.
    fn append_kv(&mut self, layer: usize, idx: usize, k: &[f32], v: &[f32]);

    /// Phase (b): attention for every query token of the batch at
    /// `layer`. `qs` and `out` are `[total_tokens * n_heads * head_dim]`
    /// where `total_tokens` sums the span lengths, item-major then
    /// chunk-offset-major; the backend must fully overwrite `out`.
    /// Span boundaries are whatever the backend was constructed with —
    /// the forward pass does not re-communicate them.
    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32]);

    /// True when item `idx` has failed (e.g. out of cache pages); the
    /// forward pass skips its per-token compute from then on.
    fn is_failed(&self, _idx: usize) -> bool {
        false
    }
}

/// Adapter running a single-sequence [`LayerBackend`] through the batched
/// forward pass (batch size 1).
struct SingleSeq<'a, B: LayerBackend>(&'a mut B);

impl<B: LayerBackend> BatchBackend for SingleSeq<'_, B> {
    fn append_kv(&mut self, layer: usize, _idx: usize, k: &[f32], v: &[f32]) {
        self.0.append_kv(layer, k, v);
    }

    fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32]) {
        out.copy_from_slice(&self.0.attend(layer, qs));
    }
}

/// GELU (tanh approximation, matching jax.nn.gelu's default).
#[inline]
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + ((0.7978845608_f64 * (x as f64 + 0.044715 * (x as f64).powi(3))).tanh()) as f32)
}

impl Model {
    /// Embed a token id.
    pub fn embed_token(&self, tok: u32) -> Vec<f32> {
        let d = self.cfg.d_model;
        let base = tok as usize * d;
        self.embed[base..base + d].to_vec()
    }

    /// Compute this token's K/V for layer 0 assuming the residual stream
    /// equals the raw embedding — exact for layer 0, which is all a
    /// 1-layer model (the retrieval model) has. Used for O(n) prefill.
    pub fn kv_from_embedding(&self, tok: u32, pos: usize) -> (Vec<f32>, Vec<f32>) {
        assert_eq!(self.cfg.n_layers, 1, "kv_from_embedding is only exact for 1-layer models");
        let c = &self.cfg;
        let x = self.embed_token(tok);
        let mut h = vec![0.0; c.d_model];
        if c.use_norm {
            rmsnorm(&x, &self.layers[0].ln1, c.norm_eps, &mut h);
        } else {
            h.copy_from_slice(&x);
        }
        let mut k = vec![0.0; c.kv_dim()];
        let mut v = vec![0.0; c.kv_dim()];
        gemv(&self.layers[0].wk, &h, None, &mut k);
        gemv(&self.layers[0].wv, &h, None, &mut v);
        if c.use_rope {
            for hh in 0..c.n_kv_heads {
                rope_inplace(&mut k[hh * c.head_dim..(hh + 1) * c.head_dim], pos, c.rope_theta);
            }
        }
        (k, v)
    }

    /// One decode step for a single sequence: embed `tok` at `pos`, run
    /// all layers (attention via `backend`), return logits `[vocab]`.
    /// A batch-of-one view over [`Model::decode_batch`].
    pub fn decode_step<B: LayerBackend>(&self, tok: u32, pos: usize, backend: &mut B) -> Vec<f32> {
        let toks = [tok];
        self.decode_batch(&[SpanRef { toks: &toks, pos, need_logits: true }], &mut SingleSeq(backend))
            .pop()
            .unwrap()
    }

    /// One batched **mixed** step: every [`SpanRef`] advances one sequence
    /// by its span — one token for decode items, a whole prefill chunk for
    /// admission items. Each layer runs as three phases (see
    /// [`BatchBackend`]); per-token compute is strictly item-major then
    /// chunk-offset-major within a phase, so a batch of single-token items
    /// is bit-identical to the historical forward pass, and a chunk is
    /// bit-identical to pushing its tokens through one at a time (a
    /// token's layer-`l` K/V depends only on its own layer-`l-1` output,
    /// which depends only on earlier tokens — layer-major evaluation
    /// computes the same values in the same per-value operation order).
    /// Returns logits `[vocab]` for the *last* token of each span
    /// (all-zero for items the backend marks failed and for items with
    /// `need_logits == false`); intermediate chunk tokens — and whole
    /// non-final chunks — skip the unembedding entirely, so prompt
    /// processing no longer pays `span` full-vocab projections.
    pub fn decode_batch<B: BatchBackend>(
        &self,
        spans: &[SpanRef<'_>],
        backend: &mut B,
    ) -> Vec<Vec<f32>> {
        let c = &self.cfg;
        let qd = c.q_dim();
        // Flatten the spans: token-level residual streams, item-major.
        let mut offs = Vec::with_capacity(spans.len());
        let mut xs: Vec<Vec<f32>> = Vec::new();
        for s in spans {
            offs.push(xs.len());
            for &tok in s.toks {
                xs.push(self.embed_token(tok));
            }
        }
        let total = xs.len();
        let mut h = vec![0.0; c.d_model];
        let mut k = vec![0.0; c.kv_dim()];
        let mut v = vec![0.0; c.kv_dim()];
        let mut ff = vec![0.0; c.d_ff];
        let mut ff_out = vec![0.0; c.d_model];
        let mut attn_res = vec![0.0; c.d_model];
        let mut qs = vec![0.0; total * qd];
        let mut attn = vec![0.0; total * qd];
        for (li, lw) in self.layers.iter().enumerate() {
            // Phase (a): norms + QKV + RoPE + KV append, serial per token
            // (appends mutate the shared page pools).
            let ta = crate::obs::trace::timer();
            for (i, s) in spans.iter().enumerate() {
                for cidx in 0..s.toks.len() {
                    if backend.is_failed(i) {
                        break; // an append mid-span failed: skip the rest
                    }
                    let t = offs[i] + cidx;
                    let pos = s.pos + cidx;
                    if c.use_norm {
                        rmsnorm(&xs[t], &lw.ln1, c.norm_eps, &mut h);
                    } else {
                        h.copy_from_slice(&xs[t]);
                    }
                    let q = &mut qs[t * qd..(t + 1) * qd];
                    gemv(&lw.wq, &h, None, q);
                    gemv(&lw.wk, &h, None, &mut k);
                    gemv(&lw.wv, &h, None, &mut v);
                    if c.use_rope {
                        for hh in 0..c.n_heads {
                            rope_inplace(
                                &mut q[hh * c.head_dim..(hh + 1) * c.head_dim],
                                pos,
                                c.rope_theta,
                            );
                        }
                        for hh in 0..c.n_kv_heads {
                            rope_inplace(
                                &mut k[hh * c.head_dim..(hh + 1) * c.head_dim],
                                pos,
                                c.rope_theta,
                            );
                        }
                    }
                    backend.append_kv(li, i, &k, &v);
                }
            }
            crate::obs::trace::stop(
                ta,
                crate::obs::trace::Stage::Append,
                crate::obs::trace::Tags { layer: li as u16, ..crate::obs::trace::Tags::NONE },
            );
            // Phase (b): attention for every query token at once.
            backend.attend_batch(li, &qs, &mut attn);
            // Phase (c): output projection + MLP, serial per token.
            for (i, s) in spans.iter().enumerate() {
                if backend.is_failed(i) {
                    continue;
                }
                for cidx in 0..s.toks.len() {
                    let t = offs[i] + cidx;
                    let x = &mut xs[t];
                    gemv(&lw.wo, &attn[t * qd..(t + 1) * qd], None, &mut attn_res);
                    for (xi, a) in x.iter_mut().zip(&attn_res) {
                        *xi += a;
                    }
                    if c.use_norm {
                        rmsnorm(x, &lw.ln2, c.norm_eps, &mut h);
                    } else {
                        h.copy_from_slice(x);
                    }
                    gemv(&lw.w1, &h, None, &mut ff);
                    for f in ff.iter_mut() {
                        *f = gelu(*f);
                    }
                    gemv(&lw.w2, &ff, None, &mut ff_out);
                    for (xi, a) in x.iter_mut().zip(&ff_out) {
                        *xi += a;
                    }
                }
            }
        }
        // Unembed the last token of each span — and only for items whose
        // logits the caller will actually read (non-final prefill chunks
        // skip the full-vocab projection entirely).
        let tu = crate::obs::trace::timer();
        let mut out = Vec::with_capacity(spans.len());
        for (i, s) in spans.iter().enumerate() {
            let mut logits = vec![0.0; c.vocab_size];
            if s.need_logits && !backend.is_failed(i) {
                let x = &xs[offs[i] + s.toks.len() - 1];
                if c.use_norm {
                    rmsnorm(x, &self.final_norm, c.norm_eps, &mut h);
                } else {
                    h.copy_from_slice(x);
                }
                gemv(&self.lm_head, &h, None, &mut logits);
            }
            out.push(logits);
        }
        crate::obs::trace::stop(
            tu,
            crate::obs::trace::Stage::Unembed,
            crate::obs::trace::Tags::NONE,
        );
        out
    }

    /// Build a randomly-initialized model — the substrate for unit
    /// tests, integration tests, and benches that need a *multi-layer*
    /// forward pass without artifacts (real weights come from
    /// [`weights::load_model`]).
    pub fn random(cfg: &ModelConfig, seed: u64) -> Model {
        let mut r = crate::util::rng::Rng::new(seed);
        let d = cfg.d_model;
        let mut vecf =
            |n: usize, std: f32| -> Vec<f32> { (0..n).map(|_| r.normal_f32(0.0, std)).collect() };
        let layers = (0..cfg.n_layers)
            .map(|_| LayerWeights {
                wq: vecf(cfg.q_dim() * d, 0.08),
                wk: vecf(cfg.kv_dim() * d, 0.08),
                wv: vecf(cfg.kv_dim() * d, 0.08),
                wo: vecf(d * cfg.q_dim(), 0.08),
                w1: vecf(cfg.d_ff * d, 0.08),
                w2: vecf(d * cfg.d_ff, 0.08),
                ln1: vec![1.0; d],
                ln2: vec![1.0; d],
            })
            .collect();
        Model {
            cfg: cfg.clone(),
            embed: vecf(cfg.vocab_size * d, 0.5),
            lm_head: vecf(cfg.vocab_size * d, 0.1),
            final_norm: vec![1.0; d],
            layers,
        }
    }

    /// Approximate parameter count.
    pub fn param_count(&self) -> usize {
        let mut n = self.embed.len() + self.lm_head.len() + self.final_norm.len();
        for l in &self.layers {
            n += l.wq.len() + l.wk.len() + l.wv.len() + l.wo.len() + l.w1.len() + l.w2.len()
                + l.ln1.len() + l.ln2.len();
        }
        n
    }
}

/// Dense per-sequence backend over plain vectors — the reference backend
/// used by tests and the ppl oracle ("Full" rows in the tables).
pub struct DenseBackend {
    pub cfg: ModelConfig,
    /// Per layer: K rows `[n][kv_dim]` flattened.
    pub k: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl DenseBackend {
    pub fn new(cfg: &ModelConfig) -> DenseBackend {
        DenseBackend {
            cfg: cfg.clone(),
            k: vec![Vec::new(); cfg.n_layers],
            v: vec![Vec::new(); cfg.n_layers],
        }
    }

    pub fn len(&self) -> usize {
        self.k[0].len() / self.cfg.kv_dim().max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.k[0].is_empty()
    }
}

impl LayerBackend for DenseBackend {
    fn append_kv(&mut self, layer: usize, k: &[f32], v: &[f32]) {
        self.k[layer].extend_from_slice(k);
        self.v[layer].extend_from_slice(v);
    }

    fn attend(&mut self, layer: usize, qs: &[f32]) -> Vec<f32> {
        let c = &self.cfg;
        let d = c.head_dim;
        let kvd = c.kv_dim();
        let n = self.k[layer].len() / kvd;
        let group = c.group();
        let mut out = vec![0.0; c.q_dim()];
        // Gather per-KV-head contiguous K/V then dense attention per head.
        let mut kh = vec![0.0; n * d];
        let mut vh = vec![0.0; n * d];
        for h in 0..c.n_heads {
            let kvh = h / group;
            for t in 0..n {
                kh[t * d..(t + 1) * d]
                    .copy_from_slice(&self.k[layer][t * kvd + kvh * d..t * kvd + (kvh + 1) * d]);
                vh[t * d..(t + 1) * d]
                    .copy_from_slice(&self.v[layer][t * kvd + kvh * d..t * kvd + (kvh + 1) * d]);
            }
            crate::attention::full::contiguous_full(
                &qs[h * d..(h + 1) * d],
                &kh,
                &vh,
                &mut out[h * d..(h + 1) * d],
            );
        }
        out
    }
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;

    pub fn tiny_config() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            vocab_size: 16,
            d_model: 24,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 6,
            d_ff: 32,
            use_rope: true,
            rope_theta: 10000.0,
            use_norm: true,
            norm_eps: 1e-5,
            max_ctx: 128,
        }
    }

    pub fn random_model(cfg: &ModelConfig, seed: u64) -> Model {
        Model::random(cfg, seed)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::{random_model, tiny_config};
    use super::*;

    #[test]
    fn decode_produces_finite_logits() {
        let cfg = tiny_config();
        let m = random_model(&cfg, 1);
        let mut b = DenseBackend::new(&cfg);
        for (pos, tok) in [3u32, 7, 1, 0, 15].iter().enumerate() {
            let logits = m.decode_step(*tok, pos, &mut b);
            assert_eq!(logits.len(), 16);
            assert!(logits.iter().all(|x| x.is_finite()));
        }
        assert_eq!(b.len(), 5);
    }

    #[test]
    fn decode_is_deterministic() {
        let cfg = tiny_config();
        let m = random_model(&cfg, 2);
        let run = || {
            let mut b = DenseBackend::new(&cfg);
            let mut last = Vec::new();
            for (pos, tok) in [1u32, 2, 3].iter().enumerate() {
                last = m.decode_step(*tok, pos, &mut b);
            }
            last
        };
        assert_eq!(run(), run());
    }

    /// A dense per-item test backend whose chunk queries attend causally
    /// over their own prefix — the reference semantics the serving engine
    /// implements with views over the paged cache.
    struct DenseBatch {
        seqs: Vec<DenseBackend>,
        /// Span length per item for the current step (set before each
        /// `decode_batch` call).
        spans: Vec<usize>,
    }

    impl BatchBackend for DenseBatch {
        fn append_kv(&mut self, layer: usize, idx: usize, k: &[f32], v: &[f32]) {
            self.seqs[idx].append_kv(layer, k, v);
        }
        fn attend_batch(&mut self, layer: usize, qs: &[f32], out: &mut [f32]) {
            let total: usize = self.spans.iter().sum();
            let qd = qs.len() / total;
            let c = self.seqs[0].cfg.clone();
            let d = c.head_dim;
            let group = c.group();
            let kvd = c.kv_dim();
            let mut t = 0;
            for (i, &span) in self.spans.iter().enumerate() {
                let b = &self.seqs[i];
                let n_after = b.k[layer].len() / kvd;
                for cidx in 0..span {
                    // Causal: query `cidx` sees its own prefix only.
                    let limit = n_after - span + cidx + 1;
                    for hh in 0..c.n_heads {
                        let kvh = hh / group;
                        let mut kh = vec![0.0; limit * d];
                        let mut vh = vec![0.0; limit * d];
                        for tok in 0..limit {
                            kh[tok * d..(tok + 1) * d].copy_from_slice(
                                &b.k[layer][tok * kvd + kvh * d..tok * kvd + (kvh + 1) * d],
                            );
                            vh[tok * d..(tok + 1) * d].copy_from_slice(
                                &b.v[layer][tok * kvd + kvh * d..tok * kvd + (kvh + 1) * d],
                            );
                        }
                        crate::attention::full::contiguous_full(
                            &qs[t * qd + hh * d..t * qd + (hh + 1) * d],
                            &kh,
                            &vh,
                            &mut out[t * qd + hh * d..t * qd + (hh + 1) * d],
                        );
                    }
                    t += 1;
                }
            }
        }
    }

    #[test]
    fn decode_batch_matches_per_sequence_decode() {
        // A batch of independent dense sequences must produce bit-identical
        // logits to the historical one-sequence-at-a-time forward pass.
        let cfg = tiny_config();
        let m = random_model(&cfg, 9);
        let streams: [&[u32]; 2] = [&[3, 7, 1, 0], &[15, 2, 2, 8]];
        // Serial reference.
        let mut serial = Vec::new();
        for toks in streams {
            let mut b = DenseBackend::new(&cfg);
            let mut last = Vec::new();
            for (pos, &tok) in toks.iter().enumerate() {
                last = m.decode_step(tok, pos, &mut b);
            }
            serial.push(last);
        }
        // Batched: both sequences advance in lock-step.
        let mut bb = DenseBatch {
            seqs: vec![DenseBackend::new(&cfg), DenseBackend::new(&cfg)],
            spans: vec![1, 1],
        };
        let mut batched = Vec::new();
        for pos in 0..streams[0].len() {
            batched = m.decode_batch(
                &[
                    SpanRef { toks: &streams[0][pos..pos + 1], pos, need_logits: true },
                    SpanRef { toks: &streams[1][pos..pos + 1], pos, need_logits: true },
                ],
                &mut bb,
            );
        }
        assert_eq!(serial[0], batched[0]);
        assert_eq!(serial[1], batched[1]);
    }

    #[test]
    fn chunked_span_matches_per_token_decode() {
        // Pushing a prompt through as one multi-token chunk must produce
        // the same final logits as token-at-a-time decode (the layer-major
        // evaluation computes identical values; attention is causal).
        let cfg = tiny_config();
        let m = random_model(&cfg, 11);
        let toks: Vec<u32> = vec![3, 7, 1, 0, 15, 2, 9, 4, 12];
        let mut serial_b = DenseBackend::new(&cfg);
        let mut serial = Vec::new();
        for (pos, &tok) in toks.iter().enumerate() {
            serial = m.decode_step(tok, pos, &mut serial_b);
        }
        for split in [1usize, 4, toks.len()] {
            let mut bb = DenseBatch { seqs: vec![DenseBackend::new(&cfg)], spans: vec![] };
            let mut last = Vec::new();
            let mut i = 0;
            while i < toks.len() {
                let end = (i + split).min(toks.len());
                bb.spans = vec![end - i];
                last = m
                    .decode_batch(
                        &[SpanRef { toks: &toks[i..end], pos: i, need_logits: end == toks.len() }],
                        &mut bb,
                    )
                    .pop()
                    .unwrap();
                i = end;
            }
            // Dense attention sums in a different order (contiguous vs the
            // chunk path both use contiguous_full here, so exact equality
            // holds).
            assert_eq!(serial, last, "chunk span {split} diverged");
        }
    }

    #[test]
    fn config_json_roundtrip() {
        let j = Json::parse(
            r#"{"name":"x","vocab_size":10,"d_model":8,"n_layers":1,"n_heads":2,
                "n_kv_heads":1,"head_dim":4,"d_ff":16,"use_rope":false,
                "use_norm":false,"max_ctx":64}"#,
        )
        .unwrap();
        let c = ModelConfig::from_json(&j).unwrap();
        assert_eq!(c.group(), 2);
        assert!(!c.use_rope);
        assert_eq!(c.max_ctx, 64);
    }

    #[test]
    fn config_validation_catches_bad_gqa() {
        let j = Json::parse(
            r#"{"vocab_size":10,"d_model":8,"n_layers":1,"n_heads":3,
                "n_kv_heads":2,"head_dim":4,"d_ff":16}"#,
        )
        .unwrap();
        assert!(ModelConfig::from_json(&j).is_err());
    }

    #[test]
    fn kv_from_embedding_matches_decode_for_1layer() {
        let mut cfg = tiny_config();
        cfg.n_layers = 1;
        let m = random_model(&cfg, 3);
        struct Capture {
            k: Vec<f32>,
            v: Vec<f32>,
        }
        impl LayerBackend for Capture {
            fn append_kv(&mut self, _l: usize, k: &[f32], v: &[f32]) {
                self.k = k.to_vec();
                self.v = v.to_vec();
            }
            fn attend(&mut self, _l: usize, qs: &[f32]) -> Vec<f32> {
                vec![0.0; qs.len()]
            }
        }
        let mut cap = Capture { k: vec![], v: vec![] };
        let _ = m.decode_step(9, 5, &mut cap);
        let (k, v) = m.kv_from_embedding(9, 5);
        for (a, b) in cap.k.iter().zip(&k) {
            assert!((a - b).abs() < 1e-6);
        }
        for (a, b) in cap.v.iter().zip(&v) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn gelu_sane() {
        assert!((gelu(0.0)).abs() < 1e-7);
        assert!((gelu(3.0) - 3.0).abs() < 0.01);
        assert!(gelu(-3.0).abs() < 0.01);
    }
}
