//! TWT tensor-archive format — the weight interchange between the python
//! compile path (`python/compile/weights_io.py`) and the Rust runtime.
//!
//! Layout (little-endian):
//! ```text
//! magic   b"TWT1"
//! u32     n_tensors
//! repeat n_tensors times:
//!   u32   name_len, name bytes (utf-8)
//!   u8    dtype (0 = f32)
//!   u8    ndim
//!   u32   dims[ndim]
//!   f32   data[prod(dims)]
//! ```

use super::{LayerWeights, Model, ModelConfig};
use crate::tensor::Tensor;
use std::collections::HashMap;
use std::io::{Read, Write};

const MAGIC: &[u8; 4] = b"TWT1";

/// Read a TWT archive into name → tensor.
pub fn read_archive<R: Read>(mut r: R) -> std::io::Result<HashMap<String, Tensor>> {
    let bad = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(bad("bad TWT magic"));
    }
    let n = read_u32(&mut r)? as usize;
    if n > 1_000_000 {
        return Err(bad("absurd tensor count"));
    }
    let mut out = HashMap::with_capacity(n);
    for _ in 0..n {
        let name_len = read_u32(&mut r)? as usize;
        if name_len > 4096 {
            return Err(bad("absurd name length"));
        }
        let mut name = vec![0u8; name_len];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name).map_err(|_| bad("non-utf8 tensor name"))?;
        let mut dt = [0u8; 1];
        r.read_exact(&mut dt)?;
        if dt[0] != 0 {
            return Err(bad("unsupported dtype"));
        }
        let mut nd = [0u8; 1];
        r.read_exact(&mut nd)?;
        let mut shape = Vec::with_capacity(nd[0] as usize);
        for _ in 0..nd[0] {
            shape.push(read_u32(&mut r)? as usize);
        }
        let numel: usize = shape.iter().product();
        if numel > 512 * 1024 * 1024 {
            return Err(bad("absurd tensor size"));
        }
        let mut bytes = vec![0u8; numel * 4];
        r.read_exact(&mut bytes)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        out.insert(name, Tensor::from_vec(data, &shape));
    }
    Ok(out)
}

/// Write a TWT archive (used by tests and the retrieval-model builder).
pub fn write_archive<W: Write>(mut w: W, tensors: &[(String, Tensor)]) -> std::io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        w.write_all(&(name.len() as u32).to_le_bytes())?;
        w.write_all(name.as_bytes())?;
        w.write_all(&[0u8, t.shape.len() as u8])?;
        for &d in &t.shape {
            w.write_all(&(d as u32).to_le_bytes())?;
        }
        for &x in &t.data {
            w.write_all(&x.to_le_bytes())?;
        }
    }
    Ok(())
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

/// Assemble a [`Model`] from an archive + config, verifying every shape.
pub fn model_from_archive(
    cfg: ModelConfig,
    mut tensors: HashMap<String, Tensor>,
) -> Result<Model, String> {
    let mut take = |name: &str, want: &[usize]| -> Result<Vec<f32>, String> {
        let t = tensors.remove(name).ok_or_else(|| format!("missing tensor '{name}'"))?;
        if t.shape != want {
            return Err(format!("tensor '{name}': shape {:?}, want {:?}", t.shape, want));
        }
        Ok(t.data)
    };
    let d = cfg.d_model;
    let embed = take("embed", &[cfg.vocab_size, d])?;
    let lm_head = take("lm_head", &[cfg.vocab_size, d])?;
    let final_norm = take("final_norm", &[d])?;
    let mut layers = Vec::with_capacity(cfg.n_layers);
    for i in 0..cfg.n_layers {
        let p = |s: &str| format!("layers.{i}.{s}");
        layers.push(LayerWeights {
            wq: take(&p("wq"), &[cfg.q_dim(), d])?,
            wk: take(&p("wk"), &[cfg.kv_dim(), d])?,
            wv: take(&p("wv"), &[cfg.kv_dim(), d])?,
            wo: take(&p("wo"), &[d, cfg.q_dim()])?,
            w1: take(&p("w1"), &[cfg.d_ff, d])?,
            w2: take(&p("w2"), &[d, cfg.d_ff])?,
            ln1: take(&p("ln1"), &[d])?,
            ln2: take(&p("ln2"), &[d])?,
        });
    }
    Ok(Model { cfg, embed, lm_head, final_norm, layers })
}

/// Load a model from `<dir>/<name>.json` + `<dir>/<name>.twt`.
pub fn load_model(dir: &str, name: &str) -> Result<Model, String> {
    let cfg = ModelConfig::load(&format!("{dir}/{name}.json"))?;
    let f = std::fs::File::open(format!("{dir}/{name}.twt"))
        .map_err(|e| format!("{dir}/{name}.twt: {e}"))?;
    let tensors = read_archive(std::io::BufReader::new(f)).map_err(|e| e.to_string())?;
    model_from_archive(cfg, tensors)
}

/// Serialize a model back to (config json, archive tensors) — used by the
/// Rust-side retrieval builder and tests.
pub fn model_to_tensors(m: &Model) -> Vec<(String, Tensor)> {
    let c = &m.cfg;
    let d = c.d_model;
    let mut out = vec![
        ("embed".to_string(), Tensor::from_vec(m.embed.clone(), &[c.vocab_size, d])),
        ("lm_head".to_string(), Tensor::from_vec(m.lm_head.clone(), &[c.vocab_size, d])),
        ("final_norm".to_string(), Tensor::from_vec(m.final_norm.clone(), &[d])),
    ];
    for (i, l) in m.layers.iter().enumerate() {
        let p = |s: &str| format!("layers.{i}.{s}");
        out.push((p("wq"), Tensor::from_vec(l.wq.clone(), &[c.q_dim(), d])));
        out.push((p("wk"), Tensor::from_vec(l.wk.clone(), &[c.kv_dim(), d])));
        out.push((p("wv"), Tensor::from_vec(l.wv.clone(), &[c.kv_dim(), d])));
        out.push((p("wo"), Tensor::from_vec(l.wo.clone(), &[d, c.q_dim()])));
        out.push((p("w1"), Tensor::from_vec(l.w1.clone(), &[c.d_ff, d])));
        out.push((p("w2"), Tensor::from_vec(l.w2.clone(), &[d, c.d_ff])));
        out.push((p("ln1"), Tensor::from_vec(l.ln1.clone(), &[d])));
        out.push((p("ln2"), Tensor::from_vec(l.ln2.clone(), &[d])));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::testutil::{random_model, tiny_config};

    #[test]
    fn archive_roundtrip() {
        let t1 = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let t2 = Tensor::from_vec(vec![0.5], &[1]);
        let mut buf = Vec::new();
        write_archive(&mut buf, &[("a".into(), t1.clone()), ("b".into(), t2.clone())]).unwrap();
        let m = read_archive(&buf[..]).unwrap();
        assert_eq!(m["a"], t1);
        assert_eq!(m["b"], t2);
    }

    #[test]
    fn rejects_bad_magic() {
        let buf = b"NOPE\x00\x00\x00\x00".to_vec();
        assert!(read_archive(&buf[..]).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let t = Tensor::from_vec(vec![1.0; 10], &[10]);
        let mut buf = Vec::new();
        write_archive(&mut buf, &[("x".into(), t)]).unwrap();
        buf.truncate(buf.len() - 5);
        assert!(read_archive(&buf[..]).is_err());
    }

    #[test]
    fn model_roundtrip_through_archive() {
        let cfg = tiny_config();
        let m = random_model(&cfg, 9);
        let tensors = model_to_tensors(&m);
        let mut buf = Vec::new();
        write_archive(&mut buf, &tensors).unwrap();
        let map = read_archive(&buf[..]).unwrap();
        let m2 = model_from_archive(cfg.clone(), map).unwrap();
        assert_eq!(m.embed, m2.embed);
        assert_eq!(m.layers[1].wo, m2.layers[1].wo);
        assert_eq!(m.param_count(), m2.param_count());
    }

    #[test]
    fn shape_mismatch_detected() {
        let cfg = tiny_config();
        let m = random_model(&cfg, 10);
        let mut tensors = model_to_tensors(&m);
        // Corrupt a shape.
        tensors[0].1 = Tensor::from_vec(vec![0.0; 4], &[2, 2]);
        let mut buf = Vec::new();
        write_archive(&mut buf, &tensors).unwrap();
        let map = read_archive(&buf[..]).unwrap();
        assert!(model_from_archive(cfg, map).is_err());
    }
}
