//! Token sampling from logits: greedy, temperature, and top-p (nucleus)
//! sampling — the *original* top-p whose analogy motivates the paper's
//! attention pruner.

use crate::util::rng::Rng;

/// Sampling parameters for a request.
#[derive(Clone, Copy, Debug)]
pub struct SamplingParams {
    pub temperature: f32,
    /// Nucleus threshold; 0.0 or 1.0 with temperature 0 = greedy.
    pub top_p: f32,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_p: 1.0 }
    }
}

/// Greedy argmax.
pub fn greedy(logits: &[f32]) -> u32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap_or(std::cmp::Ordering::Equal))
        .map(|(i, _)| i as u32)
        .unwrap_or(0)
}

/// Sample according to `params`.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> u32 {
    if params.temperature <= 0.0 {
        return greedy(logits);
    }
    let mut probs: Vec<f32> = logits.iter().map(|&l| l / params.temperature).collect();
    crate::tensor::softmax_inplace(&mut probs);
    // Nucleus filter.
    let mut order: Vec<usize> = (0..probs.len()).collect();
    order.sort_by(|&a, &b| probs[b].partial_cmp(&probs[a]).unwrap());
    let mut mass = 0.0f32;
    let mut cut = probs.len();
    for (rank, &i) in order.iter().enumerate() {
        mass += probs[i];
        if mass >= params.top_p {
            cut = rank + 1;
            break;
        }
    }
    let kept = &order[..cut];
    let total: f32 = kept.iter().map(|&i| probs[i]).sum();
    let mut u = rng.f32() * total;
    for &i in kept {
        u -= probs[i];
        if u <= 0.0 {
            return i as u32;
        }
    }
    kept[kept.len() - 1] as u32
}

/// Log-probability of `tok` under the logits (for perplexity evals).
pub fn log_prob(logits: &[f32], tok: u32) -> f64 {
    let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let z: f64 = logits.iter().map(|&l| ((l as f64) - max).exp()).sum();
    (logits[tok as usize] as f64 - max) - z.ln()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        assert_eq!(greedy(&[0.1, 3.0, -1.0]), 1);
    }

    #[test]
    fn temperature_zero_is_greedy() {
        let mut r = Rng::new(1);
        let p = SamplingParams { temperature: 0.0, top_p: 0.5 };
        assert_eq!(sample(&[0.0, 5.0, 1.0], &p, &mut r), 1);
    }

    #[test]
    fn top_p_restricts_support() {
        let mut r = Rng::new(2);
        // One dominant logit: nucleus 0.5 keeps only it.
        let p = SamplingParams { temperature: 1.0, top_p: 0.5 };
        for _ in 0..100 {
            assert_eq!(sample(&[10.0, 0.0, 0.0], &p, &mut r), 0);
        }
    }

    #[test]
    fn sampling_covers_support_at_high_temp() {
        let mut r = Rng::new(3);
        let p = SamplingParams { temperature: 5.0, top_p: 1.0 };
        let mut seen = [false; 3];
        for _ in 0..300 {
            seen[sample(&[0.1, 0.0, -0.1], &p, &mut r) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn log_prob_normalizes() {
        let logits = [1.0f32, 2.0, 3.0];
        let total: f64 = (0..3).map(|t| log_prob(&logits, t).exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }
}
