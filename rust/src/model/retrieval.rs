//! Constructed-weights retrieval model — the long-context evaluation
//! substrate (DESIGN.md §3, §7).
//!
//! A single-attention-layer GQA transformer whose weights are built
//! analytically so that its behaviour is *provable*:
//!
//! * **KV head 0 (retrieval, query heads 0–3)** implements exact
//!   key-match attention: for a NIAH query token carrying key `k`, the
//!   attention logit is `β/√dh` at every pair token bound to `k` and `0`
//!   elsewhere (the query token's own key is suppressed with a large
//!   negative flag term). The resulting weight distribution is **focused**
//!   — the regime where top-k over-selects and top-p prunes to a handful
//!   of tokens.
//! * **KV head 1 (aggregation, query heads 4–7)** implements uniform
//!   attention over pair tokens for FWE queries: the output is the value
//!   frequency vector. The distribution is **diffuse** — the regime where
//!   a fixed top-k budget under-selects and corrupts the frequency
//!   estimate.
//!
//! The unembedding reads the combined value channel, so greedy decoding
//! answers NIAH with the needle's value and FWE with the modal value —
//! *iff* the sparse-attention pipeline preserved the relevant attention
//! mass. Accuracy under any selector/pruner is therefore an exact probe
//! of selection fidelity, at any context length, with O(n) prefill
//! (single layer ⇒ K/V depend only on embeddings).
//!
//! Mirrored by `python/compile/retrieval_model.py`, which exports the
//! same weights through the TWT archive; `rust/tests/` checks parity.

use super::{LayerWeights, Model, ModelConfig};
use crate::workload::RetrievalVocab;

/// Query-head gain for retrieval heads: match logit = BETA / sqrt(dh).
pub const BETA: f32 = 90.0;
/// Suppression applied to the query token's own key signature.
pub const SELF_SUPPRESS: f32 = 10.0;
/// FWE query gain: pair-token logit = FWE_GAIN / sqrt(dh).
pub const FWE_GAIN: f32 = 17.0;
/// Output mixing: retrieval channel weight.
pub const ALPHA_R: f32 = 4.0;
/// Output mixing: aggregation channel weight.
pub const ALPHA_F: f32 = 1.0;

/// Fixed geometry of the constructed model.
pub fn retrieval_config(vocab: RetrievalVocab, max_ctx: usize) -> ModelConfig {
    assert!(vocab.n_keys <= 16 && vocab.n_vals <= 16, "channel layout sized for <=16");
    ModelConfig {
        name: "retrieval".into(),
        vocab_size: vocab.vocab_size() as usize,
        d_model: 64,
        n_layers: 1,
        n_heads: 8,
        n_kv_heads: 2,
        head_dim: 32,
        d_ff: 4,
        use_rope: false,
        rope_theta: 10000.0,
        use_norm: false,
        norm_eps: 1e-5,
        max_ctx,
    }
}

// Channel layout in d_model = 64:
const CH_KEY: usize = 0; // 0..16  key one-hot
const CH_VAL: usize = 16; // 16..32 value one-hot
const CH_IS_PAIR: usize = 32;
const CH_IS_QNIAH: usize = 33;
const CH_IS_QFWE: usize = 34;
const CH_OUT: usize = 48; // 48..64 combined value output

/// Build the model for `vocab`.
pub fn build_retrieval_model(vocab: RetrievalVocab, max_ctx: usize) -> Model {
    let cfg = retrieval_config(vocab, max_ctx);
    let d = cfg.d_model;
    let dh = cfg.head_dim;
    let nk = vocab.n_keys as usize;
    let nv = vocab.n_vals as usize;

    // ---- embeddings -----------------------------------------------------
    let mut embed = vec![0.0f32; cfg.vocab_size * d];
    for k in 0..nk as u32 {
        for v in 0..nv as u32 {
            let row = vocab.pair(k, v) as usize * d;
            embed[row + CH_KEY + k as usize] = 1.0;
            embed[row + CH_VAL + v as usize] = 1.0;
            embed[row + CH_IS_PAIR] = 1.0;
        }
        let row = vocab.query_niah(k) as usize * d;
        embed[row + CH_KEY + k as usize] = 1.0;
        embed[row + CH_IS_QNIAH] = 1.0;
    }
    embed[vocab.query_fwe() as usize * d + CH_IS_QFWE] = 1.0;
    // Answer tokens only appear as outputs; embed them harmlessly so
    // multi-token decoding stays well-defined.
    for v in 0..nv as u32 {
        embed[vocab.answer(v) as usize * d + CH_VAL + v as usize] = 1.0;
    }

    // ---- attention projections ------------------------------------------
    // W_Q: [n_heads*dh, d]. Heads 0..4 retrieval, 4..8 aggregation.
    let mut wq = vec![0.0f32; cfg.q_dim() * d];
    for h in 0..4 {
        for i in 0..nk {
            // Q[h*dh + i] = BETA * x[CH_KEY + i]
            wq[(h * dh + i) * d + CH_KEY + i] = BETA;
        }
    }
    for h in 4..8 {
        // Q[h*dh + 0] = FWE_GAIN * x[CH_IS_QFWE]
        wq[(h * dh) * d + CH_IS_QFWE] = FWE_GAIN;
    }

    // W_K: [n_kv_heads*dh, d]. KV head 0 = key signature (with query-token
    // self suppression), KV head 1 = is_pair.
    let mut wk = vec![0.0f32; cfg.kv_dim() * d];
    for i in 0..nk {
        wk[i * d + CH_KEY + i] = 1.0;
        wk[i * d + CH_IS_QNIAH] = -SELF_SUPPRESS;
    }
    wk[dh * d + CH_IS_PAIR] = 1.0; // kv head 1, dim 0

    // W_V: both KV heads expose the value one-hot in dims 0..nv.
    let mut wv = vec![0.0f32; cfg.kv_dim() * d];
    for i in 0..nv {
        wv[i * d + CH_VAL + i] = 1.0; // kv head 0
        wv[(dh + i) * d + CH_VAL + i] = 1.0; // kv head 1
    }

    // W_O: [d, n_heads*dh]. Retrieval heads write ALPHA_R/4 each,
    // aggregation heads ALPHA_F/4 each, into CH_OUT..CH_OUT+nv.
    let mut wo = vec![0.0f32; d * cfg.q_dim()];
    for h in 0..8 {
        let gain = if h < 4 { ALPHA_R / 4.0 } else { ALPHA_F / 4.0 };
        for i in 0..nv {
            wo[(CH_OUT + i) * cfg.q_dim() + h * dh + i] = gain;
        }
    }

    // ---- unembedding ------------------------------------------------------
    let mut lm_head = vec![0.0f32; cfg.vocab_size * d];
    for v in 0..nv as u32 {
        lm_head[vocab.answer(v) as usize * d + CH_OUT + v as usize] = 1.0;
    }

    let layers = vec![LayerWeights {
        wq,
        wk,
        wv,
        wo,
        w1: vec![0.0; cfg.d_ff * d],
        w2: vec![0.0; d * cfg.d_ff],
        ln1: vec![1.0; d],
        ln2: vec![1.0; d],
    }];

    Model { cfg, embed, lm_head, final_norm: vec![1.0; d], layers }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{DenseBackend, LayerBackend};
    use crate::util::rng::Rng;
    use crate::workload::{gen_fwe, gen_multi_niah, gen_niah};

    const V: RetrievalVocab = RetrievalVocab::DEFAULT;

    /// Run a request through the model with dense attention; return the
    /// predicted token.
    fn predict(m: &Model, prompt: &[u32]) -> u32 {
        let mut b = DenseBackend::new(&m.cfg);
        // O(n) prefill: single layer — K/V from embeddings.
        for (pos, &tok) in prompt[..prompt.len() - 1].iter().enumerate() {
            let (k, v) = m.kv_from_embedding(tok, pos);
            b.append_kv(0, &k, &v);
        }
        let logits = m.decode_step(*prompt.last().unwrap(), prompt.len() - 1, &mut b);
        logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0 as u32
    }

    #[test]
    fn niah_dense_accuracy_is_perfect() {
        let m = build_retrieval_model(V, 4096);
        let mut r = Rng::new(1);
        for _ in 0..10 {
            let g = gen_niah(&mut r, V, 512);
            assert_eq!(predict(&m, &g.prompt), g.answer, "NIAH failed");
        }
    }

    #[test]
    fn multi_niah_dense_accuracy_is_perfect() {
        let m = build_retrieval_model(V, 4096);
        let mut r = Rng::new(2);
        for _ in 0..5 {
            let g = gen_multi_niah(&mut r, V, 512, 4);
            assert_eq!(predict(&m, &g.prompt), g.answer, "multi-NIAH failed");
        }
    }

    #[test]
    fn fwe_dense_accuracy_is_perfect() {
        let m = build_retrieval_model(V, 4096);
        let mut r = Rng::new(3);
        for _ in 0..5 {
            let g = gen_fwe(&mut r, V, 1024, 8.0);
            assert_eq!(predict(&m, &g.prompt), g.answer, "FWE failed");
        }
    }

    #[test]
    fn retrieval_head_is_focused_and_fwe_head_is_diffuse() {
        // Measures the Fig. 3 claim directly on the constructed model.
        let m = build_retrieval_model(V, 4096);
        let mut r = Rng::new(4);
        let g = gen_niah(&mut r, V, 512);
        let mut b = DenseBackend::new(&m.cfg);
        for (pos, &tok) in g.prompt[..512].iter().enumerate() {
            let (k, v) = m.kv_from_embedding(tok, pos);
            b.append_kv(0, &k, &v);
        }
        let _ = m.decode_step(g.prompt[512], 512, &mut b);
        // Reconstruct per-head weights from the dense cache.
        let cfg = &m.cfg;
        let x = m.embed_token(g.prompt[512]);
        let mut q = vec![0.0; cfg.q_dim()];
        crate::tensor::gemv(&m.layers[0].wq, &x, None, &mut q);
        let dh = cfg.head_dim;
        let kvd = cfg.kv_dim();
        let n = b.len();
        let head_weights = |h: usize| -> Vec<f32> {
            let kvh = h / cfg.group();
            let mut w: Vec<f32> = (0..n)
                .map(|t| {
                    let kt = &b.k[0][t * kvd + kvh * dh..t * kvd + (kvh + 1) * dh];
                    crate::tensor::dot(&q[h * dh..(h + 1) * dh], kt)
                        / (dh as f32).sqrt()
                })
                .collect();
            crate::tensor::softmax_inplace(&mut w);
            w
        };
        let focused = head_weights(0); // retrieval head
        let diffuse = head_weights(4); // aggregation head (NIAH query → uniform)
        let b_focused = crate::pruner::topp::oracle_budget(&focused, 0.9);
        let b_diffuse = crate::pruner::topp::oracle_budget(&diffuse, 0.9);
        assert!(b_focused <= 4, "retrieval head budget {b_focused}");
        assert!(b_diffuse >= n / 2, "aggregation head budget {b_diffuse} of {n}");
    }

    #[test]
    fn truncating_context_breaks_niah() {
        // Sanity: the model *needs* the needle — recency-only context fails.
        let m = build_retrieval_model(V, 4096);
        let mut r = Rng::new(5);
        // Needle placed early; keep only the last 64 pairs.
        let g = loop {
            let g = gen_niah(&mut r, V, 512);
            // find needle position
            let qkey = g.prompt[512] - V.n_keys * V.n_vals;
            let pos = (0..512).find(|&p| V.pair_key(g.prompt[p]) == qkey).unwrap();
            if pos < 300 {
                break g;
            }
        };
        let mut truncated: Vec<u32> = g.prompt[448..512].to_vec();
        truncated.push(g.prompt[512]);
        assert_ne!(predict(&m, &truncated), g.answer);
    }
}
