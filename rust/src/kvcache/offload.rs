//! Tiered KV offload: the slow-tier abstraction ([`Tier`]), the paged
//! cache's residency state machine ([`TierState`]), and the hier-bound
//! prefetch plan ([`PrefetchPlan`]) — plus the original per-token
//! [`OffloadArena`] simulation kept for the Table 7 operator bench.
//!
//! The paper's offloading scenario keeps the KV cache in host memory and
//! pays a transfer cost to bring selected tokens to the GPU; Twilight
//! wins big there because its final budget is tiny while its estimation
//! cost (reading the small INT4 mirror, which stays resident) is fixed.
//! The engine-level design mirrors that split:
//!
//! * **What spills.** Only *sealed* pages (full, mirror built) ever move
//!   to the slow tier; the INT4 mirror, the Quest min/max metadata, and
//!   the unsealed fp32 tail are always resident. Stage 1 (selection) and
//!   stage 2 (pruning) therefore never fault — only stage 3's exact-K/V
//!   reads do.
//! * **Write-through at seal.** A page's K/V is written to the tier the
//!   moment it seals (and once more for pre-sealed pages when a tier is
//!   attached mid-life), so *eviction is a metadata flip*: the resident
//!   fp32 region is zeroed (stale reads fail loudly, they don't silently
//!   return old data) and the page's state becomes `EVICTED`. Faulting
//!   restores the exact bytes written at seal, which is why offloaded
//!   decode is bit-exact vs fully-resident (`rust/tests/offload_decode.rs`).
//! * **Fault-on-read.** `PagedKvCache::{k_at, v_at}` check residency and
//!   fault the whole page in on miss (one CAS winner performs the tier
//!   read; racers spin on `LOADING`). The hier-pages bound (PR 5) is the
//!   *prefetch oracle*: before the attention phase the engine ranks a
//!   sequence's non-resident sealed pages by their Quest-plus-slack logit
//!   bound into a [`PrefetchPlan`], then fuses every item's plan for the
//!   layer into **one sorted, deduped page batch** served by a single
//!   prefetch ticket scheduled *ahead of* the attention tickets: one
//!   ascending positional sweep over the tier (sequential I/O on
//!   [`FileTier`], no duplicate faults for pages shared across plans)
//!   that overlaps attention on already-resident pages. Batching cannot
//!   cross *layers* — layer `l+1`'s queries, and so its bounds, depend on
//!   layer `l`'s outputs. Per-page CAS semantics are unchanged, so the
//!   faulted set (and the fault count) is identical to per-plan tickets.
//! * **Victims.** LRU over a deterministic clock (the engine step
//!   ordinal, never wall time) with page-id tie-breaks; the governor's
//!   pressure ladder scales the effective residency cap down. Both
//!   inputs are deterministic, so the resident set — and therefore the
//!   total fault count — is identical for any thread count.
//!
//! The [`OffloadArena`] at the bottom is the original bench-only model
//! of the slow link (`load_tokens` pays `slowdown` redundant passes per
//! token); `benches/table7_offload.rs` still uses it for the per-token
//! operator comparison, while the engine panels use the real tier.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use super::PageId;

// --- the slow tier -------------------------------------------------------

/// A slow storage tier holding sealed pages' K/V at page granularity.
///
/// Implementations are shared read-only across the worker pool: faults
/// run on pool threads while the engine thread is parked in
/// `ThreadPool::run`. Per-page exclusivity is the *caller's* contract —
/// `TierState`'s `EVICTED → LOADING` CAS admits one reader per page at a
/// time, and `write_page` is only called from `&mut PagedKvCache`
/// contexts (page seal, tier attach), never concurrently with a read of
/// the same page.
pub trait Tier: Send + Sync {
    /// Stable backend name (reports / bench labels).
    fn name(&self) -> &'static str;
    /// Spill one page: `k`/`v` are the page's full
    /// `[kv_heads * page_size * head_dim]` regions.
    fn write_page(&self, page: usize, k: &[f32], v: &[f32]);
    /// Fault one page back; `write_page(page, ..)` must have happened.
    fn read_page(&self, page: usize, k_out: &mut [f32], v_out: &mut [f32]);
}

/// Interior-mutable page storage shared across pool threads.
///
/// Soundness: writes to a page's region happen either under `&mut`
/// (construction) or gated by the per-page `written` flag's
/// release-store / acquire-load pair, and the `TierState` page state
/// machine guarantees no concurrent writer+reader on the same page (see
/// [`Tier`]). Distinct pages occupy disjoint ranges.
struct TierStore(UnsafeCell<Vec<f32>>);

// SAFETY: see the struct docs — per-page exclusivity is enforced by the
// caller's page state machine; the Vec itself never reallocates after
// construction.
unsafe impl Sync for TierStore {}

impl TierStore {
    fn new(n: usize) -> TierStore {
        TierStore(UnsafeCell::new(vec![0.0; n]))
    }

    /// Read a page region. Caller guarantees no concurrent writer.
    #[inline]
    fn read(&self, a: usize, n: usize) -> &[f32] {
        unsafe { &(*self.0.get())[a..a + n] }
    }

    /// Write a page region. Caller guarantees exclusivity for the range.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    unsafe fn write(&self, a: usize, n: usize) -> &mut [f32] {
        &mut (*self.0.get())[a..a + n]
    }
}

/// A simulated-latency host pool: fully preallocated (faults are
/// allocation-free — the alloc-count contract holds with a tier
/// attached), with `slowdown` redundant read passes modeling the slow
/// link exactly like [`OffloadArena::load_tokens`] does.
pub struct SimTier {
    floats_per_page: usize,
    slowdown: usize,
    k: TierStore,
    v: TierStore,
    /// Per-page "has been spilled" flag; read-after-write guard.
    written: Vec<AtomicU8>,
}

/// Default simulated link slowdown (see the module header of the bench:
/// ~HBM:PCIe ratio with overlap, matching `OffloadArena`'s default).
pub const DEFAULT_SLOWDOWN: usize = 8;

impl SimTier {
    pub fn new(floats_per_page: usize, num_pages: usize, slowdown: usize) -> SimTier {
        SimTier {
            floats_per_page,
            slowdown: slowdown.max(1),
            k: TierStore::new(floats_per_page * num_pages),
            v: TierStore::new(floats_per_page * num_pages),
            written: (0..num_pages).map(|_| AtomicU8::new(0)).collect(),
        }
    }
}

impl Tier for SimTier {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn write_page(&self, page: usize, k: &[f32], v: &[f32]) {
        let n = self.floats_per_page;
        assert_eq!(k.len(), n);
        assert_eq!(v.len(), n);
        // SAFETY: write_page is only called from `&mut PagedKvCache`
        // contexts (seal / attach), one page at a time — no concurrent
        // access to this range (Tier contract).
        unsafe {
            self.k.write(page * n, n).copy_from_slice(k);
            self.v.write(page * n, n).copy_from_slice(v);
        }
        self.written[page].store(1, Ordering::Release);
    }

    fn read_page(&self, page: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        let n = self.floats_per_page;
        assert_eq!(
            self.written[page].load(Ordering::Acquire),
            1,
            "tier read of page {page} before any write"
        );
        let src_k = self.k.read(page * n, n);
        let src_v = self.v.read(page * n, n);
        // The "link": redundant passes the optimizer cannot elide.
        for pass in 0..self.slowdown {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += src_k[j] + src_v[j];
            }
            std::hint::black_box(acc);
            if pass + 1 == self.slowdown {
                k_out[..n].copy_from_slice(src_k);
                v_out[..n].copy_from_slice(src_v);
            }
        }
    }
}

/// A file-backed tier: pages live at fixed offsets in one flat file
/// (K region then V region per page), read/written positionally so the
/// handle is shared across pool threads without seeking.
#[cfg(unix)]
pub struct FileTier {
    file: std::fs::File,
    floats_per_page: usize,
}

#[cfg(unix)]
impl FileTier {
    /// Create (truncating) a tier file sized for `num_pages` pages.
    pub fn create(
        path: &std::path::Path,
        floats_per_page: usize,
        num_pages: usize,
    ) -> std::io::Result<FileTier> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.set_len((num_pages * floats_per_page * 2 * 4) as u64)?;
        Ok(FileTier { file, floats_per_page })
    }

    fn page_off(&self, page: usize) -> u64 {
        (page * self.floats_per_page * 2 * 4) as u64
    }
}

/// View an f32 slice as bytes (same-machine round-trip; endianness is
/// irrelevant because the file never leaves the host).
#[cfg(unix)]
fn f32_bytes(s: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding and u8 has alignment 1.
    unsafe { std::slice::from_raw_parts(s.as_ptr().cast::<u8>(), std::mem::size_of_val(s)) }
}

#[cfg(unix)]
fn f32_bytes_mut(s: &mut [f32]) -> &mut [u8] {
    // SAFETY: as above; any byte pattern is a valid f32.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr().cast::<u8>(), std::mem::size_of_val(s)) }
}

#[cfg(unix)]
impl Tier for FileTier {
    fn name(&self) -> &'static str {
        "file"
    }

    fn write_page(&self, page: usize, k: &[f32], v: &[f32]) {
        use std::os::unix::fs::FileExt;
        let n = self.floats_per_page;
        assert_eq!(k.len(), n);
        assert_eq!(v.len(), n);
        let off = self.page_off(page);
        self.file.write_all_at(f32_bytes(k), off).expect("tier file write (K)");
        self.file.write_all_at(f32_bytes(v), off + (n * 4) as u64).expect("tier file write (V)");
    }

    fn read_page(&self, page: usize, k_out: &mut [f32], v_out: &mut [f32]) {
        use std::os::unix::fs::FileExt;
        let n = self.floats_per_page;
        let off = self.page_off(page);
        self.file.read_exact_at(f32_bytes_mut(&mut k_out[..n]), off).expect("tier file read (K)");
        self.file
            .read_exact_at(f32_bytes_mut(&mut v_out[..n]), off + (n * 4) as u64)
            .expect("tier file read (V)");
    }
}

// --- residency state machine ---------------------------------------------

/// Page residency states (`TierState::state`).
pub const PAGE_RESIDENT: u8 = 0;
/// A fault winner is copying the page in; racers spin until `RESIDENT`.
pub const PAGE_LOADING: u8 = 1;
pub const PAGE_EVICTED: u8 = 2;

/// Residency bookkeeping attached to a [`super::PagedKvCache`] when a
/// slow tier is active. All hot-path fields are atomics so fault-on-read
/// works through `&PagedKvCache` on pool threads.
pub struct TierState {
    pub tier: Box<dyn Tier>,
    /// Unpressured residency cap, in pages (in-use pages only).
    pub resident_cap: usize,
    /// Per-page residency state (`PAGE_*` constants).
    pub state: Vec<AtomicU8>,
    /// Per-page last-touch stamp: the engine step ordinal (deterministic
    /// — never wall time — so LRU victims are thread-count invariant).
    pub last_touch: Vec<AtomicU64>,
    /// Current deterministic clock; the engine stores its step ordinal
    /// here before each batched step.
    pub clock: AtomicU64,
    /// Pages faulted in (demand + prefetch), cumulative.
    pub faults: AtomicU64,
    /// Faults performed by prefetch tickets (⊆ `faults`). The split
    /// between prefetch and demand is timing-dependent (a demand read
    /// can win the race for a planned page); the *total* is not.
    pub prefetched: AtomicU64,
    pub evictions: AtomicU64,
    pub bytes_faulted: AtomicU64,
    /// Pages written through to the tier (seals + attach-time spills).
    pub spilled_writes: AtomicU64,
    /// Victim-sort scratch, reserved once (fault path stays alloc-free).
    pub(super) evict_scratch: Vec<(u64, PageId)>,
}

impl TierState {
    pub fn new(tier: Box<dyn Tier>, num_pages: usize, resident_cap: usize) -> TierState {
        TierState {
            tier,
            resident_cap: resident_cap.max(1),
            state: (0..num_pages).map(|_| AtomicU8::new(PAGE_RESIDENT)).collect(),
            last_touch: (0..num_pages).map(|_| AtomicU64::new(0)).collect(),
            clock: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bytes_faulted: AtomicU64::new(0),
            spilled_writes: AtomicU64::new(0),
            evict_scratch: Vec::with_capacity(num_pages),
        }
    }

    /// Stamp `page` with the current deterministic clock.
    #[inline]
    pub fn touch(&self, page: PageId) {
        self.last_touch[page as usize]
            .store(self.clock.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Residency cap after applying the governor's pressure ladder:
    /// each degrade level sheds 10% of the unpressured cap (clamped so
    /// at least one page stays).
    pub fn effective_cap(&self, degrade_level: u8) -> usize {
        let level = degrade_level.min(3) as usize;
        (self.resident_cap * (10 - level) / 10).max(1)
    }
}

// --- prefetch plan --------------------------------------------------------

/// Mass-relevance floor for prefetch: a non-resident page is planned iff
/// its bound-mass share `exp(b − bmax) / Σ exp(·)` is at least this
/// fraction (the hier-pages §PR 5 argument: pages below it cannot shift
/// any head's top-p mass materially). Dense items pass 0.0 — they read
/// everything, so every non-resident page is planned.
pub const PREFETCH_EPS_FRAC: f32 = 1e-3;

/// One sequence's prefetch order for one layer: non-resident sealed
/// pages that can still contribute top-p mass, descending bound order
/// (page-id ties ascending). Buffers are pooled by the engine and
/// reserved to the pool's page count so steady-state planning is
/// allocation-free.
#[derive(Default)]
pub struct PrefetchPlan {
    /// Physical pages to fault, in fault order.
    pub pages: Vec<PageId>,
    /// Scratch: (bound, page) for non-resident sealed pages.
    pub(super) entries: Vec<(f32, PageId)>,
    /// Scratch: per-sealed-page bound-mass weight `exp(b − bmax)`.
    pub(super) weights: Vec<f32>,
    /// Scratch: per (kv_head × group head) `Σ|q_i|`.
    pub(super) qabs: Vec<f32>,
}

impl PrefetchPlan {
    /// Reserve every buffer to its worst-case size so planning never
    /// allocates once warm.
    pub fn reserve(&mut self, num_pages: usize, heads: usize) {
        self.pages.reserve(num_pages);
        self.entries.reserve(num_pages);
        self.weights.reserve(num_pages);
        self.qabs.reserve(heads);
    }

    pub fn clear(&mut self) {
        self.pages.clear();
        self.entries.clear();
        self.weights.clear();
        self.qabs.clear();
    }
}

// --- the original Table 7 operator-bench arena ----------------------------

/// An offloaded KV arena for one sequence and one KV head group:
/// contiguous `[token][d]` K and V. Bench-only (the engine path uses
/// [`Tier`]); kept because Table 7's operator panel compares *per-token*
/// transfer volume, which the page-granular tier cannot express.
pub struct OffloadArena {
    pub d: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// How many redundant copy passes to make per load (link slowness).
    pub slowdown: usize,
    /// Bytes "transferred" so far (diagnostics). Atomic so arenas can be
    /// shared read-only across the worker pool for overlapped loads.
    pub bytes_loaded: AtomicU64,
}

impl OffloadArena {
    pub fn new(d: usize, slowdown: usize) -> OffloadArena {
        OffloadArena {
            d,
            k: Vec::new(),
            v: Vec::new(),
            slowdown: slowdown.max(1),
            bytes_loaded: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.k.len() / self.d.max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
    }

    /// Load the K/V rows for `tokens` into `k_out`/`v_out`
    /// (`[tokens.len() * d]` each), paying the simulated link cost.
    ///
    /// Bounds are enforced in release builds too: a bad token index must
    /// fail loudly, not read a neighboring sequence's rows.
    pub fn load_tokens(&self, tokens: &[usize], k_out: &mut [f32], v_out: &mut [f32]) {
        let d = self.d;
        let n = self.len();
        assert!(
            k_out.len() >= tokens.len() * d && v_out.len() >= tokens.len() * d,
            "load_tokens: output buffers too small ({} / {} for {} tokens × d={d})",
            k_out.len(),
            v_out.len(),
            tokens.len(),
        );
        for (i, &t) in tokens.iter().enumerate() {
            assert!(t < n, "load_tokens: token index {t} out of range (arena holds {n})");
            let src_k = &self.k[t * d..(t + 1) * d];
            let src_v = &self.v[t * d..(t + 1) * d];
            let dst_k = &mut k_out[i * d..(i + 1) * d];
            let dst_v = &mut v_out[i * d..(i + 1) * d];
            // The "link": redundant passes that the optimizer cannot elide.
            for pass in 0..self.slowdown {
                let mut acc = 0.0f32;
                for j in 0..d {
                    acc += src_k[j] + src_v[j];
                }
                std::hint::black_box(acc);
                if pass + 1 == self.slowdown {
                    dst_k.copy_from_slice(src_k);
                    dst_v.copy_from_slice(src_v);
                }
            }
        }
        self.bytes_loaded.fetch_add((tokens.len() * d * 2 * 4) as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_load() {
        let mut a = OffloadArena::new(4, 2);
        for t in 0..10 {
            let k = [t as f32; 4];
            let v = [t as f32 + 100.0; 4];
            a.push(&k, &v);
        }
        assert_eq!(a.len(), 10);
        let mut k = vec![0.0; 8];
        let mut v = vec![0.0; 8];
        a.load_tokens(&[3, 7], &mut k, &mut v);
        assert_eq!(&k[0..4], &[3.0; 4]);
        assert_eq!(&k[4..8], &[7.0; 4]);
        assert_eq!(&v[0..4], &[103.0; 4]);
        assert_eq!(a.bytes_loaded.load(Ordering::Relaxed), 2 * 4 * 2 * 4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn load_rejects_bad_index_in_release() {
        let mut a = OffloadArena::new(4, 1);
        a.push(&[1.0; 4], &[1.0; 4]);
        let mut k = vec![0.0; 4];
        let mut v = vec![0.0; 4];
        a.load_tokens(&[1], &mut k, &mut v);
    }

    #[test]
    fn slowdown_costs_time() {
        use std::time::Instant;
        let d = 128;
        let n = 4096;
        let mut fast = OffloadArena::new(d, 1);
        let mut slow = OffloadArena::new(d, 32);
        let row = vec![1.0f32; d];
        for _ in 0..n {
            fast.push(&row, &row);
            slow.push(&row, &row);
        }
        let toks: Vec<usize> = (0..n).collect();
        let mut k = vec![0.0; n * d];
        let mut v = vec![0.0; n * d];
        let t0 = Instant::now();
        for _ in 0..4 {
            fast.load_tokens(&toks, &mut k, &mut v);
        }
        let t_fast = t0.elapsed();
        let t0 = Instant::now();
        for _ in 0..4 {
            slow.load_tokens(&toks, &mut k, &mut v);
        }
        let t_slow = t0.elapsed();
        assert!(t_slow > t_fast * 4, "fast={t_fast:?} slow={t_slow:?}");
    }

    #[test]
    fn sim_tier_round_trip() {
        let fpp = 2 * 16 * 8; // 2 heads × 16 slots × d=8
        let tier = SimTier::new(fpp, 4, 2);
        let k: Vec<f32> = (0..fpp).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..fpp).map(|i| -(i as f32)).collect();
        tier.write_page(2, &k, &v);
        let mut ko = vec![0.0; fpp];
        let mut vo = vec![0.0; fpp];
        tier.read_page(2, &mut ko, &mut vo);
        assert_eq!(ko, k);
        assert_eq!(vo, v);
    }

    #[test]
    #[should_panic(expected = "before any write")]
    fn sim_tier_rejects_unwritten_read() {
        let tier = SimTier::new(8, 2, 1);
        let mut ko = vec![0.0; 8];
        let mut vo = vec![0.0; 8];
        tier.read_page(0, &mut ko, &mut vo);
    }

    #[cfg(unix)]
    #[test]
    fn file_tier_round_trip() {
        let fpp = 16 * 4;
        let path = std::env::temp_dir()
            .join(format!("twilight_tier_test_{}.bin", std::process::id()));
        let tier = FileTier::create(&path, fpp, 3).unwrap();
        let k: Vec<f32> = (0..fpp).map(|i| 0.5 + i as f32).collect();
        let v: Vec<f32> = (0..fpp).map(|i| 7.0 - i as f32).collect();
        tier.write_page(1, &k, &v);
        tier.write_page(0, &v, &k); // neighbor pages must not alias
        let mut ko = vec![0.0; fpp];
        let mut vo = vec![0.0; fpp];
        tier.read_page(1, &mut ko, &mut vo);
        assert_eq!(ko, k);
        assert_eq!(vo, v);
        drop(tier);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn effective_cap_sheds_under_pressure() {
        let ts = TierState::new(Box::new(SimTier::new(8, 4, 1)), 4, 100);
        assert_eq!(ts.effective_cap(0), 100);
        assert_eq!(ts.effective_cap(1), 90);
        assert_eq!(ts.effective_cap(3), 70);
        assert_eq!(ts.effective_cap(7), 70, "ladder clamps at level 3");
        let tiny = TierState::new(Box::new(SimTier::new(8, 4, 1)), 4, 1);
        assert_eq!(tiny.effective_cap(3), 1, "at least one page stays");
    }
}
