//! CPU-offload simulation for Table 7.
//!
//! The paper's offloading scenario keeps the KV cache in host memory and
//! pays a per-token transfer cost to bring selected tokens to the GPU;
//! Twilight wins big there because its final budget is tiny while its
//! estimation cost (reading the small INT4 mirror, which stays resident)
//! is fixed. Everything here is host memory, so we model the slow link
//! explicitly: `load_tokens` copies each requested token's K/V through a
//! scratch buffer `slowdown` times. The default slowdown (8×) approximates
//! the HBM:PCIe-4.0 bandwidth ratio (~2 TB/s : ~25 GB/s would be 80×, but
//! the paper's testbed overlaps transfers; 8× reproduces the paper's
//! ~6–16× Quest→Quest-Twi gap shape without making the bench take forever).

/// An offloaded KV arena for one sequence and one KV head group:
/// contiguous `[token][d]` K and V.
pub struct OffloadArena {
    pub d: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// How many redundant copy passes to make per load (link slowness).
    pub slowdown: usize,
    /// Bytes "transferred" so far (diagnostics).
    pub bytes_loaded: std::cell::Cell<u64>,
}

impl OffloadArena {
    pub fn new(d: usize, slowdown: usize) -> OffloadArena {
        OffloadArena { d, k: Vec::new(), v: Vec::new(), slowdown: slowdown.max(1), bytes_loaded: std::cell::Cell::new(0) }
    }

    pub fn len(&self) -> usize {
        self.k.len() / self.d.max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
    }

    /// Load the K/V rows for `tokens` into `k_out`/`v_out`
    /// (`[tokens.len() * d]` each), paying the simulated link cost.
    pub fn load_tokens(&self, tokens: &[usize], k_out: &mut [f32], v_out: &mut [f32]) {
        let d = self.d;
        debug_assert!(k_out.len() >= tokens.len() * d);
        for (i, &t) in tokens.iter().enumerate() {
            let src_k = &self.k[t * d..(t + 1) * d];
            let src_v = &self.v[t * d..(t + 1) * d];
            let dst_k = &mut k_out[i * d..(i + 1) * d];
            let dst_v = &mut v_out[i * d..(i + 1) * d];
            // The "link": redundant passes that the optimizer cannot elide.
            for pass in 0..self.slowdown {
                let mut acc = 0.0f32;
                for j in 0..d {
                    acc += src_k[j] + src_v[j];
                }
                std::hint::black_box(acc);
                if pass + 1 == self.slowdown {
                    dst_k.copy_from_slice(src_k);
                    dst_v.copy_from_slice(src_v);
                }
            }
        }
        self.bytes_loaded
            .set(self.bytes_loaded.get() + (tokens.len() * d * 2 * 4) as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_load() {
        let mut a = OffloadArena::new(4, 2);
        for t in 0..10 {
            let k = [t as f32; 4];
            let v = [t as f32 + 100.0; 4];
            a.push(&k, &v);
        }
        assert_eq!(a.len(), 10);
        let mut k = vec![0.0; 8];
        let mut v = vec![0.0; 8];
        a.load_tokens(&[3, 7], &mut k, &mut v);
        assert_eq!(&k[0..4], &[3.0; 4]);
        assert_eq!(&k[4..8], &[7.0; 4]);
        assert_eq!(&v[0..4], &[103.0; 4]);
        assert_eq!(a.bytes_loaded.get(), 2 * 4 * 2 * 4);
    }

    #[test]
    fn slowdown_costs_time() {
        use std::time::Instant;
        let d = 128;
        let n = 4096;
        let mut fast = OffloadArena::new(d, 1);
        let mut slow = OffloadArena::new(d, 32);
        let row = vec![1.0f32; d];
        for _ in 0..n {
            fast.push(&row, &row);
            slow.push(&row, &row);
        }
        let toks: Vec<usize> = (0..n).collect();
        let mut k = vec![0.0; n * d];
        let mut v = vec![0.0; n * d];
        let t0 = Instant::now();
        for _ in 0..4 {
            fast.load_tokens(&toks, &mut k, &mut v);
        }
        let t_fast = t0.elapsed();
        let t0 = Instant::now();
        for _ in 0..4 {
            slow.load_tokens(&toks, &mut k, &mut v);
        }
        let t_slow = t0.elapsed();
        assert!(t_slow > t_fast * 4, "fast={t_fast:?} slow={t_slow:?}");
    }
}
