//! Tiered KV offload: the slow-tier abstraction ([`Tier`]), the paged
//! cache's residency state machine ([`TierState`]), and the hier-bound
//! prefetch plan ([`PrefetchPlan`]) — plus the original per-token
//! [`OffloadArena`] simulation kept for the Table 7 operator bench.
//!
//! The paper's offloading scenario keeps the KV cache in host memory and
//! pays a transfer cost to bring selected tokens to the GPU; Twilight
//! wins big there because its final budget is tiny while its estimation
//! cost (reading the small INT4 mirror, which stays resident) is fixed.
//! The engine-level design mirrors that split:
//!
//! * **What spills.** Only *sealed* pages (full, mirror built) ever move
//!   to the slow tier; the INT4 mirror, the Quest min/max metadata, and
//!   the unsealed fp32 tail are always resident. Stage 1 (selection) and
//!   stage 2 (pruning) therefore never fault — only stage 3's exact-K/V
//!   reads do.
//! * **Write-through at seal.** A page's K/V is written to the tier the
//!   moment it seals (and once more for pre-sealed pages when a tier is
//!   attached mid-life), so *eviction is a metadata flip*: the resident
//!   fp32 region is zeroed (stale reads fail loudly, they don't silently
//!   return old data) and the page's state becomes `EVICTED`. Faulting
//!   restores the exact bytes written at seal, which is why offloaded
//!   decode is bit-exact vs fully-resident (`rust/tests/offload_decode.rs`).
//! * **Fault-on-read.** `PagedKvCache::{k_at, v_at}` check residency and
//!   fault the whole page in on miss (one CAS winner performs the tier
//!   read; racers spin on `LOADING`). The hier-pages bound (PR 5) is the
//!   *prefetch oracle*: before the attention phase the engine ranks a
//!   sequence's non-resident sealed pages by their Quest-plus-slack logit
//!   bound into a [`PrefetchPlan`], then fuses every item's plan for the
//!   layer into **one sorted, deduped page batch** served by a single
//!   prefetch ticket scheduled *ahead of* the attention tickets: one
//!   ascending positional sweep over the tier (sequential I/O on
//!   [`FileTier`], no duplicate faults for pages shared across plans)
//!   that overlaps attention on already-resident pages. Batching cannot
//!   cross *layers* — layer `l+1`'s queries, and so its bounds, depend on
//!   layer `l`'s outputs. Per-page CAS semantics are unchanged, so the
//!   faulted set (and the fault count) is identical to per-plan tickets.
//! * **Victims.** LRU over a deterministic clock (the engine step
//!   ordinal, never wall time) with page-id tie-breaks; the governor's
//!   pressure ladder scales the effective residency cap down. Both
//!   inputs are deterministic, so the resident set — and therefore the
//!   total fault count — is identical for any thread count.
//!
//! **Fault domains (DESIGN.md §14).** Tier I/O is *fallible*: both trait
//! methods return `Result<(), TierError>`, and the cache's fault funnel
//! ([`super::PagedKvCache`]'s `fault_page_slow`) runs a bounded
//! retry-with-backoff ladder before escalating a page to `PAGE_LOST` —
//! the per-request failure signal (`CacheError::PageLost`). Writes that
//! never acknowledge leave the page non-`durable`, which pins it
//! resident (an unacknowledged — possibly torn — spill must never become
//! a page's only copy). The [`ChaosTier`] wrapper injects seeded,
//! deterministic read/write errors, added latency, torn writes, and
//! (optionally) panics into any inner tier for soak testing
//! (`TWILIGHT_CHAOS=seed:p_read:p_write[:p_panic]` / `--chaos`): fault
//! decisions are keyed on `(page, op, per-page attempt ordinal)` — never
//! on global call order — so fault sites are thread-count invariant and
//! a retry draws a fresh, independent outcome.
//!
//! The [`OffloadArena`] at the bottom is the original bench-only model
//! of the slow link (`load_tokens` pays `slowdown` redundant passes per
//! token); `benches/table7_offload.rs` still uses it for the per-token
//! operator comparison, while the engine panels use the real tier.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};

use super::PageId;

// --- the slow tier -------------------------------------------------------

/// Which tier operation failed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TierOp {
    Read,
    Write,
}

/// A failed tier operation. Carries enough to account and retry; the
/// underlying cause (I/O error, injected chaos) is deliberately erased —
/// the retry ladder treats every failure the same way.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TierError {
    pub op: TierOp,
    pub page: usize,
}

impl std::fmt::Display for TierError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let op = match self.op {
            TierOp::Read => "read",
            TierOp::Write => "write",
        };
        write!(f, "tier {op} of page {} failed", self.page)
    }
}

impl std::error::Error for TierError {}

/// A slow storage tier holding sealed pages' K/V at page granularity.
///
/// Implementations are shared read-only across the worker pool: faults
/// run on pool threads while the engine thread is parked in
/// `ThreadPool::run`. Per-page exclusivity is the *caller's* contract —
/// `TierState`'s `EVICTED → LOADING` CAS admits one reader per page at a
/// time, and `write_page` is only called from `&mut PagedKvCache`
/// contexts (page seal, tier attach), never concurrently with a read of
/// the same page.
///
/// Failure contract: on `Err` the output buffers (read) or the backing
/// store (write) may hold *partial* data — callers must either retry to
/// completion or treat the operation as if it never happened (the cache
/// zero-fills on a lost read and leaves the page non-durable on a failed
/// write; torn bytes are never observable).
pub trait Tier: Send + Sync {
    /// Stable backend name (reports / bench labels).
    fn name(&self) -> &'static str;
    /// Spill one page: `k`/`v` are the page's full
    /// `[kv_heads * page_size * head_dim]` regions.
    fn write_page(&self, page: usize, k: &[f32], v: &[f32]) -> Result<(), TierError>;
    /// Fault one page back; `write_page(page, ..)` must have happened.
    fn read_page(&self, page: usize, k_out: &mut [f32], v_out: &mut [f32])
        -> Result<(), TierError>;
}

/// Interior-mutable page storage shared across pool threads.
///
/// Soundness: writes to a page's region happen either under `&mut`
/// (construction) or gated by the per-page `written` flag's
/// release-store / acquire-load pair, and the `TierState` page state
/// machine guarantees no concurrent writer+reader on the same page (see
/// [`Tier`]). Distinct pages occupy disjoint ranges.
struct TierStore(UnsafeCell<Vec<f32>>);

// SAFETY: see the struct docs — per-page exclusivity is enforced by the
// caller's page state machine; the Vec itself never reallocates after
// construction.
unsafe impl Sync for TierStore {}

impl TierStore {
    fn new(n: usize) -> TierStore {
        TierStore(UnsafeCell::new(vec![0.0; n]))
    }

    /// Read a page region. Caller guarantees no concurrent writer.
    #[inline]
    fn read(&self, a: usize, n: usize) -> &[f32] {
        unsafe { &(*self.0.get())[a..a + n] }
    }

    /// Write a page region. Caller guarantees exclusivity for the range.
    #[allow(clippy::mut_from_ref)]
    #[inline]
    unsafe fn write(&self, a: usize, n: usize) -> &mut [f32] {
        &mut (*self.0.get())[a..a + n]
    }
}

/// A simulated-latency host pool: fully preallocated (faults are
/// allocation-free — the alloc-count contract holds with a tier
/// attached), with `slowdown` redundant read passes modeling the slow
/// link exactly like [`OffloadArena::load_tokens`] does.
pub struct SimTier {
    floats_per_page: usize,
    slowdown: usize,
    k: TierStore,
    v: TierStore,
    /// Per-page "has been spilled" flag; read-after-write guard.
    written: Vec<AtomicU8>,
}

/// Default simulated link slowdown (see the module header of the bench:
/// ~HBM:PCIe ratio with overlap, matching `OffloadArena`'s default).
pub const DEFAULT_SLOWDOWN: usize = 8;

impl SimTier {
    pub fn new(floats_per_page: usize, num_pages: usize, slowdown: usize) -> SimTier {
        SimTier {
            floats_per_page,
            slowdown: slowdown.max(1),
            k: TierStore::new(floats_per_page * num_pages),
            v: TierStore::new(floats_per_page * num_pages),
            written: (0..num_pages).map(|_| AtomicU8::new(0)).collect(),
        }
    }
}

impl Tier for SimTier {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn write_page(&self, page: usize, k: &[f32], v: &[f32]) -> Result<(), TierError> {
        let n = self.floats_per_page;
        assert_eq!(k.len(), n);
        assert_eq!(v.len(), n);
        // SAFETY: write_page is only called from `&mut PagedKvCache`
        // contexts (seal / attach), one page at a time — no concurrent
        // access to this range (Tier contract).
        unsafe {
            self.k.write(page * n, n).copy_from_slice(k);
            self.v.write(page * n, n).copy_from_slice(v);
        }
        self.written[page].store(1, Ordering::Release);
        Ok(())
    }

    fn read_page(&self, page: usize, k_out: &mut [f32], v_out: &mut [f32])
        -> Result<(), TierError> {
        let n = self.floats_per_page;
        // A read before any write is a *caller* bug (the sealing contract
        // writes through before any page can be evicted), not a fault to
        // retry — keep it a panic so the bug fails loudly in tests.
        assert_eq!(
            self.written[page].load(Ordering::Acquire),
            1,
            "tier read of page {page} before any write"
        );
        let src_k = self.k.read(page * n, n);
        let src_v = self.v.read(page * n, n);
        // The "link": redundant passes the optimizer cannot elide.
        for pass in 0..self.slowdown {
            let mut acc = 0.0f32;
            for j in 0..n {
                acc += src_k[j] + src_v[j];
            }
            std::hint::black_box(acc);
            if pass + 1 == self.slowdown {
                k_out[..n].copy_from_slice(src_k);
                v_out[..n].copy_from_slice(src_v);
            }
        }
        Ok(())
    }
}

/// A file-backed tier: pages live at fixed offsets in one flat file
/// (K region then V region per page), read/written positionally so the
/// handle is shared across pool threads without seeking.
#[cfg(unix)]
pub struct FileTier {
    file: std::fs::File,
    floats_per_page: usize,
}

#[cfg(unix)]
impl FileTier {
    /// Create (truncating) a tier file sized for `num_pages` pages.
    pub fn create(
        path: &std::path::Path,
        floats_per_page: usize,
        num_pages: usize,
    ) -> std::io::Result<FileTier> {
        let file = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.set_len((num_pages * floats_per_page * 2 * 4) as u64)?;
        Ok(FileTier { file, floats_per_page })
    }

    fn page_off(&self, page: usize) -> u64 {
        (page * self.floats_per_page * 2 * 4) as u64
    }
}

/// View an f32 slice as bytes (same-machine round-trip; endianness is
/// irrelevant because the file never leaves the host).
#[cfg(unix)]
fn f32_bytes(s: &[f32]) -> &[u8] {
    // SAFETY: f32 has no padding and u8 has alignment 1.
    unsafe { std::slice::from_raw_parts(s.as_ptr().cast::<u8>(), std::mem::size_of_val(s)) }
}

#[cfg(unix)]
fn f32_bytes_mut(s: &mut [f32]) -> &mut [u8] {
    // SAFETY: as above; any byte pattern is a valid f32.
    unsafe { std::slice::from_raw_parts_mut(s.as_mut_ptr().cast::<u8>(), std::mem::size_of_val(s)) }
}

#[cfg(unix)]
impl Tier for FileTier {
    fn name(&self) -> &'static str {
        "file"
    }

    fn write_page(&self, page: usize, k: &[f32], v: &[f32]) -> Result<(), TierError> {
        use std::os::unix::fs::FileExt;
        let n = self.floats_per_page;
        assert_eq!(k.len(), n);
        assert_eq!(v.len(), n);
        let off = self.page_off(page);
        let e = TierError { op: TierOp::Write, page };
        // A transient pwrite error is a fault, not a crash: the caller's
        // retry ladder re-attempts and, failing that, pins the page
        // resident (non-durable) — the process never dies here.
        self.file.write_all_at(f32_bytes(k), off).map_err(|_| e)?;
        self.file.write_all_at(f32_bytes(v), off + (n * 4) as u64).map_err(|_| e)?;
        Ok(())
    }

    fn read_page(&self, page: usize, k_out: &mut [f32], v_out: &mut [f32])
        -> Result<(), TierError> {
        use std::os::unix::fs::FileExt;
        let n = self.floats_per_page;
        let off = self.page_off(page);
        let e = TierError { op: TierOp::Read, page };
        self.file.read_exact_at(f32_bytes_mut(&mut k_out[..n]), off).map_err(|_| e)?;
        self.file
            .read_exact_at(f32_bytes_mut(&mut v_out[..n]), off + (n * 4) as u64)
            .map_err(|_| e)?;
        Ok(())
    }
}

// --- chaos injection ------------------------------------------------------

/// Seeded fault-injection parameters for [`ChaosTier`]. Parsed from
/// `TWILIGHT_CHAOS=seed:p_read:p_write[:p_panic]` (or `--chaos` with the
/// same format): `p_read`/`p_write` are per-attempt failure
/// probabilities in `[0, 1]`; the optional `p_panic` makes a failing
/// read *panic* instead of returning `Err` (exercising the worker-pool
/// quarantine path end to end).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    pub seed: u64,
    pub p_read: f64,
    pub p_write: f64,
    pub p_panic: f64,
}

impl ChaosConfig {
    /// Parse `seed:p_read:p_write[:p_panic]`; `None` on any malformed
    /// field (callers decide whether that is a hard error or "off").
    pub fn parse(s: &str) -> Option<ChaosConfig> {
        let mut it = s.split(':');
        let seed = it.next()?.trim().parse::<u64>().ok()?;
        let p_read = it.next()?.trim().parse::<f64>().ok()?;
        let p_write = it.next()?.trim().parse::<f64>().ok()?;
        let p_panic = match it.next() {
            Some(f) => f.trim().parse::<f64>().ok()?,
            None => 0.0,
        };
        if it.next().is_some() {
            return None;
        }
        for p in [p_read, p_write, p_panic] {
            if !(0.0..=1.0).contains(&p) {
                return None;
            }
        }
        Some(ChaosConfig { seed, p_read, p_write, p_panic })
    }

    /// `TWILIGHT_CHAOS` from the environment; `None` = chaos off (the
    /// default — with chaos off no `ChaosTier` is ever constructed, so
    /// every byte of behavior is the historical one).
    pub fn from_env() -> Option<ChaosConfig> {
        std::env::var("TWILIGHT_CHAOS").ok().as_deref().and_then(ChaosConfig::parse)
    }
}

/// SplitMix64 — the draw generator behind [`ChaosTier`]'s fault
/// decisions (stateless per draw; all state lives in the keyed inputs).
fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Spin iterations injected on a "slow op" draw (models a degraded
/// link / deep queue; deterministic in outcome, only wall time moves).
const CHAOS_SLOW_SPINS: usize = 4096;

/// A fault-injecting decorator over any inner [`Tier`].
///
/// Determinism contract: every decision is a pure hash of
/// `(seed, op, page, per-(page,op) attempt ordinal)`. The attempt
/// ordinal advances once per call *on that page*, and the cache's page
/// state machine admits exactly one tier read per (page, eviction
/// epoch) regardless of which thread wins the race — so the fault
/// *sites* (which loads fail, which spills tear) are identical for any
/// thread count, and a retry is a fresh independent draw (the ladder
/// can succeed). With the same seed the whole fault schedule replays
/// bit-for-bit.
pub struct ChaosTier {
    inner: Box<dyn Tier>,
    cfg: ChaosConfig,
    read_attempts: Vec<AtomicU64>,
    write_attempts: Vec<AtomicU64>,
    /// Injected read / write failures (diagnostics; panics count as
    /// read failures — they enter the same ladder).
    pub injected_reads: AtomicU64,
    pub injected_writes: AtomicU64,
}

impl ChaosTier {
    pub fn new(inner: Box<dyn Tier>, cfg: ChaosConfig, num_pages: usize) -> ChaosTier {
        ChaosTier {
            inner,
            cfg,
            read_attempts: (0..num_pages).map(|_| AtomicU64::new(0)).collect(),
            write_attempts: (0..num_pages).map(|_| AtomicU64::new(0)).collect(),
            injected_reads: AtomicU64::new(0),
            injected_writes: AtomicU64::new(0),
        }
    }

    /// Uniform draw in `[0, 1)` keyed on (seed, op, page, attempt).
    fn draw(&self, op: u64, page: usize, attempt: u64) -> f64 {
        let h = splitmix64(
            splitmix64(splitmix64(self.cfg.seed ^ (op << 56)) ^ page as u64) ^ attempt,
        );
        (h >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Burn deterministic time when the latency draw fires (probability
    /// `p_read`, independent of the failure draw).
    fn maybe_slow(&self, page: usize, attempt: u64) {
        if self.cfg.p_read > 0.0 && self.draw(2, page, attempt) < self.cfg.p_read {
            for _ in 0..CHAOS_SLOW_SPINS {
                std::hint::spin_loop();
            }
        }
    }
}

impl Tier for ChaosTier {
    fn name(&self) -> &'static str {
        "chaos"
    }

    fn write_page(&self, page: usize, k: &[f32], v: &[f32]) -> Result<(), TierError> {
        let attempt = self.write_attempts[page].fetch_add(1, Ordering::Relaxed);
        self.maybe_slow(page, attempt);
        if self.draw(1, page, attempt) < self.cfg.p_write {
            self.injected_writes.fetch_add(1, Ordering::Relaxed);
            // Torn write: half the draws lose the data entirely, the
            // other half land it but never acknowledge — either way the
            // caller must treat the spill as void (the page stays
            // non-durable and pinned resident until a retry succeeds).
            if self.draw(3, page, attempt) < 0.5 {
                let _ = self.inner.write_page(page, k, v);
            }
            return Err(TierError { op: TierOp::Write, page });
        }
        self.inner.write_page(page, k, v)
    }

    fn read_page(&self, page: usize, k_out: &mut [f32], v_out: &mut [f32])
        -> Result<(), TierError> {
        let attempt = self.read_attempts[page].fetch_add(1, Ordering::Relaxed);
        self.maybe_slow(page, attempt);
        let u = self.draw(0, page, attempt);
        if u < self.cfg.p_panic {
            // The nastiest failure mode: an unwind out of the fault
            // funnel. The cache's loading guard and the engine's
            // per-item quarantine must both hold for this not to kill
            // the process or wedge racers on LOADING.
            panic!("chaos: injected panic reading page {page} (attempt {attempt})");
        }
        if u < self.cfg.p_panic + self.cfg.p_read {
            self.injected_reads.fetch_add(1, Ordering::Relaxed);
            // Torn read: scribble half of K before failing, so callers
            // that ignore the Err are loudly wrong.
            let half = k_out.len() / 2;
            k_out[..half].fill(f32::NAN);
            return Err(TierError { op: TierOp::Read, page });
        }
        self.inner.read_page(page, k_out, v_out)
    }
}

// --- residency state machine ---------------------------------------------

/// Page residency states (`TierState::state`).
pub const PAGE_RESIDENT: u8 = 0;
/// A fault winner is copying the page in; racers spin until `RESIDENT`.
pub const PAGE_LOADING: u8 = 1;
pub const PAGE_EVICTED: u8 = 2;
/// The retry ladder exhausted on this page: its fp32 region is zeroed
/// and the owning request must fail with `CacheError::PageLost`. Sticky
/// until the page is freed and reallocated (`alloc_page` resets it).
pub const PAGE_LOST: u8 = 3;

/// Bounded retries per failed tier read before a page is declared lost.
pub const TIER_READ_RETRIES: u32 = 3;
/// Bounded retries per failed tier write (seal / attach spill).
pub const TIER_WRITE_RETRIES: u32 = 3;
/// Per-fault wall-clock deadline: even if retries remain, a fault that
/// has burned this long escalates to `PageLost` so one sick page cannot
/// stall a whole decode step indefinitely.
pub const TIER_RETRY_DEADLINE: std::time::Duration = std::time::Duration::from_millis(50);

/// Residency bookkeeping attached to a [`super::PagedKvCache`] when a
/// slow tier is active. All hot-path fields are atomics so fault-on-read
/// works through `&PagedKvCache` on pool threads.
pub struct TierState {
    pub tier: Box<dyn Tier>,
    /// Unpressured residency cap, in pages (in-use pages only).
    pub resident_cap: usize,
    /// Per-page residency state (`PAGE_*` constants).
    pub state: Vec<AtomicU8>,
    /// Per-page last-touch stamp: the engine step ordinal (deterministic
    /// — never wall time — so LRU victims are thread-count invariant).
    pub last_touch: Vec<AtomicU64>,
    /// Current deterministic clock; the engine stores its step ordinal
    /// here before each batched step.
    pub clock: AtomicU64,
    /// Pages faulted in (demand + prefetch), cumulative.
    pub faults: AtomicU64,
    /// Faults performed by prefetch tickets (⊆ `faults`). The split
    /// between prefetch and demand is timing-dependent (a demand read
    /// can win the race for a planned page); the *total* is not.
    pub prefetched: AtomicU64,
    pub evictions: AtomicU64,
    pub bytes_faulted: AtomicU64,
    /// Pages written through to the tier (seals + attach-time spills).
    pub spilled_writes: AtomicU64,
    /// Per-page durability: 1 once a `write_page` for the page's final
    /// contents has been *acknowledged*. Only durable pages are eviction
    /// candidates — a torn / unacknowledged spill must never become the
    /// page's only copy, so non-durable sealed pages stay pinned
    /// resident (safe degradation, never corruption).
    pub durable: Vec<AtomicU8>,
    /// Failed tier reads (every attempt, including ones a retry healed).
    pub read_errors: AtomicU64,
    /// Failed tier writes (every attempt).
    pub write_errors: AtomicU64,
    /// Retry-ladder re-attempts (reads and writes).
    pub retries: AtomicU64,
    /// Pages escalated to `PAGE_LOST` (retry ladder exhausted).
    pub lost_pages: AtomicU64,
    /// Victim-sort scratch, reserved once (fault path stays alloc-free).
    pub(super) evict_scratch: Vec<(u64, PageId)>,
}

impl TierState {
    pub fn new(tier: Box<dyn Tier>, num_pages: usize, resident_cap: usize) -> TierState {
        TierState {
            tier,
            resident_cap: resident_cap.max(1),
            state: (0..num_pages).map(|_| AtomicU8::new(PAGE_RESIDENT)).collect(),
            last_touch: (0..num_pages).map(|_| AtomicU64::new(0)).collect(),
            clock: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            prefetched: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
            bytes_faulted: AtomicU64::new(0),
            spilled_writes: AtomicU64::new(0),
            durable: (0..num_pages).map(|_| AtomicU8::new(0)).collect(),
            read_errors: AtomicU64::new(0),
            write_errors: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            lost_pages: AtomicU64::new(0),
            evict_scratch: Vec::with_capacity(num_pages),
        }
    }

    /// Stamp `page` with the current deterministic clock.
    #[inline]
    pub fn touch(&self, page: PageId) {
        self.last_touch[page as usize]
            .store(self.clock.load(Ordering::Relaxed), Ordering::Relaxed);
    }

    /// Residency cap after applying the governor's pressure ladder:
    /// each degrade level sheds 10% of the unpressured cap (clamped so
    /// at least one page stays).
    pub fn effective_cap(&self, degrade_level: u8) -> usize {
        let level = degrade_level.min(3) as usize;
        (self.resident_cap * (10 - level) / 10).max(1)
    }
}

// --- prefetch plan --------------------------------------------------------

/// Mass-relevance floor for prefetch: a non-resident page is planned iff
/// its bound-mass share `exp(b − bmax) / Σ exp(·)` is at least this
/// fraction (the hier-pages §PR 5 argument: pages below it cannot shift
/// any head's top-p mass materially). Dense items pass 0.0 — they read
/// everything, so every non-resident page is planned.
pub const PREFETCH_EPS_FRAC: f32 = 1e-3;

/// One sequence's prefetch order for one layer: non-resident sealed
/// pages that can still contribute top-p mass, descending bound order
/// (page-id ties ascending). Buffers are pooled by the engine and
/// reserved to the pool's page count so steady-state planning is
/// allocation-free.
#[derive(Default)]
pub struct PrefetchPlan {
    /// Physical pages to fault, in fault order.
    pub pages: Vec<PageId>,
    /// Scratch: (bound, page) for non-resident sealed pages.
    pub(super) entries: Vec<(f32, PageId)>,
    /// Scratch: per-sealed-page bound-mass weight `exp(b − bmax)`.
    pub(super) weights: Vec<f32>,
    /// Scratch: per (kv_head × group head) `Σ|q_i|`.
    pub(super) qabs: Vec<f32>,
}

impl PrefetchPlan {
    /// Reserve every buffer to its worst-case size so planning never
    /// allocates once warm.
    pub fn reserve(&mut self, num_pages: usize, heads: usize) {
        self.pages.reserve(num_pages);
        self.entries.reserve(num_pages);
        self.weights.reserve(num_pages);
        self.qabs.reserve(heads);
    }

    pub fn clear(&mut self) {
        self.pages.clear();
        self.entries.clear();
        self.weights.clear();
        self.qabs.clear();
    }
}

// --- the original Table 7 operator-bench arena ----------------------------

/// An offloaded KV arena for one sequence and one KV head group:
/// contiguous `[token][d]` K and V. Bench-only (the engine path uses
/// [`Tier`]); kept because Table 7's operator panel compares *per-token*
/// transfer volume, which the page-granular tier cannot express.
pub struct OffloadArena {
    pub d: usize,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// How many redundant copy passes to make per load (link slowness).
    pub slowdown: usize,
    /// Bytes "transferred" so far (diagnostics). Atomic so arenas can be
    /// shared read-only across the worker pool for overlapped loads.
    pub bytes_loaded: AtomicU64,
}

impl OffloadArena {
    pub fn new(d: usize, slowdown: usize) -> OffloadArena {
        OffloadArena {
            d,
            k: Vec::new(),
            v: Vec::new(),
            slowdown: slowdown.max(1),
            bytes_loaded: AtomicU64::new(0),
        }
    }

    pub fn len(&self) -> usize {
        self.k.len() / self.d.max(1)
    }

    pub fn is_empty(&self) -> bool {
        self.k.is_empty()
    }

    pub fn push(&mut self, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.d);
        debug_assert_eq!(v.len(), self.d);
        self.k.extend_from_slice(k);
        self.v.extend_from_slice(v);
    }

    /// Load the K/V rows for `tokens` into `k_out`/`v_out`
    /// (`[tokens.len() * d]` each), paying the simulated link cost.
    ///
    /// Bounds are enforced in release builds too: a bad token index must
    /// fail loudly, not read a neighboring sequence's rows.
    pub fn load_tokens(&self, tokens: &[usize], k_out: &mut [f32], v_out: &mut [f32]) {
        let d = self.d;
        let n = self.len();
        assert!(
            k_out.len() >= tokens.len() * d && v_out.len() >= tokens.len() * d,
            "load_tokens: output buffers too small ({} / {} for {} tokens × d={d})",
            k_out.len(),
            v_out.len(),
            tokens.len(),
        );
        for (i, &t) in tokens.iter().enumerate() {
            assert!(t < n, "load_tokens: token index {t} out of range (arena holds {n})");
            let src_k = &self.k[t * d..(t + 1) * d];
            let src_v = &self.v[t * d..(t + 1) * d];
            let dst_k = &mut k_out[i * d..(i + 1) * d];
            let dst_v = &mut v_out[i * d..(i + 1) * d];
            // The "link": redundant passes that the optimizer cannot elide.
            for pass in 0..self.slowdown {
                let mut acc = 0.0f32;
                for j in 0..d {
                    acc += src_k[j] + src_v[j];
                }
                std::hint::black_box(acc);
                if pass + 1 == self.slowdown {
                    dst_k.copy_from_slice(src_k);
                    dst_v.copy_from_slice(src_v);
                }
            }
        }
        self.bytes_loaded.fetch_add((tokens.len() * d * 2 * 4) as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_load() {
        let mut a = OffloadArena::new(4, 2);
        for t in 0..10 {
            let k = [t as f32; 4];
            let v = [t as f32 + 100.0; 4];
            a.push(&k, &v);
        }
        assert_eq!(a.len(), 10);
        let mut k = vec![0.0; 8];
        let mut v = vec![0.0; 8];
        a.load_tokens(&[3, 7], &mut k, &mut v);
        assert_eq!(&k[0..4], &[3.0; 4]);
        assert_eq!(&k[4..8], &[7.0; 4]);
        assert_eq!(&v[0..4], &[103.0; 4]);
        assert_eq!(a.bytes_loaded.load(Ordering::Relaxed), 2 * 4 * 2 * 4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn load_rejects_bad_index_in_release() {
        let mut a = OffloadArena::new(4, 1);
        a.push(&[1.0; 4], &[1.0; 4]);
        let mut k = vec![0.0; 4];
        let mut v = vec![0.0; 4];
        a.load_tokens(&[1], &mut k, &mut v);
    }

    #[test]
    fn slowdown_costs_time() {
        use std::time::Instant;
        let d = 128;
        let n = 4096;
        let mut fast = OffloadArena::new(d, 1);
        let mut slow = OffloadArena::new(d, 32);
        let row = vec![1.0f32; d];
        for _ in 0..n {
            fast.push(&row, &row);
            slow.push(&row, &row);
        }
        let toks: Vec<usize> = (0..n).collect();
        let mut k = vec![0.0; n * d];
        let mut v = vec![0.0; n * d];
        let t0 = Instant::now();
        for _ in 0..4 {
            fast.load_tokens(&toks, &mut k, &mut v);
        }
        let t_fast = t0.elapsed();
        let t0 = Instant::now();
        for _ in 0..4 {
            slow.load_tokens(&toks, &mut k, &mut v);
        }
        let t_slow = t0.elapsed();
        assert!(t_slow > t_fast * 4, "fast={t_fast:?} slow={t_slow:?}");
    }

    #[test]
    fn sim_tier_round_trip() {
        let fpp = 2 * 16 * 8; // 2 heads × 16 slots × d=8
        let tier = SimTier::new(fpp, 4, 2);
        let k: Vec<f32> = (0..fpp).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..fpp).map(|i| -(i as f32)).collect();
        tier.write_page(2, &k, &v).unwrap();
        let mut ko = vec![0.0; fpp];
        let mut vo = vec![0.0; fpp];
        tier.read_page(2, &mut ko, &mut vo).unwrap();
        assert_eq!(ko, k);
        assert_eq!(vo, v);
    }

    #[test]
    #[should_panic(expected = "before any write")]
    fn sim_tier_rejects_unwritten_read() {
        let tier = SimTier::new(8, 2, 1);
        let mut ko = vec![0.0; 8];
        let mut vo = vec![0.0; 8];
        let _ = tier.read_page(0, &mut ko, &mut vo);
    }

    #[cfg(unix)]
    #[test]
    fn file_tier_round_trip() {
        let fpp = 16 * 4;
        let path = std::env::temp_dir()
            .join(format!("twilight_tier_test_{}.bin", std::process::id()));
        let tier = FileTier::create(&path, fpp, 3).unwrap();
        let k: Vec<f32> = (0..fpp).map(|i| 0.5 + i as f32).collect();
        let v: Vec<f32> = (0..fpp).map(|i| 7.0 - i as f32).collect();
        tier.write_page(1, &k, &v).unwrap();
        tier.write_page(0, &v, &k).unwrap(); // neighbor pages must not alias
        let mut ko = vec![0.0; fpp];
        let mut vo = vec![0.0; fpp];
        tier.read_page(1, &mut ko, &mut vo).unwrap();
        assert_eq!(ko, k);
        assert_eq!(vo, v);
        drop(tier);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn chaos_config_parses_and_rejects() {
        let c = ChaosConfig::parse("7:0.05:0.02").unwrap();
        assert_eq!(c, ChaosConfig { seed: 7, p_read: 0.05, p_write: 0.02, p_panic: 0.0 });
        let c = ChaosConfig::parse("1:0.5:0.25:0.125").unwrap();
        assert_eq!(c.p_panic, 0.125);
        for bad in ["", "7", "7:0.1", "x:0.1:0.1", "7:1.5:0.0", "7:0.1:0.1:0.1:0.1", "7:-0.1:0"] {
            assert!(ChaosConfig::parse(bad).is_none(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn chaos_fault_sites_are_seed_deterministic() {
        let fpp = 8;
        let pages = 16;
        let cfg = ChaosConfig { seed: 42, p_read: 0.3, p_write: 0.3, p_panic: 0.0 };
        let run = || {
            let chaos = ChaosTier::new(Box::new(SimTier::new(fpp, pages, 1)), cfg, pages);
            let k = vec![1.0f32; fpp];
            let v = vec![2.0f32; fpp];
            let mut outcomes = Vec::new();
            for page in 0..pages {
                // Write until acknowledged (bounded: independent draws).
                let mut writes = 0;
                while chaos.write_page(page, &k, &v).is_err() {
                    writes += 1;
                    assert!(writes < 64, "write draws must be independent per attempt");
                }
                let mut ko = vec![0.0f32; fpp];
                let mut vo = vec![0.0f32; fpp];
                let mut reads = 0;
                while chaos.read_page(page, &mut ko, &mut vo).is_err() {
                    reads += 1;
                    assert!(reads < 64);
                }
                assert_eq!(ko, k, "an acknowledged read must return exact bytes");
                outcomes.push((writes, reads));
            }
            outcomes
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed must replay the same fault schedule");
        assert!(
            a.iter().any(|&(w, r)| w > 0 || r > 0),
            "p=0.3 over 16 pages should inject at least one fault: {a:?}"
        );
    }

    #[test]
    fn chaos_off_is_transparent() {
        let fpp = 8;
        let cfg = ChaosConfig { seed: 9, p_read: 0.0, p_write: 0.0, p_panic: 0.0 };
        let chaos = ChaosTier::new(Box::new(SimTier::new(fpp, 4, 1)), cfg, 4);
        let k: Vec<f32> = (0..fpp).map(|i| i as f32).collect();
        let v: Vec<f32> = (0..fpp).map(|i| -(i as f32)).collect();
        for page in 0..4 {
            chaos.write_page(page, &k, &v).unwrap();
            let mut ko = vec![0.0f32; fpp];
            let mut vo = vec![0.0f32; fpp];
            chaos.read_page(page, &mut ko, &mut vo).unwrap();
            assert_eq!(ko, k);
            assert_eq!(vo, v);
        }
        assert_eq!(chaos.injected_reads.load(Ordering::Relaxed), 0);
        assert_eq!(chaos.injected_writes.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn effective_cap_sheds_under_pressure() {
        let ts = TierState::new(Box::new(SimTier::new(8, 4, 1)), 4, 100);
        assert_eq!(ts.effective_cap(0), 100);
        assert_eq!(ts.effective_cap(1), 90);
        assert_eq!(ts.effective_cap(3), 70);
        assert_eq!(ts.effective_cap(7), 70, "ladder clamps at level 3");
        let tiny = TierState::new(Box::new(SimTier::new(8, 4, 1)), 4, 1);
        assert_eq!(tiny.effective_cap(3), 1, "at least one page stays");
    }
}
