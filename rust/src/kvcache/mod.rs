//! Paged KV cache manager (PagedAttention-style, paper §4.3) with the
//! Twilight INT4 mirror K cache and Quest page metadata maintained on
//! append.
//!
//! Layout decisions mirror the paper's system design:
//! * storage is paged (`page_size` tokens per page, default 16 — Quest's
//!   page granularity) with per-sequence page tables, so prefix sharing
//!   and the varlen attention kernels address tokens as
//!   `(page, slot)` pairs;
//! * a low-precision mirror of K (per-(page, head) asymmetric INT4 by
//!   default) is kept alongside, in the same paged layout — this is the
//!   "extra INT4 quantized K cache" of §4.2, costing 1/8 extra memory;
//!   the pruner's page-tiled SpGEMV unpacks a mirror block's codes once
//!   per candidate run (`tensor::quant::unpack_codes_into`) rather than
//!   once per row;
//! * per-(page, head) elementwise min/max of K is kept for the Quest
//!   selector's upper-bound score — and, with `--hier-pages`, doubles as
//!   the pruner's page-level logit bound (plus the mirror block's
//!   `quant::max_error` slack) for hierarchical top-p early stopping.
//!
//! **Sealing contract.** A page's mirror block is built exactly once, when
//! the page *seals* (its last slot is appended) — the paper quantizes at
//! prefill and on page close, and re-quantizing a partially-filled page
//! on every append both wastes bandwidth and makes the codes of earlier
//! slots depend on later arrivals (the per-block scale/zero shift).
//! Consumers treat the unsealed tail uniformly: the pruner's SpGEMV
//! scores in-flight rows exactly from fp32 K, and Quest scores the
//! partial tail page from exact rows instead of its (still-moving)
//! min/max. This is what makes chunked prefill chunk-size invariant: a
//! query inside a chunk sees only sealed (content-final) metadata plus
//! exact reads of the visible prefix, so its result cannot depend on how
//! many later tokens the chunk appended before it attended.
//!
//! **Tiering addendum.** With a slow tier attached
//! ([`PagedKvCache::attach_tier`], surfaced as `--resident-frac` /
//! `TWILIGHT_RESIDENT_FRAC`), the sealing contract gains a clause: a
//! page's fp32 K/V is written through to the tier at seal, so sealed
//! pages can be *evicted* (state flip + zeroed fp32, the bytes live in
//! the tier) and *faulted* back on first exact read ([`k_at`]/[`v_at`]).
//! The mirror, min/max metadata, and the unsealed tail never spill —
//! selection and pruning stay fault-free, exactly the paper's "the INT4
//! estimation mirror stays resident" deployment shape. See `offload.rs`
//! for the residency state machine and the hier-bound prefetch plan.
//!
//! [`k_at`]: PagedKvCache::k_at
//! [`v_at`]: PagedKvCache::v_at

pub mod offload;

use std::cell::UnsafeCell;
use std::sync::atomic::Ordering;

use offload::{
    PrefetchPlan, Tier, TierState, PAGE_EVICTED, PAGE_LOADING, PAGE_LOST, PAGE_RESIDENT,
    TIER_READ_RETRIES, TIER_RETRY_DEADLINE, TIER_WRITE_RETRIES,
};

use crate::tensor::quant::{self, QuantBits, QuantBlock};

/// Identifies a physical page in the pool.
pub type PageId = u32;

/// Cache geometry and precision configuration.
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Number of KV heads (GQA: may be fewer than query heads).
    pub kv_heads: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Tokens per page.
    pub page_size: usize,
    /// Total physical pages in the pool.
    pub num_pages: usize,
    /// Mirror K-cache precision (paper default INT4).
    pub mirror_bits: QuantBits,
}

impl CacheConfig {
    pub fn new(kv_heads: usize, head_dim: usize, num_pages: usize) -> CacheConfig {
        CacheConfig { kv_heads, head_dim, page_size: 16, num_pages, mirror_bits: QuantBits::Int4 }
    }

    /// Tokens the pool can hold.
    pub fn capacity_tokens(&self) -> usize {
        self.num_pages * self.page_size
    }
}

/// Per-sequence view: page table plus logical length.
#[derive(Clone, Debug, Default)]
pub struct SeqCache {
    pub pages: Vec<PageId>,
    pub len: usize,
}

impl SeqCache {
    /// Physical location of logical token `i`.
    #[inline]
    pub fn locate(&self, i: usize, page_size: usize) -> (PageId, usize) {
        (self.pages[i / page_size], i % page_size)
    }
}

/// Errors surfaced per request by the cache / batched step. The
/// scheduler maps each variant to a different fate: `OutOfPages` is
/// *transient* (preempt and requeue — pressure clears), the rest are
/// *terminal* for the request (`RequestState::Failed`) but never for
/// the process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheError {
    OutOfPages,
    /// A sealed page's bytes became unreachable: the tier-read retry
    /// ladder exhausted (see `offload::TIER_READ_RETRIES`). The page's
    /// fp32 region is zeroed — loudly wrong, never torn — and the
    /// owning request must fail rather than emit silently corrupt
    /// logits.
    PageLost,
    /// An attention work item panicked on a pool thread and was
    /// quarantined (engine-level containment; carried here so the
    /// per-item error plumbing stays one type).
    WorkerPanic,
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheError::OutOfPages => write!(f, "KV cache pool exhausted"),
            CacheError::PageLost => write!(f, "KV page lost (tier read retries exhausted)"),
            CacheError::WorkerPanic => write!(f, "attention worker panicked (item quarantined)"),
        }
    }
}

impl std::error::Error for CacheError {}

/// Interior-mutable fp32 page storage. Plain `Vec` access under `&mut
/// self` everywhere except the fault path, where the thread that won a
/// page's `EVICTED → LOADING` CAS writes that page's region through
/// `&self` while other pool threads are attending resident pages.
///
/// Soundness: the storage never reallocates after construction (fixed
/// `num_pages`); distinct pages occupy disjoint ranges; a page's range
/// is written through `&self` only by the CAS winner, and readers of
/// that page synchronize through the acquire-load of `PAGE_RESIDENT`
/// published by the winner's release-store.
struct PageStore(UnsafeCell<Vec<f32>>);

// SAFETY: see the struct docs — per-page exclusivity is enforced by the
// `TierState` page state machine.
unsafe impl Sync for PageStore {}

impl PageStore {
    fn new(n: usize) -> PageStore {
        PageStore(UnsafeCell::new(vec![0.0; n]))
    }

    fn len(&self) -> usize {
        // SAFETY: the Vec's length is fixed after construction.
        unsafe { (*self.0.get()).len() }
    }

    /// Shared read. Caller guarantees no concurrent writer for the range
    /// (resident pages are never written; loading pages are never read).
    #[inline]
    fn read(&self, a: usize, n: usize) -> &[f32] {
        // SAFETY: struct-level contract above.
        unsafe { &(*self.0.get())[a..a + n] }
    }

    /// Exclusive write through `&mut` (append / seal / evict paths).
    #[inline]
    fn slice_mut(&mut self, a: usize, n: usize) -> &mut [f32] {
        // SAFETY: `&mut self` is exclusive.
        unsafe { &mut (*self.0.get())[a..a + n] }
    }

    /// Racy write for the fault path. Caller must be the page's unique
    /// writer (the `EVICTED → LOADING` CAS winner).
    #[allow(clippy::mut_from_ref)]
    #[inline]
    unsafe fn write_racy(&self, a: usize, n: usize) -> &mut [f32] {
        &mut (*self.0.get())[a..a + n]
    }
}

/// The physical paged pool. All tensors are row-major f32; the mirror is
/// packed per (page, head).
pub struct PagedKvCache {
    pub cfg: CacheConfig,
    /// K storage: `[page][kv_head][slot][d]`.
    k: PageStore,
    /// V storage: same layout.
    v: PageStore,
    /// Mirror K codes: per (page, head) `QuantBlock` over `[slot][d]`.
    mirror: Vec<Option<QuantBlock>>,
    /// Quest metadata: per (page, head), elementwise min then max (2*d).
    minmax: Vec<f32>,
    /// Number of valid tokens currently in each page.
    page_fill: Vec<u32>,
    /// Reference counts (prefix sharing); 0 = free.
    refs: Vec<u32>,
    free: Vec<PageId>,
    /// Slow-tier residency state; `None` = everything resident (the
    /// historical fully-in-memory cache, zero overhead on the hot path
    /// beyond one branch per row read).
    tier: Option<TierState>,
}

impl PagedKvCache {
    pub fn new(cfg: CacheConfig) -> PagedKvCache {
        let per_page = cfg.kv_heads * cfg.page_size * cfg.head_dim;
        PagedKvCache {
            k: PageStore::new(cfg.num_pages * per_page),
            v: PageStore::new(cfg.num_pages * per_page),
            mirror: (0..cfg.num_pages * cfg.kv_heads).map(|_| None).collect(),
            minmax: vec![0.0; cfg.num_pages * cfg.kv_heads * 2 * cfg.head_dim],
            page_fill: vec![0; cfg.num_pages],
            refs: vec![0; cfg.num_pages],
            free: (0..cfg.num_pages as PageId).rev().collect(),
            tier: None,
            cfg,
        }
    }

    /// Floats in one page's K (or V) region, all kv heads.
    #[inline]
    fn floats_per_page(&self) -> usize {
        self.cfg.kv_heads * self.cfg.page_size * self.cfg.head_dim
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.cfg.num_pages - self.free.len()
    }

    fn alloc_page(&mut self) -> Result<PageId, CacheError> {
        let p = self.free.pop().ok_or(CacheError::OutOfPages)?;
        self.refs[p as usize] = 1;
        self.page_fill[p as usize] = 0;
        for h in 0..self.cfg.kv_heads {
            self.mirror[p as usize * self.cfg.kv_heads + h] = None;
        }
        // A fresh page starts resident (it is about to be appended to);
        // its prior incarnation may have been evicted — or lost (a
        // `PAGE_LOST` escalation is sticky only for the incarnation that
        // failed; reallocation is the reset point). The durability flag
        // resets with it: the new contents have not been spilled yet.
        if let Some(ts) = &self.tier {
            ts.state[p as usize].store(PAGE_RESIDENT, Ordering::Relaxed);
            ts.durable[p as usize].store(0, Ordering::Relaxed);
            ts.touch(p);
        }
        Ok(p)
    }

    /// Increase the refcount of every page of `seq` (prefix sharing: a
    /// forked sequence shares all full pages of its parent).
    pub fn share(&mut self, seq: &SeqCache) -> SeqCache {
        for &p in &seq.pages {
            self.refs[p as usize] += 1;
        }
        seq.clone()
    }

    /// Release a sequence's pages.
    pub fn release(&mut self, seq: &SeqCache) {
        for &p in &seq.pages {
            let r = &mut self.refs[p as usize];
            debug_assert!(*r > 0);
            *r -= 1;
            if *r == 0 {
                self.free.push(p);
            }
        }
    }

    #[inline]
    fn k_base(&self, page: PageId, head: usize, slot: usize) -> usize {
        let c = &self.cfg;
        ((page as usize * c.kv_heads + head) * c.page_size + slot) * c.head_dim
    }

    /// K vector at (page, head, slot). With a tier attached this is the
    /// fault-on-read entry point: a non-resident page is faulted in
    /// (whole page, all heads) before the row is returned.
    #[inline]
    pub fn k_at(&self, page: PageId, head: usize, slot: usize) -> &[f32] {
        if let Some(ts) = &self.tier {
            self.ensure_resident(ts, page);
        }
        let b = self.k_base(page, head, slot);
        self.k.read(b, self.cfg.head_dim)
    }

    /// V vector at (page, head, slot). Faults like [`PagedKvCache::k_at`].
    #[inline]
    pub fn v_at(&self, page: PageId, head: usize, slot: usize) -> &[f32] {
        if let Some(ts) = &self.tier {
            self.ensure_resident(ts, page);
        }
        let b = self.k_base(page, head, slot);
        self.v.read(b, self.cfg.head_dim)
    }

    /// Touch + residency check; the slow path does the actual fault.
    #[inline]
    fn ensure_resident(&self, ts: &TierState, page: PageId) {
        ts.touch(page);
        if ts.state[page as usize].load(Ordering::Acquire) != PAGE_RESIDENT {
            self.fault_page_slow(ts, page, false);
        }
    }

    /// Fault `page` in from the tier. Exactly one thread (the
    /// `EVICTED → LOADING` CAS winner) performs the tier read; racers
    /// spin until the winner publishes `RESIDENT` (or `LOST`). Returns
    /// whether this call performed the load attempt.
    ///
    /// Failure ladder (DESIGN.md §14): a failed read is retried up to
    /// [`TIER_READ_RETRIES`] times with exponential spin backoff under a
    /// [`TIER_RETRY_DEADLINE`] wall clock; exhaustion zero-fills the
    /// region and publishes `PAGE_LOST` so racers unstick and the engine
    /// fails the owning request with [`CacheError::PageLost`]. A *panic*
    /// out of the tier (chaos injection, tier bugs) is contained the
    /// same way: the unwind guard below guarantees the page never stays
    /// `LOADING`, so no racer can spin forever on a dead loader.
    #[cold]
    fn fault_page_slow(&self, ts: &TierState, page: PageId, prefetch: bool) -> bool {
        loop {
            match ts.state[page as usize].compare_exchange(
                PAGE_EVICTED,
                PAGE_LOADING,
                Ordering::Acquire,
                Ordering::Acquire,
            ) {
                Ok(_) => {
                    let t0 = std::time::Instant::now();
                    let n = self.floats_per_page();
                    let b = page as usize * n;
                    let deadline = t0 + TIER_RETRY_DEADLINE;
                    let mut attempt = 0u32;
                    let loaded = loop {
                        // SAFETY: this thread won the CAS, so it is the
                        // page's unique writer; readers wait for the
                        // release-store of RESIDENT below. The
                        // catch_unwind contains tier panics so the state
                        // machine below always runs (AssertUnwindSafe is
                        // sound: on unwind the buffers hold torn bytes,
                        // which the retry overwrites or the zero-fill
                        // below erases — they are never published).
                        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                            || unsafe {
                                ts.tier.read_page(
                                    page as usize,
                                    self.k.write_racy(b, n),
                                    self.v.write_racy(b, n),
                                )
                            },
                        ));
                        match res {
                            Ok(Ok(())) => break true,
                            Ok(Err(_)) | Err(_) => {
                                ts.read_errors.fetch_add(1, Ordering::Relaxed);
                                attempt += 1;
                                if attempt > TIER_READ_RETRIES
                                    || std::time::Instant::now() >= deadline
                                {
                                    break false;
                                }
                                ts.retries.fetch_add(1, Ordering::Relaxed);
                                // Exponential spin backoff (64·2^attempt
                                // spins): transient tier hiccups clear
                                // without yielding the OS scheduler.
                                for _ in 0..(64u32 << attempt.min(8)) {
                                    std::hint::spin_loop();
                                }
                            }
                        }
                    };
                    if loaded {
                        ts.state[page as usize].store(PAGE_RESIDENT, Ordering::Release);
                        ts.faults.fetch_add(1, Ordering::Relaxed);
                        if prefetch {
                            ts.prefetched.fetch_add(1, Ordering::Relaxed);
                        }
                        ts.bytes_faulted.fetch_add((2 * n * 4) as u64, Ordering::Relaxed);
                    } else {
                        // Ladder exhausted: erase any torn bytes (loudly
                        // wrong zeros, never corruption) and publish
                        // LOST — racers unstick, the owning request
                        // fails, neighbors are untouched.
                        // SAFETY: still the unique writer (state is
                        // LOADING until the store below).
                        unsafe {
                            self.k.write_racy(b, n).fill(0.0);
                            self.v.write_racy(b, n).fill(0.0);
                        }
                        ts.state[page as usize].store(PAGE_LOST, Ordering::Release);
                        ts.lost_pages.fetch_add(1, Ordering::Relaxed);
                    }
                    crate::obs::trace::record_ctx(
                        crate::obs::trace::Stage::PageFault,
                        t0.elapsed(),
                    );
                    return true;
                }
                Err(s) if s == PAGE_RESIDENT => return false,
                Err(s) if s == PAGE_LOST => {
                    // Sticky for this incarnation: the owning request is
                    // failing; do not burn retries on every row read.
                    return false;
                }
                Err(_) => {
                    // A racer is loading; evictions only happen under
                    // `&mut self`, so once the loader publishes a
                    // terminal state (RESIDENT or LOST) it holds for the
                    // rest of this read phase.
                    while ts.state[page as usize].load(Ordering::Acquire) == PAGE_LOADING {
                        std::hint::spin_loop();
                    }
                }
            }
        }
    }

    /// Does any page of `seq` sit in `PAGE_LOST`? The engine calls this
    /// per (request, layer) at the end of a batched step to convert lost
    /// pages into a per-request [`CacheError::PageLost`] — conservative
    /// (a lost page the item's sparse selection skipped still fails the
    /// request) but deterministic and never silently corrupt.
    pub fn has_lost_page(&self, seq: &SeqCache) -> bool {
        let Some(ts) = &self.tier else { return false };
        seq.pages
            .iter()
            .any(|&p| ts.state[p as usize].load(Ordering::Acquire) == PAGE_LOST)
    }

    /// Prefetch ticket entry point: fault `page` if it is not resident
    /// (counted as prefetched only when this call performs the load —
    /// a demand read may win the race, which changes the split but
    /// never the total fault count).
    pub fn prefetch_page(&self, page: PageId) {
        if let Some(ts) = &self.tier {
            ts.touch(page);
            if ts.state[page as usize].load(Ordering::Acquire) != PAGE_RESIDENT {
                self.fault_page_slow(ts, page, true);
            }
        }
    }

    /// Batched prefetch entry point: fault each page of `pages` in slice
    /// order. The engine merges every item's per-step plan into one
    /// offset-sorted, deduplicated batch per layer and dispatches it as
    /// a single ticket, so a positional backing tier (`FileTier`) sees
    /// one ascending sweep of reads per (step, layer) instead of
    /// per-item ticket bursts — sequential I/O the OS readahead can
    /// coalesce. Per-page claim semantics are unchanged (the CAS admits
    /// exactly one loader per page), so the faulted *set* is identical
    /// to per-page dispatch; only the issue order differs.
    pub fn prefetch_pages(&self, pages: &[PageId]) {
        for &p in pages {
            self.prefetch_page(p);
        }
    }

    /// Quest min/max metadata of (page, head): `(&min[d], &max[d])`.
    #[inline]
    pub fn minmax_at(&self, page: PageId, head: usize) -> (&[f32], &[f32]) {
        let d = self.cfg.head_dim;
        let b = (page as usize * self.cfg.kv_heads + head) * 2 * d;
        (&self.minmax[b..b + d], &self.minmax[b + d..b + 2 * d])
    }

    /// Mirror quant block of (page, head), if the page has been sealed.
    #[inline]
    pub fn mirror_at(&self, page: PageId, head: usize) -> Option<&QuantBlock> {
        self.mirror[page as usize * self.cfg.kv_heads + head].as_ref()
    }

    /// Number of valid tokens in `page`.
    #[inline]
    pub fn fill_of(&self, page: PageId) -> usize {
        self.page_fill[page as usize] as usize
    }

    /// Append one token's K/V (all kv heads at once, `k`/`v` are
    /// `[kv_heads * head_dim]`) to `seq`, allocating a page if needed.
    pub fn append(
        &mut self,
        seq: &mut SeqCache,
        k: &[f32],
        v: &[f32],
    ) -> Result<(), CacheError> {
        let c = self.cfg.clone();
        debug_assert_eq!(k.len(), c.kv_heads * c.head_dim);
        debug_assert_eq!(v.len(), c.kv_heads * c.head_dim);
        let slot = seq.len % c.page_size;
        if slot == 0 {
            let p = self.alloc_page()?;
            seq.pages.push(p);
        }
        let page = *seq.pages.last().unwrap();
        debug_assert_eq!(self.refs[page as usize], 1, "appending to shared page");
        for h in 0..c.kv_heads {
            let base = self.k_base(page, h, slot);
            let src = &k[h * c.head_dim..(h + 1) * c.head_dim];
            self.k.slice_mut(base, c.head_dim).copy_from_slice(src);
            let vsrc = &v[h * c.head_dim..(h + 1) * c.head_dim];
            self.v.slice_mut(base, c.head_dim).copy_from_slice(vsrc);
            // Update Quest min/max incrementally.
            let mb = (page as usize * c.kv_heads + h) * 2 * c.head_dim;
            if slot == 0 {
                self.minmax[mb..mb + c.head_dim].copy_from_slice(src);
                self.minmax[mb + c.head_dim..mb + 2 * c.head_dim].copy_from_slice(src);
            } else {
                for (i, &x) in src.iter().enumerate() {
                    let mn = &mut self.minmax[mb + i];
                    if x < *mn {
                        *mn = x;
                    }
                    let mx = &mut self.minmax[mb + c.head_dim + i];
                    if x > *mx {
                        *mx = x;
                    }
                }
            }
        }
        self.page_fill[page as usize] = (slot + 1) as u32;
        seq.len += 1;
        // Seal: quantize the mirror exactly once, when the page fills
        // (the paper quantizes on page close). Until then the page has no
        // mirror block and consumers score its rows exactly from fp32 K —
        // see the sealing contract in the module header.
        if slot + 1 == c.page_size {
            self.requantize_page(page);
        }
        Ok(())
    }

    /// Build the mirror blocks for `page` from its (final) contents, and
    /// — with a tier attached — write the page through to the slow tier
    /// (the sealing contract's tiering clause: eviction is thereafter a
    /// metadata flip, the authoritative bytes live in the tier).
    fn requantize_page(&mut self, page: PageId) {
        let c = self.cfg.clone();
        let fill = self.page_fill[page as usize] as usize;
        for h in 0..c.kv_heads {
            let b = self.k_base(page, h, 0);
            let block = quant::quantize(self.k.read(b, fill * c.head_dim), c.mirror_bits);
            self.mirror[page as usize * c.kv_heads + h] = Some(block);
        }
        if let Some(ts) = &self.tier {
            let n = self.floats_per_page();
            let b = page as usize * n;
            spill_page(ts, page as usize, self.k.read(b, n), self.v.read(b, n));
        }
    }

    /// Estimated score `q · K̂[tok]` from the mirror cache for a logical
    /// token index. Fused dequant-dot on the packed codes. The token's
    /// page must be sealed (see the sealing contract); in-flight rows are
    /// scored exactly via [`PagedKvCache::exact_score`] instead.
    pub fn mirror_score(&self, seq: &SeqCache, head: usize, q: &[f32], tok: usize) -> f32 {
        let c = &self.cfg;
        let (page, slot) = seq.locate(tok, c.page_size);
        let block = self.mirror_at(page, head).expect("mirror missing (page not sealed)");
        // Slice the block logically: codes for `slot` start at slot*d.
        quant_dot_row(q, block, slot * c.head_dim, c.head_dim)
    }

    /// Exact score `q · K[tok]`.
    pub fn exact_score(&self, seq: &SeqCache, head: usize, q: &[f32], tok: usize) -> f32 {
        let c = &self.cfg;
        let (page, slot) = seq.locate(tok, c.page_size);
        crate::tensor::dot(q, self.k_at(page, head, slot))
    }

    /// Bytes held by the fp32 KV store (for memory accounting).
    pub fn bytes_main(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Bytes held by the mirror cache.
    pub fn bytes_mirror(&self) -> usize {
        self.mirror
            .iter()
            .flatten()
            .map(|b| b.packed.len() + 8)
            .sum()
    }

    // --- tiered offload ---------------------------------------------------

    /// Is `page` full and mirrored (content-final)? Only sealed pages
    /// are evictable; everything else is pinned resident.
    #[inline]
    fn is_sealed(&self, page: usize) -> bool {
        self.page_fill[page] as usize == self.cfg.page_size
            && self.mirror[page * self.cfg.kv_heads].is_some()
    }

    /// Attach a slow tier with an in-use residency cap of `resident_cap`
    /// pages. Every already-sealed in-use page is spilled immediately so
    /// later eviction never has to copy out.
    pub fn attach_tier(&mut self, tier: Box<dyn Tier>, resident_cap: usize) {
        let ts = TierState::new(tier, self.cfg.num_pages, resident_cap);
        let n = self.floats_per_page();
        for p in 0..self.cfg.num_pages {
            if self.refs[p] > 0 && self.is_sealed(p) {
                let b = p * n;
                spill_page(&ts, p, self.k.read(b, n), self.v.read(b, n));
            }
        }
        self.tier = Some(ts);
    }

    /// Detach the tier, faulting every evicted in-use page back in so
    /// the cache returns to the fully-resident invariant. Reads run the
    /// same bounded retry ladder as the fault path; a page whose ladder
    /// exhausts is zero-filled and counted lost (the owning request
    /// fails on its next step) — detach never panics the process.
    pub fn detach_tier(&mut self) -> Vec<PageId> {
        let mut lost = Vec::new();
        let Some(ts) = self.tier.take() else { return lost };
        let n = self.floats_per_page();
        for p in 0..self.cfg.num_pages {
            if self.refs[p] > 0 && ts.state[p].load(Ordering::Relaxed) == PAGE_EVICTED {
                let mut ok = false;
                for attempt in 0..=TIER_READ_RETRIES {
                    // SAFETY: `&mut self` — no concurrent access.
                    let res = unsafe {
                        ts.tier.read_page(
                            p,
                            self.k.write_racy(p * n, n),
                            self.v.write_racy(p * n, n),
                        )
                    };
                    match res {
                        Ok(()) => {
                            ok = true;
                            break;
                        }
                        Err(_) => {
                            ts.read_errors.fetch_add(1, Ordering::Relaxed);
                            if attempt < TIER_READ_RETRIES {
                                ts.retries.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                    }
                }
                if !ok {
                    self.k.slice_mut(p * n, n).fill(0.0);
                    self.v.slice_mut(p * n, n).fill(0.0);
                    lost.push(p as PageId);
                }
            }
        }
        lost
    }

    /// Re-poison `pages` as `PAGE_LOST` on the *current* tier — used by
    /// the engine to carry lost pages across a detach/attach
    /// reconfiguration so their owners still fail instead of reading
    /// silent zeros. No-op without a tier (the engine then tracks the
    /// pages itself).
    pub fn mark_pages_lost(&self, pages: &[PageId]) {
        if let Some(ts) = &self.tier {
            for &p in pages {
                ts.state[p as usize].store(PAGE_LOST, Ordering::Release);
                ts.lost_pages.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// The residency bookkeeping, if a tier is attached.
    pub fn tier_state(&self) -> Option<&TierState> {
        self.tier.as_ref()
    }

    /// Advance the deterministic LRU clock (the engine step ordinal).
    pub fn set_clock(&self, step: u64) {
        if let Some(ts) = &self.tier {
            ts.clock.store(step, Ordering::Relaxed);
        }
    }

    /// Is `page` resident right now? (Trivially true without a tier.)
    pub fn is_resident(&self, page: PageId) -> bool {
        match &self.tier {
            Some(ts) => ts.state[page as usize].load(Ordering::Relaxed) == PAGE_RESIDENT,
            None => true,
        }
    }

    /// Resident in-use pages (the quantity `enforce_residency` caps).
    pub fn resident_in_use_pages(&self) -> usize {
        let Some(ts) = &self.tier else {
            return self.used_pages();
        };
        (0..self.cfg.num_pages)
            .filter(|&p| {
                self.refs[p] > 0 && ts.state[p].load(Ordering::Relaxed) == PAGE_RESIDENT
            })
            .count()
    }

    /// Evict least-recently-touched sealed pages until the resident
    /// in-use count fits the (pressure-scaled) cap. Eviction is a
    /// metadata flip plus zeroing the fp32 region — the authoritative
    /// bytes were written through at seal. Victims are ordered by
    /// (last-touch asc, page id asc) over the deterministic step clock,
    /// so the resident set is identical for any thread count.
    pub fn enforce_residency(&mut self, degrade_level: u8) {
        let num_pages = self.cfg.num_pages;
        let n = self.floats_per_page();
        let Some(ts) = &mut self.tier else { return };
        let cap = ts.effective_cap(degrade_level);
        let mut resident = 0usize;
        ts.evict_scratch.clear();
        for p in 0..num_pages {
            if self.refs[p] == 0 || ts.state[p].load(Ordering::Relaxed) != PAGE_RESIDENT {
                continue;
            }
            resident += 1;
            let sealed = self.page_fill[p] as usize == self.cfg.page_size
                && self.mirror[p * self.cfg.kv_heads].is_some();
            // Only *durable* sealed pages are evictable: a page whose
            // spill never acknowledged (torn write, tier outage) has no
            // trustworthy tier copy, so it stays pinned resident —
            // degraded capacity, never lost data.
            if sealed && ts.durable[p].load(Ordering::Relaxed) == 1 {
                let touch = ts.last_touch[p].load(Ordering::Relaxed);
                ts.evict_scratch.push((touch, p as PageId));
            }
        }
        if resident <= cap {
            return;
        }
        ts.evict_scratch.sort_unstable();
        let excess = resident - cap;
        for &(_, p) in ts.evict_scratch.iter().take(excess) {
            ts.state[p as usize].store(PAGE_EVICTED, Ordering::Release);
            ts.evictions.fetch_add(1, Ordering::Relaxed);
            // Zero the stale fp32 so any read that bypassed the fault
            // path shows up as loudly-wrong zeros, never as silently
            // stale data.
            self.k.slice_mut(p as usize * n, n).fill(0.0);
            self.v.slice_mut(p as usize * n, n).fill(0.0);
        }
    }

    /// The prefetch oracle (hier-pages bound, PR 5): rank `seq`'s
    /// non-resident sealed pages by their scaled upper logit bound
    /// `s · (quest_ub + slack · Σ|q|)`, maxed over every (kv head ×
    /// group head) of `qs` (`[kv_heads * group * head_dim]`, one
    /// query token), and keep those whose bound-mass share
    /// `exp(b − bmax) / Σ exp(·)` is ≥ `eps_frac` — pages below the
    /// floor cannot shift any head's top-p mass materially, so faulting
    /// them ahead of demand would waste link bandwidth. `eps_frac = 0`
    /// plans every non-resident sealed page (dense attention).
    ///
    /// Buffers are caller-pooled; with [`PrefetchPlan::reserve`]d
    /// capacity this never allocates.
    pub fn plan_prefetch_into(
        &self,
        seq: &SeqCache,
        qs: &[f32],
        group: usize,
        eps_frac: f32,
        plan: &mut PrefetchPlan,
    ) {
        plan.clear();
        let Some(ts) = &self.tier else { return };
        let c = &self.cfg;
        let d = c.head_dim;
        let kvn = c.kv_heads;
        debug_assert_eq!(qs.len(), kvn * group * d);
        let sealed_pages = seq.len / c.page_size;
        if sealed_pages == 0 {
            return;
        }
        let s = crate::attention::scale(d);
        for h in 0..kvn * group {
            let a: f32 = qs[h * d..(h + 1) * d].iter().map(|x| x.abs()).sum();
            plan.qabs.push(a);
        }
        // Per-page bound: the same quest-ub + quantization-slack formula
        // the hier pruner proves sound (pruner/mod.rs §hier_prune_group),
        // maxed over all heads that will read the page.
        let mut bmax = f32::NEG_INFINITY;
        for &page in &seq.pages[..sealed_pages] {
            let mut key = f32::NEG_INFINITY;
            for kvh in 0..kvn {
                let (mn, mx) = self.minmax_at(page, kvh);
                let block = self.mirror_at(page, kvh).expect("sealed page missing mirror");
                let slack = if block.bits == QuantBits::Fp16 {
                    // f16 round-off is relative — bound it from the
                    // page's max |K| (see the pruner's derivation).
                    let mut maxabs = 0.0f32;
                    for i in 0..d {
                        maxabs = maxabs.max(mn[i].abs()).max(mx[i].abs());
                    }
                    maxabs * (1.0 / 1024.0)
                } else {
                    quant::max_error(block)
                };
                for g in 0..group {
                    let h = kvh * group + g;
                    let q = &qs[h * d..(h + 1) * d];
                    let mut ub = 0.0f32;
                    for i in 0..d {
                        ub += (q[i] * mn[i]).max(q[i] * mx[i]);
                    }
                    key = key.max(s * (ub + slack * plan.qabs[h]));
                }
            }
            plan.weights.push(key);
            bmax = bmax.max(key);
        }
        let mut total = 0.0f32;
        for w in plan.weights.iter_mut() {
            *w = (*w - bmax).exp();
            total += *w;
        }
        for (&page, &w) in seq.pages[..sealed_pages].iter().zip(plan.weights.iter()) {
            if ts.state[page as usize].load(Ordering::Relaxed) == PAGE_RESIDENT {
                continue;
            }
            if w < eps_frac * total {
                continue;
            }
            plan.entries.push((w, page));
        }
        // Fault order: best bound first (`exp` is monotonic in the
        // bound), page-id ties ascending for determinism.
        plan.entries.sort_unstable_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        for &(_, p) in &plan.entries {
            plan.pages.push(p);
        }
    }

    /// Span-envelope upper logit bound of one *sealed* page for the
    /// sparse-prefill path (DESIGN.md §13): for any query row `q` with
    /// `qmin[i] ≤ q[i] ≤ qmax[i]` coordinate-wise, every token `t` of
    /// the page satisfies
    ///
    /// `q · K[t]  ≤  Σᵢ max(qmin·mn, qmin·mx, qmax·mn, qmax·mx)ᵢ
    ///               + slack · qabs_sum`
    ///
    /// — the interval-arithmetic generalization of the hier bound
    /// (`pruner::hier_prune_group` proves the single-query form): each
    /// coordinate's contribution `qᵢ·Kᵢ` is maximized over the
    /// rectangle `[qmin, qmax]ᵢ × [mn, mx]ᵢ` at a corner, and `slack`
    /// (the same Fp16/int split as the hier path) covers the gap
    /// between the metadata and the true rows with `Σ|q| ≤ qabs_sum`.
    /// One call bounds every query of a chunk span at once, which is
    /// what keeps the skip decision O(pages·d) instead of
    /// O(span·pages·d). Unscaled — callers apply `attention::scale`.
    pub fn envelope_page_bound(
        &self,
        page: PageId,
        head: usize,
        qmin: &[f32],
        qmax: &[f32],
        qabs_sum: f32,
    ) -> f32 {
        let d = self.cfg.head_dim;
        debug_assert_eq!(qmin.len(), d);
        debug_assert_eq!(qmax.len(), d);
        let (mn, mx) = self.minmax_at(page, head);
        let block = self.mirror_at(page, head).expect("sealed page missing mirror");
        let slack = if block.bits == QuantBits::Fp16 {
            let mut maxabs = 0.0f32;
            for i in 0..d {
                maxabs = maxabs.max(mn[i].abs()).max(mx[i].abs());
            }
            maxabs * (1.0 / 1024.0)
        } else {
            quant::max_error(block)
        };
        let mut ub = 0.0f32;
        for i in 0..d {
            let lo = (qmin[i] * mn[i]).max(qmin[i] * mx[i]);
            let hi = (qmax[i] * mn[i]).max(qmax[i] * mx[i]);
            ub += lo.max(hi);
        }
        ub + slack * qabs_sum
    }
}

/// Write-through spill with the bounded write-retry ladder
/// (DESIGN.md §14). On acknowledgement the page becomes durable
/// (evictable); on exhaustion it stays non-durable, which
/// `enforce_residency` treats as pinned resident — the request keeps
/// its exact bytes and only capacity degrades.
fn spill_page(ts: &TierState, page: usize, k: &[f32], v: &[f32]) {
    for attempt in 0..=TIER_WRITE_RETRIES {
        match ts.tier.write_page(page, k, v) {
            Ok(()) => {
                ts.durable[page].store(1, Ordering::Release);
                ts.spilled_writes.fetch_add(1, Ordering::Relaxed);
                return;
            }
            Err(_) => {
                ts.write_errors.fetch_add(1, Ordering::Relaxed);
                if attempt < TIER_WRITE_RETRIES {
                    ts.retries.fetch_add(1, Ordering::Relaxed);
                    for _ in 0..(64u32 << attempt.min(8)) {
                        std::hint::spin_loop();
                    }
                }
            }
        }
    }
}

/// Max head dimension supported by the stack-buffer unpack fast path.
pub const MAX_HEAD_DIM: usize = 256;

/// Dot a whole GQA *group* of queries (`qs: [group * d]`) against one
/// packed row, unpacking the codes once (§Perf: the unpack pass dominates
/// the fused dequant-dot on CPU; sharing it across the group amortizes it
/// 4× for LLaMA-3-style models). `out[g] += nothing` — results written.
#[inline]
pub fn quant_dot_row_group(
    qs: &[f32],
    qsums: &[f32],
    b: &QuantBlock,
    offset: usize,
    d: usize,
    out: &mut [f32],
) {
    let group = qsums.len();
    debug_assert_eq!(qs.len(), group * d);
    debug_assert!(d <= MAX_HEAD_DIM);
    // One shared widening routine (`unpack_codes_into`) serves this row
    // path, the single-head path below, and the page-tile unpack, so the
    // per-width bit-twiddling cannot drift apart.
    let mut codes = [0.0f32; MAX_HEAD_DIM];
    quant::unpack_codes_into(b, offset, &mut codes[..d]);
    if b.bits == QuantBits::Fp16 {
        for g in 0..group {
            out[g] = crate::tensor::dot(&qs[g * d..(g + 1) * d], &codes[..d]);
        }
        return;
    }
    for g in 0..group {
        out[g] = b.zero * qsums[g]
            + b.scale * crate::tensor::dot(&qs[g * d..(g + 1) * d], &codes[..d]);
    }
}

/// Dot `q` against a row (offset..offset+d) of a packed quant block,
/// without materializing the dequantized row in memory traffic terms:
/// codes are widened into a stack buffer (a vectorizable unpack pass,
/// the CPU analog of the CUDA kernel's shared-memory dequant) and then
/// contracted with a vectorized FMA dot.
#[inline]
pub fn quant_dot_row(q: &[f32], b: &QuantBlock, offset: usize, d: usize) -> f32 {
    let qsum: f32 = q.iter().sum();
    quant_dot_row_qsum(q, qsum, b, offset, d)
}

/// `quant_dot_row` with the (row-invariant) `sum(q)` hoisted out — the
/// SpGEMV loop computes it once per query instead of once per row.
#[inline]
pub fn quant_dot_row_qsum(q: &[f32], qsum: f32, b: &QuantBlock, offset: usize, d: usize) -> f32 {
    debug_assert!(offset + d <= b.n);
    debug_assert_eq!(q.len(), d);
    debug_assert!(d <= MAX_HEAD_DIM);
    if b.bits == QuantBits::Fp16 {
        // Fused packed-f16 dot — the historical single-head Fp16 order
        // (the backend's `dot_f16` pairs with its `dot_strict` so this
        // stays bit-for-bit stable vs widened-row dots); kept distinct
        // from the group path's throughput `dot`.
        let kn = crate::tensor::kernels::active();
        return (kn.dot_f16)(q, &b.packed[2 * offset..2 * (offset + d)]);
    }
    // Integer widths: widen via the shared `unpack_codes_into` (also
    // used by the group path and the page-tile unpack — one copy of the
    // bit-twiddling), then one vectorized dot.
    let mut codes = [0.0f32; MAX_HEAD_DIM];
    quant::unpack_codes_into(b, offset, &mut codes[..d]);
    b.zero * qsum + b.scale * crate::tensor::dot(q, &codes[..d])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mk(kv_heads: usize, d: usize, pages: usize) -> PagedKvCache {
        PagedKvCache::new(CacheConfig::new(kv_heads, d, pages))
    }

    fn rand_kv(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn append_and_read_back() {
        let mut c = mk(2, 8, 4);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(1);
        let mut ks = Vec::new();
        for _ in 0..20 {
            let k = rand_kv(&mut r, 16);
            let v = rand_kv(&mut r, 16);
            c.append(&mut seq, &k, &v).unwrap();
            ks.push(k);
        }
        assert_eq!(seq.len, 20);
        assert_eq!(seq.pages.len(), 2); // 20 tokens / 16 per page
        for (i, k) in ks.iter().enumerate() {
            let (page, slot) = seq.locate(i, 16);
            for h in 0..2 {
                assert_eq!(c.k_at(page, h, slot), &k[h * 8..(h + 1) * 8]);
            }
        }
    }

    #[test]
    fn out_of_pages() {
        let mut c = mk(1, 4, 1);
        let mut seq = SeqCache::default();
        for _ in 0..16 {
            c.append(&mut seq, &[0.0; 4], &[0.0; 4]).unwrap();
        }
        let e = c.append(&mut seq, &[0.0; 4], &[0.0; 4]);
        assert_eq!(e, Err(CacheError::OutOfPages));
    }

    #[test]
    fn release_returns_pages() {
        let mut c = mk(1, 4, 4);
        let mut seq = SeqCache::default();
        for _ in 0..40 {
            c.append(&mut seq, &[1.0; 4], &[1.0; 4]).unwrap();
        }
        assert_eq!(c.free_pages(), 1);
        c.release(&seq);
        assert_eq!(c.free_pages(), 4);
    }

    #[test]
    fn prefix_sharing_refcounts() {
        let mut c = mk(1, 4, 8);
        let mut a = SeqCache::default();
        for _ in 0..32 {
            c.append(&mut a, &[1.0; 4], &[1.0; 4]).unwrap();
        }
        let b = c.share(&a);
        c.release(&a);
        assert_eq!(c.free_pages(), 6); // b still holds 2 pages
        c.release(&b);
        assert_eq!(c.free_pages(), 8);
    }

    #[test]
    fn quest_minmax_bounds_scores() {
        let mut c = mk(1, 8, 8);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(5);
        for _ in 0..48 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
        }
        let q = rand_kv(&mut r, 8);
        // Quest upper bound per page: sum_i max(q_i*min_i, q_i*max_i)
        for (pi, &page) in seq.pages.iter().enumerate() {
            let (mn, mx) = c.minmax_at(page, 0);
            let ub: f32 = q
                .iter()
                .zip(mn.iter().zip(mx))
                .map(|(&qi, (&lo, &hi))| (qi * lo).max(qi * hi))
                .sum();
            for slot in 0..c.fill_of(page) {
                let tok = pi * 16 + slot;
                let s = c.exact_score(&seq, 0, &q, tok);
                assert!(s <= ub + 1e-4, "page {pi} slot {slot}: {s} > {ub}");
            }
        }
    }

    #[test]
    fn mirror_score_close_to_exact() {
        let mut c = mk(2, 16, 8);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(9);
        for _ in 0..64 {
            let k = rand_kv(&mut r, 32);
            c.append(&mut seq, &k, &k).unwrap();
        }
        let q = rand_kv(&mut r, 16);
        for tok in [0usize, 15, 16, 63] {
            for h in 0..2 {
                let exact = c.exact_score(&seq, h, &q, tok);
                let approx = c.mirror_score(&seq, h, &q, tok);
                // INT4 with per-(page,head) scale over N(0,1) data: coarse
                // but must stay well-correlated.
                assert!(
                    (exact - approx).abs() < 1.5,
                    "tok {tok} head {h}: exact={exact} approx={approx}"
                );
            }
        }
    }

    #[test]
    fn memory_accounting() {
        let mut c = mk(1, 16, 4);
        let mut seq = SeqCache::default();
        for _ in 0..16 {
            c.append(&mut seq, &[0.5; 16], &[0.5; 16]).unwrap();
        }
        assert_eq!(c.bytes_main(), 2 * 4 * 16 * 16 * 4);
        // One full page mirrored at int4: 16*16/2 bytes + 8 overhead.
        assert_eq!(c.bytes_mirror(), 16 * 16 / 2 + 8);
    }

    // --- tiered offload ---------------------------------------------------

    fn sim_tier_for(c: &PagedKvCache) -> Box<offload::SimTier> {
        let fpp = c.cfg.kv_heads * c.cfg.page_size * c.cfg.head_dim;
        Box::new(offload::SimTier::new(fpp, c.cfg.num_pages, 2))
    }

    #[test]
    fn eviction_then_fault_restores_exact_bytes() {
        let mut c = mk(2, 8, 6);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(11);
        let mut ks = Vec::new();
        let mut vs = Vec::new();
        for _ in 0..64 {
            let k = rand_kv(&mut r, 16);
            let v = rand_kv(&mut r, 16);
            c.append(&mut seq, &k, &v).unwrap();
            ks.push(k);
            vs.push(v);
        }
        // Attach mid-life: the 4 sealed pages spill immediately.
        let tier = sim_tier_for(&c);
        c.attach_tier(tier, 2);
        assert_eq!(c.tier_state().unwrap().spilled_writes.load(Ordering::Relaxed), 4);
        c.set_clock(1);
        c.enforce_residency(0);
        assert!(c.resident_in_use_pages() <= 2, "cap must hold after enforce");
        let evicted: Vec<PageId> =
            (0..6).map(|p| p as PageId).filter(|&p| !c.is_resident(p)).collect();
        assert!(!evicted.is_empty(), "some sealed page must have been evicted");
        // The unsealed tail page (64 tokens = 4 full pages + 0…— append
        // 3 more to create a tail) is never a victim.
        for _ in 0..3 {
            let k = rand_kv(&mut r, 16);
            c.append(&mut seq, &k, &k).unwrap();
            ks.push(k.clone());
            vs.push(k);
        }
        c.set_clock(2);
        c.enforce_residency(0);
        let tail = *seq.pages.last().unwrap();
        assert!(c.is_resident(tail), "unsealed tail must stay resident");
        // Every row reads back bit-exact through the fault path.
        for (i, k) in ks.iter().enumerate() {
            let (page, slot) = seq.locate(i, 16);
            for h in 0..2 {
                assert_eq!(c.k_at(page, h, slot), &k[h * 8..(h + 1) * 8], "tok {i} head {h}");
                assert_eq!(c.v_at(page, h, slot), &vs[i][h * 8..(h + 1) * 8]);
            }
        }
        let ts = c.tier_state().unwrap();
        assert!(ts.faults.load(Ordering::Relaxed) >= evicted.len() as u64);
    }

    #[test]
    fn resident_pages_never_refault() {
        let mut c = mk(1, 8, 4);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(3);
        for _ in 0..32 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
        }
        c.attach_tier(sim_tier_for(&c), 4);
        // Everything fits: reads must not fault.
        for i in 0..32 {
            let (page, slot) = seq.locate(i, 16);
            let _ = c.k_at(page, 0, slot);
        }
        assert_eq!(c.tier_state().unwrap().faults.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn prefetch_plan_is_nonresident_sealed_in_bound_order() {
        let mut c = mk(1, 8, 10);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(17);
        for _ in 0..130 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
        }
        c.attach_tier(sim_tier_for(&c), 3);
        c.set_clock(1);
        c.enforce_residency(0);
        let q = rand_kv(&mut r, 8);
        let mut plan = offload::PrefetchPlan::default();
        plan.reserve(c.cfg.num_pages, 1);
        c.plan_prefetch_into(&seq, &q, 1, 0.0, &mut plan);
        let sealed = seq.len / 16;
        assert!(!plan.pages.is_empty());
        for &p in &plan.pages {
            assert!(!c.is_resident(p), "planned page {p} is already resident");
            let pi = seq.pages[..sealed].iter().position(|&x| x == p);
            assert!(pi.is_some(), "planned page {p} is not a sealed page of the seq");
        }
        // eps=0 plans every non-resident sealed page.
        let nonresident = seq.pages[..sealed].iter().filter(|&&p| !c.is_resident(p)).count();
        assert_eq!(plan.pages.len(), nonresident);
        // Descending hier bound (recompute independently via the Quest
        // ub + slack formula the plan uses).
        let bound_of = |p: PageId| -> f32 {
            let (mn, mx) = c.minmax_at(p, 0);
            let block = c.mirror_at(p, 0).unwrap();
            let slack = quant::max_error(block);
            let qabs: f32 = q.iter().map(|x| x.abs()).sum();
            let ub: f32 =
                q.iter().zip(mn.iter().zip(mx)).map(|(&qi, (&lo, &hi))| (qi * lo).max(qi * hi)).sum();
            crate::attention::scale(8) * (ub + slack * qabs)
        };
        for w in plan.pages.windows(2) {
            assert!(
                bound_of(w[0]) >= bound_of(w[1]),
                "plan not in descending bound order: {:?}",
                plan.pages
            );
        }
        // A strictly positive mass floor can only shrink the plan.
        let mut strict = offload::PrefetchPlan::default();
        c.plan_prefetch_into(&seq, &q, 1, 0.5, &mut strict);
        assert!(strict.pages.len() <= plan.pages.len());
        for &p in &strict.pages {
            assert!(plan.pages.contains(&p));
        }
    }

    #[test]
    fn detach_restores_fully_resident() {
        let mut c = mk(1, 8, 6);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(23);
        let mut ks = Vec::new();
        for _ in 0..64 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
            ks.push(k);
        }
        c.attach_tier(sim_tier_for(&c), 1);
        c.set_clock(1);
        c.enforce_residency(0);
        assert!((0..6).any(|p| !c.is_resident(p as PageId)));
        c.detach_tier();
        assert!(c.tier_state().is_none());
        for (i, k) in ks.iter().enumerate() {
            let (page, slot) = seq.locate(i, 16);
            assert_eq!(c.k_at(page, 0, slot), &k[..8]);
        }
    }

    #[test]
    fn lost_page_fails_loudly_never_hangs() {
        let mut c = mk(1, 8, 6);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(31);
        for _ in 0..64 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
        }
        // Reads always fail; writes always succeed (pages can evict).
        let cfg = offload::ChaosConfig { seed: 1, p_read: 1.0, p_write: 0.0, p_panic: 0.0 };
        let chaos = Box::new(offload::ChaosTier::new(sim_tier_for(&c), cfg, 6));
        c.attach_tier(chaos, 2);
        c.set_clock(1);
        c.enforce_residency(0);
        assert!(!c.has_lost_page(&seq), "nothing is lost before any read");
        let victim = (0..64)
            .find(|&i| !c.is_resident(seq.locate(i, 16).0))
            .expect("some token must sit on an evicted page");
        let (page, slot) = seq.locate(victim, 16);
        // Demand read: the ladder exhausts, the row comes back as loud
        // zeros (never torn bytes), and nothing hangs or panics.
        assert_eq!(c.k_at(page, 0, slot), &[0.0f32; 8]);
        assert!(c.has_lost_page(&seq));
        let ts = c.tier_state().unwrap();
        assert!(ts.lost_pages.load(Ordering::Relaxed) >= 1);
        assert!(ts.read_errors.load(Ordering::Relaxed) >= 1);
        assert!(ts.retries.load(Ordering::Relaxed) >= 1);
        // Sticky: a second read must not burn another retry ladder.
        let errs = ts.read_errors.load(Ordering::Relaxed);
        let _ = c.k_at(page, 0, slot);
        let ts = c.tier_state().unwrap();
        assert_eq!(ts.read_errors.load(Ordering::Relaxed), errs);
        // Reallocation is the reset point: freed lost pages come back
        // clean for their next owner.
        c.release(&seq);
        let mut seq2 = SeqCache::default();
        for _ in 0..96 {
            c.append(&mut seq2, &[1.0; 8], &[1.0; 8]).unwrap();
        }
        assert!(!c.has_lost_page(&seq2));
    }

    #[test]
    fn unacknowledged_spill_pins_page_resident() {
        let mut c = mk(1, 8, 6);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(37);
        let mut ks = Vec::new();
        for _ in 0..64 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
            ks.push(k);
        }
        // Every spill tears: no page may ever become an eviction victim.
        let cfg = offload::ChaosConfig { seed: 5, p_read: 0.0, p_write: 1.0, p_panic: 0.0 };
        let chaos = Box::new(offload::ChaosTier::new(sim_tier_for(&c), cfg, 6));
        c.attach_tier(chaos, 1);
        c.set_clock(1);
        c.enforce_residency(0);
        assert_eq!(c.resident_in_use_pages(), 4, "non-durable pages must stay pinned");
        let ts = c.tier_state().unwrap();
        assert_eq!(ts.evictions.load(Ordering::Relaxed), 0);
        assert!(ts.write_errors.load(Ordering::Relaxed) >= 4);
        assert_eq!(ts.spilled_writes.load(Ordering::Relaxed), 0);
        // The data stayed resident, so every row is still bit-exact.
        for (i, k) in ks.iter().enumerate() {
            let (page, slot) = seq.locate(i, 16);
            assert_eq!(c.k_at(page, 0, slot), &k[..8]);
        }
        assert!(!c.has_lost_page(&seq));
    }

    #[test]
    fn transient_read_fault_heals_bit_exact() {
        use std::sync::atomic::AtomicU64;
        /// Fails the first two reads of every page, then delegates.
        struct Flaky {
            inner: offload::SimTier,
            attempts: Vec<AtomicU64>,
        }
        impl offload::Tier for Flaky {
            fn name(&self) -> &'static str {
                "flaky"
            }
            fn write_page(&self, p: usize, k: &[f32], v: &[f32])
                -> Result<(), offload::TierError> {
                self.inner.write_page(p, k, v)
            }
            fn read_page(&self, p: usize, ko: &mut [f32], vo: &mut [f32])
                -> Result<(), offload::TierError> {
                if self.attempts[p].fetch_add(1, Ordering::Relaxed) < 2 {
                    return Err(offload::TierError { op: offload::TierOp::Read, page: p });
                }
                self.inner.read_page(p, ko, vo)
            }
        }
        let mut c = mk(1, 8, 6);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(41);
        let mut ks = Vec::new();
        for _ in 0..64 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
            ks.push(k);
        }
        let fpp = c.cfg.kv_heads * c.cfg.page_size * c.cfg.head_dim;
        let flaky = Flaky {
            inner: offload::SimTier::new(fpp, 6, 1),
            attempts: (0..6).map(|_| AtomicU64::new(0)).collect(),
        };
        c.attach_tier(Box::new(flaky), 2);
        c.set_clock(1);
        c.enforce_residency(0);
        // Two failures per page sit inside the ladder's budget: every
        // row must heal to exact bytes, with zero lost pages.
        for (i, k) in ks.iter().enumerate() {
            let (page, slot) = seq.locate(i, 16);
            assert_eq!(c.k_at(page, 0, slot), &k[..8], "tok {i}");
        }
        let ts = c.tier_state().unwrap();
        assert_eq!(ts.lost_pages.load(Ordering::Relaxed), 0);
        assert!(ts.read_errors.load(Ordering::Relaxed) >= 2);
        assert!(ts.retries.load(Ordering::Relaxed) >= 2);
        assert!(!c.has_lost_page(&seq));
    }

    #[test]
    fn freed_pages_do_not_count_against_cap() {
        let mut c = mk(1, 8, 8);
        let mut a = SeqCache::default();
        for _ in 0..64 {
            c.append(&mut a, &[1.0; 8], &[1.0; 8]).unwrap();
        }
        c.attach_tier(sim_tier_for(&c), 8);
        c.release(&a);
        assert_eq!(c.resident_in_use_pages(), 0);
        c.enforce_residency(0);
        assert_eq!(c.tier_state().unwrap().evictions.load(Ordering::Relaxed), 0);
    }
}
