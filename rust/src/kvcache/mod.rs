//! Paged KV cache manager (PagedAttention-style, paper §4.3) with the
//! Twilight INT4 mirror K cache and Quest page metadata maintained on
//! append.
//!
//! Layout decisions mirror the paper's system design:
//! * storage is paged (`page_size` tokens per page, default 16 — Quest's
//!   page granularity) with per-sequence page tables, so prefix sharing
//!   and the varlen attention kernels address tokens as
//!   `(page, slot)` pairs;
//! * a low-precision mirror of K (per-(page, head) asymmetric INT4 by
//!   default) is kept alongside, in the same paged layout — this is the
//!   "extra INT4 quantized K cache" of §4.2, costing 1/8 extra memory;
//!   the pruner's page-tiled SpGEMV unpacks a mirror block's codes once
//!   per candidate run (`tensor::quant::unpack_codes_into`) rather than
//!   once per row;
//! * per-(page, head) elementwise min/max of K is kept for the Quest
//!   selector's upper-bound score — and, with `--hier-pages`, doubles as
//!   the pruner's page-level logit bound (plus the mirror block's
//!   `quant::max_error` slack) for hierarchical top-p early stopping.
//!
//! **Sealing contract.** A page's mirror block is built exactly once, when
//! the page *seals* (its last slot is appended) — the paper quantizes at
//! prefill and on page close, and re-quantizing a partially-filled page
//! on every append both wastes bandwidth and makes the codes of earlier
//! slots depend on later arrivals (the per-block scale/zero shift).
//! Consumers treat the unsealed tail uniformly: the pruner's SpGEMV
//! scores in-flight rows exactly from fp32 K, and Quest scores the
//! partial tail page from exact rows instead of its (still-moving)
//! min/max. This is what makes chunked prefill chunk-size invariant: a
//! query inside a chunk sees only sealed (content-final) metadata plus
//! exact reads of the visible prefix, so its result cannot depend on how
//! many later tokens the chunk appended before it attended.

pub mod offload;

use crate::tensor::quant::{self, QuantBits, QuantBlock};

/// Identifies a physical page in the pool.
pub type PageId = u32;

/// Cache geometry and precision configuration.
#[derive(Clone, Debug)]
pub struct CacheConfig {
    /// Number of KV heads (GQA: may be fewer than query heads).
    pub kv_heads: usize,
    /// Head dimension.
    pub head_dim: usize,
    /// Tokens per page.
    pub page_size: usize,
    /// Total physical pages in the pool.
    pub num_pages: usize,
    /// Mirror K-cache precision (paper default INT4).
    pub mirror_bits: QuantBits,
}

impl CacheConfig {
    pub fn new(kv_heads: usize, head_dim: usize, num_pages: usize) -> CacheConfig {
        CacheConfig { kv_heads, head_dim, page_size: 16, num_pages, mirror_bits: QuantBits::Int4 }
    }

    /// Tokens the pool can hold.
    pub fn capacity_tokens(&self) -> usize {
        self.num_pages * self.page_size
    }
}

/// Per-sequence view: page table plus logical length.
#[derive(Clone, Debug, Default)]
pub struct SeqCache {
    pub pages: Vec<PageId>,
    pub len: usize,
}

impl SeqCache {
    /// Physical location of logical token `i`.
    #[inline]
    pub fn locate(&self, i: usize, page_size: usize) -> (PageId, usize) {
        (self.pages[i / page_size], i % page_size)
    }
}

/// Errors from the allocator.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CacheError {
    OutOfPages,
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheError::OutOfPages => write!(f, "KV cache pool exhausted"),
        }
    }
}

impl std::error::Error for CacheError {}

/// The physical paged pool. All tensors are row-major f32; the mirror is
/// packed per (page, head).
pub struct PagedKvCache {
    pub cfg: CacheConfig,
    /// K storage: `[page][kv_head][slot][d]`.
    k: Vec<f32>,
    /// V storage: same layout.
    v: Vec<f32>,
    /// Mirror K codes: per (page, head) `QuantBlock` over `[slot][d]`.
    mirror: Vec<Option<QuantBlock>>,
    /// Quest metadata: per (page, head), elementwise min then max (2*d).
    minmax: Vec<f32>,
    /// Number of valid tokens currently in each page.
    page_fill: Vec<u32>,
    /// Reference counts (prefix sharing); 0 = free.
    refs: Vec<u32>,
    free: Vec<PageId>,
}

impl PagedKvCache {
    pub fn new(cfg: CacheConfig) -> PagedKvCache {
        let per_page = cfg.kv_heads * cfg.page_size * cfg.head_dim;
        PagedKvCache {
            k: vec![0.0; cfg.num_pages * per_page],
            v: vec![0.0; cfg.num_pages * per_page],
            mirror: (0..cfg.num_pages * cfg.kv_heads).map(|_| None).collect(),
            minmax: vec![0.0; cfg.num_pages * cfg.kv_heads * 2 * cfg.head_dim],
            page_fill: vec![0; cfg.num_pages],
            refs: vec![0; cfg.num_pages],
            free: (0..cfg.num_pages as PageId).rev().collect(),
            cfg,
        }
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn used_pages(&self) -> usize {
        self.cfg.num_pages - self.free.len()
    }

    fn alloc_page(&mut self) -> Result<PageId, CacheError> {
        let p = self.free.pop().ok_or(CacheError::OutOfPages)?;
        self.refs[p as usize] = 1;
        self.page_fill[p as usize] = 0;
        for h in 0..self.cfg.kv_heads {
            self.mirror[p as usize * self.cfg.kv_heads + h] = None;
        }
        Ok(p)
    }

    /// Increase the refcount of every page of `seq` (prefix sharing: a
    /// forked sequence shares all full pages of its parent).
    pub fn share(&mut self, seq: &SeqCache) -> SeqCache {
        for &p in &seq.pages {
            self.refs[p as usize] += 1;
        }
        seq.clone()
    }

    /// Release a sequence's pages.
    pub fn release(&mut self, seq: &SeqCache) {
        for &p in &seq.pages {
            let r = &mut self.refs[p as usize];
            debug_assert!(*r > 0);
            *r -= 1;
            if *r == 0 {
                self.free.push(p);
            }
        }
    }

    #[inline]
    fn k_base(&self, page: PageId, head: usize, slot: usize) -> usize {
        let c = &self.cfg;
        ((page as usize * c.kv_heads + head) * c.page_size + slot) * c.head_dim
    }

    /// K vector at (page, head, slot).
    #[inline]
    pub fn k_at(&self, page: PageId, head: usize, slot: usize) -> &[f32] {
        let b = self.k_base(page, head, slot);
        &self.k[b..b + self.cfg.head_dim]
    }

    /// V vector at (page, head, slot).
    #[inline]
    pub fn v_at(&self, page: PageId, head: usize, slot: usize) -> &[f32] {
        let b = self.k_base(page, head, slot);
        &self.v[b..b + self.cfg.head_dim]
    }

    /// Quest min/max metadata of (page, head): `(&min[d], &max[d])`.
    #[inline]
    pub fn minmax_at(&self, page: PageId, head: usize) -> (&[f32], &[f32]) {
        let d = self.cfg.head_dim;
        let b = (page as usize * self.cfg.kv_heads + head) * 2 * d;
        (&self.minmax[b..b + d], &self.minmax[b + d..b + 2 * d])
    }

    /// Mirror quant block of (page, head), if the page has been sealed.
    #[inline]
    pub fn mirror_at(&self, page: PageId, head: usize) -> Option<&QuantBlock> {
        self.mirror[page as usize * self.cfg.kv_heads + head].as_ref()
    }

    /// Number of valid tokens in `page`.
    #[inline]
    pub fn fill_of(&self, page: PageId) -> usize {
        self.page_fill[page as usize] as usize
    }

    /// Append one token's K/V (all kv heads at once, `k`/`v` are
    /// `[kv_heads * head_dim]`) to `seq`, allocating a page if needed.
    pub fn append(
        &mut self,
        seq: &mut SeqCache,
        k: &[f32],
        v: &[f32],
    ) -> Result<(), CacheError> {
        let c = self.cfg.clone();
        debug_assert_eq!(k.len(), c.kv_heads * c.head_dim);
        debug_assert_eq!(v.len(), c.kv_heads * c.head_dim);
        let slot = seq.len % c.page_size;
        if slot == 0 {
            let p = self.alloc_page()?;
            seq.pages.push(p);
        }
        let page = *seq.pages.last().unwrap();
        debug_assert_eq!(self.refs[page as usize], 1, "appending to shared page");
        for h in 0..c.kv_heads {
            let base = self.k_base(page, h, slot);
            let src = &k[h * c.head_dim..(h + 1) * c.head_dim];
            self.k[base..base + c.head_dim].copy_from_slice(src);
            let vsrc = &v[h * c.head_dim..(h + 1) * c.head_dim];
            self.v[base..base + c.head_dim].copy_from_slice(vsrc);
            // Update Quest min/max incrementally.
            let mb = (page as usize * c.kv_heads + h) * 2 * c.head_dim;
            if slot == 0 {
                self.minmax[mb..mb + c.head_dim].copy_from_slice(src);
                self.minmax[mb + c.head_dim..mb + 2 * c.head_dim].copy_from_slice(src);
            } else {
                for (i, &x) in src.iter().enumerate() {
                    let mn = &mut self.minmax[mb + i];
                    if x < *mn {
                        *mn = x;
                    }
                    let mx = &mut self.minmax[mb + c.head_dim + i];
                    if x > *mx {
                        *mx = x;
                    }
                }
            }
        }
        self.page_fill[page as usize] = (slot + 1) as u32;
        seq.len += 1;
        // Seal: quantize the mirror exactly once, when the page fills
        // (the paper quantizes on page close). Until then the page has no
        // mirror block and consumers score its rows exactly from fp32 K —
        // see the sealing contract in the module header.
        if slot + 1 == c.page_size {
            self.requantize_page(page);
        }
        Ok(())
    }

    /// Build the mirror blocks for `page` from its (final) contents.
    fn requantize_page(&mut self, page: PageId) {
        let c = self.cfg.clone();
        let fill = self.page_fill[page as usize] as usize;
        for h in 0..c.kv_heads {
            let b = self.k_base(page, h, 0);
            let data = &self.k[b..b + fill * c.head_dim];
            let block = quant::quantize(data, c.mirror_bits);
            self.mirror[page as usize * c.kv_heads + h] = Some(block);
        }
    }

    /// Estimated score `q · K̂[tok]` from the mirror cache for a logical
    /// token index. Fused dequant-dot on the packed codes. The token's
    /// page must be sealed (see the sealing contract); in-flight rows are
    /// scored exactly via [`PagedKvCache::exact_score`] instead.
    pub fn mirror_score(&self, seq: &SeqCache, head: usize, q: &[f32], tok: usize) -> f32 {
        let c = &self.cfg;
        let (page, slot) = seq.locate(tok, c.page_size);
        let block = self.mirror_at(page, head).expect("mirror missing (page not sealed)");
        // Slice the block logically: codes for `slot` start at slot*d.
        quant_dot_row(q, block, slot * c.head_dim, c.head_dim)
    }

    /// Exact score `q · K[tok]`.
    pub fn exact_score(&self, seq: &SeqCache, head: usize, q: &[f32], tok: usize) -> f32 {
        let c = &self.cfg;
        let (page, slot) = seq.locate(tok, c.page_size);
        crate::tensor::dot(q, self.k_at(page, head, slot))
    }

    /// Bytes held by the fp32 KV store (for memory accounting).
    pub fn bytes_main(&self) -> usize {
        (self.k.len() + self.v.len()) * 4
    }

    /// Bytes held by the mirror cache.
    pub fn bytes_mirror(&self) -> usize {
        self.mirror
            .iter()
            .flatten()
            .map(|b| b.packed.len() + 8)
            .sum()
    }
}

/// Max head dimension supported by the stack-buffer unpack fast path.
pub const MAX_HEAD_DIM: usize = 256;

/// Dot a whole GQA *group* of queries (`qs: [group * d]`) against one
/// packed row, unpacking the codes once (§Perf: the unpack pass dominates
/// the fused dequant-dot on CPU; sharing it across the group amortizes it
/// 4× for LLaMA-3-style models). `out[g] += nothing` — results written.
#[inline]
pub fn quant_dot_row_group(
    qs: &[f32],
    qsums: &[f32],
    b: &QuantBlock,
    offset: usize,
    d: usize,
    out: &mut [f32],
) {
    let group = qsums.len();
    debug_assert_eq!(qs.len(), group * d);
    debug_assert!(d <= MAX_HEAD_DIM);
    // One shared widening routine (`unpack_codes_into`) serves this row
    // path, the single-head path below, and the page-tile unpack, so the
    // per-width bit-twiddling cannot drift apart.
    let mut codes = [0.0f32; MAX_HEAD_DIM];
    quant::unpack_codes_into(b, offset, &mut codes[..d]);
    if b.bits == QuantBits::Fp16 {
        for g in 0..group {
            out[g] = crate::tensor::dot(&qs[g * d..(g + 1) * d], &codes[..d]);
        }
        return;
    }
    for g in 0..group {
        out[g] = b.zero * qsums[g]
            + b.scale * crate::tensor::dot(&qs[g * d..(g + 1) * d], &codes[..d]);
    }
}

/// Dot `q` against a row (offset..offset+d) of a packed quant block,
/// without materializing the dequantized row in memory traffic terms:
/// codes are widened into a stack buffer (a vectorizable unpack pass,
/// the CPU analog of the CUDA kernel's shared-memory dequant) and then
/// contracted with a vectorized FMA dot.
#[inline]
pub fn quant_dot_row(q: &[f32], b: &QuantBlock, offset: usize, d: usize) -> f32 {
    let qsum: f32 = q.iter().sum();
    quant_dot_row_qsum(q, qsum, b, offset, d)
}

/// `quant_dot_row` with the (row-invariant) `sum(q)` hoisted out — the
/// SpGEMV loop computes it once per query instead of once per row.
#[inline]
pub fn quant_dot_row_qsum(q: &[f32], qsum: f32, b: &QuantBlock, offset: usize, d: usize) -> f32 {
    debug_assert!(offset + d <= b.n);
    debug_assert_eq!(q.len(), d);
    debug_assert!(d <= MAX_HEAD_DIM);
    if b.bits == QuantBits::Fp16 {
        // Fused packed-f16 dot — the historical single-head Fp16 order
        // (the backend's `dot_f16` pairs with its `dot_strict` so this
        // stays bit-for-bit stable vs widened-row dots); kept distinct
        // from the group path's throughput `dot`.
        let kn = crate::tensor::kernels::active();
        return (kn.dot_f16)(q, &b.packed[2 * offset..2 * (offset + d)]);
    }
    // Integer widths: widen via the shared `unpack_codes_into` (also
    // used by the group path and the page-tile unpack — one copy of the
    // bit-twiddling), then one vectorized dot.
    let mut codes = [0.0f32; MAX_HEAD_DIM];
    quant::unpack_codes_into(b, offset, &mut codes[..d]);
    b.zero * qsum + b.scale * crate::tensor::dot(q, &codes[..d])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn mk(kv_heads: usize, d: usize, pages: usize) -> PagedKvCache {
        PagedKvCache::new(CacheConfig::new(kv_heads, d, pages))
    }

    fn rand_kv(r: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| r.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn append_and_read_back() {
        let mut c = mk(2, 8, 4);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(1);
        let mut ks = Vec::new();
        for _ in 0..20 {
            let k = rand_kv(&mut r, 16);
            let v = rand_kv(&mut r, 16);
            c.append(&mut seq, &k, &v).unwrap();
            ks.push(k);
        }
        assert_eq!(seq.len, 20);
        assert_eq!(seq.pages.len(), 2); // 20 tokens / 16 per page
        for (i, k) in ks.iter().enumerate() {
            let (page, slot) = seq.locate(i, 16);
            for h in 0..2 {
                assert_eq!(c.k_at(page, h, slot), &k[h * 8..(h + 1) * 8]);
            }
        }
    }

    #[test]
    fn out_of_pages() {
        let mut c = mk(1, 4, 1);
        let mut seq = SeqCache::default();
        for _ in 0..16 {
            c.append(&mut seq, &[0.0; 4], &[0.0; 4]).unwrap();
        }
        let e = c.append(&mut seq, &[0.0; 4], &[0.0; 4]);
        assert_eq!(e, Err(CacheError::OutOfPages));
    }

    #[test]
    fn release_returns_pages() {
        let mut c = mk(1, 4, 4);
        let mut seq = SeqCache::default();
        for _ in 0..40 {
            c.append(&mut seq, &[1.0; 4], &[1.0; 4]).unwrap();
        }
        assert_eq!(c.free_pages(), 1);
        c.release(&seq);
        assert_eq!(c.free_pages(), 4);
    }

    #[test]
    fn prefix_sharing_refcounts() {
        let mut c = mk(1, 4, 8);
        let mut a = SeqCache::default();
        for _ in 0..32 {
            c.append(&mut a, &[1.0; 4], &[1.0; 4]).unwrap();
        }
        let b = c.share(&a);
        c.release(&a);
        assert_eq!(c.free_pages(), 6); // b still holds 2 pages
        c.release(&b);
        assert_eq!(c.free_pages(), 8);
    }

    #[test]
    fn quest_minmax_bounds_scores() {
        let mut c = mk(1, 8, 8);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(5);
        for _ in 0..48 {
            let k = rand_kv(&mut r, 8);
            c.append(&mut seq, &k, &k).unwrap();
        }
        let q = rand_kv(&mut r, 8);
        // Quest upper bound per page: sum_i max(q_i*min_i, q_i*max_i)
        for (pi, &page) in seq.pages.iter().enumerate() {
            let (mn, mx) = c.minmax_at(page, 0);
            let ub: f32 = q
                .iter()
                .zip(mn.iter().zip(mx))
                .map(|(&qi, (&lo, &hi))| (qi * lo).max(qi * hi))
                .sum();
            for slot in 0..c.fill_of(page) {
                let tok = pi * 16 + slot;
                let s = c.exact_score(&seq, 0, &q, tok);
                assert!(s <= ub + 1e-4, "page {pi} slot {slot}: {s} > {ub}");
            }
        }
    }

    #[test]
    fn mirror_score_close_to_exact() {
        let mut c = mk(2, 16, 8);
        let mut seq = SeqCache::default();
        let mut r = Rng::new(9);
        for _ in 0..64 {
            let k = rand_kv(&mut r, 32);
            c.append(&mut seq, &k, &k).unwrap();
        }
        let q = rand_kv(&mut r, 16);
        for tok in [0usize, 15, 16, 63] {
            for h in 0..2 {
                let exact = c.exact_score(&seq, h, &q, tok);
                let approx = c.mirror_score(&seq, h, &q, tok);
                // INT4 with per-(page,head) scale over N(0,1) data: coarse
                // but must stay well-correlated.
                assert!(
                    (exact - approx).abs() < 1.5,
                    "tok {tok} head {h}: exact={exact} approx={approx}"
                );
            }
        }
    }

    #[test]
    fn memory_accounting() {
        let mut c = mk(1, 16, 4);
        let mut seq = SeqCache::default();
        for _ in 0..16 {
            c.append(&mut seq, &[0.5; 16], &[0.5; 16]).unwrap();
        }
        assert_eq!(c.bytes_main(), 2 * 4 * 16 * 16 * 4);
        // One full page mirrored at int4: 16*16/2 bytes + 8 overhead.
        assert_eq!(c.bytes_mirror(), 16 * 16 / 2 + 8);
    }
}
