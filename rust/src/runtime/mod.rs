//! PJRT runtime facade.
//!
//! Two interchangeable backends, selected at build time:
//! * `pjrt` feature **on** — [`pjrt`]: executes the AOT HLO artifacts
//!   (`artifacts/*.hlo.txt`, written by `python/compile/aot.py`) through
//!   the `xla` crate's PJRT CPU client. Needs the `xla` + `anyhow`
//!   dependencies (see the note in `Cargo.toml`).
//! * `pjrt` feature **off** (default) — [`stub`]: a dependency-free
//!   stand-in with the identical API. Conversion helpers work; graph
//!   execution returns a descriptive error and `runtime::available()`
//!   is `false`, which `rust/tests/hlo_parity.rs` uses to skip.
//!
//! Either way the interchange format is HLO *text* (see aot.py /
//! DESIGN.md §1): the text parser reassigns instruction ids, avoiding
//! the 64-bit-id protos that xla_extension 0.5.1 rejects.

#[cfg(feature = "pjrt")]
mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::*;

#[cfg(not(feature = "pjrt"))]
mod stub;
#[cfg(not(feature = "pjrt"))]
pub use stub::*;
