//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, written
//! once by `python/compile/aot.py`) and executes them on the request path
//! through the `xla` crate's PJRT CPU client.
//!
//! HLO *text* is the interchange format (see aot.py / DESIGN.md §1): the
//! text parser reassigns instruction ids, avoiding the 64-bit-id protos
//! that xla_extension 0.5.1 rejects. Executables are compiled on first
//! use and cached for the life of the process — Python is never invoked.

pub use xla::Literal;

use crate::tensor::Tensor;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;

/// True when the crate was built with a working PJRT backend.
pub fn available() -> bool {
    true
}

/// A loaded artifact store + executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: String,
    manifest: Json,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// Open the artifact directory (reads `manifest.json`).
    pub fn open(dir: &str) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().context("create PJRT CPU client")?;
        let manifest_path = format!("{dir}/manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("read {manifest_path} — run `make artifacts` first"))?;
        let manifest = Json::parse(&text).map_err(|e| anyhow!("{manifest_path}: {e}"))?;
        Ok(Runtime { client, dir: dir.to_string(), manifest, exes: HashMap::new() })
    }

    /// Platform string of the PJRT backend.
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Names of available graphs.
    pub fn graphs(&self) -> Vec<String> {
        match &self.manifest {
            Json::Obj(kv) => kv.iter().map(|(k, _)| k.clone()).collect(),
            _ => vec![],
        }
    }

    /// Compile (or fetch cached) an executable by manifest name.
    pub fn ensure(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let file = self
            .manifest
            .get(name)
            .and_then(|e| e.get_str("file"))
            .ok_or_else(|| anyhow!("graph '{name}' not in manifest"))?;
        let path = format!("{}/{}", self.dir, file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parse HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).with_context(|| format!("compile {name}"))?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute a graph with literal inputs; returns the decomposed output
    /// tuple as literals.
    pub fn execute(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        self.ensure(name)?;
        let exe = self.exes.get(name).unwrap();
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        Ok(result.to_tuple()?)
    }

    /// Execute and convert every output to an f32 [`Tensor`].
    pub fn execute_f32(&mut self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<Tensor>> {
        let outs = self.execute(name, inputs)?;
        outs.into_iter().map(literal_to_tensor).collect()
    }
}

/// Build an f32 literal from a tensor.
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&t.data);
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Build an i32 scalar literal.
pub fn i32_scalar(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Build an f32 scalar literal.
pub fn f32_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Build an i32 vector literal with shape.
pub fn i32_vec(xs: &[i32], shape: &[usize]) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(xs);
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(lit.reshape(&dims)?)
}

/// Convert a (non-tuple) literal to an f32 tensor.
pub fn literal_to_tensor(lit: xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>()?;
    Ok(Tensor::from_vec(data, &dims))
}

#[cfg(test)]
mod tests {
    // PJRT execution is covered by `rust/tests/hlo_parity.rs` (needs the
    // artifacts from `make artifacts`); here we only test the pure
    // conversion helpers.
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        let l = i32_scalar(42);
        assert_eq!(l.to_vec::<i32>().unwrap(), vec![42]);
        let f = f32_scalar(0.5);
        assert_eq!(f.to_vec::<f32>().unwrap(), vec![0.5]);
    }
}
