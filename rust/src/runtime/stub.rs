//! Dependency-free stand-in for the PJRT runtime (built when the `pjrt`
//! feature is off — the offline environment carries neither the `xla`
//! crate nor its xla_extension shared library).
//!
//! The API mirrors `runtime/pjrt.rs` exactly: the conversion helpers and
//! [`Literal`] are fully functional (pure Rust), while [`Runtime::open`]
//! reports that graph execution is unavailable so callers (the CLI
//! `inspect` command, `rust/tests/hlo_parity.rs`) can degrade gracefully.

use crate::tensor::Tensor;
use std::fmt;

/// True when the crate was built with a working PJRT backend.
pub fn available() -> bool {
    false
}

/// Runtime error (the stub's analog of the pjrt path's `anyhow::Error`).
#[derive(Debug, Clone)]
pub struct RuntimeError(pub String);

impl fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for RuntimeError {}

pub type Result<T> = std::result::Result<T, RuntimeError>;

fn unavailable<T>() -> Result<T> {
    Err(RuntimeError(
        "twilight was built without the `pjrt` feature; rebuild with \
         `--features pjrt` (plus the `xla` and `anyhow` dependencies, see \
         Cargo.toml) to execute HLO artifacts"
            .to_string(),
    ))
}

/// Host-side literal: shaped f32 or i32 data (what the `xla` crate's
/// `Literal` holds for the dtypes this stack uses).
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<usize> },
    I32 { data: Vec<i32>, dims: Vec<usize> },
}

impl Literal {
    /// Logical dimensions.
    pub fn dims(&self) -> &[usize] {
        match self {
            Literal::F32 { dims, .. } | Literal::I32 { dims, .. } => dims,
        }
    }

    /// The f32 payload, if this is an f32 literal.
    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            Literal::F32 { data, .. } => Some(data),
            Literal::I32 { .. } => None,
        }
    }

    /// The i32 payload, if this is an i32 literal.
    pub fn as_i32(&self) -> Option<&[i32]> {
        match self {
            Literal::I32 { data, .. } => Some(data),
            Literal::F32 { .. } => None,
        }
    }
}

/// Stub runtime: opens never succeed (no PJRT client is linked in).
pub struct Runtime {
    _private: (),
}

impl Runtime {
    /// Always fails in the stub build; the error says how to enable PJRT.
    pub fn open(_dir: &str) -> Result<Runtime> {
        unavailable()
    }

    /// Platform string of the PJRT backend.
    pub fn platform(&self) -> String {
        "unavailable".to_string()
    }

    /// Names of available graphs.
    pub fn graphs(&self) -> Vec<String> {
        Vec::new()
    }

    /// Compile (or fetch cached) an executable by manifest name.
    pub fn ensure(&mut self, _name: &str) -> Result<()> {
        unavailable()
    }

    /// Execute a graph with literal inputs.
    pub fn execute(&mut self, _name: &str, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Execute and convert every output to an f32 [`Tensor`].
    pub fn execute_f32(&mut self, _name: &str, _inputs: &[Literal]) -> Result<Vec<Tensor>> {
        unavailable()
    }
}

/// Build an f32 literal from a tensor.
pub fn tensor_to_literal(t: &Tensor) -> Result<Literal> {
    Ok(Literal::F32 { data: t.data.clone(), dims: t.shape.clone() })
}

/// Build an i32 scalar literal.
pub fn i32_scalar(x: i32) -> Literal {
    Literal::I32 { data: vec![x], dims: vec![] }
}

/// Build an f32 scalar literal.
pub fn f32_scalar(x: f32) -> Literal {
    Literal::F32 { data: vec![x], dims: vec![] }
}

/// Build an i32 vector literal with shape.
pub fn i32_vec(xs: &[i32], shape: &[usize]) -> Result<Literal> {
    if xs.len() != shape.iter().product::<usize>() {
        return Err(RuntimeError(format!(
            "i32_vec: {} elements cannot reshape to {shape:?}",
            xs.len()
        )));
    }
    Ok(Literal::I32 { data: xs.to_vec(), dims: shape.to_vec() })
}

/// Convert a (non-tuple) literal to an f32 tensor.
pub fn literal_to_tensor(lit: Literal) -> Result<Tensor> {
    match lit {
        Literal::F32 { data, dims } => Ok(Tensor::from_vec(data, &dims)),
        Literal::I32 { .. } => Err(RuntimeError("literal is i32, not f32".to_string())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]);
        let lit = tensor_to_literal(&t).unwrap();
        assert_eq!(lit.dims(), &[2, 3]);
        let back = literal_to_tensor(lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        assert_eq!(i32_scalar(42).as_i32(), Some(&[42][..]));
        assert_eq!(f32_scalar(0.5).as_f32(), Some(&[0.5f32][..]));
        assert!(i32_vec(&[1, 2, 3], &[4]).is_err());
    }

    #[test]
    fn open_reports_unavailable() {
        assert!(!available());
        let e = Runtime::open("artifacts").err().unwrap();
        assert!(e.to_string().contains("pjrt"));
    }
}
