//! The Twilight Pruner (paper §4.1–4.2): the second stage of the
//! Select-then-Prune architecture.
//!
//! Given the candidate token set chosen by a (black-box) Token Selector
//! under a conservative budget, the pruner:
//! 1. estimates attention logits for the candidates from the INT4 mirror
//!    K cache (SpGEMV, Appendix B.1);
//! 2. softmax-normalizes them (top-p requires normalized weights —
//!    Table 1's "Need Normalization?" column);
//! 3. runs top-p binary search (Algorithm 1) to keep the minimal subset
//!    with cumulative estimated mass ≥ p;
//! 4. under GQA, unions the per-query-head keep-sets across the group so
//!    the group-varlen attention kernel loads each KV row once (B.2).

pub mod topp;

use crate::attention::spgemv::estimate_scores;
use crate::kvcache::{PagedKvCache, SeqCache};

/// Pruner configuration.
#[derive(Clone, Copy, Debug)]
pub struct PrunerConfig {
    /// Cumulative-mass threshold p (paper: 0.95 LLaMA, 0.85 Longchat).
    pub p: f32,
    /// Binary-search convergence epsilon.
    pub eps: f32,
    /// Never prune below this many tokens (attention sinks + stability).
    pub min_keep: usize,
    /// Use the sort oracle instead of binary search (ablations).
    pub use_sort: bool,
}

impl Default for PrunerConfig {
    fn default() -> Self {
        PrunerConfig { p: 0.95, eps: 1e-4, min_keep: 4, use_sort: false }
    }
}

/// Outcome of pruning one query head.
#[derive(Clone, Debug)]
pub struct PruneOutcome {
    /// Kept logical token indices (subset of the candidates), ascending.
    pub kept: Vec<usize>,
    /// Estimated attention mass captured (within the candidate set).
    pub mass: f32,
    /// Estimated softmax weight (over the candidate set) of each kept
    /// token, aligned with `kept`; sums to `mass`. Empty when the pruner
    /// short-circuited (candidates ≤ min_keep) without scoring — callers
    /// that need weights must fall back to exact scores in that case.
    pub weights: Vec<f32>,
    /// Binary search iterations.
    pub iters: usize,
}

/// Scratch buffers reused across calls (hot path: no allocation).
#[derive(Default)]
pub struct PrunerScratch {
    scores: Vec<f32>,
    group_scores: Vec<f32>,
}

/// Prune `candidates` for a single query head `q` against `kv_head`'s
/// mirror cache. Returns the kept subset (minimal top-p set).
pub fn prune_head(
    cfg: &PrunerConfig,
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    q: &[f32],
    candidates: &[usize],
    scratch: &mut PrunerScratch,
) -> PruneOutcome {
    let n = candidates.len();
    if n <= cfg.min_keep {
        return PruneOutcome { kept: candidates.to_vec(), mass: 1.0, weights: Vec::new(), iters: 0 };
    }
    scratch.scores.resize(n, 0.0);
    // (1) SpGEMV estimation from the INT4 mirror.
    estimate_scores(cache, seq, kv_head, q, candidates, &mut scratch.scores);
    // (2) scale + softmax over the candidate subset.
    let s = crate::attention::scale(q.len());
    for x in scratch.scores.iter_mut() {
        *x *= s;
    }
    crate::tensor::softmax_inplace(&mut scratch.scores);
    // (3) top-p, (4) min_keep floor with truthful mass.
    let r = if cfg.use_sort {
        topp::topp_sort(&scratch.scores, cfg.p)
    } else {
        topp::topp_binary_search(&scratch.scores, cfg.p, cfg.eps)
    };
    let (kept, mass, weights) = floor_min_keep(&scratch.scores, candidates, &r, cfg.min_keep);
    PruneOutcome { kept, mass, weights, iters: r.iters }
}

/// Apply the `min_keep` floor to a top-p result: when fewer than
/// `min_keep` tokens survived, keep the `min_keep` top-scoring candidates
/// instead — and recompute the captured mass over the floored set. The
/// governor steers on `PruneOutcome::mass`, so reporting the pre-floor
/// mass would understate what the kept set actually captures exactly when
/// the floor is active (peaked heads), biasing the controller. Also
/// returns each kept token's estimated softmax weight (aligned with the
/// kept list) so downstream consumers — the SnapKV/H2O observation
/// feedback — never have to re-score what the pruner already scored.
fn floor_min_keep(
    scores: &[f32],
    candidates: &[usize],
    r: &topp::ToppResult,
    min_keep: usize,
) -> (Vec<usize>, f32, Vec<f32>) {
    if r.indices.len() >= min_keep {
        let kept = r.indices.iter().map(|&i| candidates[i]).collect();
        let weights = r.indices.iter().map(|&i| scores[i]).collect();
        return (kept, r.mass, weights);
    }
    let n = scores.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        scores[b].partial_cmp(&scores[a]).unwrap_or(std::cmp::Ordering::Equal)
    });
    order.truncate(min_keep.min(n));
    let mass = order.iter().map(|&i| scores[i]).sum();
    // Candidates are ascending, so sorting the score-indices restores
    // ascending kept order with weights still aligned.
    order.sort_unstable();
    let kept = order.iter().map(|&i| candidates[i]).collect();
    let weights = order.iter().map(|&i| scores[i]).collect();
    (kept, mass, weights)
}

/// Prune for a GQA group: `qs` is `[group * d]` query heads sharing
/// `kv_head`. Per-head top-p keep-sets are unioned (B.2) so the attention
/// kernel loads each KV row once per group. Returns the union (ascending)
/// plus per-head outcomes for budget accounting.
#[allow(clippy::too_many_arguments)]
pub fn prune_group(
    cfg: &PrunerConfig,
    cache: &PagedKvCache,
    seq: &SeqCache,
    kv_head: usize,
    qs: &[f32],
    group: usize,
    candidates: &[usize],
    scratch: &mut PrunerScratch,
) -> (Vec<usize>, Vec<PruneOutcome>) {
    let d = qs.len() / group;
    let n = candidates.len();
    if n <= cfg.min_keep {
        let out =
            PruneOutcome { kept: candidates.to_vec(), mass: 1.0, weights: Vec::new(), iters: 0 };
        return (candidates.to_vec(), vec![out; group]);
    }
    // One SpGEMV pass for the whole group (codes unpacked once per row —
    // §Perf); then per-head softmax + top-p on the shared score matrix.
    scratch.group_scores.resize(group * n, 0.0);
    crate::attention::spgemv::estimate_scores_group(
        cache, seq, kv_head, qs, group, candidates, &mut scratch.group_scores,
    );
    let s = crate::attention::scale(d);
    let mut outcomes = Vec::with_capacity(group);
    let mut union: Vec<usize> = Vec::new();
    for g in 0..group {
        let row = &mut scratch.group_scores[g * n..(g + 1) * n];
        for x in row.iter_mut() {
            *x *= s;
        }
        crate::tensor::softmax_inplace(row);
        let r = if cfg.use_sort {
            topp::topp_sort(row, cfg.p)
        } else {
            topp::topp_binary_search(row, cfg.p, cfg.eps)
        };
        let (kept, mass, weights) = floor_min_keep(row, candidates, &r, cfg.min_keep);
        union.extend_from_slice(&kept);
        outcomes.push(PruneOutcome { kept, mass, weights, iters: r.iters });
    }
    union.sort_unstable();
    union.dedup();
    (union, outcomes)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::attention::testutil::{random_cache, random_q};

    #[test]
    fn prune_keeps_subset_with_mass() {
        let (cache, seq) = random_cache(41, 1, 32, 256);
        let q = random_q(42, 32);
        let candidates: Vec<usize> = (0..256).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.9, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert!(!out.kept.is_empty());
        assert!(out.kept.len() <= 256);
        assert!(out.mass >= 0.9 - 1e-3);
        assert!(out.kept.windows(2).all(|w| w[0] < w[1]), "sorted unique");
        assert!(out.kept.iter().all(|t| candidates.contains(t)));
    }

    #[test]
    fn focused_query_prunes_harder() {
        // Make a cache where one key matches q exactly: focused attention.
        let d = 32;
        let mut cache = crate::kvcache::PagedKvCache::new(crate::kvcache::CacheConfig::new(1, d, 32));
        let mut seq = crate::kvcache::SeqCache::default();
        let mut r = crate::util::rng::Rng::new(7);
        let q = random_q(8, d);
        for i in 0..256 {
            let k: Vec<f32> = if i == 100 {
                q.iter().map(|x| x * 4.0).collect() // strong match
            } else {
                (0..d).map(|_| r.normal_f32(0.0, 0.3)).collect()
            };
            cache.append(&mut seq, &k, &k).unwrap();
        }
        let candidates: Vec<usize> = (0..256).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.9, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert!(out.kept.contains(&100), "must keep the matching token");
        assert!(out.kept.len() <= 16, "focused head should prune hard: {}", out.kept.len());
    }

    #[test]
    fn min_keep_floor() {
        let (cache, seq) = random_cache(43, 1, 16, 64);
        let q = random_q(44, 16);
        let candidates: Vec<usize> = (0..64).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert!(out.kept.len() >= 8);
    }

    #[test]
    fn floored_mass_recomputed_over_kept_set() {
        // With p≈0 the raw top-p set is a single token; the min_keep floor
        // widens it to 8, and the reported mass must cover all 8 (strictly
        // more than the single-token mass — softmax weights are positive).
        let (cache, seq) = random_cache(43, 1, 16, 64);
        let q = random_q(44, 16);
        let candidates: Vec<usize> = (0..64).collect();
        let mut scratch = PrunerScratch::default();
        let tiny = prune_head(
            &PrunerConfig { p: 0.0001, min_keep: 1, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        let floored = prune_head(
            &PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        assert_eq!(floored.kept.len(), 8);
        assert!(floored.kept.windows(2).all(|w| w[0] < w[1]));
        assert!(
            floored.mass > tiny.mass,
            "floored mass {} must exceed pre-floor mass {}",
            floored.mass,
            tiny.mass
        );
        assert!(floored.mass <= 1.0 + 1e-5);
        // The group path shares the same floor helper.
        let (_, outs) = prune_group(
            &PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() },
            &cache, &seq, 0, &q, 1, &candidates, &mut scratch,
        );
        assert_eq!(outs[0].kept, floored.kept);
        assert!((outs[0].mass - floored.mass).abs() < 1e-5);
    }

    #[test]
    fn outcome_weights_align_with_kept() {
        let (cache, seq) = random_cache(41, 1, 32, 256);
        let q = random_q(42, 32);
        let candidates: Vec<usize> = (0..256).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.9, ..Default::default() };
        let out = prune_head(&cfg, &cache, &seq, 0, &q, &candidates, &mut scratch);
        assert_eq!(out.weights.len(), out.kept.len());
        let sum: f32 = out.weights.iter().sum();
        assert!((sum - out.mass).abs() < 1e-4, "weights sum {sum} vs mass {}", out.mass);
        assert!(out.weights.iter().all(|w| *w > 0.0));
        // The floored path must stay aligned too.
        let floored = prune_head(
            &PrunerConfig { p: 0.0001, min_keep: 8, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        assert_eq!(floored.weights.len(), floored.kept.len());
        let fsum: f32 = floored.weights.iter().sum();
        assert!((fsum - floored.mass).abs() < 1e-4);
        // Short-circuit path: nothing was scored, so weights are empty.
        let few: Vec<usize> = (0..3).collect();
        let out2 = prune_head(&cfg, &cache, &seq, 0, &q, &few, &mut scratch);
        assert!(out2.weights.is_empty());
        assert_eq!(out2.kept, few);
    }

    #[test]
    fn group_union_covers_heads() {
        let (cache, seq) = random_cache(45, 1, 16, 128);
        let group = 4;
        let mut qs = Vec::new();
        for g in 0..group {
            qs.extend(random_q(50 + g as u64, 16));
        }
        let candidates: Vec<usize> = (0..128).collect();
        let mut scratch = PrunerScratch::default();
        let cfg = PrunerConfig { p: 0.8, ..Default::default() };
        let (union, outs) = prune_group(&cfg, &cache, &seq, 0, &qs, group, &candidates, &mut scratch);
        assert_eq!(outs.len(), group);
        for o in &outs {
            for t in &o.kept {
                assert!(union.binary_search(t).is_ok(), "union must contain every head's keeps");
            }
        }
        assert!(union.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn higher_p_keeps_more() {
        let (cache, seq) = random_cache(47, 1, 32, 512);
        let q = random_q(48, 32);
        let candidates: Vec<usize> = (0..512).collect();
        let mut scratch = PrunerScratch::default();
        let lo = prune_head(
            &PrunerConfig { p: 0.5, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        let hi = prune_head(
            &PrunerConfig { p: 0.99, ..Default::default() },
            &cache, &seq, 0, &q, &candidates, &mut scratch,
        );
        assert!(hi.kept.len() >= lo.kept.len());
    }
}
